//! `tb-ρ` — the turbocharged nested mini-batch algorithm (paper
//! Algorithm 9; ρ = ∞ form is Algorithm 11). This is the paper's
//! headline contribution: grow-batch nesting makes triangle-inequality
//! bounds pay off inside a mini-batch scheme.
//!
//! Two execution strategies, producing identical assignments:
//!
//! * **Point-step** (native): Algorithm 9's inner loop verbatim —
//!   per (i, j) bound tests gate individual distance computations
//!   ([`bounds::tb_point_step`]). Best on CPUs, exactly the paper.
//! * **Tile-screen** (hardware-adapted, used with the XLA engine): a
//!   cheap O(k) per-point screen splits the seen prefix into *clean*
//!   points (assignment provably unchanged, zero distance work) and
//!   *dirty* points, which are gathered into dense tiles for the
//!   Pallas/XLA `distmat` artifact; their full bound rows refresh from
//!   the tile result. See DESIGN.md §Hardware-Adaptation.

use crate::config::Rho;
use crate::coordinator::shard::chunk_ranges;
use crate::kmeans::assign::{Sel, EXPONION_MIN_K, EXPONION_SPARSE_MAX_D, NEIGH_MAX_BYTES};
use crate::kmeans::bounds::{self, BoundStore};
use crate::kmeans::controller::{self, GrowthPolicy};
use crate::kmeans::state::{batch_mse, Assignments, Centroids, SuffStats, UNASSIGNED};
use crate::kmeans::{Clusterer, Ctx, NestedState, RoundInfo};
use crate::linalg::neighbours::{NeighbourCache, NeighbourRows};
use crate::linalg::simd;

pub struct TurboBatch {
    pub(crate) cent: Centroids,
    pub(crate) stats: SuffStats,
    pub(crate) assign: Assignments,
    bounds: BoundStore,
    /// Exponion neighbour cache for first fills of newly ingested
    /// points at serving-scale k (revision-keyed; Auto-gated).
    neigh: NeighbourCache,
    /// Tile mode: decayed upper bound u(i) ≥ ‖x_i − c_{a(i)}‖.
    upper: Vec<f32>,
    n: usize,
    pub b_prev: usize,
    pub b: usize,
    rho: Rho,
    policy: GrowthPolicy,
    tile_mode: bool,
    fixed_point: bool,
    pub batch_history: Vec<usize>,
}

/// Cap on points per `dist_rows` dispatch in tile mode (bounds memory
/// traffic and keeps per-call buffers ≤ ~8k × k floats).
const TILE_DISPATCH: usize = 8192;

impl TurboBatch {
    pub fn new(cent: Centroids, n: usize, b0: usize, rho: Rho, tile_mode: bool) -> Self {
        let k = cent.k();
        let d = cent.d();
        Self {
            cent,
            stats: SuffStats::zeros(k, d),
            assign: Assignments::new(n),
            bounds: BoundStore::new(k),
            neigh: NeighbourCache::default(),
            upper: Vec::new(),
            n,
            b_prev: 0,
            b: b0.min(n).max(1),
            rho,
            policy: GrowthPolicy::Double,
            tile_mode,
            fixed_point: false,
            batch_history: vec![],
        }
    }

    /// Paper §5 future-work: alternative batch-growth laws (ablation).
    pub fn with_policy(mut self, policy: GrowthPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Rebuild mid-run from exported state (`serve` resume path).
    ///
    /// Bounds are not serialised: fresh zero rows are always-valid lower
    /// bounds, and the snapshotted `dist2` (computed against the
    /// pre-update centroid positions) plus the stored displacement `p`
    /// reconstruct valid upper bounds — the first resumed round spends
    /// extra distance computations re-tightening but the assignment
    /// sequence, statistics and centroid trajectory are unchanged.
    pub fn resume(st: NestedState, rho: Rho, tile_mode: bool) -> Self {
        let k = st.cent.k();
        assert_eq!(st.stats.k, k, "stats k mismatch");
        assert_eq!(st.stats.d, st.cent.d(), "stats d mismatch");
        assert_eq!(st.assign.label.len(), st.n, "assignments length != n");
        assert!(st.b_prev <= st.b && st.b <= st.n, "bad batch cursor");
        let upper: Vec<f32> = st.assign.dist2[..st.b_prev]
            .iter()
            .map(|d2| d2.max(0.0).sqrt())
            .collect();
        Self {
            cent: st.cent,
            stats: st.stats,
            assign: st.assign,
            bounds: BoundStore::new(k),
            neigh: NeighbourCache::default(),
            upper,
            n: st.n,
            b_prev: st.b_prev,
            b: st.b.max(1),
            rho,
            policy: GrowthPolicy::Double,
            tile_mode,
            fixed_point: false,
            batch_history: vec![],
        }
    }

    /// Point-step pass over the seen prefix: returns
    /// (delta, changed, calcs, skips).
    fn seen_pointstep(&mut self, ctx: &mut Ctx) -> (SuffStats, u64, u64, u64) {
        let b_o = self.b_prev;
        let k = self.cent.k();
        let d = self.cent.d();
        let ranges = chunk_ranges(b_o, ctx.pool.threads, 256);
        let lb_views = self.bounds.split_rows(&ranges);
        // split label/dist2 the same way
        let mut lbl_rest: &mut [u32] = &mut self.assign.label[..b_o];
        let mut d2_rest: &mut [f32] = &mut self.assign.dist2[..b_o];
        let mut jobs = Vec::with_capacity(ranges.len());
        for (r, lbv) in ranges.iter().cloned().zip(lb_views) {
            let (lh, lt) = lbl_rest.split_at_mut(r.len());
            let (dh, dt) = d2_rest.split_at_mut(r.len());
            lbl_rest = lt;
            d2_rest = dt;
            jobs.push((r, lbv, lh, dh));
        }
        let data = ctx.data;
        let cent = &self.cent;
        let work = |r: std::ops::Range<usize>,
                    lbv: &mut [f32],
                    lh: &mut [u32],
                    dh: &mut [f32]|
         -> (SuffStats, u64, u64, u64) {
            let mut delta = SuffStats::zeros(k, d);
            let (mut changed, mut calcs, mut skips) = (0u64, 0u64, 0u64);
            for (slot, i) in r.enumerate() {
                let old = lh[slot];
                let out = bounds::tb_point_step(
                    data,
                    i,
                    cent,
                    &mut lbv[slot * k..(slot + 1) * k],
                    old,
                );
                delta.reassign_point(data, i, old, out.label, out.d2);
                changed += u64::from(old != out.label);
                calcs += out.dist_calcs;
                skips += out.bound_skips;
                lh[slot] = out.label;
                dh[slot] = out.d2;
            }
            (delta, changed, calcs, skips)
        };
        let results: Vec<(SuffStats, u64, u64, u64)> = ctx
            .pool
            .run_jobs(jobs, |_, (r, lbv, lh, dh)| work(r, lbv, lh, dh));
        let mut delta = SuffStats::zeros(k, d);
        let (mut changed, mut calcs, mut skips) = (0u64, 0u64, 0u64);
        for (dd, ch, ca, sk) in results {
            crate::coordinator::merge::Mergeable::merge(&mut delta, dd);
            changed += ch;
            calcs += ca;
            skips += sk;
        }
        (delta, changed, calcs, skips)
    }

    /// Tile-screen pass over the seen prefix.
    fn seen_tilescreen(&mut self, ctx: &mut Ctx) -> (SuffStats, u64, u64, u64) {
        let b_o = self.b_prev;
        let k = self.cent.k();
        let d = self.cent.d();
        // 1. decay uppers + screen (sharded)
        let ranges = chunk_ranges(b_o, ctx.pool.threads, 1024);
        let lb_views = self.bounds.split_rows(&ranges);
        let mut up_rest: &mut [f32] = &mut self.upper[..b_o];
        let mut jobs = Vec::with_capacity(ranges.len());
        for (r, lbv) in ranges.iter().cloned().zip(lb_views) {
            let (uh, ut) = up_rest.split_at_mut(r.len());
            up_rest = ut;
            jobs.push((r, lbv, uh));
        }
        let labels = &self.assign.label;
        let cent = &self.cent;
        let screen_work = |r: std::ops::Range<usize>,
                           lbv: &mut [f32],
                           uh: &mut [f32]|
         -> Vec<usize> {
            let mut dirty = Vec::new();
            for (slot, i) in r.enumerate() {
                let a = labels[i];
                uh[slot] += cent.p[a as usize];
                if bounds::screen(
                    &mut lbv[slot * k..(slot + 1) * k],
                    &cent.p,
                    a,
                    uh[slot],
                ) {
                    dirty.push(i);
                }
            }
            dirty
        };
        let dirty_parts: Vec<Vec<usize>> = ctx
            .pool
            .run_jobs(jobs, |_, (r, lbv, uh)| screen_work(r, lbv, uh));
        let dirty: Vec<usize> = dirty_parts.into_iter().flatten().collect();
        let clean = (b_o - dirty.len()) as u64;

        // 2. gathered dense recompute for dirty points, in dispatch-size
        //    blocks, through the engine's distmat path
        let mut delta = SuffStats::zeros(k, d);
        let mut changed = 0u64;
        let mut calcs = 0u64;
        let mut buf = vec![0f32; TILE_DISPATCH.min(dirty.len().max(1)) * k];
        for block in dirty.chunks(TILE_DISPATCH) {
            let need = block.len() * k;
            calcs += ctx.engine.dist_rows(
                ctx.data,
                Sel::List(block),
                &self.cent,
                &ctx.pool,
                &mut buf[..need],
            );
            for (t, &i) in block.iter().enumerate() {
                let (j, d2) = bounds::refresh_from_distrow(
                    self.bounds.row_mut(i),
                    &buf[t * k..(t + 1) * k],
                );
                let old = self.assign.label[i];
                delta.reassign_point(ctx.data, i, old, j, d2);
                changed += u64::from(old != j);
                self.assign.label[i] = j;
                self.assign.dist2[i] = d2;
                self.upper[i] = d2.sqrt();
            }
        }
        (delta, changed, calcs, clean * k as u64)
    }

    /// Ingest new points [b_o, b): full k distances each, bounds filled.
    fn ingest_new(&mut self, ctx: &mut Ctx) -> (SuffStats, u64) {
        let (b_o, b) = (self.b_prev, self.b);
        let k = self.cent.k();
        let d = self.cent.d();
        if b <= b_o {
            return (SuffStats::zeros(k, d), 0);
        }
        let count = b - b_o;
        let ranges = chunk_ranges(count, ctx.pool.threads, 256);
        // bound rows for the new window: global rows b_o..b
        let all_rows = self.bounds.split_rows(
            &[(0..b_o), (b_o..b)].map(|r| r).to_vec(),
        );
        let new_rows = all_rows.into_iter().nth(1).unwrap();
        let mut lbl_rest: &mut [u32] = &mut self.assign.label[b_o..b];
        let mut d2_rest: &mut [f32] = &mut self.assign.dist2[b_o..b];
        let mut up_rest: &mut [f32] = &mut self.upper[b_o..b];
        let mut lb_rest: &mut [f32] = new_rows;
        let mut jobs = Vec::with_capacity(ranges.len());
        for r in ranges.iter().cloned() {
            let (lh, lt) = lbl_rest.split_at_mut(r.len());
            let (dh, dt) = d2_rest.split_at_mut(r.len());
            let (uh, ut) = up_rest.split_at_mut(r.len());
            let (bh, bt) = lb_rest.split_at_mut(r.len() * k);
            lbl_rest = lt;
            d2_rest = dt;
            up_rest = ut;
            lb_rest = bt;
            jobs.push((r, lh, dh, uh, bh));
        }
        let data = ctx.data;
        let cent = &self.cent;
        // Serving-scale k: fill new points through the exponion ball so
        // each costs far fewer than k distances. Same gates as the
        // assign engine's Auto strategy; the revision-keyed cache makes
        // repeated ingests between centroid updates free.
        let ni = (k >= EXPONION_MIN_K
            && (!data.is_sparse() || d <= EXPONION_SPARSE_MAX_D)
            && NeighbourRows::bytes_for(k) <= NEIGH_MAX_BYTES)
            .then(|| self.neigh.get(cent, simd::tier()));
        let ni = ni.as_deref();
        let work = |r: std::ops::Range<usize>,
                    lh: &mut [u32],
                    dh: &mut [f32],
                    uh: &mut [f32],
                    bh: &mut [f32]|
         -> (SuffStats, u64) {
            let mut delta = SuffStats::zeros(k, d);
            let mut calcs = 0u64;
            for (slot, off) in r.enumerate() {
                let i = b_o + off;
                let row = &mut bh[slot * k..(slot + 1) * k];
                let out = match ni {
                    Some(ni) => {
                        bounds::full_assign_fill_pruned(data, i, cent, ni, row)
                    }
                    None => bounds::full_assign_fill(data, i, cent, row),
                };
                calcs += out.dist_calcs;
                delta.add_point(data, i, out.label, out.d2);
                lh[slot] = out.label;
                dh[slot] = out.d2;
                uh[slot] = out.d2.sqrt();
            }
            (delta, calcs)
        };
        let parts: Vec<(SuffStats, u64)> = ctx
            .pool
            .run_jobs(jobs, |_, (r, lh, dh, uh, bh)| work(r, lh, dh, uh, bh));
        let mut delta = SuffStats::zeros(k, d);
        let mut calcs = 0u64;
        for (p, c) in parts {
            crate::coordinator::merge::Mergeable::merge(&mut delta, p);
            calcs += c;
        }
        (delta, calcs)
    }

    #[cfg(test)]
    pub fn stats_drift(&self, data: &crate::data::Data) -> f64 {
        let fresh = SuffStats::rebuild(
            data,
            self.cent.k(),
            0..self.b_prev,
            &self.assign.label,
            &self.assign.dist2,
        );
        self.stats.max_abs_diff(&fresh)
    }

    #[cfg(test)]
    pub fn bound_row(&self, i: usize) -> &[f32] {
        self.bounds.row(i)
    }
}

impl Clusterer for TurboBatch {
    fn round(&mut self, ctx: &mut Ctx) -> RoundInfo {
        let b = self.b;
        self.batch_history.push(b);
        self.bounds.grow_to(b);
        self.upper.resize(b, 0.0);

        // seen prefix
        let (delta_seen, changed, calcs_seen, skips) = if self.b_prev == 0 {
            (SuffStats::zeros(self.cent.k(), self.cent.d()), 0, 0, 0)
        } else if self.tile_mode {
            self.seen_tilescreen(ctx)
        } else {
            self.seen_pointstep(ctx)
        };
        crate::coordinator::merge::Mergeable::merge(&mut self.stats, delta_seen);

        // new window
        let (delta_new, calcs_new) = self.ingest_new(ctx);
        crate::coordinator::merge::Mergeable::merge(&mut self.stats, delta_new);

        // centroid update + controller
        self.stats.update_centroids(&mut self.cent);
        let decision = controller::decide(self.rho, &self.stats, &self.cent);
        let b_o = self.b_prev;
        self.b_prev = b;
        self.b = controller::grow(b, self.n, decision, self.policy);
        self.fixed_point =
            b_o == self.n && changed == 0 && self.cent.max_p() == 0.0;

        RoundInfo {
            dist_calcs: calcs_seen + calcs_new,
            bound_skips: skips,
            changed,
            batch: b,
            train_mse: batch_mse(&self.stats),
        }
    }

    fn centroids(&self) -> &Centroids {
        &self.cent
    }

    fn converged(&self) -> bool {
        self.fixed_point
    }

    fn name(&self) -> String {
        format!("tb-{}", self.rho.label())
    }

    fn export_state(&self) -> Option<NestedState> {
        Some(NestedState {
            cent: self.cent.clone(),
            stats: self.stats.clone(),
            assign: self.assign.clone(),
            b_prev: self.b_prev,
            b: self.b,
            n: self.n,
        })
    }

    fn extend_data(&mut self, new_n: usize) -> bool {
        if new_n < self.n {
            return false;
        }
        self.assign.label.resize(new_n, UNASSIGNED);
        self.assign.dist2.resize(new_n, f32::INFINITY);
        self.n = new_n;
        if new_n > self.b_prev {
            self.fixed_point = false;
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian::GaussianMixture;
    use crate::kmeans::assign::NativeEngine;
    use crate::kmeans::growbatch::GrowBatch;
    use crate::kmeans::init;
    use crate::util::rng::Pcg64;

    /// Shared engine for test contexts (Ctx borrows it for 'static).
    fn test_engine() -> &'static NativeEngine {
        static E: std::sync::OnceLock<NativeEngine> = std::sync::OnceLock::new();
        E.get_or_init(NativeEngine::default)
    }

    fn ctx(data: &crate::data::Data) -> Ctx<'_> {
        Ctx {
            data,
            engine: test_engine(),
            pool: crate::coordinator::Pool::new(2),
            rng: Pcg64::new(4, 4),
        }
    }

    #[test]
    fn tb_matches_gb_centroid_trajectory() {
        // Bounds must not change the computed clustering: tb-∞ and gb-∞
        // perform identical assignments, hence identical centroids.
        let data = GaussianMixture::default_spec(4, 6).generate(800, 2);
        let mut tb = TurboBatch::new(
            init::first_k(&data, 4), 800, 64, Rho::Infinite, false);
        let mut gb =
            GrowBatch::new(init::first_k(&data, 4), 800, 64, Rho::Infinite);
        let mut c1 = ctx(&data);
        let mut c2 = ctx(&data);
        for round in 0..15 {
            tb.round(&mut c1);
            gb.round(&mut c2);
            assert_eq!(tb.b, gb.b, "round {round}: batch sizes diverged");
            for j in 0..4 {
                for t in 0..6 {
                    let a = tb.cent.c.row(j)[t];
                    let b = gb.cent.c.row(j)[t];
                    assert!(
                        (a - b).abs() < 1e-3 * (1.0 + b.abs()),
                        "round {round} centroid {j},{t}: tb={a} gb={b}"
                    );
                }
            }
        }
    }

    #[test]
    fn tile_mode_matches_pointstep_mode() {
        let data = GaussianMixture::default_spec(3, 5).generate(600, 8);
        let mut a = TurboBatch::new(
            init::first_k(&data, 3), 600, 50, Rho::Infinite, false);
        let mut b = TurboBatch::new(
            init::first_k(&data, 3), 600, 50, Rho::Infinite, true);
        let mut c1 = ctx(&data);
        let mut c2 = ctx(&data);
        for round in 0..12 {
            a.round(&mut c1);
            b.round(&mut c2);
            assert_eq!(
                a.assign.label[..a.b_prev],
                b.assign.label[..b.b_prev],
                "round {round}: assignments diverged"
            );
            assert_eq!(a.b, b.b, "round {round}: batch size diverged");
        }
    }

    #[test]
    fn bounds_eliminate_work_as_convergence_nears() {
        let data = GaussianMixture::default_spec(5, 8).generate(1000, 3);
        let mut tb = TurboBatch::new(
            init::first_k(&data, 5), 1000, 100, Rho::Infinite, false);
        let mut c = ctx(&data);
        let mut last_skip_frac = 0.0;
        for round in 0..20 {
            let info = tb.round(&mut c);
            let possible =
                (tb.b_prev.max(1) * (5 - 1)) as f64;
            last_skip_frac = info.bound_skips as f64 / possible.max(1.0);
            let _ = round;
        }
        assert!(
            last_skip_frac > 0.5,
            "bounds should skip most work near convergence: {last_skip_frac}"
        );
    }

    #[test]
    fn stats_exact_under_bounded_reassignment() {
        let data = GaussianMixture { k: 3, d: 4, center_spread: 2.0, noise: 1.5, weights: vec![] }
            .generate(400, 10);
        let mut tb = TurboBatch::new(
            init::first_k(&data, 3), 400, 32, Rho::Finite(100.0), false);
        let mut c = ctx(&data);
        for round in 0..15 {
            tb.round(&mut c);
            let drift = tb.stats_drift(&data);
            assert!(drift < 1e-5, "round {round}: drift {drift}");
        }
    }

    #[test]
    fn export_resume_continues_bit_exactly() {
        // A paused-and-resumed tb run must retrace the uninterrupted one
        // exactly, despite the bounds being rebuilt from scratch.
        let data = GaussianMixture::default_spec(4, 6).generate(900, 12);
        let mut full = TurboBatch::new(
            init::first_k(&data, 4), 900, 64, Rho::Infinite, false);
        let mut half = TurboBatch::new(
            init::first_k(&data, 4), 900, 64, Rho::Infinite, false);
        let mut c = ctx(&data);
        for _ in 0..4 {
            full.round(&mut c);
            half.round(&mut c);
        }
        let st = Clusterer::export_state(&half).unwrap();
        let mut resumed = TurboBatch::resume(st, Rho::Infinite, false);
        for _ in 0..4 {
            full.round(&mut c);
            resumed.round(&mut c);
        }
        assert_eq!(full.cent.c.data, resumed.cent.c.data);
        assert_eq!(full.b, resumed.b);
        assert_eq!(full.assign.label, resumed.assign.label);
        assert_eq!(full.assign.dist2, resumed.assign.dist2);
        assert_eq!(full.stats.v, resumed.stats.v);
    }

    #[test]
    fn converges_to_lloyd_fixed_point() {
        let data = GaussianMixture::default_spec(3, 4).generate(300, 6);
        let mut tb = TurboBatch::new(
            init::first_k(&data, 3), 300, 30, Rho::Infinite, false);
        let mut c = ctx(&data);
        for _ in 0..200 {
            tb.round(&mut c);
            if tb.converged() {
                break;
            }
        }
        assert!(tb.converged());
        let mut cent = tb.cent.clone();
        let mut labels = vec![0u32; 300];
        let before = crate::kmeans::state::exact_mse(&data, &cent);
        crate::kmeans::lloyd::reference_round(&data, &mut cent, &mut labels);
        let after = crate::kmeans::state::exact_mse(&data, &cent);
        assert!((before - after).abs() < 1e-9 * (1.0 + before));
    }
}
