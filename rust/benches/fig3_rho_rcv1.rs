//! Bench F3 — regenerates supplementary Figure 3: the ρ sweep of
//! Figure 2 on the sparse RCV1 dataset. Same expected shape: tb-ρ wants
//! very large ρ; gb-ρ is ambiguous.

use nmbkm::experiments::{common::ExpOpts, rho_sweep};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = ExpOpts::from_args(&args);
    println!(
        "[fig3] scale={:?} seeds={} budget={}s/run",
        opts.scale, opts.seeds, opts.seconds
    );
    rho_sweep::run(3, &opts).expect("fig3 failed");
}
