//! Follower mode: a read-only mirror that tails a primary's WAL over
//! the binary-framed protocol and stays **bit-identical** to it.
//!
//! The follower bootstraps by shipping every model's snapshot (with the
//! last log seq each covers), resets its local log to the primary's
//! cursor and epoch, and then polls `wal-fetch` — appending the
//! primary's raw record bytes to its own log verbatim and replaying
//! them through the same [`wal::apply_record`] path crash recovery
//! uses. Determinism does the rest: identical bytes in, identical
//! session state out, so the follower's predicts and snapshots match
//! the primary's bit for bit (test-enforced).
//!
//! Failure handling:
//! * Disconnects and transport errors reconnect with exponential
//!   backoff (100 ms doubling to 5 s).
//! * A `reset:true` fetch answer (our cursor predates the primary's
//!   oldest retained segment — it checkpointed past us) triggers a
//!   fresh bootstrap.
//! * `promote` (the JSONL op, or `nmbkm promote`) bumps the local
//!   epoch and clears follower mode; the tail loop exits on its next
//!   iteration, and the epoch fence in [`Wal::append_raw`] rejects any
//!   batch still arriving from the stale primary's lower epoch.

use crate::obs;
use crate::serve::frame;
use crate::serve::registry::ModelRegistry;
use crate::serve::session::OnlineSession;
use crate::serve::snapshot::Snapshot;
use crate::serve::wal::{self, u64_field, u64_json, Wal};
use crate::util::json::{self, Json};
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Poll interval while the primary has nothing new.
const POLL: Duration = Duration::from_millis(200);
/// Reconnect backoff bounds.
const BACKOFF_MIN: Duration = Duration::from_millis(100);
const BACKOFF_MAX: Duration = Duration::from_secs(5);
/// Per-call socket timeouts: a wedged primary must not pin the tail
/// thread forever (the loop reconnects instead).
const CALL_TIMEOUT: Duration = Duration::from_secs(10);

/// A blocking request/response client for the binary framing: magic
/// byte on connect, then one frame out / one frame in per call.
pub struct FrameClient {
    stream: TcpStream,
}

impl FrameClient {
    pub fn connect(addr: &str) -> Result<FrameClient> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to primary {addr}"))?;
        stream.set_read_timeout(Some(CALL_TIMEOUT))?;
        stream.set_write_timeout(Some(CALL_TIMEOUT))?;
        stream.set_nodelay(true).ok();
        let mut c = FrameClient { stream };
        use std::io::Write;
        c.stream.write_all(&[frame::MAGIC]).with_context(|| {
            format!("sending binary-mode magic to {addr}")
        })?;
        Ok(c)
    }

    /// One round trip. The primary must be serving with `--binary`
    /// (otherwise the magic byte already got a JSONL error and this
    /// read fails to frame-decode — surfaced as a connect-level error).
    pub fn call(&mut self, header: &Json, body: &[u8]) -> Result<(Json, Vec<u8>)> {
        frame::write_frame(&mut self.stream, header, body)?;
        frame::read_frame(&mut self.stream)?
            .ok_or_else(|| anyhow!("primary closed the connection mid-call"))
    }

    /// `call` + `ok:true` check (errors carry the primary's message).
    fn call_ok(&mut self, header: &Json, body: &[u8]) -> Result<(Json, Vec<u8>)> {
        let (h, b) = self.call(header, body)?;
        if h.get("ok").and_then(Json::as_bool) != Some(true) {
            let msg = h
                .get("error")
                .and_then(Json::as_str)
                .unwrap_or("primary answered ok:false");
            bail!("primary: {msg}");
        }
        Ok((h, b))
    }
}

struct ReplicaMetrics {
    applied: Arc<obs::Counter>,
    reconnects: Arc<obs::Counter>,
    bootstraps: Arc<obs::Counter>,
    lag: Arc<obs::Gauge>,
}

fn metrics() -> ReplicaMetrics {
    let reg = obs::registry();
    ReplicaMetrics {
        applied: reg.counter("nmbkm_replica_applied_total", &[]),
        reconnects: reg.counter("nmbkm_replica_reconnects_total", &[]),
        bootstraps: reg.counter("nmbkm_replica_bootstraps_total", &[]),
        lag: reg.gauge("nmbkm_replica_lag_records", &[]),
    }
}

/// Run the follower loop on a new thread until promoted or `stop` is
/// set. The registry must already have its WAL attached and follower
/// mode set.
pub fn spawn_follower(
    registry: Arc<ModelRegistry>,
    primary: String,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name("nmbkm-follower".into())
        .spawn(move || run_follower(&registry, &primary, &stop))
        .expect("spawning the follower thread")
}

/// The follower loop body: reconnect-with-backoff around
/// [`tail_primary`]. Returns when promoted or stopped.
pub fn run_follower(registry: &ModelRegistry, primary: &str, stop: &AtomicBool) {
    let m = metrics();
    let mut backoff = BACKOFF_MIN;
    while !stop.load(Ordering::SeqCst) && registry.is_follower() {
        match tail_primary(registry, primary, stop, &m, &mut backoff) {
            Ok(()) => break, // promoted or stopped
            Err(e) => {
                eprintln!(
                    "[nmbkm::replica] lost primary {primary}: {e:#} — \
                     retrying in {backoff:?}"
                );
                m.reconnects.inc();
                sleep_interruptible(backoff, stop);
                backoff = (backoff * 2).min(BACKOFF_MAX);
            }
        }
    }
    eprintln!("[nmbkm::replica] follower loop stopped");
}

/// One connection's worth of following: handshake, bootstrap if our
/// log cannot reach the primary's retained history, then tail until
/// promoted/stopped (`Ok`) or the connection fails (`Err` → backoff).
fn tail_primary(
    registry: &ModelRegistry,
    primary: &str,
    stop: &AtomicBool,
    m: &ReplicaMetrics,
    backoff: &mut Duration,
) -> Result<()> {
    let wal = registry
        .wal()
        .ok_or_else(|| anyhow!("follower mode requires an attached wal"))?;
    let mut client = FrameClient::connect(primary)?;
    let (info, _) = client.call_ok(&json::obj(vec![("op", json::s("sync-info"))]), &[])?;
    let remote_epoch = u64_field(&info, "epoch")?;
    let remote_next = u64_field(&info, "next")?;
    let remote_oldest = u64_field(&info, "oldest")?;
    ensure!(
        remote_epoch >= wal.epoch(),
        "stale primary: its epoch {} is behind ours ({}) — this node \
         (or another) was promoted past it",
        remote_epoch,
        wal.epoch()
    );
    // handshake OK: the next failure is a fresh one, back off from the
    // bottom again
    *backoff = BACKOFF_MIN;
    if remote_epoch > wal.epoch() {
        wal.adopt_epoch(remote_epoch)?;
    }
    if needs_bootstrap(registry, &wal, &info, remote_oldest)? {
        bootstrap(registry, &wal, &mut client, &info, remote_next, remote_epoch, m)?;
    }
    // ── tail ─────────────────────────────────────────────────────────
    loop {
        if stop.load(Ordering::SeqCst) || !registry.is_follower() {
            m.lag.set(0);
            return Ok(());
        }
        let cursor = wal.next_seq();
        let req = json::obj(vec![
            ("op", json::s("wal-fetch")),
            ("from", u64_json(cursor)),
            ("max", json::num(wal::DEFAULT_FETCH_BYTES as f64)),
        ]);
        let (h, bytes) = client.call_ok(&req, &[])?;
        let batch_epoch = u64_field(&h, "epoch")?;
        let head = u64_field(&h, "head")?;
        if h.get("reset").and_then(Json::as_bool) == Some(true) {
            // the primary checkpointed past our cursor; re-bootstrap on
            // the next connection attempt
            bail!(
                "cursor {cursor} predates the primary's retained log — \
                 re-bootstrapping"
            );
        }
        if bytes.is_empty() {
            m.lag.set(head.saturating_sub(cursor) as i64);
            sleep_interruptible(POLL, stop);
            continue;
        }
        // durability first: mirror the primary's bytes into our own log
        // (CRC + seq contiguity + epoch fence enforced), then replay.
        // If we crash between the two, recovery replays from the log —
        // the same records, the same bits.
        let scan = wal::scan_records(&bytes);
        wal.append_raw(&bytes, batch_epoch)?;
        for (rec, _) in &scan.records {
            wal::apply_record(registry, rec)
                .with_context(|| format!("applying record {}", rec.seq))?;
            m.applied.inc();
        }
        m.lag.set(head.saturating_sub(wal.next_seq()) as i64);
        if let Err(e) = wal.maybe_checkpoint(registry) {
            eprintln!("[nmbkm::replica] checkpoint failed: {e:#}");
        }
    }
}

/// Bootstrap is needed when our log cannot splice onto the primary's
/// retained history, or our model set has diverged from the primary's.
fn needs_bootstrap(
    registry: &ModelRegistry,
    wal: &Wal,
    info: &Json,
    remote_oldest: u64,
) -> Result<bool> {
    if wal.next_seq() < remote_oldest {
        return Ok(true);
    }
    let remote: Vec<(&str, u64)> = info
        .get("models")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("sync-info missing models"))?
        .iter()
        .map(|mv| {
            let name = mv
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("sync-info model without name"))?;
            Ok((name, u64_field(mv, "seq")?))
        })
        .collect::<Result<_>>()?;
    let local = registry.entries();
    // a local model the primary lacks (or vice versa) that the log tail
    // won't reconcile means we forked — e.g. a crash mid-bootstrap
    for e in &local {
        match remote.iter().find(|(n, _)| *n == e.name()) {
            None => return Ok(true),
            // a clean mirror only applies records fetched from the
            // primary, so being ahead of its applied seq means a fork
            Some((_, rseq)) => {
                if e.last_seq() > *rseq {
                    return Ok(true);
                }
            }
        }
    }
    for (n, rseq) in &remote {
        if registry.resolve(Some(n)).is_err() && *rseq < wal.next_seq() {
            // the primary applied ops to this model before our cursor,
            // but we never got its snapshot
            return Ok(true);
        }
    }
    Ok(false)
}

/// Replace local state wholesale with the primary's: ship every model's
/// snapshot, reset the local log to the primary's cursor + epoch, and
/// persist a checkpoint so a follower restart resumes without
/// re-shipping.
fn bootstrap(
    registry: &ModelRegistry,
    wal: &Wal,
    client: &mut FrameClient,
    info: &Json,
    cursor: u64,
    epoch: u64,
    m: &ReplicaMetrics,
) -> Result<()> {
    let models = info
        .get("models")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("sync-info missing models"))?;
    eprintln!(
        "[nmbkm::replica] bootstrapping {} model(s) from the primary \
         (cursor {cursor}, epoch {epoch})",
        models.len()
    );
    // local state is about to be replaced wholesale
    for e in registry.entries() {
        registry.drop_model_unlogged(e.name())?;
    }
    for mv in models {
        let name = mv
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("sync-info model without name"))?;
        let req = json::obj(vec![
            ("op", json::s("sync-snapshot")),
            ("model", json::s(name)),
        ]);
        // transport errors propagate: the whole bootstrap retries
        let (h, body) = client.call(&req, &[])?;
        if h.get("ok").and_then(Json::as_bool) != Some(true) {
            let msg = h
                .get("error")
                .and_then(Json::as_str)
                .unwrap_or("ok:false");
            // dropped between sync-info and now: its drop record is in
            // the tail we are about to replay; skipping it is exactly
            // what the primary's own history does
            if msg.contains("unknown model") {
                eprintln!(
                    "[nmbkm::replica] model '{name}' vanished during \
                     bootstrap (dropped on the primary) — skipping"
                );
                continue;
            }
            bail!("primary: {msg}");
        }
        let seq = u64_field(&h, "seq")?;
        // format-sniffing decode: a primary configured for binary
        // sidecar snapshots ships those same bytes, a JSON primary
        // ships JSON — either way the decoded state is bit-identical
        let snap = Snapshot::from_bytes(&body)
            .with_context(|| format!("snapshot for '{name}'"))?;
        let mut session = OnlineSession::resume(snap)
            .map_err(|e| anyhow!("resuming shipped model '{name}': {e:#}"))?;
        session.set_snapshot_dir(registry.snapshot_dir());
        let entry = registry.insert(name, session)?;
        entry.set_last_seq(seq);
    }
    // our log restarts at the primary's cursor under its epoch; records
    // the snapshots already cover will be skipped by seq on replay
    wal.reset_to(cursor, epoch)?;
    // persist: a restart resumes from this checkpoint instead of
    // re-shipping every snapshot (best-effort — an uninitialised model
    // defers it, and the next fetch cycle will try again)
    if let Err(e) = wal.checkpoint(registry) {
        eprintln!("[nmbkm::replica] bootstrap checkpoint failed: {e:#}");
    }
    m.bootstraps.inc();
    Ok(())
}

fn sleep_interruptible(total: Duration, stop: &AtomicBool) {
    let mut left = total;
    let tick = Duration::from_millis(50);
    while left > Duration::ZERO {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let step = left.min(tick);
        std::thread::sleep(step);
        left -= step;
    }
}
