//! `nmbkm` — command-line interface.
//!
//! ```text
//! nmbkm run --dataset infmnist --algo tb --rho inf --k 50 --b0 5000 \
//!           --seconds 20 --seed 0 --engine xla --threads 8 --out run.csv
//! nmbkm experiment fig1|fig2|fig3|table1|table2|all [--full] [--seeds N]
//! nmbkm train --dataset gaussian --k 50 --seconds 10 --save model.json
//! nmbkm serve --snapshot model.json [--listen 127.0.0.1:7878] [--binary]
//! nmbkm serve --models news=a.json,users=b.json --listen 127.0.0.1:7878 \
//!             --metrics-addr 127.0.0.1:9100
//! nmbkm serve --wal-dir wal/ --fsync interval:50 --listen 127.0.0.1:7878 --binary
//! nmbkm serve --wal-dir fwal/ --follow 127.0.0.1:7878 --listen 127.0.0.1:7879 --binary
//! nmbkm promote --addr 127.0.0.1:7879
//! nmbkm serve --data-dir shards/ --max-resident-rows 65536 \
//!             --snapshot-format binary --listen 127.0.0.1:7878
//! nmbkm predict --snapshot model.json [--points queries.jsonl]
//! nmbkm snapshot-convert --in model.json --out model.bin --format binary
//! nmbkm bench-trend --baseline old.json --current new.json
//! nmbkm metrics-scrape --addr 127.0.0.1:9100 [--path /metrics]
//! nmbkm info [--artifacts DIR]
//! ```
//!
//! `run` executes one clustering job and writes its per-round trace;
//! `experiment` regenerates a paper table/figure (see DESIGN.md);
//! `train`/`serve`/`predict` drive the serving layer (`serve` module):
//! train-and-snapshot, resume-and-serve over JSONL (stdio or TCP), and
//! batch scoring against a saved model; `info` prints platform/artifact
//! status.

use nmbkm::config::RunConfig;
use nmbkm::coordinator::progress::results_dir;
use nmbkm::coordinator::Pool;
use nmbkm::data::{gaussian::GaussianMixture, infmnist::InfMnist, rcv1::Rcv1Sim, Dataset};
use nmbkm::experiments::{self, common::ExpOpts};
use nmbkm::kmeans::assign::NativeEngine;
use nmbkm::serve::{session, Snapshot};
use nmbkm::util::args::{usage, Args, OptSpec};
use nmbkm::util::json::Json;

fn run_spec() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "dataset", takes_value: true, default: Some("gaussian"), help: "gaussian | infmnist | rcv1" },
        OptSpec { name: "n", takes_value: true, default: Some("10000"), help: "training points" },
        OptSpec { name: "nval", takes_value: true, default: Some("2000"), help: "validation points" },
        OptSpec { name: "data-seed", takes_value: true, default: Some("7"), help: "dataset generator seed" },
        OptSpec { name: "algo", takes_value: true, default: None, help: "lloyd|elkan|sgd|mb|mbf|gb|tb [tb]" },
        OptSpec { name: "rho", takes_value: true, default: None, help: "gb/tb threshold, number or 'inf' [inf]" },
        OptSpec { name: "k", takes_value: true, default: None, help: "clusters [50]" },
        OptSpec { name: "b0", takes_value: true, default: None, help: "(initial) batch size [5000]" },
        OptSpec { name: "seconds", takes_value: true, default: None, help: "work-time budget [10]" },
        OptSpec { name: "rounds", takes_value: true, default: None, help: "max rounds" },
        OptSpec { name: "seed", takes_value: true, default: None, help: "run seed (shuffle + init) [0]" },
        OptSpec { name: "engine", takes_value: true, default: None, help: "native | xla [native]" },
        OptSpec { name: "threads", takes_value: true, default: None, help: "worker threads [all cores]" },
        OptSpec { name: "artifacts", takes_value: true, default: None, help: "artifacts dir (xla engine) [artifacts]" },
        OptSpec { name: "config", takes_value: true, default: None, help: "key=value config file (flags override)" },
        OptSpec { name: "out", takes_value: true, default: None, help: "trace CSV path" },
        OptSpec { name: "quiet", takes_value: false, default: None, help: "suppress per-round log" },
    ]
}

fn train_spec() -> Vec<OptSpec> {
    let mut spec = run_spec();
    spec.push(OptSpec {
        name: "save",
        takes_value: true,
        default: None,
        help: "snapshot output path (required)",
    });
    spec.push(OptSpec {
        name: "model-only",
        takes_value: false,
        default: None,
        help: "omit the data buffer (predict-only artifact)",
    });
    spec
}

fn serve_spec() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "snapshot", takes_value: true, default: None, help: "snapshot to serve as the implicit 'default' model" },
        OptSpec { name: "models", takes_value: true, default: None, help: "named snapshots: name=path[,name=path…]" },
        OptSpec { name: "listen", takes_value: true, default: None, help: "TCP address, e.g. 127.0.0.1:7878 [stdio]" },
        OptSpec { name: "threads", takes_value: true, default: None, help: "override snapshot thread counts" },
        OptSpec { name: "snapshot-dir", takes_value: true, default: None, help: "where wire-created models write protocol snapshots [cwd]" },
        OptSpec { name: "binary", takes_value: false, default: None, help: "accept length-prefixed binary frames (connections starting with magic byte 0xB7; JSONL clients unaffected)" },
        OptSpec { name: "metrics-addr", takes_value: true, default: None, help: "HTTP metrics endpoint, e.g. 127.0.0.1:9100 (GET /metrics = Prometheus exposition, /metrics.json = JSON report)" },
        OptSpec { name: "wal-dir", takes_value: true, default: None, help: "durable op log directory: mutations are CRC-framed to disk and replayed bit-exactly on restart" },
        OptSpec { name: "fsync", takes_value: true, default: Some("always"), help: "WAL durability: always | interval:<ms> (group commit) | never" },
        OptSpec { name: "checkpoint-bytes", takes_value: true, default: None, help: "snapshot-checkpoint + truncate the log after this many appended bytes [64MiB]" },
        OptSpec { name: "conn-timeout", takes_value: true, default: Some("60"), help: "per-connection socket read/write timeout in seconds, 0 = off" },
        OptSpec { name: "follow", takes_value: true, default: None, help: "run as a read-only follower of this primary (host:port serving --binary); requires --wal-dir" },
        OptSpec { name: "max-conns", takes_value: true, default: Some("0"), help: "admitted-connection cap; peers over it get a structured 'overloaded' error [0 = unlimited]" },
        OptSpec { name: "max-inflight", takes_value: true, default: Some("0"), help: "dispatched-but-unanswered request cap across all connections [0 = unlimited]" },
        OptSpec { name: "max-request-bytes", takes_value: true, default: Some("0"), help: "per-request size cap (JSONL line or whole frame); oversized requests get 'overloaded', the stream survives [0 = unlimited]" },
        OptSpec { name: "write-queue-cap", takes_value: true, default: Some("0"), help: "per-connection write-queue bytes before the server stops reading from that peer (backpressure) [0 = 4MiB]" },
        OptSpec { name: "max-resident", takes_value: true, default: Some("0"), help: "resident-model cap: least-recently-used models are checkpointed and evicted, lazily reloading on next use [0 = unlimited]" },
        OptSpec { name: "model-idle-secs", takes_value: true, default: Some("0"), help: "evict models untouched for this long (checkpoint-then-drop) [0 = never]" },
        OptSpec { name: "data-dir", takes_value: true, default: None, help: "bounded-memory ingest: spill every model's row buffer to disk-backed shard files under this directory (created if missing); training stays bit-identical to in-RAM" },
        OptSpec { name: "max-resident-rows", takes_value: true, default: Some("65536"), help: "rows the per-model pinned-block cache keeps in RAM when --data-dir is set" },
        OptSpec { name: "snapshot-format", takes_value: true, default: Some("json"), help: "snapshot/checkpoint output format: json | binary (reads always sniff the format on disk)" },
    ]
}

fn snapshot_convert_spec() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "in", takes_value: true, default: None, help: "source snapshot, json or binary — the format is sniffed (required)" },
        OptSpec { name: "out", takes_value: true, default: None, help: "destination path (required)" },
        OptSpec { name: "format", takes_value: true, default: Some("binary"), help: "output format: json | binary" },
    ]
}

fn metrics_scrape_spec() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "addr", takes_value: true, default: None, help: "metrics endpoint address, e.g. 127.0.0.1:9100 (required)" },
        OptSpec { name: "path", takes_value: true, default: Some("/metrics"), help: "path to fetch" },
        OptSpec { name: "print", takes_value: false, default: None, help: "echo the body after validating" },
        OptSpec { name: "retries", takes_value: true, default: Some("1"), help: "total attempts before giving up (covers server startup races)" },
        OptSpec { name: "backoff-ms", takes_value: true, default: Some("200"), help: "sleep between attempts" },
    ]
}

fn promote_spec() -> Vec<OptSpec> {
    vec![OptSpec {
        name: "addr",
        takes_value: true,
        default: None,
        help: "the follower's JSONL TCP address, e.g. 127.0.0.1:7879 (required)",
    }]
}

fn bench_trend_spec() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "baseline", takes_value: true, default: None, help: "previous bench report JSON (required)" },
        OptSpec { name: "current", takes_value: true, default: None, help: "current bench report JSON (required)" },
        OptSpec { name: "threshold", takes_value: true, default: Some("0.20"), help: "max allowed median regression fraction" },
    ]
}

fn predict_spec() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "snapshot", takes_value: true, default: None, help: "model snapshot (required)" },
        OptSpec { name: "points", takes_value: true, default: Some("-"), help: "JSONL query file (dense array or sparse {indices,values,dim} per line), '-' = stdin" },
        OptSpec { name: "threads", takes_value: true, default: None, help: "worker threads [auto]" },
    ]
}

fn build_dataset(args: &Args) -> anyhow::Result<Dataset> {
    let n = args.get_usize("n")?;
    let nval = args.get_usize("nval")?;
    let seed = args.get_u64("data-seed")?;
    Ok(match args.get("dataset").unwrap_or("gaussian") {
        "gaussian" => GaussianMixture::default_spec(10, 32).dataset(n, nval, seed),
        "infmnist" => InfMnist::default().dataset(n, nval, seed),
        "rcv1" => Rcv1Sim::default().dataset(n, nval, seed),
        other => anyhow::bail!("unknown dataset '{other}'"),
    })
}

/// Assemble the run config: config file first, explicit flags override,
/// threads default to all cores when neither specifies them.
fn resolve_cfg(args: &Args) -> anyhow::Result<RunConfig> {
    let mut cfg = RunConfig::default();
    if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path)?;
        cfg.apply_file(&text).map_err(anyhow::Error::msg)?;
    } else if args.get("threads").is_none() {
        cfg.threads = Pool::auto().threads;
    }
    let overridden = RunConfig::from_args(args).map_err(anyhow::Error::msg)?;
    // fold in only the flags that were actually passed
    if args.get("algo").is_some() { cfg.algo = overridden.algo; }
    if args.get("rho").is_some() { cfg.rho = overridden.rho; }
    if args.get("k").is_some() { cfg.k = overridden.k; }
    if args.get("b0").is_some() { cfg.b0 = overridden.b0; }
    if args.get("seconds").is_some() { cfg.max_seconds = overridden.max_seconds; }
    if args.get("rounds").is_some() { cfg.max_rounds = overridden.max_rounds; }
    if args.get("seed").is_some() { cfg.seed = overridden.seed; }
    if args.get("engine").is_some() { cfg.engine = overridden.engine; }
    if args.get("threads").is_some() { cfg.threads = overridden.threads; }
    if args.get("artifacts").is_some() { cfg.artifacts_dir = overridden.artifacts_dir; }
    Ok(cfg)
}

fn cmd_run(raw: &[String]) -> anyhow::Result<()> {
    let spec = run_spec();
    let args = Args::parse(raw, &spec).map_err(anyhow::Error::msg)?;
    let ds = build_dataset(&args)?;
    let cfg = resolve_cfg(&args)?;

    println!("dataset: {}", ds.summary());
    println!(
        "running {} (k={}, b0={}, engine={:?}, threads={})",
        cfg.label(), cfg.k, cfg.b0, cfg.engine, cfg.threads
    );
    let out = nmbkm::kmeans::run(&ds.train, Some(&ds.val), &cfg)?;
    if !args.flag("quiet") {
        for r in &out.trace.records {
            println!(
                "round {:>4}  t={:>8.3}s  b={:>7}  calcs={:>12}  skips={:>12}  changed={:>8}  mse={}",
                r.round,
                r.t_work,
                r.batch,
                r.dist_calcs,
                r.bound_skips,
                r.changed,
                r.val_mse.map(|m| format!("{m:.6e}")).unwrap_or_else(|| "-".into()),
            );
        }
    }
    println!(
        "done: {} rounds, {:.3}s work, final validation MSE {:.6e}",
        out.rounds, out.work_secs, out.final_mse
    );
    if let Some(path) = args.get("out") {
        out.trace.to_table().write_csv(std::path::Path::new(path))?;
        println!("trace written to {path}");
    }
    Ok(())
}

fn cmd_train(raw: &[String]) -> anyhow::Result<()> {
    let spec = train_spec();
    let args = Args::parse(raw, &spec).map_err(anyhow::Error::msg)?;
    let save = args
        .get("save")
        .ok_or_else(|| anyhow::anyhow!("train needs --save PATH"))?
        .to_string();
    let ds = build_dataset(&args)?;
    let cfg = resolve_cfg(&args)?;

    println!("dataset: {}", ds.summary());
    println!(
        "training {} (k={}, b0={}, threads={}) for snapshot {save}",
        cfg.label(), cfg.k, cfg.b0, cfg.threads
    );
    // paper protocol: per-seed shuffle before the nested batches form
    let shuffled = nmbkm::data::shuffle::shuffled(&ds.train, cfg.seed);
    let (session, report) = session::train(&shuffled, &cfg)?;
    let pool = Pool::new(cfg.threads);
    let cent = session.centroids().expect("trained session has a model");
    let val_mse = nmbkm::kmeans::assign::validation_mse(
        &ds.val,
        cent,
        &NativeEngine::default(),
        &pool,
    );
    if let Some(info) = report.last {
        println!(
            "trained: {} rounds, {:.3}s work, batch {} / {}, train MSE {:.6e}",
            report.rounds_run,
            report.work_secs,
            info.batch,
            shuffled.n(),
            info.train_mse
        );
    }
    println!("validation MSE {val_mse:.6e}");
    let snap = session.snapshot(!args.flag("model-only"))?;
    let path = std::path::Path::new(&save);
    snap.save(path)?;
    let bytes = std::fs::metadata(path).map(|m| m.len()).unwrap_or(0);
    println!(
        "snapshot saved to {save} ({bytes} bytes{})",
        if args.flag("model-only") { ", model-only" } else { "" }
    );
    Ok(())
}

/// Resume one snapshot into a serving session (thread override applied,
/// protocol `snapshot` writes confined to the artifact's directory).
fn resume_for_serving(
    path: &str,
    threads: Option<usize>,
) -> anyhow::Result<session::OnlineSession> {
    let mut snap = Snapshot::load(std::path::Path::new(path))?;
    if let Some(t) = threads {
        snap.cfg.threads = t.max(1);
    }
    let mut session = session::OnlineSession::resume(snap)?;
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            session.set_snapshot_dir(dir.to_path_buf());
        }
    }
    Ok(session)
}

fn cmd_serve(raw: &[String]) -> anyhow::Result<()> {
    let spec = serve_spec();
    let args = Args::parse(raw, &spec).map_err(anyhow::Error::msg)?;
    let threads = match args.get("threads") {
        Some(_) => Some(args.get_usize("threads")?),
        None => None,
    };
    let registry = std::sync::Arc::new(nmbkm::serve::ModelRegistry::new());
    // wire-created models confine their protocol `snapshot` writes here
    if let Some(dir) = args.get("snapshot-dir") {
        registry.set_snapshot_dir(std::path::PathBuf::from(dir));
    }
    // snapshot/checkpoint output format; reads always sniff, so a
    // reconfigured server keeps loading its older artifacts
    let snap_format = nmbkm::serve::SnapshotFormat::parse(
        args.get("snapshot-format").unwrap_or("json"),
    )?;
    registry.set_snapshot_format(snap_format);
    // --data-dir: bounded-memory ingest. Configured before any model is
    // loaded so preloads, WAL replay and wire-created models all pass
    // through the registry's spill funnel.
    if let Some(dir) = args.get("data-dir") {
        let dir = std::path::PathBuf::from(dir);
        std::fs::create_dir_all(&dir).map_err(|e| {
            anyhow::anyhow!("creating data dir {}: {e}", dir.display())
        })?;
        let max_resident_rows = args.get_usize("max-resident-rows")?.max(1);
        eprintln!(
            "[nmbkm::serve] bounded-memory ingest: shard files under {}, \
             ≤ {} rows resident per model",
            dir.display(),
            max_resident_rows
        );
        registry.set_spill(Some(nmbkm::serve::SpillConfig {
            dir,
            max_resident_rows,
        }));
    }
    // --snapshot serves one artifact as the implicit "default" model
    if let Some(path) = args.get("snapshot") {
        let session = resume_for_serving(path, threads)?;
        eprintln!(
            "[nmbkm::serve] resumed {} from {path} as 'default': {}",
            session.cfg().label(),
            session.stats_json().to_string()
        );
        registry
            .insert(nmbkm::serve::registry::DEFAULT_MODEL, session)
            .map_err(|e| anyhow::anyhow!("registering default model: {e:#}"))?;
    }
    // --models name=path,… loads a fleet of named artifacts
    if let Some(models) = args.get("models") {
        for part in models.split(',').filter(|p| !p.trim().is_empty()) {
            let (name, path) = part.trim().split_once('=').ok_or_else(|| {
                anyhow::anyhow!(
                    "--models entries are name=path, got '{part}'"
                )
            })?;
            let session = resume_for_serving(path.trim(), threads)?;
            eprintln!(
                "[nmbkm::serve] resumed {} from {} as '{}'",
                session.cfg().label(),
                path.trim(),
                name.trim()
            );
            registry
                .insert(name.trim(), session)
                .map_err(|e| anyhow::anyhow!("registering '{name}': {e:#}"))?;
        }
    }
    if registry.is_empty() {
        eprintln!(
            "[nmbkm::serve] starting with an empty registry — clients \
             bootstrap models over the wire with the 'create' op"
        );
    }
    // --wal-dir: recover state from the last checkpoint + log tail
    // FIRST (replay never re-logs), then attach the WAL so subsequent
    // mutations append. Recovery overrides CLI preloads of the same
    // name — the checkpointed state is authoritative.
    if let Some(dir) = args.get("wal-dir") {
        let policy = nmbkm::serve::wal::FsyncPolicy::parse(
            args.get("fsync").unwrap_or("always"),
        )?;
        let ckpt = match args.get("checkpoint-bytes") {
            Some(_) => args.get_u64("checkpoint-bytes")?,
            None => nmbkm::serve::wal::DEFAULT_CHECKPOINT_BYTES,
        };
        let rec = nmbkm::serve::wal::recover_as(
            std::path::Path::new(dir),
            policy,
            ckpt,
            snap_format,
            &registry,
        )?;
        eprintln!(
            "[nmbkm::serve] wal recovered from {dir}: {} model(s) from \
             checkpoints, {} record(s) replayed, {} skipped, {} torn \
             byte(s) truncated (epoch {}, next seq {})",
            rec.resumed_models,
            rec.replayed,
            rec.skipped,
            rec.truncated_bytes,
            rec.wal.epoch(),
            rec.wal.next_seq(),
        );
        registry.attach_wal(rec.wal);
    }
    // --follow: read-only mirror tailing a primary's log
    let follower_stop = match args.get("follow") {
        Some(primary) => {
            anyhow::ensure!(
                args.get("wal-dir").is_some(),
                "--follow requires --wal-dir (the follower mirrors the \
                 primary's log to its own)"
            );
            registry.set_follower(true);
            eprintln!(
                "[nmbkm::serve] follower mode: tailing {primary} \
                 (read-only until 'promote')"
            );
            let stop = std::sync::Arc::new(
                std::sync::atomic::AtomicBool::new(false),
            );
            nmbkm::serve::replica::spawn_follower(
                registry.clone(),
                primary.to_string(),
                stop.clone(),
            );
            Some(stop)
        }
        None => None,
    };
    // --metrics-addr: sidecar HTTP endpoint over the same registry the
    // protocol's `metrics` op reads; works for TCP and stdio serving
    if let Some(maddr) = args.get("metrics-addr") {
        nmbkm::obs::mono_nanos(); // anchor monotonic stamps at startup
        let listener = std::net::TcpListener::bind(maddr)
            .map_err(|e| anyhow::anyhow!("binding metrics addr {maddr}: {e}"))?;
        eprintln!(
            "[nmbkm::serve] metrics on http://{}/metrics (Prometheus) and \
             /metrics.json",
            listener.local_addr()?
        );
        let reg = registry.clone();
        let render: nmbkm::obs::http::Renderer =
            std::sync::Arc::new(move |path: &str| match path {
                "/metrics" => Some((
                    nmbkm::obs::http::PROMETHEUS_CTYPE,
                    nmbkm::serve::observe::render_prometheus(&reg),
                )),
                "/metrics.json" => Some((
                    "application/json",
                    nmbkm::serve::observe::metrics_json(&reg).to_string(),
                )),
                _ => None,
            });
        // detached: the scrape loop dies with the process
        let _ = nmbkm::obs::http::spawn_metrics_server(listener, render);
    }
    // model lifecycle: LRU/idle eviction under the residency cap, run
    // from the acceptor's periodic tick
    registry.set_max_resident(args.get_usize("max-resident")?);
    let idle_secs = args.get_u64("model-idle-secs")?;
    registry.set_idle_evict(
        (idle_secs > 0).then(|| std::time::Duration::from_secs(idle_secs)),
    );
    let timeout_secs = args.get_u64("conn-timeout")?;
    let opts = nmbkm::serve::server::ServeOptions {
        accept_binary: args.flag("binary"),
        conn_timeout: (timeout_secs > 0)
            .then(|| std::time::Duration::from_secs(timeout_secs)),
        max_conns: args.get_usize("max-conns")?,
        max_inflight: args.get_usize("max-inflight")?,
        max_request_bytes: args.get_usize("max-request-bytes")?,
        write_queue_cap: args.get_usize("write-queue-cap")?,
    };
    let out = match args.get("listen") {
        Some(addr) => {
            nmbkm::serve::server::serve_tcp(registry.clone(), addr, opts)
        }
        None => nmbkm::serve::server::serve_stdio(&registry, opts.accept_binary),
    };
    if let Some(stop) = follower_stop {
        stop.store(true, std::sync::atomic::Ordering::SeqCst);
    }
    out
}

/// Tell a follower to become the primary: one JSONL `promote` round
/// trip. The follower bumps its epoch (fencing any log appends still
/// arriving from the old primary) and starts accepting mutations.
fn cmd_promote(raw: &[String]) -> anyhow::Result<()> {
    let spec = promote_spec();
    let args = Args::parse(raw, &spec).map_err(anyhow::Error::msg)?;
    let addr = args
        .get("addr")
        .ok_or_else(|| anyhow::anyhow!("promote needs --addr HOST:PORT"))?;
    let mut stream = std::net::TcpStream::connect(addr)
        .map_err(|e| anyhow::anyhow!("connecting to {addr}: {e}"))?;
    stream.set_read_timeout(Some(std::time::Duration::from_secs(10)))?;
    stream.set_write_timeout(Some(std::time::Duration::from_secs(10)))?;
    use std::io::{BufRead, BufReader, Write};
    writeln!(stream, "{{\"op\":\"promote\"}}")?;
    stream.flush()?;
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line)?;
    let v = Json::parse(line.trim())
        .map_err(|e| anyhow::anyhow!("unparseable response '{line}': {e}"))?;
    anyhow::ensure!(
        v.get("ok").and_then(Json::as_bool) == Some(true),
        "promote failed: {}",
        v.get("error").and_then(Json::as_str).unwrap_or("unknown error")
    );
    println!(
        "promoted: {addr} is now a primary at epoch 0x{}",
        v.get("epoch").and_then(Json::as_str).unwrap_or("?")
    );
    Ok(())
}

/// Fetch a metrics endpoint, validate the Prometheus exposition format,
/// and report family/series counts — the CI smoke check for
/// `serve --metrics-addr`. Non-zero exit on connection failure, non-200
/// status, or a malformed exposition.
fn cmd_metrics_scrape(raw: &[String]) -> anyhow::Result<()> {
    let spec = metrics_scrape_spec();
    let args = Args::parse(raw, &spec).map_err(anyhow::Error::msg)?;
    let addr = args
        .get("addr")
        .ok_or_else(|| anyhow::anyhow!("metrics-scrape needs --addr HOST:PORT"))?;
    let path = args.get("path").unwrap_or("/metrics");
    let attempts = args.get_usize("retries")?.max(1);
    let backoff = std::time::Duration::from_millis(args.get_u64("backoff-ms")?);
    let scrape = || -> anyhow::Result<String> {
        let (status, body) = nmbkm::obs::http::http_get(addr, path)?;
        anyhow::ensure!(status == 200, "GET {addr}{path} returned HTTP {status}");
        if path.ends_with(".json") {
            let doc = Json::parse(&body)
                .map_err(|e| anyhow::anyhow!("invalid JSON body: {e}"))?;
            let n = doc
                .get("metrics")
                .and_then(Json::as_arr)
                .map(|a| a.len())
                .ok_or_else(|| anyhow::anyhow!("body has no 'metrics' array"))?;
            println!(
                "metrics-scrape OK: {addr}{path} — {n} metrics (JSON schema)"
            );
        } else {
            let summary = nmbkm::obs::export::validate_exposition(&body)
                .map_err(|e| {
                    anyhow::anyhow!("invalid Prometheus exposition: {e}")
                })?;
            println!(
                "metrics-scrape OK: {addr}{path} — {} families, {} series",
                summary.families, summary.series
            );
        }
        Ok(body)
    };
    // retry connection-level failures: CI starts the server and scrapes
    // in the same breath, and the bind may not be up yet
    let mut body = String::new();
    for attempt in 1..=attempts {
        match scrape() {
            Ok(b) => {
                body = b;
                break;
            }
            Err(e) if attempt < attempts => {
                eprintln!(
                    "[metrics-scrape] attempt {attempt}/{attempts}: {e:#} — \
                     retrying in {}ms",
                    backoff.as_millis()
                );
                std::thread::sleep(backoff);
            }
            Err(e) => return Err(e),
        }
    }
    if args.flag("print") {
        print!("{body}");
    }
    Ok(())
}

fn cmd_bench_trend(raw: &[String]) -> anyhow::Result<()> {
    let spec = bench_trend_spec();
    let args = Args::parse(raw, &spec).map_err(anyhow::Error::msg)?;
    let baseline_path = args
        .get("baseline")
        .ok_or_else(|| anyhow::anyhow!("bench-trend needs --baseline FILE"))?;
    let current_path = args
        .get("current")
        .ok_or_else(|| anyhow::anyhow!("bench-trend needs --current FILE"))?;
    let threshold = args.get_f64("threshold")?;
    anyhow::ensure!(
        threshold >= 0.0,
        "--threshold must be non-negative, got {threshold}"
    );
    let load = |p: &str| -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(p)
            .map_err(|e| anyhow::anyhow!("reading {p}: {e}"))?;
        Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {p}: {e}"))
    };
    let baseline = load(baseline_path)?;
    let current = load(current_path)?;
    let rows = nmbkm::bench::compare_reports(&baseline, &current)
        .map_err(anyhow::Error::msg)?;
    anyhow::ensure!(
        !rows.is_empty(),
        "no overlapping measurements between {baseline_path} and {current_path}"
    );
    let mut regressed = Vec::new();
    println!(
        "{:<28} {:<42} {:>12} {:>12} {:>8}",
        "set", "measurement", "baseline", "current", "ratio"
    );
    for r in &rows {
        let over = r.ratio() > 1.0 + threshold;
        let flag = match (over, r.gateable()) {
            (true, true) => "  << REGRESSION",
            // single-sample baselines (smoke runs) are too noisy to
            // gate on — report, don't fail
            (true, false) => "  (over threshold; 1-sample baseline, not gated)",
            _ => "",
        };
        println!(
            "{:<28} {:<42} {:>11.6}s {:>11.6}s {:>8.3}{flag}",
            r.set,
            r.name,
            r.base_median_s,
            r.cur_median_s,
            r.ratio()
        );
        if over && r.gateable() {
            regressed.push(format!(
                "{}/{} {:.1}% slower",
                r.set,
                r.name,
                (r.ratio() - 1.0) * 100.0
            ));
        }
    }
    // composite throughput: QPS per core, emitted by serve_throughput's
    // meta when sampled (≥2 samples). Higher is better, so the gate
    // direction inverts: regression = current < baseline × (1 − threshold).
    let qpc = |doc: &Json| {
        doc.get("meta")
            .and_then(|m| m.get("qps_per_core"))
            .and_then(Json::as_f64)
    };
    if let (Some(base_qpc), Some(cur_qpc)) = (qpc(&baseline), qpc(&current)) {
        let ratio = if base_qpc > 0.0 { cur_qpc / base_qpc } else { 1.0 };
        let low = base_qpc > 0.0 && cur_qpc < base_qpc * (1.0 - threshold);
        println!(
            "{:<28} {:<42} {:>11.1}/s {:>11.1}/s {:>8.3}{}",
            "meta",
            "qps_per_core",
            base_qpc,
            cur_qpc,
            ratio,
            if low { "  << REGRESSION" } else { "" }
        );
        if low {
            regressed.push(format!(
                "meta/qps_per_core {:.1}% lower",
                (1.0 - ratio) * 100.0
            ));
        }
    }
    anyhow::ensure!(
        regressed.is_empty(),
        "median regression beyond {:.0}%: {}",
        threshold * 100.0,
        regressed.join("; ")
    );
    if rows.iter().all(|r| !r.gateable()) {
        println!(
            "bench trend: baseline is single-sample (smoke) — nothing gated"
        );
    } else {
        println!(
            "bench trend OK: {} measurements within {:.0}% of baseline medians",
            rows.len(),
            threshold * 100.0
        );
    }
    Ok(())
}

fn cmd_predict(raw: &[String]) -> anyhow::Result<()> {
    let spec = predict_spec();
    let args = Args::parse(raw, &spec).map_err(anyhow::Error::msg)?;
    let path = args
        .get("snapshot")
        .ok_or_else(|| anyhow::anyhow!("predict needs --snapshot PATH"))?;
    let snap = Snapshot::load(std::path::Path::new(path))?;
    let cent = snap.centroids();
    let d = cent.d();
    // sparse-data snapshots score through the same O(nnz·k) CSR kernels
    // the serve layer uses, so CLI and served predicts agree bitwise
    let sparse = snap.data.as_ref().map(|x| x.is_sparse()).unwrap_or(false);
    let source = args.get("points").unwrap_or("-");
    let text = if source == "-" {
        use std::io::Read;
        let mut buf = String::new();
        std::io::stdin().read_to_string(&mut buf)?;
        buf
    } else {
        std::fs::read_to_string(source)?
    };
    // parse every query row up front — each line is one dense JSON array
    // or one sparse {"indices":…,"values":…,"dim":d} object — then score
    // everything as one engine batch
    let mut rows: Vec<nmbkm::serve::WireRow> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let v = Json::parse(line)
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        let row = nmbkm::serve::wire::row_from_json(&v)
            .map_err(|e| anyhow::anyhow!("line {}: {e:#}", lineno + 1))?;
        anyhow::ensure!(
            row.dim() == d,
            "line {}: dimension {}, model dimension is {d}",
            lineno + 1,
            row.dim()
        );
        rows.push(row);
    }
    let pool = match args.get("threads") {
        Some(_) => Pool::new(args.get_usize("threads")?),
        None => Pool::auto(),
    };
    let (lbl, d2) = nmbkm::serve::session::predict_wire(
        cent,
        d,
        &rows,
        sparse,
        None,
        None,
        &NativeEngine::default(),
        &pool,
    )?;
    for t in 0..lbl.len() {
        println!("{{\"label\":{},\"d2\":{}}}", lbl[t], d2[t] as f64);
    }
    Ok(())
}

/// Re-encode a snapshot between the hex-JSON and binary sidecar
/// formats. The input format is sniffed; state round-trips bit-exactly
/// either way, so converting is always safe.
fn cmd_snapshot_convert(raw: &[String]) -> anyhow::Result<()> {
    let spec = snapshot_convert_spec();
    let args = Args::parse(raw, &spec).map_err(anyhow::Error::msg)?;
    let src = args
        .get("in")
        .ok_or_else(|| anyhow::anyhow!("snapshot-convert needs --in PATH"))?;
    let dst = args
        .get("out")
        .ok_or_else(|| anyhow::anyhow!("snapshot-convert needs --out PATH"))?;
    let format = nmbkm::serve::SnapshotFormat::parse(
        args.get("format").unwrap_or("binary"),
    )?;
    let snap = Snapshot::load(std::path::Path::new(src))?;
    snap.save_as(std::path::Path::new(dst), format)?;
    let in_bytes = std::fs::metadata(src).map(|m| m.len()).unwrap_or(0);
    let out_bytes = std::fs::metadata(dst).map(|m| m.len()).unwrap_or(0);
    println!(
        "converted {src} ({in_bytes} bytes) -> {dst} ({out_bytes} bytes, {})",
        format.name()
    );
    Ok(())
}

fn cmd_experiment(raw: &[String]) -> anyhow::Result<()> {
    let which = raw.first().map(|s| s.as_str()).unwrap_or("");
    let rest: Vec<String> = raw.iter().skip(1).cloned().collect();
    let opts = ExpOpts::from_args(&rest);
    println!(
        "experiment {which}: scale={:?} seeds={} threads={} budget={}s",
        opts.scale, opts.seeds, opts.threads, opts.seconds
    );
    match which {
        "fig1" => experiments::fig1::run(&opts),
        "fig2" => experiments::rho_sweep::run(2, &opts),
        "fig3" => experiments::rho_sweep::run(3, &opts),
        "table1" => experiments::table1::run(&opts).map(|_| ()),
        "table2" => experiments::table2::run(&opts).map(|_| ()),
        "ablations" => experiments::ablations::run(&opts),
        "all" => {
            experiments::table1::run(&opts)?;
            experiments::fig1::run(&opts)?;
            experiments::rho_sweep::run(2, &opts)?;
            experiments::rho_sweep::run(3, &opts)?;
            experiments::table2::run(&opts).map(|_| ())
        }
        other => anyhow::bail!(
            "unknown experiment '{other}' (fig1|fig2|fig3|table1|table2|ablations|all)"
        ),
    }
}

fn cmd_info(raw: &[String]) -> anyhow::Result<()> {
    let dir = raw
        .iter()
        .position(|a| a == "--artifacts")
        .and_then(|p| raw.get(p + 1).cloned())
        .unwrap_or_else(|| "artifacts".to_string());
    println!("nmbkm — Nested Mini-Batch K-Means (Newling & Fleuret, NIPS 2016)");
    println!("results dir: {}", results_dir().display());
    println!(
        "threads available: {} (NMBKM_THREADS overrides)",
        Pool::auto().threads
    );
    match nmbkm::runtime::artifact::Manifest::load(std::path::Path::new(&dir)) {
        Ok(m) => {
            println!(
                "artifacts [{dir}]: k={} batches={:?} dims={:?}, {} programs",
                m.k,
                m.batches,
                m.dims,
                m.entries.len()
            );
            #[cfg(feature = "xla")]
            match nmbkm::runtime::executor::XlaEngine::load(&dir) {
                Ok(_) => println!("PJRT CPU client: OK (all programs compiled)"),
                Err(e) => println!("PJRT load failed: {e:#}"),
            }
            #[cfg(not(feature = "xla"))]
            println!(
                "PJRT runtime: disabled at build time (rebuild with \
                 `--features xla`)"
            );
        }
        Err(e) => println!("no artifacts ({e:#}) — run `make artifacts`"),
    }
    Ok(())
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match argv.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => ("help", vec![]),
    };
    let result = match cmd {
        "run" => cmd_run(&rest),
        "train" => cmd_train(&rest),
        "serve" => cmd_serve(&rest),
        "promote" => cmd_promote(&rest),
        "predict" => cmd_predict(&rest),
        "snapshot-convert" => cmd_snapshot_convert(&rest),
        "experiment" => cmd_experiment(&rest),
        "bench-trend" => cmd_bench_trend(&rest),
        "metrics-scrape" => cmd_metrics_scrape(&rest),
        "info" => cmd_info(&rest),
        _ => {
            println!(
                "nmbkm <run|train|serve|promote|predict|snapshot-convert|\
                 experiment|bench-trend|metrics-scrape|info>\n"
            );
            println!("{}", usage("nmbkm run", "run one clustering job", &run_spec()));
            println!(
                "{}",
                usage("nmbkm train", "train and save a model snapshot", &train_spec())
            );
            println!(
                "{}",
                usage(
                    "nmbkm serve",
                    "serve one or many model snapshots over the JSONL \
                     protocol (create|list|drop|ingest|predict|step|\
                     stats|snapshot|shutdown); points may be dense \
                     arrays or sparse {indices,values,dim} rows; TCP \
                     handles concurrent connections with \
                     snapshot-isolated batched predicts, --binary \
                     adds length-prefixed raw-f32 framing, --wal-dir \
                     adds a durable op log with bit-exact crash \
                     recovery, and --follow mirrors a primary",
                    &serve_spec()
                )
            );
            println!(
                "{}",
                usage(
                    "nmbkm promote",
                    "make a follower the primary (bumps the replication \
                     epoch, fencing the old primary's log)",
                    &promote_spec()
                )
            );
            println!(
                "{}",
                usage(
                    "nmbkm bench-trend",
                    "compare two bench report JSONs; non-zero exit on \
                     median regressions beyond the threshold",
                    &bench_trend_spec()
                )
            );
            println!(
                "{}",
                usage(
                    "nmbkm metrics-scrape",
                    "fetch a serve metrics endpoint and validate the \
                     Prometheus exposition (or JSON report)",
                    &metrics_scrape_spec()
                )
            );
            println!(
                "{}",
                usage(
                    "nmbkm predict",
                    "score JSONL query rows against a snapshot",
                    &predict_spec()
                )
            );
            println!(
                "{}",
                usage(
                    "nmbkm snapshot-convert",
                    "re-encode a snapshot between the hex-JSON and binary \
                     sidecar formats (bit-exact either way)",
                    &snapshot_convert_spec()
                )
            );
            println!(
                "nmbkm experiment <fig1|fig2|fig3|table1|table2|all> \
                 [--full] [--seeds N] [--seconds S] [--threads T] [--engine-xla]"
            );
            println!("nmbkm info [--artifacts DIR]");
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
