//! Assignment engines: who computes `argmin_j ‖x_i − c_j‖²`.
//!
//! * [`NativeEngine`] — pure-rust norms-trick loops, sharded across the
//!   coordinator pool. Works for dense and CSR data; the reference
//!   implementation every other engine is tested against. Dense
//!   selections run through the point-blocked SIMD micro-kernels
//!   ([`crate::linalg::simd::nearest_block`]): a strip of four centroid
//!   rows is re-used from cache across a block of points instead of
//!   re-streaming all k·d centroid floats for every single point.
//! * `runtime::XlaEngine` — dense tiles dispatched to the AOT-compiled
//!   Pallas/XLA artifacts over PJRT (Layer 1/2); implements the same
//!   [`AssignEngine`] trait and must agree with the native engine
//!   exactly (integration test `xla_parity`).
//!
//! Engines only produce `(label, d²)`; applying sufficient-statistics
//! updates stays with the algorithms (leader-side), keeping the engine
//! interface identical for mb, mb-f, gb-ρ and tb-ρ.

use crate::coordinator::shard::{chunk_ranges, split_outputs, Pool};
use crate::data::{Data, Storage};
use crate::kmeans::state::Centroids;
use crate::linalg::simd;
use crate::linalg::sparse::{self, TransposedCentroids};
use crate::obs;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Kernel-level observability counters, interned once in the global
/// [`obs`] registry. Inner loops accumulate plain integers; each chunk
/// of sharded work flushes here exactly once, so the atomics never sit
/// on the per-point path.
struct KernelCounters {
    prune_points_gathered: Arc<obs::Counter>,
    prune_points_swept: Arc<obs::Counter>,
    prune_centroids_evaluated: Arc<obs::Counter>,
    prune_centroids_skipped: Arc<obs::Counter>,
}

fn kernel_counters() -> &'static KernelCounters {
    static K: OnceLock<KernelCounters> = OnceLock::new();
    K.get_or_init(|| {
        let reg = obs::registry();
        KernelCounters {
            prune_points_gathered: reg
                .counter("nmbkm_sparse_prune_points_gathered_total", &[]),
            prune_points_swept: reg
                .counter("nmbkm_sparse_prune_points_swept_total", &[]),
            prune_centroids_evaluated: reg
                .counter("nmbkm_sparse_prune_centroids_evaluated_total", &[]),
            prune_centroids_skipped: reg
                .counter("nmbkm_sparse_prune_centroids_skipped_total", &[]),
        }
    })
}

/// Flush one chunk's worth of prune tallies and the block-kernel
/// dispatch count for the tier that ran them.
fn flush_kernel_stats(stats: &sparse::BlockStats, blocks: u64) {
    if blocks == 0 {
        return;
    }
    simd::note_dispatch(simd::tier(), blocks);
    let kc = kernel_counters();
    kc.prune_points_gathered.add(stats.points_gathered);
    kc.prune_points_swept.add(stats.points_swept);
    kc.prune_centroids_evaluated.add(stats.centroids_evaluated);
    kc.prune_centroids_skipped.add(stats.centroids_skipped);
}

/// A selection of datapoint indices to (re)assign.
#[derive(Clone, Copy, Debug)]
pub enum Sel<'a> {
    /// The contiguous prefix/window `[lo, hi)` — gb/tb active batches
    /// are prefixes of the per-seed shuffled data.
    Range(usize, usize),
    /// An explicit index list (mb random batches, tb dirty points).
    List(&'a [usize]),
}

impl Sel<'_> {
    pub fn len(&self) -> usize {
        match self {
            Sel::Range(lo, hi) => hi - lo,
            Sel::List(l) => l.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    #[inline]
    pub fn nth(&self, t: usize) -> usize {
        match self {
            Sel::Range(lo, _) => lo + t,
            Sel::List(l) => l[t],
        }
    }
}

/// An engine computes nearest centroids for a selection of points,
/// writing `out_lbl[t]`/`out_d2[t]` for the t-th selected point, and
/// returns the number of point-to-centroid distance computations.
pub trait AssignEngine {
    fn assign(
        &self,
        data: &Data,
        sel: Sel,
        centroids: &Centroids,
        pool: &Pool,
        out_lbl: &mut [u32],
        out_d2: &mut [f32],
    ) -> u64;

    /// Full distance rows: `out_d2[t*k + j] = ‖x_{sel(t)} − c_j‖²`.
    /// Used by the tile-path tb-ρ to refresh a dirty point's complete
    /// bound row in one pass (the XLA engine serves this from the
    /// `distmat` artifact). Returns distance-computation count.
    fn dist_rows(
        &self,
        data: &Data,
        sel: Sel,
        centroids: &Centroids,
        pool: &Pool,
        out_d2: &mut [f32],
    ) -> u64;

    /// Σ over the selection of min_j ‖x_i − c_j‖² (validation scoring).
    fn score(
        &self,
        data: &Data,
        sel: Sel,
        centroids: &Centroids,
        pool: &Pool,
    ) -> (f64, u64) {
        let n = sel.len();
        let mut lbl = vec![0u32; n];
        let mut d2 = vec![0f32; n];
        let calcs = self.assign(data, sel, centroids, pool, &mut lbl, &mut d2);
        (d2.iter().map(|&x| x as f64).sum(), calcs)
    }

    fn name(&self) -> &'static str;

    /// `(hits, builds)` of the engine's transpose cache, when it has
    /// one (observability; scraped into the serve metrics registry).
    fn trans_cache_stats(&self) -> Option<(u64, u64)> {
        None
    }

    /// A shared handle on the engine's transpose cache, when it keeps
    /// one. Metric scrapes read its counters through this handle
    /// lock-free — without touching whatever lock guards the engine
    /// itself (a serving session's mutex may be held for seconds by a
    /// training step).
    fn trans_cache_handle(&self) -> Option<Arc<TransCache>> {
        None
    }

    /// A shareable transposed-centroid handle at this centroid
    /// revision, when the engine keeps one. The serve layer carries it
    /// into published model views so sparse predicts reuse the training
    /// session's O(k·d) transpose instead of rebuilding their own.
    fn trans_handle(
        &self,
        _centroids: &Centroids,
    ) -> Option<Arc<TransposedCentroids>> {
        None
    }

    /// [`AssignEngine::assign`] with an externally shared transposed
    /// block for sparse data. Published-model predicts pass the
    /// transpose frozen into their view, bypassing the engine's cache
    /// entirely — concurrent predicts racing across publishes can never
    /// evict each other into a rebuild. Engines without a sparse fast
    /// path ignore the handle.
    #[allow(clippy::too_many_arguments)]
    fn assign_with_trans(
        &self,
        data: &Data,
        sel: Sel,
        centroids: &Centroids,
        pool: &Pool,
        _trans: Option<Arc<TransposedCentroids>>,
        out_lbl: &mut [u32],
        out_d2: &mut [f32],
    ) -> u64 {
        self.assign(data, sel, centroids, pool, out_lbl, out_d2)
    }
}

/// Pure-rust engine; the correctness reference. Each instance owns its
/// own [`TransCache`], so independent sessions (one engine per
/// [`crate::serve::OnlineSession`]) never evict each other's transposed
/// centroid block — the process-global single slot a previous revision
/// used was correct but thrashed as soon as two sparse models trained
/// concurrently.
#[derive(Clone, Debug, Default)]
pub struct NativeEngine {
    cache: Arc<TransCache>,
}

impl NativeEngine {
    /// The engine's transpose cache (tests and cache-sharing callers).
    pub fn cache(&self) -> &TransCache {
        &self.cache
    }

    /// The sharded assignment core: fan the selection out over the pool
    /// with an already-resolved (or absent) transposed block.
    fn assign_sharded(
        &self,
        data: &Data,
        sel: Sel,
        centroids: &Centroids,
        pool: &Pool,
        trans: Option<&TransposedCentroids>,
        out_lbl: &mut [u32],
        out_d2: &mut [f32],
    ) -> u64 {
        let n = sel.len();
        assert_eq!(out_lbl.len(), n);
        assert_eq!(out_d2.len(), n);
        if n == 0 {
            return 0;
        }
        let ranges = chunk_ranges(n, pool.threads, MIN_CHUNK);
        let views = split_outputs(&ranges, out_lbl, out_d2);
        // pair each view with its range and fan out over the pool
        let jobs: Vec<_> = ranges.into_iter().zip(views).collect();
        let k = centroids.k() as u64;
        pool.run_jobs(jobs, |_, (r, (vl, vd))| {
            assign_serial(data, &sel, r, centroids, trans, vl, vd);
        });
        n as u64 * k
    }
}

/// Don't fan out to threads for selections smaller than this
/// (per-item work is one k-way nearest scan).
const MIN_CHUNK: usize = 256;

/// `dist_rows` fans out earlier: per-item work there is a full row of k
/// distances, so much smaller selections already amortise a chunk
/// hand-off. (A previous revision wrote `MIN_CHUNK.max(64)`, which
/// evaluates to 256 — a chunking no-op that serialised the tb-ρ tile
/// path's 100-point dirty batches.)
const DIST_ROWS_MIN_CHUNK: usize = 64;

impl AssignEngine for NativeEngine {
    fn assign(
        &self,
        data: &Data,
        sel: Sel,
        centroids: &Centroids,
        pool: &Pool,
        out_lbl: &mut [u32],
        out_d2: &mut [f32],
    ) -> u64 {
        if sel.is_empty() {
            assert_eq!(out_lbl.len(), 0);
            assert_eq!(out_d2.len(), 0);
            return 0;
        }
        // sparse fast path: transposed centroids turn per-nnz gathers
        // into sequential k-length AXPYs (EXPERIMENTS.md §Perf, ~2x)
        let trans = transposed_for(&self.cache, data, centroids, sel.len());
        self.assign_sharded(
            data,
            sel,
            centroids,
            pool,
            trans.as_deref(),
            out_lbl,
            out_d2,
        )
    }

    fn assign_with_trans(
        &self,
        data: &Data,
        sel: Sel,
        centroids: &Centroids,
        pool: &Pool,
        trans: Option<Arc<TransposedCentroids>>,
        out_lbl: &mut [u32],
        out_d2: &mut [f32],
    ) -> u64 {
        let usable = trans.filter(|tc| {
            data.is_sparse()
                && tc.k == centroids.k()
                && tc.d == centroids.d()
        });
        match usable {
            Some(tc) if !sel.is_empty() => {
                // shared-transpose fast path: the caller froze this
                // block together with `centroids`, so no cache lookup
                // happens at all — concurrent callers holding different
                // revisions can never force a rebuild here. Recorded as
                // a hit for counter parity with the cached path.
                self.cache.note_shared();
                self.assign_sharded(
                    data,
                    sel,
                    centroids,
                    pool,
                    Some(tc.as_ref()),
                    out_lbl,
                    out_d2,
                )
            }
            _ => self.assign(data, sel, centroids, pool, out_lbl, out_d2),
        }
    }

    fn dist_rows(
        &self,
        data: &Data,
        sel: Sel,
        centroids: &Centroids,
        pool: &Pool,
        out_d2: &mut [f32],
    ) -> u64 {
        let n = sel.len();
        let k = centroids.k();
        assert_eq!(out_d2.len(), n * k);
        if n == 0 {
            return 0;
        }
        let ranges = chunk_ranges(n, pool.threads, DIST_ROWS_MIN_CHUNK);
        // split the row-major output at row boundaries
        let mut views = Vec::with_capacity(ranges.len());
        {
            let mut rest: &mut [f32] = out_d2;
            for r in &ranges {
                let (head, tail) = rest.split_at_mut(r.len() * k);
                views.push(head);
                rest = tail;
            }
        }
        let jobs: Vec<_> = ranges.into_iter().zip(views).collect();
        let trans = transposed_for(&self.cache, data, centroids, n);
        let trans = trans.as_deref();
        pool.run_jobs(jobs, |_, (r, out)| {
            dist_rows_serial(data, &sel, r, centroids, trans, out);
        });
        (n * k) as u64
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn trans_cache_stats(&self) -> Option<(u64, u64)> {
        Some((self.cache.hits(), self.cache.builds()))
    }

    fn trans_cache_handle(&self) -> Option<Arc<TransCache>> {
        Some(self.cache.clone())
    }

    fn trans_handle(
        &self,
        centroids: &Centroids,
    ) -> Option<Arc<TransposedCentroids>> {
        if centroids.k() < 8
            || TransposedCentroids::bytes_for(centroids.k(), centroids.d())
                > TRANS_MAX_BYTES
        {
            return None;
        }
        Some(self.cache.fetch(centroids))
    }
}

/// Per-engine transpose cache keyed on [`Centroids::rev`]: within a
/// round, `assign`, `dist_rows` and validation scoring all see the same
/// centroid revision, so the O(k·d) transpose is built once instead of
/// once per engine call. One cache per [`NativeEngine`] (hence per
/// session) keeps concurrently-training sparse models from evicting
/// each other. Hit/build counters are plain observability — they never
/// influence results.
#[derive(Debug, Default)]
pub struct TransCache {
    slot: Mutex<Option<(u64, Arc<TransposedCentroids>)>>,
    hits: AtomicU64,
    builds: AtomicU64,
}

impl TransCache {
    /// Revision-matched transposes served without a rebuild.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// O(k·d) transpose fills (cache misses; in-place rebuilds count —
    /// they redo the fill, just not the allocation).
    pub fn builds(&self) -> u64 {
        self.builds.load(Ordering::Relaxed)
    }

    /// Revision-matched transpose already in the slot (counted as a
    /// hit), or `None`. This is the warm-path gate: a probe never
    /// triggers a build.
    pub fn probe(&self, centroids: &Centroids) -> Option<Arc<TransposedCentroids>> {
        let tc = cache_lookup(&self.slot.lock().unwrap(), centroids)?;
        self.hits.fetch_add(1, Ordering::Relaxed);
        Some(tc)
    }

    /// Fetch the transpose for this centroid revision, building (and
    /// caching) it on a miss. On a miss the stale entry's allocation is
    /// reclaimed and rebuilt in place when no reader still holds it —
    /// steady-state *training* stops reallocating O(k·d) every centroid
    /// revision. (A session whose transpose is pinned by a published
    /// model view still allocates fresh per publish: the view
    /// legitimately holds the old block until the next publish swaps it
    /// out.) The fill runs outside the slot lock so a large transpose
    /// never serialises concurrent readers of the slot.
    pub fn fetch(&self, centroids: &Centroids) -> Arc<TransposedCentroids> {
        if let Some(tc) = self.probe(centroids) {
            return tc;
        }
        let old = self.slot.lock().unwrap().take();
        let tc = match old.and_then(|(_, arc)| Arc::try_unwrap(arc).ok()) {
            Some(mut t) => {
                t.rebuild(&centroids.c);
                Arc::new(t)
            }
            None => Arc::new(TransposedCentroids::build(&centroids.c)),
        };
        self.builds.fetch_add(1, Ordering::Relaxed);
        *self.slot.lock().unwrap() = Some((centroids.rev, tc.clone()));
        tc
    }

    /// Record a serve from an externally shared transpose
    /// ([`AssignEngine::assign_with_trans`]): counter parity with probe
    /// hits, no slot interaction.
    fn note_shared(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }
}

/// Revision-matched cache hit, or `None`.
fn cache_lookup(
    slot: &Option<(u64, Arc<TransposedCentroids>)>,
    centroids: &Centroids,
) -> Option<Arc<TransposedCentroids>> {
    match slot {
        Some((rev, tc))
            if *rev == centroids.rev
                && tc.k == centroids.k()
                && tc.d == centroids.d() =>
        {
            Some(tc.clone())
        }
        _ => None,
    }
}

/// Footprint cap on cached transposes (bounds per-session memory).
const TRANS_MAX_BYTES: usize = 256 << 20;

/// Build (or fetch) the transposed centroid block when it pays: sparse
/// data, k large enough to amortise, selection big enough to amortise
/// the O(k·d) transpose, and a bounded memory footprint. A
/// revision-matched transpose already in the cache (built by an earlier
/// call at this revision) is free and
/// is used even for selections the build gates would reject — the
/// choice never changes results, because the AXPY lanes accumulate in
/// the same order as the gather path's `spdot`, bit for bit.
fn transposed_for(
    cache: &TransCache,
    data: &Data,
    centroids: &Centroids,
    n_points: usize,
) -> Option<Arc<TransposedCentroids>> {
    if !data.is_sparse() {
        return None;
    }
    if let Some(tc) = cache.probe(centroids) {
        return Some(tc);
    }
    if centroids.k() < 8
        || n_points < 64
        || TransposedCentroids::bytes_for(centroids.k(), centroids.d())
            > TRANS_MAX_BYTES
    {
        return None;
    }
    Some(cache.fetch(centroids))
}

fn assign_serial(
    data: &Data,
    sel: &Sel,
    range: std::ops::Range<usize>,
    centroids: &Centroids,
    trans: Option<&TransposedCentroids>,
    out_lbl: &mut [u32],
    out_d2: &mut [f32],
) {
    match (trans, &data.storage) {
        (Some(tc), Storage::Sparse(m)) => {
            // row-blocked + norm-pruned: points go through the
            // transpose in SPARSE_BLOCK batches (phase-separated
            // pruning/AXPY keeps the shared d×k strips cache-resident)
            // — bit-identical to the per-point unpruned scan
            let k = tc.k;
            let mut scratch = vec![0f32; k];
            let mut lbs = vec![0f32; k];
            let mut rows: [(&[u32], &[f32]); sparse::SPARSE_BLOCK] =
                [(&[], &[]); sparse::SPARSE_BLOCK];
            let mut xns = [0f32; sparse::SPARSE_BLOCK];
            let mut stats = sparse::BlockStats::default();
            let mut blocks = 0u64;
            let mut t0 = range.start;
            while t0 < range.end {
                let p = sparse::SPARSE_BLOCK.min(range.end - t0);
                for o in 0..p {
                    let i = sel.nth(t0 + o);
                    rows[o] = m.row(i);
                    xns[o] = data.norms[i];
                }
                let base = t0 - range.start;
                stats.merge(tc.nearest_block(
                    &rows[..p],
                    &xns[..p],
                    &centroids.norms,
                    &mut lbs,
                    &mut scratch,
                    &mut out_lbl[base..base + p],
                    &mut out_d2[base..base + p],
                ));
                blocks += 1;
                t0 += p;
            }
            flush_kernel_stats(&stats, blocks);
        }
        (_, Storage::Sparse(m)) => {
            for (slot, t) in range.clone().enumerate() {
                let i = sel.nth(t);
                let (idx, vals) = m.row(i);
                let (j, d2) = sparse::nearest_sparse(
                    idx,
                    vals,
                    data.norms[i],
                    &centroids.c,
                    &centroids.norms,
                );
                out_lbl[slot] = j;
                out_d2[slot] = d2;
            }
        }
        (_, Storage::Dense(m)) => {
            // point-blocked: a 4-row centroid strip stays in cache
            // across POINT_BLOCK points (bit-identical to per-point)
            let tier = simd::tier();
            let mut blocks = 0u64;
            let mut rows: [&[f32]; simd::POINT_BLOCK] = [&[]; simd::POINT_BLOCK];
            let mut xns = [0f32; simd::POINT_BLOCK];
            let mut t0 = range.start;
            while t0 < range.end {
                let p = simd::POINT_BLOCK.min(range.end - t0);
                for o in 0..p {
                    let i = sel.nth(t0 + o);
                    rows[o] = m.row(i);
                    xns[o] = data.norms[i];
                }
                let base = t0 - range.start;
                simd::nearest_block_with(
                    tier,
                    &rows[..p],
                    &xns[..p],
                    &centroids.c,
                    &centroids.norms,
                    &mut out_lbl[base..base + p],
                    &mut out_d2[base..base + p],
                );
                blocks += 1;
                t0 += p;
            }
            simd::note_dispatch(tier, blocks);
        }
    }
}

fn dist_rows_serial(
    data: &Data,
    sel: &Sel,
    range: std::ops::Range<usize>,
    centroids: &Centroids,
    trans: Option<&TransposedCentroids>,
    out: &mut [f32],
) {
    let k = centroids.k();
    match (trans, &data.storage) {
        (Some(tc), Storage::Sparse(m)) => {
            for (slot, t) in range.clone().enumerate() {
                let i = sel.nth(t);
                let (idx, vals) = m.row(i);
                tc.dist_row(
                    idx,
                    vals,
                    data.norms[i],
                    &centroids.norms,
                    &mut out[slot * k..(slot + 1) * k],
                );
            }
        }
        (_, Storage::Sparse(m)) => {
            // no-transpose fallback: hoist the CSR row and its norm
            // once and run spdot per centroid, instead of re-deriving
            // both through `data.sq_dist_to` for every (i, j) pair
            for (slot, t) in range.clone().enumerate() {
                let i = sel.nth(t);
                let (idx, vals) = m.row(i);
                let xn = data.norms[i];
                let row = &mut out[slot * k..(slot + 1) * k];
                for j in 0..k {
                    row[j] = sparse::sq_dist_sparse(
                        idx,
                        vals,
                        xn,
                        centroids.c.row(j),
                        centroids.norms[j],
                    );
                }
            }
        }
        (_, Storage::Dense(m)) => {
            let tier = simd::tier();
            let mut blocks = 0u64;
            let mut rows: [&[f32]; simd::POINT_BLOCK] = [&[]; simd::POINT_BLOCK];
            let mut xns = [0f32; simd::POINT_BLOCK];
            let mut t0 = range.start;
            while t0 < range.end {
                let p = simd::POINT_BLOCK.min(range.end - t0);
                for o in 0..p {
                    let i = sel.nth(t0 + o);
                    rows[o] = m.row(i);
                    xns[o] = data.norms[i];
                }
                let base = t0 - range.start;
                simd::dist_rows_block_with(
                    tier,
                    &rows[..p],
                    &xns[..p],
                    &centroids.c,
                    &centroids.norms,
                    &mut out[base * k..(base + p) * k],
                );
                blocks += 1;
                t0 += p;
            }
            simd::note_dispatch(tier, blocks);
        }
    }
}

/// Validation-set mean MSE under `centroids` via any engine
/// (Σ min d² / n).
pub fn validation_mse(
    data: &Data,
    centroids: &Centroids,
    engine: &dyn AssignEngine,
    pool: &Pool,
) -> f64 {
    let (total, _) =
        engine.score(data, Sel::Range(0, data.n()), centroids, pool);
    total / data.n() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian::GaussianMixture;
    use crate::data::rcv1::Rcv1Sim;
    use crate::kmeans::init;
    use crate::util::propcheck::Cases;

    #[test]
    fn native_matches_bruteforce_and_parallel_matches_serial() {
        Cases::new(15).run(|rng| {
            let n = 100 + rng.below(900);
            let k = 2 + rng.below(10);
            let data = GaussianMixture::default_spec(k, 8)
                .generate(n, rng.next_u64());
            let cent = init::first_k(&data, k);
            let eng = NativeEngine::default();
            let mut l1 = vec![0u32; n];
            let mut d1 = vec![0f32; n];
            let calcs = eng.assign(
                &data,
                Sel::Range(0, n),
                &cent,
                &Pool::new(1),
                &mut l1,
                &mut d1,
            );
            assert_eq!(calcs, (n * k) as u64);
            let mut l4 = vec![0u32; n];
            let mut d4 = vec![0f32; n];
            eng.assign(&data, Sel::Range(0, n), &cent, &Pool::new(4), &mut l4, &mut d4);
            assert_eq!(l1, l4);
            assert_eq!(d1, d4);
            // spot-check against Data::nearest (per-point path must be
            // bit-identical to the blocked engine path)
            for i in (0..n).step_by(37) {
                let (j, d2) = data.nearest(i, &cent.c, &cent.norms);
                assert_eq!(l1[i], j);
                assert_eq!(d1[i], d2);
            }
        });
    }

    #[test]
    fn list_selection_matches_range() {
        let data = GaussianMixture::default_spec(3, 5).generate(50, 7);
        let cent = init::first_k(&data, 3);
        let eng = NativeEngine::default();
        let pool = Pool::new(2);
        let idx: Vec<usize> = (10..30).collect();
        let mut ll = vec![0u32; 20];
        let mut dl = vec![0f32; 20];
        eng.assign(&data, Sel::List(&idx), &cent, &pool, &mut ll, &mut dl);
        let mut lr = vec![0u32; 20];
        let mut dr = vec![0f32; 20];
        eng.assign(&data, Sel::Range(10, 30), &cent, &pool, &mut lr, &mut dr);
        assert_eq!(ll, lr);
        assert_eq!(dl, dr);
    }

    #[test]
    fn score_equals_sum_of_d2() {
        let data = GaussianMixture::default_spec(4, 6).generate(80, 3);
        let cent = init::first_k(&data, 4);
        let eng = NativeEngine::default();
        let pool = Pool::new(1);
        let (total, _) = eng.score(&data, Sel::Range(0, 80), &cent, &pool);
        let mse = validation_mse(&data, &cent, &eng, &pool);
        assert!((total / 80.0 - mse).abs() < 1e-12);
        let oracle = crate::kmeans::state::exact_mse(&data, &cent);
        assert!((mse - oracle).abs() < 1e-9 * (1.0 + oracle));
    }

    #[test]
    fn dist_rows_matches_pointwise() {
        let data = GaussianMixture::default_spec(3, 7).generate(40, 2);
        let cent = init::first_k(&data, 3);
        let mut out = vec![0f32; 40 * 3];
        let calcs = NativeEngine::default().dist_rows(
            &data,
            Sel::Range(0, 40),
            &cent,
            &Pool::new(3),
            &mut out,
        );
        assert_eq!(calcs, 120);
        for i in 0..40 {
            for j in 0..3 {
                let e = data.sq_dist_to(i, cent.c.row(j), cent.norms[j]);
                assert_eq!(out[i * 3 + j], e);
            }
        }
    }

    #[test]
    fn dist_rows_fans_out_at_100_rows() {
        // regression for the MIN_CHUNK.max(64) no-op: 100 rows on a
        // multi-thread pool must split into >1 chunk...
        let ranges = chunk_ranges(100, 4, DIST_ROWS_MIN_CHUNK);
        assert!(
            ranges.len() > 1,
            "100-row dist_rows stayed serial: {ranges:?}"
        );
        // ...and the fanned-out result must equal the serial one exactly
        let data = GaussianMixture::default_spec(4, 6).generate(100, 5);
        let cent = init::first_k(&data, 4);
        let mut par = vec![0f32; 100 * 4];
        let mut ser = vec![0f32; 100 * 4];
        NativeEngine::default().dist_rows(&data, Sel::Range(0, 100), &cent, &Pool::new(4), &mut par);
        NativeEngine::default().dist_rows(&data, Sel::Range(0, 100), &cent, &Pool::new(1), &mut ser);
        assert_eq!(par, ser);
    }

    #[test]
    fn transpose_cache_hits_and_invalidates() {
        let data = Rcv1Sim::default().generate(200, 3);
        let mut cent = init::first_k(&data, 10);
        let cache = TransCache::default();
        let a = cache.fetch(&cent);
        let b = cache.fetch(&cent);
        assert!(Arc::ptr_eq(&a, &b), "same revision must hit the cache");
        assert_eq!((cache.hits(), cache.builds()), (1, 1));
        cent.touch();
        let c = cache.fetch(&cent);
        assert!(!Arc::ptr_eq(&a, &c), "touch() must invalidate");
        // a clone shares the revision, so it also hits
        let clone = cent.clone();
        let d = cache.fetch(&clone);
        assert!(Arc::ptr_eq(&c, &d));
        assert_eq!((cache.hits(), cache.builds()), (2, 2));
    }

    #[test]
    fn per_engine_caches_do_not_evict_each_other() {
        // two sessions' engines interleaving sparse assigns (exactly
        // the multi-model serving pattern): each engine must build its
        // transpose once and hit thereafter. The old process-global
        // slot rebuilt on every alternation.
        let data_a = Rcv1Sim::default().generate(200, 1);
        let data_b = Rcv1Sim::default().generate(200, 2);
        let cent_a = init::first_k(&data_a, 10);
        let cent_b = init::first_k(&data_b, 10);
        let eng_a = NativeEngine::default();
        let eng_b = NativeEngine::default();
        let pool = Pool::new(2);
        let mut lbl = vec![0u32; 200];
        let mut d2 = vec![0f32; 200];
        for _ in 0..3 {
            eng_a.assign(&data_a, Sel::Range(0, 200), &cent_a, &pool, &mut lbl, &mut d2);
            eng_b.assign(&data_b, Sel::Range(0, 200), &cent_b, &pool, &mut lbl, &mut d2);
        }
        let (hits_a, builds_a) = eng_a.trans_cache_stats().unwrap();
        let (hits_b, builds_b) = eng_b.trans_cache_stats().unwrap();
        assert_eq!(builds_a, 1, "engine A rebuilt its unchanged transpose");
        assert_eq!(builds_b, 1, "engine B rebuilt its unchanged transpose");
        assert_eq!(hits_a, 2);
        assert_eq!(hits_b, 2);
        // a cloned engine shares the cache (same session handle)
        let clone_a = eng_a.clone();
        clone_a.assign(&data_a, Sel::Range(0, 200), &cent_a, &pool, &mut lbl, &mut d2);
        assert_eq!(eng_a.trans_cache_stats().unwrap(), (3, 1));
    }

    #[test]
    fn sparse_assign_tracks_centroid_updates_through_cache() {
        // end-to-end guard against stale transposes: assign, move the
        // centroids through the update path, assign again — results
        // must match the uncached per-point oracle both times
        let data = Rcv1Sim::default().generate(300, 9);
        let mut cent = init::first_k(&data, 12);
        let pool = Pool::new(2);
        let eng = NativeEngine::default();
        for round in 0..3 {
            let n = data.n();
            let mut lbl = vec![0u32; n];
            let mut d2 = vec![0f32; n];
            eng.assign(&data, Sel::Range(0, n), &cent, &pool, &mut lbl, &mut d2);
            for i in (0..n).step_by(29) {
                let (j, e) = data.nearest(i, &cent.c, &cent.norms);
                // transposed kernel may tie-break differently; distances
                // must agree to fp tolerance
                assert!(
                    (d2[i] - e).abs() <= 1e-3 * (1.0 + e.abs()),
                    "round {round} i={i}: {} vs oracle {e} (lbl {} vs {j})",
                    d2[i],
                    lbl[i]
                );
            }
            // move the centroids via the statistics path (bumps rev)
            let stats = crate::kmeans::par_add_stats(
                &data,
                Sel::Range(0, n),
                &lbl,
                &d2,
                12,
                &pool,
            );
            stats.update_centroids(&mut cent);
        }
    }

    #[test]
    fn sparse_assign_bit_identical_to_gather_oracle() {
        // the transposed + blocked + pruned path vs the per-point
        // gather path: AXPY lanes accumulate in spdot order, so labels
        // and distances must agree bit-for-bit (not just to tolerance)
        if simd::tier() == simd::Tier::Avx2Fma {
            return; // the opt-in FMA tier is documented as unfaithful
        }
        Cases::new(8).run(|rng| {
            let n = 200 + rng.below(300);
            let k = 8 + rng.below(12);
            let data = Rcv1Sim {
                vocab: 400,
                topic_vocab: 50,
                ..Default::default()
            }
            .generate(n, rng.next_u64());
            let cent = init::first_k(&data, k);
            let eng = NativeEngine::default();
            let pool = Pool::new(2);
            let mut lbl = vec![0u32; n];
            let mut d2 = vec![0f32; n];
            eng.assign(&data, Sel::Range(0, n), &cent, &pool, &mut lbl, &mut d2);
            // the transpose must actually be in play for this to test
            // the blocked path
            assert_eq!(eng.trans_cache_stats().unwrap().1, 1);
            for i in 0..n {
                let (j, e) = data.nearest(i, &cent.c, &cent.norms);
                assert_eq!(lbl[i], j, "label i={i}");
                assert_eq!(d2[i].to_bits(), e.to_bits(), "d2 i={i}");
            }
        });
    }

    #[test]
    fn warm_cache_serves_small_selections_without_building() {
        // the warm-path shortcut: a small (n < 64) sparse selection
        // would normally skip the transpose; once the cache holds the
        // current revision it must probe-hit and reuse it, never build
        if simd::tier() == simd::Tier::Avx2Fma {
            return; // the opt-in FMA tier is documented as unfaithful
        }
        let data = Rcv1Sim::default().generate(100, 4);
        let cent = init::first_k(&data, 10);
        let pool = Pool::new(1);
        let eng = NativeEngine::default();
        // warm the cache with one gate-passing selection
        let mut wl = vec![0u32; 100];
        let mut wd = vec![0f32; 100];
        eng.assign(&data, Sel::Range(0, 100), &cent, &pool, &mut wl, &mut wd);
        assert_eq!(eng.trans_cache_stats().unwrap(), (0, 1));
        let mut lbl = vec![0u32; 8];
        let mut d2 = vec![0f32; 8];
        eng.assign(&data, Sel::Range(0, 8), &cent, &pool, &mut lbl, &mut d2);
        eng.assign(&data, Sel::Range(0, 8), &cent, &pool, &mut lbl, &mut d2);
        assert_eq!(
            eng.trans_cache_stats().unwrap(),
            (2, 1),
            "warm engine must probe-hit small selections, never rebuild"
        );
        // the injected-transpose path (published-model predicts) serves
        // a cold engine without touching its cache at all
        let tc = eng.trans_handle(&cent).expect("gates pass");
        let inj = NativeEngine::default();
        let mut li = vec![0u32; 8];
        let mut di = vec![0f32; 8];
        inj.assign_with_trans(
            &data,
            Sel::Range(0, 8),
            &cent,
            &pool,
            Some(tc),
            &mut li,
            &mut di,
        );
        assert_eq!(
            inj.trans_cache_stats().unwrap(),
            (1, 0),
            "injected transpose must count a shared hit and no build"
        );
        // and the answers equal the cold gather path bitwise
        let plain = NativeEngine::default();
        let mut lbl2 = vec![0u32; 8];
        let mut d2b = vec![0f32; 8];
        plain.assign(&data, Sel::Range(0, 8), &cent, &pool, &mut lbl2, &mut d2b);
        assert_eq!(
            plain.trans_cache_stats().unwrap(),
            (0, 0),
            "a small cold selection must not build a transpose"
        );
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(lbl, lbl2);
        assert_eq!(li, lbl2);
        assert_eq!(bits(&d2), bits(&d2b));
        assert_eq!(bits(&di), bits(&d2b));
    }

    #[test]
    fn empty_selection_ok() {
        let data = GaussianMixture::default_spec(2, 3).generate(5, 0);
        let cent = init::first_k(&data, 2);
        let mut l = [];
        let mut d = [];
        let c = NativeEngine::default().assign(
            &data,
            Sel::Range(2, 2),
            &cent,
            &Pool::new(4),
            &mut l,
            &mut d,
        );
        assert_eq!(c, 0);
    }
}
