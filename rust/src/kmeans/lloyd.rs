//! Lloyd's exact k-means: the paper's quality baseline.
//!
//! Every round assigns all N points (Eq. 1) and recomputes centroids as
//! exact means (Eq. 2). MSE is monotonically non-increasing and the
//! algorithm stops at a fixed point (no assignment changes) — both
//! properties are integration-tested.

use crate::kmeans::assign::Sel;
use crate::kmeans::state::{batch_mse, Assignments, Centroids, SuffStats, UNASSIGNED};
use crate::kmeans::{Clusterer, Ctx, RoundInfo};

pub struct Lloyd {
    cent: Centroids,
    assign: Assignments,
    n: usize,
    fixed_point: bool,
}

impl Lloyd {
    pub fn new(cent: Centroids, n: usize) -> Self {
        Self { cent, assign: Assignments::new(n), n, fixed_point: false }
    }
}

impl Clusterer for Lloyd {
    fn round(&mut self, ctx: &mut Ctx) -> RoundInfo {
        let k = self.cent.k();
        let mut lbl = vec![0u32; self.n];
        let mut d2 = vec![0f32; self.n];
        let calcs = ctx.engine.assign(
            ctx.data,
            Sel::Range(0, self.n),
            &self.cent,
            &ctx.pool,
            &mut lbl,
            &mut d2,
        );
        let changed = lbl
            .iter()
            .zip(&self.assign.label)
            .filter(|(a, b)| a != b)
            .count() as u64;
        let first_round = self.assign.label[0] == UNASSIGNED;
        self.assign.label.copy_from_slice(&lbl);
        self.assign.dist2.copy_from_slice(&d2);
        // exact means from scratch (parallel)
        let stats = crate::kmeans::par_add_stats(
            ctx.data,
            Sel::Range(0, self.n),
            &lbl,
            &d2,
            k,
            &ctx.pool,
        );
        let train_mse = batch_mse(&stats);
        stats.update_centroids(&mut self.cent);
        self.fixed_point = !first_round && changed == 0;
        RoundInfo {
            dist_calcs: calcs,
            bound_skips: 0,
            changed,
            batch: self.n,
            train_mse,
        }
    }

    fn centroids(&self) -> &Centroids {
        &self.cent
    }

    fn converged(&self) -> bool {
        self.fixed_point
    }

    fn name(&self) -> String {
        "lloyd".into()
    }
}

/// Exposed for tests: one reference Lloyd round, fully serial.
pub fn reference_round(
    data: &crate::data::Data,
    cent: &mut Centroids,
    labels: &mut [u32],
) -> f64 {
    let k = cent.k();
    let mut stats = SuffStats::zeros(k, data.dim());
    let mut total = 0f64;
    for i in 0..data.n() {
        let (j, d2) = data.nearest(i, &cent.c, &cent.norms);
        labels[i] = j;
        stats.add_point(data, i, j, d2);
        total += d2 as f64;
    }
    stats.update_centroids(cent);
    total / data.n() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Algo, RunConfig};
    use crate::data::gaussian::GaussianMixture;
    use crate::kmeans::state::exact_mse;
    use crate::kmeans::{init, run};

    #[test]
    fn mse_monotone_and_converges() {
        let data = GaussianMixture::default_spec(4, 6).generate(800, 5);
        let cfg = RunConfig {
            algo: Algo::Lloyd,
            k: 4,
            max_seconds: 30.0,
            max_rounds: 200,
            seed: 3,
            threads: 2,
            ..Default::default()
        };
        let out = run(&data, None, &cfg).unwrap();
        let mses: Vec<f64> =
            out.trace.records.iter().map(|r| r.train_mse).collect();
        for w in mses.windows(2) {
            assert!(
                w[1] <= w[0] * (1.0 + 1e-6),
                "MSE increased: {} -> {}",
                w[0],
                w[1]
            );
        }
        // converged: last round had zero changes
        assert_eq!(out.trace.records.last().unwrap().changed, 0);
    }

    #[test]
    fn parallel_matches_reference_serial() {
        let data = GaussianMixture::default_spec(3, 5).generate(300, 8);
        // reference: 5 serial rounds
        let mut cent_ref = init::first_k(&data, 3);
        let mut labels = vec![0u32; 300];
        for _ in 0..5 {
            reference_round(&data, &mut cent_ref, &mut labels);
        }
        // driver: 5 rounds, 4 threads. Note run() shuffles, so compare
        // via MSE on the same unshuffled data by running seed-matched
        // shuffle manually.
        let shuffled = crate::data::shuffle::shuffled(&data, 11);
        let mut cent_ref2 = init::first_k(&shuffled, 3);
        let mut labels2 = vec![0u32; 300];
        for _ in 0..5 {
            reference_round(&shuffled, &mut cent_ref2, &mut labels2);
        }
        let cfg = RunConfig {
            algo: Algo::Lloyd,
            k: 3,
            max_rounds: 5,
            max_seconds: 30.0,
            seed: 11,
            threads: 4,
            stop_on_convergence: false,
            ..Default::default()
        };
        let out = run(&data, None, &cfg).unwrap();
        let a = exact_mse(&shuffled, &cent_ref2);
        let b = exact_mse(&shuffled, &out.centroids);
        assert!(
            (a - b).abs() < 1e-6 * (1.0 + a),
            "parallel {b} vs serial {a}"
        );
    }
}
