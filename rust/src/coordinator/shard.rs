//! Work sharding across scoped threads.
//!
//! [`Pool::run_chunks`] splits `0..n` into near-equal contiguous chunks,
//! runs a closure per chunk on worker threads, and returns results in
//! chunk order — deterministic regardless of scheduling, which the
//! reproducibility tests rely on. Output buffers are split with
//! [`split_outputs`] so each worker writes a disjoint region without
//! locks.

/// A (very small) thread pool descriptor. Threads are scoped per call:
/// for round-granularity work (≥ milliseconds) the ~10 µs spawn cost is
/// noise, and scoped borrows keep the API non-`'static`.
#[derive(Clone, Debug)]
pub struct Pool {
    pub threads: usize,
}

impl Pool {
    pub fn new(threads: usize) -> Self {
        Self { threads: threads.max(1) }
    }

    /// Use all available parallelism, unless the `NMBKM_THREADS`
    /// environment variable overrides it (clamped to ≥ 1). CI and
    /// serving deployments set the override to get deterministic thread
    /// counts independent of the host's core count.
    pub fn auto() -> Self {
        Self::auto_from(std::env::var("NMBKM_THREADS").ok().as_deref())
    }

    /// Pure core of [`Pool::auto`]: `override_val` is the raw
    /// `NMBKM_THREADS` value, if set. Unparsable values fall back to the
    /// host's parallelism. (Split out so tests never need `set_var`,
    /// which races with concurrent `getenv` in other test threads.)
    pub fn auto_from(override_val: Option<&str>) -> Self {
        if let Some(t) =
            override_val.and_then(|v| v.trim().parse::<usize>().ok())
        {
            return Self::new(t);
        }
        let t = std::thread::available_parallelism()
            .map(|x| x.get())
            .unwrap_or(1);
        Self::new(t)
    }

    /// Split `0..n` into chunks (at least `min_chunk` items each, except
    /// possibly the last) and run `f(chunk_index, range)` on each,
    /// in parallel when it pays. Results come back in chunk order.
    pub fn run_chunks<R, F>(&self, n: usize, min_chunk: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, std::ops::Range<usize>) -> R + Sync,
    {
        let ranges = chunk_ranges(n, self.threads, min_chunk);
        if ranges.len() <= 1 {
            return ranges
                .into_iter()
                .enumerate()
                .map(|(i, r)| f(i, r))
                .collect();
        }
        let mut out: Vec<Option<R>> = (0..ranges.len()).map(|_| None).collect();
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(ranges.len());
            for (slot, (i, r)) in out.iter_mut().zip(ranges.into_iter().enumerate()) {
                let f = &f;
                handles.push(scope.spawn(move || {
                    *slot = Some(f(i, r));
                }));
            }
            for h in handles {
                h.join().expect("worker panicked");
            }
        });
        out.into_iter().map(|x| x.unwrap()).collect()
    }
}

/// Contiguous near-equal chunks of `0..n`: at most `threads` chunks, each
/// at least `min_chunk` long (except a short final chunk when n is small).
pub fn chunk_ranges(n: usize, threads: usize, min_chunk: usize) -> Vec<std::ops::Range<usize>> {
    if n == 0 {
        return vec![];
    }
    let min_chunk = min_chunk.max(1);
    let max_chunks = n.div_ceil(min_chunk);
    let chunks = threads.max(1).min(max_chunks);
    let base = n / chunks;
    let rem = n % chunks;
    let mut out = Vec::with_capacity(chunks);
    let mut start = 0;
    for i in 0..chunks {
        let len = base + usize::from(i < rem);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

/// Split two output slices into per-chunk disjoint mutable views matching
/// `chunk_ranges(n, …)`, so shards write results without synchronisation.
pub fn split_outputs<'a, A, B>(
    ranges: &[std::ops::Range<usize>],
    a: &'a mut [A],
    b: &'a mut [B],
) -> Vec<(&'a mut [A], &'a mut [B])> {
    let mut out = Vec::with_capacity(ranges.len());
    let mut rest_a = a;
    let mut rest_b = b;
    let mut consumed = 0usize;
    for r in ranges {
        let len = r.len();
        debug_assert_eq!(r.start, consumed);
        let (ha, ta) = rest_a.split_at_mut(len);
        let (hb, tb) = rest_b.split_at_mut(len);
        out.push((ha, hb));
        rest_a = ta;
        rest_b = tb;
        consumed += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_partition_exactly() {
        for &(n, t, m) in
            &[(0usize, 4usize, 1usize), (1, 4, 1), (10, 3, 1), (100, 7, 16), (5, 10, 1)]
        {
            let rs = chunk_ranges(n, t, m);
            let total: usize = rs.iter().map(|r| r.len()).sum();
            assert_eq!(total, n, "n={n} t={t} m={m}");
            for w in rs.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
            if let Some(first) = rs.first() {
                assert_eq!(first.start, 0);
            }
            assert!(rs.len() <= t.max(1));
        }
    }

    #[test]
    fn min_chunk_limits_fanout() {
        let rs = chunk_ranges(10, 8, 4);
        assert!(rs.len() <= 3, "{rs:?}");
    }

    #[test]
    fn run_chunks_covers_all_items() {
        let pool = Pool::new(4);
        let touched = AtomicUsize::new(0);
        let sums = pool.run_chunks(1000, 1, |_, r| {
            touched.fetch_add(r.len(), Ordering::Relaxed);
            r.sum::<usize>()
        });
        assert_eq!(touched.load(Ordering::Relaxed), 1000);
        assert_eq!(sums.iter().sum::<usize>(), 999 * 1000 / 2);
    }

    #[test]
    fn results_in_chunk_order() {
        let pool = Pool::new(8);
        let ids = pool.run_chunks(64, 1, |i, _| i);
        assert_eq!(ids, (0..ids.len()).collect::<Vec<_>>());
    }

    #[test]
    fn auto_honors_thread_env_override() {
        // exercised through the pure core — mutating the real environment
        // from a parallel test harness is a getenv/setenv data race
        assert_eq!(Pool::auto_from(Some("3")).threads, 3);
        assert_eq!(Pool::auto_from(Some(" 5 ")).threads, 5);
        assert_eq!(Pool::auto_from(Some("0")).threads, 1, "clamped to >= 1");
        assert!(
            Pool::auto_from(Some("not-a-number")).threads >= 1,
            "garbage falls back to host parallelism"
        );
        assert!(Pool::auto_from(None).threads >= 1);
        assert!(Pool::auto().threads >= 1);
    }

    #[test]
    fn serial_pool_works() {
        let pool = Pool::new(1);
        let v = pool.run_chunks(10, 1, |_, r| r.len());
        assert_eq!(v, vec![10]);
    }

    #[test]
    fn split_outputs_disjoint_and_writable() {
        let ranges = chunk_ranges(10, 3, 1);
        let mut a = vec![0u32; 10];
        let mut b = vec![0f32; 10];
        {
            let views = split_outputs(&ranges, &mut a, &mut b);
            assert_eq!(views.len(), ranges.len());
            for (i, (va, vb)) in views.into_iter().enumerate() {
                for x in va.iter_mut() {
                    *x = i as u32;
                }
                vb.fill(i as f32);
            }
        }
        assert_eq!(a[0], 0);
        assert_eq!(*a.last().unwrap() as usize, ranges.len() - 1);
    }

    #[test]
    fn parallel_matches_serial() {
        let work = |_: usize, r: std::ops::Range<usize>| -> u64 {
            r.map(|x| (x as u64).wrapping_mul(2654435761)).sum()
        };
        let serial: Vec<u64> = Pool::new(1).run_chunks(5000, 1, work);
        let par: Vec<u64> = Pool::new(8).run_chunks(5000, 1, work);
        assert_eq!(
            serial.iter().sum::<u64>(),
            par.iter().sum::<u64>()
        );
    }
}
