//! Stress: many pipelined binary frames in flight on one TCP
//! connection. The frame loop reads requests and writes responses on
//! the same thread, so a client that pumps requests without draining
//! responses exercises request queueing in the socket buffers; a writer
//! thread keeps the pump full while the main thread drains. Responses
//! must come back in order, every one bit-identical to the unloaded
//! reference — and the server's frame counters must account for every
//! frame. A second phase keeps training steps running on another
//! connection while the pipeline is full.

use nmbkm::config::{Algo, Rho, RunConfig};
use nmbkm::data::{Data, Storage};
use nmbkm::serve::observe::serve_metrics;
use nmbkm::serve::{frame, session, ModelRegistry};
use nmbkm::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

fn cfg(k: usize, b0: usize, rounds: usize) -> RunConfig {
    RunConfig {
        algo: Algo::TbRho,
        k,
        b0,
        rho: Rho::Infinite,
        threads: 2,
        seed: 23,
        max_rounds: rounds,
        max_seconds: 60.0,
        eval_every_secs: 0.0,
        ..Default::default()
    }
}

fn sparse_corpus(n: usize, seed: u64) -> Data {
    nmbkm::data::rcv1::Rcv1Sim {
        vocab: 300,
        topic_vocab: 40,
        ..Default::default()
    }
    .generate(n, seed)
}

fn sparse_rows(data: &Data, lo: usize, hi: usize) -> Vec<(Vec<u32>, Vec<f32>)> {
    let Storage::Sparse(m) = &data.storage else {
        panic!("corpus must be sparse");
    };
    (lo..hi)
        .map(|i| {
            let (idx, vals) = m.row(i);
            (idx.to_vec(), vals.to_vec())
        })
        .collect()
}

fn predict_frame(batch: &[(Vec<u32>, Vec<f32>)], dim: usize) -> Vec<u8> {
    let body = frame::encode_sparse_points(dim, batch).unwrap();
    let mut out = Vec::new();
    frame::write_frame(
        &mut out,
        &Json::parse(r#"{"op":"predict"}"#).unwrap(),
        &body,
    )
    .unwrap();
    out
}

#[test]
fn pipelined_binary_frames_stay_ordered_and_bit_exact_under_load() {
    let listener = match TcpListener::bind("127.0.0.1:0") {
        Ok(l) => l,
        Err(_) => {
            eprintln!("skipping: cannot bind loopback");
            return;
        }
    };
    let addr = listener.local_addr().unwrap();
    let data = sparse_corpus(500, 17);
    let dim = data.dim();
    let (s, _) = session::train(&data, &cfg(8, 128, 4)).unwrap();
    let reg = Arc::new(ModelRegistry::with_default(s));
    let server = std::thread::spawn(move || {
        nmbkm::serve::server::serve_listener_opts(reg, listener, true).unwrap();
    });

    // 12 distinct query batches, cycled into 240 in-flight frames
    const DISTINCT: usize = 12;
    const IN_FLIGHT: usize = 240;
    let batches: Vec<Vec<(Vec<u32>, Vec<f32>)>> = (0..DISTINCT)
        .map(|b| sparse_rows(&data, b * 8, b * 8 + 8))
        .collect();
    let frames: Vec<Vec<u8>> =
        batches.iter().map(|b| predict_frame(b, dim)).collect();

    // unloaded reference answers, one frame at a time
    let mut expected = Vec::with_capacity(DISTINCT);
    {
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(&[frame::MAGIC]).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        for f in &frames {
            conn.write_all(f).unwrap();
            let (h, body) = frame::read_frame(&mut reader).unwrap().unwrap();
            assert_eq!(h.get("ok").unwrap().as_bool(), Some(true), "{h:?}");
            let (lbl, d2) = frame::decode_predict_body(&body).unwrap();
            expected.push((
                lbl,
                d2.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
            ));
        }
    }

    let frames_before = serve_metrics().frames.get();

    // training pressure on a second connection for the whole stress
    // run. It trains its OWN model ("aux"): registry-level churn —
    // session locking, publishes, event-log writes — without moving the
    // default model the pipelined predicts are asserted against
    // (per-model snapshot isolation is exactly the property under test)
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let trainer_stop = stop.clone();
    let trainer = std::thread::spawn(move || {
        let mut conn = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        let mut req = |conn: &mut TcpStream,
                       reader: &mut BufReader<TcpStream>,
                       line: &mut String,
                       msg: &str| {
            conn.write_all(msg.as_bytes()).unwrap();
            conn.write_all(b"\n").unwrap();
            line.clear();
            reader.read_line(line).unwrap();
            assert!(line.contains("\"ok\":true"), "trainer request failed: {line}");
        };
        req(
            &mut conn,
            &mut reader,
            &mut line,
            r#"{"op":"create","model":"aux","k":4,"dim":3,"algo":"gb","b0":16,"seed":4}"#,
        );
        let pts: Vec<String> = (0..32)
            .map(|i| format!("[{},1.0,{}]", i as f32, 0.5 * i as f32))
            .collect();
        req(
            &mut conn,
            &mut reader,
            &mut line,
            &format!(
                "{{\"op\":\"ingest\",\"model\":\"aux\",\"points\":[{}]}}",
                pts.join(",")
            ),
        );
        while !trainer_stop.load(std::sync::atomic::Ordering::SeqCst) {
            req(
                &mut conn,
                &mut reader,
                &mut line,
                r#"{"op":"step","model":"aux","rounds":1}"#,
            );
        }
    });

    // the loaded connection: a writer thread pumps all frames without
    // waiting for responses (the two directions must not deadlock even
    // with hundreds of frames in the socket buffers), the main thread
    // drains responses in order
    let mut conn = TcpStream::connect(addr).unwrap();
    conn.write_all(&[frame::MAGIC]).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut wconn = conn.try_clone().unwrap();
    let wframes = frames.clone();
    let writer = std::thread::spawn(move || {
        for t in 0..IN_FLIGHT {
            wconn.write_all(&wframes[t % DISTINCT]).unwrap();
        }
        wconn.flush().unwrap();
    });
    for t in 0..IN_FLIGHT {
        let (h, body) = frame::read_frame(&mut reader).unwrap().unwrap();
        assert_eq!(
            h.get("ok").unwrap().as_bool(),
            Some(true),
            "frame {t}: {h:?}"
        );
        let (lbl, d2) = frame::decode_predict_body(&body).unwrap();
        let (elbl, ed2) = &expected[t % DISTINCT];
        assert_eq!(&lbl, elbl, "frame {t}: labels out of order or wrong");
        assert_eq!(
            &d2.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
            ed2,
            "frame {t}: d2 bits drifted under load"
        );
    }
    writer.join().unwrap();
    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    trainer.join().unwrap();

    // every pipelined frame is accounted for (other tests in this
    // process may add to the counter; it can only overshoot)
    let frames_after = serve_metrics().frames.get();
    assert!(
        frames_after >= frames_before + IN_FLIGHT as u64,
        "frame counter lost frames: {frames_before} -> {frames_after}"
    );

    // a fresh JSONL connection shuts the server down cleanly
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    conn.write_all(b"{\"op\":\"shutdown\"}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":true"), "{line}");
    server.join().unwrap();
}
