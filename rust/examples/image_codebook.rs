//! Vector-quantisation codebook over deformed digits — the paper's
//! infMNIST scenario as a downstream application.
//!
//! Learns a k=64 codebook on the dense 784-dim infMNIST simulator with
//! three algorithms under the same small time budget and compares (a)
//! codebook quality (validation MSE) and (b) a compression proxy: mean
//! quantisation error when encoding unseen digits with the learned
//! codebook.
//!
//! ```bash
//! cargo run --release --example image_codebook
//! ```

use nmbkm::config::{Algo, Rho, RunConfig};
use nmbkm::data::infmnist::InfMnist;
use nmbkm::kmeans;

fn main() -> anyhow::Result<()> {
    let ds = InfMnist::default().dataset(30_000, 5_000, 11);
    println!("dataset: {}", ds.summary());
    let budget = 8.0;
    let threads = std::thread::available_parallelism()?.get();

    let mut results = Vec::new();
    for (algo, rho) in [
        (Algo::Mb, Rho::Infinite),
        (Algo::MbF, Rho::Infinite),
        (Algo::TbRho, Rho::Infinite),
    ] {
        let cfg = RunConfig {
            algo,
            rho,
            k: 64,
            b0: 1_000,
            max_seconds: budget,
            threads,
            eval_every_secs: budget, // final score only
            ..Default::default()
        };
        let out = kmeans::run(&ds.train, Some(&ds.val), &cfg)?;
        println!(
            "{:<6} {:>4} rounds in {:.2}s  → codebook MSE {:.5}",
            cfg.label(),
            out.rounds,
            out.work_secs,
            out.final_mse
        );
        results.push((cfg.label(), out));
    }

    // encode a fresh batch with each codebook: mean quantisation error
    let fresh = InfMnist::default().generate(2_000, 999);
    println!("\nencoding 2000 unseen digits:");
    for (label, out) in &results {
        let mut err = 0f64;
        for i in 0..fresh.n() {
            let (_, d2) = fresh.nearest(i, &out.centroids.c, &out.centroids.norms);
            err += d2 as f64;
        }
        println!(
            "  {label:<6} mean quantisation error {:.5}",
            err / fresh.n() as f64
        );
    }
    println!(
        "\n(the tb codebook should match or beat mb under the same budget — \
         that is Figure 1's claim applied downstream)"
    );
    Ok(())
}
