"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth for the L1 kernels in
``distance.py``: pytest asserts allclose between kernel and oracle across
shape/dtype sweeps (see ``python/tests/``). Keep these maximally simple —
no tiling, no tricks — so that a disagreement always indicts the kernel.
"""

import jax.numpy as jnp


def assign_ref(x, c):
    """Exact assignment step.

    Args:
      x: (B, D) batch of datapoints.
      c: (K, D) centroids.

    Returns:
      (labels (B,) int32, d2 (B,) float32): index of the nearest centroid
      and the squared distance to it.
    """
    # (B, K) full squared-distance matrix, computed the naive way.
    diff = x[:, None, :] - c[None, :, :]
    d2 = jnp.sum(diff * diff, axis=-1)
    labels = jnp.argmin(d2, axis=1).astype(jnp.int32)
    return labels, jnp.min(d2, axis=1)


def distmat_ref(x, c):
    """Full (B, K) squared-distance matrix, naive form."""
    diff = x[:, None, :] - c[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def cluster_stats_ref(x, labels, d2, k):
    """Per-cluster sufficient statistics.

    Args:
      x: (B, D) batch, labels: (B,) int32 assignments, d2: (B,) squared
      distances to assigned centroid, k: number of clusters.

    Returns:
      (S (K, D) per-cluster coordinate sums, v (K,) counts,
       sse (K,) per-cluster sum of squared errors).
    """
    onehot = (labels[:, None] == jnp.arange(k)[None, :]).astype(x.dtype)
    s = onehot.T @ x
    v = jnp.sum(onehot, axis=0)
    sse = onehot.T @ d2
    return s, v, sse


def bound_screen_ref(lb, p, d, labels):
    """Vectorised Elkan bound screen (paper Alg. 3 / tb-ρ lines 12-15).

    Decays each lower bound by the distance its centroid moved
    (``l ← l − p``), then flags points for which some non-assigned
    centroid's bound dips below the (stale) upper distance d(i): those
    points are *dirty* and need a full distance recomputation.

    Args:
      lb: (B, K) lower bounds, p: (K,) centroid displacements,
      d: (B,) distance to currently assigned centroid,
      labels: (B,) int32 current assignments.

    Returns:
      (lb' (B, K) decayed bounds, dirty (B,) int32 0/1 flags).
    """
    lb2 = lb - p[None, :]
    k = lb.shape[1]
    not_assigned = labels[:, None] != jnp.arange(k)[None, :]
    trigger = jnp.logical_and(lb2 < d[:, None], not_assigned)
    dirty = jnp.any(trigger, axis=1).astype(jnp.int32)
    return lb2, dirty
