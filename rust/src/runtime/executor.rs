//! The XLA assignment engine: executes the AOT Pallas/XLA programs on
//! the PJRT CPU client from the rust hot path.
//!
//! Dispatch rules (shape menu from the manifest):
//! * batch tiles: the selection is cut into the largest compiled batch
//!   that fits (2048), with the 256-row tile mopping up remainders;
//! * dims: inputs are zero-padded to the smallest compiled d ≥ data d —
//!   zero columns contribute nothing to distances;
//! * clusters: centroids are padded to the compiled k with zero rows
//!   whose advertised ‖c‖² is +BIG, so padded centroids never win the
//!   argmin.
//!
//! Sparse data or dims beyond the compiled menu fall back to the native
//! engine (CSR gather loops are exactly what the scalar path is for);
//! the fallback is recorded and surfaced via [`XlaEngine::stats`].

use crate::coordinator::shard::Pool;
use crate::data::Data;
use crate::kmeans::assign::{AssignEngine, NativeEngine, Sel};
use crate::kmeans::state::Centroids;
use crate::runtime::artifact::Manifest;
use anyhow::{anyhow, Context, Result};
use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::path::Path;

/// Squared-norm advertised for padding centroids: far beyond any real
/// distance, well inside f32 range.
const PAD_CNORM: f32 = 1e30;

/// Execution statistics (observability + tests).
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    pub xla_calls: u64,
    pub xla_points: u64,
    pub native_fallbacks: u64,
}

pub struct XlaEngine {
    manifest: Manifest,
    execs: HashMap<String, xla::PjRtLoadedExecutable>,
    native: NativeEngine,
    stats: RefCell<EngineStats>,
    warned_fallback: Cell<bool>,
}

impl XlaEngine {
    /// Load the manifest and compile every program on the CPU client.
    pub fn load(artifacts_dir: &str) -> Result<XlaEngine> {
        let dir = Path::new(artifacts_dir);
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let mut execs = HashMap::new();
        for entry in &manifest.entries {
            let proto = xla::HloModuleProto::from_text_file(&entry.file)
                .map_err(|e| {
                    anyhow!("parse {:?}: {e:?}", entry.file)
                })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", entry.name))?;
            execs.insert(entry.name.clone(), exe);
        }
        Ok(XlaEngine {
            manifest,
            execs,
            native: NativeEngine::default(),
            stats: RefCell::new(EngineStats::default()),
            warned_fallback: Cell::new(false),
        })
    }

    pub fn stats(&self) -> EngineStats {
        *self.stats.borrow()
    }

    pub fn kpad(&self) -> usize {
        self.manifest.k
    }

    /// Can this engine serve the workload natively on XLA?
    fn supports(&self, data: &Data, k: usize) -> bool {
        !data.is_sparse()
            && k <= self.manifest.k
            && self.manifest.fit_dim(data.dim()).is_some()
    }

    fn note_fallback(&self) {
        self.stats.borrow_mut().native_fallbacks += 1;
        if !self.warned_fallback.replace(true) {
            eprintln!(
                "[nmbkm::runtime] workload outside the compiled shape menu \
                 (sparse or d too large) — using the native engine"
            );
        }
    }

    /// Pad centroids to (kpad, dpad) + the poisoned-norm vector.
    fn pack_centroids(
        &self,
        cent: &Centroids,
        dpad: usize,
    ) -> Result<(xla::Literal, xla::Literal)> {
        let (k, d) = (cent.k(), cent.d());
        let kpad = self.manifest.k;
        let mut buf = vec![0f32; kpad * dpad];
        for j in 0..k {
            buf[j * dpad..j * dpad + d].copy_from_slice(cent.c.row(j));
        }
        let mut norms = vec![PAD_CNORM; kpad];
        norms[..k].copy_from_slice(&cent.norms);
        let c_lit = xla::Literal::vec1(&buf)
            .reshape(&[kpad as i64, dpad as i64])
            .map_err(|e| anyhow!("reshape centroids: {e:?}"))?;
        let n_lit = xla::Literal::vec1(&norms);
        Ok((c_lit, n_lit))
    }

    /// Pack `count` selected rows starting at `off` into a (b, dpad)
    /// zero-padded literal.
    fn pack_batch(
        &self,
        data: &Data,
        sel: &Sel,
        off: usize,
        count: usize,
        b: usize,
        dpad: usize,
    ) -> Result<xla::Literal> {
        let d = data.dim();
        let mut buf = vec![0f32; b * dpad];
        for t in 0..count {
            let i = sel.nth(off + t);
            data.write_row_dense(i, &mut buf[t * dpad..t * dpad + d]);
        }
        xla::Literal::vec1(&buf)
            .reshape(&[b as i64, dpad as i64])
            .map_err(|e| anyhow!("reshape batch: {e:?}"))
    }

    fn exec(
        &self,
        name: &str,
        args: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self
            .execs
            .get(name)
            .ok_or_else(|| anyhow!("no artifact '{name}'"))?;
        let result = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("sync {name}: {e:?}"))?;
        result
            .to_tuple()
            .map_err(|e| anyhow!("untuple {name}: {e:?}"))
    }

    /// XLA-tiled assignment over a dense selection.
    fn assign_xla(
        &self,
        data: &Data,
        sel: &Sel,
        cent: &Centroids,
        out_lbl: &mut [u32],
        out_d2: &mut [f32],
    ) -> Result<u64> {
        let n = sel.len();
        let dpad = self
            .manifest
            .fit_dim(data.dim())
            .context("dim outside menu")?;
        let (c_lit, n_lit) = self.pack_centroids(cent, dpad)?;
        let mut off = 0usize;
        while off < n {
            let b = self.manifest.fit_batch(n - off);
            let count = (n - off).min(b);
            let x_lit = self.pack_batch(data, sel, off, count, b, dpad)?;
            let name = format!("assign_b{b}_d{dpad}_k{}", self.manifest.k);
            let outs = self.exec(&name, &[x_lit, c_lit.clone(), n_lit.clone()])?;
            let labels = outs[0]
                .to_vec::<i32>()
                .map_err(|e| anyhow!("labels: {e:?}"))?;
            let d2 = outs[1]
                .to_vec::<f32>()
                .map_err(|e| anyhow!("d2: {e:?}"))?;
            for t in 0..count {
                out_lbl[off + t] = labels[t] as u32;
                out_d2[off + t] = d2[t];
            }
            {
                let mut s = self.stats.borrow_mut();
                s.xla_calls += 1;
                s.xla_points += count as u64;
            }
            off += count;
        }
        Ok(n as u64 * cent.k() as u64)
    }

    /// XLA-tiled full distance rows.
    fn dist_rows_xla(
        &self,
        data: &Data,
        sel: &Sel,
        cent: &Centroids,
        out_d2: &mut [f32],
    ) -> Result<u64> {
        let n = sel.len();
        let k = cent.k();
        let kpad = self.manifest.k;
        let dpad = self
            .manifest
            .fit_dim(data.dim())
            .context("dim outside menu")?;
        let (c_lit, n_lit) = self.pack_centroids(cent, dpad)?;
        let mut off = 0usize;
        while off < n {
            let b = self.manifest.fit_batch(n - off);
            let count = (n - off).min(b);
            let x_lit = self.pack_batch(data, sel, off, count, b, dpad)?;
            let name = format!("distmat_b{b}_d{dpad}_k{kpad}");
            let outs = self.exec(&name, &[x_lit, c_lit.clone(), n_lit.clone()])?;
            let mat = outs[0]
                .to_vec::<f32>()
                .map_err(|e| anyhow!("distmat: {e:?}"))?;
            for t in 0..count {
                out_d2[(off + t) * k..(off + t + 1) * k]
                    .copy_from_slice(&mat[t * kpad..t * kpad + k]);
            }
            {
                let mut s = self.stats.borrow_mut();
                s.xla_calls += 1;
                s.xla_points += count as u64;
            }
            off += count;
        }
        Ok((n * k) as u64)
    }
}

impl AssignEngine for XlaEngine {
    fn assign(
        &self,
        data: &Data,
        sel: Sel,
        centroids: &Centroids,
        pool: &Pool,
        out_lbl: &mut [u32],
        out_d2: &mut [f32],
    ) -> u64 {
        if !self.supports(data, centroids.k()) {
            self.note_fallback();
            return self
                .native
                .assign(data, sel, centroids, pool, out_lbl, out_d2);
        }
        self.assign_xla(data, &sel, centroids, out_lbl, out_d2)
            .expect("XLA assign failed")
    }

    fn dist_rows(
        &self,
        data: &Data,
        sel: Sel,
        centroids: &Centroids,
        pool: &Pool,
        out_d2: &mut [f32],
    ) -> u64 {
        if !self.supports(data, centroids.k()) {
            self.note_fallback();
            return self
                .native
                .dist_rows(data, sel, centroids, pool, out_d2);
        }
        self.dist_rows_xla(data, &sel, centroids, out_d2)
            .expect("XLA dist_rows failed")
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian::GaussianMixture;
    use crate::kmeans::init;

    fn artifacts_dir() -> Option<String> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json")
            .exists()
            .then(|| dir.to_string_lossy().into_owned())
    }

    #[test]
    fn xla_assign_matches_native() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let engine = XlaEngine::load(&dir).unwrap();
        let pool = Pool::new(2);
        // n chosen to force both tile sizes + padding (2048 + 256 pad)
        let n = 2300;
        for (k, d) in [(7usize, 30usize), (50, 784), (64, 64)] {
            let data = GaussianMixture::default_spec(k.min(10), d)
                .generate(n, 42 + k as u64);
            let cent = init::first_k(&data, k);
            let mut lx = vec![0u32; n];
            let mut dx = vec![0f32; n];
            engine.assign(&data, Sel::Range(0, n), &cent, &pool, &mut lx, &mut dx);
            let mut ln = vec![0u32; n];
            let mut dn = vec![0f32; n];
            NativeEngine::default().assign(&data, Sel::Range(0, n), &cent, &pool, &mut ln, &mut dn);
            let mut mismatched_labels = 0;
            for i in 0..n {
                // tolerance scales with ‖x‖²: the norms-trick subtraction
                // amplifies f32 rounding when the true distance is tiny
                let tol = 1e-2 * (1.0 + dn[i].abs()) + 3e-6 * data.norms[i];
                assert!(
                    (dx[i] - dn[i]).abs() <= tol,
                    "k={k} d={d} i={i}: xla d2 {} vs native {}",
                    dx[i],
                    dn[i]
                );
                if lx[i] != ln[i] {
                    // ties may break differently; distances must agree
                    mismatched_labels += 1;
                }
            }
            assert!(
                mismatched_labels < n / 20,
                "k={k} d={d}: {mismatched_labels} label mismatches"
            );
        }
        assert!(engine.stats().xla_calls > 0);
    }

    #[test]
    fn xla_dist_rows_matches_native() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let engine = XlaEngine::load(&dir).unwrap();
        let pool = Pool::new(2);
        let (n, k, d) = (300usize, 13usize, 100usize);
        let data = GaussianMixture::default_spec(5, d).generate(n, 7);
        let cent = init::first_k(&data, k);
        let mut mx = vec![0f32; n * k];
        engine.dist_rows(&data, Sel::Range(0, n), &cent, &pool, &mut mx);
        let mut mn = vec![0f32; n * k];
        NativeEngine::default().dist_rows(&data, Sel::Range(0, n), &cent, &pool, &mut mn);
        for t in 0..n * k {
            let tol = 1e-2 * (1.0 + mn[t].abs()) + 3e-6 * data.norms[t / k];
            assert!(
                (mx[t] - mn[t]).abs() <= tol,
                "t={t}: {} vs {}",
                mx[t],
                mn[t]
            );
        }
    }

    #[test]
    fn sparse_falls_back_to_native() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let engine = XlaEngine::load(&dir).unwrap();
        let pool = Pool::new(1);
        let g = crate::data::rcv1::Rcv1Sim {
            vocab: 500,
            topic_vocab: 50,
            ..Default::default()
        };
        let data = g.generate(64, 3);
        let cent = init::first_k(&data, 4);
        let mut l = vec![0u32; 64];
        let mut d2 = vec![0f32; 64];
        engine.assign(&data, Sel::Range(0, 64), &cent, &pool, &mut l, &mut d2);
        assert!(engine.stats().native_fallbacks > 0);
        let mut ln = vec![0u32; 64];
        let mut dn = vec![0f32; 64];
        NativeEngine::default().assign(&data, Sel::Range(0, 64), &cent, &pool, &mut ln, &mut dn);
        assert_eq!(l, ln);
    }
}
