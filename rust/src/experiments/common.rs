//! Shared experiment plumbing: dataset construction at CI scale or
//! paper scale, multi-seed curve averaging, and the relative-MSE
//! presentation the paper's figures use (MSE relative to the best value
//! `V0` observed across all runs).

use crate::config::{Engine, RunConfig};
use crate::coordinator::progress::{results_dir, Table};
use crate::data::{gaussian::GaussianMixture, infmnist::InfMnist, rcv1::Rcv1Sim, Dataset};
use crate::kmeans::metrics::mse_on_grid;
use crate::kmeans::{run_prepared, RunOutcome};
use crate::util::stats;

/// Experiment scale. Paper scale reproduces §4 exactly (400k infMNIST /
/// 781k RCV1, 20 seeds) and takes hours; `Quick` keeps every mechanism
/// on a few-minute budget (DESIGN.md §Substitutions notes that curve
/// *shapes*, not absolute seconds, are the reproduction target).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Quick,
    Full,
}

impl Scale {
    pub fn from_env_or_args(args: &[String]) -> Scale {
        if args.iter().any(|a| a == "--full")
            || std::env::var("NMBKM_BENCH_FULL").ok().as_deref() == Some("1")
        {
            Scale::Full
        } else {
            Scale::Quick
        }
    }
}

/// Options shared by all experiments.
#[derive(Clone, Debug)]
pub struct ExpOpts {
    pub scale: Scale,
    pub seeds: u64,
    pub threads: usize,
    pub engine: Engine,
    /// work-time budget per run (seconds)
    pub seconds: f64,
}

impl ExpOpts {
    pub fn new(scale: Scale) -> Self {
        let threads = std::thread::available_parallelism()
            .map(|x| x.get())
            .unwrap_or(4)
            .min(8);
        match scale {
            Scale::Quick => Self {
                scale,
                seeds: 3,
                threads,
                engine: Engine::Native,
                seconds: 5.0,
            },
            Scale::Full => Self {
                scale,
                seeds: 20,
                threads,
                engine: Engine::Native,
                seconds: 60.0,
            },
        }
    }

    pub fn from_args(args: &[String]) -> Self {
        let mut o = Self::new(Scale::from_env_or_args(args));
        let get = |flag: &str| -> Option<String> {
            args.iter()
                .position(|a| a == flag)
                .and_then(|p| args.get(p + 1).cloned())
        };
        if let Some(s) = get("--seeds") {
            o.seeds = s.parse().unwrap_or(o.seeds);
        }
        if let Some(s) = get("--seconds") {
            o.seconds = s.parse().unwrap_or(o.seconds);
        }
        if let Some(s) = get("--threads") {
            o.threads = s.parse().unwrap_or(o.threads);
        }
        if args.iter().any(|a| a == "--engine-xla") {
            o.engine = Engine::Xla;
        }
        o
    }
}

/// The paper's two evaluation datasets, simulated (DESIGN.md
/// §Substitutions), at the requested scale.
pub fn infmnist(scale: Scale) -> Dataset {
    match scale {
        Scale::Quick => InfMnist::default().dataset(12_000, 2_000, 20_260_710),
        Scale::Full => InfMnist::default().dataset(400_000, 40_000, 20_260_710),
    }
}

pub fn rcv1(scale: Scale) -> Dataset {
    match scale {
        Scale::Quick => Rcv1Sim::default().dataset(15_000, 2_000, 20_260_710),
        Scale::Full => Rcv1Sim::default().dataset(781_265, 23_149, 20_260_710),
    }
}

pub fn gaussian_small() -> Dataset {
    GaussianMixture::default_spec(8, 32).dataset(5_000, 1_000, 20_260_710)
}

/// Paper batch sizes, scaled with the dataset (paper: b0 = 5000 at
/// N = 400k/781k; we keep b0/N in the same regime at quick scale).
pub fn default_b0(scale: Scale) -> usize {
    match scale {
        Scale::Quick => 500,
        Scale::Full => 5_000,
    }
}

/// One curve: an algorithm's validation-MSE trajectory averaged over
/// seeds on a common time grid.
#[derive(Clone, Debug)]
pub struct Curve {
    pub label: String,
    pub grid: Vec<f64>,
    pub mean: Vec<f64>,
    pub std: Vec<f64>,
    pub best_final: f64,
    pub mean_final: f64,
}

/// Run `cfg` over `seeds` seeds and average the (t, MSE) curves.
pub fn multi_seed_curve(
    ds: &Dataset,
    base: &RunConfig,
    opts: &ExpOpts,
    engine: &dyn crate::kmeans::assign::AssignEngine,
    grid: &[f64],
) -> anyhow::Result<(Curve, Vec<RunOutcome>)> {
    let mut outs = Vec::new();
    for seed in 0..opts.seeds {
        let cfg = RunConfig {
            seed,
            threads: opts.threads,
            max_seconds: opts.seconds,
            engine: opts.engine,
            ..base.clone()
        };
        let shuffled = crate::data::shuffle::shuffled(&ds.train, seed);
        outs.push(run_prepared(&shuffled, Some(&ds.val), &cfg, engine)?);
    }
    let per_seed: Vec<Vec<f64>> = outs
        .iter()
        .map(|o| mse_on_grid(&o.trace.mse_series(), grid))
        .collect();
    let mut mean = Vec::with_capacity(grid.len());
    let mut std_v = Vec::with_capacity(grid.len());
    for gi in 0..grid.len() {
        let vals: Vec<f64> = per_seed
            .iter()
            .map(|s| s[gi])
            .filter(|x| x.is_finite())
            .collect();
        mean.push(if vals.is_empty() { f64::NAN } else { stats::mean(&vals) });
        std_v.push(if vals.len() < 2 { 0.0 } else { stats::std(&vals) });
    }
    let finals: Vec<f64> = outs.iter().map(|o| o.final_mse).collect();
    let best_final = finals.iter().cloned().fold(f64::INFINITY, f64::min);
    let curve = Curve {
        label: base.label(),
        grid: grid.to_vec(),
        mean,
        std: std_v,
        best_final,
        mean_final: stats::mean(&finals),
    };
    Ok((curve, outs))
}

/// Geometric time grid from `lo` to `hi` seconds.
pub fn time_grid(lo: f64, hi: f64, points: usize) -> Vec<f64> {
    let ratio = (hi / lo).powf(1.0 / (points - 1) as f64);
    (0..points).map(|i| lo * ratio.powi(i as i32)).collect()
}

/// Write a figure-style CSV: one row per (algo, t) with mean/std MSE
/// relative to the global best V0 (the paper's presentation).
pub fn write_curves_csv(
    name: &str,
    dataset: &str,
    curves: &[Curve],
) -> std::io::Result<std::path::PathBuf> {
    let v0 = curves
        .iter()
        .map(|c| c.best_final)
        .fold(f64::INFINITY, f64::min);
    let mut t = Table::new(&[
        "algo", "dataset", "t_work", "mse_mean", "mse_std", "rel_mean", "v0",
    ]);
    for c in curves {
        for (gi, &g) in c.grid.iter().enumerate() {
            if !c.mean[gi].is_finite() {
                continue;
            }
            t.push(vec![
                c.label.clone(),
                dataset.to_string(),
                format!("{g:.4}"),
                format!("{:.8e}", c.mean[gi]),
                format!("{:.8e}", c.std[gi]),
                format!("{:.6}", c.mean[gi] / v0),
                format!("{v0:.8e}"),
            ]);
        }
    }
    let path = results_dir().join(format!("{name}.csv"));
    t.write_csv(&path)?;
    Ok(path)
}

/// Pretty-print the end-state comparison the figures make visually.
pub fn print_final_summary(dataset: &str, curves: &[Curve]) {
    let v0 = curves
        .iter()
        .map(|c| c.best_final)
        .fold(f64::INFINITY, f64::min);
    println!("-- {dataset}: final validation MSE relative to V0 = {v0:.6e}");
    let mut sorted: Vec<&Curve> = curves.iter().collect();
    sorted.sort_by(|a, b| a.mean_final.total_cmp(&b.mean_final));
    for c in sorted {
        println!(
            "   {:<10} mean_final/V0 = {:.4}   best_final/V0 = {:.4}",
            c.label,
            c.mean_final / v0,
            c.best_final / v0
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Algo, Rho};
    use crate::kmeans::assign::NativeEngine;

    #[test]
    fn time_grid_monotone() {
        let g = time_grid(0.05, 5.0, 12);
        assert_eq!(g.len(), 12);
        assert!((g[0] - 0.05).abs() < 1e-12);
        assert!((g[11] - 5.0).abs() < 1e-9);
        for w in g.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn multi_seed_curve_shapes() {
        let ds = gaussian_small();
        let opts = ExpOpts {
            scale: Scale::Quick,
            seeds: 2,
            threads: 2,
            engine: Engine::Native,
            seconds: 0.5,
        };
        let base = RunConfig {
            algo: Algo::TbRho,
            k: 8,
            b0: 256,
            rho: Rho::Infinite,
            eval_every_secs: 0.05,
            ..Default::default()
        };
        let grid = time_grid(0.02, 0.5, 8);
        let (curve, outs) =
            multi_seed_curve(&ds, &base, &opts, &NativeEngine::default(), &grid).unwrap();
        assert_eq!(outs.len(), 2);
        assert_eq!(curve.mean.len(), 8);
        assert!(curve.best_final.is_finite());
        assert!(curve.mean_final >= curve.best_final);
    }

    #[test]
    fn csv_written_with_relative_column() {
        let dir = std::env::temp_dir().join(format!("nmbkm-exp-{}", std::process::id()));
        std::env::set_var("NMBKM_RESULTS_DIR", &dir);
        let c = Curve {
            label: "tb-inf".into(),
            grid: vec![0.1, 0.2],
            mean: vec![2.0, 1.0],
            std: vec![0.0, 0.0],
            best_final: 1.0,
            mean_final: 1.0,
        };
        let path = write_curves_csv("unit_test_curve", "toy", &[c]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("rel_mean"));
        assert!(text.contains("2.000000")); // 2.0/1.0
        std::env::remove_var("NMBKM_RESULTS_DIR");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
