//! Timing utilities with *work-time* accounting.
//!
//! The paper's protocol excludes validation-MSE computation from reported
//! runtimes ("The time taken to compute validation MSEs is not included
//! in runtimes", §4.3). [`WorkClock`] implements exactly that: a
//! stopwatch that the metrics path pauses while scoring.

use std::time::{Duration, Instant};

/// A pausable stopwatch measuring algorithm work time.
#[derive(Debug)]
pub struct WorkClock {
    accumulated: Duration,
    running_since: Option<Instant>,
}

impl Default for WorkClock {
    fn default() -> Self {
        Self::new()
    }
}

impl WorkClock {
    pub fn new() -> Self {
        Self { accumulated: Duration::ZERO, running_since: None }
    }

    /// Start (or restart) the clock. Idempotent if already running.
    pub fn start(&mut self) {
        if self.running_since.is_none() {
            self.running_since = Some(Instant::now());
        }
    }

    /// Pause, folding the elapsed span into the accumulator.
    pub fn pause(&mut self) {
        if let Some(t0) = self.running_since.take() {
            self.accumulated += t0.elapsed();
        }
    }

    /// Total accumulated work time (includes the live span if running).
    pub fn elapsed(&self) -> Duration {
        let live = self
            .running_since
            .map(|t0| t0.elapsed())
            .unwrap_or(Duration::ZERO);
        self.accumulated + live
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Run `f` with the clock paused (validation, logging, IO).
    pub fn off_clock<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let was_running = self.running_since.is_some();
        self.pause();
        let out = f();
        if was_running {
            self.start();
        }
        out
    }
}

/// Measure a closure's wall time in seconds.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread::sleep;

    #[test]
    fn accumulates_across_pauses() {
        let mut c = WorkClock::new();
        c.start();
        sleep(Duration::from_millis(20));
        c.pause();
        let a = c.elapsed();
        sleep(Duration::from_millis(30));
        assert_eq!(c.elapsed(), a, "paused clock must not advance");
        c.start();
        sleep(Duration::from_millis(10));
        c.pause();
        assert!(c.elapsed() > a);
    }

    #[test]
    fn off_clock_excludes_span() {
        let mut c = WorkClock::new();
        c.start();
        sleep(Duration::from_millis(5));
        c.off_clock(|| sleep(Duration::from_millis(50)));
        sleep(Duration::from_millis(5));
        c.pause();
        assert!(
            c.elapsed() < Duration::from_millis(40),
            "elapsed={:?}",
            c.elapsed()
        );
    }

    #[test]
    fn start_is_idempotent() {
        let mut c = WorkClock::new();
        c.start();
        c.start();
        sleep(Duration::from_millis(5));
        c.pause();
        assert!(c.elapsed() >= Duration::from_millis(4));
        assert!(c.elapsed() < Duration::from_millis(100));
    }

    #[test]
    fn time_it_returns_value() {
        let (v, t) = time_it(|| 42);
        assert_eq!(v, 42);
        assert!(t >= 0.0);
    }
}
