//! `mb-f` — Mini-Batch with contaminating assignments removed (paper
//! §3.1, Algorithm 4).
//!
//! When a point is drawn again, its *previous* contribution is first
//! subtracted (`v(a) ← v(a)−1, S(a) ← S(a)−x`), so each point
//! contributes to exactly one centroid: the one it was most recently
//! assigned to. After every round `C(j)` is the exact mean of the
//! current assignments of all points seen so far — the invariant the
//! integration tests check, and the reason mb-f converges to genuine
//! local minima while mb drags early noise forever.

use crate::kmeans::assign::Sel;
use crate::kmeans::state::{Assignments, Centroids, SuffStats};
use crate::kmeans::{Clusterer, Ctx, RoundInfo};

pub struct MiniBatchFixed {
    pub(crate) cent: Centroids,
    pub(crate) stats: SuffStats,
    pub(crate) assign: Assignments,
    order: Vec<usize>,
    cursor: usize,
    b: usize,
}

impl MiniBatchFixed {
    pub fn new(cent: Centroids, n: usize, b: usize) -> Self {
        let k = cent.k();
        let d = cent.d();
        Self {
            cent,
            stats: SuffStats::zeros(k, d),
            assign: Assignments::new(n),
            order: (0..n).collect(),
            cursor: 0,
            b: b.min(n),
        }
    }

    fn next_batch(&mut self, rng: &mut crate::util::rng::Pcg64) -> Vec<usize> {
        let n = self.order.len();
        let mut out = Vec::with_capacity(self.b);
        for _ in 0..self.b {
            if self.cursor == 0 {
                rng.shuffle(&mut self.order);
            }
            out.push(self.order[self.cursor]);
            self.cursor = (self.cursor + 1) % n;
        }
        out
    }

    /// Test hook: exact-mean invariant vs a from-scratch rebuild.
    #[cfg(test)]
    pub fn stats_drift(&self, data: &crate::data::Data) -> f64 {
        let idx: Vec<usize> =
            (0..self.assign.label.len()).filter(|&i| self.assign.seen(i)).collect();
        let fresh = SuffStats::rebuild(
            data,
            self.cent.k(),
            idx.into_iter(),
            &self.assign.label,
            &self.assign.dist2,
        );
        self.stats.max_abs_diff(&fresh)
    }
}

impl Clusterer for MiniBatchFixed {
    fn round(&mut self, ctx: &mut Ctx) -> RoundInfo {
        let idx = self.next_batch(&mut ctx.rng);
        let mut lbl = vec![0u32; idx.len()];
        let mut d2 = vec![0f32; idx.len()];
        let calcs = ctx.engine.assign(
            ctx.data,
            Sel::List(&idx),
            &self.cent,
            &ctx.pool,
            &mut lbl,
            &mut d2,
        );
        // decontaminate + re-add (serial: touches shared S rows, but a
        // batch may contain the same index twice so per-point ordering
        // matters; O(b·d) worst case ≈ the assignment cost anyway)
        let mut changed = 0u64;
        for (t, &i) in idx.iter().enumerate() {
            if self.assign.seen(i) {
                // remove the expired assignment (Alg. 4 lines 4–6)
                self.stats.remove_point(
                    ctx.data,
                    i,
                    self.assign.label[i],
                    self.assign.dist2[i],
                );
                if self.assign.label[i] != lbl[t] {
                    changed += 1;
                }
            }
            self.stats.add_point(ctx.data, i, lbl[t], d2[t]);
            self.assign.label[i] = lbl[t];
            self.assign.dist2[i] = d2[t];
        }
        self.stats.update_centroids(&mut self.cent);
        let train_mse = crate::kmeans::state::batch_mse(&self.stats);
        RoundInfo {
            dist_calcs: calcs,
            bound_skips: 0,
            changed,
            batch: self.b,
            train_mse,
        }
    }

    fn centroids(&self) -> &Centroids {
        &self.cent
    }

    fn name(&self) -> String {
        "mb-f".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian::GaussianMixture;
    use crate::kmeans::assign::NativeEngine;
    use crate::kmeans::init;
    use crate::util::rng::Pcg64;

    /// Shared engine for test contexts (Ctx borrows it for 'static).
    fn test_engine() -> &'static NativeEngine {
        static E: std::sync::OnceLock<NativeEngine> = std::sync::OnceLock::new();
        E.get_or_init(NativeEngine::default)
    }

    fn ctx(data: &crate::data::Data) -> Ctx<'_> {
        Ctx {
            data,
            engine: test_engine(),
            pool: crate::coordinator::Pool::new(2),
            rng: Pcg64::new(1, 1),
        }
    }

    #[test]
    fn centroids_are_exact_means_of_current_assignments() {
        let data = GaussianMixture::default_spec(3, 5).generate(120, 6);
        let mut alg = MiniBatchFixed::new(init::first_k(&data, 3), 120, 48);
        let mut c = ctx(&data);
        for round in 0..10 {
            alg.round(&mut c);
            // the decontamination invariant, every round
            let drift = alg.stats_drift(&data);
            assert!(drift < 1e-6, "round {round}: S/v drift {drift}");
            // each seen point counted exactly once
            let seen =
                (0..120).filter(|&i| alg.assign.seen(i)).count() as f64;
            let total_v: f64 = alg.stats.v.iter().sum();
            assert!((total_v - seen).abs() < 1e-9);
        }
    }

    #[test]
    fn duplicate_index_within_batch_handled() {
        // force b > n/2 so epoch wrap duplicates indices within a round
        let data = GaussianMixture::default_spec(2, 3).generate(10, 3);
        let mut alg = MiniBatchFixed::new(init::first_k(&data, 2), 10, 8);
        let mut c = ctx(&data);
        for _ in 0..6 {
            alg.round(&mut c);
            let total_v: f64 = alg.stats.v.iter().sum();
            let seen = (0..10).filter(|&i| alg.assign.seen(i)).count() as f64;
            assert!((total_v - seen).abs() < 1e-9);
            assert!(alg.stats.v.iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn mbf_not_worse_than_mb_on_redundant_data() {
        // After several epochs over redundant data, mb-f's training MSE
        // should be ≤ mb's (decontamination helps; paper Fig. 1).
        use crate::kmeans::minibatch::{Formulation, MiniBatch};
        let data = GaussianMixture { k: 4, d: 6, center_spread: 8.0, noise: 1.0, weights: vec![] }
            .generate(300, 12);
        let rounds = 30;
        let mut mbf = MiniBatchFixed::new(init::first_k(&data, 4), 300, 60);
        let mut mb = MiniBatch::new(init::first_k(&data, 4), 300, 60, Formulation::Alg8);
        let mut c1 = ctx(&data);
        let mut c2 = ctx(&data);
        for _ in 0..rounds {
            mbf.round(&mut c1);
            mb.round(&mut c2);
        }
        let m_f = crate::kmeans::state::exact_mse(&data, &mbf.cent);
        let m_b = crate::kmeans::state::exact_mse(&data, &mb.cent);
        assert!(
            m_f <= m_b * 1.05,
            "mb-f {m_f} should not lag mb {m_b} after {rounds} rounds"
        );
    }
}
