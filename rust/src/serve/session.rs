//! Online training sessions: a pausable, resumable, incrementally-fed
//! wrapper around the nested-batch algorithms.
//!
//! An [`OnlineSession`] owns a growable data buffer and a `gb-ρ`/`tb-ρ`
//! clusterer over it. The mini-batch setting's defining feature —
//! digesting data as it streams in (Sculley 2010) — maps directly onto
//! the nested-batch structure: ingested points are appended *after* the
//! active prefix, and enter the statistics exactly once when the σ̂_C/p
//! controller votes to grow the batch over them, so the paper's §3.1
//! each-point-counts-exactly-once invariant holds across arbitrary
//! ingest/step/snapshot/resume interleavings (tested in
//! `tests/serve.rs`).
//!
//! Lifecycle:
//!
//! ```text
//! new(cfg, dim) ──ingest──▶ (≥ k points: model initialises)
//!        │                        │
//!        ▼                        ▼
//!   train(data, cfg)          step(rounds, secs) ◀──ingest── new points
//!        │                        │
//!        └──▶ snapshot() ──save──▶ file ──load──▶ resume() ──▶ step(…)
//! ```

use crate::config::{Algo, Engine, RunConfig};
use crate::coordinator::shard::Pool;
use crate::data::shard::{ShardData, ShardKind, ShardStore};
use crate::data::{Data, Storage};
use crate::kmeans::assign::{AssignEngine, NativeEngine, Sel};
use crate::kmeans::state::Centroids;
use crate::kmeans::{self, Clusterer, Ctx, RoundInfo};
use crate::linalg::dense::{self, DenseMatrix};
use crate::linalg::neighbours::NeighbourIndex;
use crate::linalg::sparse::{CsrMatrix, TransposedCentroids};
use crate::serve::snapshot::{Snapshot, SnapshotFormat};
use crate::serve::wire::{self, WireRow};
use crate::util::json::{self, Json};
use crate::util::rng::Pcg64;
use crate::util::timer::WorkClock;
use anyhow::{anyhow, bail, ensure, Result};
use std::sync::Arc;

/// What one [`OnlineSession::step`] call did.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepReport {
    pub rounds_run: usize,
    pub work_secs: f64,
    /// Metrics of the last round executed, if any.
    pub last: Option<RoundInfo>,
    /// The algorithm reached its fixed point over the current buffer.
    pub converged: bool,
    /// The model is not initialised yet (fewer than k points ingested).
    pub waiting_for_points: bool,
}

/// A long-lived clustering session: the unit of state behind `nmbkm
/// train/serve` and the JSONL protocol. `Send` throughout (trait
/// objects included), so a [`crate::serve::registry::ModelRegistry`]
/// can host it behind a mutex shared across connection threads.
pub struct OnlineSession {
    cfg: RunConfig,
    data: Data,
    alg: Option<Box<dyn Clusterer + Send>>,
    engine: Box<dyn AssignEngine + Send>,
    pool: Pool,
    rng: Pcg64,
    rounds: usize,
    work_secs: f64,
    last_info: Option<RoundInfo>,
    /// Directory protocol `snapshot` requests may write into (they name a
    /// bare file, never a path — remote clients must not get an
    /// arbitrary-file-write primitive on the server).
    snapshot_dir: std::path::PathBuf,
}

impl OnlineSession {
    /// An empty dense session awaiting its first points. The model
    /// initialises (per `cfg.init`) once at least `cfg.k` points have
    /// arrived.
    pub fn new(cfg: RunConfig, dim: usize) -> Result<OnlineSession> {
        ensure!(dim >= 1, "dimension must be >= 1");
        Self::from_data(Data::dense(DenseMatrix::zeros(0, dim)), cfg)
    }

    /// A session over a pre-filled buffer (the `train` path). The caller
    /// shuffles if the paper's per-seed protocol is wanted; a serving
    /// deployment feeds arrival order.
    pub fn from_data(data: Data, cfg: RunConfig) -> Result<OnlineSession> {
        ensure_resumable_algo(&cfg)?;
        ensure!(cfg.k >= 1, "bad k={}", cfg.k);
        let engine = make_engine(&cfg)?;
        let rng = Pcg64::new(cfg.seed, 0x5E55).derive("serve-session");
        let pool = Pool::new(cfg.threads);
        let mut session = OnlineSession {
            cfg,
            data,
            alg: None,
            engine,
            pool,
            rng,
            rounds: 0,
            work_secs: 0.0,
            last_info: None,
            snapshot_dir: std::path::PathBuf::from("."),
        };
        session.try_init();
        Ok(session)
    }

    /// Rebuild a session exactly where a snapshot paused it. Requires
    /// the snapshot's data section (model-only artifacts serve predict
    /// traffic but cannot resume training).
    pub fn resume(snap: Snapshot) -> Result<OnlineSession> {
        let data = snap.data.ok_or_else(|| {
            anyhow!(
                "snapshot has no data section — it can answer predict \
                 queries but cannot resume training"
            )
        })?;
        ensure!(
            data.n() == snap.state.n,
            "snapshot data has {} rows but state says {}",
            data.n(),
            snap.state.n
        );
        ensure!(
            data.dim() == snap.state.cent.d(),
            "snapshot data dim {} != model dim {}",
            data.dim(),
            snap.state.cent.d()
        );
        let cfg = snap.cfg;
        ensure_resumable_algo(&cfg)?;
        let alg = kmeans::resume_clusterer(snap.state, &cfg)?;
        let engine = make_engine(&cfg)?;
        let pool = Pool::new(cfg.threads);
        Ok(OnlineSession {
            cfg,
            data,
            alg: Some(alg),
            engine,
            pool,
            rng: snap.rng,
            rounds: snap.rounds,
            work_secs: 0.0,
            last_info: None,
            snapshot_dir: std::path::PathBuf::from("."),
        })
    }

    pub fn cfg(&self) -> &RunConfig {
        &self.cfg
    }

    pub fn data(&self) -> &Data {
        &self.data
    }

    pub fn rounds(&self) -> usize {
        self.rounds
    }

    pub fn initialised(&self) -> bool {
        self.alg.is_some()
    }

    /// Where protocol `snapshot` requests are allowed to write.
    pub fn snapshot_dir(&self) -> &std::path::Path {
        &self.snapshot_dir
    }

    pub fn set_snapshot_dir(&mut self, dir: std::path::PathBuf) {
        self.snapshot_dir = dir;
    }

    /// Current model, once initialised.
    pub fn centroids(&self) -> Option<&Centroids> {
        self.alg.as_ref().map(|a| a.centroids())
    }

    /// Append points to the buffer. They are *unseen* until the growth
    /// controller expands the batch over them — this is what keeps every
    /// point counted exactly once. Returns the new buffer size.
    pub fn ingest_rows(&mut self, rows: &[Vec<f32>]) -> Result<usize> {
        let d = self.data.dim();
        for (t, r) in rows.iter().enumerate() {
            ensure!(
                r.len() == d,
                "ingest row {t}: dimension {} != session dimension {d}",
                r.len()
            );
            // non-finite coordinates would corrupt the sufficient
            // statistics irreversibly — reject at the boundary
            ensure!(
                r.iter().all(|x| x.is_finite()),
                "ingest row {t}: non-finite coordinate"
            );
        }
        for r in rows {
            self.push_dense_row(r)?;
        }
        Ok(self.finish_ingest())
    }

    /// [`OnlineSession::ingest_rows`] for wire-decoded rows: sparse
    /// encodings append straight to CSR storage (no densify round-trip)
    /// and dense encodings follow the classic path, so a row enters the
    /// buffer bit-identically whichever encoding carried it.
    pub fn ingest_wire(&mut self, rows: &[WireRow]) -> Result<usize> {
        let d = self.data.dim();
        // validate everything up front so a bad row never leaves a
        // partially-applied ingest behind
        for (t, r) in rows.iter().enumerate() {
            ensure!(
                r.dim() == d,
                "ingest row {t}: dimension {} != session dimension {d}",
                r.dim()
            );
            let finite = match r {
                WireRow::Dense(x) => x.iter().all(|v| v.is_finite()),
                WireRow::Sparse { vals, .. } => {
                    vals.iter().all(|v| v.is_finite())
                }
            };
            ensure!(finite, "ingest row {t}: non-finite coordinate");
        }
        // scratch only exists to scatter sparse rows into *dense*
        // storage; sparse-storage sessions (the RCV1 serving case)
        // never touch it, so don't pay a dim-sized zeroed buffer there
        let mut scratch =
            if self.data.is_sparse() { vec![] } else { vec![0f32; d] };
        for r in rows {
            match r {
                WireRow::Dense(x) => self.push_dense_row(x)?,
                WireRow::Sparse { idx, vals, .. } => {
                    self.push_sparse_row(idx, vals, &mut scratch)?
                }
            }
        }
        Ok(self.finish_ingest())
    }

    /// Append one dense row to whichever storage the session uses.
    /// Fallible only for shard storage (a spill append can hit disk
    /// errors); in-RAM appends never fail.
    fn push_dense_row(&mut self, r: &[f32]) -> Result<()> {
        match &mut self.data.storage {
            Storage::Dense(m) => {
                m.data.extend_from_slice(r);
                m.rows += 1;
                self.data.norms.push(dense::sq_norm(r));
            }
            Storage::Sparse(m) => {
                let mut cv = Vec::new();
                // norm summed over nonzeros in storage order, exactly
                // like CsrMatrix::row_sq_norms — snapshot load
                // recomputes norms from the CSR values, and bit-exact
                // resume requires the same summation order
                let mut norm = 0f32;
                for (c, &x) in r.iter().enumerate() {
                    if x != 0.0 {
                        cv.push((c as u32, x));
                        norm += x * x;
                    }
                }
                m.push_row(&cv);
                self.data.norms.push(norm);
            }
            Storage::Shard(s) if !s.is_sparse() => {
                s.push_dense(r)?;
                self.data.norms.push(dense::sq_norm(r));
            }
            Storage::Shard(s) => {
                // sparsify exactly like the in-RAM Sparse arm: same
                // nonzero selection, same norm summation order
                let mut idx = Vec::new();
                let mut vals = Vec::new();
                let mut norm = 0f32;
                for (c, &x) in r.iter().enumerate() {
                    if x != 0.0 {
                        idx.push(c as u32);
                        vals.push(x);
                        norm += x * x;
                    }
                }
                s.push_sparse(&idx, &vals)?;
                self.data.norms.push(norm);
            }
        }
        Ok(())
    }

    /// Append one sparse row (validated, strictly ascending indices,
    /// zeros already dropped). Sparse storage takes it verbatim — the
    /// norm accumulates in storage order, matching `push_dense_row`'s
    /// sparsification bit-for-bit; dense storage scatters it into
    /// `scratch` (zero-filled here) first.
    fn push_sparse_row(
        &mut self,
        idx: &[u32],
        vals: &[f32],
        scratch: &mut [f32],
    ) -> Result<()> {
        match &mut self.data.storage {
            Storage::Dense(m) => {
                scratch.fill(0.0);
                for (t, &c) in idx.iter().enumerate() {
                    scratch[c as usize] = vals[t];
                }
                m.data.extend_from_slice(scratch);
                m.rows += 1;
                self.data.norms.push(dense::sq_norm(scratch));
            }
            Storage::Sparse(m) => {
                let mut cv = Vec::with_capacity(idx.len());
                let mut norm = 0f32;
                for (t, &c) in idx.iter().enumerate() {
                    cv.push((c, vals[t]));
                    norm += vals[t] * vals[t];
                }
                m.push_row(&cv);
                self.data.norms.push(norm);
            }
            Storage::Shard(s) if !s.is_sparse() => {
                scratch.fill(0.0);
                for (t, &c) in idx.iter().enumerate() {
                    scratch[c as usize] = vals[t];
                }
                s.push_dense(scratch)?;
                self.data.norms.push(dense::sq_norm(scratch));
            }
            Storage::Shard(s) => {
                let mut norm = 0f32;
                for &v in vals {
                    norm += v * v;
                }
                s.push_sparse(idx, vals)?;
                self.data.norms.push(norm);
            }
        }
        Ok(())
    }

    /// Convert the session's buffer to a disk-backed shard at `path`,
    /// re-spilling any rows already in RAM (no-op if already spilled).
    /// Values round-trip f32-exactly through the shard codec and norms
    /// are carried over untouched, so training over the spilled buffer
    /// is bit-identical to the in-RAM session.
    pub fn spill_to(
        &mut self,
        path: &std::path::Path,
        max_resident_rows: usize,
    ) -> Result<()> {
        if self.data.is_sharded() {
            return Ok(());
        }
        let kind = if self.data.is_sparse() {
            ShardKind::Sparse
        } else {
            ShardKind::Dense
        };
        let store = ShardStore::create(path, kind, self.data.dim(), max_resident_rows)?;
        let mut sd = ShardData::new(Arc::new(store));
        match &self.data.storage {
            Storage::Dense(m) => {
                for i in 0..m.rows {
                    sd.push_dense(m.row(i))?;
                }
            }
            Storage::Sparse(m) => {
                for i in 0..m.rows {
                    let (idx, vals) = m.row(i);
                    sd.push_sparse(idx, vals)?;
                }
            }
            Storage::Shard(_) => unreachable!(),
        }
        self.data.storage = Storage::Shard(sd);
        Ok(())
    }

    /// The backing shard store, when the buffer is spilled — the bench
    /// and tests read cache/budget stats through this.
    pub fn shard_store(&self) -> Option<&Arc<ShardStore>> {
        match &self.data.storage {
            Storage::Shard(s) => Some(s.store()),
            _ => None,
        }
    }

    /// Post-append bookkeeping shared by both ingest paths.
    fn finish_ingest(&mut self) -> usize {
        let n = self.data.n();
        if let Some(alg) = &mut self.alg {
            let ok = alg.extend_data(n);
            debug_assert!(ok, "resumable algorithms always accept growth");
        } else {
            self.try_init();
        }
        n
    }

    /// Run up to `max_rounds` rounds or until `max_seconds` of work time
    /// elapses (whichever first), honouring `cfg.stop_on_convergence`.
    pub fn step(&mut self, max_rounds: usize, max_seconds: f64) -> Result<StepReport> {
        self.try_init();
        let Some(alg) = self.alg.as_mut() else {
            return Ok(StepReport {
                waiting_for_points: true,
                ..StepReport::default()
            });
        };
        let mut ctx = Ctx {
            data: &self.data,
            engine: self.engine.as_ref(),
            pool: self.pool.clone(),
            rng: self.rng.clone(),
        };
        let mut clock = WorkClock::new();
        let mut report = StepReport::default();
        // budget checked *before* each round so `seconds: 0` (and
        // `rounds: 0`) are true no-ops rather than one surprise round of
        // latency inside a serving request
        while report.rounds_run < max_rounds
            && clock.elapsed_secs() < max_seconds
        {
            clock.start();
            let info = alg.round(&mut ctx);
            clock.pause();
            report.rounds_run += 1;
            report.last = Some(info);
            if alg.converged() && self.cfg.stop_on_convergence {
                break;
            }
        }
        // reported even for zero-round steps (convergence polling)
        report.converged = alg.converged();
        // fold the (possibly advanced) stream back so snapshots carry it
        self.rng = ctx.rng;
        report.work_secs = clock.elapsed_secs();
        self.rounds += report.rounds_run;
        self.work_secs += report.work_secs;
        if report.last.is_some() {
            self.last_info = report.last;
        }
        Ok(report)
    }

    /// Assign each query row to its nearest centroid: `(labels, d²)`.
    /// Batched through the configured [`AssignEngine`] and shard pool —
    /// the same hot path training uses.
    pub fn predict_rows(&self, rows: &[Vec<f32>]) -> Result<(Vec<u32>, Vec<f32>)> {
        let cent = self.centroids().ok_or_else(|| {
            anyhow!(
                "model not initialised — ingest at least k={} points first",
                self.cfg.k
            )
        })?;
        predict_against(
            cent,
            self.data.dim(),
            rows,
            self.data.is_sparse(),
            None,
            None,
            self.engine.as_ref(),
            &self.pool,
        )
    }

    /// A shareable transposed-centroid handle at the current revision
    /// (sparse sessions only). The registry carries it into the
    /// published model view so concurrent sparse predicts reuse this
    /// session's O(k·d) transpose instead of rebuilding their own.
    pub fn published_trans(&self) -> Option<Arc<TransposedCentroids>> {
        if !self.data.is_sparse() {
            return None;
        }
        let cent = self.centroids()?;
        self.engine.trans_handle(cent)
    }

    /// A shareable exponion neighbour structure at the current revision,
    /// when the engine keeps one worth publishing. The registry freezes
    /// it into the published view so serving-scale-k predicts prune with
    /// the training session's O(k²·d) build — zero rebuilds between
    /// publishes. Sparse sessions above the exponion vocab gate return
    /// `None` rather than pay a full-vocab k² build at publish time.
    pub fn published_neigh(&self) -> Option<Arc<NeighbourIndex>> {
        if self.data.is_sparse()
            && self.data.dim()
                > crate::kmeans::assign::EXPONION_SPARSE_MAX_D
        {
            return None;
        }
        let cent = self.centroids()?;
        self.engine.neigh_handle(cent)
    }

    /// Export the full session as a snapshot artifact. `include_data`
    /// trades file size for resumability (without it the artifact is
    /// predict-only). Clones the data buffer — prefer
    /// [`OnlineSession::save_snapshot`] for writing to disk, which
    /// streams from borrowed state instead.
    pub fn snapshot(&self, include_data: bool) -> Result<Snapshot> {
        let state = self.export_state()?;
        Ok(Snapshot {
            cfg: self.cfg.clone(),
            state,
            rng: self.rng.clone(),
            rounds: self.rounds,
            data: if include_data {
                // shard-backed buffers materialise so the snapshot is
                // byte-identical to an in-RAM session's
                Some(self.data.to_resident())
            } else {
                None
            },
        })
    }

    /// Stream the session as a snapshot JSON document to `w` without
    /// cloning the data buffer (byte-identical to
    /// `self.snapshot(include_data)?.to_json().to_string()`).
    pub fn write_snapshot<W: std::io::Write>(
        &self,
        include_data: bool,
        w: &mut W,
    ) -> Result<()> {
        self.write_snapshot_as(include_data, SnapshotFormat::Json, w)
    }

    /// [`OnlineSession::write_snapshot`] with an explicit format.
    pub fn write_snapshot_as<W: std::io::Write>(
        &self,
        include_data: bool,
        format: SnapshotFormat,
        w: &mut W,
    ) -> Result<()> {
        let state = self.export_state()?;
        crate::serve::snapshot::write_snapshot_as(
            &self.cfg,
            &state,
            &self.rng,
            self.rounds,
            include_data.then_some(&self.data),
            format,
            w,
        )
    }

    /// Atomic streaming save: the serving-path replacement for
    /// `self.snapshot(…)?.save(path)` that avoids the transient
    /// data-buffer clone and in-memory document.
    pub fn save_snapshot(&self, path: &std::path::Path, include_data: bool) -> Result<()> {
        self.save_snapshot_as(path, include_data, SnapshotFormat::Json)
    }

    /// [`OnlineSession::save_snapshot`] with an explicit on-disk format.
    pub fn save_snapshot_as(
        &self,
        path: &std::path::Path,
        include_data: bool,
        format: SnapshotFormat,
    ) -> Result<()> {
        let state = self.export_state()?;
        crate::serve::snapshot::save_parts_as(
            &self.cfg,
            &state,
            &self.rng,
            self.rounds,
            include_data.then_some(&self.data),
            path,
            format,
        )
    }

    fn export_state(&self) -> Result<crate::kmeans::NestedState> {
        let alg = self
            .alg
            .as_ref()
            .ok_or_else(|| anyhow!("nothing to snapshot: model not initialised"))?;
        alg.export_state()
            .ok_or_else(|| anyhow!("algorithm '{}' is not resumable", alg.name()))
    }

    /// Cheap observability record (the protocol's `stats` op).
    pub fn stats_json(&self) -> Json {
        let mut fields = vec![
            ("initialised", Json::Bool(self.initialised())),
            ("algo", json::s(&self.cfg.label())),
            ("engine", json::s(self.engine.name())),
            ("k", json::num(self.cfg.k as f64)),
            ("dim", json::num(self.data.dim() as f64)),
            ("n_total", json::num(self.data.n() as f64)),
            ("rounds", json::num(self.rounds as f64)),
            ("work_secs", json::num(self.work_secs)),
            ("threads", json::num(self.pool.threads as f64)),
        ];
        if let Some(info) = &self.last_info {
            fields.push(("batch", json::num(info.batch as f64)));
            fields.push(("train_mse", json::num(info.train_mse)));
            fields.push(("last_changed", json::num(info.changed as f64)));
        }
        json::obj(fields)
    }

    /// The training engine's transpose cache, when it keeps one — the
    /// single source the metrics registry scrapes
    /// (`nmbkm_trans_cache_*_total{engine="session"}`). The bespoke
    /// `trans_cache_*` fields the `stats` op used to carry moved there.
    pub fn trans_cache(&self) -> Option<Arc<crate::kmeans::assign::TransCache>> {
        self.engine.trans_cache_handle()
    }

    /// The training engine's exponion neighbour cache, when it keeps
    /// one — scraped as `nmbkm_neigh_cache_*_total{engine="session"}`.
    pub fn neigh_cache(
        &self,
    ) -> Option<Arc<crate::linalg::neighbours::NeighbourCache>> {
        self.engine.neigh_cache_handle()
    }

    /// The session's shard pool handle (shared workers; cloning is
    /// cheap). The registry's lock-free predict path reuses it so
    /// predicts and training draw from one set of worker threads.
    pub fn pool(&self) -> &Pool {
        &self.pool
    }

    fn try_init(&mut self) {
        if self.alg.is_none() && self.data.n() >= self.cfg.k && self.data.n() > 0 {
            self.alg = Some(kmeans::make_clusterer(&self.data, &self.cfg));
        }
    }
}

/// Score query rows against an explicit model: the shared predict core.
/// Both the session's own `predict_rows` and the registry's
/// snapshot-isolated [`crate::serve::registry::PublishedModel`] path go
/// through here, so a predict answered from a published snapshot is
/// bit-identical to one answered by the live session at the same
/// centroid revision.
#[allow(clippy::too_many_arguments)]
pub fn predict_against(
    cent: &Centroids,
    dim: usize,
    rows: &[Vec<f32>],
    sparse: bool,
    trans: Option<Arc<TransposedCentroids>>,
    neigh: Option<Arc<NeighbourIndex>>,
    engine: &dyn AssignEngine,
    pool: &Pool,
) -> Result<(Vec<u32>, Vec<f32>)> {
    let n = rows.len();
    for (t, r) in rows.iter().enumerate() {
        ensure!(
            r.len() == dim,
            "predict row {t}: dimension {} != model dimension {dim}",
            r.len()
        );
    }
    // queries against a sparse model go through the CSR kernels:
    // O(nnz·k) per row against the transposed centroid block instead of
    // O(d·k) dense scans (d is 47k-shaped for these models).
    // Sparsification matches `ingest_rows` — non-zeros in coordinate
    // order — so a query row scores bit-identically to the same row
    // ingested into the session's buffer.
    let queries = if sparse {
        let mut m = CsrMatrix::empty(dim);
        let mut cv = Vec::new();
        for r in rows {
            cv.clear();
            for (c, &x) in r.iter().enumerate() {
                if x != 0.0 {
                    cv.push((c as u32, x));
                }
            }
            m.push_row(&cv);
        }
        Data::sparse(m)
    } else {
        let mut buf = Vec::with_capacity(n * dim);
        for r in rows {
            buf.extend_from_slice(r);
        }
        Data::dense(DenseMatrix::from_vec(n, dim, buf))
    };
    let mut lbl = vec![0u32; n];
    let mut d2 = vec![0f32; n];
    // carried handles (published model) ride straight into the engine
    // call — no shared-cache traffic on the predict path
    engine.assign_with_handles(
        &queries,
        Sel::Range(0, n),
        cent,
        pool,
        trans,
        neigh,
        &mut lbl,
        &mut d2,
    );
    Ok((lbl, d2))
}

/// [`predict_against`] for wire-decoded rows: sparse-encoded queries
/// land straight in the CSR form the engine consumes (no densify
/// round-trip) and dense-encoded ones follow the classic assembly, so
/// the answer is bit-identical to the dense path for the same logical
/// rows (enforced by `tests/serve_wire.rs`).
#[allow(clippy::too_many_arguments)]
pub fn predict_wire(
    cent: &Centroids,
    dim: usize,
    rows: &[WireRow],
    sparse: bool,
    trans: Option<Arc<TransposedCentroids>>,
    neigh: Option<Arc<NeighbourIndex>>,
    engine: &dyn AssignEngine,
    pool: &Pool,
) -> Result<(Vec<u32>, Vec<f32>)> {
    let queries = wire::assemble(rows, dim, sparse)?;
    let n = queries.n();
    let mut lbl = vec![0u32; n];
    let mut d2 = vec![0f32; n];
    engine.assign_with_handles(
        &queries,
        Sel::Range(0, n),
        cent,
        pool,
        trans,
        neigh,
        &mut lbl,
        &mut d2,
    );
    Ok((lbl, d2))
}

/// One-shot training driver: buffer all of `data`, then run rounds under
/// the config's budget — `kmeans::run` semantics, but leaving behind a
/// snapshot-able session instead of a bare outcome. The caller shuffles
/// (`data::shuffle::shuffled`) when the paper's protocol is wanted.
pub fn train(data: &Data, cfg: &RunConfig) -> Result<(OnlineSession, StepReport)> {
    ensure!(
        data.n() >= cfg.k,
        "training needs at least k={} points, got {}",
        cfg.k,
        data.n()
    );
    let mut session = OnlineSession::from_data(data.clone(), cfg.clone())?;
    let report = session.step(cfg.max_rounds, cfg.max_seconds)?;
    Ok((session, report))
}

fn ensure_resumable_algo(cfg: &RunConfig) -> Result<()> {
    match cfg.algo {
        Algo::GbRho | Algo::TbRho => Ok(()),
        other => bail!(
            "online sessions require a nested-batch algorithm (gb | tb), \
             got '{}'",
            other.name()
        ),
    }
}

fn make_engine(cfg: &RunConfig) -> Result<Box<dyn AssignEngine + Send>> {
    match cfg.engine {
        Engine::Native => Ok(Box::new(NativeEngine::default())),
        Engine::Xla => crate::runtime::make_engine(&cfg.artifacts_dir),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Rho;
    use crate::data::gaussian::GaussianMixture;

    fn cfg(k: usize, b0: usize) -> RunConfig {
        RunConfig {
            algo: Algo::TbRho,
            k,
            b0,
            rho: Rho::Infinite,
            threads: 2,
            seed: 7,
            max_seconds: 30.0,
            max_rounds: 8,
            ..Default::default()
        }
    }

    fn rows_of(data: &Data, lo: usize, hi: usize) -> Vec<Vec<f32>> {
        let mut out = Vec::with_capacity(hi - lo);
        let mut row = vec![0f32; data.dim()];
        for i in lo..hi {
            data.write_row_dense(i, &mut row);
            out.push(row.clone());
        }
        out
    }

    #[test]
    fn waits_for_k_points_then_initialises() {
        let data = GaussianMixture::default_spec(4, 5).generate(100, 1);
        let mut s = OnlineSession::new(cfg(4, 16), 5).unwrap();
        assert!(!s.initialised());
        let rep = s.step(5, 1.0).unwrap();
        assert!(rep.waiting_for_points);
        assert!(s.predict_rows(&rows_of(&data, 0, 1)).is_err());
        s.ingest_rows(&rows_of(&data, 0, 3)).unwrap();
        assert!(!s.initialised(), "3 < k points must not initialise");
        s.ingest_rows(&rows_of(&data, 3, 30)).unwrap();
        assert!(s.initialised());
        let rep = s.step(3, 5.0).unwrap();
        assert_eq!(rep.rounds_run, 3);
        assert!(rep.last.unwrap().train_mse.is_finite());
    }

    #[test]
    fn rejects_bad_shapes_and_algos() {
        assert!(OnlineSession::new(cfg(3, 8), 0).is_err());
        let bad = RunConfig { algo: Algo::Lloyd, ..cfg(3, 8) };
        assert!(OnlineSession::new(bad, 4).is_err());
        let mut s = OnlineSession::new(cfg(2, 8), 4).unwrap();
        assert!(s.ingest_rows(&[vec![1.0; 3]]).is_err(), "dim mismatch");
    }

    #[test]
    fn train_then_predict_matches_engine() {
        let data = GaussianMixture::default_spec(3, 6).generate(400, 9);
        let (session, rep) = train(&data, &cfg(3, 64)).unwrap();
        assert!(rep.rounds_run >= 1);
        let queries = rows_of(&data, 100, 140);
        let (lbl, d2) = session.predict_rows(&queries).unwrap();
        let cent = session.centroids().unwrap();
        for (t, q) in queries.iter().enumerate() {
            let qd = Data::dense(DenseMatrix::from_vec(1, 6, q.clone()));
            let (j, dd) = qd.nearest(0, &cent.c, &cent.norms);
            assert_eq!(lbl[t], j);
            assert_eq!(d2[t], dd);
        }
        let _ = rep.work_secs;
    }

    #[test]
    fn stats_json_reports_progress() {
        let data = GaussianMixture::default_spec(3, 4).generate(200, 2);
        let (session, _) = train(&data, &cfg(3, 32)).unwrap();
        let stats = session.stats_json();
        assert_eq!(stats.get("initialised").unwrap().as_bool(), Some(true));
        assert_eq!(stats.get("n_total").unwrap().as_usize(), Some(200));
        assert!(stats.get("rounds").unwrap().as_usize().unwrap() >= 1);
        assert!(stats.get("batch").is_some());
    }

    #[test]
    fn sparse_sessions_ingest_dense_rows() {
        let g = crate::data::rcv1::Rcv1Sim {
            vocab: 300,
            topic_vocab: 40,
            ..Default::default()
        };
        let data = g.generate(150, 5);
        let (mut session, _) = train(&data, &cfg(3, 32)).unwrap();
        let extra = rows_of(&data, 0, 10);
        let n = session.ingest_rows(&extra).unwrap();
        assert_eq!(n, 160);
        assert!(session.data().is_sparse());
        let (lbl, _) = session.predict_rows(&extra).unwrap();
        assert_eq!(lbl.len(), 10);
        // snapshot-load must reproduce the ingested rows' norms bit-exactly
        // (load recomputes them from the CSR values)
        let text = session.snapshot(true).unwrap().to_json().to_string();
        let back = Snapshot::from_json(&Json::parse(&text).unwrap()).unwrap();
        let a: Vec<u32> =
            session.data().norms.iter().map(|x| x.to_bits()).collect();
        let b: Vec<u32> =
            back.data.unwrap().norms.iter().map(|x| x.to_bits()).collect();
        assert_eq!(a, b);
    }
}
