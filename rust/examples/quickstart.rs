//! Quickstart: cluster a synthetic Gaussian mixture with the paper's
//! headline algorithm (`tb-∞`, nested mini-batch + triangle-inequality
//! bounds) and watch the MSE trajectory + eliminated work.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use nmbkm::config::{Algo, Rho, RunConfig};
use nmbkm::data::gaussian::GaussianMixture;
use nmbkm::kmeans;

fn main() -> anyhow::Result<()> {
    // 20k points in 32 dims around 10 well-separated centers
    let ds = GaussianMixture::default_spec(10, 32).dataset(20_000, 4_000, 42);
    println!("dataset: {}", ds.summary());

    let cfg = RunConfig {
        algo: Algo::TbRho,
        rho: Rho::Infinite,
        k: 10,
        b0: 512,
        max_seconds: 5.0,
        threads: std::thread::available_parallelism()?.get(),
        eval_every_secs: 0.1,
        ..Default::default()
    };
    let out = kmeans::run(&ds.train, Some(&ds.val), &cfg)?;

    println!("\nround  t_work    batch   dist_calcs  bound_skips   val MSE");
    for r in &out.trace.records {
        println!(
            "{:>5} {:>7.3}s {:>8} {:>12} {:>12}   {}",
            r.round,
            r.t_work,
            r.batch,
            r.dist_calcs,
            r.bound_skips,
            r.val_mse.map(|m| format!("{m:.4}")).unwrap_or_else(|| "-".into())
        );
    }
    println!(
        "\nconverged after {} rounds / {:.3}s work; final validation MSE {:.4}",
        out.rounds, out.work_secs, out.final_mse
    );
    // with 10 well-separated true clusters, per-point MSE ≈ d·σ² = 32
    let skips: u64 = out.trace.records.iter().map(|r| r.bound_skips).sum();
    let calcs: u64 = out.trace.records.iter().map(|r| r.dist_calcs).sum();
    println!(
        "distance computations: {calcs} performed, {skips} eliminated by bounds ({:.1}%)",
        100.0 * skips as f64 / (skips + calcs) as f64
    );
    Ok(())
}
