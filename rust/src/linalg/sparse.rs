//! CSR sparse matrices and the sparse↔dense distance kernels.
//!
//! RCV1-like data is ~76 non-zeros in 47k dimensions, while centroids
//! densify as points accumulate (the paper's φ ≫ 1 regime, Supp. A.2).
//! We therefore keep centroids dense and compute
//! `‖x−c‖² = ‖x‖² + ‖c‖² − 2 Σ_t v_t·c[idx_t]` with a gather loop over
//! the point's non-zeros only — O(nnz) per centroid, not O(d).

use crate::linalg::dense::DenseMatrix;

/// Compressed sparse row matrix, f32 values, u32 column indices.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl CsrMatrix {
    pub fn empty(cols: usize) -> Self {
        Self { rows: 0, cols, indptr: vec![0], indices: vec![], values: vec![] }
    }

    /// Append a row given (sorted or unsorted) column/value pairs.
    pub fn push_row(&mut self, cols_vals: &[(u32, f32)]) {
        for &(c, v) in cols_vals {
            assert!((c as usize) < self.cols, "column {c} out of range");
            self.indices.push(c);
            self.values.push(v);
        }
        self.rows += 1;
        self.indptr.push(self.indices.len());
    }

    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        debug_assert!(i < self.rows);
        let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    #[inline]
    pub fn nnz_row(&self, i: usize) -> usize {
        self.indptr[i + 1] - self.indptr[i]
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// ‖row_i‖² for every row.
    pub fn row_sq_norms(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|i| {
                let (_, vals) = self.row(i);
                vals.iter().map(|v| v * v).sum()
            })
            .collect()
    }

    /// Materialise a row permutation.
    pub fn permute_rows(&self, perm: &[usize]) -> CsrMatrix {
        assert_eq!(perm.len(), self.rows);
        let mut out = CsrMatrix::empty(self.cols);
        out.indices.reserve(self.nnz());
        out.values.reserve(self.nnz());
        for &p in perm {
            let (idx, vals) = self.row(p);
            out.indices.extend_from_slice(idx);
            out.values.extend_from_slice(vals);
            out.rows += 1;
            out.indptr.push(out.indices.len());
        }
        out
    }

    /// Rows `[lo, hi)` as a new matrix.
    pub fn slice_rows(&self, lo: usize, hi: usize) -> CsrMatrix {
        assert!(lo <= hi && hi <= self.rows);
        let (plo, phi) = (self.indptr[lo], self.indptr[hi]);
        CsrMatrix {
            rows: hi - lo,
            cols: self.cols,
            indptr: self.indptr[lo..=hi].iter().map(|&p| p - plo).collect(),
            indices: self.indices[plo..phi].to_vec(),
            values: self.values[plo..phi].to_vec(),
        }
    }

    /// Dense copy (tests, small data only).
    pub fn to_dense(&self) -> DenseMatrix {
        let mut m = DenseMatrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (idx, vals) = self.row(i);
            let r = m.row_mut(i);
            for (j, v) in idx.iter().zip(vals) {
                r[*j as usize] += *v;
            }
        }
        m
    }

    /// Mean number of non-zeros per row (the paper's `s`).
    pub fn mean_nnz(&self) -> f64 {
        if self.rows == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.rows as f64
        }
    }
}

/// ⟨sparse row, dense vector⟩: the sparse hot loop.
#[inline]
pub fn spdot(idx: &[u32], vals: &[f32], dense: &[f32]) -> f32 {
    debug_assert_eq!(idx.len(), vals.len());
    let mut s = 0f32;
    for t in 0..idx.len() {
        // Safety: indices are validated < cols at construction.
        unsafe {
            s += vals.get_unchecked(t)
                * dense.get_unchecked(*idx.get_unchecked(t) as usize);
        }
    }
    s
}

/// Squared distance from a sparse point to a dense centroid via norms.
#[inline]
pub fn sq_dist_sparse(
    idx: &[u32],
    vals: &[f32],
    xn: f32,
    c: &[f32],
    cn: f32,
) -> f32 {
    (xn + cn - 2.0 * spdot(idx, vals, c)).max(0.0)
}

/// Nearest dense centroid of a sparse point; counts as k distance
/// evaluations of O(nnz) each.
#[inline]
pub fn nearest_sparse(
    idx: &[u32],
    vals: &[f32],
    xn: f32,
    c: &DenseMatrix,
    cnorms: &[f32],
) -> (u32, f32) {
    let mut best_j = 0u32;
    let mut best = f32::INFINITY;
    for j in 0..c.rows {
        let d2 = sq_dist_sparse(idx, vals, xn, c.row(j), cnorms[j]);
        if d2 < best {
            best = d2;
            best_j = j as u32;
        }
    }
    (best_j, best)
}

/// Transposed centroid block (d × k, row-major) for the batched sparse
/// assignment kernel: turning `k` gathers per non-zero into one
/// sequential k-length AXPY makes the inner loop vectorisable
/// (EXPERIMENTS.md §Perf change 3).
pub struct TransposedCentroids {
    pub d: usize,
    pub k: usize,
    /// ct[col * k + j] = C(j)[col]
    pub ct: Vec<f32>,
}

impl TransposedCentroids {
    /// Heap footprint of a (k × d) transpose before building it — the
    /// engine's cache gate bounds per-session memory with this.
    pub fn bytes_for(k: usize, d: usize) -> usize {
        k * d * std::mem::size_of::<f32>()
    }

    /// Heap footprint of this transpose.
    pub fn bytes(&self) -> usize {
        Self::bytes_for(self.k, self.d)
    }

    pub fn build(c: &DenseMatrix) -> Self {
        let (k, d) = (c.rows, c.cols);
        let mut ct = vec![0f32; d * k];
        for j in 0..k {
            let row = c.row(j);
            for col in 0..d {
                ct[col * k + j] = row[col];
            }
        }
        Self { d, k, ct }
    }

    /// All-centroid dot products of one sparse row:
    /// `dots[j] = Σ_t vals[t]·C(j)[idx[t]]`, via sequential AXPYs into
    /// the k-length accumulator.
    #[inline]
    pub fn dots(&self, idx: &[u32], vals: &[f32], dots: &mut [f32]) {
        debug_assert_eq!(dots.len(), self.k);
        dots.fill(0.0);
        let k = self.k;
        for t in 0..idx.len() {
            let v = vals[t];
            let base = idx[t] as usize * k;
            // Safety: idx validated < cols = d at construction.
            let row = unsafe { self.ct.get_unchecked(base..base + k) };
            for j in 0..k {
                dots[j] += v * row[j];
            }
        }
    }

    /// Nearest centroid of a sparse point through the transposed block.
    #[inline]
    pub fn nearest(
        &self,
        idx: &[u32],
        vals: &[f32],
        xn: f32,
        cnorms: &[f32],
        scratch: &mut [f32],
    ) -> (u32, f32) {
        self.dots(idx, vals, scratch);
        let mut best = f32::INFINITY;
        let mut best_j = 0u32;
        for j in 0..self.k {
            let d2 = (xn + cnorms[j] - 2.0 * scratch[j]).max(0.0);
            if d2 < best {
                best = d2;
                best_j = j as u32;
            }
        }
        (best_j, best)
    }

    /// Full squared-distance row of a sparse point.
    #[inline]
    pub fn dist_row(
        &self,
        idx: &[u32],
        vals: &[f32],
        xn: f32,
        cnorms: &[f32],
        out: &mut [f32],
    ) {
        self.dots(idx, vals, out);
        for j in 0..self.k {
            out[j] = (xn + cnorms[j] - 2.0 * out[j]).max(0.0);
        }
    }
}

/// Scatter-add a sparse row into an f64 accumulator row.
#[inline]
pub fn scatter_add(acc: &mut [f64], idx: &[u32], vals: &[f32]) {
    for t in 0..idx.len() {
        acc[idx[t] as usize] += vals[t] as f64;
    }
}

/// Scatter-subtract a sparse row from an f64 accumulator row.
#[inline]
pub fn scatter_sub(acc: &mut [f64], idx: &[u32], vals: &[f32]) {
    for t in 0..idx.len() {
        acc[idx[t] as usize] -= vals[t] as f64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense;
    use crate::util::propcheck::Cases;
    use crate::util::rng::Pcg64;

    fn random_csr(rng: &mut Pcg64, rows: usize, cols: usize, nnz_per: usize) -> CsrMatrix {
        let mut m = CsrMatrix::empty(cols);
        for _ in 0..rows {
            let nnz = rng.below(nnz_per + 1);
            let cols_idx = rng.sample_distinct(cols, nnz.min(cols));
            let row: Vec<(u32, f32)> = cols_idx
                .iter()
                .map(|&c| (c as u32, rng.gauss_f32()))
                .collect();
            m.push_row(&row);
        }
        m
    }

    #[test]
    fn spdot_matches_dense_dot() {
        Cases::new(60).run(|rng| {
            let cols = rng.below(100) + 1;
            let m = random_csr(rng, 1, cols, 20);
            let dvec: Vec<f32> = (0..cols).map(|_| rng.gauss_f32()).collect();
            let (idx, vals) = m.row(0);
            let got = spdot(idx, vals, &dvec);
            let dense_row = m.to_dense();
            let naive = dense::dot(dense_row.row(0), &dvec);
            assert!((got - naive).abs() < 1e-3 * (1.0 + naive.abs()));
        });
    }

    #[test]
    fn sq_dist_sparse_matches_dense() {
        Cases::new(60).run(|rng| {
            let cols = rng.below(80) + 1;
            let m = random_csr(rng, 4, cols, 10);
            let c: Vec<f32> = (0..cols).map(|_| rng.gauss_f32()).collect();
            let cn = dense::sq_norm(&c);
            let dm = m.to_dense();
            let xns = m.row_sq_norms();
            for i in 0..m.rows {
                let (idx, vals) = m.row(i);
                let got = sq_dist_sparse(idx, vals, xns[i], &c, cn);
                let exact = dense::sq_dist(dm.row(i), &c);
                assert!(
                    (got - exact).abs() < 1e-2 * (1.0 + exact.abs()),
                    "i={i} got={got} exact={exact}"
                );
            }
        });
    }

    #[test]
    fn nearest_sparse_matches_dense_nearest() {
        Cases::new(40).run(|rng| {
            let cols = rng.below(60) + 2;
            let k = rng.below(8) + 1;
            let m = random_csr(rng, 3, cols, 12);
            let cmat = DenseMatrix::from_vec(
                k,
                cols,
                (0..k * cols).map(|_| rng.gauss_f32()).collect(),
            );
            let cn = cmat.row_sq_norms();
            let dm = m.to_dense();
            let xns = m.row_sq_norms();
            for i in 0..m.rows {
                let (idx, vals) = m.row(i);
                let (_, d2s) = nearest_sparse(idx, vals, xns[i], &cmat, &cn);
                let (_, d2d) =
                    dense::nearest(dm.row(i), dense::sq_norm(dm.row(i)), &cmat, &cn);
                assert!((d2s - d2d).abs() < 1e-2 * (1.0 + d2d.abs()));
            }
        });
    }

    #[test]
    fn transposed_matches_gather_path() {
        Cases::new(40).run(|rng| {
            let cols = rng.below(200) + 2;
            let k = rng.below(30) + 1;
            let m = random_csr(rng, 6, cols, 15);
            let cmat = DenseMatrix::from_vec(
                k,
                cols,
                (0..k * cols).map(|_| rng.gauss_f32()).collect(),
            );
            let cn = cmat.row_sq_norms();
            let tc = TransposedCentroids::build(&cmat);
            let xns = m.row_sq_norms();
            let mut scratch = vec![0f32; k];
            let mut row_out = vec![0f32; k];
            for i in 0..m.rows {
                let (idx, vals) = m.row(i);
                let (jt, dt) =
                    tc.nearest(idx, vals, xns[i], &cn, &mut scratch);
                let (jg, dg) = nearest_sparse(idx, vals, xns[i], &cmat, &cn);
                assert!(
                    (dt - dg).abs() <= 1e-3 * (1.0 + dg.abs()),
                    "i={i}: trans {dt} vs gather {dg}"
                );
                // indices may differ only on numerical ties
                if jt != jg {
                    let a = sq_dist_sparse(idx, vals, xns[i], cmat.row(jt as usize), cn[jt as usize]);
                    assert!((a - dg).abs() <= 1e-3 * (1.0 + dg.abs()));
                }
                tc.dist_row(idx, vals, xns[i], &cn, &mut row_out);
                for j in 0..k {
                    let e = sq_dist_sparse(idx, vals, xns[i], cmat.row(j), cn[j]);
                    assert!(
                        (row_out[j] - e).abs() <= 1e-3 * (1.0 + e.abs()),
                        "row {j}: {} vs {e}",
                        row_out[j]
                    );
                }
            }
        });
    }

    #[test]
    fn scatter_roundtrip() {
        let mut acc = vec![0.0f64; 10];
        let idx = [1u32, 5, 9];
        let vals = [1.5f32, -2.0, 0.25];
        scatter_add(&mut acc, &idx, &vals);
        assert_eq!(acc[5], -2.0);
        scatter_sub(&mut acc, &idx, &vals);
        assert!(acc.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn permute_slice_dense_consistency() {
        let mut rng = Pcg64::new(3, 3);
        let m = random_csr(&mut rng, 6, 20, 5);
        let perm = [5usize, 3, 1, 0, 2, 4];
        let p = m.permute_rows(&perm);
        for (i, &src) in perm.iter().enumerate() {
            assert_eq!(p.row(i), m.row(src));
        }
        let s = p.slice_rows(2, 5);
        assert_eq!(s.rows, 3);
        assert_eq!(s.row(0), p.row(2));
    }

    #[test]
    fn mean_nnz_and_norms() {
        let mut m = CsrMatrix::empty(4);
        m.push_row(&[(0, 3.0), (2, 4.0)]);
        m.push_row(&[]);
        assert_eq!(m.mean_nnz(), 1.0);
        assert_eq!(m.row_sq_norms(), vec![25.0, 0.0]);
    }

    #[test]
    #[should_panic]
    fn push_row_validates_columns() {
        let mut m = CsrMatrix::empty(3);
        m.push_row(&[(3, 1.0)]);
    }
}
