//! Per-centroid nearest-neighbour structure for exponion-style pruned
//! assignment (Newling & Fleuret, "Fast K-Means with Accurate Bounds").
//!
//! For every centroid `s` we keep the other `k − 1` centroids sorted by
//! a *certified lower bound* on the inter-centroid distance `‖c_s −
//! c_j‖`. A point whose provisional nearest centroid is `s` at distance
//! ≤ `r` can then walk the sorted row and stop at the first entry with
//! `cc(s, j) > r + √best`: by the triangle inequality every remaining
//! centroid is provably farther than the running best, so the walk
//! evaluates only the centroids inside the point's *exponion ball*
//! instead of all k.
//!
//! Everything here is engineered around the repo's standing bit-identity
//! guarantee: pruning may only skip a centroid whose **computed** f32
//! distance is provably *strictly* above the running best, so the
//! argmin (first-wins tie-breaks included) and the returned distance are
//! bit-identical to the unpruned scan on every non-FMA tier. That needs
//! three certified quantities, all maintained here:
//!
//! * `cc` rows built from a per-pair diff-square (`Σ (a_t − b_t)²`
//!   through the SIMD dot), shrunk by a relative slack — the error is
//!   relative to `cc²` itself, so nearby centroids keep *tight* bounds
//!   (the norms-trick form `‖a‖² + ‖b‖² − 2⟨a,b⟩` cancels
//!   catastrophically exactly there).
//! * a per-point absolute slack [`NeighbourIndex::slack_term`] bounding
//!   |computed d² − true d²| — the ball radius and every ring bound are
//!   widened by it before any skip decision.
//! * per-row `decay`: centroids move between revisions, so each sync
//!   accumulates per-centroid displacement and subtracts
//!   `cum(s) + max_j cum(j)` from row `s`'s bounds (uniform per row, so
//!   the sort order survives). When accumulated motion gets comparable
//!   to the mean nearest-neighbour gap the rows are rebuilt from
//!   scratch.
//!
//! [`NeighbourCache`] mirrors the transpose cache's revision-keyed
//! protocol (`probe` never builds; `get` hits, syncs, or rebuilds), so
//! the serve layer can freeze an index into a published model view and
//! predict against it with zero rebuilds between publishes.

use crate::linalg::dense::DenseMatrix;
use crate::linalg::simd;
use crate::linalg::sparse::{prune_slack, TransposedCentroids};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Conservative fp slack for dense norms-trick distances, as a relative
/// factor on `(‖x‖ + ‖c‖)²`. Covers the worst stored-norm error (a
/// sequential f32 sum over d terms, γ ≈ d·2⁻²⁴ — `row_sq_norms` is the
/// loosest producer; the 8-lane SIMD dot and the f64-accumulated update
/// path are tighter) plus the final roundings, with ≥ 4x margin —
/// the same construction as the sparse [`prune_slack`].
#[inline]
pub(crate) fn slack_dense(d: usize) -> f64 {
    4.0e-7 * (d as f64 + 16.0)
}

/// `Σ_t (a_t − b_t)²` through one SIMD diff-square pass: subtract into
/// `diff`, then `dot(diff, diff)` on tier `t`. Relative error vs the
/// true squared distance is ≤ (d/8 + 5)·2⁻²⁴ (per-element subtract and
/// square roundings plus the 8-virtual-lane sum) — far inside
/// [`slack_dense`]. Shared by the neighbour-row build and Elkan's
/// inter-centroid half-distance refresh.
#[inline]
pub(crate) fn diff_sq(t: simd::Tier, a: &[f32], b: &[f32], diff: &mut [f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), diff.len());
    for i in 0..a.len() {
        diff[i] = a[i] - b[i];
    }
    simd::dot_with(t, diff, diff) as f64
}

/// The sorted inter-centroid rows: for each centroid `s`, the other
/// `k − 1` centroids ascending by certified lower bound on
/// `‖c_s − c_j‖`. Immutable once built; [`NeighbourIndex`] layers
/// per-revision decay on top and shares these rows across revisions.
#[derive(Debug)]
pub struct NeighbourRows {
    pub k: usize,
    pub d: usize,
    /// `cc[s·(k−1) + p]`: p-th smallest certified lower bound on
    /// `‖c_s − c_j‖` over `j ≠ s`.
    cc: Vec<f32>,
    /// The centroid index each `cc` entry refers to.
    idx: Vec<u32>,
    /// Mean over rows of the smallest entry (nearest-neighbour gap) —
    /// the scale the rebuild-vs-decay policy compares motion against.
    pub nn_mean: f64,
}

impl NeighbourRows {
    /// Heap footprint of the rows for `k` centroids (cache gates bound
    /// per-session memory with this before building).
    pub fn bytes_for(k: usize) -> usize {
        k.saturating_sub(1) * k * (std::mem::size_of::<f32>() + std::mem::size_of::<u32>())
    }

    /// Build from a centroid matrix: O(k²·d/2) diff-squares, then a
    /// per-row sort by `(cc, idx)`. Each stored bound is
    /// `√(v·(1 − slack)) · (1 − 1e-6)` with `v` the SIMD diff-square —
    /// certified ≤ the true distance (the relative slack covers the
    /// diff-square error, the 1e-6 haircut covers the f64→f32 store
    /// rounding).
    pub fn build(t: simd::Tier, c: &DenseMatrix) -> NeighbourRows {
        let (k, d) = (c.rows, c.cols);
        assert!(k >= 2, "neighbour rows need k >= 2");
        let km = k - 1;
        let mut cc = vec![0f32; k * km];
        let mut idx = vec![0u32; k * km];
        // pre-sort layout: row s holds neighbours in index order, with
        // j's position being j for j < s and j − 1 for j > s
        for s in 0..k {
            let row = &mut idx[s * km..(s + 1) * km];
            for j in 0..s {
                row[j] = j as u32;
            }
            for j in s + 1..k {
                row[j - 1] = j as u32;
            }
        }
        let rel = slack_dense(d);
        let mut diff = vec![0f32; d];
        for a in 0..k {
            for b in a + 1..k {
                let v = diff_sq(t, c.row(a), c.row(b), &mut diff);
                let lo = ((v * (1.0 - rel)).max(0.0).sqrt() * (1.0 - 1e-6)) as f32;
                cc[a * km + (b - 1)] = lo;
                cc[b * km + a] = lo;
            }
        }
        let mut buf: Vec<(f32, u32)> = Vec::with_capacity(km);
        let mut nn_sum = 0f64;
        for s in 0..k {
            buf.clear();
            for p in 0..km {
                buf.push((cc[s * km + p], idx[s * km + p]));
            }
            buf.sort_unstable_by(|x, y| x.0.total_cmp(&y.0).then(x.1.cmp(&y.1)));
            for (p, &(c_lo, j)) in buf.iter().enumerate() {
                cc[s * km + p] = c_lo;
                idx[s * km + p] = j;
            }
            nn_sum += buf[0].0 as f64;
        }
        NeighbourRows { k, d, cc, idx, nn_mean: nn_sum / k as f64 }
    }

    /// Row `s`: `(bounds, indices)`, ascending by bound.
    #[inline]
    pub fn row(&self, s: usize) -> (&[f32], &[u32]) {
        let km = self.k - 1;
        (&self.cc[s * km..(s + 1) * km], &self.idx[s * km..(s + 1) * km])
    }
}

/// One centroid revision's view of the neighbour structure: shared
/// sorted rows plus the per-row decay that keeps the bounds valid under
/// the motion accumulated since the rows were built, and the two
/// fp-slack ingredients frozen at this revision.
#[derive(Debug)]
pub struct NeighbourIndex {
    pub rows: Arc<NeighbourRows>,
    /// `decay[s] = cum(s) + max_j cum(j)`: subtract from every bound in
    /// row `s` to re-certify it against the *current* centroids
    /// (uniform per row, so the sort order is preserved).
    pub decay: Vec<f64>,
    /// Upper bound on `max_j ‖c_j‖` at this revision (slack scale).
    pub sq_max: f64,
    /// Upper bound on `max_j |stored norms[j] − ‖c_j‖²|`: the caller's
    /// incrementally-maintained norms may drift from the true ones, and
    /// unlike the additive norm-prune bound this does *not* cancel out
    /// of a geometric bound — it is added to every slack term instead.
    pub norm_gap: f64,
    /// The [`crate::kmeans::state::Centroids::rev`] this view certifies.
    pub rev: u64,
}

impl NeighbourIndex {
    pub fn k(&self) -> usize {
        self.rows.k
    }

    pub fn d(&self) -> usize {
        self.rows.d
    }

    /// Absolute bound on |computed d²(x, c_j) − true d²(x, c_j)| for a
    /// point with stored norm `xn`, given the relative slack `base`
    /// ([`slack_dense`] for dense points, [`prune_slack`] for sparse).
    /// Every ball radius and ring bound is widened by this before a
    /// skip, which is what keeps pruning bit-faithful.
    #[inline]
    pub fn slack_term(&self, base: f64, xn: f32) -> f64 {
        let sx = (xn as f64).max(0.0).sqrt();
        let scale = (sx + self.sq_max) * (sx + self.sq_max);
        base * scale + 2.0 * self.norm_gap
    }
}

/// How far accumulated centroid motion may grow, relative to the mean
/// nearest-neighbour gap, before decayed bounds are considered too
/// loose to prune well and the rows are rebuilt from scratch.
const REBUILD_FRAC: f64 = 0.25;

/// Revision-keyed cache for [`NeighbourIndex`], mirroring the transpose
/// cache's protocol: `probe` serves warm hits and never builds; `get`
/// hits, *syncs* (new decay over shared rows — O(k·d)), or rebuilds
/// (O(k²·d)). One per engine, like the transpose cache, so concurrent
/// sessions never evict each other.
#[derive(Debug, Default)]
pub struct NeighbourCache {
    slot: Mutex<NeighSlot>,
    hits: AtomicU64,
    builds: AtomicU64,
    syncs: AtomicU64,
}

#[derive(Debug, Default)]
struct NeighSlot {
    cur: Option<Arc<NeighbourIndex>>,
    /// Centroid snapshot the last sync measured displacement against.
    prev_c: Option<DenseMatrix>,
    /// Per-centroid motion accumulated since the rows were built
    /// (sum of per-sync displacements ≥ net displacement, so the decay
    /// stays certified across any number of missed revisions).
    cum: Vec<f64>,
}

impl NeighbourCache {
    /// Revision-matched indexes served without any work.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Full O(k²·d) row builds.
    pub fn builds(&self) -> u64 {
        self.builds.load(Ordering::Relaxed)
    }

    /// Incremental O(k·d) decay refreshes over shared rows.
    pub fn syncs(&self) -> u64 {
        self.syncs.load(Ordering::Relaxed)
    }

    /// `(hits, builds, syncs)` for observability scrapes.
    pub fn stats(&self) -> (u64, u64, u64) {
        (self.hits(), self.builds(), self.syncs())
    }

    /// Revision-matched index already in the slot (counted as a hit),
    /// or `None`. Warm-path gate: a probe never builds or syncs.
    pub fn probe(&self, centroids: &crate::kmeans::state::Centroids) -> Option<Arc<NeighbourIndex>> {
        let slot = self.slot.lock().unwrap();
        match &slot.cur {
            Some(cur)
                if cur.rev == centroids.rev
                    && cur.k() == centroids.k()
                    && cur.d() == centroids.d() =>
            {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(cur.clone())
            }
            _ => None,
        }
    }

    /// Counter parity for serves from an externally shared index
    /// (published-model predicts): a hit, no slot interaction.
    pub fn note_shared(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }

    /// The index for this centroid revision: a hit when the slot holds
    /// it, an incremental sync while accumulated motion stays small
    /// relative to the nearest-neighbour gap, a full rebuild otherwise.
    pub fn get(
        &self,
        centroids: &crate::kmeans::state::Centroids,
        t: simd::Tier,
    ) -> Arc<NeighbourIndex> {
        let (k, d) = (centroids.k(), centroids.d());
        assert!(k >= 2, "neighbour cache needs k >= 2");
        let mut slot = self.slot.lock().unwrap();
        let NeighSlot { cur, prev_c, cum } = &mut *slot;
        if let Some(ni) = cur.as_ref() {
            if ni.rev == centroids.rev && ni.k() == k && ni.d() == d {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return ni.clone();
            }
        }
        let shape_ok = prev_c
            .as_ref()
            .map_or(false, |p| p.rows == k && p.cols == d)
            && cur.as_ref().map_or(false, |ni| ni.k() == k && ni.d() == d);
        if shape_ok {
            // sync: accumulate displacement since the last snapshot and
            // refresh the slack ingredients in the same O(k·d) pass
            let prev = prev_c.as_mut().unwrap();
            let mut max_cum = 0f64;
            let mut sq_max = 0f64;
            let mut gap = 0f64;
            for j in 0..k {
                let (now, old) = (centroids.c.row(j), prev.row(j));
                let mut disp2 = 0f64;
                let mut nrm = 0f64;
                for c0 in 0..d {
                    let df = now[c0] as f64 - old[c0] as f64;
                    disp2 += df * df;
                    nrm += now[c0] as f64 * now[c0] as f64;
                }
                cum[j] += disp2.sqrt() * 1.000_000_1;
                max_cum = max_cum.max(cum[j]);
                sq_max = sq_max.max(nrm.sqrt());
                gap = gap.max((centroids.norms[j] as f64 - nrm).abs());
            }
            prev.data.copy_from_slice(&centroids.c.data);
            let rows = cur.as_ref().unwrap().rows.clone();
            if 2.0 * max_cum <= REBUILD_FRAC * rows.nn_mean {
                let decay: Vec<f64> = (0..k).map(|j| cum[j] + max_cum).collect();
                let ni = Arc::new(NeighbourIndex {
                    rows,
                    decay,
                    sq_max: sq_max * 1.000_001 + 1e-12,
                    norm_gap: gap * 1.000_001 + 1e-12,
                    rev: centroids.rev,
                });
                *cur = Some(ni.clone());
                self.syncs.fetch_add(1, Ordering::Relaxed);
                return ni;
            }
        }
        // full rebuild: fresh rows, zero accumulated motion
        let rows = Arc::new(NeighbourRows::build(t, &centroids.c));
        let mut sq_max = 0f64;
        let mut gap = 0f64;
        for j in 0..k {
            let row = centroids.c.row(j);
            let nrm: f64 = row.iter().map(|&x| x as f64 * x as f64).sum();
            sq_max = sq_max.max(nrm.sqrt());
            gap = gap.max((centroids.norms[j] as f64 - nrm).abs());
        }
        *prev_c = Some(centroids.c.clone());
        *cum = vec![0.0; k];
        let ni = Arc::new(NeighbourIndex {
            rows,
            decay: vec![0.0; k],
            sq_max: sq_max * 1.000_001 + 1e-12,
            norm_gap: gap * 1.000_001 + 1e-12,
            rev: centroids.rev,
        });
        *cur = Some(ni.clone());
        self.builds.fetch_add(1, Ordering::Relaxed);
        ni
    }
}

/// Probe stride for the dense exponion seed: evaluate every
/// `stride`-th centroid (≈ √k of them, at least 8) to find a tight
/// initial ball before walking the seed's sorted row.
#[inline]
pub fn probe_stride(k: usize) -> usize {
    let mut t = 1usize;
    while (t + 1) * (t + 1) <= k {
        t += 1;
    }
    (k / t.max(8).min(k)).max(1)
}

/// Exponion-pruned nearest centroid for one dense point. Bit-identical
/// label and distance to the flat scan ([`simd::nearest_with`] /
/// [`simd::nearest_block_with`]) on every non-FMA tier: every
/// evaluation uses the same `(xn + cn − 2·dot)` formula over the same
/// tier's dot (dot4 lanes are bitwise `dot_with`), skips only happen
/// when the skipped centroid's computed d² provably exceeds the running
/// best *strictly*, and out-of-order evaluation restores first-wins
/// ties with the explicit `j < best_j` rule. Returns
/// `(label, d², evaluations)`.
pub fn nearest_dense_exponion(
    t: simd::Tier,
    x: &[f32],
    xn: f32,
    c: &DenseMatrix,
    cnorms: &[f32],
    ni: &NeighbourIndex,
) -> (u32, f32, u32) {
    let k = c.rows;
    debug_assert_eq!(ni.k(), k);
    debug_assert_eq!(ni.d(), c.cols);
    let stride = probe_stride(k);
    // probe phase: index order + strict first-wins = lexicographic
    // argmin over the probe set
    let mut best = f32::INFINITY;
    let mut best_j = 0u32;
    let mut evals = 0u32;
    let mut j = 0usize;
    while j < k {
        let d2 = (xn + cnorms[j] - 2.0 * simd::dot_with(t, x, c.row(j))).max(0.0);
        evals += 1;
        if d2 < best {
            best = d2;
            best_j = j as u32;
        }
        j += stride;
    }
    let seed = best_j as usize;
    let slack = ni.slack_term(slack_dense(c.cols), xn);
    // ball radius from the seed's *own* computed d² (== best right
    // now): true d(x, s) ≤ √(computed + slack)
    let r_s = ((best as f64) + slack).sqrt() * 1.000_000_1;
    let dec = ni.decay[seed];
    let mut thr = r_s + ((best as f64) + slack).sqrt() * 1.000_000_1;
    let (ccs, idxs) = ni.rows.row(seed);
    for p in 0..ccs.len() {
        let cc_adj = ccs[p] as f64 - dec;
        if cc_adj > thr {
            // sorted row + uniform decay: every remaining centroid has
            // computed d² provably > best — stop
            break;
        }
        let jj = idxs[p] as usize;
        if jj % stride == 0 {
            continue; // already evaluated in the probe phase
        }
        let d2 = (xn + cnorms[jj] - 2.0 * simd::dot_with(t, x, c.row(jj))).max(0.0);
        evals += 1;
        if d2 < best || (d2 == best && (jj as u32) < best_j) {
            best = d2;
            best_j = jj as u32;
            thr = r_s + ((best as f64) + slack).sqrt() * 1.000_000_1;
        }
    }
    (best_j, best, evals)
}

/// Exponion-pruned nearest centroid for one sparse point through the
/// transposed block. Seeds exactly like the norm-prune path
/// (`prune_seed` fills the norm lower bounds and evaluates the
/// smallest-bound centroid), then walks the seed's sorted neighbour row
/// with *both* prunes active: the per-candidate norm bound (`lbs[j] >
/// best`, same rule as the gather finisher) and the exponion ring
/// cut-off. Evaluations go through `dot_one`, bitwise equal to the AXPY
/// sweep lanes, so label and distance stay bit-identical to the
/// unpruned sweep. Returns `(label, d², evaluations)`.
pub fn nearest_sparse_exponion(
    tc: &TransposedCentroids,
    idx: &[u32],
    vals: &[f32],
    xn: f32,
    cnorms: &[f32],
    ni: &NeighbourIndex,
    lbs: &mut [f32],
) -> (u32, f32, u32) {
    let k = tc.k;
    debug_assert_eq!(ni.k(), k);
    debug_assert_eq!(ni.d(), tc.d);
    let (seed, d0, _survivors) = tc.prune_seed(idx, vals, xn, cnorms, lbs);
    let mut best = d0;
    let mut best_j = seed as u32;
    let mut evals = 1u32;
    let slack = ni.slack_term(prune_slack(idx.len()), xn);
    let r_s = ((d0 as f64) + slack).sqrt() * 1.000_000_1;
    let dec = ni.decay[seed];
    let mut thr = r_s + ((best as f64) + slack).sqrt() * 1.000_000_1;
    let (ccs, idxs) = ni.rows.row(seed);
    for p in 0..ccs.len() {
        let cc_adj = ccs[p] as f64 - dec;
        if cc_adj > thr {
            break;
        }
        let jj = idxs[p] as usize;
        if lbs[jj] > best {
            continue; // norm bound, same strict rule as finish_gather
        }
        let d2 = (xn + cnorms[jj] - 2.0 * tc.dot_one(idx, vals, jj)).max(0.0);
        evals += 1;
        if d2 < best || (d2 == best && (jj as u32) < best_j) {
            best = d2;
            best_j = jj as u32;
            thr = r_s + ((best as f64) + slack).sqrt() * 1.000_000_1;
        }
    }
    (best_j, best, evals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kmeans::state::Centroids;
    use crate::linalg::sparse::CsrMatrix;
    use crate::util::propcheck::Cases;
    use crate::util::rng::Pcg64;

    fn random_centroids(rng: &mut Pcg64, k: usize, d: usize) -> Centroids {
        let c = DenseMatrix::from_vec(
            k,
            d,
            (0..k * d).map(|_| rng.gauss_f32()).collect(),
        );
        Centroids::from_matrix(c)
    }

    /// True inter-centroid distance in f64 (oracle).
    fn true_cc(a: &[f32], b: &[f32]) -> f64 {
        a.iter()
            .zip(b)
            .map(|(&x, &y)| {
                let df = x as f64 - y as f64;
                df * df
            })
            .sum::<f64>()
            .sqrt()
    }

    #[test]
    fn rows_are_sorted_complete_and_certified() {
        Cases::new(20).run(|rng| {
            let k = 2 + rng.below(30);
            let d = 1 + rng.below(40);
            let cent = random_centroids(rng, k, d);
            let rows = NeighbourRows::build(simd::tier(), &cent.c);
            assert_eq!((rows.k, rows.d), (k, d));
            for s in 0..k {
                let (cc, idx) = rows.row(s);
                assert_eq!(cc.len(), k - 1);
                // sorted ascending, every other centroid exactly once
                let mut seen = vec![false; k];
                for p in 0..cc.len() {
                    if p > 0 {
                        assert!(cc[p - 1] <= cc[p], "row {s} unsorted at {p}");
                    }
                    let j = idx[p] as usize;
                    assert_ne!(j, s);
                    assert!(!seen[j], "row {s} repeats {j}");
                    seen[j] = true;
                    // certified: bound never exceeds the true distance
                    let oracle = true_cc(cent.c.row(s), cent.c.row(j));
                    assert!(
                        (cc[p] as f64) <= oracle + 1e-12,
                        "row {s} nbr {j}: bound {} above true {oracle}",
                        cc[p]
                    );
                }
            }
        });
    }

    #[test]
    fn cache_hits_syncs_and_rebuilds() {
        let mut rng = Pcg64::new(7, 1);
        let mut cent = random_centroids(&mut rng, 12, 6);
        let cache = NeighbourCache::default();
        let t = simd::tier();
        assert!(cache.probe(&cent).is_none(), "probe must never build");
        let a = cache.get(&cent, t);
        let b = cache.get(&cent, t);
        assert!(Arc::ptr_eq(&a, &b), "same revision must hit");
        assert_eq!(cache.stats(), (1, 1, 0));
        assert!(cache.probe(&cent).is_some());
        assert_eq!(cache.stats(), (2, 1, 0));
        // tiny motion: sync shares the rows, refreshes decay
        for v in cent.c.data.iter_mut() {
            *v += 1e-5;
        }
        cent.touch();
        let c = cache.get(&cent, t);
        assert!(!Arc::ptr_eq(&a, &c));
        assert!(Arc::ptr_eq(&a.rows, &c.rows), "small motion must share rows");
        assert!(c.decay.iter().all(|&x| x > 0.0));
        assert_eq!(cache.stats(), (2, 1, 1));
        // huge motion: rebuild from scratch, decay resets
        for v in cent.c.data.iter_mut() {
            *v = -*v + 3.0;
        }
        cent.touch();
        let e = cache.get(&cent, t);
        assert!(!Arc::ptr_eq(&a.rows, &e.rows), "large motion must rebuild");
        assert!(e.decay.iter().all(|&x| x == 0.0));
        assert_eq!(cache.stats(), (2, 2, 1));
    }

    #[test]
    fn decayed_bounds_stay_certified_under_motion() {
        Cases::new(10).run(|rng| {
            let k = 2 + rng.below(15);
            let d = 2 + rng.below(10);
            let mut cent = random_centroids(rng, k, d);
            let cache = NeighbourCache::default();
            let t = simd::tier();
            for _ in 0..4 {
                let ni = cache.get(&cent, t);
                for s in 0..k {
                    let (cc, idx) = ni.rows.row(s);
                    for p in 0..cc.len() {
                        let j = idx[p] as usize;
                        let oracle = true_cc(cent.c.row(s), cent.c.row(j));
                        assert!(
                            cc[p] as f64 - ni.decay[s] <= oracle + 1e-9,
                            "s={s} j={j}: decayed bound above true distance"
                        );
                    }
                }
                // drift the centroids and bump the revision
                for v in cent.c.data.iter_mut() {
                    *v += 0.01 * rng.gauss_f32();
                }
                for j in 0..k {
                    let nrm: f64 = cent
                        .c
                        .row(j)
                        .iter()
                        .map(|&x| x as f64 * x as f64)
                        .sum();
                    cent.norms[j] = nrm as f32;
                }
                cent.touch();
            }
        });
    }

    #[test]
    fn dense_exponion_bit_identical_to_flat_scan() {
        if simd::tier() == simd::Tier::Avx2Fma {
            return; // opt-in FMA tier is documented as unfaithful
        }
        let t = simd::tier();
        Cases::new(12).run(|rng| {
            let k = 2 + rng.below(96);
            let d = 1 + rng.below(24);
            let mut cdata: Vec<f32> =
                (0..k * d).map(|_| rng.gauss_f32()).collect();
            // duplicate a centroid row to force exact d² ties
            if k >= 2 {
                for c0 in 0..d {
                    cdata[(k - 1) * d + c0] = cdata[c0];
                }
            }
            let cent = Centroids::from_matrix(DenseMatrix::from_vec(k, d, cdata));
            let cache = NeighbourCache::default();
            let ni = cache.get(&cent, t);
            for _ in 0..40 {
                let x: Vec<f32> = (0..d).map(|_| rng.gauss_f32()).collect();
                let xn = simd::dot_with(t, &x, &x);
                let (jf, df) = simd::nearest_with(t, &x, xn, &cent.c, &cent.norms);
                let (je, de, _evals) =
                    nearest_dense_exponion(t, &x, xn, &cent.c, &cent.norms, &ni);
                assert_eq!(je, jf, "argmin diverged (k={k} d={d})");
                assert_eq!(de.to_bits(), df.to_bits(), "distance diverged");
            }
        });
    }

    #[test]
    fn dense_exponion_bit_identical_at_serving_k() {
        // satellite coverage: k ∈ {64, 1024, 4096}, cold structure
        if simd::tier() == simd::Tier::Avx2Fma {
            return;
        }
        let t = simd::tier();
        let mut rng = Pcg64::new(41, 5);
        for k in [64usize, 1024, 4096] {
            let d = 12;
            let cent = random_centroids(&mut rng, k, d);
            let cache = NeighbourCache::default();
            let ni = cache.get(&cent, t);
            let mut pruned_any = false;
            for _ in 0..40 {
                let x: Vec<f32> = (0..d).map(|_| rng.gauss_f32()).collect();
                let xn = simd::dot_with(t, &x, &x);
                let (jf, df) = simd::nearest_with(t, &x, xn, &cent.c, &cent.norms);
                let (je, de, evals) =
                    nearest_dense_exponion(t, &x, xn, &cent.c, &cent.norms, &ni);
                assert_eq!(je, jf, "argmin diverged at k={k}");
                assert_eq!(de.to_bits(), df.to_bits(), "distance diverged at k={k}");
                pruned_any |= (evals as usize) < k;
            }
            assert!(
                pruned_any,
                "exponion never pruned anything at k={k} — structure inert"
            );
        }
    }

    #[test]
    fn dense_exponion_bit_identical_under_motion_warm_structure() {
        // k = 1024 across several drifting revisions: syncs and
        // rebuilds must both preserve exact parity
        if simd::tier() == simd::Tier::Avx2Fma {
            return;
        }
        let t = simd::tier();
        let mut rng = Pcg64::new(13, 9);
        let k = 1024;
        let d = 10;
        let mut cent = random_centroids(&mut rng, k, d);
        let cache = NeighbourCache::default();
        for round in 0..4 {
            let ni = cache.get(&cent, t);
            for _ in 0..24 {
                let x: Vec<f32> = (0..d).map(|_| rng.gauss_f32()).collect();
                let xn = simd::dot_with(t, &x, &x);
                let (jf, df) = simd::nearest_with(t, &x, xn, &cent.c, &cent.norms);
                let (je, de, _) =
                    nearest_dense_exponion(t, &x, xn, &cent.c, &cent.norms, &ni);
                assert_eq!(je, jf, "round {round}: argmin diverged");
                assert_eq!(de.to_bits(), df.to_bits(), "round {round}: d² diverged");
            }
            // small drift so at least some rounds take the sync path
            let scale = if round == 1 { 0.5 } else { 0.004 };
            for v in cent.c.data.iter_mut() {
                *v += scale * rng.gauss_f32();
            }
            for j in 0..k {
                let nrm: f64 =
                    cent.c.row(j).iter().map(|&x| x as f64 * x as f64).sum();
                cent.norms[j] = nrm as f32;
            }
            cent.touch();
        }
        let (_, builds, syncs) = cache.stats();
        assert!(syncs >= 1, "no round took the incremental sync path");
        assert!(builds >= 1);
    }

    fn random_csr(rng: &mut Pcg64, rows: usize, cols: usize, nnz_per: usize) -> CsrMatrix {
        let mut m = CsrMatrix::empty(cols);
        for _ in 0..rows {
            let nnz = 1 + rng.below(nnz_per);
            let cols_idx = rng.sample_distinct(cols, nnz.min(cols));
            let row: Vec<(u32, f32)> = cols_idx
                .iter()
                .map(|&c| (c as u32, rng.gauss_f32()))
                .collect();
            m.push_row(&row);
        }
        m
    }

    #[test]
    fn sparse_exponion_bit_identical_to_sweep() {
        if simd::tier() == simd::Tier::Avx2Fma {
            return; // unfused gathers; skip under opt-in FMA
        }
        let t = simd::tier();
        Cases::new(10).run(|rng| {
            let d = 16 + rng.below(120);
            let k = 2 + rng.below(60);
            let m = random_csr(rng, 24, d, 12);
            let cent = random_centroids(rng, k, d);
            let tc = TransposedCentroids::build(&cent.c);
            let cache = NeighbourCache::default();
            let ni = cache.get(&cent, t);
            let xns = m.row_sq_norms();
            let mut scratch = vec![0f32; k];
            let mut lbs = vec![0f32; k];
            for i in 0..m.rows {
                let (idx, vals) = m.row(i);
                let (js, ds) =
                    tc.nearest(idx, vals, xns[i], &cent.norms, &mut scratch);
                let (je, de, _) = nearest_sparse_exponion(
                    &tc, idx, vals, xns[i], &cent.norms, &ni, &mut lbs,
                );
                assert_eq!(je, js, "point {i}: argmin diverged (k={k})");
                assert_eq!(de.to_bits(), ds.to_bits(), "point {i}: d² diverged");
            }
        });
    }

    #[test]
    fn sparse_exponion_bit_identical_at_large_k_under_motion() {
        if simd::tier() == simd::Tier::Avx2Fma {
            return;
        }
        let t = simd::tier();
        let mut rng = Pcg64::new(29, 3);
        let d = 96;
        let k = 1024;
        let m = random_csr(&mut rng, 20, d, 10);
        let mut cent = random_centroids(&mut rng, k, d);
        let cache = NeighbourCache::default();
        let xns = m.row_sq_norms();
        for round in 0..3 {
            let tc = TransposedCentroids::build(&cent.c);
            let ni = cache.get(&cent, t);
            let mut scratch = vec![0f32; k];
            let mut lbs = vec![0f32; k];
            for i in 0..m.rows {
                let (idx, vals) = m.row(i);
                let (js, ds) =
                    tc.nearest(idx, vals, xns[i], &cent.norms, &mut scratch);
                let (je, de, _) = nearest_sparse_exponion(
                    &tc, idx, vals, xns[i], &cent.norms, &ni, &mut lbs,
                );
                assert_eq!(je, js, "round {round} point {i}: argmin diverged");
                assert_eq!(de.to_bits(), ds.to_bits(), "round {round} point {i}");
            }
            for v in cent.c.data.iter_mut() {
                *v += 0.002 * rng.gauss_f32();
            }
            for j in 0..k {
                let nrm: f64 =
                    cent.c.row(j).iter().map(|&x| x as f64 * x as f64).sum();
                cent.norms[j] = nrm as f32;
            }
            cent.touch();
        }
        assert!(cache.syncs() >= 1, "large-k motion test never synced");
    }

    #[test]
    fn probe_stride_scales_like_sqrt_k() {
        assert_eq!(probe_stride(2), 1);
        assert_eq!(probe_stride(64), 8);
        assert_eq!(probe_stride(4096), 64);
        for k in [2usize, 7, 64, 100, 513, 1024, 4096, 5000] {
            let s = probe_stride(k);
            assert!(s >= 1 && s <= k);
            // at least one probe, at most ~max(√k, k/8) + 1 of them
            let probes = k.div_ceil(s);
            assert!(probes >= 1 && probes <= k);
        }
    }
}
