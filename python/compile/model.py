"""L2: the jitted JAX programs exported to the rust runtime.

Each function here composes the L1 Pallas kernels (``kernels/distance.py``)
into one of the three programs the rust coordinator executes on its hot
path (see DESIGN.md, Layer-2 table):

  * ``assign_fn``       — assignment step for one padded batch.
  * ``assign_stats_fn`` — assignment fused with per-cluster sufficient
                          statistics, used when ingesting new points into
                          the nested batch (one round trip instead of two).
  * ``stats_fn``        — statistics alone, for relabelled tiles.
  * ``screen_fn``       — Elkan bound screen for tb-ρ.

``aot.py`` lowers these for a fixed set of (B, D, K) shapes and writes
HLO text + a manifest; rust pads its batches up to a compiled shape.
Python never runs at clustering time.
"""

import jax
import jax.numpy as jnp

from compile.kernels import distance


def assign_fn(x, c, cnorm):
    """(X[B,D], C[K,D], cnorm[K]) → (labels[B] i32, d2[B] f32)."""
    return distance.assign(x, c, cnorm)


def stats_fn(x, labels, d2, *, k):
    """(X[B,D], labels[B], d2[B]) → (S[K,D], v[K], sse[K])."""
    return distance.cluster_stats(x, labels, d2, k)


def assign_stats_fn(x, c, cnorm):
    """Fused assignment + statistics for new-point ingestion.

    Returns (labels, d2, S, v, sse). Fusing keeps the (B, D) tile on
    device between the two kernels; only (K, D)-sized statistics plus the
    per-point labels return to the coordinator.
    """
    labels, d2 = distance.assign(x, c, cnorm)
    s, v, sse = distance.cluster_stats(x, labels, d2, c.shape[0])
    return labels, d2, s, v, sse


def distmat_fn(x, c, cnorm):
    """(X[B,D], C[K,D], cnorm[K]) → D²[B,K] full distance matrix."""
    return (distance.distmat(x, c, cnorm),)


def screen_fn(lb, p, d, labels):
    """(L[B,K], p[K], d[B], labels[B]) → (L'[B,K], dirty[B] i32)."""
    return distance.bound_screen(lb, p, d, labels)


def validation_mse_fn(x, c, cnorm):
    """(X[B,D], C[K,D], cnorm[K]) → scalar Σ_i min_j ‖x_i − c_j‖².

    Used by the metrics path to score a validation batch; summed (not
    averaged) so the coordinator can accumulate across padded tiles and
    divide by the true N itself.
    """
    _, d2 = distance.assign(x, c, cnorm)
    return (jnp.sum(d2),)


def lower(fn, *example_args):
    """Lower a jitted function; shared helper for aot.py and tests."""
    return jax.jit(fn).lower(*example_args)
