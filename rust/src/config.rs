//! Run configuration: which algorithm, dataset, batch policy, engine and
//! budget. Parsed from CLI args (`util::args`) or config files
//! (`key = value` lines), consumed by `kmeans::run` and the experiment
//! harnesses.

use crate::util::args::{ArgError, Args};

/// The clustering algorithms in the paper's evaluation (§4) plus the
/// Elkan-accelerated exact baseline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// Lloyd's exact algorithm.
    Lloyd,
    /// Lloyd with Elkan triangle-inequality acceleration (identical
    /// output, fewer distance computations).
    Elkan,
    /// Bottou–Bengio online k-means (mb with b = 1).
    Sgd,
    /// Sculley mini-batch (Alg. 1, via the S/v reformulation Alg. 8).
    Mb,
    /// Fixed mini-batch: removes contaminating assignments (Alg. 4).
    MbF,
    /// Grow-batch with the σ̂_C/p controller (Alg. 7; ρ=∞ → Alg. 10).
    GbRho,
    /// Turbocharged grow-batch: gb-ρ + Elkan bounds (Alg. 9 / 11).
    TbRho,
}

impl Algo {
    pub fn parse(s: &str) -> Result<Algo, ArgError> {
        Ok(match s {
            "lloyd" => Algo::Lloyd,
            "elkan" => Algo::Elkan,
            "sgd" => Algo::Sgd,
            "mb" => Algo::Mb,
            "mbf" | "mb-f" => Algo::MbF,
            "gb" | "gb-rho" => Algo::GbRho,
            "tb" | "tb-rho" => Algo::TbRho,
            other => {
                return Err(ArgError(format!(
                    "unknown algorithm '{other}' \
                     (lloyd|elkan|sgd|mb|mbf|gb|tb)"
                )))
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algo::Lloyd => "lloyd",
            Algo::Elkan => "elkan",
            Algo::Sgd => "sgd",
            Algo::Mb => "mb",
            Algo::MbF => "mb-f",
            Algo::GbRho => "gb",
            Algo::TbRho => "tb",
        }
    }
}

/// The gb/tb batch-growth threshold ρ. `Infinite` is the paper's
/// degenerate ρ=∞ case: double iff a majority of centroids did not move.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Rho {
    Finite(f64),
    Infinite,
}

impl Rho {
    pub fn parse(s: &str) -> Result<Rho, ArgError> {
        if s == "inf" || s == "infinity" || s == "∞" {
            Ok(Rho::Infinite)
        } else {
            s.parse::<f64>()
                .map(Rho::Finite)
                .map_err(|_| ArgError(format!("bad --rho '{s}'")))
        }
    }

    pub fn label(&self) -> String {
        match self {
            Rho::Finite(x) => format!("{x}"),
            Rho::Infinite => "inf".to_string(),
        }
    }
}

/// Centroid initialisation scheme. The paper's protocol is `FirstK`
/// (first k rows of the per-seed shuffle); the alternatives implement
/// its §5 future-work direction on initialisation for subsample
/// algorithms (`KmeansPPBatch` is the mini-batch-compatible variant:
/// D² seeding restricted to the initial batch, so it needs no full
/// data pass).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InitScheme {
    FirstK,
    Uniform,
    KmeansPPBatch,
}

impl InitScheme {
    pub fn parse(s: &str) -> Result<InitScheme, ArgError> {
        Ok(match s {
            "first-k" | "firstk" => InitScheme::FirstK,
            "uniform" => InitScheme::Uniform,
            "kmeans++batch" | "pp-batch" => InitScheme::KmeansPPBatch,
            other => {
                return Err(ArgError(format!(
                    "unknown init '{other}' (first-k|uniform|pp-batch)"
                )))
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            InitScheme::FirstK => "first-k",
            InitScheme::Uniform => "uniform",
            InitScheme::KmeansPPBatch => "pp-batch",
        }
    }
}

/// Which assignment engine executes the distance hot-spot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// Pure-rust scalar/unrolled loops (reference; only option for CSR).
    Native,
    /// PJRT-compiled Pallas/XLA artifacts for dense tiles (Layer 1/2).
    Xla,
}

impl Engine {
    pub fn parse(s: &str) -> Result<Engine, ArgError> {
        match s {
            "native" => Ok(Engine::Native),
            "xla" => Ok(Engine::Xla),
            other => Err(ArgError(format!("unknown engine '{other}'"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Engine::Native => "native",
            Engine::Xla => "xla",
        }
    }
}

/// Stop conditions and run policy. Defaults mirror the paper's §4.3
/// setup (k = 50, b = b0 = 5000) at CI-friendly budgets.
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    pub algo: Algo,
    pub k: usize,
    /// Mini-batch size (mb/mb-f) and initial grow-batch size b0.
    pub b0: usize,
    pub rho: Rho,
    pub engine: Engine,
    /// Worker threads for the assignment step (1 = serial).
    pub threads: usize,
    pub seed: u64,
    /// Wall-clock work-time budget in seconds (paper plots MSE vs time).
    pub max_seconds: f64,
    /// Hard cap on rounds (safety net; usize::MAX = off).
    pub max_rounds: usize,
    /// Evaluate validation MSE roughly every this many seconds of work
    /// time (0 = every round).
    pub eval_every_secs: f64,
    /// Stop when a full-batch algorithm reaches a fixed point.
    pub stop_on_convergence: bool,
    /// Path to artifacts/ for the XLA engine.
    pub artifacts_dir: String,
    /// Centroid initialisation (paper protocol: FirstK).
    pub init: InitScheme,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            algo: Algo::TbRho,
            k: 50,
            b0: 5000,
            rho: Rho::Infinite,
            engine: Engine::Native,
            threads: 1,
            seed: 0,
            max_seconds: 10.0,
            max_rounds: usize::MAX,
            eval_every_secs: 0.25,
            stop_on_convergence: true,
            artifacts_dir: "artifacts".to_string(),
            init: InitScheme::FirstK,
        }
    }
}

impl RunConfig {
    /// Fill a config from parsed CLI args (all optional, defaults above).
    pub fn from_args(args: &Args) -> Result<RunConfig, ArgError> {
        let mut cfg = RunConfig::default();
        if let Some(a) = args.get("algo") {
            cfg.algo = Algo::parse(a)?;
        }
        if args.get("k").is_some() {
            cfg.k = args.get_usize("k")?;
        }
        if args.get("b0").is_some() {
            cfg.b0 = args.get_usize("b0")?;
        }
        if let Some(r) = args.get("rho") {
            cfg.rho = Rho::parse(r)?;
        }
        if let Some(e) = args.get("engine") {
            cfg.engine = Engine::parse(e)?;
        }
        if args.get("threads").is_some() {
            cfg.threads = args.get_usize("threads")?.max(1);
        }
        if args.get("seed").is_some() {
            cfg.seed = args.get_u64("seed")?;
        }
        if args.get("seconds").is_some() {
            cfg.max_seconds = args.get_f64("seconds")?;
        }
        if args.get("rounds").is_some() {
            cfg.max_rounds = args.get_usize("rounds")?;
        }
        if let Some(d) = args.get("artifacts") {
            cfg.artifacts_dir = d.to_string();
        }
        if let Some(i) = args.get("init") {
            cfg.init = InitScheme::parse(i)?;
        }
        Ok(cfg)
    }

    /// Parse `key = value` lines (config-file form; `#` comments).
    pub fn apply_file(&mut self, text: &str) -> Result<(), ArgError> {
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| ArgError(format!("line {}: expected key = value", lineno + 1)))?;
            let (key, val) = (key.trim(), val.trim());
            match key {
                "algo" => self.algo = Algo::parse(val)?,
                "k" => self.k = parse_num(key, val)?,
                "b0" => self.b0 = parse_num(key, val)?,
                "rho" => self.rho = Rho::parse(val)?,
                "engine" => self.engine = Engine::parse(val)?,
                "threads" => self.threads = parse_num::<usize>(key, val)?.max(1),
                "seed" => self.seed = parse_num(key, val)?,
                "seconds" => self.max_seconds = parse_num(key, val)?,
                "rounds" => self.max_rounds = parse_num(key, val)?,
                "eval_every_secs" => self.eval_every_secs = parse_num(key, val)?,
                "artifacts" => self.artifacts_dir = val.to_string(),
                "init" => self.init = InitScheme::parse(val)?,
                other => {
                    return Err(ArgError(format!("unknown config key '{other}'")))
                }
            }
        }
        Ok(())
    }

    /// Serialise for the model-snapshot artifact (`serve::snapshot`).
    /// Counts stay readable JSON numbers; `f64` fields and 64-bit ints
    /// travel as hex bit patterns so the round trip is bit-exact even
    /// for `inf` budgets and `usize::MAX` round caps.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{self as json, Json};
        json::obj(vec![
            ("algo", json::s(self.algo.name())),
            ("k", json::num(self.k as f64)),
            ("b0", json::num(self.b0 as f64)),
            ("rho", json::s(&self.rho.label())),
            ("engine", json::s(self.engine.name())),
            ("threads", json::num(self.threads as f64)),
            ("seed", json::s(&format!("{:x}", self.seed))),
            ("max_seconds", json::s(&format!("{:x}", self.max_seconds.to_bits()))),
            ("max_rounds", json::s(&format!("{:x}", self.max_rounds))),
            (
                "eval_every_secs",
                json::s(&format!("{:x}", self.eval_every_secs.to_bits())),
            ),
            ("stop_on_convergence", Json::Bool(self.stop_on_convergence)),
            ("artifacts_dir", json::s(&self.artifacts_dir)),
            ("init", json::s(self.init.name())),
        ])
    }

    /// Inverse of [`RunConfig::to_json`]. Missing keys keep defaults so
    /// older snapshots stay loadable as fields are added.
    pub fn from_json(v: &crate::util::json::Json) -> Result<RunConfig, ArgError> {
        let mut cfg = RunConfig::default();
        let hex_u64 = |key: &str| -> Result<Option<u64>, ArgError> {
            match v.get(key) {
                None => Ok(None),
                Some(x) => {
                    let s = x
                        .as_str()
                        .ok_or_else(|| ArgError(format!("config {key}: expected hex string")))?;
                    u64::from_str_radix(s, 16)
                        .map(Some)
                        .map_err(|_| ArgError(format!("config {key}: bad hex '{s}'")))
                }
            }
        };
        if let Some(x) = v.get("algo").and_then(|x| x.as_str()) {
            cfg.algo = Algo::parse(x)?;
        }
        if let Some(x) = v.get("k").and_then(|x| x.as_usize()) {
            cfg.k = x;
        }
        if let Some(x) = v.get("b0").and_then(|x| x.as_usize()) {
            cfg.b0 = x;
        }
        if let Some(x) = v.get("rho").and_then(|x| x.as_str()) {
            cfg.rho = Rho::parse(x)?;
        }
        if let Some(x) = v.get("engine").and_then(|x| x.as_str()) {
            cfg.engine = Engine::parse(x)?;
        }
        if let Some(x) = v.get("threads").and_then(|x| x.as_usize()) {
            cfg.threads = x.max(1);
        }
        if let Some(x) = hex_u64("seed")? {
            cfg.seed = x;
        }
        if let Some(x) = hex_u64("max_seconds")? {
            cfg.max_seconds = f64::from_bits(x);
        }
        if let Some(x) = hex_u64("max_rounds")? {
            cfg.max_rounds = x as usize;
        }
        if let Some(x) = hex_u64("eval_every_secs")? {
            cfg.eval_every_secs = f64::from_bits(x);
        }
        if let Some(x) = v.get("stop_on_convergence").and_then(|x| x.as_bool()) {
            cfg.stop_on_convergence = x;
        }
        if let Some(x) = v.get("artifacts_dir").and_then(|x| x.as_str()) {
            cfg.artifacts_dir = x.to_string();
        }
        if let Some(x) = v.get("init").and_then(|x| x.as_str()) {
            cfg.init = InitScheme::parse(x)?;
        }
        Ok(cfg)
    }

    /// Human-readable one-liner for logs.
    pub fn label(&self) -> String {
        match self.algo {
            Algo::GbRho | Algo::TbRho => {
                format!("{}-{}", self.algo.name(), self.rho.label())
            }
            _ => self.algo.name().to_string(),
        }
    }
}

fn parse_num<T: std::str::FromStr>(key: &str, val: &str) -> Result<T, ArgError> {
    val.parse()
        .map_err(|_| ArgError(format!("bad numeric value for '{key}': '{val}'")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algo_roundtrip() {
        for s in ["lloyd", "elkan", "sgd", "mb", "mbf", "gb", "tb"] {
            let a = Algo::parse(s).unwrap();
            assert!(Algo::parse(a.name()).is_ok());
        }
        assert!(Algo::parse("bogus").is_err());
    }

    #[test]
    fn rho_parse() {
        assert_eq!(Rho::parse("inf").unwrap(), Rho::Infinite);
        assert_eq!(Rho::parse("100").unwrap(), Rho::Finite(100.0));
        assert!(Rho::parse("x").is_err());
    }

    #[test]
    fn config_file_parsing() {
        let mut cfg = RunConfig::default();
        cfg.apply_file(
            "algo = tb   # the turbo one\nk = 10\nrho = inf\nseconds = 2.5\n\n# comment\n",
        )
        .unwrap();
        assert_eq!(cfg.algo, Algo::TbRho);
        assert_eq!(cfg.k, 10);
        assert_eq!(cfg.rho, Rho::Infinite);
        assert_eq!(cfg.max_seconds, 2.5);
        assert!(cfg.apply_file("nope = 3").is_err());
        assert!(cfg.apply_file("k 3").is_err());
    }

    #[test]
    fn label_includes_rho_for_gb_tb() {
        let cfg = RunConfig { algo: Algo::TbRho, rho: Rho::Finite(100.0), ..Default::default() };
        assert_eq!(cfg.label(), "tb-100");
        let cfg = RunConfig { algo: Algo::Mb, ..Default::default() };
        assert_eq!(cfg.label(), "mb");
    }

    #[test]
    fn json_roundtrip_is_bit_exact() {
        let cfg = RunConfig {
            algo: Algo::GbRho,
            k: 13,
            b0: 777,
            rho: Rho::Finite(2.5),
            engine: Engine::Xla,
            threads: 6,
            seed: u64::MAX - 3,
            max_seconds: f64::INFINITY,
            max_rounds: usize::MAX,
            eval_every_secs: 0.1, // not exactly representable — bits must survive
            stop_on_convergence: false,
            artifacts_dir: "some/dir".to_string(),
            init: InitScheme::Uniform,
        };
        let text = cfg.to_json().to_string();
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        let back = RunConfig::from_json(&parsed).unwrap();
        assert_eq!(back, cfg);
        assert_eq!(back.eval_every_secs.to_bits(), cfg.eval_every_secs.to_bits());
        // missing keys keep defaults
        let sparse = crate::util::json::Json::parse(r#"{"k": 9}"#).unwrap();
        let c = RunConfig::from_json(&sparse).unwrap();
        assert_eq!(c.k, 9);
        assert_eq!(c.b0, RunConfig::default().b0);
        // malformed hex rejected
        let bad = crate::util::json::Json::parse(r#"{"seed": "zz"}"#).unwrap();
        assert!(RunConfig::from_json(&bad).is_err());
    }

    #[test]
    fn engine_name_roundtrip() {
        for e in [Engine::Native, Engine::Xla] {
            assert_eq!(Engine::parse(e.name()).unwrap(), e);
        }
    }

    #[test]
    fn from_args_defaults_and_overrides() {
        use crate::util::args::{Args, OptSpec};
        let spec = [
            OptSpec { name: "algo", takes_value: true, default: None, help: "" },
            OptSpec { name: "rho", takes_value: true, default: None, help: "" },
            OptSpec { name: "k", takes_value: true, default: None, help: "" },
        ];
        let raw: Vec<String> =
            ["--algo", "gb", "--rho", "10", "--k", "8"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse(&raw, &spec).unwrap();
        let cfg = RunConfig::from_args(&args).unwrap();
        assert_eq!(cfg.algo, Algo::GbRho);
        assert_eq!(cfg.rho, Rho::Finite(10.0));
        assert_eq!(cfg.k, 8);
        assert_eq!(cfg.b0, 5000); // default preserved
    }
}
