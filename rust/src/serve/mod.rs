//! The serving layer: what turns the reproduction into a long-lived
//! system.
//!
//! The paper's algorithms digest data as it streams in — but the rest of
//! this crate, like the paper's evaluation, is batch: a model lives and
//! dies inside one `kmeans::run` call. This subsystem adds the three
//! capabilities a production deployment needs on top of that:
//!
//! | module       | capability |
//! |--------------|------------|
//! | [`snapshot`] | versioned, bit-exact model artifacts (save/load, streaming writes) |
//! | [`session`]  | pause/resume training; ingest new points online    |
//! | [`registry`] | many named models per process; snapshot-isolated, batched predicts |
//! | [`wire`]     | point encodings: dense arrays and sparse `{indices,values,dim}` rows |
//! | [`protocol`] | JSONL request/response: create·ingest·predict·…·drop |
//! | [`frame`]    | opt-in length-prefixed binary frames (raw-f32 predict hot path) |
//! | [`server`]   | transports: stdio pipes and event-driven TCP, per-connection format negotiation |
//! | [`event`]    | the readiness loop: epoll/kqueue poller, connection shards, worker pool, admission + backpressure |
//! | [`observe`]  | serve-layer metrics: per-model counters/histograms, merged scrape snapshot |
//! | [`wal`]      | durable CRC-framed op log, checkpoints, bit-exact crash recovery |
//! | [`replica`]  | follower mode: bootstrap from snapshots, tail the primary's log, promote with an epoch fence |
//!
//! The load-bearing invariant throughout is the paper's §3.1
//! each-point-counts-exactly-once property: ingested points append
//! *behind* the nested batch and enter the sufficient statistics exactly
//! once, when the σ̂_C/p controller grows the batch over them; snapshots
//! serialise every accumulator bit-exactly so a resumed session retraces
//! the uninterrupted run. Per model, that invariant is untouched by
//! concurrency: mutations serialise on the model's session lock while
//! predicts read immutable published snapshots. CLI front-ends: `nmbkm
//! train --save`, `nmbkm serve [--models]`, `nmbkm predict`.

pub mod event;
pub mod frame;
pub mod observe;
pub mod protocol;
pub mod registry;
pub mod replica;
pub mod server;
pub mod session;
pub mod snapshot;
pub mod wal;
pub mod wire;

pub use registry::{ModelRegistry, PublishedModel, SpillConfig};
pub use session::OnlineSession;
pub use snapshot::{Snapshot, SnapshotFormat};
pub use wire::WireRow;
