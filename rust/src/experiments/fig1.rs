//! Figure 1: validation MSE (relative to best V0) versus work time for
//! {lloyd, mb, mb-f, gb-∞, tb-∞} on infMNIST (dense) and RCV1 (sparse).
//!
//! The paper's claims this reproduces:
//!   1. `mb-f` beats `mb` after roughly one pass through the data;
//!   2. `gb-∞` is already favourable versus `mb-f`;
//!   3. `tb-∞` dominates everything and reaches lloyd-grade minima
//!      orders of magnitude sooner than `lloyd`.

use crate::config::{Algo, Rho, RunConfig};
use crate::data::Dataset;
use crate::experiments::common::{self, Curve, ExpOpts};
use crate::kmeans::assign::AssignEngine;

pub fn algo_set() -> Vec<RunConfig> {
    let base = RunConfig::default();
    vec![
        RunConfig { algo: Algo::Lloyd, ..base.clone() },
        RunConfig { algo: Algo::Mb, ..base.clone() },
        RunConfig { algo: Algo::MbF, ..base.clone() },
        RunConfig { algo: Algo::GbRho, rho: Rho::Infinite, ..base.clone() },
        RunConfig { algo: Algo::TbRho, rho: Rho::Infinite, ..base },
    ]
}

/// Run the Figure-1 comparison on one dataset; returns curves in the
/// same order as [`algo_set`].
pub fn run_dataset(
    ds: &Dataset,
    opts: &ExpOpts,
    engine: &dyn AssignEngine,
) -> anyhow::Result<Vec<Curve>> {
    let b0 = common::default_b0(opts.scale);
    let grid = common::time_grid(opts.seconds / 100.0, opts.seconds, 24);
    let mut curves = Vec::new();
    for mut cfg in algo_set() {
        cfg.k = 50.min(ds.train.n() / 4).max(2);
        cfg.b0 = b0;
        cfg.eval_every_secs = opts.seconds / 40.0;
        let (curve, _) =
            common::multi_seed_curve(ds, &cfg, opts, engine, &grid)?;
        println!(
            "   [{}] {}: mean final MSE {:.6e}",
            ds.name, curve.label, curve.mean_final
        );
        curves.push(curve);
    }
    Ok(curves)
}

/// Full Figure-1 experiment: both datasets, CSV per dataset.
pub fn run(opts: &ExpOpts) -> anyhow::Result<()> {
    let engine: Box<dyn AssignEngine + Send> = match opts.engine {
        crate::config::Engine::Native => {
            Box::new(crate::kmeans::assign::NativeEngine::default())
        }
        crate::config::Engine::Xla => crate::runtime::make_engine("artifacts")?,
    };
    for (ds, tag) in [
        (common::infmnist(opts.scale), "infmnist"),
        (common::rcv1(opts.scale), "rcv1"),
    ] {
        println!("== Figure 1 on {} ==", ds.summary());
        let curves = run_dataset(&ds, opts, engine.as_ref())?;
        common::print_final_summary(tag, &curves);
        let path = common::write_curves_csv(&format!("fig1_{tag}"), tag, &curves)?;
        println!("   wrote {}", path.display());
        check_shape(tag, &curves);
    }
    Ok(())
}

/// The qualitative assertions the paper's Figure 1 makes; printed as a
/// PASS/WARN line so bench logs record whether the reproduction holds.
pub fn check_shape(tag: &str, curves: &[Curve]) {
    let find = |label: &str| curves.iter().find(|c| c.label == label);
    let (Some(mb), Some(mbf), Some(tb)) = (find("mb"), find("mb-f"), find("tb-inf"))
    else {
        println!("   [shape] missing curves, skipping check");
        return;
    };
    let ok1 = mbf.mean_final <= mb.mean_final * 1.05;
    let ok2 = tb.mean_final <= mb.mean_final * 1.02;
    println!(
        "   [shape {tag}] mb-f ≤ mb at end: {}   tb-∞ ≤ mb at end: {}",
        if ok1 { "PASS" } else { "WARN" },
        if ok2 { "PASS" } else { "WARN" },
    );
}
