//! Bench F2 — regenerates Figure 2: the effect of ρ ∈ {1,10,100,1000,∞}
//! on gb-ρ and tb-ρ, infMNIST, with mb for reference.
//!
//! Expected shape (paper §4.3.1): gb-ρ has an intermediate sweet spot
//! early with large ρ winning late; tb-ρ is best at very large ρ
//! (ρ=1000 ≈ ρ=∞), and ρ=1 shows the redundancy-induced slowdown.

use nmbkm::experiments::{common::ExpOpts, rho_sweep};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = ExpOpts::from_args(&args);
    println!(
        "[fig2] scale={:?} seeds={} budget={}s/run",
        opts.scale, opts.seeds, opts.seconds
    );
    rho_sweep::run(2, &opts).expect("fig2 failed");
}
