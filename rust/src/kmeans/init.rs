//! Centroid initialisation.
//!
//! The paper's protocol (§4.3) shuffles the training set per seed and
//! takes the first k datapoints — [`first_k`]. [`uniform`] and
//! [`kmeanspp`] (Arthur & Vassilvitskii 2007) are provided for the
//! initialisation discussion in §1/§5 and for the examples.

use crate::data::Data;
use crate::kmeans::state::Centroids;
use crate::linalg::dense::DenseMatrix;
use crate::util::rng::Pcg64;

/// Densify rows `idx` of `data` into a centroid matrix.
pub fn from_rows(data: &Data, idx: &[usize]) -> Centroids {
    let d = data.dim();
    let mut c = DenseMatrix::zeros(idx.len(), d);
    for (r, &i) in idx.iter().enumerate() {
        data.write_row_dense(i, c.row_mut(r));
    }
    Centroids::from_matrix(c)
}

/// Paper init: first k rows (the caller shuffles the data per seed).
pub fn first_k(data: &Data, k: usize) -> Centroids {
    assert!(k <= data.n(), "k={k} > n={}", data.n());
    from_rows(data, &(0..k).collect::<Vec<_>>())
}

/// k distinct uniformly sampled datapoints.
pub fn uniform(data: &Data, k: usize, rng: &mut Pcg64) -> Centroids {
    assert!(k <= data.n());
    let idx = rng.sample_distinct(data.n(), k);
    from_rows(data, &idx)
}

/// k-means++ D² seeding. O(n·k) distance computations; requires one full
/// pass per centroid, which is exactly why the paper notes it is
/// impractical for mini-batch settings — we provide it for the `lloyd`
/// baseline and the examples.
pub fn kmeanspp(data: &Data, k: usize, rng: &mut Pcg64) -> Centroids {
    assert!(k <= data.n());
    let n = data.n();
    let d = data.dim();
    let mut chosen = Vec::with_capacity(k);
    chosen.push(rng.below(n));
    let mut row = vec![0f32; d];
    data.write_row_dense(chosen[0], &mut row);
    let mut cnorm = crate::linalg::dense::sq_norm(&row);
    // d2[i] = distance to nearest chosen centroid so far
    let mut d2: Vec<f64> = (0..n)
        .map(|i| data.sq_dist_to(i, &row, cnorm) as f64)
        .collect();
    while chosen.len() < k {
        let total: f64 = d2.iter().sum();
        let next = if total <= 0.0 {
            // all points coincide with chosen centroids: fall back
            rng.below(n)
        } else {
            let mut t = rng.next_f64() * total;
            let mut pick = n - 1;
            for (i, &w) in d2.iter().enumerate() {
                t -= w;
                if t <= 0.0 {
                    pick = i;
                    break;
                }
            }
            pick
        };
        chosen.push(next);
        data.write_row_dense(next, &mut row);
        cnorm = crate::linalg::dense::sq_norm(&row);
        for i in 0..n {
            let nd = data.sq_dist_to(i, &row, cnorm) as f64;
            if nd < d2[i] {
                d2[i] = nd;
            }
        }
    }
    from_rows(data, &chosen)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian::GaussianMixture;
    use crate::kmeans::state::exact_mse;

    #[test]
    fn first_k_copies_rows() {
        let data = GaussianMixture::default_spec(2, 5).generate(10, 3);
        let c = first_k(&data, 3);
        let mut row = vec![0.0; 5];
        for j in 0..3 {
            data.write_row_dense(j, &mut row);
            assert_eq!(c.c.row(j), &row[..]);
        }
        assert_eq!(c.k(), 3);
    }

    #[test]
    fn uniform_rows_come_from_data() {
        let data = GaussianMixture::default_spec(2, 4).generate(30, 1);
        let mut rng = Pcg64::new(5, 0);
        let c = uniform(&data, 5, &mut rng);
        let mut row = vec![0.0; 4];
        for j in 0..5 {
            let found = (0..30).any(|i| {
                data.write_row_dense(i, &mut row);
                row == c.c.row(j)
            });
            assert!(found, "centroid {j} not a datapoint");
        }
    }

    #[test]
    fn kmeanspp_beats_uniform_on_average() {
        // classic sanity: D² seeding should give a no-worse initial MSE
        // on a well-separated mixture (averaged over seeds).
        let spec = GaussianMixture { k: 8, d: 6, center_spread: 30.0, noise: 0.5, weights: vec![] };
        let data = spec.generate(400, 11);
        let mut mse_pp = 0.0;
        let mut mse_u = 0.0;
        for seed in 0..5 {
            let mut rng = Pcg64::new(seed, 1);
            mse_pp += exact_mse(&data, &kmeanspp(&data, 8, &mut rng));
            let mut rng = Pcg64::new(seed, 2);
            mse_u += exact_mse(&data, &uniform(&data, 8, &mut rng));
        }
        assert!(
            mse_pp < mse_u * 1.05,
            "kmeans++ {mse_pp} vs uniform {mse_u}"
        );
    }

    #[test]
    fn kmeanspp_handles_duplicate_points() {
        // all points identical: D² mass is zero after the first pick
        let m = crate::linalg::dense::DenseMatrix::from_vec(
            6,
            2,
            vec![1.0, 2.0].repeat(6),
        );
        let data = Data::dense(m);
        let mut rng = Pcg64::new(0, 0);
        let c = kmeanspp(&data, 3, &mut rng);
        assert_eq!(c.k(), 3);
        for j in 0..3 {
            assert_eq!(c.c.row(j), &[1.0, 2.0]);
        }
    }

    #[test]
    #[should_panic]
    fn k_larger_than_n_panics() {
        let data = GaussianMixture::default_spec(2, 2).generate(3, 0);
        first_k(&data, 10);
    }
}
