//! CSR sparse matrices and the sparse↔dense distance kernels.
//!
//! RCV1-like data is ~76 non-zeros in 47k dimensions, while centroids
//! densify as points accumulate (the paper's φ ≫ 1 regime, Supp. A.2).
//! We therefore keep centroids dense and compute
//! `‖x−c‖² = ‖x‖² + ‖c‖² − 2 Σ_t v_t·c[idx_t]` with a gather loop over
//! the point's non-zeros only — O(nnz) per centroid, not O(d).

use crate::linalg::dense::DenseMatrix;
use crate::linalg::simd;

/// Compressed sparse row matrix, f32 values, u32 column indices.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl CsrMatrix {
    pub fn empty(cols: usize) -> Self {
        Self { rows: 0, cols, indptr: vec![0], indices: vec![], values: vec![] }
    }

    /// Append a row given (sorted or unsorted) column/value pairs.
    pub fn push_row(&mut self, cols_vals: &[(u32, f32)]) {
        for &(c, v) in cols_vals {
            assert!((c as usize) < self.cols, "column {c} out of range");
            self.indices.push(c);
            self.values.push(v);
        }
        self.rows += 1;
        self.indptr.push(self.indices.len());
    }

    /// Append a row given parallel column/value slices (the layout
    /// [`Self::row`] hands back), avoiding a pair-building pass when
    /// copying rows between matrices.
    pub fn push_row_parts(&mut self, idx: &[u32], vals: &[f32]) {
        assert_eq!(idx.len(), vals.len());
        for &c in idx {
            assert!((c as usize) < self.cols, "column {c} out of range");
        }
        self.indices.extend_from_slice(idx);
        self.values.extend_from_slice(vals);
        self.rows += 1;
        self.indptr.push(self.indices.len());
    }

    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        debug_assert!(i < self.rows);
        let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    #[inline]
    pub fn nnz_row(&self, i: usize) -> usize {
        self.indptr[i + 1] - self.indptr[i]
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// ‖row_i‖² for every row.
    pub fn row_sq_norms(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|i| {
                let (_, vals) = self.row(i);
                vals.iter().map(|v| v * v).sum()
            })
            .collect()
    }

    /// Materialise a row permutation.
    pub fn permute_rows(&self, perm: &[usize]) -> CsrMatrix {
        assert_eq!(perm.len(), self.rows);
        let mut out = CsrMatrix::empty(self.cols);
        out.indices.reserve(self.nnz());
        out.values.reserve(self.nnz());
        for &p in perm {
            let (idx, vals) = self.row(p);
            out.indices.extend_from_slice(idx);
            out.values.extend_from_slice(vals);
            out.rows += 1;
            out.indptr.push(out.indices.len());
        }
        out
    }

    /// Rows `[lo, hi)` as a new matrix.
    pub fn slice_rows(&self, lo: usize, hi: usize) -> CsrMatrix {
        assert!(lo <= hi && hi <= self.rows);
        let (plo, phi) = (self.indptr[lo], self.indptr[hi]);
        CsrMatrix {
            rows: hi - lo,
            cols: self.cols,
            indptr: self.indptr[lo..=hi].iter().map(|&p| p - plo).collect(),
            indices: self.indices[plo..phi].to_vec(),
            values: self.values[plo..phi].to_vec(),
        }
    }

    /// Dense copy (tests, small data only).
    pub fn to_dense(&self) -> DenseMatrix {
        let mut m = DenseMatrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (idx, vals) = self.row(i);
            let r = m.row_mut(i);
            for (j, v) in idx.iter().zip(vals) {
                r[*j as usize] += *v;
            }
        }
        m
    }

    /// Mean number of non-zeros per row (the paper's `s`).
    pub fn mean_nnz(&self) -> f64 {
        if self.rows == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.rows as f64
        }
    }
}

/// ⟨sparse row, dense vector⟩: the sparse hot loop.
#[inline]
pub fn spdot(idx: &[u32], vals: &[f32], dense: &[f32]) -> f32 {
    debug_assert_eq!(idx.len(), vals.len());
    let mut s = 0f32;
    for t in 0..idx.len() {
        // Safety: indices are validated < cols at construction.
        unsafe {
            s += vals.get_unchecked(t)
                * dense.get_unchecked(*idx.get_unchecked(t) as usize);
        }
    }
    s
}

/// Squared distance from a sparse point to a dense centroid via norms.
#[inline]
pub fn sq_dist_sparse(
    idx: &[u32],
    vals: &[f32],
    xn: f32,
    c: &[f32],
    cn: f32,
) -> f32 {
    (xn + cn - 2.0 * spdot(idx, vals, c)).max(0.0)
}

/// Nearest dense centroid of a sparse point; counts as k distance
/// evaluations of O(nnz) each.
#[inline]
pub fn nearest_sparse(
    idx: &[u32],
    vals: &[f32],
    xn: f32,
    c: &DenseMatrix,
    cnorms: &[f32],
) -> (u32, f32) {
    let mut best_j = 0u32;
    let mut best = f32::INFINITY;
    for j in 0..c.rows {
        let d2 = sq_dist_sparse(idx, vals, xn, c.row(j), cnorms[j]);
        if d2 < best {
            best = d2;
            best_j = j as u32;
        }
    }
    (best_j, best)
}

/// Points per block in the row-blocked sparse assignment: the
/// candidate-pruning phase and the full-AXPY phase are batched over
/// this many points so the d×k transpose strips the AXPY sweeps stream
/// stay cache-resident across consecutive points of a block (the
/// sparse analogue of the dense kernel's `POINT_BLOCK`).
pub const SPARSE_BLOCK: usize = 16;

/// A pruned scan falls back to per-candidate strided gathers (instead
/// of the full k-wide AXPY sweep) when at most `k / PRUNE_GATHER_DIV`
/// candidates survive the norm bound — below that the scalar gathers
/// beat the SIMD sweep.
const PRUNE_GATHER_DIV: usize = 8;

/// Column/row tile edge for the transpose (re)build scatter.
const BUILD_TILE: usize = 64;

/// Conservative floating-point slack for the norm-based pruning bound,
/// as a relative factor on `xn + cn + 2·ub_dot`.
///
/// The pruning bound is the additive form `xn + cn[j] − 2·ub_dot(j)`,
/// using the *same stored* `xn`/`cn[j]` the distance formula adds (so
/// their own summation error cancels out of the comparison — crucially,
/// the O(d)-term error in a 47k-dim centroid norm never enters) with
/// `ub_dot(j) = √xn·sqnorms[j]`, an upper bound on the dot via
/// Cauchy–Schwarz over *accurate* norms: `sqnorms[j]` is f64-summed at
/// transpose build (error ~2⁻²⁴), and `√xn ≥ ‖x‖(1 − γ/2)` since the
/// stored `xn` under-estimates ‖x‖² by at most the γ of an nnz-term
/// f32 sum. What remains is nnz-proportional: `spdot` deviates from
/// `⟨x,c⟩` by ≤ γ·‖x‖‖c‖ with `γ ≈ (nnz + 3)·2⁻²⁴`, plus the final
/// f32 roundings of the distance itself — together under
/// `(1.5γ + 4ε)·(xn + cn + 2·ub_dot)`. The slack `4e-7·(nnz + 16)` is
/// ≥ 4x that (the bound arithmetic itself runs in f64, adding nothing
/// material), so `lb_safe(j) ≤ fl(d²(j))` always — which is what makes
/// pruned and unpruned argmins bit-identical
/// (`pruned_nearest_matches_unpruned_bitwise` and
/// `prune_bound_never_exceeds_computed_distance` hammer this).
#[inline]
pub(crate) fn prune_slack(nnz: usize) -> f64 {
    4.0e-7 * (nnz as f64 + 16.0)
}

/// Transposed centroid block (d × k, row-major) for the batched sparse
/// assignment kernel: turning `k` gathers per non-zero into one
/// sequential k-length AXPY makes the inner loop vectorisable — and the
/// AXPY now runs through the runtime-dispatched SIMD tiers
/// ([`crate::linalg::simd::axpy_with`]). Lane `j` of the accumulator
/// performs exactly the rounded-add sequence `spdot` performs against
/// `C(j)`, so the transposed, gather, and pruned paths all produce
/// bit-identical dots on every non-FMA tier.
#[derive(Clone, Debug)]
/// Tallies from one [`TransposedCentroids::nearest_block`] call: how
/// the norm-prune split the block between cheap per-candidate gathers
/// and full AXPY sweeps, and how many exact centroid evaluations the
/// bound skipped. Plain integers — callers accumulate across blocks
/// and flush to atomic counters once per work chunk.
#[derive(Clone, Copy, Default, Debug)]
pub struct BlockStats {
    /// Points settled in phase 1 via per-candidate gathers.
    pub points_gathered: u64,
    /// Points that fell back to the full AXPY sweep (phase 2).
    pub points_swept: u64,
    /// Exact centroid distance evaluations performed.
    pub centroids_evaluated: u64,
    /// Centroid evaluations the norm bound skipped (gathered points).
    pub centroids_skipped: u64,
}

impl BlockStats {
    /// Fold another block's tallies into this one.
    pub fn merge(&mut self, o: BlockStats) {
        self.points_gathered += o.points_gathered;
        self.points_swept += o.points_swept;
        self.centroids_evaluated += o.centroids_evaluated;
        self.centroids_skipped += o.centroids_skipped;
    }
}

pub struct TransposedCentroids {
    pub d: usize,
    pub k: usize,
    /// ct[col * k + j] = C(j)[col]
    pub ct: Vec<f32>,
    /// Accurate L2 norms `‖C(j)‖` (f64-accumulated at build, one f32
    /// rounding) — the pruning pass's Cauchy–Schwarz upper bounds.
    /// Deliberately *not* the engine's incrementally-maintained
    /// `cnorms`: those carry summation error that grows with d, which
    /// would silently void the prune-safety margin at RCV1 dimensions.
    pub sqnorms: Vec<f32>,
}

impl TransposedCentroids {
    /// Heap footprint of a (k × d) transpose before building it — the
    /// engine's cache gate bounds per-session memory with this.
    pub fn bytes_for(k: usize, d: usize) -> usize {
        k * d * std::mem::size_of::<f32>()
    }

    /// Heap footprint of this transpose.
    pub fn bytes(&self) -> usize {
        Self::bytes_for(self.k, self.d)
    }

    pub fn build(c: &DenseMatrix) -> Self {
        let mut tc = Self { d: 0, k: 0, ct: Vec::new(), sqnorms: Vec::new() };
        tc.rebuild(c);
        tc
    }

    /// Re-fill this transpose from a (possibly different-shape)
    /// centroid matrix, reusing the existing allocation when the
    /// footprint allows — the engine's revision cache rebuilds in place
    /// instead of reallocating O(k·d) every centroid revision.
    ///
    /// The scatter is tile-blocked: within a `BUILD_TILE`² tile the
    /// writes walk `j` innermost (contiguous in `ct`) while the reads
    /// walk a bounded window of `c`, instead of the previous full-`d`
    /// strided sweep per centroid that touched every destination
    /// cacheline `k` times from cold.
    pub fn rebuild(&mut self, c: &DenseMatrix) {
        let (k, d) = (c.rows, c.cols);
        self.k = k;
        self.d = d;
        if self.ct.len() != d * k {
            self.ct.resize(d * k, 0.0);
        }
        let cd = &c.data;
        let mut c0 = 0;
        while c0 < d {
            let c1 = (c0 + BUILD_TILE).min(d);
            let mut j0 = 0;
            while j0 < k {
                let j1 = (j0 + BUILD_TILE).min(k);
                for col in c0..c1 {
                    let dst = &mut self.ct[col * k..col * k + k];
                    for j in j0..j1 {
                        dst[j] = cd[j * d + col];
                    }
                }
                j0 = j1;
            }
            c0 = c1;
        }
        self.sqnorms.clear();
        self.sqnorms.reserve(k);
        for j in 0..k {
            let row = &cd[j * d..(j + 1) * d];
            let sq: f64 = row.iter().map(|&x| x as f64 * x as f64).sum();
            self.sqnorms.push(sq.sqrt() as f32);
        }
    }

    /// All-centroid dot products of one sparse row through the active
    /// SIMD tier: `dots[j] = Σ_t vals[t]·C(j)[idx[t]]`.
    #[inline]
    pub fn dots(&self, idx: &[u32], vals: &[f32], dots: &mut [f32]) {
        self.dots_with(simd::tier(), idx, vals, dots)
    }

    /// [`TransposedCentroids::dots`] through an explicit tier: paired
    /// k-strided AXPYs (two non-zeros per accumulator pass), single
    /// AXPY for an odd tail. Lane `j` accumulates in non-zero order, so
    /// `dots[j]` is bit-identical to `spdot(idx, vals, C(j))` on every
    /// non-FMA tier (property-tested).
    #[inline]
    pub fn dots_with(
        &self,
        t: simd::Tier,
        idx: &[u32],
        vals: &[f32],
        dots: &mut [f32],
    ) {
        debug_assert_eq!(idx.len(), vals.len());
        assert_eq!(dots.len(), self.k);
        dots.fill(0.0);
        let k = self.k;
        let nnz = idx.len();
        let mut p = 0;
        while p + 2 <= nnz {
            let b0 = idx[p] as usize * k;
            let b1 = idx[p + 1] as usize * k;
            // Safety: idx validated < cols = d at construction.
            let (r0, r1) = unsafe {
                (
                    self.ct.get_unchecked(b0..b0 + k),
                    self.ct.get_unchecked(b1..b1 + k),
                )
            };
            simd::axpy2_with(t, vals[p], r0, vals[p + 1], r1, dots);
            p += 2;
        }
        if p < nnz {
            let b = idx[p] as usize * k;
            let row = unsafe { self.ct.get_unchecked(b..b + k) };
            simd::axpy_with(t, vals[p], row, dots);
        }
    }

    /// `Σ_t vals[t]·C(j)[idx[t]]` for a single centroid, read out of
    /// the transpose (stride-k gather). Same accumulation order over
    /// the same stored values as [`spdot`] against row `j`, hence
    /// bit-identical to it — the pruned scan relies on this.
    #[inline]
    pub fn dot_one(&self, idx: &[u32], vals: &[f32], j: usize) -> f32 {
        debug_assert!(j < self.k);
        let k = self.k;
        let mut s = 0f32;
        for t in 0..idx.len() {
            // Safety: idx validated < d at construction, j < k.
            unsafe {
                s += vals.get_unchecked(t)
                    * self
                        .ct
                        .get_unchecked(*idx.get_unchecked(t) as usize * k + j);
            }
        }
        s
    }

    /// Fill `lbs[j]` with the fp-safe norm lower bound on the computed
    /// `d²(j)` — `xn + cnorms[j] − 2·ub_dot(j)` minus the
    /// [`prune_slack`] margin, evaluated in f64 against the accurate
    /// `sqnorms` — then seed the running best by evaluating the
    /// centroid with the smallest bound exactly. Returns
    /// `(seed_j, seed_d2, survivors)` where `survivors` counts
    /// centroids whose bound does not already rule them out against the
    /// seed.
    pub(crate) fn prune_seed(
        &self,
        idx: &[u32],
        vals: &[f32],
        xn: f32,
        cnorms: &[f32],
        lbs: &mut [f32],
    ) -> (usize, f32, usize) {
        let k = self.k;
        let xnf = xn as f64;
        let sqxn = xnf.sqrt();
        let slack = prune_slack(idx.len());
        let mut j0 = 0usize;
        for j in 0..k {
            let ub = sqxn * self.sqnorms[j] as f64;
            let scale = xnf + cnorms[j] as f64 + 2.0 * ub;
            lbs[j] = (xnf + cnorms[j] as f64 - 2.0 * ub - slack * scale) as f32;
            if lbs[j] < lbs[j0] {
                j0 = j;
            }
        }
        let d0 = (xn + cnorms[j0] - 2.0 * self.dot_one(idx, vals, j0)).max(0.0);
        let survivors = lbs.iter().filter(|&&lb| lb <= d0).count();
        (j0, d0, survivors)
    }

    /// Finish a pruned scan via per-candidate strided gathers: visit
    /// centroids in index order, skipping every `j` whose bound
    /// provably exceeds the running best. First-wins ties are restored
    /// with the explicit `j < best_j` rule (the seed was evaluated out
    /// of order), so the result is bit-identical to the unpruned scan.
    /// The third return is the number of exact distance evaluations
    /// performed (seed included) — the prune's observable work saved.
    #[allow(clippy::too_many_arguments)]
    fn finish_gather(
        &self,
        idx: &[u32],
        vals: &[f32],
        xn: f32,
        cnorms: &[f32],
        lbs: &[f32],
        seed_j: usize,
        seed_d2: f32,
    ) -> (u32, f32, usize) {
        let mut best = seed_d2;
        let mut best_j = seed_j as u32;
        let mut evals = 1usize;
        for j in 0..self.k {
            if j == seed_j || lbs[j] > best {
                continue;
            }
            let d2 = (xn + cnorms[j] - 2.0 * self.dot_one(idx, vals, j)).max(0.0);
            evals += 1;
            if d2 < best || (d2 == best && (j as u32) < best_j) {
                best = d2;
                best_j = j as u32;
            }
        }
        (best_j, best, evals)
    }

    /// Nearest centroid of a sparse point through the transposed block:
    /// one SIMD AXPY sweep for all k dots, then a first-wins argmin.
    #[inline]
    pub fn nearest(
        &self,
        idx: &[u32],
        vals: &[f32],
        xn: f32,
        cnorms: &[f32],
        scratch: &mut [f32],
    ) -> (u32, f32) {
        self.dots(idx, vals, scratch);
        let mut best = f32::INFINITY;
        let mut best_j = 0u32;
        for j in 0..self.k {
            let d2 = (xn + cnorms[j] - 2.0 * scratch[j]).max(0.0);
            if d2 < best {
                best = d2;
                best_j = j as u32;
            }
        }
        (best_j, best)
    }

    /// [`TransposedCentroids::nearest`] with norm-based candidate
    /// pruning: when few centroids survive the
    /// `xn + cn[j] − 2·ub_dot(j)` bound, only those are evaluated
    /// (per-candidate gathers); otherwise one full AXPY sweep runs as
    /// usual. `lbs` and `scratch` are k-length scratch. Argmin and
    /// distance are bit-identical to the unpruned scan.
    #[inline]
    pub fn nearest_pruned(
        &self,
        idx: &[u32],
        vals: &[f32],
        xn: f32,
        cnorms: &[f32],
        lbs: &mut [f32],
        scratch: &mut [f32],
    ) -> (u32, f32) {
        let k = self.k;
        if k == 0 {
            return (0, f32::INFINITY);
        }
        let (seed_j, seed_d2, survivors) =
            self.prune_seed(idx, vals, xn, cnorms, lbs);
        if survivors * PRUNE_GATHER_DIV <= k {
            let (j, d2, _evals) =
                self.finish_gather(idx, vals, xn, cnorms, lbs, seed_j, seed_d2);
            (j, d2)
        } else {
            self.nearest(idx, vals, xn, cnorms, scratch)
        }
    }

    /// Row-blocked pruned assignment over ≤ [`SPARSE_BLOCK`] sparse
    /// rows: phase 1 runs the norm-bound pruning per point and settles
    /// every point with a small candidate set via gathers; phase 2 runs
    /// the full AXPY sweeps for the rest back-to-back, so the transpose
    /// strips shared between neighbouring points stay cache-resident
    /// instead of being evicted by interleaved pruning work. Results
    /// are bit-identical to per-point [`TransposedCentroids::nearest`].
    /// Returns per-block [`BlockStats`] so callers can tally prune
    /// effectiveness without any atomics on the inner loops.
    #[allow(clippy::too_many_arguments)]
    pub fn nearest_block(
        &self,
        rows: &[(&[u32], &[f32])],
        xns: &[f32],
        cnorms: &[f32],
        lbs: &mut [f32],
        scratch: &mut [f32],
        out_lbl: &mut [u32],
        out_d2: &mut [f32],
    ) -> BlockStats {
        let p = rows.len();
        debug_assert!(p <= SPARSE_BLOCK);
        assert_eq!(xns.len(), p, "nearest_block: norms length mismatch");
        assert_eq!(out_lbl.len(), p, "nearest_block: label buffer mismatch");
        assert_eq!(out_d2.len(), p, "nearest_block: d2 buffer mismatch");
        let mut stats = BlockStats::default();
        let k = self.k;
        if k == 0 {
            out_lbl.fill(0);
            out_d2.fill(f32::INFINITY);
            return stats;
        }
        let tier = simd::tier();
        let mut defer = [false; SPARSE_BLOCK];
        for ti in 0..p {
            let (idx, vals) = rows[ti];
            let (seed_j, seed_d2, survivors) =
                self.prune_seed(idx, vals, xns[ti], cnorms, lbs);
            if survivors * PRUNE_GATHER_DIV <= k {
                let (j, d2, evals) = self.finish_gather(
                    idx, vals, xns[ti], cnorms, lbs, seed_j, seed_d2,
                );
                out_lbl[ti] = j;
                out_d2[ti] = d2;
                stats.points_gathered += 1;
                stats.centroids_evaluated += evals as u64;
                stats.centroids_skipped += (k - evals) as u64;
            } else {
                defer[ti] = true;
            }
        }
        for ti in 0..p {
            if !defer[ti] {
                continue;
            }
            let (idx, vals) = rows[ti];
            self.dots_with(tier, idx, vals, scratch);
            let mut best = f32::INFINITY;
            let mut best_j = 0u32;
            for j in 0..k {
                let d2 = (xns[ti] + cnorms[j] - 2.0 * scratch[j]).max(0.0);
                if d2 < best {
                    best = d2;
                    best_j = j as u32;
                }
            }
            out_lbl[ti] = best_j;
            out_d2[ti] = best;
            stats.points_swept += 1;
            stats.centroids_evaluated += k as u64;
        }
        stats
    }

    /// [`TransposedCentroids::nearest_block`] without the pruning pass:
    /// every point goes straight to the full AXPY sweep. This is the
    /// adaptive engine's **flat** strategy — on corpora whose centroid
    /// norms are (near-)equal the norm bound can never rule anything
    /// out, so the O(k) bound arithmetic per point is pure overhead
    /// (the measured ~20% regression on unit-normalised rows). Results
    /// are bit-identical to the pruned and per-point paths: the sweep
    /// body is the same first-wins scan over the same AXPY dots.
    pub fn nearest_block_flat(
        &self,
        rows: &[(&[u32], &[f32])],
        xns: &[f32],
        cnorms: &[f32],
        scratch: &mut [f32],
        out_lbl: &mut [u32],
        out_d2: &mut [f32],
    ) -> BlockStats {
        let p = rows.len();
        debug_assert!(p <= SPARSE_BLOCK);
        assert_eq!(xns.len(), p, "nearest_block_flat: norms length mismatch");
        assert_eq!(out_lbl.len(), p, "nearest_block_flat: label buffer mismatch");
        assert_eq!(out_d2.len(), p, "nearest_block_flat: d2 buffer mismatch");
        let mut stats = BlockStats::default();
        let k = self.k;
        if k == 0 {
            out_lbl.fill(0);
            out_d2.fill(f32::INFINITY);
            return stats;
        }
        let tier = simd::tier();
        for ti in 0..p {
            let (idx, vals) = rows[ti];
            self.dots_with(tier, idx, vals, scratch);
            let mut best = f32::INFINITY;
            let mut best_j = 0u32;
            for j in 0..k {
                let d2 = (xns[ti] + cnorms[j] - 2.0 * scratch[j]).max(0.0);
                if d2 < best {
                    best = d2;
                    best_j = j as u32;
                }
            }
            out_lbl[ti] = best_j;
            out_d2[ti] = best;
            stats.points_swept += 1;
            stats.centroids_evaluated += k as u64;
        }
        stats
    }

    /// Full squared-distance row of a sparse point.
    #[inline]
    pub fn dist_row(
        &self,
        idx: &[u32],
        vals: &[f32],
        xn: f32,
        cnorms: &[f32],
        out: &mut [f32],
    ) {
        self.dots(idx, vals, out);
        for j in 0..self.k {
            out[j] = (xn + cnorms[j] - 2.0 * out[j]).max(0.0);
        }
    }
}

/// Scatter-add a sparse row into an f64 accumulator row.
#[inline]
pub fn scatter_add(acc: &mut [f64], idx: &[u32], vals: &[f32]) {
    for t in 0..idx.len() {
        acc[idx[t] as usize] += vals[t] as f64;
    }
}

/// Scatter-subtract a sparse row from an f64 accumulator row.
#[inline]
pub fn scatter_sub(acc: &mut [f64], idx: &[u32], vals: &[f32]) {
    for t in 0..idx.len() {
        acc[idx[t] as usize] -= vals[t] as f64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense;
    use crate::util::propcheck::Cases;
    use crate::util::rng::Pcg64;

    fn random_csr(rng: &mut Pcg64, rows: usize, cols: usize, nnz_per: usize) -> CsrMatrix {
        let mut m = CsrMatrix::empty(cols);
        for _ in 0..rows {
            let nnz = rng.below(nnz_per + 1);
            let cols_idx = rng.sample_distinct(cols, nnz.min(cols));
            let row: Vec<(u32, f32)> = cols_idx
                .iter()
                .map(|&c| (c as u32, rng.gauss_f32()))
                .collect();
            m.push_row(&row);
        }
        m
    }

    #[test]
    fn spdot_matches_dense_dot() {
        Cases::new(60).run(|rng| {
            let cols = rng.below(100) + 1;
            let m = random_csr(rng, 1, cols, 20);
            let dvec: Vec<f32> = (0..cols).map(|_| rng.gauss_f32()).collect();
            let (idx, vals) = m.row(0);
            let got = spdot(idx, vals, &dvec);
            let dense_row = m.to_dense();
            let naive = dense::dot(dense_row.row(0), &dvec);
            assert!((got - naive).abs() < 1e-3 * (1.0 + naive.abs()));
        });
    }

    #[test]
    fn sq_dist_sparse_matches_dense() {
        Cases::new(60).run(|rng| {
            let cols = rng.below(80) + 1;
            let m = random_csr(rng, 4, cols, 10);
            let c: Vec<f32> = (0..cols).map(|_| rng.gauss_f32()).collect();
            let cn = dense::sq_norm(&c);
            let dm = m.to_dense();
            let xns = m.row_sq_norms();
            for i in 0..m.rows {
                let (idx, vals) = m.row(i);
                let got = sq_dist_sparse(idx, vals, xns[i], &c, cn);
                let exact = dense::sq_dist(dm.row(i), &c);
                assert!(
                    (got - exact).abs() < 1e-2 * (1.0 + exact.abs()),
                    "i={i} got={got} exact={exact}"
                );
            }
        });
    }

    #[test]
    fn nearest_sparse_matches_dense_nearest() {
        Cases::new(40).run(|rng| {
            let cols = rng.below(60) + 2;
            let k = rng.below(8) + 1;
            let m = random_csr(rng, 3, cols, 12);
            let cmat = DenseMatrix::from_vec(
                k,
                cols,
                (0..k * cols).map(|_| rng.gauss_f32()).collect(),
            );
            let cn = cmat.row_sq_norms();
            let dm = m.to_dense();
            let xns = m.row_sq_norms();
            for i in 0..m.rows {
                let (idx, vals) = m.row(i);
                let (_, d2s) = nearest_sparse(idx, vals, xns[i], &cmat, &cn);
                let (_, d2d) =
                    dense::nearest(dm.row(i), dense::sq_norm(dm.row(i)), &cmat, &cn);
                assert!((d2s - d2d).abs() < 1e-2 * (1.0 + d2d.abs()));
            }
        });
    }

    #[test]
    fn transposed_matches_gather_path() {
        Cases::new(40).run(|rng| {
            let cols = rng.below(200) + 2;
            let k = rng.below(30) + 1;
            let m = random_csr(rng, 6, cols, 15);
            let cmat = DenseMatrix::from_vec(
                k,
                cols,
                (0..k * cols).map(|_| rng.gauss_f32()).collect(),
            );
            let cn = cmat.row_sq_norms();
            let tc = TransposedCentroids::build(&cmat);
            let xns = m.row_sq_norms();
            let mut scratch = vec![0f32; k];
            let mut row_out = vec![0f32; k];
            for i in 0..m.rows {
                let (idx, vals) = m.row(i);
                let (jt, dt) =
                    tc.nearest(idx, vals, xns[i], &cn, &mut scratch);
                let (jg, dg) = nearest_sparse(idx, vals, xns[i], &cmat, &cn);
                assert!(
                    (dt - dg).abs() <= 1e-3 * (1.0 + dg.abs()),
                    "i={i}: trans {dt} vs gather {dg}"
                );
                // indices may differ only on numerical ties
                if jt != jg {
                    let a = sq_dist_sparse(idx, vals, xns[i], cmat.row(jt as usize), cn[jt as usize]);
                    assert!((a - dg).abs() <= 1e-3 * (1.0 + dg.abs()));
                }
                tc.dist_row(idx, vals, xns[i], &cn, &mut row_out);
                for j in 0..k {
                    let e = sq_dist_sparse(idx, vals, xns[i], cmat.row(j), cn[j]);
                    assert!(
                        (row_out[j] - e).abs() <= 1e-3 * (1.0 + e.abs()),
                        "row {j}: {} vs {e}",
                        row_out[j]
                    );
                }
            }
        });
    }

    fn exact_tiers() -> Vec<simd::Tier> {
        simd::available_tiers()
            .into_iter()
            .filter(|&t| t != simd::Tier::Avx2Fma)
            .collect()
    }

    #[test]
    fn dots_bit_identical_across_tiers_and_to_spdot() {
        // the tentpole invariant: every sparse SIMD tier reproduces the
        // scalar AXPY reference bit-for-bit, and lane j of the sweep is
        // bitwise spdot against centroid row j. Shapes cover empty
        // rows, single non-zeros (the odd axpy tail), and k % 8 != 0.
        Cases::new(120).run(|rng| {
            let cols = rng.below(150) + 2;
            let k = rng.below(37) + 1;
            let m = random_csr(rng, 5, cols, 24);
            let cmat = DenseMatrix::from_vec(
                k,
                cols,
                (0..k * cols).map(|_| rng.gauss_f32()).collect(),
            );
            let tc = TransposedCentroids::build(&cmat);
            let mut reference = vec![0f32; k];
            let mut got = vec![0f32; k];
            let bits =
                |x: &[f32]| x.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            for i in 0..m.rows {
                let (idx, vals) = m.row(i);
                tc.dots_with(simd::Tier::Scalar, idx, vals, &mut reference);
                for t in exact_tiers() {
                    tc.dots_with(t, idx, vals, &mut got);
                    assert_eq!(
                        bits(&got),
                        bits(&reference),
                        "row {i} tier {} (k={k}, nnz={})",
                        t.name(),
                        idx.len()
                    );
                }
                // lane-order invariant vs the gather path
                for j in 0..k {
                    let g = spdot(idx, vals, cmat.row(j));
                    assert_eq!(
                        reference[j].to_bits(),
                        g.to_bits(),
                        "row {i} lane {j}: axpy {} vs spdot {g}",
                        reference[j]
                    );
                    assert_eq!(tc.dot_one(idx, vals, j).to_bits(), g.to_bits());
                }
            }
        });
    }

    #[test]
    fn pruned_nearest_matches_unpruned_bitwise() {
        // pruning must never change the answer: argmin AND distance
        // bit-identical to the full scan, ties included (duplicated
        // centroid rows force exact ties)
        if simd::tier() == simd::Tier::Avx2Fma {
            return; // pruned gathers are unfused; skip under opt-in FMA
        }
        Cases::new(120).run(|rng| {
            let cols = rng.below(120) + 2;
            let k = rng.below(30) + 1;
            let m = random_csr(rng, 6, cols, 18);
            let mut cdata: Vec<f32> =
                (0..k * cols).map(|_| rng.gauss_f32()).collect();
            // duplicate a centroid row to create exact d² ties
            if k >= 2 {
                let (src, dst) = (0usize, k - 1);
                for c in 0..cols {
                    cdata[dst * cols + c] = cdata[src * cols + c];
                }
            }
            let cmat = DenseMatrix::from_vec(k, cols, cdata);
            let cn = cmat.row_sq_norms();
            let tc = TransposedCentroids::build(&cmat);
            let xns = m.row_sq_norms();
            let mut scratch = vec![0f32; k];
            let mut lbs = vec![0f32; k];
            for i in 0..m.rows {
                let (idx, vals) = m.row(i);
                let (ju, du) = tc.nearest(idx, vals, xns[i], &cn, &mut scratch);
                let (jp, dp) = tc.nearest_pruned(
                    idx, vals, xns[i], &cn, &mut lbs, &mut scratch,
                );
                assert_eq!(jp, ju, "row {i}: pruned argmin diverged");
                assert_eq!(
                    dp.to_bits(),
                    du.to_bits(),
                    "row {i}: pruned distance diverged ({dp} vs {du})"
                );
            }
        });
    }

    #[test]
    fn nearest_block_bit_identical_to_per_point() {
        if simd::tier() == simd::Tier::Avx2Fma {
            return; // pruned gathers are unfused; skip under opt-in FMA
        }
        Cases::new(60).run(|rng| {
            let cols = rng.below(100) + 2;
            let k = rng.below(25) + 1;
            let n = rng.below(2 * SPARSE_BLOCK) + 1;
            let m = random_csr(rng, n, cols, 14);
            let cmat = DenseMatrix::from_vec(
                k,
                cols,
                (0..k * cols).map(|_| rng.gauss_f32()).collect(),
            );
            let cn = cmat.row_sq_norms();
            let tc = TransposedCentroids::build(&cmat);
            let xns = m.row_sq_norms();
            let mut scratch = vec![0f32; k];
            let mut lbs = vec![0f32; k];
            let mut lo = 0;
            while lo < n {
                let hi = (lo + SPARSE_BLOCK).min(n);
                let rows: Vec<(&[u32], &[f32])> =
                    (lo..hi).map(|i| m.row(i)).collect();
                let mut lbl = vec![0u32; hi - lo];
                let mut d2 = vec![0f32; hi - lo];
                tc.nearest_block(
                    &rows,
                    &xns[lo..hi],
                    &cn,
                    &mut lbs,
                    &mut scratch,
                    &mut lbl,
                    &mut d2,
                );
                for (o, i) in (lo..hi).enumerate() {
                    let (idx, vals) = m.row(i);
                    let (j, e) =
                        tc.nearest(idx, vals, xns[i], &cn, &mut scratch);
                    assert_eq!(lbl[o], j, "point {i}");
                    assert_eq!(d2[o].to_bits(), e.to_bits(), "point {i}");
                }
                lo = hi;
            }
        });
    }

    #[test]
    fn prune_bound_never_exceeds_computed_distance() {
        // the fp-safety property the pruning correctness proof rests
        // on: lb_safe(j) ≤ fl(d²(j)) for every point/centroid pair.
        // Exercised both with exact stored norms and with deliberately
        // perturbed ones — the additive bound form uses the same stored
        // cn the distance adds, so a (d-dependent) norm-summation error
        // cancels out of the comparison by construction.
        Cases::new(120).run(|rng| {
            let cols = rng.below(200) + 2;
            let k = rng.below(20) + 1;
            let m = random_csr(rng, 4, cols, 30);
            let cmat = DenseMatrix::from_vec(
                k,
                cols,
                (0..k * cols).map(|_| rng.gauss_f32()).collect(),
            );
            let tc = TransposedCentroids::build(&cmat);
            let exact_cn = cmat.row_sq_norms();
            // like the engine's incrementally-maintained norms at high
            // d, a stored cn can be off by far more than f32 epsilon
            let skew = 1.0 + 1e-3 * (rng.gauss_f32().clamp(-2.0, 2.0));
            let skewed_cn: Vec<f32> =
                exact_cn.iter().map(|x| x * skew).collect();
            let xns = m.row_sq_norms();
            for cn in [&exact_cn, &skewed_cn] {
                for i in 0..m.rows {
                    let (idx, vals) = m.row(i);
                    let xnf = xns[i] as f64;
                    let sqxn = xnf.sqrt();
                    let slack = prune_slack(idx.len());
                    for j in 0..k {
                        let ub = sqxn * tc.sqnorms[j] as f64;
                        let scale = xnf + cn[j] as f64 + 2.0 * ub;
                        let lb = (xnf + cn[j] as f64
                            - 2.0 * ub
                            - slack * scale) as f32;
                        let d2 = sq_dist_sparse(
                            idx, vals, xns[i], cmat.row(j), cn[j],
                        );
                        assert!(
                            lb <= d2,
                            "i={i} j={j}: bound {lb} above computed d² {d2}"
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn rebuild_reuses_allocation_and_matches_fresh_build() {
        let mut rng = Pcg64::new(11, 4);
        let c1 = DenseMatrix::from_vec(
            7,
            100,
            (0..700).map(|_| rng.gauss_f32()).collect(),
        );
        let mut tc = TransposedCentroids::build(&c1);
        assert_eq!((tc.k, tc.d), (7, 100));
        let ptr_before = tc.ct.as_ptr();
        // same shape: rebuild must reuse the allocation exactly
        let c2 = DenseMatrix::from_vec(
            7,
            100,
            (0..700).map(|_| rng.gauss_f32()).collect(),
        );
        tc.rebuild(&c2);
        assert_eq!(tc.ct.as_ptr(), ptr_before, "same-shape rebuild reallocated");
        let fresh = TransposedCentroids::build(&c2);
        assert_eq!(tc.ct, fresh.ct);
        assert_eq!(tc.sqnorms, fresh.sqnorms);
        // sqnorms are the f64-accurate row norms (pruning safety needs
        // them tighter than any f32-summed norm can be)
        for j in 0..7 {
            let exact: f64 = c2.row(j).iter().map(|&x| x as f64 * x as f64).sum();
            assert_eq!(fresh.sqnorms[j], exact.sqrt() as f32);
        }
        // shape change: contents must still match a fresh build,
        // including shapes straddling the tile edge
        for (k, d) in [(3usize, 130usize), (65, 64), (1, 1), (9, 257)] {
            let c = DenseMatrix::from_vec(
                k,
                d,
                (0..k * d).map(|_| rng.gauss_f32()).collect(),
            );
            tc.rebuild(&c);
            let fresh = TransposedCentroids::build(&c);
            assert_eq!((tc.k, tc.d), (k, d));
            assert_eq!(tc.ct, fresh.ct, "k={k} d={d}");
            for j in 0..k {
                for col in 0..d {
                    assert_eq!(tc.ct[col * k + j], c.row(j)[col]);
                }
            }
        }
    }

    #[test]
    fn scatter_roundtrip() {
        let mut acc = vec![0.0f64; 10];
        let idx = [1u32, 5, 9];
        let vals = [1.5f32, -2.0, 0.25];
        scatter_add(&mut acc, &idx, &vals);
        assert_eq!(acc[5], -2.0);
        scatter_sub(&mut acc, &idx, &vals);
        assert!(acc.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn permute_slice_dense_consistency() {
        let mut rng = Pcg64::new(3, 3);
        let m = random_csr(&mut rng, 6, 20, 5);
        let perm = [5usize, 3, 1, 0, 2, 4];
        let p = m.permute_rows(&perm);
        for (i, &src) in perm.iter().enumerate() {
            assert_eq!(p.row(i), m.row(src));
        }
        let s = p.slice_rows(2, 5);
        assert_eq!(s.rows, 3);
        assert_eq!(s.row(0), p.row(2));
    }

    #[test]
    fn mean_nnz_and_norms() {
        let mut m = CsrMatrix::empty(4);
        m.push_row(&[(0, 3.0), (2, 4.0)]);
        m.push_row(&[]);
        assert_eq!(m.mean_nnz(), 1.0);
        assert_eq!(m.row_sq_norms(), vec![25.0, 0.0]);
    }

    #[test]
    #[should_panic]
    fn push_row_validates_columns() {
        let mut m = CsrMatrix::empty(3);
        m.push_row(&[(3, 1.0)]);
    }
}
