//! Experiment harnesses: one module per paper table/figure
//! (DESIGN.md experiment index). Both the CLI (`nmbkm experiment …`)
//! and the `cargo bench` targets drive these, so the numbers in
//! EXPERIMENTS.md regenerate identically from either entry point.

pub mod ablations;
pub mod common;
pub mod fig1;
pub mod rho_sweep;
pub mod table1;
pub mod table2;
