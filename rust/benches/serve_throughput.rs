//! Serve-layer throughput.
//!
//! Five trials land in `BENCH_serve.json`:
//!
//! * `predict_during_training` — predict QPS at 1 vs 4 concurrent TCP
//!   connections **while the model trains**; the multi-connection
//!   server answers predicts from published snapshots without touching
//!   the session lock, so throughput should scale with connections
//!   instead of serialising behind training rounds.
//! * `predict_wire_variants` — a static RCV1-shaped sparse model
//!   queried over one connection through every wire route at batch
//!   sizes 1/16/64: dense JSONL (the PR 1 format), sparse-encoded
//!   JSONL (`{"indices":…,"values":…,"dim":d}`), and length-prefixed
//!   binary frames. Batching amortises per-request parse/dispatch, so
//!   batch 64 should clear ≥2x the batch-1 QPS; the derived speedups
//!   and per-query payload sizes at the full RCV1 shape land in `meta`.
//! * `ingest_wal` — the same ingest stream with the WAL off, on with
//!   `--fsync never`, and on with `--fsync always`; the overhead
//!   ratios land in `meta` (`wal_append_overhead`,
//!   `wal_fsync_always_overhead`) so the trend gate sees WAL cost.
//! * `ingest_out_of_core` — the same ingest+train stream against a
//!   resident session and one spilled to a disk shard with a 2-block
//!   pinned cache; the overhead ratio, ingest rates, and the
//!   bounded-memory evidence (peak pinned blocks vs the cache budget,
//!   dataset size vs resident budget, VmHWM) land in `meta`.
//! * `c10k_saturation` — thousands of idle connections held open
//!   (4096 at quick/full scale, fewer in smoke or under a tight
//!   RLIMIT_NOFILE) while 64 active peers drive predicts; the timed
//!   active phase is trend-gateable, and `meta` records the accept
//!   rate, active-predict p99, and resident-memory growth per idle
//!   connection — the event loop's C10K evidence.
//!
//! CI runs `--quick` (3 samples) so the medians are trend-gateable by
//! `nmbkm bench-trend`, exactly like `BENCH_micro.json`.
//!
//! Usage: cargo bench --bench serve_throughput -- [--quick|--smoke]
//!        [--json BENCH_serve.json]

use nmbkm::bench::{BenchOpts, BenchReport, BenchSet};
use nmbkm::config::{Algo, Rho, RunConfig};
use nmbkm::coordinator::Pool;
use nmbkm::data::gaussian::GaussianMixture;
use nmbkm::data::rcv1::Rcv1Sim;
use nmbkm::data::{Data, Storage};
use nmbkm::serve::server::{serve_listener_with, ServeOptions};
use nmbkm::serve::wal::{self, FsyncPolicy};
use nmbkm::serve::wire::{dense_points_json, sparse_points_json};
use nmbkm::serve::{event, frame, observe, session, ModelRegistry};
use nmbkm::util::json::{self, Json};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

struct Scale {
    n_points: usize,
    k: usize,
    dim: usize,
    predicts_per_conn: usize,
    query_batch: usize,
    /// `predict_wire_variants`: total queries per measurement and the
    /// sparse corpus shape.
    wire_queries: usize,
    wire_n_points: usize,
    wire_vocab: usize,
    wire_k: usize,
    /// `ingest_wal`: ingest requests per measurement × points each.
    ingest_batches: usize,
    ingest_batch: usize,
    /// `c10k_saturation`: connections held idle, peers driving load,
    /// and predicts completed per active peer per sample.
    idle_conns: usize,
    active_conns: usize,
    active_predicts: usize,
}

fn scale_for(opts: &BenchOpts) -> Scale {
    if opts.samples <= 1 {
        // CI smoke: prove the paths work, in milliseconds
        Scale {
            n_points: 2000,
            k: 10,
            dim: 16,
            predicts_per_conn: 30,
            query_batch: 8,
            wire_queries: 64,
            wire_n_points: 600,
            wire_vocab: 400,
            wire_k: 8,
            ingest_batches: 12,
            ingest_batch: 32,
            idle_conns: 128,
            active_conns: 8,
            active_predicts: 10,
        }
    } else if opts.samples <= BenchOpts::quick().samples {
        // CI quick: enough work for stable gateable medians, still
        // seconds not minutes
        Scale {
            n_points: 6000,
            k: 20,
            dim: 24,
            predicts_per_conn: 100,
            query_batch: 16,
            wire_queries: 512,
            wire_n_points: 3000,
            wire_vocab: 1000,
            wire_k: 16,
            ingest_batches: 40,
            ingest_batch: 64,
            idle_conns: 4096,
            active_conns: 64,
            active_predicts: 15,
        }
    } else {
        Scale {
            n_points: 20000,
            k: 50,
            dim: 32,
            predicts_per_conn: 300,
            query_batch: 16,
            wire_queries: 2048,
            wire_n_points: 8000,
            wire_vocab: 2000,
            wire_k: 32,
            ingest_batches: 120,
            ingest_batch: 128,
            idle_conns: 4096,
            active_conns: 64,
            active_predicts: 40,
        }
    }
}

fn cfg(k: usize) -> RunConfig {
    RunConfig {
        algo: Algo::TbRho,
        k,
        b0: 1024,
        rho: Rho::Infinite,
        threads: Pool::auto().threads.min(4),
        seed: 11,
        max_rounds: usize::MAX,
        max_seconds: f64::INFINITY,
        stop_on_convergence: false,
        ..Default::default()
    }
}

fn roundtrip(conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str) -> Json {
    conn.write_all(req.as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    Json::parse(line.trim()).unwrap()
}

fn connect(addr: std::net::SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let conn = TcpStream::connect(addr).unwrap();
    let reader = BufReader::new(conn.try_clone().unwrap());
    (conn, reader)
}

/// One trial: serve a training model over TCP; `conns` client threads
/// each complete `predicts_per_conn` predict requests while a driver
/// connection keeps issuing training steps. Returns when every client
/// finished (the timed region).
fn run_trial(data: &Data, scale: &Scale, conns: usize) {
    let queries: Vec<Vec<f32>> = {
        let mut out = Vec::with_capacity(scale.query_batch);
        let mut row = vec![0f32; data.dim()];
        for i in 0..scale.query_batch {
            data.write_row_dense(i * 7 % data.n(), &mut row);
            out.push(row.clone());
        }
        out
    };
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().unwrap();
    let served = session::OnlineSession::from_data(data.clone(), cfg(scale.k))
        .expect("session");
    let reg = Arc::new(ModelRegistry::with_default(served));
    let server = std::thread::spawn(move || {
        nmbkm::serve::server::serve_listener(reg, listener).unwrap();
    });

    // training pressure: keep stepping until the clients are done
    let stop = Arc::new(AtomicBool::new(false));
    let trainer_stop = stop.clone();
    let trainer = std::thread::spawn(move || {
        let (mut conn, mut reader) = connect(addr);
        while !trainer_stop.load(Ordering::SeqCst) {
            let resp = roundtrip(
                &mut conn,
                &mut reader,
                r#"{"op":"step","rounds":1}"#,
            );
            assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
        }
        (conn, reader)
    });

    let req = format!("{{\"op\":\"predict\",\"points\":{}}}", dense_points_json(&queries));
    let per_conn = scale.predicts_per_conn;
    let mut clients = Vec::new();
    for _ in 0..conns {
        let req = req.clone();
        clients.push(std::thread::spawn(move || {
            let (mut conn, mut reader) = connect(addr);
            for _ in 0..per_conn {
                let resp = roundtrip(&mut conn, &mut reader, &req);
                assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    stop.store(true, Ordering::SeqCst);
    let (mut conn, mut reader) = trainer.join().unwrap();
    roundtrip(&mut conn, &mut reader, r#"{"op":"shutdown"}"#);
    server.join().unwrap();
}

/// Rows `0..n` of a sparse corpus as `(indices, values)` pairs plus
/// their dense twins.
#[allow(clippy::type_complexity)]
fn query_rows(data: &Data, n: usize) -> (Vec<(Vec<u32>, Vec<f32>)>, Vec<Vec<f32>>) {
    let Storage::Sparse(m) = &data.storage else {
        panic!("wire-variant corpus must be sparse");
    };
    let mut sparse = Vec::with_capacity(n);
    let mut dense = Vec::with_capacity(n);
    let mut row = vec![0f32; data.dim()];
    for t in 0..n {
        let i = (t * 13) % data.n();
        let (idx, vals) = m.row(i);
        sparse.push((idx.to_vec(), vals.to_vec()));
        data.write_row_dense(i, &mut row);
        dense.push(row.clone());
    }
    (sparse, dense)
}

/// Fingerprint of a JSONL predict response: `(labels, d2 bit patterns)`
/// — f32 → f64 JSON → f32 is lossless, so these are the engine's bits.
fn fingerprint(resp: &Json) -> (Vec<u32>, Vec<u32>) {
    let labels = resp
        .get("labels")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as u32)
        .collect();
    let d2 = resp
        .get("d2")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| (x.as_f64().unwrap() as f32).to_bits())
        .collect();
    (labels, d2)
}

/// Complete the prebuilt JSONL predict requests over one connection.
fn drive_jsonl(addr: std::net::SocketAddr, requests: &[String]) {
    let (mut conn, mut reader) = connect(addr);
    let mut line = String::new();
    for req in requests {
        conn.write_all(req.as_bytes()).unwrap();
        conn.write_all(b"\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(
            !line.contains("\"ok\":false"),
            "predict failed: {line}"
        );
    }
}

/// Complete the prebuilt binary predict frames over one connection
/// (magic byte first — the same port serves JSONL clients).
fn drive_binary(addr: std::net::SocketAddr, frames: &[Vec<u8>]) {
    let mut conn = TcpStream::connect(addr).unwrap();
    conn.write_all(&[frame::MAGIC]).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    for f in frames {
        conn.write_all(f).unwrap();
        let (header, body) = frame::read_frame(&mut reader).unwrap().unwrap();
        assert_eq!(header.get("ok").and_then(Json::as_bool), Some(true));
        let (lbl, _) = frame::decode_predict_body(&body).unwrap();
        assert!(!lbl.is_empty());
    }
}

fn predict_frame(batch: &[(Vec<u32>, Vec<f32>)], dim: usize) -> Vec<u8> {
    let body = frame::encode_sparse_points(dim, batch).unwrap();
    let mut out = Vec::new();
    frame::write_frame(
        &mut out,
        &Json::parse(r#"{"op":"predict"}"#).unwrap(),
        &body,
    )
    .unwrap();
    out
}

/// Mean per-query wire payload sizes at the full RCV1 shape (d=47,236,
/// ~76 nnz/doc) for the README's encoding table.
fn payload_sizes_rcv1(report: &mut BenchReport) {
    let data = Rcv1Sim::default().generate(8, 3);
    let (sparse, dense) = query_rows(&data, 8);
    let dense_json = dense_points_json(&dense).len() as f64 / 8.0;
    let sparse_json = sparse_points_json(data.dim(), &sparse).len() as f64 / 8.0;
    let sparse_bin =
        frame::encode_sparse_points(data.dim(), &sparse).unwrap().len() as f64 / 8.0;
    report.meta("payload_bytes_per_query_dense_json_rcv1", json::num(dense_json));
    report.meta("payload_bytes_per_query_sparse_json_rcv1", json::num(sparse_json));
    report.meta("payload_bytes_per_query_sparse_binary_rcv1", json::num(sparse_bin));
    report.meta(
        "payload_ratio_sparse_json_rcv1",
        json::num(dense_json / sparse_json),
    );
    report.meta(
        "payload_ratio_sparse_binary_rcv1",
        json::num(dense_json / sparse_bin),
    );
    println!(
        "RCV1-shape payload/query: dense JSON {dense_json:.0} B, sparse JSON \
         {sparse_json:.0} B ({:.0}x), binary sparse {sparse_bin:.0} B ({:.0}x)",
        dense_json / sparse_json,
        dense_json / sparse_bin
    );
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = BenchOpts::from_env_or_args(&args);
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|p| args.get(p + 1).cloned());
    let scale = scale_for(&opts);
    let data = GaussianMixture::default_spec(scale.k, scale.dim)
        .generate(scale.n_points, 7);

    let mut report = BenchReport::new("serve_throughput");
    report.meta("threads", json::num(Pool::auto().threads as f64));
    report.meta("n_points", json::num(scale.n_points as f64));
    report.meta("k", json::num(scale.k as f64));
    report.meta("dim", json::num(scale.dim as f64));
    report.meta(
        "predicts_per_conn",
        json::num(scale.predicts_per_conn as f64),
    );

    let mut set = BenchSet::new("predict_during_training", opts);
    for conns in [1usize, 4] {
        set.bench(&format!("conns{conns}"), || {
            run_trial(&data, &scale, conns)
        });
    }
    // derived: aggregate QPS at each arity, and the scaling ratio the
    // reader/writer split buys (4 conns do 4x the work; perfect scaling
    // keeps wall time flat → ratio ≈ 4)
    let t1 = set.get("conns1").map(|m| m.median_secs()).unwrap_or(f64::NAN);
    let t4 = set.get("conns4").map(|m| m.median_secs()).unwrap_or(f64::NAN);
    let total1 = scale.predicts_per_conn as f64;
    let total4 = 4.0 * scale.predicts_per_conn as f64;
    report.meta("qps_conns1", json::num(total1 / t1));
    report.meta("qps_conns4", json::num(total4 / t4));
    report.meta("scaling_x", json::num((total4 / t4) / (total1 / t1)));
    println!(
        "predict throughput during training: {:.0} qps @1 conn, {:.0} qps @4 conns ({:.2}x)",
        total1 / t1,
        total4 / t4,
        (total4 / t4) / (total1 / t1)
    );
    report.push(set);

    // ── wire variants: sparse-encoded and binary-framed predicts ──────
    let sdata = Rcv1Sim {
        vocab: scale.wire_vocab,
        topic_vocab: (scale.wire_vocab / 8).max(40),
        ..Default::default()
    }
    .generate(scale.wire_n_points, 5);
    let dim = sdata.dim();
    let mut scfg = cfg(scale.wire_k);
    scfg.max_rounds = 6;
    scfg.max_seconds = 60.0;
    let (trained, _) = session::train(&sdata, &scfg).expect("train sparse model");
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().unwrap();
    let reg = Arc::new(ModelRegistry::with_default(trained));
    let server = std::thread::spawn(move || {
        nmbkm::serve::server::serve_listener_opts(reg, listener, true).unwrap();
    });
    let (sparse_rows, dense_rows) = query_rows(&sdata, scale.wire_queries);
    report.meta("wire_queries", json::num(scale.wire_queries as f64));
    report.meta("wire_vocab", json::num(scale.wire_vocab as f64));
    report.meta(
        "wire_mean_nnz",
        json::num(match &sdata.storage {
            Storage::Sparse(m) => m.mean_nnz(),
            _ => 0.0,
        }),
    );

    // sanity: all three routes answer the first batch with the same bits
    {
        let (mut conn, mut reader) = connect(addr);
        let dense_resp = roundtrip(
            &mut conn,
            &mut reader,
            &format!(
                "{{\"op\":\"predict\",\"points\":{}}}",
                dense_points_json(&dense_rows[..8])
            ),
        );
        let sparse_resp = roundtrip(
            &mut conn,
            &mut reader,
            &format!(
                "{{\"op\":\"predict\",\"points\":{}}}",
                sparse_points_json(dim, &sparse_rows[..8])
            ),
        );
        assert_eq!(fingerprint(&dense_resp), fingerprint(&sparse_resp));
        let mut bconn = TcpStream::connect(addr).unwrap();
        bconn.write_all(&[frame::MAGIC]).unwrap();
        let mut breader = BufReader::new(bconn.try_clone().unwrap());
        bconn
            .write_all(&predict_frame(&sparse_rows[..8], dim))
            .unwrap();
        let (_, body) = frame::read_frame(&mut breader).unwrap().unwrap();
        let (blbl, bd2) = frame::decode_predict_body(&body).unwrap();
        let (jlbl, jd2) = fingerprint(&dense_resp);
        assert_eq!(blbl, jlbl, "binary route diverged from JSONL");
        assert_eq!(
            bd2.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            jd2,
            "binary d2 bits diverged from JSONL"
        );
    }

    let mut set = BenchSet::new("predict_wire_variants", opts);
    let mut qps = Vec::new();
    for batch in [1usize, 16, 64] {
        // prebuild every request so the timed region is pure
        // request/response traffic
        let jsonl_dense: Vec<String> = dense_rows
            .chunks(batch)
            .map(|c| format!("{{\"op\":\"predict\",\"points\":{}}}", dense_points_json(c)))
            .collect();
        let jsonl_sparse: Vec<String> = sparse_rows
            .chunks(batch)
            .map(|c| {
                format!(
                    "{{\"op\":\"predict\",\"points\":{}}}",
                    sparse_points_json(dim, c)
                )
            })
            .collect();
        let frames: Vec<Vec<u8>> = sparse_rows
            .chunks(batch)
            .map(|c| predict_frame(c, dim))
            .collect();
        let variants: [(String, Box<dyn FnMut() + '_>); 3] = [
            (
                format!("jsonl_dense_b{batch}"),
                Box::new(|| drive_jsonl(addr, &jsonl_dense)),
            ),
            (
                format!("jsonl_sparse_b{batch}"),
                Box::new(|| drive_jsonl(addr, &jsonl_sparse)),
            ),
            (
                format!("binary_sparse_b{batch}"),
                Box::new(|| drive_binary(addr, &frames)),
            ),
        ];
        for (name, mut runner) in variants {
            let m = set.bench(&name, &mut runner);
            qps.push((name, scale.wire_queries as f64 / m.median_secs()));
        }
    }
    for (name, q) in &qps {
        report.meta(&format!("qps_{name}"), json::num(*q));
    }
    let lookup = |n: &str| {
        qps.iter().find(|(name, _)| name == n).map(|(_, q)| *q).unwrap_or(f64::NAN)
    };
    let sp_jsonl = lookup("jsonl_sparse_b64") / lookup("jsonl_sparse_b1");
    let sp_bin = lookup("binary_sparse_b64") / lookup("binary_sparse_b1");
    report.meta("speedup_sparse_jsonl_b64_over_b1", json::num(sp_jsonl));
    report.meta("speedup_sparse_binary_b64_over_b1", json::num(sp_bin));
    println!(
        "sparse predict batching: jsonl b64/b1 {sp_jsonl:.2}x, binary b64/b1 {sp_bin:.2}x"
    );

    // composite: queries answered per median wall-second per core, over
    // every timed predict path in this report. `nmbkm bench-trend` gates
    // on this with inverted direction (lower = regression), so only emit
    // it when the medians rest on ≥2 samples — smoke medians are noise.
    if opts.samples >= 2 {
        let wire_secs: f64 = set.results.iter().map(|m| m.median_secs()).sum();
        let wire_q = 9.0 * scale.wire_queries as f64; // 3 variants × 3 batch sizes
        let total_q = total1 + total4 + wire_q;
        let total_s = t1 + t4 + wire_secs;
        let cores = Pool::auto().threads.max(1) as f64;
        let qpc = total_q / total_s / cores;
        report.meta("qps_per_core", json::num(qpc));
        println!(
            "composite: {qpc:.1} predict queries/s/core \
             ({total_q:.0} queries over {total_s:.3} median-s, {cores:.0} cores)"
        );
    }
    payload_sizes_rcv1(&mut report);

    let (mut conn, mut reader) = connect(addr);
    roundtrip(&mut conn, &mut reader, r#"{"op":"shutdown"}"#);
    server.join().unwrap();

    report.push(set);

    // ── WAL append overhead on the ingest path ────────────────────────
    // the same dense ingest stream against no WAL, a WAL that never
    // fsyncs (pure encode+write cost), and a WAL fsyncing every append
    // (the durability ceiling); ratios land in meta for the trend gate
    let wdata = GaussianMixture::default_spec(8, scale.dim)
        .generate(scale.n_points.min(4000), 13);
    let ingest_reqs = ingest_requests(&wdata, &scale);
    report.meta("wal_ingest_batches", json::num(scale.ingest_batches as f64));
    report.meta("wal_ingest_batch", json::num(scale.ingest_batch as f64));
    let mut wset = BenchSet::new("ingest_wal", opts);
    let tmp = std::env::temp_dir().join(format!("nmbkm-walbench-{}", std::process::id()));
    for (name, policy) in [
        ("wal_off", None),
        ("wal_fsync_never", Some(FsyncPolicy::Never)),
        ("wal_fsync_always", Some(FsyncPolicy::Always)),
    ] {
        let dir = tmp.join(name);
        let _ = std::fs::remove_dir_all(&dir);
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
        let addr = listener.local_addr().unwrap();
        let served = session::OnlineSession::from_data(wdata.clone(), cfg(8))
            .expect("session");
        let reg = Arc::new(ModelRegistry::with_default(served));
        if let Some(policy) = policy {
            // u64::MAX checkpoint threshold: measure appends, not
            // checkpoint snapshots
            let rec = wal::recover(&dir, policy, u64::MAX, &reg).expect("wal init");
            reg.attach_wal(rec.wal);
        }
        let sreg = reg.clone();
        let server = std::thread::spawn(move || {
            nmbkm::serve::server::serve_listener(sreg, listener).unwrap();
        });
        wset.bench(name, || drive_jsonl(addr, &ingest_reqs));
        let (mut conn, mut reader) = connect(addr);
        roundtrip(&mut conn, &mut reader, r#"{"op":"shutdown"}"#);
        server.join().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }
    let med = |n: &str| {
        wset.get(n).map(|m| m.median_secs()).unwrap_or(f64::NAN)
    };
    let overhead = med("wal_fsync_never") / med("wal_off");
    let overhead_always = med("wal_fsync_always") / med("wal_off");
    report.meta("wal_append_overhead", json::num(overhead));
    report.meta("wal_fsync_always_overhead", json::num(overhead_always));
    println!(
        "ingest WAL overhead: fsync-never {overhead:.3}x, fsync-always \
         {overhead_always:.3}x vs no WAL"
    );
    report.push(wset);

    // ── out-of-core ingest: disk-backed shards vs resident rows ───────
    let oset = out_of_core_trial(&mut report, &scale, opts);
    report.push(oset);

    // ── c10k saturation: thousands of idle conns + an active load ─────
    let sat = saturation_trial(&mut report, &data, &scale, opts);
    report.push(sat);

    if let Some(path) = json_path {
        report.write(&path).expect("writing bench report");
    }
}

/// This process's resident set in kB, from `/proc/self/status`
/// (`None` off Linux — the meta key is simply omitted there).
fn rss_kb() -> Option<f64> {
    proc_status_kb("VmRSS:")
}

/// Lifetime peak resident set in kB (`None` off Linux).
fn vm_hwm_kb() -> Option<f64> {
    proc_status_kb("VmHWM:")
}

fn proc_status_kb(key: &str) -> Option<f64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with(key))?;
    line.split_whitespace().nth(1)?.parse::<f64>().ok()
}

/// Out-of-core ingest trial: the identical ingest+train stream runs
/// against a fully resident session and one spilled to a disk shard
/// whose pinned-block cache holds a tiny fraction of the rows. The
/// timed measurements feed the trend gate; `meta` carries the
/// bounded-memory evidence — the shard store's own peak pinned-block
/// count against its budget (dataset ≫ budget), which is what "RSS
/// bounded by the cache, not the corpus" means once allocator noise is
/// excluded.
fn out_of_core_trial(
    report: &mut BenchReport,
    scale: &Scale,
    opts: BenchOpts,
) -> BenchSet {
    let n = (scale.n_points * 2).max(8192);
    let odata = GaussianMixture::default_spec(8, scale.dim).generate(n, 29);
    let rows: Vec<Vec<f32>> = {
        let mut out = Vec::with_capacity(n);
        let mut row = vec![0f32; odata.dim()];
        for i in 0..n {
            odata.write_row_dense(i, &mut row);
            out.push(row.clone());
        }
        out
    };
    // 2048 resident rows = a 2-block pinned cache; the corpus spans
    // n/1024 blocks, so most fetches go through eviction
    let max_resident = 2048usize;
    let shard_dir = std::env::temp_dir()
        .join(format!("nmbkm-oocbench-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&shard_dir);
    std::fs::create_dir_all(&shard_dir).expect("shard dir");

    let run_ingest = |spill: bool| {
        let mut s = session::OnlineSession::new(cfg(8), odata.dim())
            .expect("session");
        if spill {
            s.spill_to(&shard_dir.join("bench.rows"), max_resident)
                .expect("spill");
        }
        for chunk in rows.chunks(1024) {
            s.ingest_rows(chunk).expect("ingest");
            s.step(1, f64::INFINITY).expect("step");
        }
        s
    };

    let mut set = BenchSet::new("ingest_out_of_core", opts);
    set.bench("ram", || {
        run_ingest(false);
    });
    set.bench("ooc", || {
        run_ingest(true);
    });
    let med = |n: &str| set.get(n).map(|m| m.median_secs()).unwrap_or(f64::NAN);
    let ram_rate = n as f64 / med("ram");
    let ooc_rate = n as f64 / med("ooc");
    report.meta("ooc_rows", json::num(n as f64));
    report.meta("ram_ingest_rows_per_s", json::num(ram_rate));
    report.meta("ooc_ingest_rows_per_s", json::num(ooc_rate));
    report.meta("ooc_overhead_x", json::num(med("ooc") / med("ram")));

    // bounded-memory evidence from an instrumented single pass
    let s = run_ingest(true);
    let store = s.shard_store().expect("spilled session has a shard store");
    let dataset_mb = (n * odata.dim() * 4) as f64 / (1024.0 * 1024.0);
    let budget_mb = (store.cache_cap() * 1024 * odata.dim() * 4) as f64
        / (1024.0 * 1024.0);
    assert!(
        store.peak_cached_blocks() <= store.cache_cap(),
        "pinned blocks {} exceeded the cache budget {}",
        store.peak_cached_blocks(),
        store.cache_cap()
    );
    report.meta(
        "ooc_peak_cached_blocks",
        json::num(store.peak_cached_blocks() as f64),
    );
    report.meta("ooc_cache_cap_blocks", json::num(store.cache_cap() as f64));
    report.meta("ooc_disk_reads", json::num(store.disk_reads() as f64));
    report.meta("ooc_dataset_mb", json::num(dataset_mb));
    report.meta("ooc_resident_budget_mb", json::num(budget_mb));
    if let Some(hwm) = vm_hwm_kb() {
        report.meta("vmhwm_mb", json::num(hwm / 1024.0));
    }
    println!(
        "out-of-core ingest: {ram_rate:.0} rows/s resident, {ooc_rate:.0} \
         rows/s disk-backed ({:.2}x); pinned {}/{} blocks, {:.1} MB corpus \
         vs {:.1} MB resident budget, {} disk reads",
        med("ooc") / med("ram"),
        store.peak_cached_blocks(),
        store.cache_cap(),
        dataset_mb,
        budget_mb,
        store.disk_reads()
    );
    drop(s);
    let _ = std::fs::remove_dir_all(&shard_dir);
    set
}

/// Saturating many-connection trial: hold `idle_conns` admitted
/// connections open (scaled down only if RLIMIT_NOFILE refuses to
/// budge) while `active_conns` peers each complete
/// `active_predicts` predict round-trips. The accept phase is
/// measured against the server's own `open_connections` gauge — the
/// clock stops when every connection is *admitted*, not merely
/// SYN-ACKed out of the kernel backlog — and the active phase is a
/// gateable [`BenchSet`] measurement. RSS growth per idle connection
/// lands in `meta` as the bounded-memory evidence.
fn saturation_trial(
    report: &mut BenchReport,
    data: &Data,
    scale: &Scale,
    opts: BenchOpts,
) -> BenchSet {
    // two fds per connection (client + server end, same process) plus
    // headroom for the poller, listener, wake pipe, and stdio
    let want = 2 * (scale.idle_conns + scale.active_conns) as u64 + 128;
    let got = event::raise_nofile_limit(want);
    let budget = (got as usize / 2).saturating_sub(scale.active_conns + 64);
    let idle_n = scale.idle_conns.min(budget.max(16));
    if idle_n < scale.idle_conns {
        println!(
            "c10k: RLIMIT_NOFILE caps at {got} fds; holding {idle_n} idle \
             conns instead of {}",
            scale.idle_conns
        );
    }

    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().unwrap();
    let served = session::OnlineSession::from_data(data.clone(), cfg(scale.k))
        .expect("session");
    let reg = Arc::new(ModelRegistry::with_default(served));
    let server = std::thread::spawn(move || {
        serve_listener_with(
            reg,
            listener,
            // no idle reaping: the whole point is to hold conns open
            ServeOptions { conn_timeout: None, ..Default::default() },
        )
        .unwrap();
    });

    // accept phase: stopwatch from first connect until the server's
    // gauge shows every idle conn admitted
    let gauge = &observe::serve_metrics().open_connections;
    let g0 = gauge.get();
    let rss0 = rss_kb();
    let t0 = Instant::now();
    let mut idle = Vec::with_capacity(idle_n);
    for _ in 0..idle_n {
        idle.push(TcpStream::connect(addr).expect("idle connect"));
    }
    while gauge.get() < g0 + idle_n as i64 {
        assert!(
            t0.elapsed().as_secs() < 120,
            "server admitted only {} of {idle_n} idle conns in 120s",
            gauge.get() - g0
        );
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    let accept_secs = t0.elapsed().as_secs_f64();
    let accept_rate = idle_n as f64 / accept_secs;
    report.meta("c10k_idle_conns", json::num(idle_n as f64));
    report.meta("c10k_active_conns", json::num(scale.active_conns as f64));
    report.meta("c10k_accept_rate_conns_per_s", json::num(accept_rate));
    if let (Some(r0), Some(r1)) = (rss0, rss_kb()) {
        let per_conn = (r1 - r0).max(0.0) * 1024.0 / idle_n as f64;
        report.meta("c10k_rss_bytes_per_idle_conn", json::num(per_conn));
        println!(
            "c10k: {idle_n} idle conns admitted in {accept_secs:.3}s \
             ({accept_rate:.0}/s), {per_conn:.0} B RSS each"
        );
    } else {
        println!(
            "c10k: {idle_n} idle conns admitted in {accept_secs:.3}s \
             ({accept_rate:.0}/s)"
        );
    }

    // active phase: timed predict load with the idle herd still open
    let queries: Vec<Vec<f32>> = {
        let mut out = Vec::with_capacity(scale.query_batch);
        let mut row = vec![0f32; data.dim()];
        for i in 0..scale.query_batch {
            data.write_row_dense(i * 11 % data.n(), &mut row);
            out.push(row.clone());
        }
        out
    };
    let req = Arc::new(format!(
        "{{\"op\":\"predict\",\"points\":{}}}",
        dense_points_json(&queries)
    ));
    let lat = Arc::new(Mutex::new(Vec::new()));
    let mut set = BenchSet::new("c10k_saturation", opts);
    let per_conn = scale.active_predicts;
    set.bench("active_predicts_under_idle_load", || {
        let mut clients = Vec::with_capacity(scale.active_conns);
        for _ in 0..scale.active_conns {
            let req = req.clone();
            let lat = lat.clone();
            clients.push(std::thread::spawn(move || {
                let (mut conn, mut reader) = connect(addr);
                let mut mine = Vec::with_capacity(per_conn);
                for _ in 0..per_conn {
                    let q0 = Instant::now();
                    let resp = roundtrip(&mut conn, &mut reader, &req);
                    mine.push(q0.elapsed().as_secs_f64());
                    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
                }
                lat.lock().unwrap().extend(mine);
            }));
        }
        for c in clients {
            c.join().unwrap();
        }
    });

    // p99 over every recorded round-trip (warmup included — cold-path
    // latency is exactly what a tail percentile should own)
    let mut all = lat.lock().unwrap().clone();
    all.sort_by(f64::total_cmp);
    if !all.is_empty() {
        let p99 = all[(all.len() * 99 / 100).min(all.len() - 1)] * 1e3;
        report.meta("c10k_p99_predict_ms", json::num(p99));
        println!(
            "c10k: active predict p99 {p99:.2} ms across {} round-trips \
             with {idle_n} idle conns open",
            all.len()
        );
    }

    drop(idle);
    let (mut conn, mut reader) = connect(addr);
    roundtrip(&mut conn, &mut reader, r#"{"op":"shutdown"}"#);
    server.join().unwrap();
    set
}

/// Prebuilt dense JSONL ingest requests (one per nested batch).
fn ingest_requests(data: &Data, scale: &Scale) -> Vec<String> {
    let mut out = Vec::with_capacity(scale.ingest_batches);
    let mut row = vec![0f32; data.dim()];
    for b in 0..scale.ingest_batches {
        let mut batch = Vec::with_capacity(scale.ingest_batch);
        for i in 0..scale.ingest_batch {
            data.write_row_dense(
                (b * scale.ingest_batch + i) % data.n(),
                &mut row,
            );
            batch.push(row.clone());
        }
        out.push(format!(
            "{{\"op\":\"ingest\",\"rounds\":1,\"points\":{}}}",
            dense_points_json(&batch)
        ));
    }
    out
}
