//! Figures 2 & 3: the effect of ρ on gb-ρ and tb-ρ
//! (ρ ∈ {1, 10, 100, 1000, ∞}, with mb for reference).
//!
//! Paper findings this reproduces: for `gb-ρ` an intermediate ρ can be
//! best early while large ρ wins late; for `tb-ρ` large ρ is clearly
//! optimal (fine-tuning is cheap under bounds, so premature-finetuning
//! risk vanishes — §4.3.1). Figure 2 is infMNIST, Figure 3 (supp.)
//! is RCV1.

use crate::config::{Algo, Rho, RunConfig};
use crate::data::Dataset;
use crate::experiments::common::{self, Curve, ExpOpts};
use crate::kmeans::assign::AssignEngine;

pub const RHOS: [Rho; 5] = [
    Rho::Finite(1.0),
    Rho::Finite(10.0),
    Rho::Finite(100.0),
    Rho::Finite(1000.0),
    Rho::Infinite,
];

pub fn algo_set() -> Vec<RunConfig> {
    let base = RunConfig::default();
    let mut v = vec![RunConfig { algo: Algo::Mb, ..base.clone() }];
    for rho in RHOS {
        v.push(RunConfig { algo: Algo::GbRho, rho, ..base.clone() });
    }
    for rho in RHOS {
        v.push(RunConfig { algo: Algo::TbRho, rho, ..base.clone() });
    }
    v
}

pub fn run_dataset(
    ds: &Dataset,
    opts: &ExpOpts,
    engine: &dyn AssignEngine,
) -> anyhow::Result<Vec<Curve>> {
    let grid = common::time_grid(opts.seconds / 100.0, opts.seconds, 24);
    let mut curves = Vec::new();
    for mut cfg in algo_set() {
        cfg.k = 50.min(ds.train.n() / 4).max(2);
        cfg.b0 = common::default_b0(opts.scale);
        cfg.eval_every_secs = opts.seconds / 40.0;
        let (curve, _) =
            common::multi_seed_curve(ds, &cfg, opts, engine, &grid)?;
        println!(
            "   [{}] {}: mean final MSE {:.6e}",
            ds.name, curve.label, curve.mean_final
        );
        curves.push(curve);
    }
    Ok(curves)
}

/// `figure` is 2 (infmnist) or 3 (rcv1).
pub fn run(figure: u8, opts: &ExpOpts) -> anyhow::Result<()> {
    let engine: Box<dyn AssignEngine + Send> = match opts.engine {
        crate::config::Engine::Native => {
            Box::new(crate::kmeans::assign::NativeEngine::default())
        }
        crate::config::Engine::Xla => crate::runtime::make_engine("artifacts")?,
    };
    let (ds, tag) = match figure {
        2 => (common::infmnist(opts.scale), "infmnist"),
        3 => (common::rcv1(opts.scale), "rcv1"),
        other => anyhow::bail!("rho sweep figure must be 2 or 3, got {other}"),
    };
    println!("== Figure {figure}: ρ sweep on {} ==", ds.summary());
    let curves = run_dataset(&ds, opts, engine.as_ref())?;
    common::print_final_summary(tag, &curves);
    let path =
        common::write_curves_csv(&format!("fig{figure}_rho_{tag}"), tag, &curves)?;
    println!("   wrote {}", path.display());
    check_shape(&curves);
    Ok(())
}

/// Paper §4.3.1: for tb-ρ, very large ρ (1000/∞) should be at least as
/// good as small ρ (=1) at the end of the budget.
pub fn check_shape(curves: &[Curve]) {
    let find = |label: &str| curves.iter().find(|c| c.label == label);
    if let (Some(tb1), Some(tbinf)) = (find("tb-1"), find("tb-inf")) {
        let ok = tbinf.mean_final <= tb1.mean_final * 1.05;
        println!(
            "   [shape] tb-∞ ≤ tb-1 at end: {}",
            if ok { "PASS" } else { "WARN" }
        );
    }
}
