//! Leader-side merging of per-shard results.
//!
//! Workers produce partial sufficient-statistics deltas (or any type
//! implementing [`Mergeable`]); the leader folds them in shard order so
//! the result is deterministic for a given chunking.

/// Types that can absorb another instance of themselves.
pub trait Mergeable {
    fn merge(&mut self, other: Self);
}

/// Fold shard results in order; returns `None` for an empty set.
pub fn fold<T: Mergeable>(parts: Vec<T>) -> Option<T> {
    let mut it = parts.into_iter();
    let mut acc = it.next()?;
    for p in it {
        acc.merge(p);
    }
    Some(acc)
}

/// A pair of scalar accumulators many shards produce (e.g. distance
/// calculations + bound skips).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Counters {
    pub dist_calcs: u64,
    pub bound_skips: u64,
}

impl Mergeable for Counters {
    fn merge(&mut self, other: Self) {
        self.dist_calcs += other.dist_calcs;
        self.bound_skips += other.bound_skips;
    }
}

impl Mergeable for f64 {
    fn merge(&mut self, other: Self) {
        *self += other;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fold_counters() {
        let parts = vec![
            Counters { dist_calcs: 1, bound_skips: 10 },
            Counters { dist_calcs: 2, bound_skips: 20 },
            Counters { dist_calcs: 3, bound_skips: 30 },
        ];
        let total = fold(parts).unwrap();
        assert_eq!(total, Counters { dist_calcs: 6, bound_skips: 60 });
        assert!(fold::<Counters>(vec![]).is_none());
    }

    #[test]
    fn fold_scalars() {
        assert_eq!(fold(vec![1.0, 2.0, 3.5]).unwrap(), 6.5);
    }
}
