//! Summary statistics used across experiments and the batch controller:
//! mean/std over seed runs, medians (the controller's cluster vote), and
//! an online accumulator for streaming summaries.

/// Mean of a slice; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample standard deviation; 0 for n < 2.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
        / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Median (upper median for even n), tolerating NaN/∞ entries by the
/// IEEE total order — the controller's ratios can legitimately be ∞
/// (p(j) = 0, paper §3.3.3), and the median over values including ∞ is
/// exactly the mechanism that triggers doubling for ρ = ∞.
pub fn median(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "median of empty slice");
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    v[v.len() / 2]
}

/// Quantile in [0,1] by nearest-rank on the sorted data.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let idx = ((v.len() as f64 - 1.0) * q).round() as usize;
    v[idx.min(v.len() - 1)]
}

/// Online mean/min/max/std accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Online {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Online {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basics() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std(&xs) - 2.138089935299395).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std(&[1.0]), 0.0);
    }

    #[test]
    fn median_with_infinities() {
        // three of five ratios are ∞ → median is ∞ (controller doubles)
        let xs = [1.0, f64::INFINITY, f64::INFINITY, 0.5, f64::INFINITY];
        assert!(median(&xs).is_infinite());
        // two of five → median finite
        let xs = [1.0, f64::INFINITY, 2.0, 0.5, f64::INFINITY];
        assert_eq!(median(&xs), 2.0);
    }

    #[test]
    fn median_even_upper() {
        assert_eq!(median(&[1.0, 2.0, 3.0, 4.0]), 3.0);
        assert_eq!(median(&[5.0]), 5.0);
    }

    #[test]
    fn quantiles() {
        let xs: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert_eq!(quantile(&xs, 0.0), 0.0);
        assert_eq!(quantile(&xs, 0.5), 50.0);
        assert_eq!(quantile(&xs, 1.0), 100.0);
    }

    #[test]
    fn online_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut o = Online::new();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - mean(&xs)).abs() < 1e-12);
        assert!((o.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(o.min, 1.0);
        assert_eq!(o.max, 9.0);
    }
}
