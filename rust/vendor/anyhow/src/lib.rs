//! Offline drop-in shim for the `anyhow` crate.
//!
//! The build image has no crates.io access, so this path dependency
//! provides the (small) API subset the workspace uses: [`Error`],
//! [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`] macros and the
//! [`Context`] extension trait. Semantics follow the real crate:
//!
//! * `Error` deliberately does **not** implement `std::error::Error`,
//!   which is what makes the blanket `From<E: std::error::Error>`
//!   conversion coherent (the same trick the real crate uses);
//! * `{e}` displays the outermost message, `{e:#}` the full
//!   colon-separated context chain.
//!
//! Swapping in the real crate is a one-line Cargo.toml change; no call
//! site depends on anything beyond this surface.

use std::fmt;

/// An error chain: `msgs[0]` is the outermost context, the last entry
/// the root cause.
pub struct Error {
    msgs: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msgs: vec![m.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, ctx: C) -> Error {
        self.msgs.insert(0, ctx.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.msgs.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.msgs.join(": "))
        } else {
            write!(f, "{}", self.msgs[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msgs[0])?;
        if self.msgs.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for m in &self.msgs[1..] {
                write!(f, "\n    {m}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        Error { msgs }
    }
}

/// `anyhow::Result<T>`, defaulting the error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self,
        ctx: C,
    ) -> Result<T, Error>;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self,
        ctx: C,
    ) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self,
        ctx: C,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)+) => {
        $crate::Error::msg(format!($fmt, $($arg)+))
    };
}

/// Early-return with an error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Early-return with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"))?;
        Ok(())
    }

    #[test]
    fn from_std_error_via_question_mark() {
        let e = fails_io().unwrap_err();
        assert_eq!(format!("{e}"), "disk on fire");
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let e = fails_io().context("loading model").unwrap_err();
        assert_eq!(format!("{e}"), "loading model");
        assert_eq!(format!("{e:#}"), "loading model: disk on fire");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
    }

    #[test]
    fn macros() {
        fn inner(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Err(anyhow!("fell through with {x}"))
        }
        assert_eq!(format!("{}", inner(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", inner(3).unwrap_err()), "three is right out");
        assert_eq!(format!("{}", inner(1).unwrap_err()), "fell through with 1");
        // single-expression form takes any Display
        let e: Error = anyhow!(std::io::Error::new(
            std::io::ErrorKind::Other,
            "boom"
        ));
        assert_eq!(format!("{e}"), "boom");
    }
}
