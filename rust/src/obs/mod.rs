//! Dependency-free, lock-free observability primitives: sharded atomic
//! counters, gauges, and fixed-bucket log₂ latency histograms behind a
//! process-wide named registry.
//!
//! Design constraints, in order:
//!
//! * **Never perturb results.** Metrics are recorded *about* the
//!   kernels, never *inside* their arithmetic: hot paths accumulate
//!   plain integers per chunk and flush once, so every bit-exactness
//!   test passes with recording enabled.
//! * **One `fetch_add` per record.** A counter add is a single relaxed
//!   `fetch_add` on a cache-line-padded shard picked per thread; a
//!   histogram record is a single relaxed `fetch_add` on the bucket
//!   indexed by `floor(log2(nanos))`. No locks anywhere on the record
//!   path; reads sum shards/buckets with relaxed loads (monotone, may
//!   trail in-flight adds by one — fine for observability).
//! * **Near-zero when disabled.** `NMBKM_METRICS=0` flips one process
//!   flag: [`Timer::start`] returns an empty timer (no clock read) and
//!   recording helpers no-op. Counters cost one relaxed `fetch_add`
//!   either way — cheaper than the branch that would skip them.
//!
//! Handles ([`Counter`], [`Gauge`], [`Histogram`]) are interned in the
//! global [`registry`] under `(name, labels)` and cached by callers in
//! `OnceLock` statics or struct fields, so the registry's `RwLock` is
//! touched at acquisition and scrape time only. Exposure lives in
//! [`export`] (stable JSON + Prometheus text exposition), [`http`]
//! (a hand-rolled `GET /metrics` listener), and [`log`] (the opt-in
//! `NMBKM_LOG` JSONL event log).

pub mod export;
pub mod http;
pub mod log;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, OnceLock, RwLock};
use std::time::{Duration, Instant};

/// Counter shard lanes. Eight 64-byte-padded slots bound same-line
/// contention at 8 writer threads per counter without bloating every
/// counter past two cache lines of hot data.
pub const COUNTER_SHARDS: usize = 8;

/// Histogram bucket count: log₂ buckets from [`HIST_MIN_POW`] up, the
/// last bucket catching everything larger (`+Inf` in the exposition).
pub const HIST_BUCKETS: usize = 28;

/// Bucket 0 spans `[0, 2^(HIST_MIN_POW+1))` nanoseconds (≈ 2 µs): one
/// bucket for everything cheaper than a syscall, then a ×2 ladder up to
/// `2^(HIST_MIN_POW+HIST_BUCKETS)` ns ≈ 275 s — the whole latency range
/// a serve request can plausibly occupy, in 28 buckets.
pub const HIST_MIN_POW: u32 = 10;

#[repr(align(64))]
#[derive(Default)]
struct PaddedU64(AtomicU64);

/// A monotone counter sharded across padded cache lines. One relaxed
/// `fetch_add` per [`Counter::add`]; [`Counter::get`] sums the shards.
#[derive(Default)]
pub struct Counter {
    shards: [PaddedU64; COUNTER_SHARDS],
}

impl Counter {
    pub fn add(&self, n: u64) {
        self.shards[shard_lane()].0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.shards.iter().map(|s| s.0.load(Ordering::Relaxed)).sum()
    }
}

/// Per-thread shard lane: assigned round-robin on first touch, so
/// steady-state worker threads never share a counter cache line.
fn shard_lane() -> usize {
    use std::cell::Cell;
    thread_local! {
        static LANE: Cell<usize> = const { Cell::new(usize::MAX) };
    }
    LANE.with(|l| {
        let mut v = l.get();
        if v == usize::MAX {
            static NEXT: AtomicU64 = AtomicU64::new(0);
            v = NEXT.fetch_add(1, Ordering::Relaxed) as usize % COUNTER_SHARDS;
            l.set(v);
        }
        v
    })
}

/// An up/down instantaneous value (queue depths, in-flight work).
/// Unsharded: gauges sit off the per-item hot paths.
#[derive(Default)]
pub struct Gauge {
    v: AtomicI64,
}

impl Gauge {
    pub fn add(&self, d: i64) {
        self.v.fetch_add(d, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn dec(&self) {
        self.add(-1);
    }

    pub fn set(&self, x: i64) {
        self.v.store(x, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Fixed-bucket log₂ latency histogram: bucket `i` counts samples in
/// `[2^(HIST_MIN_POW+i), 2^(HIST_MIN_POW+i+1))` ns (bucket 0 also takes
/// everything smaller, the last bucket everything larger). One relaxed
/// `fetch_add` per record; p50/p90/p99 derive from the bucket counts at
/// read time ([`quantile_nanos`]), each answer exact up to its bucket's
/// upper bound.
#[derive(Default)]
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
}

impl Histogram {
    /// Bucket index for a sample of `nanos`.
    pub fn bucket_index(nanos: u64) -> usize {
        if nanos < (1 << (HIST_MIN_POW + 1)) {
            return 0;
        }
        let pow = 63 - nanos.leading_zeros(); // floor(log2), nanos ≥ 2^(MIN+1)
        ((pow - HIST_MIN_POW) as usize).min(HIST_BUCKETS - 1)
    }

    /// Inclusive upper bound of bucket `i` in nanoseconds; `None` for
    /// the last (`+Inf`) bucket.
    pub fn le_nanos(i: usize) -> Option<u64> {
        if i >= HIST_BUCKETS - 1 {
            None
        } else {
            Some(1u64 << (HIST_MIN_POW + i as u32 + 1))
        }
    }

    pub fn record_nanos(&self, nanos: u64) {
        self.buckets[Self::bucket_index(nanos)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn record(&self, d: Duration) {
        self.record_nanos(d.as_nanos() as u64);
    }

    /// Relaxed per-bucket snapshot (not atomic across buckets — each
    /// bucket is individually monotone, which is all quantile and
    /// monotonicity consumers need).
    pub fn snapshot(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }
}

/// Quantile `q` in (0, 1] over a bucket snapshot: the upper bound of
/// the bucket where the cumulative count crosses `ceil(q·total)` — an
/// overestimate by at most one ×2 bucket. Returns 0 on an empty
/// histogram; the open-ended last bucket clamps to its lower bound ×2.
pub fn quantile_nanos(buckets: &[u64], q: f64) -> u64 {
    let total: u64 = buckets.iter().sum();
    if total == 0 {
        return 0;
    }
    let target = ((q * total as f64).ceil() as u64).clamp(1, total);
    let mut cum = 0u64;
    for (i, &b) in buckets.iter().enumerate() {
        cum += b;
        if cum >= target {
            return Histogram::le_nanos(i)
                .unwrap_or(1u64 << (HIST_MIN_POW + HIST_BUCKETS as u32));
        }
    }
    1u64 << (HIST_MIN_POW + HIST_BUCKETS as u32)
}

/// Estimated sum of all recorded samples in nanoseconds: Σ bucket_count
/// × geometric-bucket midpoint (1.5 × lower bound). The histogram keeps
/// one `fetch_add` per record instead of a second for an exact sum, so
/// the Prometheus `_sum` series is an estimate — documented as such.
pub fn estimated_sum_nanos(buckets: &[u64]) -> u64 {
    buckets
        .iter()
        .enumerate()
        .map(|(i, &b)| b.saturating_mul((3u64 << (HIST_MIN_POW + i as u32)) / 2))
        .sum()
}

// --- enable flag ----------------------------------------------------------

const EN_OFF: u8 = 0;
const EN_ON: u8 = 1;
const EN_UNSET: u8 = 2;
static ENABLED: AtomicU8 = AtomicU8::new(EN_UNSET);

/// Whether timing collection is on (default yes; `NMBKM_METRICS=0`
/// disables). Gates clock reads, not counter adds — a relaxed
/// `fetch_add` is cheaper than making every add conditional.
pub fn enabled() -> bool {
    match ENABLED.load(Ordering::Relaxed) {
        EN_OFF => false,
        EN_ON => true,
        _ => {
            let on = !matches!(
                std::env::var("NMBKM_METRICS").as_deref(),
                Ok("0") | Ok("off") | Ok("false")
            );
            ENABLED.store(if on { EN_ON } else { EN_OFF }, Ordering::Relaxed);
            on
        }
    }
}

/// Override the enable flag (benches measuring disabled-path cost).
pub fn set_enabled(on: bool) {
    ENABLED.store(if on { EN_ON } else { EN_OFF }, Ordering::Relaxed);
}

/// A latency timer that reads the clock only when metrics are enabled:
/// `Timer::start()?…?observe(&hist)` brackets a request with at most
/// two `Instant` reads and one `fetch_add`, or nothing at all.
pub struct Timer(Option<Instant>);

impl Timer {
    pub fn start() -> Timer {
        Timer(if enabled() { Some(Instant::now()) } else { None })
    }

    /// Elapsed nanoseconds so far, when the timer is live.
    pub fn elapsed_nanos(&self) -> Option<u64> {
        self.0.map(|t0| t0.elapsed().as_nanos() as u64)
    }

    /// Record the elapsed time into `h` (no-op for a disabled timer).
    pub fn observe(self, h: &Histogram) {
        if let Some(t0) = self.0 {
            h.record_nanos(t0.elapsed().as_nanos() as u64);
        }
    }
}

/// Nanoseconds since the process-wide monotonic anchor (first call
/// wins; the serve CLI touches it at startup so event-log timestamps
/// count from roughly process start).
pub fn mono_nanos() -> u64 {
    static ANCHOR: OnceLock<Instant> = OnceLock::new();
    ANCHOR.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

// --- the named registry ---------------------------------------------------

/// Sorted `(key, value)` label pairs; part of a metric's identity.
pub type Labels = Vec<(String, String)>;

/// A registered metric handle.
#[derive(Clone)]
pub enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// One scraped time-series value.
pub enum Value {
    Counter(u64),
    Gauge(i64),
    /// Per-bucket (non-cumulative) counts, [`HIST_BUCKETS`] long.
    Histogram(Vec<u64>),
}

/// One scraped sample: `(name, labels)` plus the value at scrape time.
pub struct Sample {
    pub name: String,
    pub labels: Labels,
    pub value: Value,
}

/// The process-wide metric table. Handles are interned once per
/// `(name, labels)` and shared; the lock guards registration and
/// scrapes only, never the record path.
#[derive(Default)]
pub struct Registry {
    metrics: RwLock<BTreeMap<(String, Labels), Metric>>,
}

/// The global registry every layer records into.
pub fn registry() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(Registry::default)
}

fn own_labels(labels: &[(&str, &str)]) -> Labels {
    let mut v: Labels = labels
        .iter()
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect();
    v.sort();
    v
}

impl Registry {
    fn intern(&self, name: &str, labels: &[(&str, &str)], make: impl FnOnce() -> Metric) -> Metric {
        let key = (name.to_string(), own_labels(labels));
        if let Some(m) = self.metrics.read().unwrap().get(&key) {
            return m.clone();
        }
        let mut w = self.metrics.write().unwrap();
        w.entry(key).or_insert_with(make).clone()
    }

    /// The counter registered under `(name, labels)`, created on first
    /// use. Panics if the name is already registered at another kind —
    /// a programming error, caught in tests.
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        match self.intern(name, labels, || Metric::Counter(Arc::new(Counter::default()))) {
            Metric::Counter(c) => c,
            _ => panic!("metric '{name}' is not a counter"),
        }
    }

    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        match self.intern(name, labels, || Metric::Gauge(Arc::new(Gauge::default()))) {
            Metric::Gauge(g) => g,
            _ => panic!("metric '{name}' is not a gauge"),
        }
    }

    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Histogram> {
        match self.intern(name, labels, || {
            Metric::Histogram(Arc::new(Histogram::default()))
        }) {
            Metric::Histogram(h) => h,
            _ => panic!("metric '{name}' is not a histogram"),
        }
    }

    /// Scrape every registered metric, `(name, labels)`-ordered.
    pub fn snapshot(&self) -> Vec<Sample> {
        self.metrics
            .read()
            .unwrap()
            .iter()
            .map(|((name, labels), m)| Sample {
                name: name.clone(),
                labels: labels.clone(),
                value: match m {
                    Metric::Counter(c) => Value::Counter(c.get()),
                    Metric::Gauge(g) => Value::Gauge(g.get()),
                    Metric::Histogram(h) => Value::Histogram(h.snapshot()),
                },
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_shards_sum_across_threads() {
        let c = Arc::new(Counter::default());
        let mut hs = Vec::new();
        for _ in 0..8 {
            let c = c.clone();
            hs.push(std::thread::spawn(move || {
                for _ in 0..10_000 {
                    c.inc();
                }
                c.add(5);
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 8 * 10_005);
    }

    #[test]
    fn histogram_buckets_follow_log2_ladder() {
        // bucket 0 takes everything below 2^(MIN+1)
        assert_eq!(Histogram::bucket_index(0), 0);
        assert_eq!(Histogram::bucket_index(1), 0);
        assert_eq!(Histogram::bucket_index((1 << (HIST_MIN_POW + 1)) - 1), 0);
        // exact powers land at their own bucket's lower edge
        assert_eq!(Histogram::bucket_index(1 << (HIST_MIN_POW + 1)), 1);
        assert_eq!(Histogram::bucket_index((1 << (HIST_MIN_POW + 2)) - 1), 1);
        assert_eq!(Histogram::bucket_index(1 << (HIST_MIN_POW + 2)), 2);
        // the last bucket is open-ended
        assert_eq!(Histogram::bucket_index(u64::MAX), HIST_BUCKETS - 1);
        // every finite le bound is the first value of the next bucket
        for i in 0..HIST_BUCKETS - 1 {
            let le = Histogram::le_nanos(i).unwrap();
            assert_eq!(Histogram::bucket_index(le - 1), i);
            assert_eq!(Histogram::bucket_index(le), i + 1);
        }
        assert!(Histogram::le_nanos(HIST_BUCKETS - 1).is_none());
    }

    #[test]
    fn quantiles_walk_cumulative_buckets() {
        let h = Histogram::default();
        assert_eq!(quantile_nanos(&h.snapshot(), 0.5), 0, "empty histogram");
        // 90 fast samples, 10 slow ones
        for _ in 0..90 {
            h.record_nanos(100); // bucket 0
        }
        for _ in 0..10 {
            h.record_nanos(1 << 20); // ~1ms bucket
        }
        let snap = h.snapshot();
        assert_eq!(h.count(), 100);
        let p50 = quantile_nanos(&snap, 0.50);
        let p99 = quantile_nanos(&snap, 0.99);
        assert_eq!(p50, Histogram::le_nanos(0).unwrap());
        assert_eq!(
            p99,
            Histogram::le_nanos(Histogram::bucket_index(1 << 20)).unwrap()
        );
        assert!(estimated_sum_nanos(&snap) > 0);
    }

    #[test]
    fn registry_interns_by_name_and_labels() {
        let reg = Registry::default();
        let a = reg.counter("t_total", &[("model", "a")]);
        let a2 = reg.counter("t_total", &[("model", "a")]);
        let b = reg.counter("t_total", &[("model", "b")]);
        a.inc();
        a2.inc();
        b.add(7);
        assert_eq!(a.get(), 2, "same (name, labels) shares one counter");
        assert_eq!(b.get(), 7);
        reg.gauge("depth", &[]).set(3);
        reg.histogram("lat_seconds", &[]).record_nanos(500);
        let snap = reg.snapshot();
        assert_eq!(snap.len(), 4);
        // BTreeMap keys: name-ordered, then label-ordered
        assert_eq!(snap[0].name, "depth");
        assert_eq!(snap[1].name, "lat_seconds");
        assert_eq!(snap[2].labels, vec![("model".to_string(), "a".to_string())]);
        match &snap[2].value {
            Value::Counter(v) => assert_eq!(*v, 2),
            _ => panic!("expected counter"),
        }
    }

    #[test]
    fn timer_respects_enable_flag() {
        // NB: the flag is process-global; restore it so parallel tests
        // in this binary keep timing (they only ever assert monotone
        // growth, never exact histogram counts, so a blip is harmless)
        set_enabled(false);
        assert!(Timer::start().elapsed_nanos().is_none());
        set_enabled(true);
        assert!(Timer::start().elapsed_nanos().is_some());
        let h = Histogram::default();
        Timer::start().observe(&h);
        assert_eq!(h.count(), 1);
    }
}
