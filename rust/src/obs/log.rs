//! Opt-in structured JSONL event log: `NMBKM_LOG=path` (or an explicit
//! [`open`]) appends one JSON object per event — model publishes,
//! session lifecycle, connection open/close, request errors — each
//! stamped with a wall-clock `ts_ms` and a monotonic `mono_ns` (from
//! the process anchor, so intervals between events are meaningful even
//! across wall-clock steps). When no sink is configured the first
//! [`event`] call collapses to one relaxed atomic load.

use crate::obs::mono_nanos;
use crate::util::json::{self, Json};
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;

const ST_UNSET: u8 = 0;
const ST_OFF: u8 = 1;
const ST_ON: u8 = 2;
static STATE: AtomicU8 = AtomicU8::new(ST_UNSET);
static SINK: Mutex<Option<BufWriter<File>>> = Mutex::new(None);

/// Whether an event sink is installed. First call resolves `NMBKM_LOG`.
pub fn active() -> bool {
    match STATE.load(Ordering::Relaxed) {
        ST_ON => true,
        ST_OFF => false,
        _ => init_from_env(),
    }
}

fn init_from_env() -> bool {
    match std::env::var("NMBKM_LOG") {
        Ok(path) if !path.is_empty() => match open(Path::new(&path)) {
            Ok(()) => true,
            Err(e) => {
                eprintln!("[nmbkm::obs] NMBKM_LOG={path}: {e} (event log disabled)");
                STATE.store(ST_OFF, Ordering::Relaxed);
                false
            }
        },
        _ => {
            STATE.store(ST_OFF, Ordering::Relaxed);
            false
        }
    }
}

/// Install (or replace) the event sink: the file is opened in append
/// mode, so restarts extend an existing log.
pub fn open(path: &Path) -> std::io::Result<()> {
    let f = OpenOptions::new().create(true).append(true).open(path)?;
    *SINK.lock().unwrap() = Some(BufWriter::new(f));
    STATE.store(ST_ON, Ordering::Relaxed);
    Ok(())
}

/// Flush and remove the sink (tests; a serving process just exits).
pub fn close() {
    if let Some(mut w) = SINK.lock().unwrap().take() {
        let _ = w.flush();
    }
    STATE.store(ST_OFF, Ordering::Relaxed);
}

/// Append one event line: `{"event": kind, "ts_ms": …, "mono_ns": …,
/// …fields}` (keys alphabetical — the JSON tree is a `BTreeMap`).
/// Events are rare (publishes, connections, errors — not requests), so
/// each line is flushed through to the file immediately.
pub fn event(kind: &str, fields: &[(&str, Json)]) {
    if !active() {
        return;
    }
    let wall_ms = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as f64)
        .unwrap_or(0.0);
    let mut pairs = vec![
        ("event", json::s(kind)),
        ("ts_ms", json::num(wall_ms)),
        ("mono_ns", json::num(mono_nanos() as f64)),
    ];
    for (k, v) in fields {
        pairs.push((*k, v.clone()));
    }
    let line = json::obj(pairs).to_string();
    if let Some(w) = SINK.lock().unwrap().as_mut() {
        let _ = writeln!(w, "{line}");
        let _ = w.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_append_parseable_jsonl() {
        let path = std::env::temp_dir()
            .join(format!("nmbkm_obs_log_test_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        open(&path).unwrap();
        assert!(active());
        event("model_publish", &[("model", json::s("default")), ("rev", json::num(3.0))]);
        event("error", &[("message", json::s("boom \"quoted\""))]);
        close();
        assert!(!active(), "close() must deactivate the sink");
        event("dropped", &[]); // no sink: must be a no-op, not a panic
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let first = Json::parse(lines[0]).unwrap();
        assert_eq!(first.get("event").unwrap().as_str(), Some("model_publish"));
        assert_eq!(first.get("model").unwrap().as_str(), Some("default"));
        assert_eq!(first.get("rev").unwrap().as_f64(), Some(3.0));
        assert!(first.get("ts_ms").unwrap().as_f64().unwrap() > 0.0);
        let m0 = first.get("mono_ns").unwrap().as_f64().unwrap();
        let second = Json::parse(lines[1]).unwrap();
        let m1 = second.get("mono_ns").unwrap().as_f64().unwrap();
        assert!(m1 >= m0, "monotonic stamps must not go backwards");
        assert_eq!(
            second.get("message").unwrap().as_str(),
            Some("boom \"quoted\"")
        );
        let _ = std::fs::remove_file(&path);
    }
}
