//! Dense and sparse (CSR) linear algebra built for the k-means hot path.
//!
//! Everything is `f32` storage with `f64` accumulation where exactness
//! matters (sufficient statistics survive millions of add/subtract
//! cycles in the nested-batch algorithms — see `kmeans::state`).

pub mod dense;
pub mod neighbours;
pub mod simd;
pub mod sparse;

pub use dense::DenseMatrix;
pub use sparse::CsrMatrix;
