//! Leader/worker coordination: the assignment step of every algorithm is
//! sharded across a thread pool; per-shard results (labels, distances,
//! statistics deltas) are merged serially by the leader, which owns the
//! centroid update and the batch-growth vote (k ≪ N work).
//!
//! The offline image has no tokio/rayon; [`shard::Pool`] is a small
//! persistent parked-worker pool built on `std::thread` + condvars,
//! which is all a compute-bound workload needs.

pub mod merge;
pub mod progress;
pub mod shard;

pub use shard::Pool;
