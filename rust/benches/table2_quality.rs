//! Bench T2 — regenerates the paper's Table 2: final cluster quality of
//! lloyd vs tb-∞ for initial batch sizes b0 across both datasets.
//!
//! Expected shape: on dense infMNIST, tb-∞ ≈ lloyd for all b0; on
//! sparse RCV1, tb-∞ degrades as b0 shrinks while lloyd stays flat.

use nmbkm::experiments::{common::ExpOpts, table2};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut opts = ExpOpts::from_args(&args);
    // quality cells want longer budgets than curve benches
    if !args.iter().any(|a| a == "--seconds") {
        opts.seconds *= 2.0;
    }
    println!(
        "[table2] scale={:?} seeds={} budget={}s/run",
        opts.scale, opts.seeds, opts.seconds
    );
    table2::run(&opts).expect("table2 failed");
}
