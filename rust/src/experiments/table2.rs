//! Table 2: final cluster quality — lloyd vs tb-∞ across initial batch
//! sizes b0 ∈ {100, 1000, 5000}.
//!
//! Paper finding: on (dense) infMNIST the two reach equally good final
//! validation MSE for every b0; on (sparse) RCV1 tb-∞ is worse at small
//! b0 and approaches lloyd as b0 grows. Values are mean final
//! validation MSE over seeds, relative to the best MSE over all runs —
//! the same normalisation as the figures.

use crate::config::{Algo, Rho, RunConfig};
use crate::coordinator::progress::{results_dir, Table};
use crate::data::Dataset;
use crate::experiments::common::{self, ExpOpts, Scale};
use crate::kmeans::assign::AssignEngine;
use crate::util::stats;

pub fn b0_grid(scale: Scale) -> Vec<usize> {
    match scale {
        // paper values
        Scale::Full => vec![100, 1000, 5000],
        // same ratios at quick dataset scale
        Scale::Quick => vec![50, 200, 1000],
    }
}

pub struct Cell {
    pub dataset: String,
    pub algo: String,
    pub b0: usize,
    pub mean_final: f64,
    pub std_final: f64,
}

fn run_cell(
    ds: &Dataset,
    cfg: &RunConfig,
    opts: &ExpOpts,
    engine: &dyn AssignEngine,
) -> anyhow::Result<(f64, f64)> {
    let mut finals = Vec::new();
    for seed in 0..opts.seeds {
        let cfg = RunConfig {
            seed,
            threads: opts.threads,
            max_seconds: opts.seconds,
            engine: opts.engine,
            ..cfg.clone()
        };
        let shuffled = crate::data::shuffle::shuffled(&ds.train, seed);
        let out = crate::kmeans::run_prepared(&shuffled, Some(&ds.val), &cfg, engine)?;
        finals.push(out.final_mse);
    }
    Ok((stats::mean(&finals), stats::std(&finals)))
}

pub fn run(opts: &ExpOpts) -> anyhow::Result<Vec<Cell>> {
    let engine: Box<dyn AssignEngine + Send> = match opts.engine {
        crate::config::Engine::Native => {
            Box::new(crate::kmeans::assign::NativeEngine::default())
        }
        crate::config::Engine::Xla => crate::runtime::make_engine("artifacts")?,
    };
    let mut cells = Vec::new();
    for ds in [common::infmnist(opts.scale), common::rcv1(opts.scale)] {
        println!("== Table 2 on {} ==", ds.summary());
        let k = 50.min(ds.train.n() / 4).max(2);
        for b0 in b0_grid(opts.scale) {
            for (algo, rho) in
                [(Algo::Lloyd, Rho::Infinite), (Algo::TbRho, Rho::Infinite)]
            {
                let cfg = RunConfig {
                    algo,
                    rho,
                    k,
                    b0,
                    eval_every_secs: opts.seconds, // final eval only
                    ..Default::default()
                };
                let (mean, std) = run_cell(&ds, &cfg, opts, engine.as_ref())?;
                println!(
                    "   {:<8} b0={:<6} mean final MSE {:.6e} (±{:.1e})",
                    cfg.label(),
                    b0,
                    mean,
                    std
                );
                cells.push(Cell {
                    dataset: ds.name.clone(),
                    algo: cfg.label(),
                    b0,
                    mean_final: mean,
                    std_final: std,
                });
            }
        }
    }
    // normalise by the global best and write the paper-shaped table
    let v0 = cells
        .iter()
        .map(|c| c.mean_final)
        .fold(f64::INFINITY, f64::min);
    let mut t = Table::new(&[
        "dataset", "algo", "b0", "mean_final_mse", "std", "relative_to_v0",
    ]);
    for c in &cells {
        t.push(vec![
            c.dataset.clone(),
            c.algo.clone(),
            c.b0.to_string(),
            format!("{:.8e}", c.mean_final),
            format!("{:.3e}", c.std_final),
            format!("{:.4}", c.mean_final / v0),
        ]);
    }
    let path = results_dir().join("table2_quality.csv");
    t.write_csv(&path)?;
    println!("   wrote {}", path.display());
    check_shape(&cells);
    Ok(cells)
}

/// Paper shape: dense — tb-∞ ≈ lloyd for all b0; sparse — tb-∞ degrades
/// as b0 shrinks (monotone-ish in b0) while lloyd is flat.
pub fn check_shape(cells: &[Cell]) {
    let get = |ds: &str, algo: &str, b0: usize| {
        cells
            .iter()
            .find(|c| c.dataset == ds && c.algo.starts_with(algo) && c.b0 == b0)
            .map(|c| c.mean_final)
    };
    let b0s: Vec<usize> = {
        let mut v: Vec<usize> = cells.iter().map(|c| c.b0).collect();
        v.sort_unstable();
        v.dedup();
        v
    };
    if let (Some(&bmin), Some(&bmax)) = (b0s.first(), b0s.last()) {
        if let (Some(l), Some(t)) =
            (get("infmnist-sim", "lloyd", bmax), get("infmnist-sim", "tb", bmax))
        {
            let ok = t <= l * 1.15;
            println!(
                "   [shape dense] tb-∞ ≈ lloyd at large b0: {} ({t:.4e} vs {l:.4e})",
                if ok { "PASS" } else { "WARN" }
            );
        }
        if let (Some(t_small), Some(t_large)) =
            (get("rcv1-sim", "tb", bmin), get("rcv1-sim", "tb", bmax))
        {
            let ok = t_large <= t_small * 1.02;
            println!(
                "   [shape sparse] tb-∞ improves with b0: {} (b0={bmin}: {t_small:.4e}, b0={bmax}: {t_large:.4e})",
                if ok { "PASS" } else { "WARN" }
            );
        }
    }
}
