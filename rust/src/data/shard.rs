//! Disk-backed row shards: bounded-memory storage for bigger-than-RAM
//! ingestion.
//!
//! A [`ShardStore`] spills rows to a versioned on-disk shard file as
//! they arrive and reads them back on demand through a small pinned
//! LRU block cache, so a session's resident row payload is bounded by
//! `--max-resident-rows` instead of the dataset size. Row bytes round-
//! trip exactly through the [`serve::wire`](crate::serve::wire) row
//! codec (f32 little-endian, no re-quantisation), and squared row
//! norms stay resident and are accumulated at push time in the same
//! coordinate order as the in-RAM paths — which is what makes the
//! nested mini-batch schedule over a shard **bit-identical** to the
//! in-RAM run (property-tested in `tests/ooc_parity.rs`).
//!
//! File layout (all little-endian):
//!
//! ```text
//! header (16 B): magic "NMBKMSH1" | version u8 = 1 | kind u8 (1 dense, 2 sparse)
//!                | 2 reserved | dim u32
//! blocks:        [rows u32][bytes u32][payload]  (repeated)
//! ```
//!
//! Every sealed block holds exactly [`BLOCK_ROWS`] rows (so row → block
//! indexing is a division) and its payload is an
//! [`encode_rows`](crate::serve::wire::encode_rows) batch. The
//! still-filling tail block lives in RAM and is sealed — encoded,
//! appended with `write_all_at`, and retired into the cache — when it
//! fills. A torn tail from a crash mid-seal is rejected by
//! [`ShardStore::open`]; recovery recreates the spill from snapshot +
//! WAL, which is the durability story anyway (the shard file is a
//! cache of row payloads, not a system of record — it is deleted on
//! drop).

use crate::linalg::dense::DenseMatrix;
use crate::linalg::sparse::CsrMatrix;
use crate::serve::wire::{self, WireRow};
use anyhow::{bail, ensure, Context, Result};
use std::fs::{File, OpenOptions};
use std::io::Read;
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// Shard file magic ("NMBKM SHard v1").
pub const SHARD_MAGIC: &[u8; 8] = b"NMBKMSH1";
/// Fixed shard header length in bytes.
pub const SHARD_HEADER_LEN: usize = 16;
/// Rows per sealed block. Power of two so `i / BLOCK_ROWS` is a shift.
pub const BLOCK_ROWS: usize = 1024;
/// Per-block on-disk header: rows u32 | payload bytes u32.
const BLOCK_HEADER_LEN: usize = 8;
/// Minimum encoded size of one row: tag u8 + dim u32 + (one f32 value
/// for dense `dim ≥ 1`, or nnz u32 for sparse). Used as a plausibility
/// floor when validating declared block sizes before allocating.
const MIN_ROW_BYTES: usize = 9;

/// Row representation of a shard (mirrors `Storage` minus the shard
/// variant itself).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardKind {
    Dense,
    Sparse,
}

impl ShardKind {
    fn tag(self) -> u8 {
        match self {
            ShardKind::Dense => 1,
            ShardKind::Sparse => 2,
        }
    }

    fn from_tag(t: u8) -> Result<Self> {
        match t {
            1 => Ok(ShardKind::Dense),
            2 => Ok(ShardKind::Sparse),
            other => bail!("shard header: unknown kind tag {other}"),
        }
    }
}

/// A decoded block of consecutive rows, shared read-only via `Arc` so
/// a fetch hands back a zero-copy view into cached storage.
#[derive(Clone, Debug)]
pub enum BlockRows {
    Dense(DenseMatrix),
    Sparse(CsrMatrix),
}

impl BlockRows {
    fn empty(kind: ShardKind, dim: usize) -> Self {
        match kind {
            ShardKind::Dense => BlockRows::Dense(DenseMatrix::zeros(0, dim)),
            ShardKind::Sparse => BlockRows::Sparse(CsrMatrix::empty(dim)),
        }
    }

    pub fn rows(&self) -> usize {
        match self {
            BlockRows::Dense(m) => m.rows,
            BlockRows::Sparse(m) => m.rows,
        }
    }
}

/// Offset + payload size of a sealed block (always [`BLOCK_ROWS`] rows).
#[derive(Clone, Copy, Debug)]
struct BlockMeta {
    offset: u64,
    bytes: u32,
}

#[derive(Debug)]
struct Inner {
    file: File,
    append_at: u64,
    blocks: Vec<BlockMeta>,
    /// Still-filling tail (< BLOCK_ROWS rows). `Arc` so readers hold a
    /// stable view; appends go through `Arc::make_mut`, which clones
    /// only if a reader is currently borrowing the tail.
    tail: Arc<BlockRows>,
    rows: usize,
    /// LRU cache of decoded sealed blocks, most recently used last.
    cache: Vec<(usize, Arc<BlockRows>)>,
    /// High-water mark of `cache.len()`, for budget-boundedness tests.
    peak_cached: usize,
    /// Sealed-block reads served from disk (cache misses).
    disk_reads: u64,
    scratch: Vec<u8>,
}

/// A disk-backed row store. Interior-mutable behind a `Mutex` so an
/// `Arc<ShardStore>` can be shared between a `Data` view and the
/// session that keeps appending to it.
#[derive(Debug)]
pub struct ShardStore {
    path: PathBuf,
    kind: ShardKind,
    dim: usize,
    cache_cap: usize,
    inner: Mutex<Inner>,
}

impl ShardStore {
    /// Create (or truncate) a shard file. `max_resident_rows` is the
    /// pinned-block budget: the cache keeps at most
    /// `max(2, max_resident_rows / BLOCK_ROWS)` decoded blocks.
    pub fn create(
        path: &Path,
        kind: ShardKind,
        dim: usize,
        max_resident_rows: usize,
    ) -> Result<Self> {
        ensure!(dim >= 1, "shard dim must be >= 1");
        ensure!(dim <= u32::MAX as usize, "shard dim {dim} exceeds u32");
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)
            .with_context(|| format!("create shard {}", path.display()))?;
        let mut header = [0u8; SHARD_HEADER_LEN];
        header[..8].copy_from_slice(SHARD_MAGIC);
        header[8] = 1; // version
        header[9] = kind.tag();
        header[12..16].copy_from_slice(&(dim as u32).to_le_bytes());
        file.write_all_at(&header, 0)
            .with_context(|| format!("write shard header {}", path.display()))?;
        Ok(Self::from_parts(path, kind, dim, max_resident_rows, file, vec![]))
    }

    /// Open an existing shard file, validating the header and every
    /// block's declared geometry against the file length **before**
    /// allocating anything for it. A torn or hostile file errors out
    /// cleanly here rather than at first fetch.
    pub fn open(path: &Path, max_resident_rows: usize) -> Result<Self> {
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(path)
            .with_context(|| format!("open shard {}", path.display()))?;
        let len = file
            .metadata()
            .with_context(|| format!("stat shard {}", path.display()))?
            .len();
        ensure!(
            len >= SHARD_HEADER_LEN as u64,
            "shard {}: {len} bytes is shorter than the {SHARD_HEADER_LEN}-byte header",
            path.display()
        );
        let mut header = [0u8; SHARD_HEADER_LEN];
        file.read_exact(&mut header)
            .with_context(|| format!("read shard header {}", path.display()))?;
        ensure!(&header[..8] == SHARD_MAGIC, "shard {}: bad magic", path.display());
        ensure!(header[8] == 1, "shard {}: unknown version {}", path.display(), header[8]);
        let kind = ShardKind::from_tag(header[9])
            .with_context(|| format!("shard {}", path.display()))?;
        let dim = u32::from_le_bytes(header[12..16].try_into().unwrap()) as usize;
        ensure!(dim >= 1, "shard {}: dim 0", path.display());

        let mut blocks = Vec::new();
        let mut at = SHARD_HEADER_LEN as u64;
        while at < len {
            ensure!(
                len - at >= BLOCK_HEADER_LEN as u64,
                "shard {}: truncated block header at byte {at}",
                path.display()
            );
            let mut bh = [0u8; BLOCK_HEADER_LEN];
            file.read_exact_at(&mut bh, at)
                .with_context(|| format!("read block header {}", path.display()))?;
            let rows = u32::from_le_bytes(bh[..4].try_into().unwrap()) as usize;
            let bytes = u32::from_le_bytes(bh[4..].try_into().unwrap());
            ensure!(
                rows == BLOCK_ROWS,
                "shard {}: block at byte {at} declares {rows} rows (sealed blocks hold {BLOCK_ROWS})",
                path.display()
            );
            // Reject a declared payload that overflows the mapped
            // length or is too small to hold its row count, before any
            // allocation is sized from it.
            ensure!(
                bytes as u64 <= len - at - BLOCK_HEADER_LEN as u64,
                "shard {}: block at byte {at} declares {bytes} payload bytes past EOF",
                path.display()
            );
            ensure!(
                bytes as usize >= 4 + rows * MIN_ROW_BYTES,
                "shard {}: block at byte {at} declares {bytes} bytes for {rows} rows",
                path.display()
            );
            blocks.push(BlockMeta { offset: at + BLOCK_HEADER_LEN as u64, bytes });
            at += BLOCK_HEADER_LEN as u64 + bytes as u64;
        }
        Ok(Self::from_parts(path, kind, dim, max_resident_rows, file, blocks))
    }

    fn from_parts(
        path: &Path,
        kind: ShardKind,
        dim: usize,
        max_resident_rows: usize,
        file: File,
        blocks: Vec<BlockMeta>,
    ) -> Self {
        let append_at = blocks
            .last()
            .map(|b| b.offset + b.bytes as u64)
            .unwrap_or(SHARD_HEADER_LEN as u64);
        let rows = blocks.len() * BLOCK_ROWS;
        Self {
            path: path.to_path_buf(),
            kind,
            dim,
            cache_cap: (max_resident_rows / BLOCK_ROWS).max(2),
            inner: Mutex::new(Inner {
                file,
                append_at,
                blocks,
                tail: Arc::new(BlockRows::empty(kind, dim)),
                rows,
                cache: Vec::new(),
                peak_cached: 0,
                disk_reads: 0,
                scratch: Vec::new(),
            }),
        }
    }

    pub fn kind(&self) -> ShardKind {
        self.kind
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn rows(&self) -> usize {
        self.inner.lock().unwrap().rows
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Pinned-block budget: max decoded sealed blocks kept resident.
    pub fn cache_cap(&self) -> usize {
        self.cache_cap
    }

    /// High-water mark of resident decoded blocks (cache + nothing
    /// else; the tail is extra but bounded by one block).
    pub fn peak_cached_blocks(&self) -> usize {
        self.inner.lock().unwrap().peak_cached
    }

    /// Sealed-block fetches that had to hit the disk.
    pub fn disk_reads(&self) -> u64 {
        self.inner.lock().unwrap().disk_reads
    }

    /// Append one dense row. IO errors surface here (disk full), so
    /// callers can fail the ingest instead of corrupting state later.
    pub fn push_dense(&self, r: &[f32]) -> Result<()> {
        assert_eq!(self.kind, ShardKind::Dense, "dense push into sparse shard");
        assert_eq!(r.len(), self.dim);
        let mut g = self.inner.lock().unwrap();
        match Arc::make_mut(&mut g.tail) {
            BlockRows::Dense(m) => {
                m.data.extend_from_slice(r);
                m.rows += 1;
            }
            BlockRows::Sparse(_) => unreachable!(),
        }
        g.rows += 1;
        self.seal_if_full(&mut g)
    }

    /// Append one sparse row (columns strictly ascending, as the wire
    /// validation layer guarantees).
    pub fn push_sparse(&self, idx: &[u32], vals: &[f32]) -> Result<()> {
        assert_eq!(self.kind, ShardKind::Sparse, "sparse push into dense shard");
        let mut g = self.inner.lock().unwrap();
        match Arc::make_mut(&mut g.tail) {
            BlockRows::Sparse(m) => m.push_row_parts(idx, vals),
            BlockRows::Dense(_) => unreachable!(),
        }
        g.rows += 1;
        self.seal_if_full(&mut g)
    }

    fn seal_if_full(&self, g: &mut Inner) -> Result<()> {
        if g.tail.rows() < BLOCK_ROWS {
            return Ok(());
        }
        let mut payload = std::mem::take(&mut g.scratch);
        payload.clear();
        payload.extend_from_slice(&(BLOCK_ROWS as u32).to_le_bytes());
        match &*g.tail {
            BlockRows::Dense(m) => {
                for i in 0..m.rows {
                    wire::encode_dense_row_into(&mut payload, m.row(i));
                }
            }
            BlockRows::Sparse(m) => {
                for i in 0..m.rows {
                    let (idx, vals) = m.row(i);
                    wire::encode_sparse_row_into(&mut payload, self.dim, idx, vals);
                }
            }
        }
        ensure!(
            payload.len() <= u32::MAX as usize,
            "shard block payload {} bytes exceeds u32",
            payload.len()
        );
        let mut framed = Vec::with_capacity(BLOCK_HEADER_LEN + payload.len());
        framed.extend_from_slice(&(BLOCK_ROWS as u32).to_le_bytes());
        framed.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        framed.extend_from_slice(&payload);
        g.file
            .write_all_at(&framed, g.append_at)
            .with_context(|| format!("append shard block {}", self.path.display()))?;
        let id = g.blocks.len();
        g.blocks.push(BlockMeta {
            offset: g.append_at + BLOCK_HEADER_LEN as u64,
            bytes: payload.len() as u32,
        });
        g.append_at += framed.len() as u64;
        g.scratch = payload;
        // Retire the sealed tail into the cache still decoded — the
        // freshest rows are exactly what the next nested mini-batch
        // reads, so this keeps the hot path warm at zero decode cost.
        let sealed = std::mem::replace(
            &mut g.tail,
            Arc::new(BlockRows::empty(self.kind, self.dim)),
        );
        self.cache_insert(g, id, sealed);
        Ok(())
    }

    fn cache_insert(&self, g: &mut Inner, id: usize, block: Arc<BlockRows>) {
        g.cache.push((id, block));
        while g.cache.len() > self.cache_cap {
            g.cache.remove(0);
        }
        g.peak_cached = g.peak_cached.max(g.cache.len());
    }

    /// Fetch the block holding row `i` plus the row's index within it.
    /// Panics on IO/decode errors: by the time rows are being read the
    /// file was validated at create/open, so a failure here is an
    /// operational fault (disk yanked), not an input error.
    pub fn fetch(&self, i: usize) -> (Arc<BlockRows>, usize) {
        let mut g = self.inner.lock().unwrap();
        assert!(i < g.rows, "row {i} out of range ({} rows)", g.rows);
        let sealed_rows = g.blocks.len() * BLOCK_ROWS;
        if i >= sealed_rows {
            return (g.tail.clone(), i - sealed_rows);
        }
        let id = i / BLOCK_ROWS;
        if let Some(pos) = g.cache.iter().position(|(b, _)| *b == id) {
            let entry = g.cache.remove(pos);
            let arc = entry.1.clone();
            g.cache.push(entry);
            return (arc, i % BLOCK_ROWS);
        }
        let block = Arc::new(
            self.read_block(&mut g, id)
                .with_context(|| format!("shard {} block {id}", self.path.display()))
                .expect("shard block read failed"),
        );
        g.disk_reads += 1;
        self.cache_insert(&mut g, id, block.clone());
        (block, i % BLOCK_ROWS)
    }

    fn read_block(&self, g: &mut Inner, id: usize) -> Result<BlockRows> {
        let meta = g.blocks[id];
        let mut payload = vec![0u8; meta.bytes as usize];
        g.file.read_exact_at(&mut payload, meta.offset)?;
        let rows = wire::decode_rows(&payload)?;
        ensure!(rows.len() == BLOCK_ROWS, "block decoded {} rows", rows.len());
        match self.kind {
            ShardKind::Dense => {
                let mut data = Vec::with_capacity(BLOCK_ROWS * self.dim);
                for row in &rows {
                    match row {
                        WireRow::Dense(r) if r.len() == self.dim => {
                            data.extend_from_slice(r)
                        }
                        WireRow::Dense(r) => {
                            bail!("dense row dim {} != shard dim {}", r.len(), self.dim)
                        }
                        WireRow::Sparse { .. } => bail!("sparse row in dense shard"),
                    }
                }
                Ok(BlockRows::Dense(DenseMatrix::from_vec(BLOCK_ROWS, self.dim, data)))
            }
            ShardKind::Sparse => {
                let mut m = CsrMatrix::empty(self.dim);
                for row in &rows {
                    match row {
                        WireRow::Sparse { dim, idx, vals } if *dim == self.dim => {
                            m.push_row_parts(idx, vals)
                        }
                        WireRow::Sparse { dim, .. } => {
                            bail!("sparse row dim {dim} != shard dim {}", self.dim)
                        }
                        WireRow::Dense(_) => bail!("dense row in sparse shard"),
                    }
                }
                Ok(BlockRows::Sparse(m))
            }
        }
    }
}

impl Drop for ShardStore {
    fn drop(&mut self) {
        // The shard is a spill cache, not a system of record; reclaim
        // the disk when the last owner goes away.
        let _ = std::fs::remove_file(&self.path);
    }
}

/// A fixed-length view of a [`ShardStore`] — `Data`'s shard storage
/// variant. The row count is frozen at clone time so snapshots and
/// engine borrows don't observe rows appended after them, mirroring
/// the value semantics of the in-RAM storages.
#[derive(Clone, Debug)]
pub struct ShardData {
    store: Arc<ShardStore>,
    rows: usize,
}

impl ShardData {
    pub fn new(store: Arc<ShardStore>) -> Self {
        let rows = store.rows();
        Self { store, rows }
    }

    pub fn store(&self) -> &Arc<ShardStore> {
        &self.store
    }

    pub fn n(&self) -> usize {
        self.rows
    }

    pub fn dim(&self) -> usize {
        self.store.dim()
    }

    pub fn is_sparse(&self) -> bool {
        self.store.kind() == ShardKind::Sparse
    }

    /// Fetch the block holding row `i` (must be within this view).
    #[inline]
    pub fn fetch(&self, i: usize) -> (Arc<BlockRows>, usize) {
        assert!(i < self.rows, "row {i} out of shard view ({} rows)", self.rows);
        self.store.fetch(i)
    }

    /// Append a dense row and grow this view to include it. Only the
    /// up-to-date view (the ingesting session's) may append.
    pub fn push_dense(&mut self, r: &[f32]) -> Result<()> {
        assert_eq!(self.rows, self.store.rows(), "stale shard view cannot append");
        self.store.push_dense(r)?;
        self.rows += 1;
        Ok(())
    }

    /// Append a sparse row and grow this view to include it.
    pub fn push_sparse(&mut self, idx: &[u32], vals: &[f32]) -> Result<()> {
        assert_eq!(self.rows, self.store.rows(), "stale shard view cannot append");
        self.store.push_sparse(idx, vals)?;
        self.rows += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("nmbkm-shard-{}-{name}", std::process::id()));
        let _ = std::fs::remove_file(&p);
        p
    }

    fn dense_row(i: usize, dim: usize) -> Vec<f32> {
        (0..dim).map(|c| (i * dim + c) as f32 * 0.25 - 3.0).collect()
    }

    #[test]
    fn dense_rows_round_trip_across_blocks() {
        let path = tmp("dense");
        let dim = 7;
        let n = 3 * BLOCK_ROWS + 17;
        {
            let store = ShardStore::create(&path, ShardKind::Dense, dim, 2 * BLOCK_ROWS).unwrap();
            for i in 0..n {
                store.push_dense(&dense_row(i, dim)).unwrap();
            }
            assert_eq!(store.rows(), n);
            for &i in &[0, 1, BLOCK_ROWS - 1, BLOCK_ROWS, 2 * BLOCK_ROWS + 5, n - 1] {
                let (blk, r) = store.fetch(i);
                match &*blk {
                    BlockRows::Dense(m) => assert_eq!(m.row(r), &dense_row(i, dim)[..]),
                    _ => panic!("dense shard returned sparse block"),
                }
            }
            // Cache stays within the pinned budget even after touching
            // every sealed block.
            for i in 0..n {
                store.fetch(i);
            }
            assert!(store.peak_cached_blocks() <= store.cache_cap());
            assert_eq!(store.cache_cap(), 2);
        }
        assert!(!path.exists(), "shard file must be removed on drop");
    }

    #[test]
    fn sparse_rows_round_trip_and_reopen() {
        let path = tmp("sparse");
        let dim = 40;
        let n = 2 * BLOCK_ROWS + 3;
        let row = |i: usize| -> (Vec<u32>, Vec<f32>) {
            // Two strictly ascending columns per row.
            let idx = vec![(i % (dim - 1)) as u32, (dim - 1) as u32];
            let vals = vec![i as f32 + 0.5, -(i as f32) * 0.125];
            (idx, vals)
        };
        {
            let store = ShardStore::create(&path, ShardKind::Sparse, dim, BLOCK_ROWS).unwrap();
            for i in 0..n {
                let (idx, vals) = row(i);
                store.push_sparse(&idx, &vals).unwrap();
            }
            for &i in &[0, BLOCK_ROWS, 2 * BLOCK_ROWS, n - 1] {
                let (blk, r) = store.fetch(i);
                let (idx, vals) = row(i);
                match &*blk {
                    BlockRows::Sparse(m) => {
                        assert_eq!(m.row(r), (&idx[..], &vals[..]));
                    }
                    _ => panic!("sparse shard returned dense block"),
                }
            }
            // Keep the file for reopen: forget the store so Drop does
            // not unlink it.
            std::mem::forget(store);
        }
        {
            let store = ShardStore::open(&path, BLOCK_ROWS).unwrap();
            // Tail rows were never sealed: only full blocks survive.
            assert_eq!(store.rows(), 2 * BLOCK_ROWS);
            for &i in &[0, BLOCK_ROWS + 1, 2 * BLOCK_ROWS - 1] {
                let (blk, r) = store.fetch(i);
                let (idx, vals) = row(i);
                match &*blk {
                    BlockRows::Sparse(m) => assert_eq!(m.row(r), (&idx[..], &vals[..])),
                    _ => unreachable!(),
                }
            }
        }
        assert!(!path.exists());
    }

    #[test]
    fn stale_view_cannot_append_but_still_reads() {
        let path = tmp("view");
        let store = Arc::new(ShardStore::create(&path, ShardKind::Dense, 3, 4096).unwrap());
        let mut live = ShardData::new(store.clone());
        live.push_dense(&[1.0, 2.0, 3.0]).unwrap();
        let frozen = live.clone();
        live.push_dense(&[4.0, 5.0, 6.0]).unwrap();
        assert_eq!(frozen.n(), 1);
        assert_eq!(live.n(), 2);
        let (blk, r) = frozen.fetch(0);
        match &*blk {
            BlockRows::Dense(m) => assert_eq!(m.row(r), &[1.0, 2.0, 3.0]),
            _ => unreachable!(),
        }
    }

    #[test]
    fn open_rejects_hostile_files() {
        let dim = 4;
        // Build a small valid shard (1 sealed block) to mutate.
        let path = tmp("hostile-base");
        let store = ShardStore::create(&path, ShardKind::Dense, dim, 4096).unwrap();
        for i in 0..BLOCK_ROWS {
            store.push_dense(&dense_row(i, dim)).unwrap();
        }
        let good = std::fs::read(&path).unwrap();
        drop(store);
        assert!(ShardStore::open(&path, 4096).is_err(), "file is gone after drop");

        let write_variant = |name: &str, bytes: &[u8]| -> anyhow::Error {
            let p = tmp(name);
            std::fs::write(&p, bytes).unwrap();
            let err = ShardStore::open(&p, 4096).expect_err("hostile shard must not open");
            let _ = std::fs::remove_file(&p);
            err
        };

        // Truncated header.
        write_variant("h-short", &good[..10]);
        // Bad magic.
        let mut b = good.clone();
        b[0] ^= 0xff;
        write_variant("h-magic", &b);
        // Unknown version.
        let mut b = good.clone();
        b[8] = 9;
        write_variant("h-version", &b);
        // Unknown kind tag.
        let mut b = good.clone();
        b[9] = 7;
        write_variant("h-kind", &b);
        // Block payload length pointing past EOF: must be rejected
        // before sizing any allocation from it.
        let mut b = good.clone();
        let at = SHARD_HEADER_LEN;
        b[at + 4..at + 8].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = write_variant("h-overflow", &b);
        assert!(format!("{err:#}").contains("past EOF"), "got: {err:#}");
        // Implausibly small payload for the declared row count.
        let mut b = good.clone();
        b[at + 4..at + 8].copy_from_slice(&8u32.to_le_bytes());
        write_variant("h-small", &b);
        // Row count that is not a full block.
        let mut b = good.clone();
        b[at..at + 4].copy_from_slice(&3u32.to_le_bytes());
        write_variant("h-rows", &b);
        // Torn trailing block header.
        let mut b = good.clone();
        b.extend_from_slice(&[1, 2, 3]);
        write_variant("h-torn", &b);
    }

    #[test]
    fn corrupt_block_payload_fails_decode() {
        let path = tmp("corrupt-payload");
        let store = ShardStore::create(&path, ShardKind::Dense, 2, 4096).unwrap();
        for i in 0..BLOCK_ROWS {
            store.push_dense(&dense_row(i, 2)).unwrap();
        }
        let mut bytes = std::fs::read(&path).unwrap();
        drop(store);
        // Corrupt a row tag inside the payload (first row's tag byte).
        let tag_at = SHARD_HEADER_LEN + BLOCK_HEADER_LEN + 4;
        bytes[tag_at] = 9;
        std::fs::write(&path, &bytes).unwrap();
        let store = ShardStore::open(&path, 4096).unwrap();
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| store.fetch(0)));
        assert!(res.is_err(), "corrupt payload must fail the fetch");
        drop(store);
    }
}
