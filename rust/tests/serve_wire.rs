//! Wire-protocol overhaul acceptance: predicts routed through the
//! sparse point encoding, the batched predict path, and the binary
//! framing are **bit-identical** to the dense JSONL path at the same
//! published round, and per-connection format negotiation keeps JSONL
//! clients working.

use nmbkm::config::{Algo, Rho, RunConfig};
use nmbkm::data::{Data, Storage};
use nmbkm::serve::wire::{dense_points_json, sparse_points_json};
use nmbkm::serve::{frame, protocol, session, ModelRegistry, WireRow};
use nmbkm::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

fn cfg(algo: Algo, k: usize, b0: usize, rounds: usize) -> RunConfig {
    RunConfig {
        algo,
        k,
        b0,
        rho: Rho::Infinite,
        threads: 2,
        seed: 19,
        max_rounds: rounds,
        max_seconds: 60.0,
        eval_every_secs: 0.0,
        ..Default::default()
    }
}

fn sparse_corpus(n: usize, seed: u64) -> Data {
    nmbkm::data::rcv1::Rcv1Sim {
        vocab: 400,
        topic_vocab: 50,
        ..Default::default()
    }
    .generate(n, seed)
}

fn dense_rows(data: &Data, lo: usize, hi: usize) -> Vec<Vec<f32>> {
    let mut out = Vec::with_capacity(hi - lo);
    let mut row = vec![0f32; data.dim()];
    for i in lo..hi {
        data.write_row_dense(i, &mut row);
        out.push(row.clone());
    }
    out
}

fn sparse_rows(data: &Data, lo: usize, hi: usize) -> Vec<(Vec<u32>, Vec<f32>)> {
    let Storage::Sparse(m) = &data.storage else {
        panic!("corpus must be sparse");
    };
    (lo..hi)
        .map(|i| {
            let (idx, vals) = m.row(i);
            (idx.to_vec(), vals.to_vec())
        })
        .collect()
}

/// Serve one request line and return the raw response line.
fn serve_one(reg: &ModelRegistry, req: &str) -> String {
    let mut out = Vec::new();
    protocol::serve_lines(
        reg,
        std::io::Cursor::new(format!("{req}\n")),
        &mut out,
    )
    .unwrap();
    String::from_utf8(out).unwrap().trim().to_string()
}

fn fingerprint(resp: &Json) -> (Vec<u32>, Vec<u32>) {
    let labels = resp
        .get("labels")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as u32)
        .collect();
    let d2 = resp
        .get("d2")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| (x.as_f64().unwrap() as f32).to_bits())
        .collect();
    (labels, d2)
}

#[test]
fn sparse_encoding_bit_matches_dense_jsonl_on_sparse_model() {
    let data = sparse_corpus(500, 7);
    let (s, _) = session::train(&data, &cfg(Algo::GbRho, 8, 128, 5)).unwrap();
    let reg = ModelRegistry::with_default(s);
    let dense = dense_rows(&data, 20, 32);
    let sparse = sparse_rows(&data, 20, 32);
    let a = serve_one(
        &reg,
        &format!(
            "{{\"op\":\"predict\",\"points\":{}}}",
            dense_points_json(&dense)
        ),
    );
    let b = serve_one(
        &reg,
        &format!(
            "{{\"op\":\"predict\",\"points\":{}}}",
            sparse_points_json(data.dim(), &sparse)
        ),
    );
    assert!(a.contains("\"ok\":true"), "{a}");
    // the whole response line is byte-identical — same labels, same d2
    // bits, same field layout — whichever encoding carried the queries
    assert_eq!(a, b);
}

#[test]
fn sparse_encoding_bit_matches_dense_jsonl_on_dense_model() {
    // a dense model scatters sparse-encoded queries into dense rows;
    // the answer must still match the dense encoding exactly
    let data = nmbkm::data::gaussian::GaussianMixture::default_spec(4, 6)
        .generate(400, 3);
    let (s, _) = session::train(&data, &cfg(Algo::TbRho, 4, 64, 5)).unwrap();
    let reg = ModelRegistry::with_default(s);
    let dense = dense_rows(&data, 0, 10);
    let sparse_enc: Vec<(Vec<u32>, Vec<f32>)> = dense
        .iter()
        .map(|r| {
            let mut idx = Vec::new();
            let mut vals = Vec::new();
            for (c, &x) in r.iter().enumerate() {
                if x != 0.0 {
                    idx.push(c as u32);
                    vals.push(x);
                }
            }
            (idx, vals)
        })
        .collect();
    let a = serve_one(
        &reg,
        &format!(
            "{{\"op\":\"predict\",\"points\":{}}}",
            dense_points_json(&dense)
        ),
    );
    let b = serve_one(
        &reg,
        &format!(
            "{{\"op\":\"predict\",\"points\":{}}}",
            sparse_points_json(data.dim(), &sparse_enc)
        ),
    );
    assert!(a.contains("\"ok\":true"), "{a}");
    assert_eq!(a, b);
}

#[test]
fn batched_predict_bit_matches_per_point_requests() {
    let data = sparse_corpus(600, 9);
    let (s, _) = session::train(&data, &cfg(Algo::TbRho, 10, 128, 5)).unwrap();
    let reg = ModelRegistry::with_default(s);
    let sparse = sparse_rows(&data, 0, 64);

    // one batch-64 request: the registry splits it across the shard
    // pool (64 > PREDICT_JOB_ROWS), one published-Arc clone per job
    let batched = Json::parse(&serve_one(
        &reg,
        &format!(
            "{{\"op\":\"predict\",\"points\":{}}}",
            sparse_points_json(data.dim(), &sparse)
        ),
    ))
    .unwrap();
    assert_eq!(batched.get("ok").unwrap().as_bool(), Some(true));
    let (blbl, bd2) = fingerprint(&batched);
    assert_eq!(blbl.len(), 64);

    // 64 single-point requests against the same published round
    let mut lbl = Vec::new();
    let mut d2 = Vec::new();
    for row in &sparse {
        let resp = Json::parse(&serve_one(
            &reg,
            &format!(
                "{{\"op\":\"predict\",\"points\":{}}}",
                sparse_points_json(data.dim(), std::slice::from_ref(row))
            ),
        ))
        .unwrap();
        let (l, d) = fingerprint(&resp);
        lbl.extend(l);
        d2.extend(d);
    }
    assert_eq!(blbl, lbl, "batch split changed labels");
    assert_eq!(bd2, d2, "batch split changed d2 bits");

    // and the registry-level wire path agrees with the classic dense
    // Vec path bit-for-bit
    let entry = reg.resolve(None).unwrap();
    let wire: Vec<WireRow> = sparse
        .iter()
        .map(|(idx, vals)| {
            nmbkm::serve::wire::sparse_row(
                data.dim(),
                idx.clone(),
                vals.clone(),
            )
            .unwrap()
        })
        .collect();
    let (wl, wd) = entry.predict_wire(&wire).unwrap();
    let (cl, cd) = entry.predict(&dense_rows(&data, 0, 64)).unwrap();
    assert_eq!(wl, cl);
    assert_eq!(
        wd.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        cd.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
    );
}

fn connect(addr: std::net::SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let conn = TcpStream::connect(addr).unwrap();
    let reader = BufReader::new(conn.try_clone().unwrap());
    (conn, reader)
}

fn roundtrip(
    conn: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    req: &str,
) -> Json {
    conn.write_all(req.as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    Json::parse(line.trim()).unwrap()
}

#[test]
fn binary_frames_bit_match_jsonl_over_tcp() {
    let listener = match TcpListener::bind("127.0.0.1:0") {
        Ok(l) => l,
        Err(_) => {
            eprintln!("skipping: cannot bind loopback");
            return;
        }
    };
    let addr = listener.local_addr().unwrap();
    let data = sparse_corpus(500, 13);
    let (s, _) = session::train(&data, &cfg(Algo::GbRho, 8, 128, 4)).unwrap();
    let reg = Arc::new(ModelRegistry::with_default(s));
    let server = std::thread::spawn(move || {
        nmbkm::serve::server::serve_listener_opts(reg, listener, true).unwrap();
    });

    let dense = dense_rows(&data, 40, 52);
    let sparse = sparse_rows(&data, 40, 52);

    // JSONL reference on one connection
    let (mut jconn, mut jreader) = connect(addr);
    let jresp = roundtrip(
        &mut jconn,
        &mut jreader,
        &format!(
            "{{\"op\":\"predict\",\"points\":{}}}",
            dense_points_json(&dense)
        ),
    );
    assert_eq!(jresp.get("ok").unwrap().as_bool(), Some(true), "{jresp:?}");
    let (jlbl, jd2) = fingerprint(&jresp);

    // binary twin on a second connection of the same port: magic byte,
    // then a sparse-encoded predict frame
    let mut bconn = TcpStream::connect(addr).unwrap();
    bconn.write_all(&[frame::MAGIC]).unwrap();
    let mut breader = BufReader::new(bconn.try_clone().unwrap());
    let body = frame::encode_sparse_points(data.dim(), &sparse).unwrap();
    frame::write_frame(
        &mut bconn,
        &Json::parse(r#"{"op":"predict"}"#).unwrap(),
        &body,
    )
    .unwrap();
    let (header, rbody) = frame::read_frame(&mut breader).unwrap().unwrap();
    assert_eq!(header.get("ok").unwrap().as_bool(), Some(true), "{header:?}");
    assert_eq!(header.get("n").unwrap().as_usize(), Some(12));
    let (blbl, bd2) = frame::decode_predict_body(&rbody).unwrap();
    assert_eq!(blbl, jlbl, "binary labels diverged from JSONL");
    assert_eq!(
        bd2.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        jd2,
        "binary d2 bits diverged from JSONL"
    );

    // non-predict ops work over binary frames too: create + ingest a
    // dense-point block + stats on a second model
    frame::write_frame(
        &mut bconn,
        &Json::parse(r#"{"op":"create","model":"tiny","k":2,"dim":3,"algo":"gb","b0":16,"seed":4}"#)
            .unwrap(),
        &[],
    )
    .unwrap();
    let (h, b) = frame::read_frame(&mut breader).unwrap().unwrap();
    assert_eq!(h.get("ok").unwrap().as_bool(), Some(true), "{h:?}");
    assert!(b.is_empty(), "non-predict responses are header-only");
    let pts: Vec<Vec<f32>> =
        (0..20).map(|i| vec![i as f32, 1.0, 0.5 * i as f32]).collect();
    frame::write_frame(
        &mut bconn,
        &Json::parse(r#"{"op":"ingest","model":"tiny","rounds":1}"#).unwrap(),
        &frame::encode_dense_points(3, &pts).unwrap(),
    )
    .unwrap();
    let (h, _) = frame::read_frame(&mut breader).unwrap().unwrap();
    assert_eq!(h.get("ok").unwrap().as_bool(), Some(true), "{h:?}");
    assert_eq!(h.get("n").unwrap().as_usize(), Some(20));
    // a malformed frame body is an error response, not a dead stream
    frame::write_frame(
        &mut bconn,
        &Json::parse(r#"{"op":"predict","model":"tiny"}"#).unwrap(),
        &[9, 9, 9],
    )
    .unwrap();
    let (h, _) = frame::read_frame(&mut breader).unwrap().unwrap();
    assert_eq!(h.get("ok").unwrap().as_bool(), Some(false));
    frame::write_frame(
        &mut bconn,
        &Json::parse(r#"{"op":"stats","model":"tiny"}"#).unwrap(),
        &[],
    )
    .unwrap();
    let (h, _) = frame::read_frame(&mut breader).unwrap().unwrap();
    assert_eq!(h.get("ok").unwrap().as_bool(), Some(true), "{h:?}");
    assert_eq!(h.get("n_total").unwrap().as_usize(), Some(20));

    // shutdown from the binary connection stops the whole server
    frame::write_frame(
        &mut bconn,
        &Json::parse(r#"{"op":"shutdown"}"#).unwrap(),
        &[],
    )
    .unwrap();
    let (h, _) = frame::read_frame(&mut breader).unwrap().unwrap();
    assert_eq!(h.get("op").unwrap().as_str(), Some("shutdown"));
    server.join().expect("server exits after binary shutdown");
}

#[test]
fn magic_byte_refused_when_binary_disabled() {
    let listener = match TcpListener::bind("127.0.0.1:0") {
        Ok(l) => l,
        Err(_) => {
            eprintln!("skipping: cannot bind loopback");
            return;
        }
    };
    let addr = listener.local_addr().unwrap();
    let data = nmbkm::data::gaussian::GaussianMixture::default_spec(3, 4)
        .generate(200, 2);
    let (s, _) = session::train(&data, &cfg(Algo::GbRho, 3, 32, 3)).unwrap();
    let reg = Arc::new(ModelRegistry::with_default(s));
    let server = std::thread::spawn(move || {
        // default accept loop: binary framing off
        nmbkm::serve::server::serve_listener(reg, listener).unwrap();
    });

    // a binary client gets a JSONL error and is never served frames
    let mut bconn = TcpStream::connect(addr).unwrap();
    bconn.write_all(&[frame::MAGIC]).unwrap();
    let mut breader = BufReader::new(bconn.try_clone().unwrap());
    let mut line = String::new();
    breader.read_line(&mut line).unwrap();
    let resp = Json::parse(line.trim()).unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
    assert!(
        resp.get("error").unwrap().as_str().unwrap().contains("--binary"),
        "{resp:?}"
    );

    // JSONL clients are untouched
    let (mut conn, mut reader) = connect(addr);
    let resp = roundtrip(&mut conn, &mut reader, r#"{"op":"stats"}"#);
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
    roundtrip(&mut conn, &mut reader, r#"{"op":"shutdown"}"#);
    server.join().unwrap();
    // after server exit the refused connection reads EOF, not frames
    line.clear();
    assert_eq!(breader.read_line(&mut line).unwrap(), 0, "connection closed");
}

#[test]
fn sparse_ingest_bit_matches_dense_ingest() {
    // two twin sessions fed the same logical rows through the two
    // encodings must end up with bit-identical buffers and models
    let data = sparse_corpus(400, 21);
    let c = cfg(Algo::TbRho, 6, 64, 4);
    let (mut a, _) = session::train(&data.slice(0, 300), &c).unwrap();
    let (mut b, _) = session::train(&data.slice(0, 300), &c).unwrap();

    let dense = dense_rows(&data, 300, 360);
    a.ingest_rows(&dense).unwrap();
    let wire: Vec<WireRow> = sparse_rows(&data, 300, 360)
        .into_iter()
        .map(|(idx, vals)| {
            nmbkm::serve::wire::sparse_row(data.dim(), idx, vals).unwrap()
        })
        .collect();
    b.ingest_wire(&wire).unwrap();

    assert_eq!(a.data().n(), b.data().n());
    let na: Vec<u32> = a.data().norms.iter().map(|x| x.to_bits()).collect();
    let nb: Vec<u32> = b.data().norms.iter().map(|x| x.to_bits()).collect();
    assert_eq!(na, nb, "ingest norms diverged between encodings");
    // train both over the grown buffer: identical trajectories
    a.step(4, 1e9).unwrap();
    b.step(4, 1e9).unwrap();
    let ca = a.centroids().unwrap();
    let cb = b.centroids().unwrap();
    assert_eq!(
        ca.c.data.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        cb.c.data.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        "training diverged after mixed-encoding ingest"
    );
}
