//! Concurrency tests for multi-model serving: predicts issued from
//! concurrent TCP connections while a session trains must be
//! bit-identical to sequential serving (snapshot isolation), the
//! registry must route create/ingest/predict/drop by model name over
//! real sockets, and concurrently training sparse sessions must keep
//! their own transpose caches.

use nmbkm::config::{Algo, Rho, RunConfig};
use nmbkm::data::Data;
use nmbkm::data::gaussian::GaussianMixture;
use nmbkm::serve::{session, ModelRegistry};
use nmbkm::util::json::Json;
use std::collections::HashSet;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

fn cfg(algo: Algo, k: usize, b0: usize) -> RunConfig {
    RunConfig {
        algo,
        k,
        b0,
        rho: Rho::Infinite,
        threads: 2,
        seed: 23,
        max_rounds: usize::MAX,
        max_seconds: f64::INFINITY,
        eval_every_secs: 0.0,
        ..Default::default()
    }
}

fn rows_of(data: &Data, lo: usize, hi: usize) -> Vec<Vec<f32>> {
    let mut out = Vec::with_capacity(hi - lo);
    let mut row = vec![0f32; data.dim()];
    for i in lo..hi {
        data.write_row_dense(i, &mut row);
        out.push(row.clone());
    }
    out
}

fn points_json(rows: &[Vec<f32>]) -> String {
    let coords: Vec<String> = rows
        .iter()
        .map(|q| {
            let xs: Vec<String> = q.iter().map(|x| format!("{x}")).collect();
            format!("[{}]", xs.join(","))
        })
        .collect();
    format!("[{}]", coords.join(","))
}

/// One request/response exchange on an open connection.
fn roundtrip(
    conn: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    req: &str,
) -> Json {
    conn.write_all(req.as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    Json::parse(line.trim()).unwrap()
}

fn connect(addr: std::net::SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let conn = TcpStream::connect(addr).unwrap();
    let reader = BufReader::new(conn.try_clone().unwrap());
    (conn, reader)
}

/// Bit-pattern fingerprint of one predict answer.
fn fingerprint(resp: &Json) -> (Vec<u32>, Vec<u32>) {
    let labels: Vec<u32> = resp
        .get("labels")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as u32)
        .collect();
    // f32 → f64 JSON number → f32 is lossless, so these are the exact
    // bits the serving engine produced
    let d2: Vec<u32> = resp
        .get("d2")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| (x.as_f64().unwrap() as f32).to_bits())
        .collect();
    (labels, d2)
}

/// The acceptance-criteria test: ≥4 concurrent TCP connections hammer
/// predicts while the session trains round by round. Every concurrent
/// answer must be bit-identical to the *sequential* answer at some
/// round boundary — snapshot isolation means a predict sees exactly a
/// completed round's model, never a blend.
#[test]
fn concurrent_predicts_bit_match_sequential_serving() {
    const ROUNDS: usize = 8;
    const CONNS: usize = 4;
    let data = GaussianMixture::default_spec(5, 6).generate(1500, 3);
    let queries = rows_of(&data, 100, 130);

    // sequential reference: same config, same data ⇒ deterministic
    // trajectory; collect the predict answer at every round boundary
    let mut reference = HashSet::new();
    let mut final_ref = None;
    {
        let mut s =
            session::OnlineSession::from_data(data.clone(), cfg(Algo::TbRho, 5, 128))
                .unwrap();
        for r in 0..=ROUNDS {
            let (lbl, d2) = s.predict_rows(&queries).unwrap();
            let fp = (
                lbl,
                d2.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            );
            if r == ROUNDS {
                final_ref = Some(fp.clone());
            }
            reference.insert(fp);
            if r < ROUNDS {
                s.step(1, f64::INFINITY).unwrap();
            }
        }
    }

    // served twin: identical construction, driven over TCP
    let listener = match TcpListener::bind("127.0.0.1:0") {
        Ok(l) => l,
        Err(_) => {
            eprintln!("skipping: cannot bind loopback");
            return;
        }
    };
    let addr = listener.local_addr().unwrap();
    let served =
        session::OnlineSession::from_data(data.clone(), cfg(Algo::TbRho, 5, 128))
            .unwrap();
    let reg = Arc::new(ModelRegistry::with_default(served));
    let server = std::thread::spawn(move || {
        nmbkm::serve::server::serve_listener(reg, listener).unwrap();
    });

    let predict_req = format!(
        "{{\"op\":\"predict\",\"points\":{}}}",
        points_json(&queries)
    );
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let mut clients = Vec::new();
    for c in 0..CONNS {
        let req = predict_req.clone();
        let stop = stop.clone();
        clients.push(std::thread::spawn(move || {
            let (mut conn, mut reader) = connect(addr);
            let mut fps = Vec::new();
            let mut polls = 0usize;
            while !stop.load(std::sync::atomic::Ordering::SeqCst)
                || polls == 0
            {
                let resp = roundtrip(&mut conn, &mut reader, &req);
                assert_eq!(
                    resp.get("ok").and_then(Json::as_bool),
                    Some(true),
                    "client {c}: {resp:?}"
                );
                fps.push(fingerprint(&resp));
                polls += 1;
            }
            fps
        }));
    }

    // drive training round-by-round from its own connection while the
    // predict clients run
    let (mut conn, mut reader) = connect(addr);
    for _ in 0..ROUNDS {
        let resp =
            roundtrip(&mut conn, &mut reader, r#"{"op":"step","rounds":1}"#);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    stop.store(true, std::sync::atomic::Ordering::SeqCst);

    let mut total = 0usize;
    for client in clients {
        for fp in client.join().unwrap() {
            assert!(
                reference.contains(&fp),
                "a concurrent predict answered with bits no sequential \
                 round boundary ever produced (snapshot isolation broken)"
            );
            total += 1;
        }
    }
    assert!(total >= CONNS, "every client answered at least once");

    // after training settles, the served answer equals the final
    // sequential answer exactly
    let resp = roundtrip(&mut conn, &mut reader, &predict_req);
    assert_eq!(fingerprint(&resp), final_ref.unwrap());
    roundtrip(&mut conn, &mut reader, r#"{"op":"shutdown"}"#);
    server.join().unwrap();
}

/// Registry lifecycle over real sockets: create two models from
/// different connections, route by name, list, drop, and verify the
/// whole server shuts down from any connection.
#[test]
fn registry_create_route_drop_over_tcp() {
    let listener = match TcpListener::bind("127.0.0.1:0") {
        Ok(l) => l,
        Err(_) => {
            eprintln!("skipping: cannot bind loopback");
            return;
        }
    };
    let addr = listener.local_addr().unwrap();
    let reg = Arc::new(ModelRegistry::new());
    let server = std::thread::spawn(move || {
        nmbkm::serve::server::serve_listener(reg, listener).unwrap();
    });

    let (mut c1, mut r1) = connect(addr);
    let (mut c2, mut r2) = connect(addr);

    // connection 1 creates a 4-dim model; connection 2 a 6-dim model
    let resp = roundtrip(
        &mut c1,
        &mut r1,
        r#"{"op":"create","model":"narrow","k":3,"dim":4,"algo":"gb","b0":32,"seed":1}"#,
    );
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");
    let resp = roundtrip(
        &mut c2,
        &mut r2,
        r#"{"op":"create","model":"wide","k":2,"dim":6,"algo":"tb","b0":32,"seed":2}"#,
    );
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");

    // feed each model from the *other* connection (registry is shared)
    let narrow = GaussianMixture::default_spec(3, 4).generate(80, 4);
    let wide = GaussianMixture::default_spec(2, 6).generate(80, 5);
    let resp = roundtrip(
        &mut c2,
        &mut r2,
        &format!(
            "{{\"op\":\"ingest\",\"model\":\"narrow\",\"points\":{},\"rounds\":1}}",
            points_json(&rows_of(&narrow, 0, 80))
        ),
    );
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");
    let resp = roundtrip(
        &mut c1,
        &mut r1,
        &format!(
            "{{\"op\":\"ingest\",\"model\":\"wide\",\"points\":{},\"rounds\":1}}",
            points_json(&rows_of(&wide, 0, 80))
        ),
    );
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");

    // predicts route by name — the payload dimension proves which model
    // answered
    let resp = roundtrip(
        &mut c1,
        &mut r1,
        r#"{"op":"predict","model":"narrow","points":[[0,0,0,0]]}"#,
    );
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(resp.get("model").and_then(Json::as_str), Some("narrow"));
    let resp = roundtrip(
        &mut c1,
        &mut r1,
        r#"{"op":"predict","model":"wide","points":[[0,0,0,0]]}"#,
    );
    assert_eq!(
        resp.get("ok").and_then(Json::as_bool),
        Some(false),
        "4-dim query must not fit the 6-dim model"
    );
    // no default model exists in this registry
    let resp = roundtrip(
        &mut c2,
        &mut r2,
        r#"{"op":"predict","points":[[0,0,0,0]]}"#,
    );
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));

    // list shows both models with their shapes
    let resp = roundtrip(&mut c2, &mut r2, r#"{"op":"list"}"#);
    let models = resp.get("models").unwrap().as_arr().unwrap();
    assert_eq!(models.len(), 2);
    assert_eq!(models[0].get("model").and_then(Json::as_str), Some("narrow"));
    assert_eq!(models[0].get("dim").and_then(Json::as_usize), Some(4));
    assert_eq!(models[1].get("model").and_then(Json::as_str), Some("wide"));
    assert_eq!(models[1].get("dim").and_then(Json::as_usize), Some(6));

    // drop on one connection is immediately visible on the other
    let resp = roundtrip(&mut c1, &mut r1, r#"{"op":"drop","model":"wide"}"#);
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
    let resp = roundtrip(
        &mut c2,
        &mut r2,
        r#"{"op":"stats","model":"wide"}"#,
    );
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));

    roundtrip(&mut c2, &mut r2, r#"{"op":"shutdown"}"#);
    server.join().expect("server exits after shutdown from any connection");
}

/// Acceptance (sparse hot-path overhaul): predicts against a published
/// sparse model must be served entirely by the transpose carried from
/// the training session — zero transpose rebuilds across predicts
/// between publishes, from any number of concurrent predict threads —
/// and must stay bit-identical to the live session's own answers.
#[test]
fn published_sparse_predicts_never_rebuild_transpose() {
    const THREADS: usize = 4;
    const PREDICTS_PER_THREAD: usize = 6;
    let data = nmbkm::data::rcv1::Rcv1Sim {
        vocab: 500,
        topic_vocab: 60,
        ..Default::default()
    }
    .generate(600, 11);
    let mut session =
        session::OnlineSession::from_data(data.clone(), cfg(Algo::GbRho, 12, 256))
            .unwrap();
    session.step(5, f64::INFINITY).unwrap();
    let reg = ModelRegistry::with_default(session);
    let entry = reg.resolve(None).unwrap();
    assert!(
        entry.current().trans.is_some(),
        "sparse publish must carry the session transpose"
    );
    let queries = rows_of(&data, 0, 8);

    // hammer the published view from concurrent threads
    let mut workers = Vec::new();
    for _ in 0..THREADS {
        let entry = entry.clone();
        let queries = queries.clone();
        workers.push(std::thread::spawn(move || {
            for _ in 0..PREDICTS_PER_THREAD {
                let (lbl, d2) = entry.predict(&queries).unwrap();
                assert_eq!(lbl.len(), 8);
                assert!(d2.iter().all(|x| x.is_finite()));
            }
        }));
    }
    for w in workers {
        w.join().unwrap();
    }
    let (hits, builds) = entry.predict_cache_stats();
    assert_eq!(
        builds, 0,
        "predicts between publishes rebuilt the transpose"
    );
    assert_eq!(hits as usize, THREADS * PREDICTS_PER_THREAD);

    // republish (training step) and predict again: the refreshed
    // transpose is carried too — predict-side builds stay at zero
    // across arbitrarily many publish/predict cycles
    for _ in 0..3 {
        entry
            .with_session_mut(|s| s.step(1, f64::INFINITY).map(|_| ()))
            .unwrap();
        let (lbl_pub, d2_pub) = entry.predict(&queries).unwrap();
        let (lbl_live, d2_live) =
            entry.with_session(|s| s.predict_rows(&queries)).unwrap();
        assert_eq!(lbl_pub, lbl_live);
        assert_eq!(
            d2_pub.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            d2_live.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "published sparse predict diverged from the live session"
        );
    }
    assert_eq!(
        entry.predict_cache_stats().1,
        0,
        "a publish cycle leaked a rebuild into the predict path"
    );
}

/// ROADMAP acceptance: two concurrently training sparse sessions must
/// not evict each other's transpose cache. Per-session builds stay
/// bounded by the number of centroid revisions that session itself
/// produced (the old process-global slot rebuilt on every interleaved
/// call), and within-round reuse still registers hits.
#[test]
fn concurrent_sparse_sessions_keep_their_transpose_caches() {
    const ROUNDS: usize = 6;
    let gen = |seed: u64| {
        nmbkm::data::rcv1::Rcv1Sim {
            vocab: 500,
            topic_vocab: 60,
            ..Default::default()
        }
        .generate(800, seed)
    };
    let mut handles = Vec::new();
    for seed in [1u64, 2u64] {
        let data = gen(seed);
        handles.push(std::thread::spawn(move || {
            let mut s = session::OnlineSession::from_data(
                data,
                cfg(Algo::GbRho, 12, 256),
            )
            .unwrap();
            for _ in 0..ROUNDS {
                s.step(1, f64::INFINITY).unwrap();
                // yield so the two trainers genuinely interleave
                std::thread::yield_now();
            }
            let cache = s
                .trans_cache()
                .expect("native engine exposes its transpose cache");
            (cache.hits() as usize, cache.builds() as usize)
        }));
    }
    for h in handles {
        let (hits, builds) = h.join().unwrap();
        // one build per centroid revision this session used (+1 for the
        // initial centroids). This is the eviction signal: the old
        // process-global slot rebuilt on (nondeterministically many)
        // interleaved calls from the other session, blowing well past
        // this bound. Exact hit counts for the interleaved-call pattern
        // are asserted in the engine-level unit test
        // (`per_engine_caches_do_not_evict_each_other`).
        assert!(
            builds <= ROUNDS + 1,
            "per-session transpose cache thrashed: {builds} builds for \
             {ROUNDS} rounds"
        );
        // every round makes at least one cache-eligible engine fetch
        assert!(
            hits + builds >= ROUNDS,
            "cache counters undercount engine calls \
             (hits={hits}, builds={builds}, rounds={ROUNDS})"
        );
    }
}
