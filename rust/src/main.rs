//! `nmbkm` — command-line interface.
//!
//! ```text
//! nmbkm run --dataset infmnist --algo tb --rho inf --k 50 --b0 5000 \
//!           --seconds 20 --seed 0 --engine xla --threads 8 --out run.csv
//! nmbkm experiment fig1|fig2|fig3|table1|table2|all [--full] [--seeds N]
//! nmbkm info [--artifacts DIR]
//! ```
//!
//! `run` executes one clustering job and writes its per-round trace;
//! `experiment` regenerates a paper table/figure (see DESIGN.md);
//! `info` prints platform/artifact status.

use nmbkm::config::RunConfig;
use nmbkm::coordinator::progress::results_dir;
use nmbkm::data::{gaussian::GaussianMixture, infmnist::InfMnist, rcv1::Rcv1Sim, Dataset};
use nmbkm::experiments::{self, common::ExpOpts};
use nmbkm::util::args::{usage, Args, OptSpec};

fn run_spec() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "dataset", takes_value: true, default: Some("gaussian"), help: "gaussian | infmnist | rcv1" },
        OptSpec { name: "n", takes_value: true, default: Some("10000"), help: "training points" },
        OptSpec { name: "nval", takes_value: true, default: Some("2000"), help: "validation points" },
        OptSpec { name: "data-seed", takes_value: true, default: Some("7"), help: "dataset generator seed" },
        OptSpec { name: "algo", takes_value: true, default: None, help: "lloyd|elkan|sgd|mb|mbf|gb|tb [tb]" },
        OptSpec { name: "rho", takes_value: true, default: None, help: "gb/tb threshold, number or 'inf' [inf]" },
        OptSpec { name: "k", takes_value: true, default: None, help: "clusters [50]" },
        OptSpec { name: "b0", takes_value: true, default: None, help: "(initial) batch size [5000]" },
        OptSpec { name: "seconds", takes_value: true, default: None, help: "work-time budget [10]" },
        OptSpec { name: "rounds", takes_value: true, default: None, help: "max rounds" },
        OptSpec { name: "seed", takes_value: true, default: None, help: "run seed (shuffle + init) [0]" },
        OptSpec { name: "engine", takes_value: true, default: None, help: "native | xla [native]" },
        OptSpec { name: "threads", takes_value: true, default: None, help: "worker threads [all cores]" },
        OptSpec { name: "artifacts", takes_value: true, default: None, help: "artifacts dir (xla engine) [artifacts]" },
        OptSpec { name: "config", takes_value: true, default: None, help: "key=value config file (flags override)" },
        OptSpec { name: "out", takes_value: true, default: None, help: "trace CSV path" },
        OptSpec { name: "quiet", takes_value: false, default: None, help: "suppress per-round log" },
    ]
}

fn build_dataset(args: &Args) -> anyhow::Result<Dataset> {
    let n = args.get_usize("n")?;
    let nval = args.get_usize("nval")?;
    let seed = args.get_u64("data-seed")?;
    Ok(match args.get("dataset").unwrap_or("gaussian") {
        "gaussian" => GaussianMixture::default_spec(10, 32).dataset(n, nval, seed),
        "infmnist" => InfMnist::default().dataset(n, nval, seed),
        "rcv1" => Rcv1Sim::default().dataset(n, nval, seed),
        other => anyhow::bail!("unknown dataset '{other}'"),
    })
}

fn cmd_run(raw: &[String]) -> anyhow::Result<()> {
    let spec = run_spec();
    let args = Args::parse(raw, &spec).map_err(anyhow::Error::msg)?;
    let ds = build_dataset(&args)?;
    let mut cfg = RunConfig::default();
    // config file first, explicit flags override
    if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path)?;
        cfg.apply_file(&text).map_err(anyhow::Error::msg)?;
    } else if args.get("threads").is_none() {
        cfg.threads = std::thread::available_parallelism()
            .map(|x| x.get())
            .unwrap_or(1);
    }
    let overridden = RunConfig::from_args(&args).map_err(anyhow::Error::msg)?;
    // fold in only the flags that were actually passed
    if args.get("algo").is_some() { cfg.algo = overridden.algo; }
    if args.get("rho").is_some() { cfg.rho = overridden.rho; }
    if args.get("k").is_some() { cfg.k = overridden.k; }
    if args.get("b0").is_some() { cfg.b0 = overridden.b0; }
    if args.get("seconds").is_some() { cfg.max_seconds = overridden.max_seconds; }
    if args.get("rounds").is_some() { cfg.max_rounds = overridden.max_rounds; }
    if args.get("seed").is_some() { cfg.seed = overridden.seed; }
    if args.get("engine").is_some() { cfg.engine = overridden.engine; }
    if args.get("threads").is_some() { cfg.threads = overridden.threads; }
    if args.get("artifacts").is_some() { cfg.artifacts_dir = overridden.artifacts_dir; }

    println!("dataset: {}", ds.summary());
    println!(
        "running {} (k={}, b0={}, engine={:?}, threads={})",
        cfg.label(), cfg.k, cfg.b0, cfg.engine, cfg.threads
    );
    let out = nmbkm::kmeans::run(&ds.train, Some(&ds.val), &cfg)?;
    if !args.flag("quiet") {
        for r in &out.trace.records {
            println!(
                "round {:>4}  t={:>8.3}s  b={:>7}  calcs={:>12}  skips={:>12}  changed={:>8}  mse={}",
                r.round,
                r.t_work,
                r.batch,
                r.dist_calcs,
                r.bound_skips,
                r.changed,
                r.val_mse.map(|m| format!("{m:.6e}")).unwrap_or_else(|| "-".into()),
            );
        }
    }
    println!(
        "done: {} rounds, {:.3}s work, final validation MSE {:.6e}",
        out.rounds, out.work_secs, out.final_mse
    );
    if let Some(path) = args.get("out") {
        out.trace.to_table().write_csv(std::path::Path::new(path))?;
        println!("trace written to {path}");
    }
    Ok(())
}

fn cmd_experiment(raw: &[String]) -> anyhow::Result<()> {
    let which = raw.first().map(|s| s.as_str()).unwrap_or("");
    let rest: Vec<String> = raw.iter().skip(1).cloned().collect();
    let opts = ExpOpts::from_args(&rest);
    println!(
        "experiment {which}: scale={:?} seeds={} threads={} budget={}s",
        opts.scale, opts.seeds, opts.threads, opts.seconds
    );
    match which {
        "fig1" => experiments::fig1::run(&opts),
        "fig2" => experiments::rho_sweep::run(2, &opts),
        "fig3" => experiments::rho_sweep::run(3, &opts),
        "table1" => experiments::table1::run(&opts).map(|_| ()),
        "table2" => experiments::table2::run(&opts).map(|_| ()),
        "ablations" => experiments::ablations::run(&opts),
        "all" => {
            experiments::table1::run(&opts)?;
            experiments::fig1::run(&opts)?;
            experiments::rho_sweep::run(2, &opts)?;
            experiments::rho_sweep::run(3, &opts)?;
            experiments::table2::run(&opts).map(|_| ())
        }
        other => anyhow::bail!(
            "unknown experiment '{other}' (fig1|fig2|fig3|table1|table2|ablations|all)"
        ),
    }
}

fn cmd_info(raw: &[String]) -> anyhow::Result<()> {
    let dir = raw
        .iter()
        .position(|a| a == "--artifacts")
        .and_then(|p| raw.get(p + 1).cloned())
        .unwrap_or_else(|| "artifacts".to_string());
    println!("nmbkm — Nested Mini-Batch K-Means (Newling & Fleuret, NIPS 2016)");
    println!("results dir: {}", results_dir().display());
    println!(
        "threads available: {}",
        std::thread::available_parallelism().map(|x| x.get()).unwrap_or(1)
    );
    match nmbkm::runtime::artifact::Manifest::load(std::path::Path::new(&dir)) {
        Ok(m) => {
            println!(
                "artifacts [{dir}]: k={} batches={:?} dims={:?}, {} programs",
                m.k,
                m.batches,
                m.dims,
                m.entries.len()
            );
            match nmbkm::runtime::executor::XlaEngine::load(&dir) {
                Ok(_) => println!("PJRT CPU client: OK (all programs compiled)"),
                Err(e) => println!("PJRT load failed: {e:#}"),
            }
        }
        Err(e) => println!("no artifacts ({e:#}) — run `make artifacts`"),
    }
    Ok(())
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match argv.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => ("help", vec![]),
    };
    let result = match cmd {
        "run" => cmd_run(&rest),
        "experiment" => cmd_experiment(&rest),
        "info" => cmd_info(&rest),
        _ => {
            println!("nmbkm <run|experiment|info>\n");
            println!("{}", usage("nmbkm run", "run one clustering job", &run_spec()));
            println!(
                "nmbkm experiment <fig1|fig2|fig3|table1|table2|all> \
                 [--full] [--seeds N] [--seconds S] [--threads T] [--engine-xla]"
            );
            println!("nmbkm info [--artifacts DIR]");
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
