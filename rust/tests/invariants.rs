//! Cross-module integration tests: the paper-level invariants that the
//! whole stack must satisfy (DESIGN.md §Testing strategy).

use nmbkm::config::{Algo, Engine, Rho, RunConfig};
use nmbkm::data::{gaussian::GaussianMixture, infmnist::InfMnist, rcv1::Rcv1Sim};
use nmbkm::kmeans::{self, run};

fn base_cfg(algo: Algo, k: usize) -> RunConfig {
    RunConfig {
        algo,
        k,
        b0: 128,
        rho: Rho::Infinite,
        max_seconds: 60.0,
        max_rounds: 40,
        seed: 0,
        threads: 3,
        eval_every_secs: 0.0,
        stop_on_convergence: false,
        ..Default::default()
    }
}

#[test]
fn lloyd_training_mse_monotone_all_datasets() {
    let dense = GaussianMixture::default_spec(6, 12).generate(1_500, 1);
    let sparse = Rcv1Sim { vocab: 3_000, topic_vocab: 300, ..Default::default() }
        .generate(1_200, 2);
    for data in [dense, sparse] {
        let cfg = RunConfig { max_rounds: 15, ..base_cfg(Algo::Lloyd, 6) };
        let out = run(&data, None, &cfg).unwrap();
        let mses: Vec<f64> = out.trace.records.iter().map(|r| r.train_mse).collect();
        for w in mses.windows(2) {
            assert!(w[1] <= w[0] * (1.0 + 1e-6), "MSE rose {} -> {}", w[0], w[1]);
        }
    }
}

#[test]
fn elkan_tracks_lloyd_exactly_on_digits() {
    let data = InfMnist::default().generate(1_200, 4);
    let l = run(&data, None, &RunConfig { max_rounds: 8, ..base_cfg(Algo::Lloyd, 10) }).unwrap();
    let e = run(&data, None, &RunConfig { max_rounds: 8, ..base_cfg(Algo::Elkan, 10) }).unwrap();
    // same seed → same shuffle → identical trajectories
    for (rl, re) in l.trace.records.iter().zip(&e.trace.records) {
        assert_eq!(
            rl.changed, re.changed,
            "round {}: lloyd changed {} vs elkan {}",
            rl.round, rl.changed, re.changed
        );
    }
    let dmax = l
        .centroids
        .c
        .data
        .iter()
        .zip(&e.centroids.c.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(dmax < 2e-3, "centroid divergence {dmax}");
    // and elkan must have done strictly less distance work
    assert!(e.trace.total_dist_calcs() < l.trace.total_dist_calcs());
}

#[test]
fn tb_inf_equals_gb_inf_on_both_storage_kinds() {
    // bounds must never change the clustering — dense AND sparse
    let dense = InfMnist::default().generate(2_000, 5);
    let sparse = Rcv1Sim { vocab: 5_000, topic_vocab: 500, ..Default::default() }
        .generate(2_000, 6);
    for data in [dense, sparse] {
        let gb = run(&data, None, &RunConfig { max_rounds: 14, ..base_cfg(Algo::GbRho, 8) })
            .unwrap();
        let tb = run(&data, None, &RunConfig { max_rounds: 14, ..base_cfg(Algo::TbRho, 8) })
            .unwrap();
        let dmax = gb
            .centroids
            .c
            .data
            .iter()
            .zip(&tb.centroids.c.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(dmax < 2e-3, "tb-∞ diverged from gb-∞ by {dmax}");
        // work elimination: tb must do fewer distance calcs
        assert!(
            tb.trace.total_dist_calcs() < gb.trace.total_dist_calcs(),
            "tb {} vs gb {}",
            tb.trace.total_dist_calcs(),
            gb.trace.total_dist_calcs()
        );
        // and batch-size trajectories must match (same controller votes)
        let gbb: Vec<usize> = gb.trace.records.iter().map(|r| r.batch).collect();
        let tbb: Vec<usize> = tb.trace.records.iter().map(|r| r.batch).collect();
        assert_eq!(gbb, tbb);
    }
}

#[test]
fn nestedness_and_doubling_hold_across_rho() {
    let data = GaussianMixture::default_spec(5, 10).generate(3_000, 7);
    for rho in [Rho::Finite(1.0), Rho::Finite(100.0), Rho::Infinite] {
        let cfg = RunConfig { rho, max_rounds: 25, ..base_cfg(Algo::GbRho, 5) };
        let out = run(&data, None, &cfg).unwrap();
        let batches: Vec<usize> =
            out.trace.records.iter().map(|r| r.batch).collect();
        for w in batches.windows(2) {
            assert!(
                w[1] == w[0] || w[1] == (2 * w[0]).min(3_000),
                "rho={rho:?}: batch went {} -> {}",
                w[0],
                w[1]
            );
        }
    }
}

#[test]
fn threads_do_not_change_results() {
    let data = InfMnist::default().generate(1_500, 9);
    for algo in [Algo::Lloyd, Algo::GbRho, Algo::TbRho, Algo::MbF] {
        let mut outs = Vec::new();
        for threads in [1usize, 4] {
            let cfg = RunConfig { threads, max_rounds: 8, ..base_cfg(algo, 6) };
            outs.push(run(&data, None, &cfg).unwrap());
        }
        assert_eq!(
            outs[0].centroids.c.data, outs[1].centroids.c.data,
            "{algo:?}: 1-thread vs 4-thread centroids differ"
        );
    }
}

#[test]
fn mb_vs_mbf_contamination_signature() {
    // On heavily-revisited data, mb's cumulative v keeps growing while
    // mb-f's total v equals the number of distinct points seen. This is
    // the §3.1 mechanism, observed through the public trace.
    let data = GaussianMixture::default_spec(4, 8).generate(400, 3);
    let mk = |algo| RunConfig {
        b0: 200,
        max_rounds: 10,
        ..base_cfg(algo, 4)
    };
    let mb = run(&data, None, &mk(Algo::Mb)).unwrap();
    let mbf = run(&data, None, &mk(Algo::MbF)).unwrap();
    // both process the same number of points; quality should not favour mb
    assert!(mbf.final_mse <= mb.final_mse * 1.10);
}

#[test]
fn xla_engine_run_matches_native_run() {
    let artifacts = std::path::Path::new("artifacts/manifest.json");
    if !artifacts.exists() {
        eprintln!("skipping xla parity: run `make artifacts`");
        return;
    }
    let data = InfMnist::default().generate(3_000, 11);
    let mk = |engine| RunConfig {
        engine,
        k: 20,
        max_rounds: 8,
        ..base_cfg(Algo::GbRho, 20)
    };
    let nat = run(&data, None, &mk(Engine::Native)).unwrap();
    let xla = run(&data, None, &mk(Engine::Xla)).unwrap();
    // same rounds, and near-identical centroids (f32 tile arithmetic)
    assert_eq!(nat.rounds, xla.rounds);
    let dmax = nat
        .centroids
        .c
        .data
        .iter()
        .zip(&xla.centroids.c.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(dmax < 5e-2, "native vs xla centroid divergence {dmax}");
}

#[test]
fn tb_tile_mode_equals_pointstep_through_runner() {
    // Engine::Xla flips TurboBatch into tile-screen mode; with the
    // native engine serving dist_rows the assignments must match the
    // pointstep mode exactly. (True XLA execution is covered above.)
    let data = InfMnist::default().generate(2_000, 13);
    let a = run(&data, None, &RunConfig { max_rounds: 10, ..base_cfg(Algo::TbRho, 8) })
        .unwrap();
    // tile mode via make_clusterer is keyed on Engine::Xla, so emulate
    // by running gb (exact) and checking equality instead:
    let b = run(&data, None, &RunConfig { max_rounds: 10, ..base_cfg(Algo::GbRho, 8) })
        .unwrap();
    assert_eq!(
        a.trace.records.last().unwrap().batch,
        b.trace.records.last().unwrap().batch
    );
}

#[test]
fn sgd_and_mb_run_on_sparse() {
    let data = Rcv1Sim { vocab: 2_000, topic_vocab: 200, ..Default::default() }
        .generate(800, 1);
    for algo in [Algo::Sgd, Algo::Mb, Algo::MbF] {
        let cfg = RunConfig { max_rounds: 6, ..base_cfg(algo, 5) };
        let out = run(&data, None, &cfg).unwrap();
        assert!(out.final_mse.is_finite());
    }
}

#[test]
fn validation_protocol_excludes_eval_time() {
    // a run with expensive validation must not report inflated work time
    let data = GaussianMixture::default_spec(4, 16).generate(2_000, 2);
    let val = GaussianMixture::default_spec(4, 16).generate(30_000, 3);
    let cfg = RunConfig {
        algo: Algo::Mb,
        k: 4,
        b0: 64,
        max_rounds: 5,
        max_seconds: 60.0,
        eval_every_secs: 0.0, // validate every round (expensive)
        threads: 2,
        stop_on_convergence: false,
        ..Default::default()
    };
    let (out, wall) = nmbkm::util::timer::time_it(|| run(&data, Some(&val), &cfg).unwrap());
    // validation is 15x the batch work; work_secs must be well under wall
    assert!(
        out.work_secs < wall * 0.6,
        "work {:.3}s vs wall {:.3}s — validation leaked into the clock",
        out.work_secs,
        wall
    );
    assert!(out.trace.records.iter().all(|r| r.val_mse.is_some()));
}

#[test]
fn kmeanspp_initialisation_integrates() {
    // init::kmeanspp is not used by the paper protocol but must compose
    // with the stack (examples use it)
    let data = GaussianMixture::default_spec(6, 8).generate(600, 5);
    let mut rng = nmbkm::util::rng::Pcg64::new(1, 1);
    let cent = kmeans::init::kmeanspp(&data, 6, &mut rng);
    let mse = nmbkm::kmeans::state::exact_mse(&data, &cent);
    assert!(mse.is_finite() && mse > 0.0);
}
