//! The batch-growth controller — the paper's Algorithm 6.
//!
//! After each centroid update the controller compares, per cluster, the
//! standard error of the centroid estimate σ̂_C(j) against the distance
//! p(j) the centroid just moved:
//!
//! * σ̂_C(j) ≪ p(j): more data is *redundant* (Ineq. 11) — keep b.
//! * σ̂_C(j) ≫ p(j): the batch is being over-fit / prematurely
//!   fine-tuned (Ineq. 12) — grow.
//!
//! A majority vote via the median ratio decides; double-or-nothing
//! because σ̂_C shrinks by √2 per doubling. The degenerate ρ = ∞ case
//! (Alg. 10/11) doubles iff a strict majority of centroids did not move
//! at all (those ratios are +∞ — see §3.3.3).

use crate::config::Rho;
use crate::kmeans::state::{Centroids, SuffStats};
use crate::util::stats::median;

/// Outcome of one controller evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    Stay,
    Double,
}

/// Per-cluster ratio σ̂_C(j)/p(j); +∞ when p(j) = 0 (unchanged centroid)
/// or when the cluster is too small for a variance estimate.
pub fn ratios(stats: &SuffStats, cent: &Centroids) -> Vec<f64> {
    (0..stats.k)
        .map(|j| {
            let p = cent.p[j] as f64;
            if p <= 0.0 {
                f64::INFINITY
            } else {
                stats.sigma_c(j) / p
            }
        })
        .collect()
}

/// Algorithm 6: double iff `med_j [σ̂_C(j)/p(j)] ≥ ρ`.
///
/// For `Rho::Infinite` the σ̂ values are irrelevant (the paper's
/// "slight simplification"): the median is ≥ ∞ iff a strict majority of
/// the ratios are +∞, i.e. a strict majority of centroids have p(j)=0.
pub fn decide(rho: Rho, stats: &SuffStats, cent: &Centroids) -> Decision {
    match rho {
        Rho::Infinite => {
            let unchanged =
                cent.p.iter().filter(|&&p| p <= 0.0).count();
            if 2 * unchanged > cent.k() {
                Decision::Double
            } else {
                Decision::Stay
            }
        }
        Rho::Finite(r) => {
            let rs = ratios(stats, cent);
            if median(&rs) >= r {
                Decision::Double
            } else {
                Decision::Stay
            }
        }
    }
}

/// Apply a decision: `b ← min(2b, N)`.
pub fn next_batchsize(b: usize, n: usize, d: Decision) -> usize {
    grow(b, n, d, GrowthPolicy::Double)
}

/// Alternative batch-growth laws — the paper's second future-work
/// direction (§5: "there are potentially better approaches" to
/// increasing the batch). The σ̂_C √2-per-doubling argument motivates
/// `Double`; the ablation bench (`cargo bench --bench ablations`)
/// measures what the alternatives cost.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GrowthPolicy {
    /// The paper's double-or-nothing (Algorithm 6).
    Double,
    /// Gentler geometric growth: b ← ⌈1.5·b⌉.
    Geometric15,
    /// Additive growth by the initial batch size: b ← b + b0.
    Additive(usize),
    /// Ignore the vote entirely; always grow (a gb algorithm with a
    /// schedule, no statistics — the naive strawman).
    AlwaysDouble,
}

/// Apply `policy` given the controller's vote.
pub fn grow(b: usize, n: usize, d: Decision, policy: GrowthPolicy) -> usize {
    let grown = match (policy, d) {
        (GrowthPolicy::AlwaysDouble, _) => 2 * b,
        (_, Decision::Stay) => b,
        (GrowthPolicy::Double, Decision::Double) => 2 * b,
        (GrowthPolicy::Geometric15, Decision::Double) => (3 * b).div_ceil(2),
        (GrowthPolicy::Additive(b0), Decision::Double) => b + b0.max(1),
    };
    grown.min(n).max(b.min(n))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::DenseMatrix;

    fn mk(k: usize, p: &[f32], v: &[f64], sse: &[f64]) -> (SuffStats, Centroids) {
        let mut stats = SuffStats::zeros(k, 2);
        stats.v.copy_from_slice(v);
        stats.sse.copy_from_slice(sse);
        let mut cent = Centroids::from_matrix(DenseMatrix::zeros(k, 2));
        cent.p.copy_from_slice(p);
        (stats, cent)
    }

    #[test]
    fn rho_inf_majority_rule() {
        // 3 of 5 unchanged → double
        let (st, ce) = mk(5, &[0.0, 0.0, 0.0, 1.0, 1.0], &[10.0; 5], &[1.0; 5]);
        assert_eq!(decide(Rho::Infinite, &st, &ce), Decision::Double);
        // 2 of 5 unchanged → stay
        let (st, ce) = mk(5, &[0.0, 0.0, 1.0, 1.0, 1.0], &[10.0; 5], &[1.0; 5]);
        assert_eq!(decide(Rho::Infinite, &st, &ce), Decision::Stay);
        // exactly half (2 of 4) is NOT a strict majority → stay
        let (st, ce) = mk(4, &[0.0, 0.0, 1.0, 1.0], &[10.0; 4], &[1.0; 4]);
        assert_eq!(decide(Rho::Infinite, &st, &ce), Decision::Stay);
    }

    #[test]
    fn finite_rho_median_rule() {
        // σ̂_C(j) = sqrt(sse/(v(v-1))) = sqrt(90/(10*9)) = 1; p = 0.5
        // ⇒ every ratio = 2
        let (st, ce) = mk(3, &[0.5; 3], &[10.0; 3], &[90.0; 3]);
        assert_eq!(decide(Rho::Finite(2.0), &st, &ce), Decision::Double);
        assert_eq!(decide(Rho::Finite(2.1), &st, &ce), Decision::Stay);
        assert_eq!(decide(Rho::Finite(1.0), &st, &ce), Decision::Double);
    }

    #[test]
    fn unchanged_clusters_push_ratio_to_infinity() {
        let (st, ce) = mk(3, &[0.0, 0.0, 0.5], &[10.0; 3], &[90.0; 3]);
        let rs = ratios(&st, &ce);
        assert!(rs[0].is_infinite() && rs[1].is_infinite());
        // median of {∞, ∞, 2} = ∞ ≥ any finite ρ
        assert_eq!(decide(Rho::Finite(1e12), &st, &ce), Decision::Double);
    }

    #[test]
    fn tiny_clusters_vote_to_grow() {
        let (mut st, ce) = mk(3, &[0.5; 3], &[10.0; 3], &[90.0; 3]);
        st.v = vec![1.0, 1.0, 10.0]; // two clusters below variance-estimable size
        let rs = ratios(&st, &ce);
        assert!(rs[0].is_infinite() && rs[1].is_infinite());
    }

    #[test]
    fn next_batchsize_caps_at_n() {
        assert_eq!(next_batchsize(5000, 60000, Decision::Double), 10000);
        assert_eq!(next_batchsize(40000, 60000, Decision::Double), 60000);
        assert_eq!(next_batchsize(60000, 60000, Decision::Double), 60000);
        assert_eq!(next_batchsize(70000, 60000, Decision::Stay), 60000);
    }

    #[test]
    fn growth_policies() {
        use GrowthPolicy::*;
        assert_eq!(grow(100, 1000, Decision::Double, Double), 200);
        assert_eq!(grow(100, 1000, Decision::Double, Geometric15), 150);
        assert_eq!(grow(100, 1000, Decision::Double, Additive(64)), 164);
        assert_eq!(grow(100, 1000, Decision::Stay, Additive(64)), 100);
        assert_eq!(grow(100, 1000, Decision::Stay, AlwaysDouble), 200);
        // never shrinks, always capped
        for p in [Double, Geometric15, Additive(10), AlwaysDouble] {
            for d in [Decision::Stay, Decision::Double] {
                let nb = grow(900, 1000, d, p);
                assert!((900..=1000).contains(&nb), "{p:?} {d:?} -> {nb}");
            }
        }
    }
}
