//! Offline **stub** of the `xla` PJRT bindings.
//!
//! The build image carries no PJRT shared library, so this crate keeps
//! `nmbkm --features xla` *compiling* without it: the API surface that
//! `nmbkm::runtime::executor` consumes is reproduced type-for-type, and
//! [`PjRtClient::cpu`] fails with a clear message. Every downstream
//! path already treats client construction as fallible (engine load
//! errors surface as "xla unavailable" and runs fall back to the native
//! engine or skip), so swapping in the real bindings is purely a
//! dependency change — no call-site edits.

use std::fmt;

/// Stub error: everything fails with this until the real bindings are
/// linked.
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "PJRT unavailable: nmbkm was built against the offline `xla` stub \
         (rust/vendor/xla). Link the real xla/PJRT bindings to execute \
         compiled artifacts."
            .to_string(),
    )
}

/// Host literal (stub: tracks only the element count).
#[derive(Clone, Debug)]
pub struct Literal {
    elems: usize,
}

impl Literal {
    pub fn vec1<T: Copy>(v: &[T]) -> Literal {
        Literal { elems: v.len() }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(self.clone())
    }

    pub fn to_vec<T: Clone + Default>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    pub fn element_count(&self) -> usize {
        self.elems
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<std::path::Path>>(
        _path: P,
    ) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Shape: per-device vec of per-output buffers, as in the real
    /// bindings.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// PJRT client (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(format!("{err:?}").contains("stub"));
    }

    #[test]
    fn literal_shape_plumbing_works() {
        let l = Literal::vec1(&[1f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.element_count(), 4);
        assert!(l.to_vec::<f32>().is_err());
    }
}
