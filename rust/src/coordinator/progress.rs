//! Run progress: event logging and tabular result output.
//!
//! Experiments write their series as CSV under `artifacts/results/` (one
//! file per run or per figure) plus optional JSON sidecars; the bench
//! harnesses print the paper-shaped tables from these.

use std::io::Write;
use std::path::{Path, PathBuf};

/// A simple CSV table builder (header + typed rows as strings).
#[derive(Clone, Debug)]
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.header.len(), "row arity mismatch");
        self.rows.push(row);
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.header.join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }

    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }
}

/// Where experiment outputs land (`artifacts/results/` by default,
/// override with `NMBKM_RESULTS_DIR`).
pub fn results_dir() -> PathBuf {
    std::env::var("NMBKM_RESULTS_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts/results"))
}

/// An append-only event log with wall timestamps, for debugging long
/// experiment runs (`--verbose` paths print it live).
#[derive(Debug, Default)]
pub struct EventLog {
    pub events: Vec<(f64, String)>,
    start: Option<std::time::Instant>,
    pub echo: bool,
}

impl EventLog {
    pub fn new(echo: bool) -> Self {
        Self { events: vec![], start: Some(std::time::Instant::now()), echo }
    }

    pub fn log(&mut self, msg: impl Into<String>) {
        let t = self.start.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
        let msg = msg.into();
        if self.echo {
            eprintln!("[{t:8.3}s] {msg}");
        }
        self.events.push((t, msg));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_shape() {
        let mut t = Table::new(&["a", "b"]);
        t.push(vec!["1".into(), "x".into()]);
        t.push(vec!["2".into(), "y".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,x\n2,y\n");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.push(vec!["1".into()]);
    }

    #[test]
    fn write_csv_creates_dirs() {
        let dir = std::env::temp_dir().join(format!("nmbkm-test-{}", std::process::id()));
        let path = dir.join("sub/table.csv");
        let mut t = Table::new(&["x"]);
        t.push(vec!["7".into()]);
        t.write_csv(&path).unwrap();
        assert!(path.exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn event_log_ordered() {
        let mut l = EventLog::new(false);
        l.log("first");
        l.log("second");
        assert_eq!(l.events.len(), 2);
        assert!(l.events[0].0 <= l.events[1].0);
        assert_eq!(l.events[1].1, "second");
    }
}
