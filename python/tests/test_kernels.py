"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes and data regimes; every test asserts allclose
(or exact equality for integer outputs) against ``kernels/ref.py``.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import distance, ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _rng(seed):
    return np.random.default_rng(seed)


def make_xc(rng, b, d, k, scale=1.0, dupes=False):
    x = rng.normal(size=(b, d)).astype(np.float32) * scale
    c = rng.normal(size=(k, d)).astype(np.float32) * scale
    if dupes:
        # duplicate centroids exercise argmin tie-breaking
        c[1 % k] = c[0]
    return jnp.asarray(x), jnp.asarray(c)


shapes = st.tuples(
    st.sampled_from([8, 64, 256, 512]),     # b (multiple of tile when big)
    st.integers(min_value=1, max_value=96),  # d
    st.integers(min_value=1, max_value=40),  # k
)


@given(shapes, st.integers(0, 2**32 - 1), st.booleans())
def test_assign_matches_ref(shape, seed, dupes):
    b, d, k = shape
    rng = _rng(seed)
    x, c = make_xc(rng, b, d, k, dupes=dupes)
    tile = min(b, distance.TILE_B)
    lbl, d2 = distance.assign(x, c, jnp.sum(c * c, axis=1), tile_b=tile)
    lbl_r, d2_r = ref.assign_ref(x, c)
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d2_r),
                               rtol=1e-4, atol=1e-3)
    # label may differ from ref only where distances tie numerically
    mism = np.asarray(lbl) != np.asarray(lbl_r)
    if mism.any():
        xm = np.asarray(x)[mism]
        cm = np.asarray(c)
        da = ((xm[:, None, :] - cm[None]) ** 2).sum(-1)
        picked = da[np.arange(mism.sum()), np.asarray(lbl)[mism]]
        best = da.min(1)
        np.testing.assert_allclose(picked, best, rtol=1e-4, atol=1e-3)


@given(shapes, st.integers(0, 2**32 - 1))
def test_distmat_matches_ref(shape, seed):
    b, d, k = shape
    rng = _rng(seed)
    x, c = make_xc(rng, b, d, k)
    tile = min(b, distance.TILE_B)
    got = distance.distmat(x, c, jnp.sum(c * c, axis=1), tile_b=tile)
    want = ref.distmat_ref(x, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)
    assert float(jnp.min(got)) >= 0.0


@given(shapes, st.integers(0, 2**32 - 1))
def test_cluster_stats_matches_ref(shape, seed):
    b, d, k = shape
    rng = _rng(seed)
    x, c = make_xc(rng, b, d, k)
    tile = min(b, distance.TILE_B)
    lbl, d2 = ref.assign_ref(x, c)
    s, v, sse = distance.cluster_stats(x, lbl, d2, k, tile_b=tile)
    s_r, v_r, sse_r = ref.cluster_stats_ref(x, lbl, d2, k)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_r),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v_r))
    np.testing.assert_allclose(np.asarray(sse), np.asarray(sse_r),
                               rtol=1e-4, atol=1e-3)


@given(shapes, st.integers(0, 2**32 - 1),
       st.floats(min_value=0.0, max_value=2.0))
def test_bound_screen_matches_ref(shape, seed, pscale):
    b, _, k = shape
    rng = _rng(seed)
    lb = jnp.asarray(np.abs(rng.normal(size=(b, k))).astype(np.float32))
    p = jnp.asarray((np.abs(rng.normal(size=(k,))) * pscale)
                    .astype(np.float32))
    d = jnp.asarray(np.abs(rng.normal(size=(b,))).astype(np.float32))
    lbl = jnp.asarray(rng.integers(0, k, size=(b,)).astype(np.int32))
    tile = min(b, distance.TILE_B)
    lb2, dirty = distance.bound_screen(lb, p, d, lbl, tile_b=tile)
    lb2_r, dirty_r = ref.bound_screen_ref(lb, p, d, lbl)
    np.testing.assert_allclose(np.asarray(lb2), np.asarray(lb2_r),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(dirty), np.asarray(dirty_r))


def test_assign_k1_degenerate():
    rng = _rng(7)
    x, c = make_xc(rng, 64, 5, 1)
    lbl, d2 = distance.assign(x, c, jnp.sum(c * c, axis=1), tile_b=64)
    assert (np.asarray(lbl) == 0).all()
    np.testing.assert_allclose(
        np.asarray(d2), ((np.asarray(x) - np.asarray(c)[0]) ** 2).sum(-1),
        rtol=1e-4, atol=1e-3)


def test_assign_exact_hit_distance_zero():
    """A point equal to a centroid must get d2 == 0 (clamped, not -eps)."""
    rng = _rng(9)
    x, c = make_xc(rng, 8, 16, 4)
    x = x.at[3].set(c[2])
    lbl, d2 = distance.assign(x, c, jnp.sum(c * c, axis=1), tile_b=8)
    assert int(lbl[3]) == 2
    assert float(d2[3]) <= 1e-3
    assert float(d2.min()) >= 0.0


def test_screen_clean_point_not_dirty():
    """If all bounds (after decay) stay above d, the point is clean."""
    b, k = 8, 4
    lb = jnp.full((b, k), 10.0, dtype=jnp.float32)
    p = jnp.zeros((k,), dtype=jnp.float32)
    d = jnp.ones((b,), dtype=jnp.float32)
    lbl = jnp.zeros((b,), dtype=jnp.int32)
    _, dirty = distance.bound_screen(lb, p, d, lbl, tile_b=b)
    assert (np.asarray(dirty) == 0).all()


def test_screen_own_centroid_never_triggers():
    """The assigned centroid's own bound must not mark a point dirty."""
    b, k = 8, 4
    lb = jnp.full((b, k), 10.0, dtype=jnp.float32)
    lbl = jnp.asarray(np.arange(b) % k, dtype=jnp.int32)
    lb = lb.at[jnp.arange(b), lbl].set(0.0)   # own bound far below d
    p = jnp.zeros((k,), dtype=jnp.float32)
    d = jnp.ones((b,), dtype=jnp.float32)
    _, dirty = distance.bound_screen(lb, p, d, lbl, tile_b=b)
    assert (np.asarray(dirty) == 0).all()


def test_stats_counts_sum_to_batch():
    rng = _rng(11)
    x, c = make_xc(rng, 256, 32, 8)
    lbl, d2 = ref.assign_ref(x, c)
    _, v, sse = distance.cluster_stats(x, lbl, d2, 8, tile_b=256)
    assert float(jnp.sum(v)) == 256.0
    np.testing.assert_allclose(float(jnp.sum(sse)), float(jnp.sum(d2)),
                               rtol=1e-5)


def test_multi_tile_grid_consistency():
    """Results must not depend on how the batch is tiled."""
    rng = _rng(13)
    x, c = make_xc(rng, 512, 24, 6)
    cn = jnp.sum(c * c, axis=1)
    l1, d1 = distance.assign(x, c, cn, tile_b=512)
    l2, d2 = distance.assign(x, c, cn, tile_b=128)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-6)
