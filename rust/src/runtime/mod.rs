//! PJRT runtime: loads the AOT-compiled Pallas/XLA artifacts and serves
//! them to the Layer-3 hot path.
//!
//! The interchange format is HLO *text* (`artifacts/*.hlo.txt` + a JSON
//! manifest), produced once by `python/compile/aot.py` — see
//! DESIGN.md. At startup we compile every manifest entry on the PJRT
//! CPU client; per round the [`executor::XlaEngine`] pads batches to a
//! compiled tile shape and executes.

pub mod artifact;
pub mod executor;

use crate::kmeans::assign::AssignEngine;

/// Build the XLA-backed assignment engine from an artifacts directory.
pub fn make_engine(artifacts_dir: &str) -> anyhow::Result<Box<dyn AssignEngine>> {
    let engine = executor::XlaEngine::load(artifacts_dir)?;
    Ok(Box::new(engine))
}
