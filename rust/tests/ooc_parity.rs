//! Bigger-than-RAM ingest parity: a session whose row buffer is
//! spilled to a disk-backed shard file must be **bit-identical** to the
//! same session kept fully in RAM — same centroids, same labels, same
//! predict bits, same snapshot bytes in both formats — across an
//! interleaved ingest/step workload (dense and sparse). Also covers the
//! binary checkpoint path end to end: a WAL configured for the binary
//! sidecar format checkpoints spilled models, recovers them bit-exactly,
//! and the recovered registry re-spills them through the same funnel.

use nmbkm::config::{Algo, Rho, RunConfig};
use nmbkm::data::gaussian::GaussianMixture;
use nmbkm::data::rcv1::Rcv1Sim;
use nmbkm::data::shard::ShardKind;
use nmbkm::data::{Data, Storage};
use nmbkm::serve::protocol::{self, Request};
use nmbkm::serve::wal::{self, FsyncPolicy};
use nmbkm::serve::{
    ModelRegistry, OnlineSession, SnapshotFormat, SpillConfig, WireRow,
};
use nmbkm::util::json::Json;
use std::fs;
use std::path::PathBuf;

fn cfg(k: usize, b0: usize, seed: u64) -> RunConfig {
    RunConfig {
        algo: Algo::TbRho,
        k,
        b0,
        rho: Rho::Infinite,
        threads: 2,
        seed,
        max_rounds: 50,
        max_seconds: 60.0,
        eval_every_secs: 0.0,
        ..Default::default()
    }
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("nmbkm-ooc-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    fs::create_dir_all(&d).unwrap();
    d
}

fn dense_rows(data: &Data, lo: usize, hi: usize) -> Vec<Vec<f32>> {
    let mut row = vec![0f32; data.dim()];
    (lo..hi)
        .map(|i| {
            data.write_row_dense(i, &mut row);
            row.clone()
        })
        .collect()
}

fn sparse_rows(data: &Data, lo: usize, hi: usize) -> Vec<WireRow> {
    let Storage::Sparse(m) = &data.storage else {
        panic!("sparse_rows needs CSR data");
    };
    (lo..hi)
        .map(|i| {
            let (idx, vals) = m.row(i);
            WireRow::Sparse {
                dim: data.dim(),
                idx: idx.to_vec(),
                vals: vals.to_vec(),
            }
        })
        .collect()
}

fn snapshot_bytes(s: &OnlineSession, format: SnapshotFormat) -> Vec<u8> {
    let mut buf = Vec::new();
    s.write_snapshot_as(true, format, &mut buf).unwrap();
    buf
}

fn bits(x: &[f32]) -> Vec<u32> {
    x.iter().map(|v| v.to_bits()).collect()
}

/// Drive `ram` and `ooc` through the identical interleaved workload and
/// assert bit-identity at every boundary the system exposes.
fn assert_parity(
    ram: &mut OnlineSession,
    ooc: &mut OnlineSession,
    ingests: &[Vec<WireRow>],
    queries: &[Vec<f32>],
) {
    assert!(ooc.data().is_sharded(), "ooc session must be disk-backed");
    assert!(!ram.data().is_sharded());
    for chunk in ingests {
        let na = ram.ingest_wire(chunk).unwrap();
        let nb = ooc.ingest_wire(chunk).unwrap();
        assert_eq!(na, nb);
        let ra = ram.step(2, f64::INFINITY).unwrap();
        let rb = ooc.step(2, f64::INFINITY).unwrap();
        assert_eq!(ra.rounds_run, rb.rounds_run);
    }
    // predicts answer with the same bits
    let (la, da) = ram.predict_rows(queries).unwrap();
    let (lb, db) = ooc.predict_rows(queries).unwrap();
    assert_eq!(la, lb, "labels diverged between RAM and disk-backed runs");
    assert_eq!(bits(&da), bits(&db), "distances diverged");
    // full serialised state is byte-identical in both formats — this
    // covers centroids, sufficient stats, labels, dist2, rng and the
    // materialised data section in one comparison
    assert_eq!(
        snapshot_bytes(ram, SnapshotFormat::Json),
        snapshot_bytes(ooc, SnapshotFormat::Json),
        "JSON snapshots diverged"
    );
    assert_eq!(
        snapshot_bytes(ram, SnapshotFormat::Binary),
        snapshot_bytes(ooc, SnapshotFormat::Binary),
        "binary snapshots diverged"
    );
}

#[test]
fn dense_ooc_ingest_matches_ram_bit_for_bit() {
    let dir = tmpdir("dense");
    let data = GaussianMixture::default_spec(5, 8).generate(400, 3);
    let c = cfg(5, 32, 7);
    let mut ram = OnlineSession::new(c.clone(), 8).unwrap();
    let mut ooc = OnlineSession::new(c, 8).unwrap();
    let shard_path = dir.join("dense.rows");
    // tiny resident budget: with 400 rows over 1024-row blocks this
    // still exercises the cache, and the budget bound below proves the
    // pinned set never exceeded it
    ooc.spill_to(&shard_path, 64).unwrap();
    let ingests: Vec<Vec<WireRow>> = [(0, 60), (60, 200), (200, 400)]
        .iter()
        .map(|&(lo, hi)| {
            dense_rows(&data, lo, hi)
                .into_iter()
                .map(WireRow::Dense)
                .collect()
        })
        .collect();
    let queries = dense_rows(&data, 0, 16);
    assert_parity(&mut ram, &mut ooc, &ingests, &queries);
    let store = ooc.shard_store().unwrap();
    assert!(
        store.peak_cached_blocks() <= store.cache_cap(),
        "pinned blocks {} exceeded the cache budget {}",
        store.peak_cached_blocks(),
        store.cache_cap()
    );
    assert!(shard_path.exists());
    drop(ooc);
    assert!(
        !shard_path.exists(),
        "dropping the session must delete its shard file"
    );
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn sparse_ooc_ingest_matches_ram_bit_for_bit() {
    let dir = tmpdir("sparse");
    let data = Rcv1Sim {
        vocab: 200,
        topic_vocab: 30,
        ..Default::default()
    }
    .generate(400, 9);
    let c = cfg(6, 32, 5);
    // both sessions start from the same 60-row CSR prefix; the spill
    // re-writes those resident rows through the shard codec, so the
    // prefix itself is part of what parity proves
    let prefix = data.slice(0, 60);
    let mut ram = OnlineSession::from_data(prefix.clone(), c.clone()).unwrap();
    let mut ooc = OnlineSession::from_data(prefix, c).unwrap();
    let shard_path = dir.join("sparse.rows");
    ooc.spill_to(&shard_path, 32).unwrap();
    let ingests: Vec<Vec<WireRow>> = [(60, 150), (150, 280), (280, 400)]
        .iter()
        .map(|&(lo, hi)| sparse_rows(&data, lo, hi))
        .collect();
    let queries = dense_rows(&data, 0, 12);
    assert_parity(&mut ram, &mut ooc, &ingests, &queries);
    let store = ooc.shard_store().unwrap();
    assert_eq!(store.kind(), ShardKind::Sparse);
    assert!(store.peak_cached_blocks() <= store.cache_cap());
    let _ = fs::remove_dir_all(&dir);
}

/// Run one request through the real protocol layer so WAL appends fire
/// exactly as in production.
fn exec(reg: &ModelRegistry, req: &Request) -> Json {
    let (resp, _) = protocol::handle_request(reg, req);
    assert_eq!(
        resp.get("ok").and_then(Json::as_bool),
        Some(true),
        "request failed: {}",
        resp.to_string()
    );
    resp
}

fn model_bytes(reg: &ModelRegistry, name: &str) -> String {
    reg.resolve(Some(name))
        .unwrap()
        .with_session(|s| Ok(s.snapshot(true)?.to_json().to_string()))
        .unwrap()
}

/// A WAL configured for binary checkpoints over a spill-configured
/// registry: the checkpoint file is a binary sidecar, recovery loads it
/// by sniffing, and the recovered model — re-spilled through the same
/// registry funnel — is bit-identical to the pre-crash one.
#[test]
fn binary_checkpoints_recover_spilled_models_bit_exactly() {
    let wal_dir = tmpdir("walbin");
    let data_dir = tmpdir("walbin-data");
    let data = GaussianMixture::default_spec(4, 6).generate(300, 21);
    let spill = SpillConfig {
        dir: data_dir.clone(),
        max_resident_rows: 48,
    };

    let reg = ModelRegistry::new();
    reg.set_spill(Some(spill.clone()));
    reg.set_snapshot_format(SnapshotFormat::Binary);
    let rec = wal::recover_as(
        &wal_dir,
        FsyncPolicy::Always,
        u64::MAX,
        SnapshotFormat::Binary,
        &reg,
    )
    .unwrap();
    reg.attach_wal(rec.wal.clone());

    exec(
        &reg,
        &Request::Create {
            model: Some("m1".into()),
            dim: data.dim(),
            cfg: cfg(4, 16, 11),
        },
    );
    let points: Vec<WireRow> = dense_rows(&data, 0, 120)
        .into_iter()
        .map(WireRow::Dense)
        .collect();
    exec(
        &reg,
        &Request::Ingest {
            model: Some("m1".into()),
            points,
            rounds: 3,
            seconds: f64::INFINITY,
        },
    );
    // the wire-created model went through the spill funnel
    let sharded = reg
        .resolve(Some("m1"))
        .unwrap()
        .with_session(|s| Ok(s.data().is_sharded()))
        .unwrap();
    assert!(sharded, "create must route through the registry spill funnel");
    let before = model_bytes(&reg, "m1");

    assert!(rec.wal.checkpoint(&reg).unwrap());
    let ckpt = wal_dir.join("ckpt-m1.bin");
    assert!(ckpt.exists(), "binary WAL checkpoints are .bin sidecars");
    let head = fs::read(&ckpt).unwrap();
    assert_eq!(&head[..8], b"NMBKMSB1", "checkpoint must be binary-coded");
    drop(rec);
    drop(reg);

    // recover into a fresh registry with the same spill policy
    let reg2 = ModelRegistry::new();
    reg2.set_spill(Some(spill));
    reg2.set_snapshot_format(SnapshotFormat::Binary);
    let rec2 = wal::recover_as(
        &wal_dir,
        FsyncPolicy::Always,
        u64::MAX,
        SnapshotFormat::Binary,
        &reg2,
    )
    .unwrap();
    reg2.attach_wal(rec2.wal.clone());
    assert_eq!(rec2.resumed_models, 1);
    let resharded = reg2
        .resolve(Some("m1"))
        .unwrap()
        .with_session(|s| Ok(s.data().is_sharded()))
        .unwrap();
    assert!(resharded, "recovery must route through the spill funnel too");
    assert_eq!(
        before,
        model_bytes(&reg2, "m1"),
        "recovered model diverged from the checkpointed one"
    );
    // and it keeps training: the replayed state is live, not a husk
    exec(
        &reg2,
        &Request::Step {
            model: Some("m1".into()),
            rounds: 1,
            seconds: f64::INFINITY,
        },
    );
    drop(rec2);
    drop(reg2);
    let _ = fs::remove_dir_all(&wal_dir);
    let _ = fs::remove_dir_all(&data_dir);
}
