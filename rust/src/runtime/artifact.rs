//! Artifact manifest: what `python/compile/aot.py` exported.
//!
//! The manifest is the contract between the build-time python layer and
//! the rust runtime; this module parses and validates it with the
//! in-house JSON reader (no serde offline).

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// dtype of a program input/output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "float32" => Ok(Dtype::F32),
            "int32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype '{other}'"),
        }
    }
}

/// One tensor signature.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSig {
    pub dtype: Dtype,
    pub shape: Vec<usize>,
}

impl TensorSig {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One exported program.
#[derive(Clone, Debug)]
pub struct Entry {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub k: usize,
    pub batches: Vec<usize>,
    pub dims: Vec<usize>,
    pub fingerprint: String,
    pub entries: Vec<Entry>,
}

fn parse_sig(v: &Json) -> Result<TensorSig> {
    let arr = v.as_arr().ok_or_else(|| anyhow!("signature not an array"))?;
    let dtype = Dtype::parse(
        arr.first()
            .and_then(|d| d.as_str())
            .ok_or_else(|| anyhow!("missing dtype"))?,
    )?;
    let shape = arr
        .get(1)
        .and_then(|s| s.as_arr())
        .ok_or_else(|| anyhow!("missing shape"))?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    Ok(TensorSig { dtype, shape })
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let v = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let k = v
            .get("k")
            .and_then(|x| x.as_usize())
            .ok_or_else(|| anyhow!("manifest missing k"))?;
        let nums = |key: &str| -> Result<Vec<usize>> {
            v.get(key)
                .and_then(|x| x.as_arr())
                .ok_or_else(|| anyhow!("manifest missing {key}"))?
                .iter()
                .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad {key}")))
                .collect()
        };
        let batches = nums("batches")?;
        let dims = nums("dims")?;
        let fingerprint = v
            .get("fingerprint")
            .and_then(|x| x.as_str())
            .unwrap_or_default()
            .to_string();
        let mut entries = vec![];
        for e in v
            .get("entries")
            .and_then(|x| x.as_arr())
            .ok_or_else(|| anyhow!("manifest missing entries"))?
        {
            let name = e
                .get("name")
                .and_then(|x| x.as_str())
                .ok_or_else(|| anyhow!("entry missing name"))?
                .to_string();
            let file = dir.join(
                e.get("file")
                    .and_then(|x| x.as_str())
                    .ok_or_else(|| anyhow!("entry missing file"))?,
            );
            let sigs = |key: &str| -> Result<Vec<TensorSig>> {
                e.get(key)
                    .and_then(|x| x.as_arr())
                    .ok_or_else(|| anyhow!("entry missing {key}"))?
                    .iter()
                    .map(parse_sig)
                    .collect()
            };
            entries.push(Entry {
                name,
                file,
                inputs: sigs("inputs")?,
                outputs: sigs("outputs")?,
            });
        }
        anyhow::ensure!(!entries.is_empty(), "manifest has no entries");
        Ok(Manifest { k, batches, dims, fingerprint, entries })
    }

    pub fn entry(&self, name: &str) -> Option<&Entry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Smallest compiled dim ≥ `d`, if any.
    pub fn fit_dim(&self, d: usize) -> Option<usize> {
        self.dims.iter().cloned().filter(|&x| x >= d).min()
    }

    /// Largest compiled batch tile ≤ `n`, falling back to the smallest.
    pub fn fit_batch(&self, n: usize) -> usize {
        self.batches
            .iter()
            .cloned()
            .filter(|&b| b <= n)
            .max()
            .unwrap_or_else(|| self.batches.iter().cloned().min().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
 "k": 64, "batches": [2048, 256], "dims": [64, 784],
 "fingerprint": "deadbeef",
 "entries": [
  {"name": "assign_b256_d64_k64", "file": "assign_b256_d64_k64.hlo.txt",
   "inputs": [["float32", [256, 64]], ["float32", [64, 64]], ["float32", [64]]],
   "outputs": [["int32", [256]], ["float32", [256]]]}
 ]}"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/arts")).unwrap();
        assert_eq!(m.k, 64);
        assert_eq!(m.batches, vec![2048, 256]);
        let e = m.entry("assign_b256_d64_k64").unwrap();
        assert_eq!(e.inputs[0].shape, vec![256, 64]);
        assert_eq!(e.outputs[0].dtype, Dtype::I32);
        assert_eq!(e.file, Path::new("/tmp/arts/assign_b256_d64_k64.hlo.txt"));
        assert_eq!(e.inputs[0].numel(), 256 * 64);
    }

    #[test]
    fn fit_rules() {
        let m = Manifest::parse(SAMPLE, Path::new("/x")).unwrap();
        assert_eq!(m.fit_dim(10), Some(64));
        assert_eq!(m.fit_dim(64), Some(64));
        assert_eq!(m.fit_dim(300), Some(784));
        assert_eq!(m.fit_dim(10_000), None);
        assert_eq!(m.fit_batch(100), 256);
        assert_eq!(m.fit_batch(256), 256);
        assert_eq!(m.fit_batch(5000), 2048);
    }

    #[test]
    fn rejects_bad_manifests() {
        assert!(Manifest::parse("{}", Path::new("/x")).is_err());
        assert!(Manifest::parse("[1,2]", Path::new("/x")).is_err());
        assert!(
            Manifest::parse(r#"{"k":64,"batches":[1],"dims":[1],"entries":[]}"#, Path::new("/x"))
                .is_err()
        );
    }

    #[test]
    fn real_manifest_if_built() {
        // when `make artifacts` has run, validate the real thing
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.entry(&format!("assign_b256_d64_k{}", m.k)).is_some());
            for e in &m.entries {
                assert!(e.file.exists(), "missing {:?}", e.file);
            }
        }
    }
}
