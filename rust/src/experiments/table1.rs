//! Table 1: mb implementation throughput — time to process N datapoints.
//!
//! The paper compares its own mb implementation against scikit-learn and
//! sofia-ml; neither exists in this offline image, so the ablation that
//! drives the paper's discussion (Supp. A.1) is run instead: the
//! Algorithm-1 per-sample centroid update (the WWW'10 formulation,
//! structurally what sklearn/sofia do) versus the Algorithm-8 S/v
//! reformulation ("our"), on dense infMNIST-sim and sparse RCV1-sim.
//! The paper's point — formulation dominates runtime, most dramatically
//! for sparse data where centroid scaling is the expensive op — is
//! exactly what this table measures. The XLA-engine row additionally
//! reports the Pallas/PJRT dense path.

#[cfg(test)]
use crate::config::{Algo, Engine, RunConfig};
use crate::coordinator::progress::{results_dir, Table};
use crate::data::Dataset;
use crate::experiments::common::{self, ExpOpts};
use crate::kmeans::minibatch::{Formulation, MiniBatch};
use crate::kmeans::{init, Clusterer, Ctx};
use crate::util::timer;

/// Time one epoch (N points) of mb with a given formulation/engine.
/// Returns seconds.
pub fn time_epoch(
    ds: &Dataset,
    formulation: Formulation,
    engine: &dyn crate::kmeans::assign::AssignEngine,
    threads: usize,
    b: usize,
) -> f64 {
    let data = crate::data::shuffle::shuffled(&ds.train, 0);
    let k = 50.min(data.n() / 4).max(2);
    let mut alg = MiniBatch::new(init::first_k(&data, k), data.n(), b, formulation);
    let mut ctx = Ctx {
        data: &data,
        engine,
        pool: crate::coordinator::Pool::new(threads),
        rng: crate::util::rng::Pcg64::new(0, 0),
    };
    let rounds = data.n().div_ceil(b);
    let (_, secs) = timer::time_it(|| {
        for _ in 0..rounds {
            alg.round(&mut ctx);
        }
    });
    secs
}

pub struct Row {
    pub dataset: String,
    pub implementation: String,
    pub n: usize,
    pub secs: f64,
}

pub fn run(opts: &ExpOpts) -> anyhow::Result<Vec<Row>> {
    let b = common::default_b0(opts.scale) * 2;
    let native = crate::kmeans::assign::NativeEngine::default();
    let xla: Option<Box<dyn crate::kmeans::assign::AssignEngine + Send>> =
        crate::runtime::make_engine("artifacts").ok();
    let mut rows = Vec::new();
    for ds in [common::infmnist(opts.scale), common::rcv1(opts.scale)] {
        println!("== Table 1 on {} ==", ds.summary());
        let mut push = |implementation: &str, secs: f64| {
            println!("   {:<26} {:>8.3}s / {} points", implementation, secs, ds.train.n());
            rows.push(Row {
                dataset: ds.name.clone(),
                implementation: implementation.to_string(),
                n: ds.train.n(),
                secs,
            });
        };
        push(
            "alg8 S/v (our)",
            time_epoch(&ds, Formulation::Alg8, &native, opts.threads, b),
        );
        push(
            "alg1 per-sample (baseline)",
            time_epoch(&ds, Formulation::Alg1, &native, opts.threads, b),
        );
        if let Some(x) = &xla {
            if !ds.train.is_sparse() {
                push(
                    "alg8 + xla engine",
                    time_epoch(&ds, Formulation::Alg8, x.as_ref(), opts.threads, b),
                );
            }
        }
    }
    // CSV
    let mut t = Table::new(&["dataset", "implementation", "n", "secs"]);
    for r in &rows {
        t.push(vec![
            r.dataset.clone(),
            r.implementation.clone(),
            r.n.to_string(),
            format!("{:.4}", r.secs),
        ]);
    }
    let path = results_dir().join("table1_throughput.csv");
    t.write_csv(&path)?;
    println!("   wrote {}", path.display());
    check_shape(&rows);
    Ok(rows)
}

/// Paper shape: Alg-8 ≤ Alg-1 everywhere, with the sparse gap being the
/// decisive one (sklearn's 63.6s vs our 15.2s was 4×; the mechanism is
/// the per-sample dense-centroid scaling Alg-1 performs).
pub fn check_shape(rows: &[Row]) {
    for dsname in ["infmnist-sim", "rcv1-sim"] {
        let get = |imp: &str| {
            rows.iter()
                .find(|r| r.dataset == dsname && r.implementation.starts_with(imp))
                .map(|r| r.secs)
        };
        if let (Some(our), Some(base)) = (get("alg8 S/v"), get("alg1")) {
            let ok = our <= base * 1.05;
            println!(
                "   [shape {dsname}] alg8 ≤ alg1: {} ({our:.3}s vs {base:.3}s, {:.2}x)",
                if ok { "PASS" } else { "WARN" },
                base / our
            );
        }
    }
}

/// Run the minimal unit-sized version (tests).
#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Engine;

    #[test]
    fn epoch_timing_positive_and_formulations_run() {
        let ds = common::gaussian_small();
        let native = crate::kmeans::assign::NativeEngine::default();
        let s8 = time_epoch(&ds, Formulation::Alg8, &native, 2, 512);
        let s1 = time_epoch(&ds, Formulation::Alg1, &native, 2, 512);
        assert!(s8 > 0.0 && s1 > 0.0);
    }

    #[test]
    fn unused_imports_quiet() {
        // keep the RunConfig/Algo/Engine imports meaningful
        let _ = RunConfig { algo: Algo::Mb, engine: Engine::Native, ..Default::default() };
    }
}
