//! The serving wire protocol: JSON Lines, dependency-free, transport
//! agnostic (stdio and TCP both speak it — see `serve::server`).
//!
//! One request per line, one response per line, in order:
//!
//! ```text
//! → {"op":"ingest","points":[[…],[…]],"rounds":2}
//! ← {"ok":true,"op":"ingest","added":2,"n":10002,"rounds_run":2,…}
//! → {"op":"predict","points":[[…]]}
//! ← {"ok":true,"op":"predict","labels":[7],"d2":[0.125]}
//! → {"op":"stats"}
//! ← {"ok":true,"op":"stats","initialised":true,"n_total":10002,…}
//! → {"op":"snapshot","path":"model.json"}
//! ← {"ok":true,"op":"snapshot","path":"model.json","bytes":123456}
//! → {"op":"shutdown"}
//! ← {"ok":true,"op":"shutdown"}
//! ```
//!
//! Errors never kill the stream: a malformed or failing request gets
//! `{"ok":false,"error":"…"}` and the loop continues. `d2` values are
//! exact — f32 widens losslessly to the f64 JSON number and the parser
//! round-trips f64, so predict responses carry the same bits the engine
//! produced.

use crate::serve::session::OnlineSession;
use crate::util::json::{self, Json};
use anyhow::{anyhow, bail, ensure, Result};
use std::io::{BufRead, Write};

/// A parsed request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Append points, then (optionally) run training rounds over the
    /// grown buffer.
    Ingest { points: Vec<Vec<f32>>, rounds: usize, seconds: f64 },
    /// Nearest-centroid queries.
    Predict { points: Vec<Vec<f32>> },
    /// Run training rounds without new data.
    Step { rounds: usize, seconds: f64 },
    /// Observability counters.
    Stats,
    /// Persist the model (and, unless `include_data` is false, the
    /// buffer) to a snapshot file on the server's filesystem.
    Snapshot { path: String, include_data: bool },
    /// Stop serving (closes the stream; a TCP server exits its accept
    /// loop).
    Shutdown,
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Request> {
    let v = Json::parse(line).map_err(|e| anyhow!("bad request json: {e}"))?;
    let op = v
        .get("op")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("request missing string field 'op'"))?;
    let rounds = |default: usize| -> Result<usize> {
        match v.get("rounds") {
            None => Ok(default),
            Some(x) => x
                .as_f64()
                .filter(|r| *r >= 0.0 && r.fract() == 0.0)
                .map(|r| r as usize)
                .ok_or_else(|| anyhow!("'rounds' must be a non-negative integer")),
        }
    };
    let seconds = || -> Result<f64> {
        match v.get("seconds") {
            None => Ok(f64::INFINITY),
            Some(x) => x
                .as_f64()
                .filter(|s| *s >= 0.0)
                .ok_or_else(|| anyhow!("'seconds' must be a non-negative number")),
        }
    };
    Ok(match op {
        "ingest" => Request::Ingest {
            points: parse_points(&v)?,
            rounds: rounds(1)?,
            seconds: seconds()?,
        },
        "predict" => Request::Predict { points: parse_points(&v)? },
        "step" => Request::Step { rounds: rounds(1)?, seconds: seconds()? },
        "stats" => Request::Stats,
        "snapshot" => Request::Snapshot {
            path: v
                .get("path")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("snapshot op needs a 'path' string"))?
                .to_string(),
            include_data: v
                .get("include_data")
                .and_then(Json::as_bool)
                .unwrap_or(true),
        },
        "shutdown" | "quit" => Request::Shutdown,
        other => bail!(
            "unknown op '{other}' (ingest|predict|step|stats|snapshot|shutdown)"
        ),
    })
}

fn parse_points(v: &Json) -> Result<Vec<Vec<f32>>> {
    let arr = v
        .get("points")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("request needs 'points': [[…], …]"))?;
    let mut out = Vec::with_capacity(arr.len());
    for (t, row) in arr.iter().enumerate() {
        let row = row
            .as_arr()
            .ok_or_else(|| anyhow!("points[{t}] is not an array"))?;
        let mut r = Vec::with_capacity(row.len());
        for (u, x) in row.iter().enumerate() {
            let x = x
                .as_f64()
                .ok_or_else(|| anyhow!("points[{t}][{u}] is not a number"))?;
            // a single inf/NaN coordinate would poison the sufficient
            // statistics (and every later snapshot) for good; the check
            // is on the narrowed value so f64s beyond f32 range are
            // caught too
            ensure!(
                (x as f32).is_finite(),
                "points[{t}][{u}] is not a finite f32 ({x})"
            );
            r.push(x as f32);
        }
        out.push(r);
    }
    Ok(out)
}

/// Execute one request against the session. Never fails: errors become
/// `ok:false` responses. The bool is true when the stream should close.
pub fn handle_line(session: &mut OnlineSession, line: &str) -> (Json, bool) {
    let req = match parse_request(line) {
        Ok(r) => r,
        Err(e) => return (err_json(&e), false),
    };
    match execute(session, &req) {
        Ok(resp) => (resp, matches!(req, Request::Shutdown)),
        Err(e) => (err_json(&e), false),
    }
}

fn err_json(e: &anyhow::Error) -> Json {
    json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", json::s(&format!("{e:#}"))),
    ])
}

fn execute(session: &mut OnlineSession, req: &Request) -> Result<Json> {
    Ok(match req {
        Request::Ingest { points, rounds, seconds } => {
            let n = session.ingest_rows(points)?;
            let rep = session.step(*rounds, *seconds)?;
            let mut fields = vec![
                ("ok", Json::Bool(true)),
                ("op", json::s("ingest")),
                ("added", json::num(points.len() as f64)),
                ("n", json::num(n as f64)),
                ("rounds_run", json::num(rep.rounds_run as f64)),
                ("initialised", Json::Bool(session.initialised())),
            ];
            if let Some(info) = rep.last {
                fields.push(("batch", json::num(info.batch as f64)));
                fields.push(("train_mse", json::num(info.train_mse)));
            }
            json::obj(fields)
        }
        Request::Predict { points } => {
            let (lbl, d2) = session.predict_rows(points)?;
            json::obj(vec![
                ("ok", Json::Bool(true)),
                ("op", json::s("predict")),
                (
                    "labels",
                    Json::Arr(lbl.iter().map(|&j| json::num(j as f64)).collect()),
                ),
                (
                    "d2",
                    Json::Arr(d2.iter().map(|&x| json::num(x as f64)).collect()),
                ),
            ])
        }
        Request::Step { rounds, seconds } => {
            let rep = session.step(*rounds, *seconds)?;
            let mut fields = vec![
                ("ok", Json::Bool(true)),
                ("op", json::s("step")),
                ("rounds_run", json::num(rep.rounds_run as f64)),
                ("converged", Json::Bool(rep.converged)),
                ("waiting_for_points", Json::Bool(rep.waiting_for_points)),
            ];
            if let Some(info) = rep.last {
                fields.push(("batch", json::num(info.batch as f64)));
                fields.push(("train_mse", json::num(info.train_mse)));
            }
            json::obj(fields)
        }
        Request::Stats => {
            let mut resp = session.stats_json();
            if let Json::Obj(m) = &mut resp {
                m.insert("ok".to_string(), Json::Bool(true));
                m.insert("op".to_string(), json::s("stats"));
            }
            resp
        }
        Request::Snapshot { path, include_data } => {
            // clients name a bare file inside the server's snapshot
            // directory; anything path-like is rejected so a remote peer
            // never gets an arbitrary-file-write primitive
            ensure!(
                !path.is_empty()
                    && path != "."
                    && path != ".."
                    && !path.contains('/')
                    && !path.contains('\\')
                    // ':' blocks Windows drive-prefixed names like
                    // "C:evil", which Path::join resolves outside the base
                    && !path.contains(':')
                    && !path.contains('\0'),
                "snapshot 'path' must be a bare file name (it is resolved \
                 inside the server's snapshot directory), got {path:?}"
            );
            let snap = session.snapshot(*include_data)?;
            let target = session.snapshot_dir().join(path);
            snap.save(&target)?;
            let bytes = std::fs::metadata(&target).map(|m| m.len()).unwrap_or(0);
            json::obj(vec![
                ("ok", Json::Bool(true)),
                ("op", json::s("snapshot")),
                ("path", json::s(&target.display().to_string())),
                ("bytes", json::num(bytes as f64)),
            ])
        }
        Request::Shutdown => json::obj(vec![
            ("ok", Json::Bool(true)),
            ("op", json::s("shutdown")),
        ]),
    })
}

/// Drive a whole request stream: read JSONL requests from `input`, write
/// JSONL responses to `output`. Returns true when the stream ended with
/// an explicit shutdown (as opposed to EOF).
pub fn serve_lines<R: BufRead, W: Write>(
    session: &mut OnlineSession,
    input: R,
    output: &mut W,
) -> Result<bool> {
    for line in input.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let (resp, quit) = handle_line(session, &line);
        writeln!(output, "{}", resp.to_string())?;
        output.flush()?;
        if quit {
            return Ok(true);
        }
    }
    Ok(false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Algo, Rho, RunConfig};
    use crate::data::gaussian::GaussianMixture;
    use crate::serve::session;

    fn ready_session() -> OnlineSession {
        let data = GaussianMixture::default_spec(3, 4).generate(300, 1);
        let cfg = RunConfig {
            algo: Algo::GbRho,
            k: 3,
            b0: 32,
            rho: Rho::Infinite,
            threads: 2,
            max_rounds: 5,
            max_seconds: 30.0,
            ..Default::default()
        };
        session::train(&data, &cfg).unwrap().0
    }

    #[test]
    fn parse_request_forms() {
        assert_eq!(parse_request(r#"{"op":"stats"}"#).unwrap(), Request::Stats);
        assert_eq!(
            parse_request(r#"{"op":"shutdown"}"#).unwrap(),
            Request::Shutdown
        );
        let r = parse_request(r#"{"op":"ingest","points":[[1,2],[3,4]]}"#).unwrap();
        assert_eq!(
            r,
            Request::Ingest {
                points: vec![vec![1.0, 2.0], vec![3.0, 4.0]],
                rounds: 1,
                seconds: f64::INFINITY,
            }
        );
        let r = parse_request(r#"{"op":"step","rounds":4,"seconds":0.5}"#).unwrap();
        assert_eq!(r, Request::Step { rounds: 4, seconds: 0.5 });
        let r = parse_request(r#"{"op":"snapshot","path":"m.json","include_data":false}"#)
            .unwrap();
        assert_eq!(
            r,
            Request::Snapshot { path: "m.json".into(), include_data: false }
        );
        for bad in [
            "not json",
            r#"{"no_op":1}"#,
            r#"{"op":"transmogrify"}"#,
            r#"{"op":"predict"}"#,
            r#"{"op":"predict","points":[1]}"#,
            r#"{"op":"predict","points":[["x"]]}"#,
            r#"{"op":"step","rounds":-1}"#,
            r#"{"op":"step","rounds":1.5}"#,
            r#"{"op":"snapshot"}"#,
            r#"{"op":"ingest","points":[[1e400]]}"#,
        ] {
            assert!(parse_request(bad).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn errors_do_not_close_the_stream() {
        let mut s = ready_session();
        let input = "{\"op\":\"bogus\"}\n\n{\"op\":\"stats\"}\n";
        let mut out = Vec::new();
        let shutdown =
            serve_lines(&mut s, std::io::Cursor::new(input), &mut out).unwrap();
        assert!(!shutdown, "EOF, not shutdown");
        let lines: Vec<&str> =
            std::str::from_utf8(&out).unwrap().trim().lines().collect();
        assert_eq!(lines.len(), 2, "blank line skipped, two responses");
        let first = Json::parse(lines[0]).unwrap();
        assert_eq!(first.get("ok").unwrap().as_bool(), Some(false));
        let second = Json::parse(lines[1]).unwrap();
        assert_eq!(second.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(second.get("op").unwrap().as_str(), Some("stats"));
    }

    #[test]
    fn shutdown_terminates_and_reports() {
        let mut s = ready_session();
        let input = "{\"op\":\"shutdown\"}\n{\"op\":\"stats\"}\n";
        let mut out = Vec::new();
        let shutdown =
            serve_lines(&mut s, std::io::Cursor::new(input), &mut out).unwrap();
        assert!(shutdown);
        let lines: Vec<&str> =
            std::str::from_utf8(&out).unwrap().trim().lines().collect();
        assert_eq!(lines.len(), 1, "nothing served after shutdown");
    }

    #[test]
    fn ingest_then_stats_reflects_growth() {
        let mut s = ready_session();
        let input = "{\"op\":\"ingest\",\"points\":[[0.5,0.5,0.5,0.5]],\"rounds\":0}\n\
                     {\"op\":\"stats\"}\n";
        let mut out = Vec::new();
        serve_lines(&mut s, std::io::Cursor::new(input), &mut out).unwrap();
        let lines: Vec<&str> =
            std::str::from_utf8(&out).unwrap().trim().lines().collect();
        let ingest = Json::parse(lines[0]).unwrap();
        assert_eq!(ingest.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(ingest.get("n").unwrap().as_usize(), Some(301));
        let stats = Json::parse(lines[1]).unwrap();
        assert_eq!(stats.get("n_total").unwrap().as_usize(), Some(301));
    }

    #[test]
    fn snapshot_op_confined_to_snapshot_dir() {
        let mut s = ready_session();
        s.set_snapshot_dir(std::env::temp_dir());
        // path-like names are rejected outright
        for bad in ["../escape.json", "/etc/owned", "a/b.json", "C:evil.json", "..", ""] {
            let req = format!(
                "{{\"op\":\"snapshot\",\"path\":{}}}",
                Json::Str(bad.to_string()).to_string()
            );
            let (resp, _) = handle_line(&mut s, &req);
            assert_eq!(
                resp.get("ok").unwrap().as_bool(),
                Some(false),
                "accepted {bad:?}"
            );
        }
        // a bare file name lands inside the configured directory
        let (resp, _) = handle_line(
            &mut s,
            r#"{"op":"snapshot","path":"nmbkm-proto-snap-test.json"}"#,
        );
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
        let written = std::env::temp_dir().join("nmbkm-proto-snap-test.json");
        assert!(written.exists());
        assert!(resp.get("bytes").unwrap().as_usize().unwrap() > 0);
        std::fs::remove_file(&written).ok();
    }

    #[test]
    fn predict_dimension_mismatch_is_an_ok_false() {
        let mut s = ready_session();
        let (resp, quit) =
            handle_line(&mut s, r#"{"op":"predict","points":[[1,2]]}"#);
        assert!(!quit);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("dimension"));
    }
}
