//! Randomised end-to-end property tests over the full stack
//! (hand-rolled harness, DESIGN.md §Testing strategy).

use nmbkm::config::{Algo, Rho, RunConfig};
use nmbkm::data::gaussian::GaussianMixture;
use nmbkm::kmeans::run;
use nmbkm::util::propcheck::Cases;
use nmbkm::util::rng::Pcg64;

fn random_cfg(rng: &mut Pcg64, k: usize) -> RunConfig {
    let algos = [
        Algo::Lloyd,
        Algo::Elkan,
        Algo::Sgd,
        Algo::Mb,
        Algo::MbF,
        Algo::GbRho,
        Algo::TbRho,
    ];
    let rhos = [
        Rho::Finite(1.0),
        Rho::Finite(10.0),
        Rho::Finite(1000.0),
        Rho::Infinite,
    ];
    RunConfig {
        algo: algos[rng.below(algos.len())],
        rho: rhos[rng.below(rhos.len())],
        k,
        b0: 16 + rng.below(200),
        threads: 1 + rng.below(4),
        seed: rng.next_u64(),
        max_rounds: 3 + rng.below(12),
        max_seconds: 30.0,
        eval_every_secs: 0.0,
        stop_on_convergence: rng.next_f64() < 0.5,
        ..Default::default()
    }
}

#[test]
fn any_config_any_shape_terminates_with_finite_state() {
    Cases::new(30).run(|rng| {
        let k = 2 + rng.below(8);
        let n = k * 4 + rng.below(600);
        let d = 2 + rng.below(24);
        let spec = GaussianMixture {
            k,
            d,
            center_spread: 10f64.powf(rng.range_f64(-0.5, 1.2)),
            noise: 10f64.powf(rng.range_f64(-1.0, 0.5)),
            weights: vec![],
        };
        let data = spec.generate(n, rng.next_u64());
        let cfg = random_cfg(rng, k);
        let out = run(&data, None, &cfg)
            .unwrap_or_else(|e| panic!("{cfg:?} failed: {e:#}"));
        // invariants on any run whatsoever:
        assert!(out.rounds >= 1 && out.rounds <= cfg.max_rounds);
        assert!(out.centroids.c.data.iter().all(|x| x.is_finite()),
                "{cfg:?}: non-finite centroid");
        assert!(out.final_mse.is_finite() && out.final_mse >= 0.0);
        // batches never exceed n and never shrink for gb/tb
        if matches!(cfg.algo, Algo::GbRho | Algo::TbRho) {
            let batches: Vec<usize> =
                out.trace.records.iter().map(|r| r.batch).collect();
            for w in batches.windows(2) {
                assert!(w[1] >= w[0], "batch shrank: {batches:?}");
                assert!(w[1] <= n);
            }
        }
    });
}

#[test]
fn quality_never_catastrophically_worse_than_lloyd() {
    // any algorithm given a decent budget should land within a factor
    // of lloyd's local minimum on an easy, well-separated mixture
    Cases::new(8).run(|rng| {
        let k = 3 + rng.below(4);
        let spec = GaussianMixture {
            k,
            d: 8,
            center_spread: 25.0,
            noise: 1.0,
            weights: vec![],
        };
        let data = spec.generate(1_200, rng.next_u64());
        let seed = rng.next_u64();
        let mk = |algo| RunConfig {
            algo,
            k,
            b0: 128,
            rho: Rho::Infinite,
            seed,
            threads: 2,
            max_rounds: 60,
            max_seconds: 10.0,
            eval_every_secs: 0.0,
            ..Default::default()
        };
        let lloyd = run(&data, None, &mk(Algo::Lloyd)).unwrap();
        for algo in [Algo::MbF, Algo::GbRho, Algo::TbRho] {
            let out = run(&data, None, &mk(algo)).unwrap();
            let base = nmbkm::kmeans::state::exact_mse(&data, &lloyd.centroids);
            let got = nmbkm::kmeans::state::exact_mse(&data, &out.centroids);
            assert!(
                got <= base * 3.0 + 1e-9,
                "{algo:?}: mse {got} vs lloyd {base}"
            );
        }
    });
}

#[test]
fn determinism_full_stack() {
    Cases::new(10).run(|rng| {
        let k = 2 + rng.below(5);
        let data = GaussianMixture::default_spec(k, 6)
            .generate(300 + rng.below(300), rng.next_u64());
        let cfg = random_cfg(rng, k);
        let a = run(&data, None, &cfg).unwrap();
        let b = run(&data, None, &cfg).unwrap();
        assert_eq!(a.rounds, b.rounds, "{cfg:?}");
        assert_eq!(a.centroids.c.data, b.centroids.c.data, "{cfg:?}");
    });
}

#[test]
fn sparse_engine_paths_agree_bitwise() {
    // end-to-end form of the sparse kernel invariant: the transposed
    // (SIMD AXPY, blocked, norm-pruned) path, the threaded variant, and
    // the cold-cache gather fallback must all return the same label and
    // distance bits for the same points — path selection (cache warmth,
    // selection size, thread count) must never change results
    use nmbkm::coordinator::Pool;
    use nmbkm::data::rcv1::Rcv1Sim;
    use nmbkm::kmeans::assign::{AssignEngine, NativeEngine, Sel};
    use nmbkm::kmeans::init;
    use nmbkm::linalg::simd;

    if simd::tier() == simd::Tier::Avx2Fma {
        return; // the opt-in FMA tier is documented as not bit-exact
    }
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    Cases::new(6).run(|rng| {
        let n = 300 + rng.below(200);
        let k = 8 + rng.below(8);
        let data = Rcv1Sim {
            vocab: 600,
            topic_vocab: 80,
            ..Default::default()
        }
        .generate(n, rng.next_u64());
        let cent = init::first_k(&data, k);
        let warm = NativeEngine::default();
        let mut l1 = vec![0u32; n];
        let mut d1 = vec![0f32; n];
        warm.assign(&data, Sel::Range(0, n), &cent, &Pool::new(1), &mut l1, &mut d1);
        let (_, builds) = warm.trans_cache_stats().unwrap();
        assert_eq!(builds, 1, "large sparse selection must build the transpose");
        let mut l4 = vec![0u32; n];
        let mut d4 = vec![0f32; n];
        warm.assign(&data, Sel::Range(0, n), &cent, &Pool::new(4), &mut l4, &mut d4);
        assert_eq!(l1, l4, "thread count changed sparse labels");
        assert_eq!(bits(&d1), bits(&d4), "thread count changed sparse distances");
        // cold engine + tiny selection → gather fallback, no transpose
        let cold = NativeEngine::default();
        let m = 32.min(n);
        let mut lg = vec![0u32; m];
        let mut dg = vec![0f32; m];
        cold.assign(&data, Sel::Range(0, m), &cent, &Pool::new(2), &mut lg, &mut dg);
        assert_eq!(
            cold.trans_cache_stats().unwrap(),
            (0, 0),
            "tiny cold selection must stay on the gather path"
        );
        assert_eq!(&l1[..m], &lg[..], "gather vs transposed labels diverged");
        assert_eq!(bits(&d1[..m]), bits(&dg), "gather vs transposed distances diverged");
    });
}
