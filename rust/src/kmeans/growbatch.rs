//! `gb-ρ` — the nested Grow-Batch algorithm (paper §3.2–3.3,
//! Algorithm 7; the ρ = ∞ degenerate form is Algorithm 10).
//!
//! The defining property is *nestedness*: `M_t ⊆ M_{t+1}` — once a point
//! enters the active batch it stays. Because the data is pre-shuffled
//! per seed, the active batch is simply the prefix `[0, b)`; each round
//!
//! 1. reassigns the already-seen prefix `[0, b_o)` exactly (full k
//!    distance computations — `tb-ρ` replaces this step with bounds),
//! 2. ingests the new window `[b_o, b)`,
//! 3. updates centroids from the exact nested-batch statistics, and
//! 4. asks the σ̂_C/p controller whether to double b.

use crate::config::Rho;
use crate::kmeans::assign::Sel;
use crate::kmeans::controller::{self, GrowthPolicy};
use crate::kmeans::state::{batch_mse, Assignments, Centroids, SuffStats, UNASSIGNED};
use crate::kmeans::{Clusterer, Ctx, NestedState, RoundInfo};

pub struct GrowBatch {
    pub(crate) cent: Centroids,
    stats: SuffStats,
    assign: Assignments,
    n: usize,
    /// b_o: number of points already seen (prefix length).
    pub b_prev: usize,
    /// b: current active batch size.
    pub b: usize,
    rho: Rho,
    policy: GrowthPolicy,
    fixed_point: bool,
    /// history of batch sizes, for the nestedness tests
    pub batch_history: Vec<usize>,
}

impl GrowBatch {
    pub fn new(cent: Centroids, n: usize, b0: usize, rho: Rho) -> Self {
        let k = cent.k();
        let d = cent.d();
        Self {
            cent,
            stats: SuffStats::zeros(k, d),
            assign: Assignments::new(n),
            n,
            b_prev: 0,
            b: b0.min(n).max(1),
            rho,
            policy: GrowthPolicy::Double,
            fixed_point: false,
            batch_history: vec![],
        }
    }

    /// Paper §5 future-work: alternative batch-growth laws (ablation).
    pub fn with_policy(mut self, policy: GrowthPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Rebuild mid-run from exported state (`serve` resume path). The
    /// continuation is bit-exact: gb-ρ rounds are deterministic in
    /// (data, centroids, stats, batch cursor).
    pub fn resume(st: NestedState, rho: Rho) -> Self {
        let k = st.cent.k();
        assert_eq!(st.stats.k, k, "stats k mismatch");
        assert_eq!(st.stats.d, st.cent.d(), "stats d mismatch");
        assert_eq!(st.assign.label.len(), st.n, "assignments length != n");
        assert!(st.b_prev <= st.b && st.b <= st.n, "bad batch cursor");
        Self {
            cent: st.cent,
            stats: st.stats,
            assign: st.assign,
            n: st.n,
            b_prev: st.b_prev,
            b: st.b.max(1),
            rho,
            policy: GrowthPolicy::Double,
            fixed_point: false,
            batch_history: vec![],
        }
    }

    /// Exact S/v versus a rebuild over the active prefix (test hook).
    #[cfg(test)]
    pub fn stats_drift(&self, data: &crate::data::Data) -> f64 {
        let fresh = SuffStats::rebuild(
            data,
            self.cent.k(),
            0..self.b_prev,
            &self.assign.label,
            &self.assign.dist2,
        );
        self.stats.max_abs_diff(&fresh)
    }
}

impl Clusterer for GrowBatch {
    fn round(&mut self, ctx: &mut Ctx) -> RoundInfo {
        let k = self.cent.k();
        let (b_o, b) = (self.b_prev, self.b);
        self.batch_history.push(b);
        let mut calcs = 0u64;
        let mut changed = 0u64;

        // 1. reassign the seen prefix [0, b_o)
        if b_o > 0 {
            let mut lbl = vec![0u32; b_o];
            let mut d2 = vec![0f32; b_o];
            calcs += ctx.engine.assign(
                ctx.data,
                Sel::Range(0, b_o),
                &self.cent,
                &ctx.pool,
                &mut lbl,
                &mut d2,
            );
            let (delta, ch) = crate::kmeans::par_reassign_stats(
                ctx.data,
                Sel::Range(0, b_o),
                &self.assign.label[..b_o],
                &lbl,
                &d2,
                k,
                &ctx.pool,
            );
            changed += ch;
            crate::coordinator::merge::Mergeable::merge(&mut self.stats, delta);
            self.assign.label[..b_o].copy_from_slice(&lbl);
            self.assign.dist2[..b_o].copy_from_slice(&d2);
        }

        // 2. ingest the new window [b_o, b)
        if b > b_o {
            let mut lbl = vec![0u32; b - b_o];
            let mut d2 = vec![0f32; b - b_o];
            calcs += ctx.engine.assign(
                ctx.data,
                Sel::Range(b_o, b),
                &self.cent,
                &ctx.pool,
                &mut lbl,
                &mut d2,
            );
            let delta = crate::kmeans::par_add_stats(
                ctx.data,
                Sel::Range(b_o, b),
                &lbl,
                &d2,
                k,
                &ctx.pool,
            );
            crate::coordinator::merge::Mergeable::merge(&mut self.stats, delta);
            self.assign.label[b_o..b].copy_from_slice(&lbl);
            self.assign.dist2[b_o..b].copy_from_slice(&d2);
        }

        // 3. centroid update
        self.stats.update_centroids(&mut self.cent);

        // 4. controller vote
        let decision = controller::decide(self.rho, &self.stats, &self.cent);
        self.b_prev = b;
        self.b = controller::grow(b, self.n, decision, self.policy);
        self.fixed_point =
            b_o == self.n && changed == 0 && self.cent.max_p() == 0.0;

        RoundInfo {
            dist_calcs: calcs,
            bound_skips: 0,
            changed,
            batch: b,
            train_mse: batch_mse(&self.stats),
        }
    }

    fn centroids(&self) -> &Centroids {
        &self.cent
    }

    fn converged(&self) -> bool {
        self.fixed_point
    }

    fn name(&self) -> String {
        format!("gb-{}", self.rho.label())
    }

    fn export_state(&self) -> Option<NestedState> {
        Some(NestedState {
            cent: self.cent.clone(),
            stats: self.stats.clone(),
            assign: self.assign.clone(),
            b_prev: self.b_prev,
            b: self.b,
            n: self.n,
        })
    }

    fn extend_data(&mut self, new_n: usize) -> bool {
        if new_n < self.n {
            return false;
        }
        self.assign.label.resize(new_n, UNASSIGNED);
        self.assign.dist2.resize(new_n, f32::INFINITY);
        self.n = new_n;
        // new unseen points mean the run can no longer be at its global
        // fixed point
        if new_n > self.b_prev {
            self.fixed_point = false;
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian::GaussianMixture;
    use crate::kmeans::assign::NativeEngine;
    use crate::kmeans::init;
    use crate::util::rng::Pcg64;

    /// Shared engine for test contexts (Ctx borrows it for 'static).
    fn test_engine() -> &'static NativeEngine {
        static E: std::sync::OnceLock<NativeEngine> = std::sync::OnceLock::new();
        E.get_or_init(NativeEngine::default)
    }

    fn ctx(data: &crate::data::Data) -> Ctx<'_> {
        Ctx {
            data,
            engine: test_engine(),
            pool: crate::coordinator::Pool::new(2),
            rng: Pcg64::new(3, 3),
        }
    }

    #[test]
    fn batches_are_nested_and_double_or_stay() {
        let data = GaussianMixture::default_spec(4, 6).generate(1000, 1);
        let mut alg =
            GrowBatch::new(init::first_k(&data, 4), 1000, 50, Rho::Infinite);
        let mut c = ctx(&data);
        for _ in 0..25 {
            alg.round(&mut c);
        }
        let h = &alg.batch_history;
        for w in h.windows(2) {
            assert!(
                w[1] == w[0] || w[1] == (2 * w[0]).min(1000),
                "batch went {} -> {}",
                w[0],
                w[1]
            );
        }
        assert!(h[0] == 50);
        // on an easy mixture the batch must eventually grow
        assert!(*h.last().unwrap() > 50, "batch never grew: {h:?}");
    }

    #[test]
    fn stats_stay_exact_under_churn() {
        let data = GaussianMixture { k: 3, d: 5, center_spread: 2.0, noise: 1.5, weights: vec![] }
            .generate(600, 7);
        let mut alg =
            GrowBatch::new(init::first_k(&data, 3), 600, 32, Rho::Finite(10.0));
        let mut c = ctx(&data);
        for round in 0..20 {
            alg.round(&mut c);
            let drift = alg.stats_drift(&data);
            assert!(drift < 1e-5, "round {round}: drift {drift}");
        }
    }

    #[test]
    fn converges_to_lloyd_fixed_point() {
        // Once b = N, gb-∞ is exactly lloyd; it must reach a fixed point
        // and that fixed point must be lloyd-stable.
        let data = GaussianMixture::default_spec(3, 4).generate(300, 5);
        let mut alg =
            GrowBatch::new(init::first_k(&data, 3), 300, 30, Rho::Infinite);
        let mut c = ctx(&data);
        for _ in 0..200 {
            alg.round(&mut c);
            if alg.converged() {
                break;
            }
        }
        assert!(alg.converged(), "gb-∞ failed to converge in 200 rounds");
        // fixed point check: one lloyd round moves nothing
        let mut cent = alg.cent.clone();
        let mut labels = vec![0u32; 300];
        let mse_before = crate::kmeans::state::exact_mse(&data, &cent);
        crate::kmeans::lloyd::reference_round(&data, &mut cent, &mut labels);
        let mse_after = crate::kmeans::state::exact_mse(&data, &cent);
        assert!(
            (mse_before - mse_after).abs() < 1e-9 * (1.0 + mse_before),
            "not a lloyd fixed point: {mse_before} vs {mse_after}"
        );
    }

    #[test]
    fn export_resume_continues_bit_exactly() {
        let data = GaussianMixture::default_spec(4, 6).generate(900, 11);
        let mut full =
            GrowBatch::new(init::first_k(&data, 4), 900, 64, Rho::Infinite);
        let mut half =
            GrowBatch::new(init::first_k(&data, 4), 900, 64, Rho::Infinite);
        let mut c = ctx(&data);
        for _ in 0..4 {
            full.round(&mut c);
            half.round(&mut c);
        }
        let st = Clusterer::export_state(&half).unwrap();
        let mut resumed = GrowBatch::resume(st, Rho::Infinite);
        for _ in 0..4 {
            full.round(&mut c);
            resumed.round(&mut c);
        }
        assert_eq!(full.cent.c.data, resumed.cent.c.data);
        assert_eq!(full.b, resumed.b);
        assert_eq!(full.assign.label, resumed.assign.label);
        assert_eq!(full.stats.v, resumed.stats.v);
    }

    #[test]
    fn extend_data_appends_unseen_points() {
        let data = GaussianMixture::default_spec(3, 5).generate(800, 2);
        let head = data.slice(0, 500);
        let mut alg =
            GrowBatch::new(init::first_k(&head, 3), 500, 64, Rho::Infinite);
        let mut c = ctx(&head);
        for _ in 0..3 {
            alg.round(&mut c);
        }
        assert!(Clusterer::extend_data(&mut alg, 800));
        assert!(!Clusterer::extend_data(&mut alg, 700), "never shrinks");
        let mut c = ctx(&data);
        for _ in 0..200 {
            alg.round(&mut c);
            if alg.b_prev > 500 {
                break;
            }
        }
        // the controller eventually grows into the appended points, each
        // counted exactly once: Σv equals the seen-prefix length
        assert!(alg.b_prev > 500, "batch never grew into new points");
        let total: f64 = alg.stats.v.iter().sum();
        assert_eq!(total as usize, alg.b_prev);
        assert!(alg.stats_drift(&data) < 1e-5);
    }

    #[test]
    fn rho_one_grows_faster_than_rho_large() {
        // small ρ votes to double more eagerly (risking redundancy);
        // large ρ is conservative (risking premature finetuning)
        let data = GaussianMixture { k: 4, d: 8, center_spread: 3.0, noise: 1.2, weights: vec![] }
            .generate(2000, 9);
        let run_with = |rho: Rho| {
            let mut alg = GrowBatch::new(init::first_k(&data, 4), 2000, 16, rho);
            let mut c = ctx(&data);
            for _ in 0..12 {
                alg.round(&mut c);
            }
            alg.b
        };
        let b_small = run_with(Rho::Finite(1.0));
        let b_large = run_with(Rho::Finite(1e9));
        assert!(
            b_small >= b_large,
            "rho=1 batch {b_small} < rho=1e9 batch {b_large}"
        );
    }
}
