//! Stress: many pipelined binary frames in flight on one TCP
//! connection. The frame loop reads requests and writes responses on
//! the same thread, so a client that pumps requests without draining
//! responses exercises request queueing in the socket buffers; a writer
//! thread keeps the pump full while the main thread drains. Responses
//! must come back in order, every one bit-identical to the unloaded
//! reference — and the server's frame counters must account for every
//! frame. A second phase keeps training steps running on another
//! connection while the pipeline is full.

use nmbkm::config::{Algo, Rho, RunConfig};
use nmbkm::data::gaussian::GaussianMixture;
use nmbkm::data::{Data, Storage};
use nmbkm::serve::observe::serve_metrics;
use nmbkm::serve::server::{serve_listener_with, ServeOptions};
use nmbkm::serve::{frame, session, ModelRegistry};
use nmbkm::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn cfg(k: usize, b0: usize, rounds: usize) -> RunConfig {
    RunConfig {
        algo: Algo::TbRho,
        k,
        b0,
        rho: Rho::Infinite,
        threads: 2,
        seed: 23,
        max_rounds: rounds,
        max_seconds: 60.0,
        eval_every_secs: 0.0,
        ..Default::default()
    }
}

fn sparse_corpus(n: usize, seed: u64) -> Data {
    nmbkm::data::rcv1::Rcv1Sim {
        vocab: 300,
        topic_vocab: 40,
        ..Default::default()
    }
    .generate(n, seed)
}

fn sparse_rows(data: &Data, lo: usize, hi: usize) -> Vec<(Vec<u32>, Vec<f32>)> {
    let Storage::Sparse(m) = &data.storage else {
        panic!("corpus must be sparse");
    };
    (lo..hi)
        .map(|i| {
            let (idx, vals) = m.row(i);
            (idx.to_vec(), vals.to_vec())
        })
        .collect()
}

fn predict_frame(batch: &[(Vec<u32>, Vec<f32>)], dim: usize) -> Vec<u8> {
    let body = frame::encode_sparse_points(dim, batch).unwrap();
    let mut out = Vec::new();
    frame::write_frame(
        &mut out,
        &Json::parse(r#"{"op":"predict"}"#).unwrap(),
        &body,
    )
    .unwrap();
    out
}

#[test]
fn pipelined_binary_frames_stay_ordered_and_bit_exact_under_load() {
    let listener = match TcpListener::bind("127.0.0.1:0") {
        Ok(l) => l,
        Err(_) => {
            eprintln!("skipping: cannot bind loopback");
            return;
        }
    };
    let addr = listener.local_addr().unwrap();
    let data = sparse_corpus(500, 17);
    let dim = data.dim();
    let (s, _) = session::train(&data, &cfg(8, 128, 4)).unwrap();
    let reg = Arc::new(ModelRegistry::with_default(s));
    let server = std::thread::spawn(move || {
        nmbkm::serve::server::serve_listener_opts(reg, listener, true).unwrap();
    });

    // 12 distinct query batches, cycled into 240 in-flight frames
    const DISTINCT: usize = 12;
    const IN_FLIGHT: usize = 240;
    let batches: Vec<Vec<(Vec<u32>, Vec<f32>)>> = (0..DISTINCT)
        .map(|b| sparse_rows(&data, b * 8, b * 8 + 8))
        .collect();
    let frames: Vec<Vec<u8>> =
        batches.iter().map(|b| predict_frame(b, dim)).collect();

    // unloaded reference answers, one frame at a time
    let mut expected = Vec::with_capacity(DISTINCT);
    {
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(&[frame::MAGIC]).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        for f in &frames {
            conn.write_all(f).unwrap();
            let (h, body) = frame::read_frame(&mut reader).unwrap().unwrap();
            assert_eq!(h.get("ok").unwrap().as_bool(), Some(true), "{h:?}");
            let (lbl, d2) = frame::decode_predict_body(&body).unwrap();
            expected.push((
                lbl,
                d2.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
            ));
        }
    }

    let frames_before = serve_metrics().frames.get();

    // training pressure on a second connection for the whole stress
    // run. It trains its OWN model ("aux"): registry-level churn —
    // session locking, publishes, event-log writes — without moving the
    // default model the pipelined predicts are asserted against
    // (per-model snapshot isolation is exactly the property under test)
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let trainer_stop = stop.clone();
    let trainer = std::thread::spawn(move || {
        let mut conn = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        let mut req = |conn: &mut TcpStream,
                       reader: &mut BufReader<TcpStream>,
                       line: &mut String,
                       msg: &str| {
            conn.write_all(msg.as_bytes()).unwrap();
            conn.write_all(b"\n").unwrap();
            line.clear();
            reader.read_line(line).unwrap();
            assert!(line.contains("\"ok\":true"), "trainer request failed: {line}");
        };
        req(
            &mut conn,
            &mut reader,
            &mut line,
            r#"{"op":"create","model":"aux","k":4,"dim":3,"algo":"gb","b0":16,"seed":4}"#,
        );
        let pts: Vec<String> = (0..32)
            .map(|i| format!("[{},1.0,{}]", i as f32, 0.5 * i as f32))
            .collect();
        req(
            &mut conn,
            &mut reader,
            &mut line,
            &format!(
                "{{\"op\":\"ingest\",\"model\":\"aux\",\"points\":[{}]}}",
                pts.join(",")
            ),
        );
        while !trainer_stop.load(std::sync::atomic::Ordering::SeqCst) {
            req(
                &mut conn,
                &mut reader,
                &mut line,
                r#"{"op":"step","model":"aux","rounds":1}"#,
            );
        }
    });

    // the loaded connection: a writer thread pumps all frames without
    // waiting for responses (the two directions must not deadlock even
    // with hundreds of frames in the socket buffers), the main thread
    // drains responses in order
    let mut conn = TcpStream::connect(addr).unwrap();
    conn.write_all(&[frame::MAGIC]).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut wconn = conn.try_clone().unwrap();
    let wframes = frames.clone();
    let writer = std::thread::spawn(move || {
        for t in 0..IN_FLIGHT {
            wconn.write_all(&wframes[t % DISTINCT]).unwrap();
        }
        wconn.flush().unwrap();
    });
    for t in 0..IN_FLIGHT {
        let (h, body) = frame::read_frame(&mut reader).unwrap().unwrap();
        assert_eq!(
            h.get("ok").unwrap().as_bool(),
            Some(true),
            "frame {t}: {h:?}"
        );
        let (lbl, d2) = frame::decode_predict_body(&body).unwrap();
        let (elbl, ed2) = &expected[t % DISTINCT];
        assert_eq!(&lbl, elbl, "frame {t}: labels out of order or wrong");
        assert_eq!(
            &d2.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
            ed2,
            "frame {t}: d2 bits drifted under load"
        );
    }
    writer.join().unwrap();
    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    trainer.join().unwrap();

    // every pipelined frame is accounted for (other tests in this
    // process may add to the counter; it can only overshoot)
    let frames_after = serve_metrics().frames.get();
    assert!(
        frames_after >= frames_before + IN_FLIGHT as u64,
        "frame counter lost frames: {frames_before} -> {frames_after}"
    );

    // a fresh JSONL connection shuts the server down cleanly
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    conn.write_all(b"{\"op\":\"shutdown\"}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":true"), "{line}");
    server.join().unwrap();
}

fn dense_session(k: usize, seed: u64) -> session::OnlineSession {
    let data = GaussianMixture::default_spec(k, 4).generate(500, seed);
    session::train(&data, &cfg(k, 128, 4)).unwrap().0
}

/// Shut a server down over a fresh JSONL connection, retrying while
/// the admission cap is still reaping recently-closed peers.
fn shutdown_server(addr: std::net::SocketAddr) {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let mut conn = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        conn.write_all(b"{\"op\":\"shutdown\"}\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        if line.contains("\"ok\":true") {
            return;
        }
        assert!(line.contains("overloaded"), "{line}");
        assert!(Instant::now() < deadline, "shutdown never admitted");
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// A peer that pumps hundreds of large predicts without reading a byte
/// back must trip the per-connection write-queue cap: the server stops
/// reading from it (bounding memory at the cap, not at the pipeline
/// size) while an interactive peer on the same server keeps getting
/// prompt answers. When the slow reader finally drains, every response
/// arrives in order, bit-identical to the unloaded reference.
#[test]
fn slow_reader_backpressure_isolates_fast_peers_and_stays_bit_exact() {
    let listener = match TcpListener::bind("127.0.0.1:0") {
        Ok(l) => l,
        Err(_) => {
            eprintln!("skipping: cannot bind loopback");
            return;
        }
    };
    let addr = listener.local_addr().unwrap();
    let reg = Arc::new(ModelRegistry::with_default(dense_session(8, 3)));
    let server = std::thread::spawn(move || {
        serve_listener_with(
            reg,
            listener,
            ServeOptions {
                accept_binary: true,
                conn_timeout: None,
                write_queue_cap: 64 << 10,
                ..Default::default()
            },
        )
        .unwrap();
    });

    // one 4096-row predict frame: its ~32 KiB response overflows the
    // 64 KiB write queue after a couple of unread answers
    let queries: Vec<Vec<f32>> = (0..4096)
        .map(|i| {
            let x = (i % 97) as f32 * 0.03125;
            vec![x, 1.0 - x, 0.5 * x, -0.25]
        })
        .collect();
    let body = frame::encode_dense_points(4, &queries).unwrap();
    let mut big_frame = Vec::new();
    frame::write_frame(
        &mut big_frame,
        &Json::parse(r#"{"op":"predict"}"#).unwrap(),
        &body,
    )
    .unwrap();

    // unloaded reference answer
    let (ref_lbl, ref_bits) = {
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(&[frame::MAGIC]).unwrap();
        conn.write_all(&big_frame).unwrap();
        let mut reader = BufReader::new(conn);
        let (h, body) = frame::read_frame(&mut reader).unwrap().unwrap();
        assert_eq!(h.get("ok").unwrap().as_bool(), Some(true), "{h:?}");
        let (lbl, d2) = frame::decode_predict_body(&body).unwrap();
        (lbl, d2.iter().map(|x| x.to_bits()).collect::<Vec<u32>>())
    };

    let bp_before = serve_metrics().conn_backpressure.get();

    // the slow reader: a writer thread force-feeds 400 frames and never
    // reads; ~13 MiB of responses must queue behind a 64 KiB cap
    const PUMP: usize = 400;
    let slow = TcpStream::connect(addr).unwrap();
    let mut slow_writer = slow.try_clone().unwrap();
    let pump_frame = big_frame.clone();
    let writer = std::thread::spawn(move || {
        slow_writer.write_all(&[frame::MAGIC]).unwrap();
        for _ in 0..PUMP {
            slow_writer.write_all(&pump_frame).unwrap();
        }
        slow_writer.flush().unwrap();
    });

    // the fast peer: sequential JSONL predicts must answer promptly the
    // whole time the slow reader is jamming its own queue
    let mut fast = TcpStream::connect(addr).unwrap();
    fast.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut fast_reader = BufReader::new(fast.try_clone().unwrap());
    let mut line = String::new();
    for t in 0..25 {
        fast.write_all(b"{\"op\":\"predict\",\"points\":[[0.5,0.25,-1.0,2.0]]}\n")
            .unwrap();
        line.clear();
        fast_reader
            .read_line(&mut line)
            .unwrap_or_else(|e| panic!("fast peer starved at request {t}: {e}"));
        assert!(line.contains("\"ok\":true"), "fast peer request {t}: {line}");
    }

    // the cap must actually have engaged
    let deadline = Instant::now() + Duration::from_secs(20);
    while serve_metrics().conn_backpressure.get() == bp_before {
        assert!(
            Instant::now() < deadline,
            "write-queue cap never triggered backpressure"
        );
        std::thread::sleep(Duration::from_millis(50));
    }

    // drain the slow connection: all 400 responses, in order, bit-exact
    let mut reader = BufReader::new(slow);
    for t in 0..PUMP {
        let (h, body) = frame::read_frame(&mut reader).unwrap().unwrap();
        assert_eq!(h.get("ok").unwrap().as_bool(), Some(true), "frame {t}: {h:?}");
        let (lbl, d2) = frame::decode_predict_body(&body).unwrap();
        assert_eq!(lbl, ref_lbl, "frame {t}: labels drifted under backpressure");
        assert_eq!(
            d2.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
            ref_bits,
            "frame {t}: d2 bits drifted under backpressure"
        );
    }
    writer.join().unwrap();

    shutdown_server(addr);
    server.join().unwrap();
}

/// Admission control under a hostile burst: over-cap connections and
/// oversized requests get structured `overloaded` errors (never a
/// hang), surviving streams keep working, and a separate max-inflight
/// server refuses over-limit dispatches while still answering in-limit
/// ones.
#[test]
fn overload_bursts_get_structured_errors_and_streams_survive() {
    let listener = match TcpListener::bind("127.0.0.1:0") {
        Ok(l) => l,
        Err(_) => {
            eprintln!("skipping: cannot bind loopback");
            return;
        }
    };
    let addr = listener.local_addr().unwrap();
    // empty registry: `list` is the liveness probe
    let reg = Arc::new(ModelRegistry::new());
    let server = std::thread::spawn(move || {
        serve_listener_with(
            reg,
            listener,
            ServeOptions {
                accept_binary: false,
                conn_timeout: None,
                max_conns: 3,
                max_request_bytes: 4096,
                ..Default::default()
            },
        )
        .unwrap();
    });

    let list_ok = |conn: &mut TcpStream, reader: &mut BufReader<TcpStream>| {
        conn.write_all(b"{\"op\":\"list\"}\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":true"), "{line}");
    };

    // fill the admission cap
    let mut admitted: Vec<(TcpStream, BufReader<TcpStream>)> = (0..3)
        .map(|_| {
            let conn = TcpStream::connect(addr).unwrap();
            let reader = BufReader::new(conn.try_clone().unwrap());
            (conn, reader)
        })
        .collect();
    for (conn, reader) in admitted.iter_mut() {
        list_ok(conn, reader);
    }

    // the 4th peer is refused with a structured error, then closed
    let over_before = serve_metrics().overloaded_conns.get();
    {
        let conn = TcpStream::connect(addr).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut reader = BufReader::new(conn);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("overloaded"), "{line}");
        assert!(line.contains("--max-conns=3"), "{line}");
        line.clear();
        assert_eq!(reader.read_line(&mut line).unwrap(), 0, "expected close");
    }
    assert!(serve_metrics().overloaded_conns.get() > over_before);

    // an oversized line is refused and the stream survives
    let bytes_before = serve_metrics().overloaded_bytes.get();
    {
        let (conn, reader) = &mut admitted[0];
        let fat = format!("{{\"op\":\"list\",\"pad\":\"{}\"}}\n", "x".repeat(8192));
        conn.write_all(fat.as_bytes()).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("overloaded"), "{line}");
        assert!(line.contains("--max-request-bytes=4096"), "{line}");
        list_ok(conn, reader);
    }
    assert!(serve_metrics().overloaded_bytes.get() > bytes_before);

    // closing an admitted peer frees a slot (the close is asynchronous:
    // retry until the server has seen it)
    drop(admitted.pop());
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        conn.write_all(b"{\"op\":\"list\"}\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        if line.contains("\"ok\":true") {
            break;
        }
        assert!(
            line.contains("overloaded"),
            "unexpected reply while waiting for a free slot: {line}"
        );
        assert!(
            Instant::now() < deadline,
            "closed connection never freed an admission slot"
        );
        std::thread::sleep(Duration::from_millis(50));
    }

    drop(admitted);
    shutdown_server(addr);
    server.join().unwrap();

    // --- max-inflight on its own server: a 16-connection pipelined
    // burst must see at least one refusal and at least one answer, and
    // every stream stays intact (50 replies per connection)
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let reg = Arc::new(ModelRegistry::with_default(dense_session(4, 11)));
    let server = std::thread::spawn(move || {
        serve_listener_with(
            reg,
            listener,
            ServeOptions {
                accept_binary: false,
                conn_timeout: None,
                max_inflight: 1,
                ..Default::default()
            },
        )
        .unwrap();
    });

    let row = "[0.5,0.25,-1.0,2.0]";
    let burst_line = format!(
        "{{\"op\":\"predict\",\"points\":[{}]}}\n",
        vec![row; 256].join(",")
    );
    const CLIENTS: usize = 16;
    const PER_CONN: usize = 50;
    let burst_line = Arc::new(burst_line);
    let handles: Vec<_> = (0..CLIENTS)
        .map(|_| {
            let line_bytes = burst_line.clone();
            std::thread::spawn(move || {
                let mut conn = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(conn.try_clone().unwrap());
                for _ in 0..PER_CONN {
                    conn.write_all(line_bytes.as_bytes()).unwrap();
                }
                conn.flush().unwrap();
                let (mut ok, mut over) = (0usize, 0usize);
                let mut line = String::new();
                for t in 0..PER_CONN {
                    line.clear();
                    let n = reader.read_line(&mut line).unwrap();
                    assert!(n > 0, "stream died after {t} replies");
                    if line.contains("\"ok\":true") {
                        ok += 1;
                    } else {
                        assert!(
                            line.contains("overloaded")
                                && line.contains("--max-inflight=1"),
                            "reply {t}: {line}"
                        );
                        over += 1;
                    }
                }
                (ok, over)
            })
        })
        .collect();
    let (mut ok_total, mut over_total) = (0usize, 0usize);
    for h in handles {
        let (ok, over) = h.join().unwrap();
        ok_total += ok;
        over_total += over;
    }
    assert_eq!(ok_total + over_total, CLIENTS * PER_CONN);
    assert!(ok_total >= 1, "nothing got through the inflight gate");
    assert!(
        over_total >= 1,
        "an 800-request pipelined burst never tripped --max-inflight=1"
    );

    // after the burst, a sequential predict answers normally (retry:
    // the last inflight slot may release a beat after its reply lands)
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        conn.write_all(b"{\"op\":\"predict\",\"points\":[[0.5,0.25,-1.0,2.0]]}\n")
            .unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        if line.contains("\"ok\":true") {
            break;
        }
        assert!(line.contains("overloaded"), "{line}");
        assert!(Instant::now() < deadline, "inflight gate never released");
        std::thread::sleep(Duration::from_millis(50));
    }

    shutdown_server(addr);
    server.join().unwrap();
}
