//! Run metrics: per-round records and the validation-MSE protocol.
//!
//! Following §4.3, validation MSE is computed at regular *work-time*
//! intervals and its cost is excluded from reported runtimes (the
//! driver scores off-clock via `WorkClock::off_clock`).

use crate::coordinator::progress::Table;

/// One round of one run.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    /// cumulative work seconds when the round finished
    pub t_work: f64,
    /// active batch size (N for full-batch algorithms)
    pub batch: usize,
    /// point↔centroid distance computations this round
    pub dist_calcs: u64,
    /// bound tests that eliminated a distance computation
    pub bound_skips: u64,
    /// assignments that changed this round
    pub changed: u64,
    /// validation MSE, when scored this round
    pub val_mse: Option<f64>,
    /// running training-batch MSE proxy (Σsse/Σv), free from the stats
    pub train_mse: f64,
}

/// A full run trace plus its outcome summary.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub algo: String,
    pub dataset: String,
    pub seed: u64,
    pub records: Vec<RoundRecord>,
}

impl Trace {
    pub fn push(&mut self, r: RoundRecord) {
        self.records.push(r);
    }

    /// Last validation MSE seen (the experiment's headline number).
    pub fn final_val_mse(&self) -> Option<f64> {
        self.records.iter().rev().find_map(|r| r.val_mse)
    }

    /// Best (lowest) validation MSE over the run.
    pub fn best_val_mse(&self) -> Option<f64> {
        self.records
            .iter()
            .filter_map(|r| r.val_mse)
            .min_by(|a, b| a.total_cmp(b))
    }

    /// Total distance computations.
    pub fn total_dist_calcs(&self) -> u64 {
        self.records.iter().map(|r| r.dist_calcs).sum()
    }

    /// The (t_work, val_mse) series for plotting, carrying forward the
    /// most recent score.
    pub fn mse_series(&self) -> Vec<(f64, f64)> {
        self.records
            .iter()
            .filter_map(|r| r.val_mse.map(|m| (r.t_work, m)))
            .collect()
    }

    /// CSV rows in the layout the experiment harnesses emit.
    pub fn to_table(&self) -> Table {
        let mut t = Table::new(&[
            "algo", "dataset", "seed", "round", "t_work", "batch",
            "dist_calcs", "bound_skips", "changed", "val_mse", "train_mse",
        ]);
        for r in &self.records {
            t.push(vec![
                self.algo.clone(),
                self.dataset.clone(),
                self.seed.to_string(),
                r.round.to_string(),
                format!("{:.6}", r.t_work),
                r.batch.to_string(),
                r.dist_calcs.to_string(),
                r.bound_skips.to_string(),
                r.changed.to_string(),
                r.val_mse.map(|m| format!("{m:.8e}")).unwrap_or_default(),
                format!("{:.8e}", r.train_mse),
            ]);
        }
        t
    }
}

/// Interpolate a trace's validation MSE onto a common time grid
/// (step-function carry-forward), for averaging curves across seeds as
/// Figure 1 does.
pub fn mse_on_grid(series: &[(f64, f64)], grid: &[f64]) -> Vec<f64> {
    let mut out = Vec::with_capacity(grid.len());
    for &t in grid {
        let mut last = f64::NAN;
        for &(ts, m) in series {
            if ts <= t {
                last = m;
            } else {
                break;
            }
        }
        out.push(last);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, t: f64, mse: Option<f64>) -> RoundRecord {
        RoundRecord {
            round,
            t_work: t,
            batch: 100,
            dist_calcs: 10,
            bound_skips: 5,
            changed: 2,
            val_mse: mse,
            train_mse: 1.0,
        }
    }

    #[test]
    fn final_and_best_mse() {
        let mut tr = Trace::default();
        tr.push(rec(0, 0.1, Some(5.0)));
        tr.push(rec(1, 0.2, None));
        tr.push(rec(2, 0.3, Some(3.0)));
        tr.push(rec(3, 0.4, Some(4.0)));
        assert_eq!(tr.final_val_mse(), Some(4.0));
        assert_eq!(tr.best_val_mse(), Some(3.0));
        assert_eq!(tr.total_dist_calcs(), 40);
    }

    #[test]
    fn grid_interpolation_carries_forward() {
        let series = vec![(0.1, 5.0), (0.3, 3.0)];
        let grid = vec![0.0, 0.1, 0.2, 0.3, 1.0];
        let vals = mse_on_grid(&series, &grid);
        assert!(vals[0].is_nan());
        assert_eq!(vals[1], 5.0);
        assert_eq!(vals[2], 5.0);
        assert_eq!(vals[3], 3.0);
        assert_eq!(vals[4], 3.0);
    }

    #[test]
    fn csv_has_all_columns() {
        let mut tr = Trace {
            algo: "tb-inf".into(),
            dataset: "x".into(),
            seed: 3,
            records: vec![],
        };
        tr.push(rec(0, 0.5, Some(1.25)));
        let csv = tr.to_table().to_csv();
        assert!(csv.starts_with("algo,dataset,seed,round"));
        assert!(csv.contains("tb-inf"));
        assert!(csv.contains("1.25"));
    }
}
