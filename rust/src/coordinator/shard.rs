//! Work sharding across a persistent worker pool.
//!
//! [`Pool::run_chunks`] splits `0..n` into near-equal contiguous chunks,
//! runs a closure per chunk on worker threads, and returns results in
//! chunk order — deterministic regardless of scheduling, which the
//! reproducibility tests rely on. [`Pool::run_jobs`] is the owned-input
//! generalisation the algorithm layer uses to ship per-chunk mutable
//! views to workers. Output buffers are split with [`split_outputs`] so
//! each worker writes a disjoint region without locks.
//!
//! Workers are spawned once per [`Pool`] and parked on a condvar between
//! calls. The previous implementation spawned scoped threads on every
//! call; at round granularity (≥ milliseconds) the ~10 µs spawn cost was
//! noise, but the serve layer now drives assignment at sub-millisecond
//! rounds where respawning dominated. The submitting thread participates
//! as the final worker, so a `Pool::new(t)` still applies exactly `t`
//! threads of compute, and chunk claims are index-ordered atomics while
//! results land in per-chunk slots — chunk-ordered, deterministic output
//! is preserved exactly.
//!
//! The queue holds *many* in-flight jobs: concurrent submitters (several
//! serving sessions, predict handlers racing a training step) each push
//! their own job and drain it themselves, while parked workers pick up
//! whichever queued job still has unclaimed chunks. A previous revision
//! kept a single job slot, which serialised concurrent submitters behind
//! each other; multi-model serving made that the bottleneck. Per-job
//! results still land in that job's own per-chunk slots and panics are
//! flagged per job, so chunk-ordered determinism and panic propagation
//! are unchanged by the concurrency.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// A handle to a persistent worker pool. Cloning shares the same
/// workers; the threads exit when the last clone drops.
pub struct Pool {
    pub threads: usize,
    core: Option<Arc<PoolCore>>,
}

impl Clone for Pool {
    fn clone(&self) -> Self {
        Self { threads: self.threads, core: self.core.clone() }
    }
}

impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pool").field("threads", &self.threads).finish()
    }
}

/// One submitted batch of chunk indices `0..total`. The closure is held
/// as a raw pointer (not a lifetime-transmuted reference) so the type
/// itself documents that it is only valid while the submitter blocks in
/// [`PoolCore::execute`]; it is dereferenced exclusively inside
/// [`drain_job`]'s claimed-chunk path.
struct Job {
    f: *const (dyn Fn(usize) + Sync),
    next: AtomicUsize,
    total: usize,
    done: Mutex<usize>,
    done_cv: Condvar,
    panicked: AtomicBool,
}

// Safety: `f` points at a Sync closure that outlives every dereference
// (see `PoolCore::execute`); all other fields are Send + Sync.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

#[derive(Default)]
struct PoolState {
    /// Jobs with possibly-unclaimed chunks, oldest first. A job stays
    /// queued until its submitter observes completion and removes it;
    /// workers skip fully-claimed entries (`next >= total`).
    jobs: Vec<Arc<Job>>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    work_cv: Condvar,
}

struct PoolCore {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Drop for PoolCore {
    fn drop(&mut self) {
        self.shared.state.lock().unwrap().shutdown = true;
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Claim-execute loop shared by parked workers and the submitting
/// thread. Claims are `fetch_add` on the job's chunk cursor, so each
/// chunk index runs exactly once; panics are trapped and re-raised by
/// the submitter so a worker never dies mid-pool.
fn drain_job(job: &Job) {
    loop {
        let i = job.next.fetch_add(1, Ordering::Relaxed);
        if i >= job.total {
            break;
        }
        // Safety: a successful claim (i < total) means the submitter is
        // still blocked in `execute` waiting for this chunk's `done`
        // increment, so the closure behind `f` is alive.
        let f = unsafe { &*job.f };
        if catch_unwind(AssertUnwindSafe(|| f(i))).is_err() {
            job.panicked.store(true, Ordering::Relaxed);
        }
        let mut d = job.done.lock().unwrap();
        *d += 1;
        if *d == job.total {
            job.done_cv.notify_all();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                // oldest job with unclaimed chunks; fully-claimed jobs
                // stay queued (their submitter removes them) but offer
                // no work, so skip them
                if let Some(j) = st
                    .jobs
                    .iter()
                    .find(|j| j.next.load(Ordering::Relaxed) < j.total)
                {
                    break j.clone();
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        drain_job(&job);
    }
}

impl PoolCore {
    /// Run `f(i)` for every `i in 0..total` across the workers plus the
    /// calling thread; returns once all chunks completed.
    ///
    /// Safety of the pointer erasure: workers dereference `job.f` only
    /// while executing a successfully claimed chunk, every claimed chunk
    /// increments `done` when it finishes, and this function blocks
    /// until `done == total` — so `f` (and everything it borrows)
    /// strictly outlives every dereference. Late wakers only touch the
    /// atomic cursor, never `f`.
    fn execute(&self, total: usize, f: &(dyn Fn(usize) + Sync)) {
        let pm = pool_metrics();
        pm.jobs.inc();
        pm.chunks.add(total as u64);
        pm.jobs_inflight.add(1);
        // Lifetime-erase into the raw field (same-layout fat pointer;
        // a plain `as` cast cannot widen the trait-object lifetime).
        let fp: *const (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute(f) };
        let job = Arc::new(Job {
            f: fp,
            next: AtomicUsize::new(0),
            total,
            done: Mutex::new(0),
            done_cv: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        {
            let mut st = self.shared.state.lock().unwrap();
            st.jobs.push(job.clone());
        }
        self.shared.work_cv.notify_all();
        // the submitting thread drains *its own* job only — it never
        // picks up another submitter's chunks, so a fast caller is not
        // held hostage by a slow concurrent one
        drain_job(&job);
        {
            let mut d = job.done.lock().unwrap();
            while *d < total {
                d = job.done_cv.wait(d).unwrap();
            }
        }
        {
            let mut st = self.shared.state.lock().unwrap();
            if let Some(pos) =
                st.jobs.iter().position(|j| Arc::ptr_eq(j, &job))
            {
                // keep FIFO order so workers always scan oldest-first
                st.jobs.remove(pos);
            }
        }
        pm.jobs_inflight.add(-1);
        if job.panicked.load(Ordering::Relaxed) {
            panic!("worker panicked");
        }
    }
}

/// Pool-level observability: submitted jobs, total chunks sharded, and
/// a live in-flight gauge (queue depth as seen by submitters). One
/// counter bump per *job*, not per chunk, so sharding overhead is
/// untouched.
struct PoolMetrics {
    jobs: Arc<crate::obs::Counter>,
    chunks: Arc<crate::obs::Counter>,
    jobs_inflight: Arc<crate::obs::Gauge>,
}

fn pool_metrics() -> &'static PoolMetrics {
    static M: std::sync::OnceLock<PoolMetrics> = std::sync::OnceLock::new();
    M.get_or_init(|| {
        let reg = crate::obs::registry();
        PoolMetrics {
            jobs: reg.counter("nmbkm_pool_jobs_total", &[]),
            chunks: reg.counter("nmbkm_pool_chunks_total", &[]),
            jobs_inflight: reg.gauge("nmbkm_pool_jobs_inflight", &[]),
        }
    })
}

impl Pool {
    /// A pool applying `threads` compute threads (`threads − 1` parked
    /// workers plus the submitting thread). `threads <= 1` runs
    /// everything inline with no worker threads at all.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let core = if threads > 1 {
            let shared = Arc::new(Shared {
                state: Mutex::new(PoolState::default()),
                work_cv: Condvar::new(),
            });
            let mut handles = Vec::with_capacity(threads - 1);
            for w in 0..threads - 1 {
                let sh = shared.clone();
                handles.push(
                    std::thread::Builder::new()
                        .name(format!("nmbkm-pool-{w}"))
                        .spawn(move || worker_loop(sh))
                        .expect("failed to spawn pool worker"),
                );
            }
            Some(Arc::new(PoolCore { shared, handles }))
        } else {
            None
        };
        Self { threads, core }
    }

    /// Use all available parallelism, unless the `NMBKM_THREADS`
    /// environment variable overrides it (clamped to ≥ 1). CI and
    /// serving deployments set the override to get deterministic thread
    /// counts independent of the host's core count.
    pub fn auto() -> Self {
        Self::auto_from(std::env::var("NMBKM_THREADS").ok().as_deref())
    }

    /// Pure core of [`Pool::auto`]: `override_val` is the raw
    /// `NMBKM_THREADS` value, if set. Unparsable values fall back to the
    /// host's parallelism. (Split out so tests never need `set_var`,
    /// which races with concurrent `getenv` in other test threads.)
    pub fn auto_from(override_val: Option<&str>) -> Self {
        if let Some(t) =
            override_val.and_then(|v| v.trim().parse::<usize>().ok())
        {
            return Self::new(t);
        }
        let t = std::thread::available_parallelism()
            .map(|x| x.get())
            .unwrap_or(1);
        Self::new(t)
    }

    /// Run `f(i, jobs[i])` for every job, in parallel when it pays.
    /// Results come back in job order. Jobs own their inputs — the
    /// algorithm layer passes `(range, &mut view…)` tuples so each
    /// worker writes a disjoint output region without locks.
    ///
    /// Concurrent `run_jobs` calls on clones of one pool from different
    /// threads are safe *and* interleave: every submission is queued as
    /// its own job, each submitter drains only its own chunks, and
    /// parked workers pull from whichever queued job still has work.
    /// Results, ordering and panic propagation are per-job, exactly as
    /// in the serial case.
    pub fn run_jobs<T, R, F>(&self, jobs: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, T) -> R + Sync,
    {
        let total = jobs.len();
        if total == 0 {
            return vec![];
        }
        match &self.core {
            Some(core) if total > 1 => {
                let inputs: Vec<Mutex<Option<T>>> =
                    jobs.into_iter().map(|t| Mutex::new(Some(t))).collect();
                let outputs: Vec<Mutex<Option<R>>> =
                    (0..total).map(|_| Mutex::new(None)).collect();
                let runner = |i: usize| {
                    let t = inputs[i].lock().unwrap().take().expect("chunk claimed twice");
                    let r = f(i, t);
                    *outputs[i].lock().unwrap() = Some(r);
                };
                core.execute(total, &runner);
                outputs
                    .into_iter()
                    .map(|m| {
                        m.into_inner().unwrap().expect("missing chunk result")
                    })
                    .collect()
            }
            _ => jobs.into_iter().enumerate().map(|(i, t)| f(i, t)).collect(),
        }
    }

    /// Split `0..n` into chunks (at least `min_chunk` items each, except
    /// possibly the last) and run `f(chunk_index, range)` on each,
    /// in parallel when it pays. Results come back in chunk order.
    pub fn run_chunks<R, F>(&self, n: usize, min_chunk: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, std::ops::Range<usize>) -> R + Sync,
    {
        let ranges = chunk_ranges(n, self.threads, min_chunk);
        self.run_jobs(ranges, |i, r| f(i, r))
    }
}

/// Contiguous near-equal chunks of `0..n`: at most `threads` chunks, each
/// at least `min_chunk` long (except a short final chunk when n is small).
pub fn chunk_ranges(n: usize, threads: usize, min_chunk: usize) -> Vec<std::ops::Range<usize>> {
    if n == 0 {
        return vec![];
    }
    let min_chunk = min_chunk.max(1);
    let max_chunks = n.div_ceil(min_chunk);
    let chunks = threads.max(1).min(max_chunks);
    let base = n / chunks;
    let rem = n % chunks;
    let mut out = Vec::with_capacity(chunks);
    let mut start = 0;
    for i in 0..chunks {
        let len = base + usize::from(i < rem);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

/// Split two output slices into per-chunk disjoint mutable views matching
/// `chunk_ranges(n, …)`, so shards write results without synchronisation.
pub fn split_outputs<'a, A, B>(
    ranges: &[std::ops::Range<usize>],
    a: &'a mut [A],
    b: &'a mut [B],
) -> Vec<(&'a mut [A], &'a mut [B])> {
    let mut out = Vec::with_capacity(ranges.len());
    let mut rest_a = a;
    let mut rest_b = b;
    let mut consumed = 0usize;
    for r in ranges {
        let len = r.len();
        debug_assert_eq!(r.start, consumed);
        let (ha, ta) = rest_a.split_at_mut(len);
        let (hb, tb) = rest_b.split_at_mut(len);
        out.push((ha, hb));
        rest_a = ta;
        rest_b = tb;
        consumed += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_partition_exactly() {
        for &(n, t, m) in
            &[(0usize, 4usize, 1usize), (1, 4, 1), (10, 3, 1), (100, 7, 16), (5, 10, 1)]
        {
            let rs = chunk_ranges(n, t, m);
            let total: usize = rs.iter().map(|r| r.len()).sum();
            assert_eq!(total, n, "n={n} t={t} m={m}");
            for w in rs.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
            if let Some(first) = rs.first() {
                assert_eq!(first.start, 0);
            }
            assert!(rs.len() <= t.max(1));
        }
    }

    #[test]
    fn min_chunk_limits_fanout() {
        let rs = chunk_ranges(10, 8, 4);
        assert!(rs.len() <= 3, "{rs:?}");
    }

    #[test]
    fn run_chunks_covers_all_items() {
        let pool = Pool::new(4);
        let touched = AtomicUsize::new(0);
        let sums = pool.run_chunks(1000, 1, |_, r| {
            touched.fetch_add(r.len(), Ordering::Relaxed);
            r.sum::<usize>()
        });
        assert_eq!(touched.load(Ordering::Relaxed), 1000);
        assert_eq!(sums.iter().sum::<usize>(), 999 * 1000 / 2);
    }

    #[test]
    fn results_in_chunk_order() {
        let pool = Pool::new(8);
        let ids = pool.run_chunks(64, 1, |i, _| i);
        assert_eq!(ids, (0..ids.len()).collect::<Vec<_>>());
    }

    #[test]
    fn auto_honors_thread_env_override() {
        // exercised through the pure core — mutating the real environment
        // from a parallel test harness is a getenv/setenv data race
        assert_eq!(Pool::auto_from(Some("3")).threads, 3);
        assert_eq!(Pool::auto_from(Some(" 5 ")).threads, 5);
        assert_eq!(Pool::auto_from(Some("0")).threads, 1, "clamped to >= 1");
        assert!(
            Pool::auto_from(Some("not-a-number")).threads >= 1,
            "garbage falls back to host parallelism"
        );
        assert!(Pool::auto_from(None).threads >= 1);
        assert!(Pool::auto().threads >= 1);
    }

    #[test]
    fn serial_pool_works() {
        let pool = Pool::new(1);
        let v = pool.run_chunks(10, 1, |_, r| r.len());
        assert_eq!(v, vec![10]);
    }

    #[test]
    fn split_outputs_disjoint_and_writable() {
        let ranges = chunk_ranges(10, 3, 1);
        let mut a = vec![0u32; 10];
        let mut b = vec![0f32; 10];
        {
            let views = split_outputs(&ranges, &mut a, &mut b);
            assert_eq!(views.len(), ranges.len());
            for (i, (va, vb)) in views.into_iter().enumerate() {
                for x in va.iter_mut() {
                    *x = i as u32;
                }
                vb.fill(i as f32);
            }
        }
        assert_eq!(a[0], 0);
        assert_eq!(*a.last().unwrap() as usize, ranges.len() - 1);
    }

    #[test]
    fn parallel_matches_serial() {
        let work = |_: usize, r: std::ops::Range<usize>| -> u64 {
            r.map(|x| (x as u64).wrapping_mul(2654435761)).sum()
        };
        let serial: Vec<u64> = Pool::new(1).run_chunks(5000, 1, work);
        let par: Vec<u64> = Pool::new(8).run_chunks(5000, 1, work);
        assert_eq!(
            serial.iter().sum::<u64>(),
            par.iter().sum::<u64>()
        );
    }

    #[test]
    fn workers_persist_across_many_calls() {
        // the point of the rewrite: sub-millisecond rounds must not
        // respawn threads; 500 back-to-back submissions on one pool
        // must stay correct and ordered
        let pool = Pool::new(4);
        for round in 0..500usize {
            let v = pool.run_chunks(64 + round % 7, 1, |i, r| (i, r.len()));
            let total: usize = v.iter().map(|(_, l)| l).sum();
            assert_eq!(total, 64 + round % 7);
            for (idx, (i, _)) in v.iter().enumerate() {
                assert_eq!(idx, *i);
            }
        }
    }

    #[test]
    fn run_jobs_moves_inputs_in_order() {
        let pool = Pool::new(3);
        let jobs: Vec<String> = (0..10).map(|i| format!("job-{i}")).collect();
        let out = pool.run_jobs(jobs, |i, s| format!("{i}:{s}"));
        for (i, s) in out.iter().enumerate() {
            assert_eq!(*s, format!("{i}:job-{i}"));
        }
    }

    #[test]
    fn run_jobs_borrows_mutable_views() {
        // the algorithm-layer pattern: owned (range, &mut view) inputs
        let pool = Pool::new(4);
        let mut buf = vec![0u32; 100];
        let ranges = chunk_ranges(100, 4, 1);
        {
            let mut rest: &mut [u32] = &mut buf;
            let mut jobs = Vec::new();
            for r in ranges.iter().cloned() {
                let (head, tail) = rest.split_at_mut(r.len());
                rest = tail;
                jobs.push((r, head));
            }
            pool.run_jobs(jobs, |_, (r, view)| {
                for (slot, i) in r.enumerate() {
                    view[slot] = i as u32 * 2;
                }
            });
        }
        for (i, v) in buf.iter().enumerate() {
            assert_eq!(*v as usize, i * 2);
        }
    }

    #[test]
    #[should_panic(expected = "worker panicked")]
    fn worker_panic_propagates() {
        let pool = Pool::new(4);
        pool.run_chunks(100, 1, |i, _| {
            if i == 2 {
                panic!("boom");
            }
            i
        });
    }

    #[test]
    fn concurrent_submitters_interleave_and_stay_ordered() {
        // multi-model serving: several sessions submit to one pool at
        // once; every submission must come back complete, chunk-ordered
        // and correct, no matter how the workers interleave the jobs
        let pool = Pool::new(4);
        let mut handles = Vec::new();
        for s in 0..6usize {
            let p = pool.clone();
            handles.push(std::thread::spawn(move || {
                for round in 0..200usize {
                    let n = 37 + (s * 13 + round) % 91;
                    let v = p.run_chunks(n, 1, |i, r| {
                        (i, r.map(|x| x as u64 + s as u64).sum::<u64>())
                    });
                    let expect: u64 =
                        (0..n as u64).sum::<u64>() + (n * s) as u64;
                    let total: u64 = v.iter().map(|(_, t)| t).sum();
                    assert_eq!(total, expect, "submitter {s} round {round}");
                    for (idx, (i, _)) in v.iter().enumerate() {
                        assert_eq!(idx, *i, "submitter {s} round {round}");
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn panic_in_one_submitter_leaves_others_intact() {
        let pool = Pool::new(4);
        let ok_pool = pool.clone();
        let ok = std::thread::spawn(move || {
            for _ in 0..300usize {
                let v = ok_pool.run_chunks(128, 1, |i, _| i);
                assert_eq!(v, (0..v.len()).collect::<Vec<_>>());
            }
        });
        let bad_pool = pool.clone();
        let bad = std::thread::spawn(move || {
            let caught = catch_unwind(AssertUnwindSafe(|| {
                bad_pool.run_chunks(64, 1, |i, _| {
                    if i == 1 {
                        panic!("boom");
                    }
                    i
                });
            }));
            assert!(caught.is_err(), "panic must reach the submitter");
        });
        bad.join().unwrap();
        ok.join().unwrap();
        // the pool is still serviceable after a job panicked
        let v = pool.run_chunks(32, 1, |i, _| i * 2);
        assert_eq!(v.iter().sum::<usize>(), (0..32).map(|i| i * 2).sum());
    }

    #[test]
    fn pool_clones_share_workers_and_drop_cleanly() {
        let pool = Pool::new(3);
        let clone = pool.clone();
        let a = pool.run_chunks(50, 1, |i, _| i);
        let b = clone.run_chunks(50, 1, |i, _| i);
        assert_eq!(a, b);
        drop(pool);
        // workers still alive through the clone
        let c = clone.run_chunks(50, 1, |i, _| i);
        assert_eq!(b, c);
    }
}
