//! Minimal JSON reader/writer (serde is unavailable offline).
//!
//! The reader is a full, strict recursive-descent parser — it exists to
//! load `artifacts/manifest.json` written by the python AOT exporter.
//! The writer emits metrics/result records. Both are intentionally
//! simple: no streaming, values are owned trees.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// An owned JSON value tree.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    /// Serialise compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if !x.is_finite() {
                    // JSON has no inf/NaN token; emit null rather than
                    // producing an unparsable document
                    out.push_str("null");
                } else if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self
                                .bump()
                                .ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(
                            char::from_u32(code)
                                .ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // re-decode multibyte UTF-8 starting at pos-1
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.src.len());
                    let s = std::str::from_utf8(&self.src[start..end])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Lowercase hex of a byte blob. JSON numbers are f64 and silently lose
/// integer/float bit patterns beyond 2^53, so binary payloads (model
/// snapshots) travel as hex strings of their little-endian bytes — the
/// round trip is bit-exact by construction.
pub fn hex_encode(bytes: &[u8]) -> String {
    let mut out = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        let _ = write!(out, "{b:02x}");
    }
    out
}

/// Inverse of [`hex_encode`]; `None` on odd length or a non-hex digit.
pub fn hex_decode(s: &str) -> Option<Vec<u8>> {
    let b = s.as_bytes();
    if b.len() % 2 != 0 {
        return None;
    }
    let mut out = Vec::with_capacity(b.len() / 2);
    for pair in b.chunks_exact(2) {
        let hi = (pair[0] as char).to_digit(16)?;
        let lo = (pair[1] as char).to_digit(16)?;
        out.push(((hi << 4) | lo) as u8);
    }
    Some(out)
}

/// Convenience builder for writing result records.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":"x"}],"c":null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"entries":[{"file":"a.hlo.txt","inputs":[["float32",[256,64]]],"name":"assign"}],"k":64}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_strings() {
        let v = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ✓"));
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn escaped_unicode() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }

    #[test]
    fn non_finite_numbers_serialise_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        let v = obj(vec![("x", num(f64::NEG_INFINITY))]);
        assert_eq!(Json::parse(&v.to_string()).unwrap().get("x"), Some(&Json::Null));
    }

    #[test]
    fn hex_blob_roundtrip() {
        let bytes: Vec<u8> = (0..=255).collect();
        let hex = hex_encode(&bytes);
        assert_eq!(hex.len(), 512);
        assert_eq!(hex_decode(&hex).unwrap(), bytes);
        assert_eq!(hex_decode("").unwrap(), Vec::<u8>::new());
        assert!(hex_decode("abc").is_none()); // odd length
        assert!(hex_decode("zz").is_none()); // bad digit
        assert_eq!(hex_decode("DEADbeef").unwrap(), vec![0xde, 0xad, 0xbe, 0xef]);
    }

    #[test]
    fn bool_accessor() {
        assert_eq!(Json::parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(Json::parse("1").unwrap().as_bool(), None);
    }

    #[test]
    fn real_manifest_shape() {
        // mirror of what python/compile/aot.py emits
        let src = r#"{
 "k": 64, "batches": [2048, 256], "dims": [64, 784],
 "fingerprint": "abc",
 "entries": [
  {"name": "assign_b256_d64_k64", "file": "assign_b256_d64_k64.hlo.txt",
   "inputs": [["float32", [256, 64]], ["float32", [64, 64]], ["float32", [64]]],
   "outputs": [["int32", [256]], ["float32", [256]]]}
 ]}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("k").unwrap().as_usize(), Some(64));
        let e = &v.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(
            e.get("inputs").unwrap().as_arr().unwrap()[0]
                .as_arr()
                .unwrap()[1]
                .as_arr()
                .unwrap()
                .len(),
            2
        );
    }
}
