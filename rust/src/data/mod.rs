//! Datasets: a storage-agnostic [`Data`] handle (dense or CSR) with
//! precomputed row norms, plus the synthetic workload generators that
//! stand in for the paper's infMNIST and RCV1 corpora (see DESIGN.md
//! §Substitutions) and a Gaussian-mixture generator for tests/examples.

pub mod gaussian;
pub mod infmnist;
pub mod rcv1;
pub mod shard;
pub mod shuffle;

use crate::linalg::dense::{self, DenseMatrix};
use crate::linalg::sparse::{self, CsrMatrix};
use shard::{BlockRows, ShardData};

/// Physical storage of a dataset.
///
/// `Shard` is a disk-backed variant (see [`shard`]): row payloads live
/// in an on-disk shard file behind a bounded block cache, while the
/// `Data`-level norms stay resident. Row accessors fetch the owning
/// block and delegate to exactly the same dense/sparse kernels as the
/// in-RAM variants, so results are bit-identical.
#[derive(Clone, Debug)]
pub enum Storage {
    Dense(DenseMatrix),
    Sparse(CsrMatrix),
    Shard(ShardData),
}

/// A dataset: storage + precomputed squared row norms (`‖x_i‖²`), the
/// quantity every norms-trick distance needs.
#[derive(Clone, Debug)]
pub struct Data {
    pub storage: Storage,
    pub norms: Vec<f32>,
}

impl Data {
    pub fn dense(m: DenseMatrix) -> Self {
        let norms = m.row_sq_norms();
        Self { storage: Storage::Dense(m), norms }
    }

    pub fn sparse(m: CsrMatrix) -> Self {
        let norms = m.row_sq_norms();
        Self { storage: Storage::Sparse(m), norms }
    }

    pub fn n(&self) -> usize {
        match &self.storage {
            Storage::Dense(m) => m.rows,
            Storage::Sparse(m) => m.rows,
            Storage::Shard(s) => s.n(),
        }
    }

    pub fn dim(&self) -> usize {
        match &self.storage {
            Storage::Dense(m) => m.cols,
            Storage::Sparse(m) => m.cols,
            Storage::Shard(s) => s.dim(),
        }
    }

    /// Whether rows are CSR-encoded (true for sparse-kind shards too —
    /// kernel and wire paths branch on row encoding, not residency).
    pub fn is_sparse(&self) -> bool {
        match &self.storage {
            Storage::Dense(_) => false,
            Storage::Sparse(_) => true,
            Storage::Shard(s) => s.is_sparse(),
        }
    }

    /// Whether rows live in a disk shard rather than RAM.
    pub fn is_sharded(&self) -> bool {
        matches!(self.storage, Storage::Shard(_))
    }

    /// Squared distance from point `i` to a dense centroid row.
    #[inline]
    pub fn sq_dist_to(&self, i: usize, c: &[f32], cn: f32) -> f32 {
        match &self.storage {
            Storage::Dense(m) => {
                dense::sq_dist_norms(m.row(i), self.norms[i], c, cn)
            }
            Storage::Sparse(m) => {
                let (idx, vals) = m.row(i);
                sparse::sq_dist_sparse(idx, vals, self.norms[i], c, cn)
            }
            Storage::Shard(s) => {
                let (blk, r) = s.fetch(i);
                match &*blk {
                    BlockRows::Dense(m) => {
                        dense::sq_dist_norms(m.row(r), self.norms[i], c, cn)
                    }
                    BlockRows::Sparse(m) => {
                        let (idx, vals) = m.row(r);
                        sparse::sq_dist_sparse(idx, vals, self.norms[i], c, cn)
                    }
                }
            }
        }
    }

    /// Nearest centroid of point `i`: `(argmin_j, min ‖x_i − c_j‖²)`.
    #[inline]
    pub fn nearest(&self, i: usize, c: &DenseMatrix, cnorms: &[f32]) -> (u32, f32) {
        match &self.storage {
            Storage::Dense(m) => {
                dense::nearest(m.row(i), self.norms[i], c, cnorms)
            }
            Storage::Sparse(m) => {
                let (idx, vals) = m.row(i);
                sparse::nearest_sparse(idx, vals, self.norms[i], c, cnorms)
            }
            Storage::Shard(s) => {
                let (blk, r) = s.fetch(i);
                match &*blk {
                    BlockRows::Dense(m) => {
                        dense::nearest(m.row(r), self.norms[i], c, cnorms)
                    }
                    BlockRows::Sparse(m) => {
                        let (idx, vals) = m.row(r);
                        sparse::nearest_sparse(idx, vals, self.norms[i], c, cnorms)
                    }
                }
            }
        }
    }

    /// `acc += x_i` (f64 accumulator row).
    #[inline]
    pub fn add_row_to(&self, i: usize, acc: &mut [f64]) {
        match &self.storage {
            Storage::Dense(m) => dense::add_into(acc, m.row(i)),
            Storage::Sparse(m) => {
                let (idx, vals) = m.row(i);
                sparse::scatter_add(acc, idx, vals);
            }
            Storage::Shard(s) => {
                let (blk, r) = s.fetch(i);
                match &*blk {
                    BlockRows::Dense(m) => dense::add_into(acc, m.row(r)),
                    BlockRows::Sparse(m) => {
                        let (idx, vals) = m.row(r);
                        sparse::scatter_add(acc, idx, vals);
                    }
                }
            }
        }
    }

    /// `acc -= x_i`.
    #[inline]
    pub fn sub_row_from(&self, i: usize, acc: &mut [f64]) {
        match &self.storage {
            Storage::Dense(m) => dense::sub_from(acc, m.row(i)),
            Storage::Sparse(m) => {
                let (idx, vals) = m.row(i);
                sparse::scatter_sub(acc, idx, vals);
            }
            Storage::Shard(s) => {
                let (blk, r) = s.fetch(i);
                match &*blk {
                    BlockRows::Dense(m) => dense::sub_from(acc, m.row(r)),
                    BlockRows::Sparse(m) => {
                        let (idx, vals) = m.row(r);
                        sparse::scatter_sub(acc, idx, vals);
                    }
                }
            }
        }
    }

    /// Copy row `i` densely into `out` (zero-filled first). Used by the
    /// XLA engine to pack batch tiles and by initialisation.
    pub fn write_row_dense(&self, i: usize, out: &mut [f32]) {
        assert_eq!(out.len(), self.dim());
        match &self.storage {
            Storage::Dense(m) => out.copy_from_slice(m.row(i)),
            Storage::Sparse(m) => {
                out.fill(0.0);
                let (idx, vals) = m.row(i);
                for t in 0..idx.len() {
                    out[idx[t] as usize] = vals[t];
                }
            }
            Storage::Shard(s) => {
                let (blk, r) = s.fetch(i);
                match &*blk {
                    BlockRows::Dense(m) => out.copy_from_slice(m.row(r)),
                    BlockRows::Sparse(m) => {
                        out.fill(0.0);
                        let (idx, vals) = m.row(r);
                        for t in 0..idx.len() {
                            out[idx[t] as usize] = vals[t];
                        }
                    }
                }
            }
        }
    }

    /// Materialise the given rows (in iteration order) into an owned
    /// in-RAM `Data` of the same row encoding, reusing the stored
    /// norms. This is how shard-backed chunks are staged for the
    /// blocked assignment kernels: same values, same norms, same order
    /// → bit-identical results.
    pub fn gather_rows(&self, picks: impl Iterator<Item = usize>) -> Data {
        let dim = self.dim();
        let mut norms = Vec::new();
        // Memoise the last block so consecutive picks from the same
        // block take the store lock once.
        let mut memo: Option<(usize, std::sync::Arc<BlockRows>)> = None;
        let mut block_row = |s: &ShardData, i: usize| -> (std::sync::Arc<BlockRows>, usize) {
            let b = i / shard::BLOCK_ROWS;
            match &memo {
                Some((mb, arc)) if *mb == b && i % shard::BLOCK_ROWS < arc.rows() => {
                    (arc.clone(), i % shard::BLOCK_ROWS)
                }
                _ => {
                    let (arc, r) = s.fetch(i);
                    memo = Some((b, arc.clone()));
                    (arc, r)
                }
            }
        };
        if self.is_sparse() {
            let mut m = CsrMatrix::empty(dim);
            for i in picks {
                norms.push(self.norms[i]);
                match &self.storage {
                    Storage::Sparse(src) => {
                        let (idx, vals) = src.row(i);
                        m.push_row_parts(idx, vals);
                    }
                    Storage::Shard(s) => {
                        let (blk, r) = block_row(s, i);
                        match &*blk {
                            BlockRows::Sparse(src) => {
                                let (idx, vals) = src.row(r);
                                m.push_row_parts(idx, vals);
                            }
                            BlockRows::Dense(_) => unreachable!(),
                        }
                    }
                    Storage::Dense(_) => unreachable!(),
                }
            }
            Data { storage: Storage::Sparse(m), norms }
        } else {
            let mut buf = Vec::new();
            let mut rows = 0usize;
            for i in picks {
                norms.push(self.norms[i]);
                rows += 1;
                match &self.storage {
                    Storage::Dense(src) => buf.extend_from_slice(src.row(i)),
                    Storage::Shard(s) => {
                        let (blk, r) = block_row(s, i);
                        match &*blk {
                            BlockRows::Dense(src) => buf.extend_from_slice(src.row(r)),
                            BlockRows::Sparse(_) => unreachable!(),
                        }
                    }
                    Storage::Sparse(_) => unreachable!(),
                }
            }
            Data { storage: Storage::Dense(DenseMatrix::from_vec(rows, dim, buf)), norms }
        }
    }

    /// An in-RAM copy of this dataset (identity for already-resident
    /// storage). Serialisation paths (snapshots, wire) go through this
    /// so a shard-backed session writes byte-identical artifacts to an
    /// in-RAM one.
    pub fn to_resident(&self) -> Data {
        match &self.storage {
            Storage::Shard(_) => self.gather_rows(0..self.n()),
            _ => self.clone(),
        }
    }

    /// Materialise a row permutation (norms re-used, not recomputed).
    /// Shard-backed data materialises to RAM first — only the batch
    /// harness shuffles, and it owns its dataset.
    pub fn permute(&self, perm: &[usize]) -> Data {
        let norms = perm.iter().map(|&p| self.norms[p]).collect();
        let storage = match &self.storage {
            Storage::Dense(m) => Storage::Dense(m.permute_rows(perm)),
            Storage::Sparse(m) => Storage::Sparse(m.permute_rows(perm)),
            Storage::Shard(_) => return self.gather_rows(perm.iter().copied()),
        };
        Data { storage, norms }
    }

    /// Rows `[lo, hi)` as a new dataset (shard rows materialise).
    pub fn slice(&self, lo: usize, hi: usize) -> Data {
        let storage = match &self.storage {
            Storage::Dense(m) => Storage::Dense(m.slice_rows(lo, hi)),
            Storage::Sparse(m) => Storage::Sparse(m.slice_rows(lo, hi)),
            Storage::Shard(_) => return self.gather_rows(lo..hi),
        };
        Data { storage, norms: self.norms[lo..hi].to_vec() }
    }
}

/// A train/validation pair with provenance, as the experiments consume.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub train: Data,
    pub val: Data,
}

impl Dataset {
    pub fn summary(&self) -> String {
        let kind = if self.train.is_sparse() { "sparse" } else { "dense" };
        format!(
            "{} [{}]: train n={} d={}, val n={}",
            self.name,
            kind,
            self.train.n(),
            self.train.dim(),
            self.val.n()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_dense() -> Data {
        Data::dense(DenseMatrix::from_vec(
            3,
            2,
            vec![1.0, 0.0, 0.0, 2.0, 3.0, 4.0],
        ))
    }

    fn tiny_sparse() -> Data {
        let mut m = CsrMatrix::empty(4);
        m.push_row(&[(0, 1.0), (3, 2.0)]);
        m.push_row(&[(1, -1.0)]);
        Data::sparse(m)
    }

    #[test]
    fn norms_precomputed() {
        assert_eq!(tiny_dense().norms, vec![1.0, 4.0, 25.0]);
        assert_eq!(tiny_sparse().norms, vec![5.0, 1.0]);
    }

    #[test]
    fn nearest_agrees_between_storages() {
        let d = tiny_sparse();
        let c = DenseMatrix::from_vec(2, 4, vec![1.0, 0.0, 0.0, 2.0, 0.0, -1.0, 0.0, 0.0]);
        let cn = c.row_sq_norms();
        let (j0, d0) = d.nearest(0, &c, &cn);
        assert_eq!(j0, 0);
        assert!(d0.abs() < 1e-6);
        let (j1, d1) = d.nearest(1, &c, &cn);
        assert_eq!(j1, 1);
        assert!(d1.abs() < 1e-6);
    }

    #[test]
    fn add_sub_row_dense_sparse() {
        for data in [tiny_dense(), tiny_sparse()] {
            let d = data.dim();
            let mut acc = vec![0.0f64; d];
            data.add_row_to(0, &mut acc);
            data.add_row_to(1, &mut acc);
            data.sub_row_from(0, &mut acc);
            let mut expect = vec![0.0f32; d];
            data.write_row_dense(1, &mut expect);
            for t in 0..d {
                assert!((acc[t] - expect[t] as f64).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn permute_slice_consistency() {
        let d = tiny_dense();
        let p = d.permute(&[2, 1, 0]);
        assert_eq!(p.norms, vec![25.0, 4.0, 1.0]);
        let s = p.slice(1, 3);
        assert_eq!(s.n(), 2);
        assert_eq!(s.norms, vec![4.0, 1.0]);
        let mut row = vec![0.0; 2];
        s.write_row_dense(1, &mut row);
        assert_eq!(row, vec![1.0, 0.0]);
    }

    #[test]
    fn write_row_dense_zero_fills() {
        let d = tiny_sparse();
        let mut out = vec![9.0f32; 4];
        d.write_row_dense(1, &mut out);
        assert_eq!(out, vec![0.0, -1.0, 0.0, 0.0]);
    }
}
