//! Wire-level point encodings, shared by every ingress path.
//!
//! A row can cross the wire two ways:
//!
//! * **dense** — a JSON array of numbers (the PR 1 format), or a raw
//!   little-endian f32 block in a binary frame;
//! * **sparse** — `{"indices":[…],"values":[…],"dim":d}` with strictly
//!   ascending indices, or the equivalent binary block. RCV1-shaped
//!   queries are ~76 non-zeros in 47,236 dimensions, so this cuts
//!   predict payloads by orders of magnitude (see README §Wire formats).
//!
//! Decoding never densifies a sparse row for a sparse model (and never
//! sparsifies a dense model's row twice): [`assemble`] builds exactly
//! the storage the engine consumes. Bit-parity across encodings is a
//! hard invariant — a sparse-encoded row must score **bit-identically**
//! to its dense twin — so decode normalises to what the dense path
//! produces: explicit zeros are dropped (dense rows are sparsified by
//! skipping zeros) and non-finite values are rejected at the boundary,
//! exactly like `OnlineSession::ingest_rows`. Enforced by
//! `tests/serve_wire.rs`.

use crate::data::Data;
use crate::linalg::dense::DenseMatrix;
use crate::linalg::sparse::CsrMatrix;
use crate::util::json::Json;
use anyhow::{anyhow, bail, ensure, Result};

/// One query/ingest row as it arrived on the wire.
#[derive(Clone, Debug, PartialEq)]
pub enum WireRow {
    /// All `dim` coordinates, in order.
    Dense(Vec<f32>),
    /// Non-zeros only, indices strictly ascending. Explicit zeros were
    /// dropped at decode time (see module docs).
    Sparse {
        dim: usize,
        idx: Vec<u32>,
        vals: Vec<f32>,
    },
}

impl WireRow {
    /// The row's logical dimensionality.
    pub fn dim(&self) -> usize {
        match self {
            WireRow::Dense(r) => r.len(),
            WireRow::Sparse { dim, .. } => *dim,
        }
    }

    /// Stored coordinate count (`dim` for dense rows, nnz for sparse).
    pub fn stored(&self) -> usize {
        match self {
            WireRow::Dense(r) => r.len(),
            WireRow::Sparse { idx, .. } => idx.len(),
        }
    }
}

/// Validate a dense row (binary ingress: values are already f32).
pub fn dense_row(r: Vec<f32>) -> Result<WireRow> {
    for (u, x) in r.iter().enumerate() {
        ensure!(x.is_finite(), "coordinate {u} is not a finite f32 ({x})");
    }
    Ok(WireRow::Dense(r))
}

/// Validate and normalise a sparse row: indices strictly ascending and
/// in `0..dim`, values finite, explicit zeros dropped so the row is
/// exactly the sparsification of its dense twin.
pub fn sparse_row(dim: usize, idx: Vec<u32>, vals: Vec<f32>) -> Result<WireRow> {
    ensure!(dim >= 1, "sparse row: 'dim' must be >= 1");
    ensure!(
        idx.len() == vals.len(),
        "sparse row: {} indices but {} values",
        idx.len(),
        vals.len()
    );
    let mut prev: Option<u32> = None;
    for (t, &c) in idx.iter().enumerate() {
        ensure!(
            (c as usize) < dim,
            "sparse row: index {c} out of range for dim {dim}"
        );
        if let Some(p) = prev {
            ensure!(
                c > p,
                "sparse row: indices must be strictly ascending ({p} then {c})"
            );
        }
        prev = Some(c);
        ensure!(
            vals[t].is_finite(),
            "sparse row: non-finite value at index {c}"
        );
    }
    if vals.iter().any(|&x| x == 0.0) {
        let mut ni = Vec::with_capacity(idx.len());
        let mut nv = Vec::with_capacity(vals.len());
        for (t, &c) in idx.iter().enumerate() {
            if vals[t] != 0.0 {
                ni.push(c);
                nv.push(vals[t]);
            }
        }
        return Ok(WireRow::Sparse { dim, idx: ni, vals: nv });
    }
    Ok(WireRow::Sparse { dim, idx, vals })
}

/// Decode one JSON row: an array of numbers (dense) or an
/// `{"indices":…,"values":…,"dim":d}` object (sparse).
pub fn row_from_json(x: &Json) -> Result<WireRow> {
    if let Some(arr) = x.as_arr() {
        let mut r = Vec::with_capacity(arr.len());
        for (u, v) in arr.iter().enumerate() {
            let v = v
                .as_f64()
                .ok_or_else(|| anyhow!("coordinate {u} is not a number"))?;
            // check the narrowed value so f64s beyond f32 range are
            // caught too — a single inf/NaN would poison the sufficient
            // statistics for good
            ensure!(
                (v as f32).is_finite(),
                "coordinate {u} is not a finite f32 ({v})"
            );
            r.push(v as f32);
        }
        return Ok(WireRow::Dense(r));
    }
    if matches!(x, Json::Obj(_)) {
        let nums = |key: &str| -> Result<&[Json]> {
            x.get(key).and_then(Json::as_arr).ok_or_else(|| {
                anyhow!("sparse row needs an array field '{key}'")
            })
        };
        let dim = x
            .get("dim")
            .and_then(Json::as_f64)
            .filter(|d| *d >= 1.0 && d.fract() == 0.0)
            .ok_or_else(|| {
                anyhow!("sparse row needs a positive integer 'dim'")
            })? as usize;
        let raw_idx = nums("indices")?;
        let raw_vals = nums("values")?;
        let mut idx = Vec::with_capacity(raw_idx.len());
        for (t, v) in raw_idx.iter().enumerate() {
            let v = v
                .as_f64()
                .filter(|c| *c >= 0.0 && c.fract() == 0.0)
                .ok_or_else(|| {
                    anyhow!("indices[{t}] is not a non-negative integer")
                })?;
            ensure!(
                v < u32::MAX as f64,
                "indices[{t}] = {v} does not fit in u32"
            );
            idx.push(v as u32);
        }
        let mut vals = Vec::with_capacity(raw_vals.len());
        for (t, v) in raw_vals.iter().enumerate() {
            let v = v
                .as_f64()
                .ok_or_else(|| anyhow!("values[{t}] is not a number"))?;
            ensure!(
                (v as f32).is_finite(),
                "values[{t}] is not a finite f32 ({v})"
            );
            vals.push(v as f32);
        }
        return sparse_row(dim, idx, vals);
    }
    bail!(
        "a point must be an array of numbers or a sparse \
         {{\"indices\":…,\"values\":…,\"dim\":d}} object"
    )
}

/// Decode a request's `points` field: an array of rows, each dense or
/// sparse (encodings may mix within one request).
pub fn rows_from_json(v: &Json) -> Result<Vec<WireRow>> {
    let arr = v.get("points").and_then(Json::as_arr).ok_or_else(|| {
        anyhow!(
            "request needs 'points': an array of rows (dense arrays \
             and/or sparse {{\"indices\",\"values\",\"dim\"}} objects)"
        )
    })?;
    let mut out = Vec::with_capacity(arr.len());
    for (t, row) in arr.iter().enumerate() {
        out.push(
            row_from_json(row).map_err(|e| anyhow!("points[{t}]: {e:#}"))?,
        );
    }
    Ok(out)
}

/// Render dense rows as the protocol's JSON `points` array — the
/// reference client-side encoder. The benches and integration tests
/// share it, so the format under test has exactly one definition.
pub fn dense_points_json(rows: &[Vec<f32>]) -> String {
    let coords: Vec<String> = rows
        .iter()
        .map(|q| {
            let xs: Vec<String> = q.iter().map(|x| format!("{x}")).collect();
            format!("[{}]", xs.join(","))
        })
        .collect();
    format!("[{}]", coords.join(","))
}

/// Render sparse rows (`(indices, values)` per row, shared `dim`) as
/// the protocol's JSON `points` array of
/// `{"indices":…,"values":…,"dim":d}` objects.
pub fn sparse_points_json(dim: usize, rows: &[(Vec<u32>, Vec<f32>)]) -> String {
    let objs: Vec<String> = rows
        .iter()
        .map(|(idx, vals)| {
            let is: Vec<String> = idx.iter().map(|c| format!("{c}")).collect();
            let vs: Vec<String> =
                vals.iter().map(|x| format!("{x}")).collect();
            format!(
                "{{\"indices\":[{}],\"values\":[{}],\"dim\":{dim}}}",
                is.join(","),
                vs.join(",")
            )
        })
        .collect();
    format!("[{}]", objs.join(","))
}

/// Assemble wire rows into engine-ready storage for a model of
/// dimension `dim`: CSR when the model stores sparse data, dense
/// otherwise. Dense rows are sparsified exactly like
/// `OnlineSession::ingest_rows` (non-zeros in coordinate order) and
/// sparse rows scatter into a zero row, so a row scores bit-identically
/// whichever encoding carried it.
pub fn assemble(rows: &[WireRow], dim: usize, sparse: bool) -> Result<Data> {
    for (t, row) in rows.iter().enumerate() {
        ensure!(
            row.dim() == dim,
            "row {t}: dimension {} != model dimension {dim}",
            row.dim()
        );
    }
    if sparse {
        let mut m = CsrMatrix::empty(dim);
        let mut cv: Vec<(u32, f32)> = Vec::new();
        for row in rows {
            cv.clear();
            match row {
                WireRow::Dense(r) => {
                    for (c, &x) in r.iter().enumerate() {
                        if x != 0.0 {
                            cv.push((c as u32, x));
                        }
                    }
                }
                WireRow::Sparse { idx, vals, .. } => {
                    for (t, &c) in idx.iter().enumerate() {
                        cv.push((c, vals[t]));
                    }
                }
            }
            m.push_row(&cv);
        }
        Ok(Data::sparse(m))
    } else {
        let n = rows.len();
        let mut buf = vec![0f32; n * dim];
        for (t, row) in rows.iter().enumerate() {
            let out = &mut buf[t * dim..(t + 1) * dim];
            match row {
                WireRow::Dense(r) => out.copy_from_slice(r),
                WireRow::Sparse { idx, vals, .. } => {
                    for (u, &c) in idx.iter().enumerate() {
                        out[c as usize] = vals[u];
                    }
                }
            }
        }
        Ok(Data::dense(DenseMatrix::from_vec(n, dim, buf)))
    }
}

/// Binary encoding of a mixed dense/sparse row batch — the WAL's ingest
/// record body. Layout (all little-endian):
///
/// ```text
/// u32 n_rows, then per row:
///   u8 tag = 1 (dense):  u32 dim | dim × f32
///   u8 tag = 2 (sparse): u32 dim | u32 nnz | nnz × u32 idx | nnz × f32
/// ```
///
/// Unlike the frame-body point blocks (`serve::frame`), rows here keep
/// their original encoding and per-row dimension, so a decoded batch is
/// exactly the `Vec<WireRow>` the primary ingested — replay feeds
/// `ingest_wire` the same rows and gets the same bits.
pub fn encode_rows(rows: &[WireRow]) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 + rows.iter().map(WireRow::stored).sum::<usize>() * 8);
    out.extend_from_slice(&(rows.len() as u32).to_le_bytes());
    for row in rows {
        match row {
            WireRow::Dense(r) => encode_dense_row_into(&mut out, r),
            WireRow::Sparse { dim, idx, vals } => {
                encode_sparse_row_into(&mut out, *dim, idx, vals)
            }
        }
    }
    out
}

/// Append one dense row in the [`encode_rows`] per-row layout. Shared
/// with the disk shard block writer (`data::shard`) and the binary
/// snapshot data section so every on-disk row speaks the same codec.
pub fn encode_dense_row_into(out: &mut Vec<u8>, r: &[f32]) {
    out.push(1);
    out.extend_from_slice(&(r.len() as u32).to_le_bytes());
    for x in r {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Append one sparse row in the [`encode_rows`] per-row layout.
pub fn encode_sparse_row_into(out: &mut Vec<u8>, dim: usize, idx: &[u32], vals: &[f32]) {
    debug_assert_eq!(idx.len(), vals.len());
    out.push(2);
    out.extend_from_slice(&(dim as u32).to_le_bytes());
    out.extend_from_slice(&(idx.len() as u32).to_le_bytes());
    for c in idx {
        out.extend_from_slice(&c.to_le_bytes());
    }
    for x in vals {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Decode an [`encode_rows`] batch, re-validating every row through the
/// same [`dense_row`]/[`sparse_row`] boundary as live ingress (a corrupt
/// log record must fail loudly, not poison the statistics).
pub fn decode_rows(bytes: &[u8]) -> Result<Vec<WireRow>> {
    fn take<'a>(bytes: &'a [u8], at: &mut usize, n: usize) -> Result<&'a [u8]> {
        let end = at
            .checked_add(n)
            .filter(|&e| e <= bytes.len())
            .ok_or_else(|| anyhow!("row batch truncated at byte {at}"))?;
        let s = &bytes[*at..end];
        *at = end;
        Ok(s)
    }
    fn take_u32(bytes: &[u8], at: &mut usize) -> Result<u32> {
        Ok(u32::from_le_bytes(take(bytes, at, 4)?.try_into().unwrap()))
    }
    fn take_f32s(bytes: &[u8], at: &mut usize, n: usize) -> Result<Vec<f32>> {
        let cnt = n.checked_mul(4).ok_or_else(|| anyhow!("row length overflow"))?;
        Ok(take(bytes, at, cnt)?
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
    let mut at = 0usize;
    let n = take_u32(bytes, &mut at)? as usize;
    ensure!(
        n <= bytes.len(), // each row costs ≥ 5 bytes; cheap pre-alloc cap
        "row batch claims {n} rows in {} bytes",
        bytes.len()
    );
    let mut rows = Vec::with_capacity(n);
    for t in 0..n {
        let tag = take(bytes, &mut at, 1)?[0];
        let dim = take_u32(bytes, &mut at)? as usize;
        let row = match tag {
            1 => dense_row(take_f32s(bytes, &mut at, dim)?),
            2 => {
                let nnz = take_u32(bytes, &mut at)? as usize;
                let cnt = nnz
                    .checked_mul(4)
                    .ok_or_else(|| anyhow!("row {t}: nnz overflow"))?;
                let idx: Vec<u32> = take(bytes, &mut at, cnt)?
                    .chunks_exact(4)
                    .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                let vals = take_f32s(bytes, &mut at, nnz)?;
                sparse_row(dim, idx, vals)
            }
            other => bail!("row {t}: unknown encoding tag {other}"),
        };
        rows.push(row.map_err(|e| anyhow!("row {t}: {e:#}"))?);
    }
    ensure!(at == bytes.len(), "row batch has {} trailing bytes", bytes.len() - at);
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Storage;

    fn parse_row(src: &str) -> Result<WireRow> {
        row_from_json(&Json::parse(src).unwrap())
    }

    #[test]
    fn dense_json_rows_decode() {
        let r = parse_row("[1,2.5,0]").unwrap();
        assert_eq!(r, WireRow::Dense(vec![1.0, 2.5, 0.0]));
        assert_eq!(r.dim(), 3);
        assert!(parse_row("[1,\"x\"]").is_err());
        assert!(parse_row("[1e400]").is_err(), "overflows f32");
        assert!(parse_row("3").is_err(), "scalar is not a row");
    }

    #[test]
    fn sparse_json_rows_decode_and_normalise() {
        let r = parse_row(
            r#"{"indices":[1,4,7],"values":[0.5,-2,3],"dim":10}"#,
        )
        .unwrap();
        assert_eq!(
            r,
            WireRow::Sparse {
                dim: 10,
                idx: vec![1, 4, 7],
                vals: vec![0.5, -2.0, 3.0]
            }
        );
        assert_eq!((r.dim(), r.stored()), (10, 3));
        // explicit zeros (and negative zero) are dropped, matching how
        // dense rows sparsify on ingest
        let r = parse_row(
            r#"{"indices":[0,2,5],"values":[1,0,-0.0],"dim":6}"#,
        )
        .unwrap();
        assert_eq!(
            r,
            WireRow::Sparse { dim: 6, idx: vec![0], vals: vec![1.0] }
        );
        // the empty row is legal (an all-zero document)
        let r = parse_row(r#"{"indices":[],"values":[],"dim":4}"#).unwrap();
        assert_eq!(r.stored(), 0);
    }

    #[test]
    fn sparse_json_rows_reject_malformed() {
        for bad in [
            r#"{"indices":[1],"values":[1,2],"dim":4}"#, // length mismatch
            r#"{"indices":[2,1],"values":[1,2],"dim":4}"#, // unsorted
            r#"{"indices":[1,1],"values":[1,2],"dim":4}"#, // duplicate
            r#"{"indices":[4],"values":[1],"dim":4}"#,   // out of range
            r#"{"indices":[1],"values":[1e400],"dim":4}"#, // non-finite
            r#"{"indices":[1.5],"values":[1],"dim":4}"#, // fractional index
            r#"{"indices":[-1],"values":[1],"dim":4}"#,  // negative index
            r#"{"indices":[1],"values":[1]}"#,           // missing dim
            r#"{"indices":[1],"values":[1],"dim":0}"#,   // bad dim
            r#"{"values":[1],"dim":4}"#,                 // missing indices
            r#"{"indices":[1],"dim":4}"#,                // missing values
        ] {
            assert!(parse_row(bad).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn client_encoders_roundtrip_through_the_parser() {
        let dense = vec![vec![1.0f32, 0.0, -2.5], vec![0.25, 3.0, 0.5]];
        let req = Json::parse(&format!(
            "{{\"points\":{}}}",
            dense_points_json(&dense)
        ))
        .unwrap();
        let rows = rows_from_json(&req).unwrap();
        assert_eq!(rows[0], WireRow::Dense(dense[0].clone()));
        assert_eq!(rows[1], WireRow::Dense(dense[1].clone()));
        let sparse = vec![(vec![1u32, 7], vec![0.5f32, -1.5])];
        let req = Json::parse(&format!(
            "{{\"points\":{}}}",
            sparse_points_json(9, &sparse)
        ))
        .unwrap();
        let rows = rows_from_json(&req).unwrap();
        assert_eq!(
            rows[0],
            WireRow::Sparse { dim: 9, idx: vec![1, 7], vals: vec![0.5, -1.5] }
        );
    }

    #[test]
    fn rows_from_json_mixes_encodings() {
        let v = Json::parse(
            r#"{"points":[[1,0,2],{"indices":[0,2],"values":[1,2],"dim":3}]}"#,
        )
        .unwrap();
        let rows = rows_from_json(&v).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].dim(), 3);
        assert_eq!(rows[1].stored(), 2);
        assert!(rows_from_json(&Json::parse(r#"{"op":"x"}"#).unwrap()).is_err());
    }

    #[test]
    fn row_batch_binary_roundtrip() {
        let rows = vec![
            WireRow::Dense(vec![1.0, -2.5, 0.0]),
            sparse_row(9, vec![1, 7], vec![0.5, -1.5]).unwrap(),
            WireRow::Dense(vec![]),
            sparse_row(4, vec![], vec![]).unwrap(),
        ];
        let bytes = encode_rows(&rows);
        let back = decode_rows(&bytes).unwrap();
        assert_eq!(back, rows);
        // every truncation fails cleanly instead of panicking
        for cut in 0..bytes.len() {
            assert!(decode_rows(&bytes[..cut]).is_err(), "accepted cut at {cut}");
        }
        // trailing garbage is rejected (a record must be exactly one batch)
        let mut padded = bytes.clone();
        padded.push(0);
        assert!(decode_rows(&padded).is_err());
        // unknown tag
        let mut bad = bytes.clone();
        bad[4] = 9;
        assert!(decode_rows(&bad).is_err());
    }

    #[test]
    fn assemble_parity_across_encodings() {
        // the same logical rows, once dense-encoded, once sparse-encoded
        let dense = vec![
            WireRow::Dense(vec![0.0, 1.5, 0.0, -2.0]),
            WireRow::Dense(vec![3.0, 0.0, 0.0, 0.0]),
        ];
        let sparse = vec![
            sparse_row(4, vec![1, 3], vec![1.5, -2.0]).unwrap(),
            sparse_row(4, vec![0], vec![3.0]).unwrap(),
        ];
        // sparse target: identical CSR bits
        let a = assemble(&dense, 4, true).unwrap();
        let b = assemble(&sparse, 4, true).unwrap();
        let (Storage::Sparse(ma), Storage::Sparse(mb)) =
            (&a.storage, &b.storage)
        else {
            panic!("expected CSR storage");
        };
        assert_eq!(ma.indices, mb.indices);
        assert_eq!(
            ma.values.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            mb.values.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(
            a.norms.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.norms.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        // dense target: identical row-major buffers
        let a = assemble(&dense, 4, false).unwrap();
        let b = assemble(&sparse, 4, false).unwrap();
        let (Storage::Dense(ma), Storage::Dense(mb)) =
            (&a.storage, &b.storage)
        else {
            panic!("expected dense storage");
        };
        assert_eq!(
            ma.data.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            mb.data.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        // dimension mismatches are rejected with the row index
        let err = assemble(&dense, 5, false).unwrap_err();
        assert!(format!("{err:#}").contains("row 0"), "{err:#}");
    }
}
