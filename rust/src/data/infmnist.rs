//! Synthetic *infinite MNIST* simulator.
//!
//! The paper uses infMNIST (Loosli et al. 2007): a program that emits
//! unlimited random deformations of the 28×28 MNIST digits. The real
//! generator (and MNIST itself) is not available in this offline image,
//! so we reproduce the property the algorithms actually exercise — a
//! dense 784-dim dataset with ~10 modes and heavy redundancy (endless
//! near-duplicate deformations of the same prototypes):
//!
//! 1. Ten smooth prototype glyphs are drawn once per seed as sums of
//!    random Gaussian strokes on the 28×28 grid.
//! 2. Each sample picks a prototype and applies a random small affine
//!    transform (rotation, anisotropic scale, translation) via bilinear
//!    resampling — the same family of deformations infMNIST applies —
//!    plus light pixel noise.
//!
//! See DESIGN.md §Substitutions for the fidelity argument.

use crate::data::{Data, Dataset};
use crate::linalg::dense::DenseMatrix;
use crate::util::rng::Pcg64;

pub const SIDE: usize = 28;
pub const DIM: usize = SIDE * SIDE;
pub const N_CLASSES: usize = 10;

/// Configuration for the simulator.
#[derive(Clone, Debug)]
pub struct InfMnist {
    /// Maximum |rotation| in radians.
    pub max_rot: f64,
    /// Scale jitter: factor in [1−s, 1+s] per axis.
    pub max_scale: f64,
    /// Maximum |translation| in pixels per axis.
    pub max_shift: f64,
    /// Additive pixel noise σ.
    pub noise: f64,
}

impl Default for InfMnist {
    fn default() -> Self {
        Self { max_rot: 0.18, max_scale: 0.12, max_shift: 2.5, noise: 0.02 }
    }
}

/// The ten prototype glyphs for a seed (row = flattened 28×28 image).
pub fn prototypes(seed: u64) -> DenseMatrix {
    let mut rng = Pcg64::new(seed, 0xD161).derive("infmnist-protos");
    let mut protos = DenseMatrix::zeros(N_CLASSES, DIM);
    for c in 0..N_CLASSES {
        let img = protos.row_mut(c);
        // 4–7 Gaussian strokes per glyph, anchored inside the frame
        let strokes = 4 + rng.below(4);
        for _ in 0..strokes {
            let cx = rng.range_f64(6.0, 22.0);
            let cy = rng.range_f64(6.0, 22.0);
            let sx = rng.range_f64(1.2, 3.5);
            let sy = rng.range_f64(1.2, 3.5);
            let amp = rng.range_f64(0.5, 1.0);
            for y in 0..SIDE {
                for x in 0..SIDE {
                    let dx = (x as f64 - cx) / sx;
                    let dy = (y as f64 - cy) / sy;
                    img[y * SIDE + x] +=
                        (amp * (-(dx * dx + dy * dy) / 2.0).exp()) as f32;
                }
            }
        }
        // normalise glyph to peak 1
        let peak = img.iter().cloned().fold(0f32, f32::max).max(1e-6);
        for p in img.iter_mut() {
            *p = (*p / peak).min(1.0);
        }
    }
    protos
}

#[inline]
fn bilinear(img: &[f32], x: f64, y: f64) -> f32 {
    if x < 0.0 || y < 0.0 || x > (SIDE - 1) as f64 || y > (SIDE - 1) as f64 {
        return 0.0;
    }
    let x0 = x.floor() as usize;
    let y0 = y.floor() as usize;
    let x1 = (x0 + 1).min(SIDE - 1);
    let y1 = (y0 + 1).min(SIDE - 1);
    let fx = (x - x0 as f64) as f32;
    let fy = (y - y0 as f64) as f32;
    let v00 = img[y0 * SIDE + x0];
    let v01 = img[y0 * SIDE + x1];
    let v10 = img[y1 * SIDE + x0];
    let v11 = img[y1 * SIDE + x1];
    v00 * (1.0 - fx) * (1.0 - fy)
        + v01 * fx * (1.0 - fy)
        + v10 * (1.0 - fx) * fy
        + v11 * fx * fy
}

impl InfMnist {
    /// Render one deformed sample of `proto` into `out` (length 784).
    pub fn render(&self, proto: &[f32], rng: &mut Pcg64, out: &mut [f32]) {
        debug_assert_eq!(out.len(), DIM);
        let theta = rng.range_f64(-self.max_rot, self.max_rot);
        let sx = 1.0 + rng.range_f64(-self.max_scale, self.max_scale);
        let sy = 1.0 + rng.range_f64(-self.max_scale, self.max_scale);
        let tx = rng.range_f64(-self.max_shift, self.max_shift);
        let ty = rng.range_f64(-self.max_shift, self.max_shift);
        let (sin, cos) = theta.sin_cos();
        let c = (SIDE - 1) as f64 / 2.0;
        for y in 0..SIDE {
            for x in 0..SIDE {
                // inverse-map output pixel to prototype coordinates
                let ox = x as f64 - c - tx;
                let oy = y as f64 - c - ty;
                let px = (cos * ox + sin * oy) / sx + c;
                let py = (-sin * ox + cos * oy) / sy + c;
                let mut v = bilinear(proto, px, py);
                if self.noise > 0.0 {
                    v += (rng.gauss() * self.noise) as f32;
                }
                out[y * SIDE + x] = v.clamp(0.0, 1.0);
            }
        }
    }

    /// Generate `n` samples as a dense dataset.
    pub fn generate(&self, n: usize, seed: u64) -> Data {
        self.generate_stream(n, seed, "infmnist-samples")
    }

    /// Generate from the glyph family of `seed` but an independent
    /// deformation stream — train/validation splits share prototypes
    /// (as the real infMNIST program does) while drawing disjoint
    /// deformations.
    pub fn generate_stream(&self, n: usize, seed: u64, stream: &str) -> Data {
        let protos = prototypes(seed);
        let mut rng = Pcg64::new(seed, 0xD161).derive(stream);
        let mut m = DenseMatrix::zeros(n, DIM);
        for i in 0..n {
            let class = rng.below(N_CLASSES);
            // split borrow: render into a temporary row
            let proto = protos.row(class).to_vec();
            self.render(&proto, &mut rng, m.row_mut(i));
        }
        Data::dense(m)
    }

    /// Train/validation pair mirroring the paper's 10:1 split.
    pub fn dataset(&self, n_train: usize, n_val: usize, seed: u64) -> Dataset {
        Dataset {
            name: "infmnist-sim".into(),
            train: self.generate_stream(n_train, seed, "infmnist-samples"),
            // same prototypes, fresh deformations (paper: same corpus)
            val: self.generate_stream(n_val, seed, "infmnist-val"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let g = InfMnist::default();
        let a = g.generate(20, 5);
        let b = g.generate(20, 5);
        let c = g.generate(20, 6);
        let (ma, mb, mc) = match (&a.storage, &b.storage, &c.storage) {
            (
                crate::data::Storage::Dense(x),
                crate::data::Storage::Dense(y),
                crate::data::Storage::Dense(z),
            ) => (x, y, z),
            _ => panic!(),
        };
        assert_eq!(ma.data, mb.data);
        assert_ne!(ma.data, mc.data);
    }

    #[test]
    fn pixels_in_unit_range() {
        let g = InfMnist::default();
        let d = g.generate(50, 1);
        if let crate::data::Storage::Dense(m) = &d.storage {
            assert!(m.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
            // images must not be blank
            let mass: f32 = m.data.iter().sum();
            assert!(mass > 50.0, "mass={mass}");
        }
    }

    #[test]
    fn redundancy_same_class_closer_than_cross_class() {
        // Deformations of one prototype should usually be nearer each
        // other than to another prototype's deformations.
        let g = InfMnist { noise: 0.0, ..Default::default() };
        let protos = prototypes(9);
        let mut rng = Pcg64::new(9, 0).derive("t");
        let mut a1 = vec![0.0; DIM];
        let mut a2 = vec![0.0; DIM];
        let mut b1 = vec![0.0; DIM];
        g.render(proto_row(&protos, 0), &mut rng, &mut a1);
        g.render(proto_row(&protos, 0), &mut rng, &mut a2);
        g.render(proto_row(&protos, 7), &mut rng, &mut b1);
        let within = crate::linalg::dense::sq_dist(&a1, &a2);
        let cross = crate::linalg::dense::sq_dist(&a1, &b1);
        assert!(within < cross, "within={within} cross={cross}");
    }

    fn proto_row(m: &DenseMatrix, i: usize) -> &[f32] {
        m.row(i)
    }

    #[test]
    fn bilinear_identity_at_integer_coords() {
        let protos = prototypes(3);
        let img = protos.row(0);
        for y in (0..SIDE).step_by(5) {
            for x in (0..SIDE).step_by(5) {
                let v = bilinear(img, x as f64, y as f64);
                assert!((v - img[y * SIDE + x]).abs() < 1e-6);
            }
        }
        assert_eq!(bilinear(img, -1.0, 5.0), 0.0);
        assert_eq!(bilinear(img, 5.0, 100.0), 0.0);
    }

    #[test]
    fn dataset_shapes() {
        let ds = InfMnist::default().dataset(30, 10, 0);
        assert_eq!(ds.train.dim(), DIM);
        assert_eq!(ds.val.n(), 10);
    }

    #[test]
    fn val_shares_prototypes_but_not_samples() {
        let g = InfMnist::default();
        let ds = g.dataset(40, 40, 3);
        // distinct streams
        let (mt, mv) = match (&ds.train.storage, &ds.val.storage) {
            (crate::data::Storage::Dense(a), crate::data::Storage::Dense(b)) => (a, b),
            _ => panic!(),
        };
        assert_ne!(mt.data, mv.data);
        // same glyph family: mean val point is close to some train point
        // relative to a foreign-seed dataset
        let foreign = g.generate(40, 999);
        let near = |x: &Data, y: &Data| -> f64 {
            let mut total = 0f64;
            let mut row = vec![0f32; DIM];
            for i in 0..y.n() {
                y.write_row_dense(i, &mut row);
                let mut best = f32::INFINITY;
                for j in 0..x.n() {
                    let d = x.sq_dist_to(j, &row, crate::linalg::dense::sq_norm(&row));
                    best = best.min(d);
                }
                total += best as f64;
            }
            total / y.n() as f64
        };
        let same_family = near(&ds.train, &ds.val);
        let cross_family = near(&ds.train, &foreign);
        assert!(
            same_family < cross_family,
            "val should be nearer its own glyph family: {same_family} vs {cross_family}"
        );
    }
}
