"""L1 Pallas kernels: the k-means compute hot-spots.

Three kernels, all tiled over the batch dimension so centroids stay
resident in VMEM while batch tiles stream HBM→VMEM:

  * ``assign``        — nearest-centroid labels + squared distance, via the
                        MXU-form  D² = ‖x‖² + ‖c‖² − 2 X·Cᵀ  (one GEMM per
                        tile instead of a (B,K,D) broadcast).
  * ``cluster_stats`` — per-cluster sufficient statistics (Σx, counts, sse)
                        as a one-hot GEMM, accumulated across tiles.
  * ``bound_screen``  — the vectorised Elkan screen used by tb-ρ: decay
                        lower bounds by centroid displacement and emit a
                        per-point dirty flag.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper skips
individual (i, j) distance computations on a CPU; on an MXU that branchy
skipping is worthless, so the screen produces a *per-point* dirty mask and
the rust coordinator routes only dirty points into dense ``assign`` tiles.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and the AOT artifacts must run under the
rust CPU client. The BlockSpec structure is nevertheless written as it
would be for a real TPU lowering (see the VMEM budget in DESIGN.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch-tile size: 256 rows keeps the f32 working set ≈1 MB for d=832,
# k=64 (X-tile + C + D²-tile), far under a 16 MB VMEM budget, while the
# (256, d) @ (d, 64) GEMM is big enough to keep the MXU busy.
TILE_B = 256


def _assign_kernel(x_ref, c_ref, cnorm_ref, lbl_ref, d2_ref):
    """One batch tile of the assignment step.

    x_ref: (TB, D) tile, c_ref: (K, D) full centroid block,
    cnorm_ref: (K,) precomputed ‖c_j‖² (rust maintains these incrementally),
    lbl_ref: (TB,) int32 out, d2_ref: (TB,) f32 out.
    """
    x = x_ref[...]
    c = c_ref[...]
    xn = jnp.sum(x * x, axis=1, keepdims=True)              # (TB, 1)
    # The GEMM that the MXU runs; everything else is VPU elementwise.
    dots = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                        # (TB, K)
    d2 = xn + cnorm_ref[...][None, :] - 2.0 * dots
    # Cancellation can push tiny true distances below zero; clamp.
    d2 = jnp.maximum(d2, 0.0)
    lbl_ref[...] = jnp.argmin(d2, axis=1).astype(jnp.int32)
    d2_ref[...] = jnp.min(d2, axis=1)


def assign(x, c, cnorm, *, tile_b=TILE_B):
    """Nearest-centroid assignment over a (B, D) batch.

    B must be a multiple of ``tile_b`` (the rust runtime pads batches up
    to the compiled tile). Returns (labels (B,) int32, d2 (B,) f32).
    """
    b, d = x.shape
    k, _ = c.shape
    assert b % tile_b == 0, f"batch {b} not a multiple of tile {tile_b}"
    grid = (b // tile_b,)
    return pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),   # centroids resident
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((tile_b,), lambda i: (i,)),
            pl.BlockSpec((tile_b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        interpret=True,
    )(x, c, cnorm)


def _distmat_kernel(x_ref, c_ref, cnorm_ref, d2_ref):
    """One batch tile of the full distance matrix (no argmin reduction).

    Serves the tile-path tb-ρ: dirty points need their complete bound
    row refreshed, so the whole (TB, K) block leaves the kernel.
    """
    x = x_ref[...]
    xn = jnp.sum(x * x, axis=1, keepdims=True)
    dots = jax.lax.dot_general(
        x, c_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    d2_ref[...] = jnp.maximum(xn + cnorm_ref[...][None, :] - 2.0 * dots, 0.0)


def distmat(x, c, cnorm, *, tile_b=TILE_B):
    """Full (B, K) squared-distance matrix."""
    b, d = x.shape
    k, _ = c.shape
    assert b % tile_b == 0
    grid = (b // tile_b,)
    return pl.pallas_call(
        _distmat_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=[pl.BlockSpec((tile_b, k), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((b, k), jnp.float32)],
        interpret=True,
    )(x, c, cnorm)[0]


def _stats_kernel(k, x_ref, lbl_ref, d2_ref, s_ref, v_ref, sse_ref):
    """Accumulate one tile's one-hot GEMM into the (K, D) stats block.

    The output BlockSpecs map every grid step onto the same block, so the
    kernel initialises on step 0 and accumulates afterwards — the standard
    Pallas reduction-across-grid pattern.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)
        v_ref[...] = jnp.zeros_like(v_ref)
        sse_ref[...] = jnp.zeros_like(sse_ref)

    x = x_ref[...]
    lbl = lbl_ref[...]
    onehot = (lbl[:, None] == jnp.arange(k)[None, :]).astype(x.dtype)  # (TB, K)
    s_ref[...] += jax.lax.dot_general(
        onehot, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    v_ref[...] += jnp.sum(onehot, axis=0)
    sse_ref[...] += onehot.T @ d2_ref[...]


def cluster_stats(x, labels, d2, k, *, tile_b=TILE_B):
    """Per-cluster (Σx, counts, sse) for a labelled batch.

    Used by the rust coordinator when ingesting *new* points into the
    nested batch (gb/tb lines 24-30): the (K, D) deltas travel back to the
    leader instead of the full (B, D) tile.
    """
    b, d = x.shape
    assert b % tile_b == 0
    grid = (b // tile_b,)
    return pl.pallas_call(
        functools.partial(_stats_kernel, k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, d), lambda i: (i, 0)),
            pl.BlockSpec((tile_b,), lambda i: (i,)),
            pl.BlockSpec((tile_b,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ],
        interpret=True,
    )(x, labels, d2)


def _screen_kernel(lb_ref, p_ref, d_ref, lbl_ref, lb_out_ref, dirty_ref):
    """One tile of the Elkan bound screen (pure VPU work, no GEMM)."""
    lb = lb_ref[...] - p_ref[...][None, :]
    k = lb.shape[1]
    not_assigned = lbl_ref[...][:, None] != jnp.arange(k)[None, :]
    trigger = jnp.logical_and(lb < d_ref[...][:, None], not_assigned)
    lb_out_ref[...] = lb
    dirty_ref[...] = jnp.any(trigger, axis=1).astype(jnp.int32)


def bound_screen(lb, p, d, labels, *, tile_b=TILE_B):
    """Decay lower bounds by centroid displacement; flag dirty points.

    Returns (lb' (B, K), dirty (B,) int32). Clean points keep their
    assignment and skip the O(dk) distance tile entirely — the paper's
    distance-calculation elimination, expressed at point granularity.
    """
    b, k = lb.shape
    assert b % tile_b == 0
    grid = (b // tile_b,)
    return pl.pallas_call(
        _screen_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, k), lambda i: (i, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((tile_b,), lambda i: (i,)),
            pl.BlockSpec((tile_b,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((tile_b, k), lambda i: (i, 0)),
            pl.BlockSpec((tile_b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        ],
        interpret=True,
    )(lb, p, d, labels)
