//! Protocol transports: stdio and TCP.
//!
//! Both speak the JSONL protocol (`serve::protocol`) against one
//! [`OnlineSession`]. The TCP server accepts connections sequentially —
//! the session is a single training state and every mutation must be
//! serialised anyway; per-request parallelism comes from the shard pool
//! inside the assignment engine, which is where the cycles go. An
//! explicit `shutdown` request ends the whole server (stdio: EOF works
//! too).

use crate::serve::protocol::serve_lines;
use crate::serve::session::OnlineSession;
use anyhow::{Context, Result};
use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};

/// Serve requests from stdin, responses to stdout, until EOF or
/// `shutdown`.
pub fn serve_stdio(session: &mut OnlineSession) -> Result<()> {
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    serve_lines(session, stdin.lock(), &mut out)?;
    Ok(())
}

/// Bind `addr` (e.g. `127.0.0.1:7878`, or port 0 for ephemeral) and
/// serve until a client sends `shutdown`.
pub fn serve_tcp(session: &mut OnlineSession, addr: &str) -> Result<()> {
    let listener =
        TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    eprintln!(
        "[nmbkm::serve] listening on {} (JSONL: ingest|predict|step|stats|snapshot|shutdown)",
        listener.local_addr()?
    );
    serve_listener(session, listener)
}

/// Accept-loop over an already-bound listener (split out so tests can
/// bind an ephemeral port themselves).
pub fn serve_listener(
    session: &mut OnlineSession,
    listener: TcpListener,
) -> Result<()> {
    for conn in listener.incoming() {
        let stream = match conn {
            Ok(s) => s,
            Err(e) => {
                eprintln!("[nmbkm::serve] accept failed: {e}");
                continue;
            }
        };
        match serve_connection(session, stream) {
            Ok(true) => break, // explicit shutdown ends the server
            Ok(false) => {}    // client hung up; accept the next one
            Err(e) => eprintln!("[nmbkm::serve] connection error: {e:#}"),
        }
    }
    Ok(())
}

fn serve_connection(
    session: &mut OnlineSession,
    stream: TcpStream,
) -> Result<bool> {
    if let Ok(peer) = stream.peer_addr() {
        eprintln!("[nmbkm::serve] client {peer} connected");
    }
    let reader = BufReader::new(stream.try_clone().context("cloning stream")?);
    let mut writer = BufWriter::new(stream);
    serve_lines(session, reader, &mut writer)
}
