//! Substrate utilities built from scratch for the offline environment:
//! reproducible RNG streams, JSON read/write, CLI parsing, timers and
//! summary statistics, and a tiny property-testing harness.

pub mod args;
pub mod json;
pub mod propcheck;
pub mod rng;
pub mod stats;
pub mod timer;
