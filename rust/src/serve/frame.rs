//! Opt-in length-prefixed binary framing: the same ops as the JSONL
//! protocol, with point/score payloads as raw little-endian f32 blocks
//! so the predict hot loop never parses or formats a float.
//!
//! A connection enters binary mode by sending the magic byte
//! [`MAGIC`] (`0xB7`) before its first frame. JSONL is UTF-8 text (a
//! request line starts with `{` or whitespace), so the byte is
//! unambiguous and JSONL clients keep working unchanged on the same
//! port; the server only honours it when started with `nmbkm serve
//! --binary` (see `serve::server`).
//!
//! ## Frame layout (everything little-endian)
//!
//! ```text
//! request  := u32 header_len | header | u32 body_len | body
//! response := u32 header_len | header | u32 body_len | body
//! ```
//!
//! The header is a JSON object — exactly a JSONL request/response,
//! minus the bulk arrays. A request body, when non-empty, carries the
//! `points` (replacing the header's `points` field):
//!
//! ```text
//! body := 0x01 | u32 n | u32 dim | n·dim × f32              (dense)
//!       | 0x02 | u32 n | u32 dim | n × u32 nnz_i
//!              | Σnnz × u32 index | Σnnz × f32 value        (sparse)
//! ```
//!
//! Sparse rows obey the same rules as the JSON encoding (strictly
//! ascending indices, finite values; explicit zeros are dropped at
//! decode): both ingresses funnel through `serve::wire`, so a binary
//! predict is bit-identical to its JSONL twin. A `predict` response
//! carries `{"ok":true,"op":"predict","model":…,"n":N}` in the header
//! and the scores in the body:
//!
//! ```text
//! body := u32 n | n × u32 label | n × f32 d2
//! ```
//!
//! Every other response is header-only (`body_len == 0`), as is every
//! error (`{"ok":false,"error":…}` — the stream survives, exactly like
//! JSONL). Length prefixes are capped ([`MAX_HEADER_BYTES`],
//! [`MAX_BODY_BYTES`]) so a remote peer cannot ask the server to
//! allocate unboundedly — same hardening posture as the snapshot op's
//! path confinement.

use crate::obs;
use crate::serve::observe::serve_metrics;
use crate::serve::protocol::{self, Request};
use crate::serve::registry::ModelRegistry;
use crate::serve::wal;
use crate::serve::wire::{self, WireRow};
use crate::util::json::{self, Json};
use anyhow::{anyhow, bail, ensure, Result};
use std::io::{Read, Write};

/// First byte of a binary-mode connection. Not valid leading UTF-8, so
/// it can never be confused with a JSONL request line.
pub const MAGIC: u8 = 0xB7;

/// Body tag: dense f32 rows.
pub const ENC_DENSE: u8 = 1;
/// Body tag: CSR-shaped sparse rows.
pub const ENC_SPARSE: u8 = 2;

/// Cap on a frame's JSON header (ops and names are tiny).
pub const MAX_HEADER_BYTES: usize = 1 << 20;
/// Cap on a frame's binary body (256 MiB ≈ 1.4M RCV1-shaped rows).
pub const MAX_BODY_BYTES: usize = 1 << 28;
/// The most rows one predict frame may carry: its response body is
/// `4 + 8·n` bytes, and every accepted request must produce a response
/// the client's own [`read_frame`] (which enforces [`MAX_BODY_BYTES`])
/// can decode. Enforced on the request with an `ok:false` answer, so a
/// too-large batch degrades into an error, never an undecodable frame.
pub const MAX_PREDICT_ROWS: usize = (MAX_BODY_BYTES - 4) / 8;

/// Write one frame: `[u32 header_len][header][u32 body_len][body]`.
/// Returns the total bytes put on the wire (prefixes included).
pub fn write_frame<W: Write>(w: &mut W, header: &Json, body: &[u8]) -> Result<usize> {
    let h = header.to_string();
    w.write_all(&(h.len() as u32).to_le_bytes())?;
    w.write_all(h.as_bytes())?;
    w.write_all(&(body.len() as u32).to_le_bytes())?;
    w.write_all(body)?;
    w.flush()?;
    Ok(8 + h.len() + body.len())
}

/// Read one frame's raw parts; `Ok(None)` on clean EOF at a frame
/// boundary. Errors here are structural (truncation, cap violations) —
/// the stream cannot be re-synchronised after one.
pub fn read_frame_raw<R: Read>(r: &mut R) -> Result<Option<(Vec<u8>, Vec<u8>)>> {
    let Some(hlen) = read_u32_or_eof(r)? else {
        return Ok(None);
    };
    let hlen = hlen as usize;
    ensure!(
        hlen <= MAX_HEADER_BYTES,
        "frame header of {hlen} bytes exceeds the {MAX_HEADER_BYTES}-byte cap"
    );
    let hbytes = read_exact_vec(r, hlen, "header")?;
    let blen = read_u32_req(r)? as usize;
    ensure!(
        blen <= MAX_BODY_BYTES,
        "frame body of {blen} bytes exceeds the {MAX_BODY_BYTES}-byte cap"
    );
    let body = read_exact_vec(r, blen, "body")?;
    Ok(Some((hbytes, body)))
}

pub(crate) fn parse_header(hbytes: &[u8]) -> Result<Json> {
    let htext = std::str::from_utf8(hbytes)
        .map_err(|_| anyhow!("frame header is not UTF-8"))?;
    Json::parse(htext).map_err(|e| anyhow!("bad frame header json: {e}"))
}

/// Incremental frame delimiting for the nonblocking event loop: the
/// total wire length (`8 + header + body`) of the frame starting at
/// `buf[0]`, or `None` until enough prefix bytes are buffered to know
/// it. Cap violations error with the same messages as the blocking
/// [`read_frame_raw`] — they are structural, the stream cannot be
/// re-synchronised.
pub(crate) fn scan_frame_total(buf: &[u8]) -> Result<Option<usize>> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let hlen = u32::from_le_bytes(buf[0..4].try_into().unwrap()) as usize;
    ensure!(
        hlen <= MAX_HEADER_BYTES,
        "frame header of {hlen} bytes exceeds the {MAX_HEADER_BYTES}-byte cap"
    );
    if buf.len() < 8 + hlen {
        return Ok(None);
    }
    let blen =
        u32::from_le_bytes(buf[4 + hlen..8 + hlen].try_into().unwrap()) as usize;
    ensure!(
        blen <= MAX_BODY_BYTES,
        "frame body of {blen} bytes exceeds the {MAX_BODY_BYTES}-byte cap"
    );
    Ok(Some(8 + hlen + blen))
}

/// Read one frame with the header parsed; `Ok(None)` on clean EOF.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<(Json, Vec<u8>)>> {
    match read_frame_raw(r)? {
        None => Ok(None),
        Some((hbytes, body)) => Ok(Some((parse_header(&hbytes)?, body))),
    }
}

/// Encode dense rows as a points body (client side and tests). `dim`
/// is explicit — like [`encode_sparse_points`] — so an empty batch
/// still encodes a decodable block.
pub fn encode_dense_points(dim: usize, rows: &[Vec<f32>]) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(9 + rows.len() * dim * 4);
    out.push(ENC_DENSE);
    out.extend_from_slice(&(rows.len() as u32).to_le_bytes());
    out.extend_from_slice(&(dim as u32).to_le_bytes());
    for r in rows {
        ensure!(
            r.len() == dim,
            "dense point block rows must share one dimension ({} != {dim})",
            r.len()
        );
        for x in r {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
    Ok(out)
}

/// Encode sparse rows (`(indices, values)` per row, shared `dim`) as a
/// points body.
pub fn encode_sparse_points(
    dim: usize,
    rows: &[(Vec<u32>, Vec<f32>)],
) -> Result<Vec<u8>> {
    let mut out = vec![ENC_SPARSE];
    out.extend_from_slice(&(rows.len() as u32).to_le_bytes());
    out.extend_from_slice(&(dim as u32).to_le_bytes());
    for (idx, vals) in rows {
        ensure!(
            idx.len() == vals.len(),
            "sparse point block row has {} indices but {} values",
            idx.len(),
            vals.len()
        );
        out.extend_from_slice(&(idx.len() as u32).to_le_bytes());
    }
    for (idx, _) in rows {
        for c in idx {
            out.extend_from_slice(&c.to_le_bytes());
        }
    }
    for (_, vals) in rows {
        for x in vals {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
    Ok(out)
}

/// Decode a request body into wire rows (validated exactly like the
/// JSON encoding — `serve::wire` is the single gatekeeper). `n`, `dim`
/// and the nnz table are attacker-controlled, so every size is checked
/// against the body's actual length (with overflow-safe arithmetic)
/// **before** any allocation is sized from it.
pub fn decode_points(body: &[u8]) -> Result<Vec<WireRow>> {
    let mut r = ByteReader { buf: body, pos: 0 };
    let tag = r.u8()?;
    let n = r.u32()? as usize;
    let dim = r.u32()? as usize;
    ensure!(dim >= 1, "points block: dim must be >= 1");
    match tag {
        ENC_DENSE => {
            let expect = (n as u64)
                .checked_mul(dim as u64)
                .and_then(|x| x.checked_mul(4))
                .ok_or_else(|| {
                    anyhow!("dense points block: n={n} dim={dim} overflows")
                })?;
            ensure!(
                r.remaining() as u64 == expect,
                "dense points block: {} payload bytes for n={n} dim={dim}",
                r.remaining()
            );
            // n ≤ remaining/4 once the exact-size check passed
            let mut rows = Vec::with_capacity(n);
            for t in 0..n {
                let mut row = Vec::with_capacity(dim);
                for _ in 0..dim {
                    row.push(r.f32()?);
                }
                rows.push(
                    wire::dense_row(row)
                        .map_err(|e| anyhow!("points[{t}]: {e:#}"))?,
                );
            }
            Ok(rows)
        }
        ENC_SPARSE => {
            // the nnz table must physically fit before n sizes anything
            ensure!(
                r.remaining() as u64 >= n as u64 * 4,
                "sparse points block: {} payload bytes cannot hold {n} \
                 row counts",
                r.remaining()
            );
            let mut nnz = Vec::with_capacity(n);
            // total ≤ n·dim ≤ (body/4)·2³² < 2⁶² — no overflow in u64
            let mut total = 0u64;
            for _ in 0..n {
                let c = r.u32()? as usize;
                ensure!(
                    c <= dim,
                    "sparse points block: row nnz {c} exceeds dim {dim}"
                );
                total += c as u64;
                nnz.push(c);
            }
            ensure!(
                r.remaining() as u64 == total * 8,
                "sparse points block: {} payload bytes for Σnnz={total}",
                r.remaining()
            );
            // the tail is one contiguous index block then one value
            // block; walk them with separate cursors so each element is
            // copied exactly once, straight into its row
            let tail = &body[body.len() - r.remaining()..];
            let (idx_bytes, val_bytes) = tail.split_at((total * 4) as usize);
            let mut ir = ByteReader { buf: idx_bytes, pos: 0 };
            let mut vr = ByteReader { buf: val_bytes, pos: 0 };
            let mut rows = Vec::with_capacity(n);
            for (t, &c) in nnz.iter().enumerate() {
                let mut idx = Vec::with_capacity(c);
                for _ in 0..c {
                    idx.push(ir.u32()?);
                }
                let mut vals = Vec::with_capacity(c);
                for _ in 0..c {
                    vals.push(vr.f32()?);
                }
                rows.push(
                    wire::sparse_row(dim, idx, vals)
                        .map_err(|e| anyhow!("points[{t}]: {e:#}"))?,
                );
            }
            Ok(rows)
        }
        other => bail!("unknown points encoding tag {other}"),
    }
}

/// Encode a predict answer body: `u32 n | n × u32 label | n × f32 d2`.
pub fn encode_predict_body(lbl: &[u32], d2: &[f32]) -> Vec<u8> {
    debug_assert_eq!(lbl.len(), d2.len());
    let mut out = Vec::with_capacity(4 + lbl.len() * 8);
    out.extend_from_slice(&(lbl.len() as u32).to_le_bytes());
    for j in lbl {
        out.extend_from_slice(&j.to_le_bytes());
    }
    for x in d2 {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Decode a predict answer body (client side and tests).
pub fn decode_predict_body(body: &[u8]) -> Result<(Vec<u32>, Vec<f32>)> {
    let mut r = ByteReader { buf: body, pos: 0 };
    let n = r.u32()? as usize;
    ensure!(
        r.remaining() == n * 8,
        "predict body: {} payload bytes for n={n}",
        r.remaining()
    );
    let mut lbl = Vec::with_capacity(n);
    for _ in 0..n {
        lbl.push(r.u32()?);
    }
    let mut d2 = Vec::with_capacity(n);
    for _ in 0..n {
        d2.push(r.f32()?);
    }
    Ok((lbl, d2))
}

/// Drive a whole binary-framed request stream (the magic byte already
/// consumed by the transport). Mirrors `protocol::serve_lines`: request
/// errors — a malformed header included, since the frame is still
/// well-delimited — never kill the stream; only structural failures
/// (truncation, cap violations) do, because re-synchronisation is
/// impossible after one. The bool reports an explicit shutdown.
pub fn serve_frames<R: Read, W: Write>(
    registry: &ModelRegistry,
    input: &mut R,
    output: &mut W,
) -> Result<bool> {
    let sm = serve_metrics();
    while let Some((hbytes, body)) = read_frame_raw(input)? {
        sm.frames.inc();
        sm.frame_bytes_read.add(8 + (hbytes.len() + body.len()) as u64);
        let (resp, resp_body, quit) = match parse_header(&hbytes) {
            Ok(header) => handle_frame(registry, &header, &body),
            Err(e) => {
                sm.op_counter("invalid").inc();
                (protocol::err_json(&e), vec![], false)
            }
        };
        let written = write_frame(output, &resp, &resp_body)?;
        sm.frame_bytes_written.add(written as u64);
        if quit {
            return Ok(true);
        }
    }
    Ok(false)
}

/// Execute one frame. Predicts take the raw-f32 fast path — labels and
/// scores go back as a binary block, bypassing float formatting
/// entirely; every other op reuses the JSONL executor and answers
/// header-only.
fn handle_frame(
    registry: &ModelRegistry,
    header: &Json,
    body: &[u8],
) -> (Json, Vec<u8>, bool) {
    let req = match parse_frame_request(header, body) {
        Ok(r) => r,
        Err(e) => {
            serve_metrics().op_counter("invalid").inc();
            return (protocol::err_json(&e), vec![], false);
        }
    };
    execute_frame(registry, &req)
}

/// Decode one delimited frame's header + body into a [`Request`]. Pure
/// parsing — no metric counting (the caller counts one `invalid` per
/// error, whichever transport it drives).
pub(crate) fn parse_frame_request(header: &Json, body: &[u8]) -> Result<Request> {
    let points = if body.is_empty() { None } else { Some(decode_points(body)?) };
    protocol::request_from_json(header, points)
}

/// Execute one parsed frame request; returns `(header, body, quit)`.
pub(crate) fn execute_frame(
    registry: &ModelRegistry,
    req: &Request,
) -> (Json, Vec<u8>, bool) {
    let sm = serve_metrics();
    match req {
        Request::Predict { model, points, .. } => {
            predict_response(registry, model.as_deref(), points)
        }
        // the replication ops ship binary bodies (raw log records, a
        // snapshot stream), so like predict they bypass the JSONL
        // executor — which `ok:false`s them on text connections
        Request::WalFetch { from, max } => {
            sm.op_counter("wal-fetch").inc();
            let timer = obs::Timer::start();
            let out = result_frame(wal_fetch_frame(registry, *from, *max));
            timer.observe(&sm.request_seconds);
            out
        }
        Request::SyncSnapshot { model } => {
            sm.op_counter("sync-snapshot").inc();
            let timer = obs::Timer::start();
            let out =
                result_frame(sync_snapshot_frame(registry, model.as_deref()));
            timer.observe(&sm.request_seconds);
            out
        }
        _ => {
            let (resp, quit) = protocol::handle_request(registry, req);
            (resp, vec![], quit)
        }
    }
}

/// The frame fast path for predicts: answers without touching the JSONL
/// executor (labels and scores go back as a raw-f32 block), so it
/// carries its own op count + timing. Also serves JSONL requests with
/// the `"binary":true` response hint.
pub(crate) fn predict_response(
    registry: &ModelRegistry,
    model: Option<&str>,
    points: &[WireRow],
) -> (Json, Vec<u8>, bool) {
    let sm = serve_metrics();
    sm.op_counter("predict").inc();
    let timer = obs::Timer::start();
    if points.len() > MAX_PREDICT_ROWS {
        let e = anyhow!(
            "predict of {} rows would overflow the response frame \
             body cap — send at most {MAX_PREDICT_ROWS} rows per \
             frame",
            points.len()
        );
        return (protocol::err_json(&e), vec![], false);
    }
    let answered = registry.resolve(model).and_then(|e| {
        let out = e.predict_wire(points)?;
        Ok((e.name().to_string(), out))
    });
    let out = match answered {
        Ok((name, (lbl, d2))) => {
            let h = json::obj(vec![
                ("ok", Json::Bool(true)),
                ("op", json::s("predict")),
                ("model", json::s(&name)),
                ("n", json::num(lbl.len() as f64)),
            ]);
            (h, encode_predict_body(&lbl, &d2), false)
        }
        Err(e) => (protocol::err_json(&e), vec![], false),
    };
    timer.observe(&sm.request_seconds);
    out
}

fn result_frame(r: Result<(Json, Vec<u8>)>) -> (Json, Vec<u8>, bool) {
    match r {
        Ok((h, b)) => (h, b, false),
        Err(e) => (protocol::err_json(&e), vec![], false),
    }
}

/// `wal-fetch`: the raw on-disk bytes of records `[from, …)`, capped
/// near `max`, with cursor/epoch bookkeeping in the header. `reset:true`
/// tells the follower its cursor predates the oldest retained segment —
/// it must re-bootstrap from `sync-snapshot`.
fn wal_fetch_frame(
    registry: &ModelRegistry,
    from: u64,
    max: usize,
) -> Result<(Json, Vec<u8>)> {
    let w = registry.wal().ok_or_else(|| {
        anyhow!("no wal attached — start the server with --wal-dir")
    })?;
    let f = w.fetch(from, max.min(MAX_BODY_BYTES))?;
    let h = json::obj(vec![
        ("ok", Json::Bool(true)),
        ("op", json::s("wal-fetch")),
        ("epoch", wal::u64_json(f.epoch)),
        ("from", wal::u64_json(f.from)),
        ("next", wal::u64_json(f.next)),
        // the log's true head, beyond this batch — the follower's lag
        // gauge is `head - local next`
        ("head", wal::u64_json(w.next_seq())),
        ("count", json::num(f.count as f64)),
        ("reset", Json::Bool(f.reset)),
    ]);
    Ok((h, f.bytes))
}

/// `sync-snapshot`: one model's full snapshot (data included) as the
/// frame body, with the last WAL seq it covers — read under the same
/// session lock that streams the bytes, so state and seq can never be
/// torn apart by a concurrent ingest.
fn sync_snapshot_frame(
    registry: &ModelRegistry,
    model: Option<&str>,
) -> Result<(Json, Vec<u8>)> {
    let w = registry.wal().ok_or_else(|| {
        anyhow!("no wal attached — start the server with --wal-dir")
    })?;
    let entry = registry.resolve(model)?;
    // the configured format rides the wire too — the follower's decode
    // sniffs, so a binary-sidecar primary ships the smaller bytes
    let fmt = registry.snapshot_format();
    let (seq, bytes) = entry.with_session(|s| {
        let seq = entry.last_seq();
        let mut buf = Vec::new();
        s.write_snapshot_as(true, fmt, &mut buf)?;
        Ok((seq, buf))
    })?;
    ensure!(
        bytes.len() <= MAX_BODY_BYTES,
        "snapshot of {} bytes exceeds the frame body cap",
        bytes.len()
    );
    let h = json::obj(vec![
        ("ok", Json::Bool(true)),
        ("op", json::s("sync-snapshot")),
        ("model", json::s(entry.name())),
        ("seq", wal::u64_json(seq)),
        ("epoch", wal::u64_json(w.epoch())),
    ]);
    Ok((h, bytes))
}

struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl ByteReader<'_> {
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn u8(&mut self) -> Result<u8> {
        ensure!(self.remaining() >= 1, "truncated block");
        self.pos += 1;
        Ok(self.buf[self.pos - 1])
    }

    fn u32(&mut self) -> Result<u32> {
        ensure!(self.remaining() >= 4, "truncated block");
        let b: [u8; 4] =
            self.buf[self.pos..self.pos + 4].try_into().unwrap();
        self.pos += 4;
        Ok(u32::from_le_bytes(b))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }
}

/// Read a u32 length prefix, distinguishing clean EOF (no bytes at all)
/// from a truncated prefix.
fn read_u32_or_eof<R: Read>(r: &mut R) -> Result<Option<u32>> {
    let mut b = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        let n = r.read(&mut b[got..])?;
        if n == 0 {
            if got == 0 {
                return Ok(None);
            }
            bail!("truncated frame: EOF inside a length prefix");
        }
        got += n;
    }
    Ok(Some(u32::from_le_bytes(b)))
}

fn read_u32_req<R: Read>(r: &mut R) -> Result<u32> {
    read_u32_or_eof(r)?.ok_or_else(|| {
        anyhow!("truncated frame: EOF where a length prefix was expected")
    })
}

fn read_exact_vec<R: Read>(r: &mut R, len: usize, what: &str) -> Result<Vec<u8>> {
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)
        .map_err(|e| anyhow!("truncated frame {what}: {e}"))?;
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Algo, Rho, RunConfig};
    use crate::data::gaussian::GaussianMixture;
    use crate::serve::session;
    use std::io::Cursor;

    fn ready_registry() -> ModelRegistry {
        let data = GaussianMixture::default_spec(3, 4).generate(300, 1);
        let cfg = RunConfig {
            algo: Algo::GbRho,
            k: 3,
            b0: 32,
            rho: Rho::Infinite,
            threads: 2,
            max_rounds: 5,
            max_seconds: 30.0,
            ..Default::default()
        };
        ModelRegistry::with_default(session::train(&data, &cfg).unwrap().0)
    }

    fn frame_bytes(header: &str, body: &[u8]) -> Vec<u8> {
        let mut out = Vec::new();
        write_frame(&mut out, &Json::parse(header).unwrap(), body).unwrap();
        out
    }

    #[test]
    fn points_blocks_roundtrip() {
        let dense = vec![vec![1.0f32, 0.0, -2.5], vec![0.25, 3.0, 0.0]];
        let rows = decode_points(&encode_dense_points(3, &dense).unwrap()).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0], WireRow::Dense(dense[0].clone()));
        let sparse = vec![
            (vec![1u32, 7], vec![0.5f32, -1.5]),
            (vec![], vec![]),
            (vec![0u32], vec![2.0f32]),
        ];
        let rows =
            decode_points(&encode_sparse_points(9, &sparse).unwrap()).unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(
            rows[0],
            WireRow::Sparse { dim: 9, idx: vec![1, 7], vals: vec![0.5, -1.5] }
        );
        assert_eq!(rows[1].stored(), 0);
        // validation is shared with the JSON ingress: unsorted and
        // out-of-range blocks are rejected, zeros dropped
        let bad = encode_sparse_points(9, &[(vec![7, 1], vec![1.0, 2.0])]).unwrap();
        assert!(decode_points(&bad).is_err());
        let oob = encode_sparse_points(3, &[(vec![3], vec![1.0])]).unwrap();
        assert!(decode_points(&oob).is_err());
        let zeroed =
            decode_points(&encode_sparse_points(4, &[(vec![1, 2], vec![0.0, 5.0])]).unwrap())
                .unwrap();
        assert_eq!(
            zeroed[0],
            WireRow::Sparse { dim: 4, idx: vec![2], vals: vec![5.0] }
        );
        // truncation and trailing garbage are errors, not panics
        let mut block = encode_dense_points(3, &dense).unwrap();
        block.pop();
        assert!(decode_points(&block).is_err());
        let mut block = encode_dense_points(3, &dense).unwrap();
        block.push(0);
        assert!(decode_points(&block).is_err());
        assert!(decode_points(&[9u8, 0, 0, 0, 0, 1, 0, 0, 0]).is_err());
    }

    #[test]
    fn decode_points_rejects_advertised_sizes_before_allocating() {
        // a 9-byte body advertising n = u32::MAX must fail the size
        // check, never size a Vec from the header (the old code tried a
        // multi-GB reserve before validating)
        let mut huge = vec![ENC_DENSE];
        huge.extend_from_slice(&u32::MAX.to_le_bytes());
        huge.extend_from_slice(&1u32.to_le_bytes());
        assert!(decode_points(&huge).is_err());
        // same on the sparse path: the nnz table cannot fit
        let mut huge = vec![ENC_SPARSE];
        huge.extend_from_slice(&u32::MAX.to_le_bytes());
        huge.extend_from_slice(&4u32.to_le_bytes());
        assert!(decode_points(&huge).is_err());
        // n·dim·4 overflowing u64 is an error, not a wrap-around pass
        let mut wrap = vec![ENC_DENSE];
        wrap.extend_from_slice(&u32::MAX.to_le_bytes());
        wrap.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_points(&wrap).is_err());
    }

    #[test]
    fn predict_row_cap_fits_the_body_cap() {
        // every answerable predict must produce a decodable response
        assert!(4 + 8 * MAX_PREDICT_ROWS as u64 <= MAX_BODY_BYTES as u64);
        assert!(4 + 8 * (MAX_PREDICT_ROWS as u64 + 1) > MAX_BODY_BYTES as u64);
    }

    #[test]
    fn predict_body_roundtrips_bits() {
        let lbl = vec![3u32, 0, 7];
        let d2 = vec![0.125f32, f32::MIN_POSITIVE, 1e30];
        let (l2, s2) = decode_predict_body(&encode_predict_body(&lbl, &d2)).unwrap();
        assert_eq!(l2, lbl);
        assert_eq!(
            s2.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            d2.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert!(decode_predict_body(&[1, 0, 0, 0]).is_err(), "truncated");
    }

    #[test]
    fn frames_roundtrip_and_reject_oversize() {
        let mut buf = Vec::new();
        let h = Json::parse(r#"{"op":"stats"}"#).unwrap();
        write_frame(&mut buf, &h, &[1, 2, 3]).unwrap();
        let mut cur = Cursor::new(buf);
        let (h2, b2) = read_frame(&mut cur).unwrap().unwrap();
        assert_eq!(h2, h);
        assert_eq!(b2, vec![1, 2, 3]);
        assert!(read_frame(&mut cur).unwrap().is_none(), "clean EOF");
        // truncated header is an error, not EOF
        let mut cur = Cursor::new(vec![5u8, 0, 0, 0, b'{']);
        assert!(read_frame(&mut cur).is_err());
        // a huge advertised header is refused before allocation
        let mut cur = Cursor::new(u32::MAX.to_le_bytes().to_vec());
        assert!(read_frame(&mut cur).is_err());
    }

    #[test]
    fn serve_frames_stream_semantics() {
        let reg = ready_registry();
        let mut input = Vec::new();
        input.extend_from_slice(&frame_bytes(r#"{"op":"bogus"}"#, &[]));
        input.extend_from_slice(&frame_bytes(r#"{"op":"stats"}"#, &[]));
        let mut out = Vec::new();
        let quit =
            serve_frames(&reg, &mut Cursor::new(input), &mut out).unwrap();
        assert!(!quit, "EOF, not shutdown");
        let mut cur = Cursor::new(out);
        let (first, body) = read_frame(&mut cur).unwrap().unwrap();
        assert_eq!(first.get("ok").unwrap().as_bool(), Some(false));
        assert!(body.is_empty());
        let (second, body) = read_frame(&mut cur).unwrap().unwrap();
        assert_eq!(second.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(second.get("op").unwrap().as_str(), Some("stats"));
        assert!(body.is_empty());
        assert!(read_frame(&mut cur).unwrap().is_none());

        // a malformed header is a well-delimited frame: it gets an
        // error response and the stream continues, exactly like a bad
        // JSONL line
        let mut input = Vec::new();
        let garbage = b"{{{";
        input.extend_from_slice(&(garbage.len() as u32).to_le_bytes());
        input.extend_from_slice(garbage);
        input.extend_from_slice(&0u32.to_le_bytes());
        input.extend_from_slice(&frame_bytes(r#"{"op":"stats"}"#, &[]));
        let mut out = Vec::new();
        let quit =
            serve_frames(&reg, &mut Cursor::new(input), &mut out).unwrap();
        assert!(!quit);
        let mut cur = Cursor::new(out);
        let (first, _) = read_frame(&mut cur).unwrap().unwrap();
        assert_eq!(first.get("ok").unwrap().as_bool(), Some(false));
        assert!(
            first.get("error").unwrap().as_str().unwrap().contains("header"),
            "{first:?}"
        );
        let (second, _) = read_frame(&mut cur).unwrap().unwrap();
        assert_eq!(second.get("op").unwrap().as_str(), Some("stats"));

        // shutdown stops the stream and reports it
        let mut input = Vec::new();
        input.extend_from_slice(&frame_bytes(r#"{"op":"shutdown"}"#, &[]));
        input.extend_from_slice(&frame_bytes(r#"{"op":"stats"}"#, &[]));
        let mut out = Vec::new();
        let quit =
            serve_frames(&reg, &mut Cursor::new(input), &mut out).unwrap();
        assert!(quit);
        let mut cur = Cursor::new(out);
        let (only, _) = read_frame(&mut cur).unwrap().unwrap();
        assert_eq!(only.get("op").unwrap().as_str(), Some("shutdown"));
        assert!(read_frame(&mut cur).unwrap().is_none(), "nothing after shutdown");
    }

    #[test]
    fn predict_frames_answer_raw_f32() {
        let reg = ready_registry();
        let queries = vec![vec![0.5f32, 0.5, 0.5, 0.5], vec![0.0, 0.1, 0.2, 0.3]];
        let body = encode_dense_points(4, &queries).unwrap();
        let input = frame_bytes(r#"{"op":"predict"}"#, &body);
        let mut out = Vec::new();
        serve_frames(&reg, &mut Cursor::new(input), &mut out).unwrap();
        let mut cur = Cursor::new(out);
        let (h, body) = read_frame(&mut cur).unwrap().unwrap();
        assert_eq!(h.get("ok").unwrap().as_bool(), Some(true), "{h:?}");
        assert_eq!(h.get("n").unwrap().as_usize(), Some(2));
        assert_eq!(h.get("model").unwrap().as_str(), Some("default"));
        let (lbl, d2) = decode_predict_body(&body).unwrap();
        // reference: the registry's own predict path
        let (rl, rd) = reg.resolve(None).unwrap().predict(&queries).unwrap();
        assert_eq!(lbl, rl);
        assert_eq!(
            d2.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            rd.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        // dimension mismatch is an error frame, stream-surviving
        let body = encode_dense_points(1, &[vec![1.0f32]]).unwrap();
        let input = frame_bytes(r#"{"op":"predict"}"#, &body);
        let mut out = Vec::new();
        serve_frames(&reg, &mut Cursor::new(input), &mut out).unwrap();
        let (h, _) = read_frame(&mut Cursor::new(out)).unwrap().unwrap();
        assert_eq!(h.get("ok").unwrap().as_bool(), Some(false));
        assert!(h.get("error").unwrap().as_str().unwrap().contains("dimension"));
    }
}
