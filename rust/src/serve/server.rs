//! Protocol transports: stdio and TCP, with per-connection wire-format
//! negotiation.
//!
//! Both transports speak the JSONL protocol (`serve::protocol`) against
//! one shared [`ModelRegistry`]; when the server was started with
//! binary framing enabled (`nmbkm serve --binary`), a connection whose
//! first byte is the magic [`crate::serve::frame::MAGIC`] speaks the
//! length-prefixed binary protocol (`serve::frame`) instead — JSONL
//! clients on the same port are untouched, because no JSONL request can
//! start with that byte. The TCP server runs **one thread per
//! connection**: predicts resolve a published model snapshot and run
//! lock-free, so read traffic scales with connections while mutations
//! (ingest/step/snapshot) serialise only on their own model's session
//! lock — two different models train and answer concurrently without
//! touching each other. An explicit `shutdown` request from any
//! connection (either framing) stops the whole server (stdio: EOF works
//! too).

use crate::obs::log as obslog;
use crate::serve::frame;
use crate::serve::observe::serve_metrics;
use crate::serve::protocol::serve_lines;
use crate::serve::registry::ModelRegistry;
use crate::util::json::{self, Json};
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Accept-loop knobs. The default matches `nmbkm serve`'s defaults:
/// JSONL only, 60 s per-connection socket timeouts.
#[derive(Clone, Copy)]
pub struct ServeOptions {
    /// Negotiate the binary framing on a leading magic byte.
    pub accept_binary: bool,
    /// Read/write timeout applied to every accepted socket (`None`
    /// disables). A peer that stalls a single read or write longer than
    /// this gets its connection dropped — the slowloris defence — and
    /// counts on `nmbkm_connection_timeouts_total`.
    pub conn_timeout: Option<Duration>,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions { accept_binary: false, conn_timeout: Some(Duration::from_secs(60)) }
    }
}

/// Serve requests from stdin, responses to stdout, until EOF or
/// `shutdown`. Single-threaded by construction (one client).
/// `accept_binary` lets a piped supervisor use the binary framing too.
pub fn serve_stdio(registry: &ModelRegistry, accept_binary: bool) -> Result<()> {
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut input = stdin.lock();
    let mut out = stdout.lock();
    let ended = serve_negotiated(registry, &mut input, &mut out, accept_binary);
    drain_wal(registry);
    ended?;
    Ok(())
}

/// Graceful drain on shutdown: fsync the WAL's tail and cut a final
/// checkpoint, so a restart replays nothing. Called once every handler
/// has exited (no mutation can race the flush). Failures keep the log —
/// recovery replay still reaches the same state.
fn drain_wal(registry: &ModelRegistry) {
    if let Some(w) = registry.wal() {
        match w.drain(registry) {
            Ok(()) => {
                eprintln!("[nmbkm::serve] wal drained (synced + final checkpoint)")
            }
            Err(e) => eprintln!("[nmbkm::serve] wal drain failed: {e:#}"),
        }
    }
}

/// Dispatch one request stream by its first byte: the binary magic
/// (when enabled) selects frame mode, anything else — including EOF —
/// stays on JSONL. Returns whether the stream ended with an explicit
/// shutdown.
fn serve_negotiated<R: BufRead, W: Write>(
    registry: &ModelRegistry,
    input: &mut R,
    output: &mut W,
    accept_binary: bool,
) -> Result<bool> {
    let first = input.fill_buf()?.first().copied();
    match first {
        Some(frame::MAGIC) if accept_binary => {
            input.consume(1);
            frame::serve_frames(registry, input, output)
        }
        Some(frame::MAGIC) => {
            // refuse loudly in the client's only other dialect, then
            // drop the connection — silence would look like a hang
            let resp = json::obj(vec![
                ("ok", Json::Bool(false)),
                (
                    "error",
                    json::s(
                        "binary framing is not enabled on this server \
                         (start it with --binary)",
                    ),
                ),
            ]);
            writeln!(output, "{}", resp.to_string())?;
            output.flush()?;
            Ok(false)
        }
        _ => serve_lines(registry, input, output),
    }
}

/// Bind `addr` (e.g. `127.0.0.1:7878`, or port 0 for ephemeral) and
/// serve concurrent connections until a client sends `shutdown`.
pub fn serve_tcp(
    registry: Arc<ModelRegistry>,
    addr: &str,
    opts: ServeOptions,
) -> Result<()> {
    let listener =
        TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    eprintln!(
        "[nmbkm::serve] listening on {} ({} models; JSONL: create|list|drop|\
         ingest|predict|step|stats|snapshot|metrics|sync-info|promote|\
         shutdown{})",
        listener.local_addr()?,
        registry.len(),
        if opts.accept_binary {
            "; binary frames negotiated by magic byte 0xB7"
        } else {
            ""
        },
    );
    serve_listener_with(registry, listener, opts)
}

/// [`serve_listener_with`] with binary framing off and no socket
/// timeouts: the JSONL-only accept loop every pre-existing caller gets.
pub fn serve_listener(
    registry: Arc<ModelRegistry>,
    listener: TcpListener,
) -> Result<()> {
    serve_listener_opts(registry, listener, false)
}

/// [`serve_listener_with`] keyed by the binary toggle alone (no socket
/// timeouts) — the historical test/bench entry point.
pub fn serve_listener_opts(
    registry: Arc<ModelRegistry>,
    listener: TcpListener,
    accept_binary: bool,
) -> Result<()> {
    serve_listener_with(
        registry,
        listener,
        ServeOptions { accept_binary, conn_timeout: None },
    )
}

/// Accept-loop over an already-bound listener (split out so tests can
/// bind an ephemeral port themselves). Every accepted connection gets
/// its own handler thread against the shared registry and negotiates
/// its wire format independently.
pub fn serve_listener_with(
    registry: Arc<ModelRegistry>,
    listener: TcpListener,
    opts: ServeOptions,
) -> Result<()> {
    let local = listener.local_addr().ok();
    let stop = Arc::new(AtomicBool::new(false));
    // handler thread + a clone of its socket: the clone lets the
    // acceptor shut the socket down at exit, which unblocks handlers
    // parked in a read so joining them cannot deadlock on an idle client
    let mut handlers: Vec<(std::thread::JoinHandle<()>, TcpStream)> =
        Vec::new();
    for conn in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break; // a handler processed `shutdown` (conn is its wake-up)
        }
        let stream = match conn {
            Ok(s) => s,
            Err(e) => {
                eprintln!("[nmbkm::serve] accept failed: {e}");
                continue;
            }
        };
        // socket-level timeouts so one stalled peer cannot pin its
        // handler thread (and any session lock it holds) forever
        if opts.conn_timeout.is_some() {
            let _ = stream.set_read_timeout(opts.conn_timeout);
            let _ = stream.set_write_timeout(opts.conn_timeout);
        }
        let peer = match stream.try_clone() {
            Ok(c) => c,
            Err(e) => {
                eprintln!("[nmbkm::serve] clone failed: {e}");
                continue;
            }
        };
        let reg = registry.clone();
        let stop_flag = stop.clone();
        let handle = std::thread::spawn(move || {
            match serve_connection(&reg, stream, opts.accept_binary) {
                Ok(true) => {
                    // explicit shutdown: flag the acceptor, then poke the
                    // listener so its blocking accept() returns. If the
                    // bound address is not self-connectable (external
                    // interface), fall back to loopback on the same port.
                    stop_flag.store(true, Ordering::SeqCst);
                    if let Some(addr) = local {
                        if TcpStream::connect(addr).is_err() {
                            let _ = TcpStream::connect((
                                std::net::Ipv4Addr::LOCALHOST,
                                addr.port(),
                            ));
                        }
                    }
                }
                Ok(false) => {} // client hung up; nothing to do
                Err(e) => eprintln!("[nmbkm::serve] connection error: {e:#}"),
            }
        });
        handlers.push((handle, peer));
        // reap finished handlers so long-lived servers don't accumulate
        handlers.retain(|(h, _)| !h.is_finished());
    }
    // close every live connection so handlers blocked mid-read wake with
    // EOF, then join — never waits on a client that simply stays silent
    for (_, peer) in &handlers {
        let _ = peer.shutdown(std::net::Shutdown::Both);
    }
    for (h, _) in handlers {
        let _ = h.join();
    }
    drain_wal(&registry);
    Ok(())
}

/// Whether an error chain reads like a socket timeout. The vendored
/// `anyhow` shim keeps errors as display strings (no downcast to
/// `io::Error`), so classification is textual: `SO_RCVTIMEO` expiry
/// surfaces as `WouldBlock` ("Resource temporarily unavailable") on
/// Linux and `TimedOut` elsewhere.
fn is_timeout(e: &anyhow::Error) -> bool {
    let s = format!("{e:#}").to_lowercase();
    s.contains("timed out")
        || s.contains("temporarily unavailable")
        || s.contains("would block")
        || s.contains("os error 11")
}

fn serve_connection(
    registry: &ModelRegistry,
    stream: TcpStream,
    accept_binary: bool,
) -> Result<bool> {
    let sm = serve_metrics();
    sm.conns_opened.inc();
    let peer = stream
        .peer_addr()
        .map(|p| p.to_string())
        .unwrap_or_else(|_| "?".to_string());
    eprintln!("[nmbkm::serve] client {peer} connected");
    obslog::event("connection_open", &[("peer", json::s(&peer))]);
    let mut reader =
        BufReader::new(stream.try_clone().context("cloning stream")?);
    let mut writer = BufWriter::new(stream);
    let out = serve_negotiated(registry, &mut reader, &mut writer, accept_binary);
    sm.conns_closed.inc();
    let timed_out = out.as_ref().err().map(is_timeout).unwrap_or(false);
    if timed_out {
        sm.conn_timeouts.inc();
        obslog::event("connection_timeout", &[("peer", json::s(&peer))]);
    }
    obslog::event(
        "connection_close",
        &[
            ("peer", json::s(&peer)),
            ("clean", Json::Bool(out.is_ok())),
        ],
    );
    out
}
