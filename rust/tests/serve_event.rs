//! Integration tests for the event-driven serving path beyond raw
//! throughput: the `"binary":true` response hint on JSONL connections
//! (frame-encoded predicts, bit-identical to the pure-binary route),
//! per-frame size admission, and the model lifecycle — idle eviction
//! with lazy reload over a live connection, and WAL-checkpointed
//! eviction whose one on-disk copy survives later checkpoints and a
//! full restart.

use nmbkm::config::{Algo, Rho, RunConfig};
use nmbkm::data::gaussian::GaussianMixture;
use nmbkm::data::Data;
use nmbkm::serve::observe::serve_metrics;
use nmbkm::serve::protocol::{self, Request};
use nmbkm::serve::server::{serve_listener_opts, serve_listener_with, ServeOptions};
use nmbkm::serve::wal::{self, FsyncPolicy};
use nmbkm::serve::{frame, session, ModelRegistry, WireRow};
use nmbkm::util::json::Json;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

const NO_CKPT: u64 = u64::MAX;

fn cfg(k: usize, b0: usize) -> RunConfig {
    RunConfig {
        algo: Algo::TbRho,
        k,
        b0,
        rho: Rho::Infinite,
        threads: 2,
        seed: 19,
        max_rounds: 6,
        max_seconds: 60.0,
        eval_every_secs: 0.0,
        ..Default::default()
    }
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("nmbkm-serve-event-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn dense_registry(k: usize, seed: u64) -> ModelRegistry {
    let data = GaussianMixture::default_spec(k, 4).generate(500, seed);
    ModelRegistry::with_default(session::train(&data, &cfg(k, 128)).unwrap().0)
}

fn rows(data: &Data, lo: usize, hi: usize) -> Vec<WireRow> {
    let mut row = vec![0f32; data.dim()];
    (lo..hi)
        .map(|i| {
            data.write_row_dense(i, &mut row);
            WireRow::Dense(row.clone())
        })
        .collect()
}

fn exec(reg: &ModelRegistry, req: &Request) -> Json {
    let (resp, _) = protocol::handle_request(reg, req);
    assert_eq!(
        resp.get("ok").and_then(Json::as_bool),
        Some(true),
        "request failed: {}",
        resp.to_string()
    );
    resp
}

fn bind_or_skip() -> Option<TcpListener> {
    match TcpListener::bind("127.0.0.1:0") {
        Ok(l) => Some(l),
        Err(_) => {
            eprintln!("skipping: cannot bind loopback");
            None
        }
    }
}

fn shutdown(addr: std::net::SocketAddr) {
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    conn.write_all(b"{\"op\":\"shutdown\"}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":true"), "{line}");
}

/// A JSONL predict carrying `"binary":true` answers with a
/// magic-prefixed frame that is byte-identical to the pure-binary
/// route's response, the connection stays in text mode afterwards, and
/// hint-predict errors stay JSON.
#[test]
fn binary_hint_matches_the_binary_route_bit_for_bit() {
    let Some(listener) = bind_or_skip() else { return };
    let addr = listener.local_addr().unwrap();
    let reg = Arc::new(dense_registry(3, 5));
    let server = std::thread::spawn(move || {
        serve_listener_opts(reg, listener, true).unwrap();
    });

    // values chosen to round-trip JSON text to f32 exactly
    let queries = vec![vec![0.5f32, 0.25, -1.0, 2.0], vec![1.5, 0.5, 3.0, -0.75]];

    // reference: the pure-binary route
    let (ref_h, ref_body) = {
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(&[frame::MAGIC]).unwrap();
        let body = frame::encode_dense_points(4, &queries).unwrap();
        let mut req = Vec::new();
        frame::write_frame(
            &mut req,
            &Json::parse(r#"{"op":"predict"}"#).unwrap(),
            &body,
        )
        .unwrap();
        conn.write_all(&req).unwrap();
        let mut reader = BufReader::new(conn);
        frame::read_frame(&mut reader).unwrap().unwrap()
    };
    assert_eq!(ref_h.get("ok").unwrap().as_bool(), Some(true), "{ref_h:?}");

    // the hinted JSONL route: same points as JSON text
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    conn.write_all(
        b"{\"op\":\"predict\",\"points\":[[0.5,0.25,-1.0,2.0],\
          [1.5,0.5,3.0,-0.75]],\"binary\":true}\n",
    )
    .unwrap();
    let mut magic = [0u8; 1];
    reader.read_exact(&mut magic).unwrap();
    assert_eq!(magic[0], frame::MAGIC, "hinted reply must lead with the magic");
    let (h, body) = frame::read_frame(&mut reader).unwrap().unwrap();
    assert_eq!(h, ref_h, "hinted header differs from the binary route");
    assert_eq!(body, ref_body, "hinted body differs from the binary route");

    // the connection is back in text mode
    conn.write_all(b"{\"op\":\"stats\"}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":true"), "{line}");

    // a failing hinted predict answers JSON, not a frame
    conn.write_all(b"{\"op\":\"predict\",\"points\":[[1.0]],\"binary\":true}\n")
        .unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with('{'), "{line}");
    assert!(line.contains("\"ok\":false"), "{line}");

    shutdown(addr);
    server.join().unwrap();
}

/// An over-limit binary frame is skipped by its own length prefix: the
/// client gets a structured `overloaded` error frame and the stream
/// keeps answering.
#[test]
fn oversized_frames_are_skipped_and_the_stream_survives() {
    let Some(listener) = bind_or_skip() else { return };
    let addr = listener.local_addr().unwrap();
    let reg = Arc::new(dense_registry(3, 7));
    let server = std::thread::spawn(move || {
        serve_listener_with(
            reg,
            listener,
            ServeOptions {
                accept_binary: true,
                conn_timeout: None,
                max_request_bytes: 4096,
                ..Default::default()
            },
        )
        .unwrap();
    });

    let frame_for = |rows: &[Vec<f32>]| {
        let body = frame::encode_dense_points(4, rows).unwrap();
        let mut out = Vec::new();
        frame::write_frame(
            &mut out,
            &Json::parse(r#"{"op":"predict"}"#).unwrap(),
            &body,
        )
        .unwrap();
        out
    };
    let small = frame_for(&[vec![0.5f32, 0.25, -1.0, 2.0], vec![0.0, 0.0, 0.0, 0.0]]);
    let big = frame_for(
        &(0..1000)
            .map(|i| vec![i as f32, 0.5, -0.5, 1.0])
            .collect::<Vec<_>>(),
    );
    assert!(big.len() > 4096 && small.len() <= 4096);

    let mut conn = TcpStream::connect(addr).unwrap();
    conn.write_all(&[frame::MAGIC]).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());

    conn.write_all(&small).unwrap();
    let (h, _) = frame::read_frame(&mut reader).unwrap().unwrap();
    assert_eq!(h.get("ok").unwrap().as_bool(), Some(true), "{h:?}");
    assert_eq!(h.get("n").unwrap().as_usize(), Some(2));

    conn.write_all(&big).unwrap();
    let (h, body) = frame::read_frame(&mut reader).unwrap().unwrap();
    assert_eq!(h.get("ok").unwrap().as_bool(), Some(false), "{h:?}");
    let err = h.get("error").unwrap().as_str().unwrap();
    assert!(err.contains("overloaded"), "{err}");
    assert!(err.contains("--max-request-bytes=4096"), "{err}");
    assert!(body.is_empty());

    // the stream survives: the next frame answers normally
    conn.write_all(&small).unwrap();
    let (h, _) = frame::read_frame(&mut reader).unwrap().unwrap();
    assert_eq!(h.get("ok").unwrap().as_bool(), Some(true), "{h:?}");

    shutdown(addr);
    server.join().unwrap();
}

/// Idle models are checkpointed and evicted by the acceptor's lifecycle
/// tick while the server runs, and the next request over a *live*
/// connection transparently reloads them — answering bit-identically to
/// the pre-eviction predict.
#[test]
fn idle_models_evict_and_lazily_reload_over_the_protocol() {
    let Some(listener) = bind_or_skip() else { return };
    let addr = listener.local_addr().unwrap();
    let snapdir = tmpdir("idle");
    std::fs::create_dir_all(&snapdir).unwrap();
    let reg = Arc::new(ModelRegistry::new());
    reg.set_snapshot_dir(snapdir.clone());
    let sreg = reg.clone();
    let server = std::thread::spawn(move || {
        serve_listener_opts(sreg, listener, false).unwrap();
    });

    // bootstrap a model entirely over the wire
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut req = |conn: &mut TcpStream,
                   reader: &mut BufReader<TcpStream>,
                   msg: &str|
     -> String {
        conn.write_all(msg.as_bytes()).unwrap();
        conn.write_all(b"\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        line
    };
    let ok = |line: &str| {
        assert!(line.contains("\"ok\":true"), "{line}");
    };
    ok(&req(
        &mut conn,
        &mut reader,
        r#"{"op":"create","model":"m","k":4,"dim":3,"algo":"gb","b0":16,"seed":4}"#,
    ));
    let pts: Vec<String> = (0..48)
        .map(|i| format!("[{},1.0,{}]", i as f32 * 0.125, 0.5 * i as f32))
        .collect();
    ok(&req(
        &mut conn,
        &mut reader,
        &format!("{{\"op\":\"ingest\",\"model\":\"m\",\"points\":[{}]}}", pts.join(",")),
    ));
    ok(&req(&mut conn, &mut reader, r#"{"op":"step","model":"m","rounds":3}"#));
    let probe = r#"{"op":"predict","model":"m","points":[[0.5,1.0,-0.25]]}"#;
    let baseline = req(&mut conn, &mut reader, probe);
    ok(&baseline);

    // arm idle eviction and wait for the acceptor tick to fire it
    // (poll the registry, not the process-global eviction counter —
    // other tests in this binary evict too)
    let rl_before = serve_metrics().model_reloads.get();
    reg.set_idle_evict(Some(Duration::from_millis(50)));
    let deadline = Instant::now() + Duration::from_secs(30);
    while !reg.is_empty() {
        assert!(
            Instant::now() < deadline,
            "lifecycle tick never evicted the idle model"
        );
        std::thread::sleep(Duration::from_millis(100));
    }
    reg.set_idle_evict(None); // stop the churn before reloading
    assert!(
        snapdir.join("evicted-m.json").is_file(),
        "eviction left no checkpoint"
    );

    // the same live connection transparently reloads it, bit-exact
    let after = req(&mut conn, &mut reader, probe);
    assert_eq!(after, baseline, "reloaded predict differs from pre-eviction");
    assert!(
        serve_metrics().model_reloads.get() > rl_before,
        "reload not accounted"
    );

    shutdown(addr);
    server.join().unwrap();
    let _ = std::fs::remove_dir_all(&snapdir);
}

/// With a WAL attached, eviction checkpoints through the log: the
/// evicted model's only copy is its `ckpt-*.json`, which must survive
/// a *later* checkpoint's manifest + GC (cut while the model is not
/// resident) and come back bit-identically — by lazy reload and by a
/// full recovery into a fresh registry.
#[test]
fn wal_checkpointed_eviction_survives_later_checkpoints_and_restart() {
    let data = GaussianMixture::default_spec(4, 6).generate(200, 13);
    let dir = tmpdir("wal-evict");
    let reg = ModelRegistry::new();
    let rec = wal::recover(&dir, FsyncPolicy::Always, NO_CKPT, &reg).unwrap();
    reg.attach_wal(rec.wal.clone());

    for name in ["m1", "m2"] {
        exec(
            &reg,
            &Request::Create {
                model: Some(name.to_string()),
                dim: data.dim(),
                cfg: cfg(4, 16),
            },
        );
        exec(
            &reg,
            &Request::Ingest {
                model: Some(name.to_string()),
                points: rows(&data, 0, 90),
                rounds: 3,
                seconds: f64::INFINITY,
            },
        );
    }
    let bytes_of = |reg: &ModelRegistry, name: &str| {
        reg.resolve(Some(name))
            .unwrap()
            .with_session(|s| Ok(s.snapshot(true)?.to_json().to_string()))
            .unwrap()
    };
    let want1 = bytes_of(&reg, "m1");
    let want2 = bytes_of(&reg, "m2");

    // evict m1: the WAL checkpoint is its only copy now
    assert!(reg.evict_model("m1").unwrap(), "m1 eviction refused");
    assert!(dir.join("ckpt-m1.json").is_file());
    assert!(reg.resolve(Some("m2")).is_ok() && reg.list().len() == 1);

    // lazy reload is bit-identical
    assert_eq!(bytes_of(&reg, "m1"), want1);

    // evict both; m2's checkpoint is cut while m1 is *not* resident —
    // the manifest must still list m1 and the GC must keep its file
    assert!(reg.evict_model("m1").unwrap());
    assert!(reg.evict_model("m2").unwrap(), "m2 eviction refused");
    assert!(reg.is_empty());
    assert!(
        dir.join("ckpt-m1.json").is_file(),
        "later checkpoint GC deleted the evicted model's only copy"
    );

    // a fresh process recovers both models bit-identically
    let revived = ModelRegistry::new();
    let rec2 = wal::recover(&dir, FsyncPolicy::Always, NO_CKPT, &revived).unwrap();
    assert_eq!(rec2.resumed_models, 2, "evicted model lost across restart");
    revived.attach_wal(rec2.wal.clone());
    assert_eq!(bytes_of(&revived, "m1"), want1);
    assert_eq!(bytes_of(&revived, "m2"), want2);

    let _ = std::fs::remove_dir_all(&dir);
}
