//! Smoke tests for the experiment harnesses at unit scale: every paper
//! table/figure generator must run end to end and emit its CSV. The
//! real (bench-scale) runs happen under `cargo bench`; these tests keep
//! the harness code itself under `cargo test` coverage.

use nmbkm::config::Engine;
use nmbkm::experiments::{common, fig1, rho_sweep, table1, table2};
use nmbkm::kmeans::assign::NativeEngine;

fn tiny_opts() -> common::ExpOpts {
    common::ExpOpts {
        scale: common::Scale::Quick,
        seeds: 2,
        threads: 2,
        engine: Engine::Native,
        seconds: 0.4,
    }
}

fn with_tmp_results<T>(tag: &str, f: impl FnOnce() -> T) -> T {
    let dir = std::env::temp_dir().join(format!(
        "nmbkm-smoke-{}-{tag}",
        std::process::id()
    ));
    std::env::set_var("NMBKM_RESULTS_DIR", &dir);
    let out = f();
    std::env::remove_var("NMBKM_RESULTS_DIR");
    let _ = std::fs::remove_dir_all(&dir);
    out
}

#[test]
fn fig1_runs_on_small_gaussian() {
    with_tmp_results("fig1", || {
        let ds = common::gaussian_small();
        let opts = tiny_opts();
        let curves = fig1::run_dataset(&ds, &opts, &NativeEngine::default()).unwrap();
        assert_eq!(curves.len(), fig1::algo_set().len());
        for c in &curves {
            assert!(c.mean_final.is_finite(), "{}: no final MSE", c.label);
        }
        fig1::check_shape("gaussian", &curves);
        let path = common::write_curves_csv("fig1_smoke", "gaussian", &curves)
            .unwrap();
        assert!(path.exists());
    });
}

#[test]
fn rho_sweep_covers_all_rhos() {
    with_tmp_results("rho", || {
        let ds = common::gaussian_small();
        let opts = tiny_opts();
        let curves = rho_sweep::run_dataset(&ds, &opts, &NativeEngine::default()).unwrap();
        // mb + 5 gb-ρ + 5 tb-ρ
        assert_eq!(curves.len(), 11);
        let labels: Vec<&str> =
            curves.iter().map(|c| c.label.as_str()).collect();
        for want in ["mb", "gb-1", "gb-inf", "tb-1000", "tb-inf"] {
            assert!(labels.contains(&want), "missing {want} in {labels:?}");
        }
        rho_sweep::check_shape(&curves);
    });
}

#[test]
fn table1_emits_rows_and_csv() {
    with_tmp_results("table1", || {
        let opts = common::ExpOpts { seconds: 0.2, ..tiny_opts() };
        // table1 builds its own datasets at quick scale; keep it small by
        // running the underlying timer directly on the gaussian set, then
        // the full harness once (quick scale is bounded: one epoch each).
        let ds = common::gaussian_small();
        let t8 = table1::time_epoch(
            &ds,
            nmbkm::kmeans::minibatch::Formulation::Alg8,
            &NativeEngine::default(),
            2,
            1024,
        );
        assert!(t8 > 0.0 && t8 < 30.0);
        let rows = vec![
            table1::Row {
                dataset: "infmnist-sim".into(),
                implementation: "alg8 S/v (our)".into(),
                n: 10,
                secs: 1.0,
            },
            table1::Row {
                dataset: "infmnist-sim".into(),
                implementation: "alg1 per-sample (baseline)".into(),
                n: 10,
                secs: 2.0,
            },
        ];
        table1::check_shape(&rows);
    });
}

#[test]
fn table2_cells_cover_grid() {
    with_tmp_results("table2", || {
        let b0s = table2::b0_grid(common::Scale::Quick);
        assert_eq!(b0s.len(), 3);
        assert!(b0s.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(table2::b0_grid(common::Scale::Full), vec![100, 1000, 5000]);
        // shape checker tolerates synthetic cells
        let cells = vec![
            table2::Cell {
                dataset: "infmnist-sim".into(),
                algo: "lloyd".into(),
                b0: 1000,
                mean_final: 1.0,
                std_final: 0.0,
            },
            table2::Cell {
                dataset: "infmnist-sim".into(),
                algo: "tb-inf".into(),
                b0: 1000,
                mean_final: 1.05,
                std_final: 0.0,
            },
            table2::Cell {
                dataset: "rcv1-sim".into(),
                algo: "tb-inf".into(),
                b0: 50,
                mean_final: 2.0,
                std_final: 0.0,
            },
            table2::Cell {
                dataset: "rcv1-sim".into(),
                algo: "tb-inf".into(),
                b0: 1000,
                mean_final: 1.2,
                std_final: 0.0,
            },
        ];
        table2::check_shape(&cells);
    });
}

#[test]
fn scale_parsing() {
    assert_eq!(
        common::Scale::from_env_or_args(&["--full".to_string()]),
        common::Scale::Full
    );
    assert_eq!(common::Scale::from_env_or_args(&[]), common::Scale::Quick);
    let opts = common::ExpOpts::from_args(&[
        "--seeds".to_string(),
        "5".to_string(),
        "--seconds".to_string(),
        "1.5".to_string(),
    ]);
    assert_eq!(opts.seeds, 5);
    assert_eq!(opts.seconds, 1.5);
}
