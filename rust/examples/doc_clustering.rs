//! Sparse document clustering — the paper's RCV1 scenario.
//!
//! Clusters 40k synthetic RCV1-like documents (47,236-dim sparse ltc
//! vectors, ~76 non-zeros) at k = 50 with `tb-∞`, then inspects the
//! result: cluster sizes, within-cluster cohesion, and the top terms of
//! the largest clusters. This is the φ ≫ 1 regime (dense centroids over
//! sparse points) where the S/v reformulation and nested batches matter
//! most (paper Supp. A.1/A.2).
//!
//! ```bash
//! cargo run --release --example doc_clustering
//! ```

use nmbkm::config::{Algo, Rho, RunConfig};
use nmbkm::data::rcv1::Rcv1Sim;
use nmbkm::kmeans;

fn main() -> anyhow::Result<()> {
    let ds = Rcv1Sim::default().dataset(40_000, 4_000, 7);
    println!("dataset: {}", ds.summary());
    if let nmbkm::data::Storage::Sparse(m) = &ds.train.storage {
        println!("mean nnz/doc: {:.1} (RCV1: ~76)", m.mean_nnz());
    }

    let cfg = RunConfig {
        algo: Algo::TbRho,
        rho: Rho::Infinite,
        k: 50,
        b0: 1_000,
        max_seconds: 10.0,
        threads: std::thread::available_parallelism()?.get(),
        eval_every_secs: 0.5,
        ..Default::default()
    };
    let out = kmeans::run(&ds.train, Some(&ds.val), &cfg)?;
    println!(
        "clustered in {} rounds / {:.2}s work; validation MSE {:.5}",
        out.rounds, out.work_secs, out.final_mse
    );

    // centroid densification: the paper's φ = centroid nnz / doc nnz
    let cent = &out.centroids;
    let mut cluster_nnz = Vec::new();
    for j in 0..cent.k() {
        let nnz = cent.c.row(j).iter().filter(|&&x| x.abs() > 1e-7).count();
        cluster_nnz.push(nnz);
    }
    let mean_cnnz =
        cluster_nnz.iter().sum::<usize>() as f64 / cluster_nnz.len() as f64;
    if let nmbkm::data::Storage::Sparse(m) = &ds.train.storage {
        println!(
            "centroid densification φ ≈ {:.0} ({}-nnz centroids over {:.0}-nnz docs)",
            mean_cnnz / m.mean_nnz(),
            mean_cnnz as usize,
            m.mean_nnz()
        );
    }

    // top terms of the 5 heaviest centroids
    for j in 0..cent.k().min(5) {
        let row = cent.c.row(j);
        let mut top: Vec<(usize, f32)> =
            row.iter().cloned().enumerate().collect();
        top.sort_by(|a, b| b.1.total_cmp(&a.1));
        let terms: Vec<String> =
            top.iter().take(5).map(|(w, v)| format!("t{w}:{v:.3}")).collect();
        println!("cluster {j:>2}: top terms {}", terms.join(" "));
    }
    Ok(())
}
