//! Online (stochastic gradient descent) k-means — Bottou & Bengio 1995.
//!
//! `mb` with batch size 1: each point immediately pulls its nearest
//! centroid with learning rate `1/v(j)`, which keeps every centroid the
//! mean of all points ever assigned to it. One [`Clusterer::round`]
//! processes `b0` points so traces have comparable granularity to the
//! batch algorithms, but centroids update after *every* point (that is
//! what distinguishes sgd from mb).

use crate::kmeans::state::{Assignments, Centroids, SuffStats};
use crate::kmeans::{Clusterer, Ctx, RoundInfo};
use crate::linalg::dense;

pub struct Sgd {
    pub(crate) cent: Centroids,
    pub(crate) stats: SuffStats,
    pub(crate) assign: Assignments,
    points_per_round: usize,
    order: Vec<usize>,
    cursor: usize,
}

impl Sgd {
    pub fn new(cent: Centroids, points_per_round: usize) -> Self {
        let k = cent.k();
        let d = cent.d();
        Self {
            cent,
            stats: SuffStats::zeros(k, d),
            assign: Assignments::new(0),
            points_per_round: points_per_round.max(1),
            order: vec![],
            cursor: 0,
        }
    }
}

impl Clusterer for Sgd {
    fn round(&mut self, ctx: &mut Ctx) -> RoundInfo {
        let n = ctx.data.n();
        if self.order.len() != n {
            self.order = (0..n).collect();
            self.assign = Assignments::new(n);
            self.cursor = 0;
        }
        let d = self.cent.d();
        let k = self.cent.k();
        let mut xrow = vec![0f32; d];
        let mut sum_d2 = 0f64;
        let mut changed = 0u64;
        let steps = self.points_per_round.min(n);
        for _ in 0..steps {
            if self.cursor == 0 {
                ctx.rng.shuffle(&mut self.order);
            }
            let i = self.order[self.cursor];
            self.cursor = (self.cursor + 1) % n;
            // single-point assignment against *current* centroids
            let (j, d2) =
                ctx.data.nearest(i, &self.cent.c, &self.cent.norms);
            if self.assign.seen(i) && self.assign.label[i] != j {
                changed += 1;
            }
            self.assign.label[i] = j;
            self.assign.dist2[i] = d2;
            sum_d2 += d2 as f64;
            self.stats.add_point(ctx.data, i, j, d2);
            // online convex pull: c ← c + (x − c)/v
            let v = self.stats.v[j as usize];
            ctx.data.write_row_dense(i, &mut xrow);
            let row = self.cent.c.row_mut(j as usize);
            let eta = (1.0 / v) as f32;
            for t in 0..d {
                row[t] += eta * (xrow[t] - row[t]);
            }
            self.cent.norms[j as usize] =
                dense::sq_norm(self.cent.c.row(j as usize));
        }
        // per-point pulls mutate `c` directly; one revision refresh per
        // round keeps engine caches (validation scoring) coherent
        self.cent.touch();
        RoundInfo {
            dist_calcs: (steps * k) as u64,
            bound_skips: 0,
            changed,
            batch: 1,
            train_mse: sum_d2 / steps.max(1) as f64,
        }
    }

    fn centroids(&self) -> &Centroids {
        &self.cent
    }

    fn name(&self) -> String {
        "sgd".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian::GaussianMixture;
    use crate::kmeans::assign::NativeEngine;
    use crate::kmeans::init;
    use crate::util::rng::Pcg64;

    /// Shared engine for test contexts (Ctx borrows it for 'static).
    fn test_engine() -> &'static NativeEngine {
        static E: std::sync::OnceLock<NativeEngine> = std::sync::OnceLock::new();
        E.get_or_init(NativeEngine::default)
    }

    fn ctx(data: &crate::data::Data) -> Ctx<'_> {
        Ctx {
            data,
            engine: test_engine(),
            pool: crate::coordinator::Pool::new(1),
            rng: Pcg64::new(2, 2),
        }
    }

    #[test]
    fn centroid_equals_running_mean() {
        let data = GaussianMixture::default_spec(3, 4).generate(200, 3);
        let mut alg = Sgd::new(init::first_k(&data, 3), 100);
        let mut c = ctx(&data);
        alg.round(&mut c);
        alg.round(&mut c);
        // after the online updates, C(j) must equal S(j)/v(j): the
        // 1/v learning rate *is* the running mean
        for j in 0..3 {
            if alg.stats.v[j] > 0.0 {
                for t in 0..4 {
                    let mean = alg.stats.s_row(j)[t] / alg.stats.v[j];
                    let got = alg.cent.c.row(j)[t] as f64;
                    assert!(
                        (got - mean).abs() < 1e-4 * (1.0 + mean.abs()),
                        "j={j},t={t}: {got} vs {mean}"
                    );
                }
            }
        }
    }

    #[test]
    fn improves_over_rounds() {
        let data = GaussianMixture::default_spec(4, 8).generate(500, 1);
        let mut alg = Sgd::new(init::first_k(&data, 4), 250);
        let mut c = ctx(&data);
        let before = crate::kmeans::state::exact_mse(&data, &alg.cent);
        for _ in 0..8 {
            alg.round(&mut c);
        }
        let after = crate::kmeans::state::exact_mse(&data, &alg.cent);
        assert!(after < before, "{before} -> {after}");
    }
}
