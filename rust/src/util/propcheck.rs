//! Minimal property-testing harness (proptest is unavailable offline).
//!
//! A [`Cases`] driver runs a property closure over many seeded cases and
//! reports the failing seed, so failures reproduce exactly:
//!
//! ```ignore
//! Cases::new(200).run(|rng| {
//!     let n = rng.below(100) + 1;
//!     /* generate instance, assert invariant */
//! });
//! ```

use crate::util::rng::Pcg64;

/// Property-test driver: `count` cases, each with an independent RNG
/// derived from a base seed (overridable via `NMBKM_PROP_SEED` for
/// replaying CI failures).
pub struct Cases {
    pub count: usize,
    pub base_seed: u64,
}

impl Cases {
    pub fn new(count: usize) -> Self {
        let base_seed = std::env::var("NMBKM_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xA11C_E5ED);
        Self { count, base_seed }
    }

    /// Run the property; panics with the failing case seed on error.
    pub fn run(&self, prop: impl Fn(&mut Pcg64)) {
        for case in 0..self.count {
            let seed = self.base_seed.wrapping_add(case as u64);
            let mut rng = Pcg64::new(seed, 0xC0FFEE);
            let result = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| prop(&mut rng)),
            );
            if let Err(e) = result {
                eprintln!(
                    "property failed at case {case} \
                     (replay with NMBKM_PROP_SEED={seed} and count=1)"
                );
                std::panic::resume_unwind(e);
            }
        }
    }
}

/// Common generators for k-means shaped instances.
pub mod gen {
    use crate::util::rng::Pcg64;

    /// Random (n, d, k) with n ≥ k, suitable for clustering instances.
    pub fn shape(rng: &mut Pcg64, max_n: usize, max_d: usize, max_k: usize)
        -> (usize, usize, usize)
    {
        let k = rng.below(max_k) + 1;
        let n = k + rng.below(max_n.saturating_sub(k) + 1);
        let d = rng.below(max_d) + 1;
        (n, d, k)
    }

    /// Row-major gaussian matrix with a random per-row scale, so ties
    /// and near-ties occur with reasonable probability.
    pub fn matrix(rng: &mut Pcg64, rows: usize, cols: usize) -> Vec<f32> {
        let scale = 10f64.powf(rng.range_f64(-1.0, 1.0)) as f32;
        (0..rows * cols).map(|_| rng.gauss_f32() * scale).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut hits = std::cell::Cell::new(0usize);
        Cases { count: 17, base_seed: 1 }.run(|_| {
            hits.set(hits.get() + 1);
        });
        assert_eq!(hits.get_mut(), &mut 17);
    }

    #[test]
    fn cases_get_distinct_streams() {
        let mut firsts = std::collections::HashSet::new();
        let firsts_ref = std::cell::RefCell::new(&mut firsts);
        Cases { count: 10, base_seed: 2 }.run(|rng| {
            firsts_ref.borrow_mut().insert(rng.next_u64());
        });
        assert_eq!(firsts.len(), 10);
    }

    #[test]
    #[should_panic]
    fn propagates_failure() {
        Cases { count: 5, base_seed: 3 }.run(|rng| {
            assert!(rng.next_f64() < 0.9, "intentional");
        });
    }

    #[test]
    fn gen_shape_valid() {
        Cases { count: 50, base_seed: 4 }.run(|rng| {
            let (n, d, k) = gen::shape(rng, 100, 20, 10);
            assert!(n >= k && k >= 1 && d >= 1);
            assert!(n <= 110 && d <= 20 && k <= 10);
        });
    }
}
