//! PJRT runtime: loads the AOT-compiled Pallas/XLA artifacts and serves
//! them to the Layer-3 hot path.
//!
//! The interchange format is HLO *text* (`artifacts/*.hlo.txt` + a JSON
//! manifest), produced once by `python/compile/aot.py` — see
//! DESIGN.md. At startup we compile every manifest entry on the PJRT
//! CPU client; per round the [`executor::XlaEngine`] pads batches to a
//! compiled tile shape and executes.
//!
//! The PJRT dependency is gated behind the off-by-default `xla` cargo
//! feature so the default build is fully self-contained; without it
//! [`make_engine`] reports the engine as unavailable and callers fall
//! back to the native engine or skip (they already treat engine
//! construction as fallible).

pub mod artifact;
#[cfg(feature = "xla")]
pub mod executor;

use crate::kmeans::assign::AssignEngine;

/// Build the XLA-backed assignment engine from an artifacts directory.
#[cfg(feature = "xla")]
pub fn make_engine(artifacts_dir: &str) -> anyhow::Result<Box<dyn AssignEngine + Send>> {
    let engine = executor::XlaEngine::load(artifacts_dir)?;
    Ok(Box::new(engine))
}

/// Build the XLA-backed assignment engine — unavailable in this build.
#[cfg(not(feature = "xla"))]
pub fn make_engine(_artifacts_dir: &str) -> anyhow::Result<Box<dyn AssignEngine + Send>> {
    anyhow::bail!(
        "this binary was built without the `xla` feature — rebuild with \
         `cargo build --features xla` (and run `make artifacts`) to use \
         the PJRT engine"
    )
}
