//! Multi-model serving: many named [`OnlineSession`]s in one process,
//! each with a snapshot-isolated, lock-free predict path.
//!
//! The registry is the reader/writer split the serve layer needed to
//! scale predict traffic with cores:
//!
//! * **Writers** (ingest/step/snapshot ops) take the model's session
//!   mutex, mutate training state, and then *publish* an immutable
//!   [`PublishedModel`] — a self-contained copy of the centroids and
//!   the metadata predicts need — by swapping an `Arc` behind a
//!   read-mostly lock.
//! * **Readers** (predict ops, one thread per TCP connection) clone the
//!   current `Arc` (nanoseconds under a read lock) and compute against
//!   that frozen snapshot. A predict never waits for a training round
//!   and never observes a half-updated model: it sees exactly the model
//!   as of some completed mutation — the same read-mostly discipline
//!   that motivates bounds-based reuse in "Fast K-Means with Accurate
//!   Bounds" (reads must not pay for writes they don't depend on).
//!
//! Because the predict path funnels through the same
//! [`session::predict_against`] core and SIMD kernels as the live
//! session, a predict answered from a published snapshot is
//! bit-identical to one answered sequentially at the same centroid
//! revision (enforced by `tests/serve_concurrent.rs`).

use crate::config::RunConfig;
use crate::coordinator::shard::Pool;
use crate::kmeans::assign::{AssignEngine, NativeEngine, TransCache};
use crate::kmeans::state::Centroids;
use crate::linalg::neighbours::{NeighbourCache, NeighbourIndex};
use crate::linalg::sparse::TransposedCentroids;
use crate::obs::{self, log as obslog};
use crate::serve::observe::{serve_metrics, ModelMetrics};
use crate::serve::session::{self, OnlineSession};
use crate::serve::snapshot::{Snapshot, SnapshotFormat};
use crate::serve::wal::{u64_json, Wal};
use crate::serve::wire::WireRow;
use crate::util::json::{self, Json};
use anyhow::{anyhow, bail, ensure, Result};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};
use std::time::{Duration, Instant};

/// The model name requests route to when they carry no `model` field —
/// what keeps single-model clients from PR 1 working unchanged.
pub const DEFAULT_MODEL: &str = "default";

/// Hard cap on registered models. Each model owns a session, a pool and
/// growing buffers, and the wire `create` op is remote-reachable — an
/// unbounded registry would hand clients a resource-exhaustion
/// primitive (same posture as the snapshot op's path confinement).
pub const MAX_MODELS: usize = 256;

/// Sub-batch size of the batched predict path. Small enough that a
/// batch-64 request fans out across four workers, and far below the
/// engine's own `MIN_CHUNK` (256), so a sub-batch never re-shards
/// inside the engine — the outer `run_jobs` is the only fan-out.
pub const PREDICT_JOB_ROWS: usize = 16;

/// Nanoseconds on a process-local monotone clock (an `Instant` epoch
/// fixed at first use). Fits in an `AtomicU64`, which `Instant` itself
/// does not; only differences are meaningful.
fn mono_nanos() -> u64 {
    static T0: OnceLock<Instant> = OnceLock::new();
    T0.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// An immutable published view of one model: everything a predict needs,
/// frozen at the end of some mutation. Swapped wholesale under an `Arc`,
/// never mutated in place.
#[derive(Clone, Debug)]
pub struct PublishedModel {
    pub model: String,
    /// `None` until the session has seen ≥ k points.
    pub cent: Option<Centroids>,
    pub dim: usize,
    pub k: usize,
    pub rounds: usize,
    pub n_total: usize,
    pub algo: String,
    /// Centroid revision this view froze (0 when uninitialised);
    /// process-unique, so equal revisions imply identical centroids.
    pub rev: u64,
    /// The model stores sparse (CSR) data; predict queries are
    /// sparsified so they run the O(nnz·k) kernels.
    pub sparse: bool,
    /// The training session's transposed centroid block at `rev`
    /// (sparse models only): carried into the published view so
    /// concurrent sparse predicts share one O(k·d) transpose instead of
    /// each predict engine rebuilding its own per publish.
    pub trans: Option<Arc<TransposedCentroids>>,
    /// The training session's exponion neighbour structure at `rev`
    /// (serving-scale k only): carried so predicts prune with the
    /// session's O(k²·d) build — zero neighbour rebuilds between
    /// publishes.
    pub neigh: Option<Arc<NeighbourIndex>>,
}

impl PublishedModel {
    /// Score query rows against this frozen model. Same validation and
    /// kernel path as [`OnlineSession::predict_rows`].
    pub fn predict(
        &self,
        rows: &[Vec<f32>],
        engine: &NativeEngine,
        pool: &Pool,
    ) -> Result<(Vec<u32>, Vec<f32>)> {
        let cent = self.cent.as_ref().ok_or_else(|| {
            anyhow!(
                "model '{}' not initialised — ingest at least k={} points first",
                self.model,
                self.k
            )
        })?;
        // zero-rebuild sparse predicts: the transpose frozen into this
        // view rides straight into the engine call, so predicts racing
        // across publishes can never evict each other into a rebuild
        // (no shared cache slot is involved at all)
        let trans = if self.sparse { self.trans.clone() } else { None };
        session::predict_against(
            cent,
            self.dim,
            rows,
            self.sparse,
            trans,
            self.neigh.clone(),
            engine,
            pool,
        )
    }

    /// [`PublishedModel::predict`] for wire-decoded rows: sparse
    /// encodings score straight off this view's CSR kernels, dense ones
    /// follow the classic path — same validation, same bits.
    pub fn predict_wire(
        &self,
        rows: &[WireRow],
        engine: &NativeEngine,
        pool: &Pool,
    ) -> Result<(Vec<u32>, Vec<f32>)> {
        let cent = self.cent.as_ref().ok_or_else(|| {
            anyhow!(
                "model '{}' not initialised — ingest at least k={} points first",
                self.model,
                self.k
            )
        })?;
        let trans = if self.sparse { self.trans.clone() } else { None };
        session::predict_wire(
            cent,
            self.dim,
            rows,
            self.sparse,
            trans,
            self.neigh.clone(),
            engine,
            pool,
        )
    }

    /// One row of the protocol's `list` response.
    pub fn summary_json(&self) -> Json {
        json::obj(vec![
            ("model", json::s(&self.model)),
            ("initialised", Json::Bool(self.cent.is_some())),
            ("algo", json::s(&self.algo)),
            ("k", json::num(self.k as f64)),
            ("dim", json::num(self.dim as f64)),
            ("n_total", json::num(self.n_total as f64)),
            ("rounds", json::num(self.rounds as f64)),
        ])
    }
}

/// One registered model: the mutable training session behind a mutex,
/// plus the current published snapshot and the resources the lock-free
/// predict path uses (its own engine handle and a clone of the
/// session's pool — shared workers, separate submissions).
pub struct ModelEntry {
    name: String,
    session: Mutex<OnlineSession>,
    published: RwLock<Arc<PublishedModel>>,
    predict_engine: NativeEngine,
    pool: Pool,
    /// Per-model op counters and latency histograms (labelled
    /// `model=<name>` in the global metrics registry).
    metrics: ModelMetrics,
    /// The training engine's transpose cache, captured at registration
    /// so metric scrapes read its counters lock-free — never through
    /// the session mutex a training step may hold for seconds.
    session_cache: Option<Arc<TransCache>>,
    /// The training engine's exponion neighbour cache, captured the
    /// same way for the same lock-free scrapes.
    session_neigh: Option<Arc<NeighbourCache>>,
    /// Highest WAL sequence number applied to this model (0 = none).
    /// Checkpoints persist it next to the snapshot; recovery and the
    /// follower use it to skip records a snapshot already covers.
    last_seq: AtomicU64,
    /// [`mono_nanos`] of the last [`ModelRegistry::resolve`] that
    /// returned this entry — the recency that LRU and idle eviction
    /// rank by.
    last_used: AtomicU64,
}

impl ModelEntry {
    fn new(name: &str, session: OnlineSession) -> Arc<ModelEntry> {
        let pool = session.pool().clone();
        let session_cache = session.trans_cache();
        let session_neigh = session.neigh_cache();
        let view = Arc::new(publish_view(name, &session));
        Arc::new(ModelEntry {
            name: name.to_string(),
            session: Mutex::new(session),
            published: RwLock::new(view),
            predict_engine: NativeEngine::default(),
            pool,
            metrics: ModelMetrics::for_model(name),
            session_cache,
            session_neigh,
            last_seq: AtomicU64::new(0),
            last_used: AtomicU64::new(mono_nanos()),
        })
    }

    /// Mark the entry used now. Every successful resolve calls this;
    /// idle eviction compares against it.
    pub fn touch(&self) {
        self.last_used.store(mono_nanos(), Ordering::Relaxed);
    }

    /// [`mono_nanos`] of the last use (resolve or registration).
    fn last_used(&self) -> u64 {
        self.last_used.load(Ordering::Relaxed)
    }

    /// Highest WAL seq folded into this model's state (0 = none).
    pub fn last_seq(&self) -> u64 {
        self.last_seq.load(Ordering::SeqCst)
    }

    pub fn set_last_seq(&self, seq: u64) {
        self.last_seq.store(seq, Ordering::SeqCst);
    }

    /// This model's metric handles.
    pub fn metrics(&self) -> &ModelMetrics {
        &self.metrics
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// The current published snapshot (cheap `Arc` clone; never blocks
    /// on the session mutex).
    pub fn current(&self) -> Arc<PublishedModel> {
        self.published.read().unwrap().clone()
    }

    /// Snapshot-isolated predict: resolves the published model once and
    /// computes against it, concurrent training steps notwithstanding.
    pub fn predict(&self, rows: &[Vec<f32>]) -> Result<(Vec<u32>, Vec<f32>)> {
        let timer = obs::Timer::start();
        let out = self.current().predict(rows, &self.predict_engine, &self.pool)?;
        self.metrics.predict_requests.inc();
        self.metrics.predict_rows.add(rows.len() as u64);
        timer.observe(&self.metrics.predict_seconds);
        Ok(out)
    }

    /// Snapshot-isolated **batched** predict for wire-decoded rows: the
    /// published model is resolved once, then large `points` arrays
    /// split into [`PREDICT_JOB_ROWS`]-row sub-batches fanned across the
    /// shard pool via `run_jobs` — one published-`Arc` clone per
    /// sub-batch. Each row's answer depends only on that row and the
    /// frozen centroids, so the split is invisible in the results: bits
    /// are identical to the single-batch path (enforced by
    /// `tests/serve_wire.rs`). Sub-batches sit below the engine's own
    /// fan-out threshold, so jobs never re-shard recursively.
    pub fn predict_wire(&self, rows: &[WireRow]) -> Result<(Vec<u32>, Vec<f32>)> {
        let timer = obs::Timer::start();
        let out = self.predict_wire_inner(rows)?;
        self.metrics.predict_requests.inc();
        self.metrics.predict_rows.add(rows.len() as u64);
        timer.observe(&self.metrics.predict_seconds);
        Ok(out)
    }

    fn predict_wire_inner(&self, rows: &[WireRow]) -> Result<(Vec<u32>, Vec<f32>)> {
        let view = self.current();
        if rows.len() <= PREDICT_JOB_ROWS || self.pool.threads <= 1 {
            return view.predict_wire(rows, &self.predict_engine, &self.pool);
        }
        // dimensions are validated before the split so a bad row is
        // reported by its request-global index — per-job validation
        // would name the position inside some 16-row sub-batch instead
        for (t, row) in rows.iter().enumerate() {
            ensure!(
                row.dim() == view.dim,
                "row {t}: dimension {} != model dimension {}",
                row.dim(),
                view.dim
            );
        }
        let jobs: Vec<&[WireRow]> = rows.chunks(PREDICT_JOB_ROWS).collect();
        let results = self.pool.run_jobs(jobs, |_, slice| {
            let batch_view = view.clone();
            batch_view.predict_wire(slice, &self.predict_engine, &self.pool)
        });
        let mut lbl = Vec::with_capacity(rows.len());
        let mut d2 = Vec::with_capacity(rows.len());
        for r in results {
            let (l, d) = r?;
            lbl.extend_from_slice(&l);
            d2.extend_from_slice(&d);
        }
        Ok((lbl, d2))
    }

    /// Run a mutation under the session lock; on success the
    /// post-mutation model is published for readers.
    pub fn with_session_mut<T>(
        &self,
        f: impl FnOnce(&mut OnlineSession) -> Result<T>,
    ) -> Result<T> {
        let mut s = self.lock_session()?;
        let out = f(&mut s)?;
        let view = Arc::new(publish_view(&self.name, &s));
        self.metrics.publishes.inc();
        obslog::event(
            "model_publish",
            &[
                ("model", json::s(&self.name)),
                ("rev", json::num(view.rev as f64)),
                ("rounds", json::num(view.rounds as f64)),
                ("n_total", json::num(view.n_total as f64)),
            ],
        );
        *self.published.write().unwrap() = view;
        Ok(out)
    }

    /// Run a read-only closure under the session lock (stats,
    /// snapshot-to-disk). Mutation-free, so nothing is republished.
    pub fn with_session<T>(
        &self,
        f: impl FnOnce(&OnlineSession) -> Result<T>,
    ) -> Result<T> {
        let s = self.lock_session()?;
        f(&s)
    }

    /// `(hits, builds)` of the lock-free predict engine's transpose
    /// cache. With published sparse models the builds must stay at
    /// zero: every predict is served by the carried transpose
    /// (asserted in `tests/serve_concurrent.rs`).
    pub fn predict_cache_stats(&self) -> (u64, u64) {
        let c = self.predict_engine.cache();
        (c.hits(), c.builds())
    }

    /// `(hits, builds)` of the **training** engine's transpose cache,
    /// read through the handle captured at registration — no session
    /// lock. `None` when the engine keeps no cache (e.g. XLA).
    pub fn session_cache_stats(&self) -> Option<(u64, u64)> {
        self.session_cache.as_ref().map(|c| (c.hits(), c.builds()))
    }

    /// `(hits, builds, syncs)` of the lock-free predict engine's
    /// exponion neighbour cache. With a published serving-scale model
    /// the builds must stay at zero: every predict prunes with the
    /// carried structure.
    pub fn predict_neigh_stats(&self) -> Option<(u64, u64, u64)> {
        self.predict_engine.neigh_cache_stats()
    }

    /// `(hits, builds, syncs)` of the **training** engine's neighbour
    /// cache, via the handle captured at registration — no session
    /// lock. `None` when the engine keeps none (e.g. XLA).
    pub fn session_neigh_stats(&self) -> Option<(u64, u64, u64)> {
        self.session_neigh.as_ref().map(|c| c.stats())
    }

    fn lock_session(&self) -> Result<std::sync::MutexGuard<'_, OnlineSession>> {
        self.session.lock().map_err(|_| {
            anyhow!(
                "model '{}' is unavailable: a previous operation on it \
                 panicked",
                self.name
            )
        })
    }
}

fn publish_view(name: &str, s: &OnlineSession) -> PublishedModel {
    PublishedModel {
        model: name.to_string(),
        cent: s.centroids().cloned(),
        dim: s.data().dim(),
        k: s.cfg().k,
        rounds: s.rounds(),
        n_total: s.data().n(),
        algo: s.cfg().label(),
        rev: s.centroids().map(|c| c.rev).unwrap_or(0),
        sparse: s.data().is_sparse(),
        // builds (at most once per revision, in the session engine's
        // cache) the transpose every sparse predict against this view
        // will share — the publish is the one place that pays O(k·d)
        trans: s.published_trans(),
        // same deal for the exponion neighbour structure: the publish
        // is the one place that may pay O(k²·d), predicts never do
        neigh: s.published_neigh(),
    }
}

/// Bounded-memory ingest policy applied to every session entering the
/// registry: row buffers are spilled to disk-backed shard files under
/// `dir`, keeping at most `max_resident_rows` rows pinned in the block
/// cache. Training over a spilled buffer is bit-identical to the
/// in-RAM session (enforced by `tests/ooc_parity.rs`).
#[derive(Clone, Debug)]
pub struct SpillConfig {
    /// Directory shard files are created under (must exist).
    pub dir: PathBuf,
    /// Rows the per-model pinned-block cache may keep resident.
    pub max_resident_rows: usize,
}

/// Where an evicted model's state lives while it is out of memory —
/// enough to rebuild the entry bit-exactly on the next request for it.
#[derive(Clone)]
struct EvictedModel {
    /// The snapshot file holding the model (a WAL checkpoint's
    /// `ckpt-<name>.{json,bin}`, or `evicted-<name>.{json,bin}` under
    /// the snapshot dir when no WAL is attached).
    path: PathBuf,
    /// The entry's `last_seq` at eviction (restored on reload so replay
    /// and `sync-info` cursors stay exact).
    last_seq: u64,
    /// `Some(file)` when `path` is a WAL checkpoint file: future
    /// checkpoints must keep listing it in the manifest so segment GC
    /// never deletes the only copy of an evicted model.
    ckpt_file: Option<String>,
}

/// The process-wide model table: named entries behind a read-mostly
/// lock. `Sync`, so one registry is shared by every connection thread.
pub struct ModelRegistry {
    models: RwLock<BTreeMap<String, Arc<ModelEntry>>>,
    /// Where protocol `snapshot` ops of wire-created models may write
    /// (models loaded from a snapshot file keep that file's directory).
    snapshot_dir: Mutex<PathBuf>,
    /// Attached write-ahead log: when present, every successful
    /// create/ingest/step/drop is appended (create/drop here, under the
    /// same write lock that makes them visible; ingest/step by the
    /// protocol layer inside the session closure). Attached *after*
    /// recovery replay so replay never re-logs.
    wal: RwLock<Option<Arc<Wal>>>,
    /// Follower mode: the protocol layer rejects mutations (this node's
    /// state is a bit-exact mirror of a primary's log) until promotion
    /// flips it back.
    follower: AtomicBool,
    /// Resident-model cap enforced by the lifecycle sweep (0 = no cap):
    /// past it, least-recently-used models are checkpointed and
    /// dropped from memory, reloading lazily on their next request.
    max_resident: AtomicUsize,
    /// Idle horizon in nanoseconds (0 = never): a model untouched this
    /// long is evicted by the lifecycle sweep.
    idle_evict_nanos: AtomicU64,
    /// Evicted models by name. **Lock order: this mutex is always taken
    /// before `models`**, never the other way round — eviction inserts
    /// here then removes from `models`; reload re-checks `models` while
    /// holding this lock so a racing resolve either finds the resident
    /// entry or waits for the record.
    evicted: Mutex<BTreeMap<String, EvictedModel>>,
    /// Bounded-memory ingest: when set, every session entering the
    /// registry (create, preload, WAL replay, evicted reload) has its
    /// row buffer spilled to a shard file before it becomes visible.
    spill: Mutex<Option<SpillConfig>>,
    /// Monotone suffix for shard file names: a recreated model must
    /// never reuse a path a dying session's `Drop` is about to delete.
    spill_nonce: AtomicU64,
    /// Format eviction snapshots are written in on the no-WAL path
    /// (reads always sniff; WAL checkpoints use the WAL's own format).
    snapshot_format: Mutex<SnapshotFormat>,
}

impl Default for ModelRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl ModelRegistry {
    /// An empty registry: every model arrives via `create` or
    /// [`ModelRegistry::insert`].
    pub fn new() -> ModelRegistry {
        ModelRegistry {
            models: RwLock::new(BTreeMap::new()),
            snapshot_dir: Mutex::new(PathBuf::from(".")),
            wal: RwLock::new(None),
            follower: AtomicBool::new(false),
            max_resident: AtomicUsize::new(0),
            idle_evict_nanos: AtomicU64::new(0),
            evicted: Mutex::new(BTreeMap::new()),
            spill: Mutex::new(None),
            spill_nonce: AtomicU64::new(0),
            snapshot_format: Mutex::new(SnapshotFormat::default()),
        }
    }

    /// Bounded-memory ingest policy (`--data-dir`/`--max-resident-rows`;
    /// `None` keeps buffers fully in RAM). Applied to every session that
    /// enters the registry from now on — already-resident sessions are
    /// not retro-spilled.
    pub fn set_spill(&self, spill: Option<SpillConfig>) {
        *self.spill.lock().unwrap() = spill;
    }

    /// Format protocol/eviction snapshots are written in
    /// (`--snapshot-format`; reads always sniff the format on disk).
    pub fn set_snapshot_format(&self, format: SnapshotFormat) {
        *self.snapshot_format.lock().unwrap() = format;
    }

    /// The configured snapshot output format.
    pub fn snapshot_format(&self) -> SnapshotFormat {
        *self.snapshot_format.lock().unwrap()
    }

    /// Spill `session`'s buffer per the configured policy; no-op when
    /// spilling is off or the buffer is already disk-backed. The shard
    /// file name carries a process-unique nonce so a recreated model
    /// never collides with a dying predecessor's file (whose `Drop`
    /// deletes its own path).
    fn apply_spill(&self, name: &str, session: &mut OnlineSession) -> Result<()> {
        let Some(cfg) = self.spill.lock().unwrap().clone() else {
            return Ok(());
        };
        let nonce = self.spill_nonce.fetch_add(1, Ordering::Relaxed);
        let path = cfg.dir.join(format!("shard-{name}-{nonce}.rows"));
        session.spill_to(&path, cfg.max_resident_rows)
    }

    /// Attach the durable op log. Call after [`crate::serve::wal::recover`]
    /// has finished replaying — everything logged from here on is new.
    pub fn attach_wal(&self, wal: Arc<Wal>) {
        *self.wal.write().unwrap() = Some(wal);
    }

    /// The attached log, if any (cheap `Arc` clone).
    pub fn wal(&self) -> Option<Arc<Wal>> {
        self.wal.read().unwrap().clone()
    }

    /// Whether this node is a read-only follower tailing a primary.
    pub fn is_follower(&self) -> bool {
        self.follower.load(Ordering::SeqCst)
    }

    /// Flip follower mode (promotion clears it).
    pub fn set_follower(&self, on: bool) {
        self.follower.store(on, Ordering::SeqCst);
    }

    /// A registry hosting `session` as the implicit [`DEFAULT_MODEL`] —
    /// the back-compat wrapper for single-model serving.
    pub fn with_default(session: OnlineSession) -> ModelRegistry {
        let reg = ModelRegistry::new();
        reg.insert(DEFAULT_MODEL, session)
            .expect("empty registry accepts the default model");
        reg
    }

    /// Directory `create`d models write their protocol snapshots into.
    pub fn set_snapshot_dir(&self, dir: PathBuf) {
        *self.snapshot_dir.lock().unwrap() = dir;
    }

    /// Directory `create`d models write their protocol snapshots into
    /// (WAL replay builds its sessions with the same setting).
    pub fn snapshot_dir(&self) -> PathBuf {
        self.snapshot_dir.lock().unwrap().clone()
    }

    /// Register an existing session under `name` **without** logging a
    /// create record — the path for preloaded snapshots, WAL replay and
    /// follower bootstrap, whose history is already durable elsewhere.
    pub fn insert(&self, name: &str, session: OnlineSession) -> Result<Arc<ModelEntry>> {
        self.insert_inner(name, session, None)
    }

    fn insert_inner(
        &self,
        name: &str,
        mut session: OnlineSession,
        log_create: Option<(&RunConfig, usize)>,
    ) -> Result<Arc<ModelEntry>> {
        validate_name(name)?;
        // the one funnel every session passes through on its way into
        // the table — create, preload, WAL replay and evicted reload
        // all get the same bounded-memory treatment here
        self.apply_spill(name, &mut session)?;
        let entry = ModelEntry::new(name, session);
        let mut models = self.models.write().unwrap();
        ensure!(
            !models.contains_key(name),
            "model '{name}' already exists"
        );
        ensure!(
            models.len() < MAX_MODELS,
            "registry is full ({MAX_MODELS} models) — drop one first"
        );
        // the create record is appended *before* the insert makes the
        // model visible, under the same write lock: a concurrent ingest
        // can only resolve the model (and log against it) after its
        // create is in the log, so replay never sees an orphan ingest.
        // The logged config is the session's exact bit-level config —
        // wire-form defaults (e.g. thread clamping to the host) were
        // already resolved, so replay on any host rebuilds it verbatim.
        if let Some((cfg, dim)) = log_create {
            if let Some(wal) = self.wal() {
                let header = json::obj(vec![
                    ("op", json::s("create")),
                    ("model", json::s(name)),
                    ("dim", json::num(dim as f64)),
                    ("config", cfg.to_json()),
                ]);
                let seq = wal.append(&header, &[])?;
                entry.set_last_seq(seq);
            }
        }
        models.insert(name.to_string(), entry.clone());
        obslog::event("model_register", &[("model", json::s(name))]);
        Ok(entry)
    }

    /// Create a fresh empty session (the protocol `create` op), logging
    /// it to the WAL when one is attached. The model initialises once
    /// `cfg.k` points have been ingested.
    pub fn create(
        &self,
        name: &str,
        cfg: RunConfig,
        dim: usize,
    ) -> Result<Arc<ModelEntry>> {
        validate_name(name)?;
        // an evicted model still exists (it reloads on use) — its name
        // is not free until an explicit drop
        ensure!(
            !self.evicted.lock().unwrap().contains_key(name),
            "model '{name}' already exists"
        );
        let mut session = OnlineSession::new(cfg.clone(), dim)?;
        session.set_snapshot_dir(self.snapshot_dir());
        let entry = self.insert_inner(name, session, Some((&cfg, dim)))?;
        // keep residency bounded even between lifecycle ticks; the new
        // entry is the most recently used, so LRU never picks it
        self.enforce_residency();
        Ok(entry)
    }

    /// Look up a model; `None` routes to [`DEFAULT_MODEL`]. A model the
    /// lifecycle sweep evicted is transparently reloaded from its
    /// checkpoint — callers cannot tell eviction ever happened (beyond
    /// the one-off reload latency and `nmbkm_model_reloads_total`).
    pub fn resolve(&self, name: Option<&str>) -> Result<Arc<ModelEntry>> {
        let name = name.unwrap_or(DEFAULT_MODEL);
        {
            let models = self.models.read().unwrap();
            if let Some(e) = models.get(name) {
                e.touch();
                return Ok(e.clone());
            }
        }
        if let Some(e) = self.reload_evicted(name)? {
            e.touch();
            return Ok(e);
        }
        let models = self.models.read().unwrap();
        let known: Vec<&str> = models.keys().map(|k| k.as_str()).collect();
        Err(anyhow!(
            "unknown model '{name}' (known: [{}])",
            known.join(", ")
        ))
    }

    /// Remove a model (logging a drop record when a WAL is attached).
    /// Its sessions' in-flight operations finish on their own `Arc`;
    /// the name is immediately reusable.
    pub fn drop_model(&self, name: &str) -> Result<()> {
        self.drop_model_inner(name, true)
    }

    /// [`ModelRegistry::drop_model`] without logging — replay and
    /// follower apply, where the drop is already in the log.
    pub fn drop_model_unlogged(&self, name: &str) -> Result<()> {
        self.drop_model_inner(name, false)
    }

    fn drop_model_inner(&self, name: &str, log: bool) -> Result<()> {
        let mut evicted = self.evicted.lock().unwrap();
        let mut models = self.models.write().unwrap();
        ensure!(
            models.contains_key(name) || evicted.contains_key(name),
            "unknown model '{name}': nothing to drop"
        );
        // logged before the removal becomes visible, under the write
        // lock — mirror of the create ordering, so the log's op order
        // is exactly the order effects became visible
        if log {
            if let Some(wal) = self.wal() {
                let header = json::obj(vec![
                    ("op", json::s("drop")),
                    ("model", json::s(name)),
                ]);
                wal.append(&header, &[])?;
            }
        }
        models.remove(name);
        if let Some(rec) = evicted.remove(name) {
            // an eviction-only snapshot is ours to delete; a WAL
            // checkpoint file is the WAL's — once the record is gone the
            // next checkpoint's GC collects it
            if rec.ckpt_file.is_none() {
                let _ = std::fs::remove_file(&rec.path);
            }
        }
        obslog::event("model_drop", &[("model", json::s(name))]);
        Ok(())
    }

    /// Cap on resident models (`--max-resident`; 0 = no cap). Enforced
    /// by [`ModelRegistry::run_lifecycle`], LRU-first.
    pub fn set_max_resident(&self, cap: usize) {
        self.max_resident.store(cap, Ordering::SeqCst);
    }

    /// Evict models untouched for `idle` (`--model-idle-secs`; `None`
    /// disables). Enforced by [`ModelRegistry::run_lifecycle`].
    pub fn set_idle_evict(&self, idle: Option<Duration>) {
        let ns = idle.map(|d| d.as_nanos() as u64).unwrap_or(0);
        self.idle_evict_nanos.store(ns, Ordering::SeqCst);
    }

    /// One lifecycle sweep: idle eviction, then LRU eviction down to
    /// the residency cap. Called periodically by the serve acceptor
    /// (and after every `create`); returns how many models were
    /// evicted. Cheap when both knobs are off.
    pub fn run_lifecycle(&self) -> usize {
        self.evict_idle() + self.enforce_residency()
    }

    /// Evict every resident model idle past the configured horizon.
    fn evict_idle(&self) -> usize {
        let idle_ns = self.idle_evict_nanos.load(Ordering::SeqCst);
        if idle_ns == 0 {
            return 0;
        }
        let now = mono_nanos();
        let stale: Vec<String> = self
            .models
            .read()
            .unwrap()
            .values()
            .filter(|e| now.saturating_sub(e.last_used()) > idle_ns)
            .map(|e| e.name().to_string())
            .collect();
        let mut n = 0;
        for name in stale {
            if matches!(self.evict_model(&name), Ok(true)) {
                n += 1;
            }
        }
        n
    }

    /// Evict least-recently-used models until at most `max_resident`
    /// remain. Stops early when a candidate cannot be evicted safely
    /// (in use, mutated mid-eviction, or not yet checkpointable) — the
    /// next sweep retries.
    fn enforce_residency(&self) -> usize {
        let cap = self.max_resident.load(Ordering::SeqCst);
        if cap == 0 {
            return 0;
        }
        let mut n = 0;
        loop {
            let candidate = {
                let models = self.models.read().unwrap();
                if models.len() <= cap {
                    break;
                }
                models
                    .values()
                    .min_by_key(|e| e.last_used())
                    .map(|e| e.name().to_string())
            };
            let Some(name) = candidate else { break };
            match self.evict_model(&name) {
                Ok(true) => n += 1,
                _ => break,
            }
        }
        n
    }

    /// Checkpoint-then-drop one model from memory, keeping a reload
    /// record so the next request for it transparently resurrects it.
    /// Returns `Ok(false)` when the model is not resident or cannot be
    /// evicted *safely* right now: its durable copy could not be cut,
    /// a request holds its entry, or it was used/mutated while the
    /// snapshot was being written. Never loses state — the in-memory
    /// entry survives any bail-out.
    pub fn evict_model(&self, name: &str) -> Result<bool> {
        let Some(entry) = self.models.read().unwrap().get(name).cloned() else {
            return Ok(false);
        };
        let seq0 = entry.last_seq();
        let rev0 = entry.current().rev;
        // cut the durable copy with no registry locks held (a WAL
        // checkpoint takes every session lock in turn)
        let (path, ckpt_file) = if let Some(wal) = self.wal() {
            if !wal.checkpoint(self)? {
                return Ok(false); // e.g. an uninitialised model somewhere
            }
            // must mirror the WAL's own checkpoint file naming — the
            // reload record points straight at the file GC protects
            let file = format!("ckpt-{name}.{}", wal.snapshot_format().ext());
            (wal.dir().join(&file), Some(file))
        } else {
            let fmt = self.snapshot_format();
            let path = self
                .snapshot_dir()
                .join(format!("evicted-{name}.{}", fmt.ext()));
            entry.with_session(|s| s.save_snapshot_as(&path, true, fmt))?;
            (path, None)
        };
        // record first, removal second (under the evicted lock
        // throughout): a resolve that misses `models` blocks on the
        // record and reloads — there is no instant where the model is
        // neither resident nor reloadable
        let mut evicted = self.evicted.lock().unwrap();
        evicted.insert(
            name.to_string(),
            EvictedModel { path, last_seq: seq0, ckpt_file },
        );
        let mut models = self.models.write().unwrap();
        // safe only if nothing happened since the durable copy: same
        // entry, no other Arc holder (map + ours = 2), same WAL seq and
        // centroid revision. Any mismatch rolls the record back.
        let safe = match models.get(name) {
            Some(cur) => {
                Arc::ptr_eq(cur, &entry)
                    && Arc::strong_count(&entry) == 2
                    && entry.last_seq() == seq0
                    && entry.current().rev == rev0
            }
            None => false,
        };
        if !safe {
            drop(models);
            evicted.remove(name);
            return Ok(false);
        }
        models.remove(name);
        drop(models);
        drop(evicted);
        serve_metrics().model_evictions.inc();
        obslog::event(
            "model_evict",
            &[("model", json::s(name)), ("seq", u64_json(seq0))],
        );
        Ok(true)
    }

    /// Resurrect an evicted model from its snapshot. `Ok(None)` when no
    /// record exists (a genuinely unknown name). Holds the evicted lock
    /// throughout so concurrent requests reload once, not N times.
    fn reload_evicted(&self, name: &str) -> Result<Option<Arc<ModelEntry>>> {
        let mut evicted = self.evicted.lock().unwrap();
        // a racing resolve may have reloaded while we waited, or an
        // eviction may have rolled back — re-check residency first
        if let Some(e) = self.models.read().unwrap().get(name) {
            return Ok(Some(e.clone()));
        }
        let Some(rec) = evicted.get(name).cloned() else {
            return Ok(None);
        };
        let snap = Snapshot::load(&rec.path).map_err(|e| {
            anyhow!("reloading evicted model '{name}': {e:#}")
        })?;
        let mut session = OnlineSession::resume(snap)?;
        session.set_snapshot_dir(self.snapshot_dir());
        let entry = self.insert(name, session)?;
        entry.set_last_seq(rec.last_seq);
        evicted.remove(name);
        serve_metrics().model_reloads.inc();
        obslog::event("model_reload", &[("model", json::s(name))]);
        Ok(Some(entry))
    }

    /// `(name, checkpoint file, seq)` of every evicted model whose only
    /// copy is a WAL checkpoint file. The WAL folds these into each new
    /// manifest so its GC and segment truncation never orphan them.
    pub fn evicted_for_checkpoint(&self) -> Vec<(String, String, u64)> {
        let evicted = self.evicted.lock().unwrap();
        let models = self.models.read().unwrap();
        evicted
            .iter()
            .filter(|(name, r)| {
                r.ckpt_file.is_some() && !models.contains_key(*name)
            })
            .map(|(name, r)| {
                (name.clone(), r.ckpt_file.clone().unwrap(), r.last_seq)
            })
            .collect()
    }

    /// One `sync-info` row per model: name + last applied WAL seq (the
    /// follower's bootstrap cursor is the minimum of these).
    pub fn sync_rows(&self) -> Json {
        Json::Arr(
            self.entries()
                .iter()
                .map(|e| {
                    json::obj(vec![
                        ("name", json::s(e.name())),
                        ("seq", u64_json(e.last_seq())),
                    ])
                })
                .collect(),
        )
    }

    /// Every registered entry, name-ordered (metric scrapes poll the
    /// per-entry cache counters through this).
    pub fn entries(&self) -> Vec<Arc<ModelEntry>> {
        self.models.read().unwrap().values().cloned().collect()
    }

    /// Published snapshots of every model, name-ordered.
    pub fn list(&self) -> Vec<Arc<PublishedModel>> {
        self.models
            .read()
            .unwrap()
            .values()
            .map(|e| e.current())
            .collect()
    }

    pub fn len(&self) -> usize {
        self.models.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

fn validate_name(name: &str) -> Result<()> {
    ensure!(
        !name.is_empty() && name.len() <= 64,
        "model name must be 1..=64 characters, got {:?}",
        name
    );
    ensure!(
        name.chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.')),
        "model name may contain only [A-Za-z0-9._-], got {name:?}"
    );
    if name == "." || name == ".." {
        bail!("model name {name:?} is reserved");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Algo, Rho};
    use crate::data::gaussian::GaussianMixture;
    use crate::data::Data;

    fn cfg(k: usize, dim_seed: u64) -> RunConfig {
        RunConfig {
            algo: Algo::TbRho,
            k,
            b0: 32,
            rho: Rho::Infinite,
            threads: 2,
            seed: dim_seed,
            max_rounds: 6,
            max_seconds: 30.0,
            ..Default::default()
        }
    }

    fn rows_of(data: &Data, lo: usize, hi: usize) -> Vec<Vec<f32>> {
        let mut out = Vec::with_capacity(hi - lo);
        let mut row = vec![0f32; data.dim()];
        for i in lo..hi {
            data.write_row_dense(i, &mut row);
            out.push(row.clone());
        }
        out
    }

    #[test]
    fn create_route_drop_lifecycle() {
        let reg = ModelRegistry::new();
        assert!(reg.is_empty());
        reg.create("alpha", cfg(3, 1), 4).unwrap();
        reg.create("beta", cfg(2, 2), 6).unwrap();
        assert_eq!(reg.len(), 2);
        // duplicate and invalid names rejected
        assert!(reg.create("alpha", cfg(3, 1), 4).is_err());
        let too_long = "x".repeat(65);
        for bad in ["", "a/b", "a b", "..", too_long.as_str()] {
            assert!(reg.create(bad, cfg(2, 3), 4).is_err(), "accepted {bad:?}");
        }
        assert_eq!(reg.resolve(Some("alpha")).unwrap().name(), "alpha");
        assert!(reg.resolve(Some("gamma")).is_err());
        assert!(reg.resolve(None).is_err(), "no default model registered");
        let names: Vec<String> =
            reg.list().iter().map(|m| m.model.clone()).collect();
        assert_eq!(names, vec!["alpha".to_string(), "beta".to_string()]);
        reg.drop_model("alpha").unwrap();
        assert!(reg.drop_model("alpha").is_err());
        assert_eq!(reg.len(), 1);
        // dropped names are reusable
        reg.create("alpha", cfg(3, 9), 4).unwrap();
    }

    #[test]
    fn registry_is_capped_and_drop_frees_a_slot() {
        // empty single-thread sessions are cheap: fill to the cap
        let reg = ModelRegistry::new();
        let cheap = || RunConfig { threads: 1, ..cfg(2, 1) };
        for i in 0..MAX_MODELS {
            reg.create(&format!("m{i}"), cheap(), 3).unwrap();
        }
        let err = reg.create("one-too-many", cheap(), 3).unwrap_err();
        assert!(format!("{err:#}").contains("full"), "{err:#}");
        // dropping makes room again
        reg.drop_model("m0").unwrap();
        reg.create("one-too-many", cheap(), 3).unwrap();
        assert_eq!(reg.len(), MAX_MODELS);
    }

    #[test]
    fn default_model_routes_unnamed_requests() {
        let data = GaussianMixture::default_spec(3, 5).generate(200, 4);
        let (session, _) = session::train(&data, &cfg(3, 4)).unwrap();
        let reg = ModelRegistry::with_default(session);
        let entry = reg.resolve(None).unwrap();
        assert_eq!(entry.name(), DEFAULT_MODEL);
        let (lbl, d2) = entry.predict(&rows_of(&data, 0, 10)).unwrap();
        assert_eq!(lbl.len(), 10);
        assert!(d2.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn published_snapshot_is_isolated_from_training() {
        let data = GaussianMixture::default_spec(4, 6).generate(400, 7);
        let (session, _) = session::train(&data, &cfg(4, 7)).unwrap();
        let reg = ModelRegistry::with_default(session);
        let entry = reg.resolve(None).unwrap();
        let queries = rows_of(&data, 20, 40);

        let frozen = entry.current();
        let (lbl_a, d2_a) =
            frozen.predict(&queries, &NativeEngine::default(), &entry.pool).unwrap();
        // mutate the session: more rounds move the centroids
        entry
            .with_session_mut(|s| s.step(3, 1e9).map(|_| ()))
            .unwrap();
        // the frozen view still answers identically (snapshot isolation)
        let (lbl_b, d2_b) =
            frozen.predict(&queries, &NativeEngine::default(), &entry.pool).unwrap();
        assert_eq!(lbl_a, lbl_b);
        assert_eq!(
            d2_a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            d2_b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        // while the entry's live view has advanced
        let now = entry.current();
        assert!(now.rounds > frozen.rounds);
        assert_ne!(now.rev, frozen.rev);
        // and the live predict matches the session's own answer bitwise
        let (lbl_live, d2_live) = entry.predict(&queries).unwrap();
        let (lbl_sess, d2_sess) = entry
            .with_session(|s| s.predict_rows(&queries))
            .unwrap();
        assert_eq!(lbl_live, lbl_sess);
        assert_eq!(
            d2_live.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            d2_sess.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn sparse_publish_carries_transpose_and_predicts_never_rebuild() {
        let data = crate::data::rcv1::Rcv1Sim {
            vocab: 300,
            topic_vocab: 40,
            ..Default::default()
        }
        .generate(400, 8);
        let (session, _) = session::train(&data, &cfg(10, 8)).unwrap();
        let reg = ModelRegistry::with_default(session);
        let entry = reg.resolve(None).unwrap();
        let view = entry.current();
        assert!(view.sparse);
        let tc = view
            .trans
            .as_ref()
            .expect("sparse publish must carry the transpose");
        assert_eq!((tc.k, tc.d), (10, 300));
        let queries = rows_of(&data, 0, 6);
        for _ in 0..4 {
            entry.predict(&queries).unwrap();
        }
        assert_eq!(
            entry.predict_cache_stats(),
            (4, 0),
            "published sparse predicts must be served by the carried \
             transpose, never a rebuild"
        );
        // live and published answers agree bitwise on the sparse path
        let (la, da) = entry.predict(&queries).unwrap();
        let (lb, db) =
            entry.with_session(|s| s.predict_rows(&queries)).unwrap();
        assert_eq!(la, lb);
        assert_eq!(
            da.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            db.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        // a training step publishes a fresh transpose; predicts against
        // the new view still never build their own
        entry
            .with_session_mut(|s| s.step(1, 1e9).map(|_| ()))
            .unwrap();
        entry.predict(&queries).unwrap();
        assert_eq!(entry.predict_cache_stats().1, 0);
        assert!(entry.current().trans.is_some());
        // dense models carry no transpose
        let dense = GaussianMixture::default_spec(3, 5).generate(100, 4);
        let (ds, _) = session::train(&dense, &cfg(3, 4)).unwrap();
        let reg2 = ModelRegistry::with_default(ds);
        let dview = reg2.resolve(None).unwrap().current();
        assert!(!dview.sparse);
        assert!(dview.trans.is_none());
    }

    #[test]
    fn serving_scale_publish_carries_neigh_and_predicts_never_rebuild() {
        // serving-scale k crosses the exponion gate: the published view
        // must carry the neighbour structure and every predict must
        // prune with it — zero O(k²·d) builds on the predict engine
        let k = crate::kmeans::assign::EXPONION_MIN_K;
        let data = GaussianMixture::default_spec(8, 8).generate(k + 128, 13);
        let (session, _) = session::train(&data, &cfg(k, 17)).unwrap();
        let reg = ModelRegistry::with_default(session);
        let entry = reg.resolve(None).unwrap();
        let view = entry.current();
        assert!(!view.sparse);
        let ni = view
            .neigh
            .as_ref()
            .expect("serving-scale publish must carry the neighbour structure");
        assert_eq!((ni.k(), ni.d()), (k, 8));
        assert_eq!(ni.rev, view.rev);
        let queries = rows_of(&data, 0, 6);
        for _ in 0..4 {
            entry.predict(&queries).unwrap();
        }
        let (hits, builds, syncs) = entry.predict_neigh_stats().unwrap();
        assert_eq!(
            (hits, builds, syncs),
            (4, 0, 0),
            "published predicts must prune with the carried structure, \
             never build their own"
        );
        // published and live answers agree bitwise
        let (la, da) = entry.predict(&queries).unwrap();
        let (lb, db) =
            entry.with_session(|s| s.predict_rows(&queries)).unwrap();
        assert_eq!(la, lb);
        assert_eq!(
            da.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            db.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        // a training step republishes; predicts against the new view
        // still never build
        entry
            .with_session_mut(|s| s.step(1, 1e9).map(|_| ()))
            .unwrap();
        entry.predict(&queries).unwrap();
        assert_eq!(entry.predict_neigh_stats().unwrap().1, 0);
        assert!(entry.current().neigh.is_some());
        // the training engine's neighbour cache is scraped lock-free
        let (_, sb, _) = entry.session_neigh_stats().unwrap();
        assert!(sb >= 1, "training at serving-scale k must build once");
    }

    #[test]
    fn uninitialised_model_rejects_predicts_until_fed() {
        let reg = ModelRegistry::new();
        let entry = reg.create("fresh", cfg(3, 5), 4).unwrap();
        assert!(entry.predict(&[vec![0.0; 4]]).is_err());
        let data = GaussianMixture::default_spec(3, 4).generate(50, 5);
        entry
            .with_session_mut(|s| {
                s.ingest_rows(&rows_of(&data, 0, 50)).map(|_| ())
            })
            .unwrap();
        let (lbl, _) = entry.predict(&[vec![0.0; 4]]).unwrap();
        assert_eq!(lbl.len(), 1);
        let view = entry.current();
        assert!(view.cent.is_some());
        assert_eq!(view.n_total, 50);
    }

    fn lifecycle_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("nmbkm-reg-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn eviction_and_lazy_reload_are_bit_exact() {
        let dir = lifecycle_dir("evict");
        let data = GaussianMixture::default_spec(3, 5).generate(300, 11);
        let (session, _) = session::train(&data, &cfg(3, 11)).unwrap();
        let reg = ModelRegistry::with_default(session);
        reg.set_snapshot_dir(dir.clone());
        let queries = rows_of(&data, 0, 12);
        let entry = reg.resolve(None).unwrap();
        let (lbl_a, d2_a) = entry.predict(&queries).unwrap();
        let rev_a = entry.current().rev;
        drop(entry); // eviction refuses while an Arc is held
        assert!(reg.evict_model(DEFAULT_MODEL).unwrap());
        assert_eq!(reg.len(), 0, "evicted model leaves memory");
        assert!(
            dir.join("evicted-default.json").exists(),
            "no-WAL eviction snapshots under the registry's snapshot dir"
        );
        // resolve resurrects it transparently, bit-exactly
        let back = reg.resolve(None).unwrap();
        assert_eq!(reg.len(), 1);
        let (lbl_b, d2_b) = back.predict(&queries).unwrap();
        assert_eq!(lbl_a, lbl_b);
        assert_eq!(
            d2_a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            d2_b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(back.current().rev, rev_a, "revision survives the trip");
        // the reloaded model keeps training where it left off
        back.with_session_mut(|s| s.step(1, 1e9).map(|_| ())).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn eviction_refuses_while_entry_is_held() {
        let dir = lifecycle_dir("held");
        let data = GaussianMixture::default_spec(3, 4).generate(120, 3);
        let (session, _) = session::train(&data, &cfg(3, 3)).unwrap();
        let reg = ModelRegistry::with_default(session);
        reg.set_snapshot_dir(dir.clone());
        let held = reg.resolve(None).unwrap();
        assert!(
            !reg.evict_model(DEFAULT_MODEL).unwrap(),
            "a held Arc must veto eviction"
        );
        assert_eq!(reg.len(), 1);
        drop(held);
        assert!(reg.evict_model(DEFAULT_MODEL).unwrap());
        // double-evict is a clean no-op
        assert!(!reg.evict_model(DEFAULT_MODEL).unwrap());
        // create over an evicted name is a duplicate; drop frees it and
        // removes the parked snapshot file
        let err = reg.create(DEFAULT_MODEL, cfg(3, 3), 4).unwrap_err();
        assert!(format!("{err:#}").contains("already exists"), "{err:#}");
        reg.drop_model(DEFAULT_MODEL).unwrap();
        assert!(!dir.join("evicted-default.json").exists());
        assert!(reg.resolve(None).is_err(), "dropped, not evicted");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn residency_cap_evicts_least_recently_used() {
        let dir = lifecycle_dir("lru");
        let reg = ModelRegistry::new();
        reg.set_snapshot_dir(dir.clone());
        let data = GaussianMixture::default_spec(2, 4).generate(60, 9);
        for name in ["a", "b", "c"] {
            let e = reg.create(name, RunConfig { threads: 1, ..cfg(2, 9) }, 4).unwrap();
            e.with_session_mut(|s| s.ingest_rows(&rows_of(&data, 0, 60)).map(|_| ()))
                .unwrap();
        }
        // recency order now a < b < c; touch a so b becomes LRU
        reg.resolve(Some("a")).unwrap();
        reg.set_max_resident(2);
        assert_eq!(reg.run_lifecycle(), 1);
        assert_eq!(reg.len(), 2);
        let resident: Vec<String> =
            reg.list().iter().map(|m| m.model.clone()).collect();
        assert_eq!(resident, vec!["a".to_string(), "c".to_string()]);
        // b still answers — it reloads on demand, and the reload makes
        // it most-recent, pushing the cap onto the next LRU victim
        assert_eq!(reg.resolve(Some("b")).unwrap().name(), "b");
        assert_eq!(reg.len(), 3);
        assert_eq!(reg.run_lifecycle(), 1);
        assert_eq!(reg.len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn idle_horizon_evicts_untouched_models() {
        let dir = lifecycle_dir("idle");
        let data = GaussianMixture::default_spec(2, 4).generate(60, 2);
        let (session, _) = session::train(&data, &cfg(2, 2)).unwrap();
        let reg = ModelRegistry::with_default(session);
        reg.set_snapshot_dir(dir.clone());
        reg.set_idle_evict(Some(Duration::from_nanos(1)));
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(reg.run_lifecycle(), 1);
        assert_eq!(reg.len(), 0);
        // disabling the horizon stops the sweep
        let back = reg.resolve(None).unwrap();
        drop(back);
        reg.set_idle_evict(None);
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(reg.run_lifecycle(), 0);
        assert_eq!(reg.len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
