"""L2 + AOT path tests: model graphs, shape contracts, HLO export.

These exercise exactly what the rust runtime depends on: every manifest
entry lowers to parseable HLO text, with the input/output signature the
manifest advertises, and the fused graphs agree with their unfused parts.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


def _mk(seed, b, d, k):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    return x, c, jnp.sum(c * c, axis=1)


def test_assign_stats_fused_matches_unfused():
    x, c, cn = _mk(0, 256, 32, 16)
    lbl, d2, s, v, sse = model.assign_stats_fn(x, c, cn)
    lbl_r, d2_r = ref.assign_ref(x, c)
    s_r, v_r, sse_r = ref.cluster_stats_ref(x, lbl_r, d2_r, 16)
    np.testing.assert_array_equal(np.asarray(lbl), np.asarray(lbl_r))
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_r),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v_r))


def test_validation_mse_is_sum_of_min_d2():
    x, c, cn = _mk(1, 256, 16, 8)
    (total,) = model.validation_mse_fn(x, c, cn)
    _, d2 = ref.assign_ref(x, c)
    np.testing.assert_allclose(float(total), float(jnp.sum(d2)), rtol=1e-5)


def test_build_entries_cover_manifest_menu():
    entries = aot.build_entries()
    names = {e[0] for e in entries}
    for b in aot.BATCHES:
        for d in aot.DIMS:
            for prefix in ("assign", "assign_stats", "stats", "vmse",
                           "distmat"):
                assert f"{prefix}_b{b}_d{d}_k{aot.K}" in names
        assert f"screen_b{b}_k{aot.K}" in names
    # 5 programs × |B|×|D| + screen × |B|
    assert len(entries) == 5 * len(aot.BATCHES) * len(aot.DIMS) \
        + len(aot.BATCHES)


@pytest.mark.parametrize("which", ["assign_b256_d64", "screen_b256"])
def test_lowered_hlo_text_parses(which):
    """Each program lowers to HLO text that XLA's own parser accepts —
    the same parser path the rust xla crate uses."""
    from jax._src.lib import xla_client as xc
    entry = next(e for e in aot.build_entries() if e[0].startswith(which))
    name, fn, args, _ = entry
    text = aot.to_hlo_text(model.lower(fn, *args))
    assert "ENTRY" in text and "ROOT" in text
    # round-trip through the HLO parser
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(model.lower(fn, *args).compiler_ir("stablehlo")),
        use_tuple_args=False, return_tuple=True)
    assert comp.as_hlo_text() == text


def test_manifest_written(tmp_path):
    """End-to-end aot run (filtered to one entry) produces manifest +
    HLO file with matching signatures."""
    import subprocess, sys
    out = tmp_path / "arts"
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--only", "assign_b256_d64"],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        check=True, env=env)
    man = json.loads((out / "manifest.json").read_text())
    assert man["k"] == aot.K
    (e,) = man["entries"]
    assert e["name"] == "assign_b256_d64_k64"
    assert e["inputs"][0] == ["float32", [256, 64]]
    assert e["outputs"][0] == ["int32", [256]]
    assert (out / e["file"]).exists()


def test_fingerprint_stable():
    assert aot.input_fingerprint() == aot.input_fingerprint()
