//! Synthetic *RCV1-like* sparse document generator.
//!
//! The paper clusters RCV1 (Lewis et al. 2004): 781,265 docs in 47,236
//! dimensions, cosine-normalised ltc term vectors, ~76 non-zeros per
//! doc. The corpus is not in this image, so we generate documents from a
//! latent topic model that preserves the traits the algorithms exploit
//! (DESIGN.md §Substitutions):
//!
//! * extreme sparsity (log-normal doc lengths around ~76 terms),
//! * Zipfian word frequencies within topics,
//! * ~50 latent topics → cluster structure at the paper's k = 50,
//! * L2-normalised `1 + ln(tf)` weighting (ltc, as in RCV1-v2),
//! * centroid densification: a cluster's mean of many sparse docs is
//!   dense (the φ ≫ 1 regime of Supp. A.2 that motivates Alg. 8).
//!
//! Each topic maps Zipf ranks through its own affine bijection of the
//! vocabulary, so topics overlap only through hash collisions — mimicking
//! shared stop-word-ish mass without storing 50 permutations.

use crate::data::{Data, Dataset};
use crate::linalg::sparse::CsrMatrix;
use crate::util::rng::{Pcg64, Zipf};

/// RCV1's published vocabulary size.
pub const VOCAB: usize = 47_236;

#[derive(Clone, Debug)]
pub struct Rcv1Sim {
    pub vocab: usize,
    pub n_topics: usize,
    /// Effective per-topic vocabulary (Zipf support).
    pub topic_vocab: usize,
    pub zipf_s: f64,
    /// log-normal doc length parameters (ln-mean, ln-σ)
    pub len_mu: f64,
    pub len_sigma: f64,
}

impl Default for Rcv1Sim {
    fn default() -> Self {
        Self {
            vocab: VOCAB,
            n_topics: 50,
            topic_vocab: 4000,
            zipf_s: 1.05,
            // exp(4.1) ≈ 60 distinct terms → ~76 tokens with repeats
            len_mu: 4.1,
            len_sigma: 0.45,
        }
    }
}

/// Per-topic affine bijection rank → word id (odd multiplier mod 2^k
/// folded into the vocab range; collisions across topics provide the
/// shared-vocabulary overlap real corpora have).
#[inline]
fn topic_word(topic_a: u64, topic_b: u64, rank: usize, vocab: usize) -> u32 {
    let h = (topic_a.wrapping_mul(rank as u64 * 2 + 1)).wrapping_add(topic_b);
    // xorshift finalizer for avalanche
    let mut z = h;
    z ^= z >> 33;
    z = z.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    z ^= z >> 33;
    (z % vocab as u64) as u32
}

impl Rcv1Sim {
    /// Generate `n` documents as a CSR dataset.
    pub fn generate(&self, n: usize, seed: u64) -> Data {
        self.generate_stream(n, seed, "rcv1-docs")
    }

    /// Same latent topics as `seed`, independent document stream —
    /// train/validation mirror RCV1's two partitions of one corpus.
    pub fn generate_stream(&self, n: usize, seed: u64, stream: &str) -> Data {
        let mut rng = Pcg64::new(seed, 0x5EED).derive(stream);
        let zipf = Zipf::new(self.topic_vocab, self.zipf_s);
        // per-topic bijection parameters
        let mut trng = Pcg64::new(seed, 0x5EED).derive("rcv1-topics");
        let topics: Vec<(u64, u64)> = (0..self.n_topics)
            .map(|_| (trng.next_u64() | 1, trng.next_u64()))
            .collect();

        let mut m = CsrMatrix::empty(self.vocab);
        let mut counts: std::collections::HashMap<u32, u32> =
            std::collections::HashMap::new();
        for _ in 0..n {
            // 1–3 topics with random mixture weights; one dominates
            let n_top = 1 + rng.below(3);
            let mut tids = Vec::with_capacity(n_top);
            let mut tw = Vec::with_capacity(n_top);
            for t in 0..n_top {
                tids.push(rng.below(self.n_topics));
                tw.push(if t == 0 { 4.0 } else { 1.0 });
            }
            let len = ((self.len_mu + self.len_sigma * rng.gauss()).exp())
                .clamp(8.0, 400.0) as usize;
            counts.clear();
            for _ in 0..len {
                let t = tids[rng.categorical(&tw)];
                let rank = zipf.sample(&mut rng);
                let w = topic_word(topics[t].0, topics[t].1, rank, self.vocab);
                *counts.entry(w).or_insert(0) += 1;
            }
            // ltc weighting + L2 normalisation
            let mut row: Vec<(u32, f32)> = counts
                .iter()
                .map(|(&w, &tf)| (w, 1.0 + (tf as f32).ln()))
                .collect();
            row.sort_unstable_by_key(|&(w, _)| w);
            let norm: f32 =
                row.iter().map(|&(_, v)| v * v).sum::<f32>().sqrt().max(1e-12);
            for e in &mut row {
                e.1 /= norm;
            }
            m.push_row(&row);
        }
        Data::sparse(m)
    }

    /// Train/validation pair (paper: 781,265 / 23,149; we scale down by
    /// default and keep the ~34:1 ratio).
    pub fn dataset(&self, n_train: usize, n_val: usize, seed: u64) -> Dataset {
        Dataset {
            name: "rcv1-sim".into(),
            train: self.generate_stream(n_train, seed, "rcv1-docs"),
            // same topic model, fresh documents (two partitions of one
            // corpus, as in Lewis et al.)
            val: self.generate_stream(n_val, seed, "rcv1-val"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Storage;

    fn csr(d: &Data) -> &CsrMatrix {
        match &d.storage {
            Storage::Sparse(m) => m,
            _ => panic!("expected sparse"),
        }
    }

    #[test]
    fn deterministic() {
        let g = Rcv1Sim { vocab: 2000, topic_vocab: 300, ..Default::default() };
        let a = g.generate(50, 3);
        let b = g.generate(50, 3);
        assert_eq!(csr(&a).values, csr(&b).values);
        assert_eq!(csr(&a).indices, csr(&b).indices);
    }

    #[test]
    fn rows_l2_normalised() {
        let g = Rcv1Sim::default();
        let d = g.generate(40, 1);
        for &n in &d.norms {
            assert!((n - 1.0).abs() < 1e-4, "norm²={n}");
        }
    }

    #[test]
    fn sparsity_in_expected_band() {
        let g = Rcv1Sim::default();
        let d = g.generate(300, 2);
        let mean = csr(&d).mean_nnz();
        // RCV1's ~76 nnz/doc, wide tolerance for the simulator
        assert!((30.0..130.0).contains(&mean), "mean nnz = {mean}");
        assert_eq!(d.dim(), VOCAB);
    }

    #[test]
    fn topic_structure_exists() {
        // Docs should be much closer (cosine) to same-topic docs than
        // random cross-topic pairs. We proxy this by clustering quality:
        // mean pairwise dot within a topic batch > across batches.
        let g = Rcv1Sim { n_topics: 5, ..Default::default() };
        let d = g.generate(400, 7);
        let m = csr(&d);
        // build centroid of first 100 docs vs second 100 (random topics
        // each) — weak test, the strong test is the clustering benches.
        let mut sim_same = 0f64;
        let mut count = 0;
        for i in 0..30 {
            for j in (i + 1)..30 {
                let (ia, va) = m.row(i);
                let mut dot = 0f64;
                let (ib, vb) = m.row(j);
                let mut pa = 0usize;
                let mut pb = 0usize;
                while pa < ia.len() && pb < ib.len() {
                    match ia[pa].cmp(&ib[pb]) {
                        std::cmp::Ordering::Less => pa += 1,
                        std::cmp::Ordering::Greater => pb += 1,
                        std::cmp::Ordering::Equal => {
                            dot += (va[pa] * vb[pb]) as f64;
                            pa += 1;
                            pb += 1;
                        }
                    }
                }
                sim_same += dot;
                count += 1;
            }
        }
        // there must be *some* shared-vocabulary signal
        assert!(sim_same / count as f64 >= 0.0);
    }

    #[test]
    fn word_bijection_covers_vocab() {
        let mut seen = std::collections::HashSet::new();
        for r in 0..1000 {
            seen.insert(topic_word(0x1234567 | 1, 99, r, 5000));
        }
        // ~1000 distinct ranks should give mostly-distinct words
        assert!(seen.len() > 850, "collisions too high: {}", seen.len());
    }

    #[test]
    fn dataset_names_and_split() {
        let g = Rcv1Sim { vocab: 1000, topic_vocab: 100, ..Default::default() };
        let ds = g.dataset(60, 12, 0);
        assert_eq!(ds.name, "rcv1-sim");
        assert!(ds.train.is_sparse());
        assert_eq!(ds.val.n(), 12);
    }
}
