//! Protocol transports: stdio and TCP, with per-connection wire-format
//! negotiation.
//!
//! Both transports speak the JSONL protocol (`serve::protocol`) against
//! one shared [`ModelRegistry`]; when the server was started with
//! binary framing enabled (`nmbkm serve --binary`), a connection whose
//! first byte is the magic [`crate::serve::frame::MAGIC`] speaks the
//! length-prefixed binary protocol (`serve::frame`) instead — JSONL
//! clients on the same port are untouched, because no JSONL request can
//! start with that byte. The TCP server is the **event-driven readiness
//! loop** in [`crate::serve::event`]: an acceptor plus a few event-loop
//! shards own every socket, a small worker pool executes requests, and
//! per-connection write queues give slow peers backpressure instead of
//! a pinned thread. Predicts resolve a published model snapshot and run
//! lock-free, so read traffic scales with connections while mutations
//! (ingest/step/snapshot) serialise only on their own model's session
//! lock. An explicit `shutdown` request from any connection (either
//! framing) stops the whole server (stdio: EOF works too); shutdown is
//! a poller wake token, not a loopback self-connect.

use crate::serve::event;
use crate::serve::frame;
use crate::serve::protocol::serve_lines;
use crate::serve::registry::ModelRegistry;
use crate::util::json::{self, Json};
use anyhow::{Context, Result};
use std::io::{BufRead, Write};
use std::net::TcpListener;
use std::sync::Arc;
use std::time::Duration;

/// The JSONL refusal a magic-byte opener gets when framing is off.
pub(crate) const BINARY_DISABLED_MSG: &str =
    "binary framing is not enabled on this server (start it with --binary)";

/// Accept-loop knobs. The default matches `nmbkm serve`'s defaults:
/// JSONL only, 60 s idle timeout, no admission limits.
#[derive(Clone, Copy)]
pub struct ServeOptions {
    /// Negotiate the binary framing on a leading magic byte.
    pub accept_binary: bool,
    /// Idle timeout for every accepted socket (`None` disables). A peer
    /// that sits idle with no request in flight longer than this gets
    /// its connection dropped — the slowloris defence — and counts on
    /// `nmbkm_connection_timeouts_total`.
    pub conn_timeout: Option<Duration>,
    /// Admitted-connection cap (`--max-conns`; 0 = unlimited). Peers
    /// over the cap get a structured `overloaded` error and a close.
    pub max_conns: usize,
    /// Dispatched-but-unanswered request cap across all connections
    /// (`--max-inflight`; 0 = unlimited). Over-limit requests get an
    /// `overloaded` error; the connection survives.
    pub max_inflight: usize,
    /// Per-request size cap in bytes — a JSONL line or a whole binary
    /// frame (`--max-request-bytes`; 0 = unlimited). Oversized requests
    /// are skipped with an `overloaded` error; the stream survives.
    pub max_request_bytes: usize,
    /// Per-connection write-queue cap before the server stops reading
    /// from that peer (backpressure; 0 = the 4 MiB default).
    pub write_queue_cap: usize,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            accept_binary: false,
            conn_timeout: Some(Duration::from_secs(60)),
            max_conns: 0,
            max_inflight: 0,
            max_request_bytes: 0,
            write_queue_cap: 0,
        }
    }
}

/// Serve requests from stdin, responses to stdout, until EOF or
/// `shutdown`. Single-threaded by construction (one client).
/// `accept_binary` lets a piped supervisor use the binary framing too.
pub fn serve_stdio(registry: &ModelRegistry, accept_binary: bool) -> Result<()> {
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut input = stdin.lock();
    let mut out = stdout.lock();
    let ended = serve_negotiated(registry, &mut input, &mut out, accept_binary);
    drain_wal(registry);
    ended?;
    Ok(())
}

/// Graceful drain on shutdown: fsync the WAL's tail and cut a final
/// checkpoint, so a restart replays nothing. Called once every handler
/// has exited (no mutation can race the flush). Failures keep the log —
/// recovery replay still reaches the same state.
pub(crate) fn drain_wal(registry: &ModelRegistry) {
    if let Some(w) = registry.wal() {
        match w.drain(registry) {
            Ok(()) => {
                eprintln!("[nmbkm::serve] wal drained (synced + final checkpoint)")
            }
            Err(e) => eprintln!("[nmbkm::serve] wal drain failed: {e:#}"),
        }
    }
}

/// Dispatch one request stream by its first byte: the binary magic
/// (when enabled) selects frame mode, anything else — including EOF —
/// stays on JSONL. Returns whether the stream ended with an explicit
/// shutdown. This blocking path serves stdio and doubles as the
/// reference implementation the event loop is byte-parity-tested
/// against.
fn serve_negotiated<R: BufRead, W: Write>(
    registry: &ModelRegistry,
    input: &mut R,
    output: &mut W,
    accept_binary: bool,
) -> Result<bool> {
    let first = input.fill_buf()?.first().copied();
    match first {
        Some(frame::MAGIC) if accept_binary => {
            input.consume(1);
            frame::serve_frames(registry, input, output)
        }
        Some(frame::MAGIC) => {
            // refuse loudly in the client's only other dialect, then
            // drop the connection — silence would look like a hang
            let resp = json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", json::s(BINARY_DISABLED_MSG)),
            ]);
            writeln!(output, "{}", resp.to_string())?;
            output.flush()?;
            Ok(false)
        }
        _ => serve_lines(registry, input, output),
    }
}

/// Bind `addr` (e.g. `127.0.0.1:7878`, or port 0 for ephemeral) and
/// serve concurrent connections until a client sends `shutdown`.
pub fn serve_tcp(
    registry: Arc<ModelRegistry>,
    addr: &str,
    opts: ServeOptions,
) -> Result<()> {
    let listener =
        TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    eprintln!(
        "[nmbkm::serve] listening on {} ({} models; JSONL: create|list|drop|\
         ingest|predict|step|stats|snapshot|metrics|sync-info|promote|\
         shutdown{})",
        listener.local_addr()?,
        registry.len(),
        if opts.accept_binary {
            "; binary frames negotiated by magic byte 0xB7"
        } else {
            ""
        },
    );
    serve_listener_with(registry, listener, opts)
}

/// [`serve_listener_with`] with binary framing off and no socket
/// timeouts: the JSONL-only accept loop every pre-existing caller gets.
pub fn serve_listener(
    registry: Arc<ModelRegistry>,
    listener: TcpListener,
) -> Result<()> {
    serve_listener_opts(registry, listener, false)
}

/// [`serve_listener_with`] keyed by the binary toggle alone (no socket
/// timeouts) — the historical test/bench entry point.
pub fn serve_listener_opts(
    registry: Arc<ModelRegistry>,
    listener: TcpListener,
    accept_binary: bool,
) -> Result<()> {
    serve_listener_with(
        registry,
        listener,
        ServeOptions { accept_binary, conn_timeout: None, ..Default::default() },
    )
}

/// Serve an already-bound listener (split out so tests can bind an
/// ephemeral port themselves) with the event-driven readiness loop:
/// see [`crate::serve::event`] for the architecture. Returns after a
/// client's `shutdown` has drained connections and the WAL.
pub fn serve_listener_with(
    registry: Arc<ModelRegistry>,
    listener: TcpListener,
    opts: ServeOptions,
) -> Result<()> {
    event::run(registry, listener, opts)
}
