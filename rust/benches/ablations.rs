//! Bench A — ablations beyond the paper's tables: growth-policy law
//! (double vs ×1.5 vs additive vs always-double) and initialisation
//! scheme (shuffle-first-k vs uniform vs batch-restricted k-means++),
//! both identified as future work in the paper's §5.

use nmbkm::experiments::{ablations, common::ExpOpts};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = ExpOpts::from_args(&args);
    println!(
        "[ablations] scale={:?} seeds={} budget={}s/run",
        opts.scale, opts.seeds, opts.seconds
    );
    ablations::run(&opts).expect("ablations failed");
}
