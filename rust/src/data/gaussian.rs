//! Gaussian-mixture generator: the controlled workload for unit tests,
//! property tests and the quickstart example. Ground-truth centers are
//! returned so tests can check recovery.

use crate::data::{Data, Dataset};
use crate::linalg::dense::DenseMatrix;
use crate::util::rng::Pcg64;

/// Specification of an isotropic Gaussian mixture in `d` dimensions.
#[derive(Clone, Debug)]
pub struct GaussianMixture {
    pub k: usize,
    pub d: usize,
    /// Distance scale between centers (centers ~ N(0, center_spread²·I)).
    pub center_spread: f64,
    /// Within-cluster noise σ.
    pub noise: f64,
    /// Mixing weights (uniform if empty).
    pub weights: Vec<f64>,
}

impl GaussianMixture {
    /// A well-separated default: spread 5σ.
    pub fn default_spec(k: usize, d: usize) -> Self {
        Self { k, d, center_spread: 5.0, noise: 1.0, weights: vec![] }
    }

    /// Draw ground-truth centers for a given seed.
    pub fn centers(&self, seed: u64) -> DenseMatrix {
        let mut rng = Pcg64::new(seed, 0xCE17).derive("gmm-centers");
        let mut c = DenseMatrix::zeros(self.k, self.d);
        for j in 0..self.k {
            for t in 0..self.d {
                c.row_mut(j)[t] = (rng.gauss() * self.center_spread) as f32;
            }
        }
        c
    }

    /// Generate `n` points (row-major dense).
    pub fn generate(&self, n: usize, seed: u64) -> Data {
        self.generate_stream(n, seed, "gmm-points")
    }

    /// Same mixture (centers from `seed`) with an independent sample
    /// stream — used for train/validation pairs.
    pub fn generate_stream(&self, n: usize, seed: u64, stream: &str) -> Data {
        let centers = self.centers(seed);
        let mut rng = Pcg64::new(seed, 0xCE17).derive(stream);
        let weights = if self.weights.is_empty() {
            vec![1.0; self.k]
        } else {
            assert_eq!(self.weights.len(), self.k);
            self.weights.clone()
        };
        let mut m = DenseMatrix::zeros(n, self.d);
        for i in 0..n {
            let j = rng.categorical(&weights);
            let cj = centers.row(j);
            let r = m.row_mut(i);
            for t in 0..self.d {
                r[t] = cj[t] + (rng.gauss() * self.noise) as f32;
            }
        }
        Data::dense(m)
    }

    /// Train + validation dataset pair.
    pub fn dataset(&self, n_train: usize, n_val: usize, seed: u64) -> Dataset {
        Dataset {
            name: format!("gaussian-k{}-d{}", self.k, self.d),
            train: self.generate_stream(n_train, seed, "gmm-points"),
            // same mixture, independent sample stream
            val: self.generate_stream(n_val, seed, "gmm-val"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let spec = GaussianMixture::default_spec(4, 8);
        let a = spec.generate(100, 7);
        let b = spec.generate(100, 7);
        match (&a.storage, &b.storage) {
            (crate::data::Storage::Dense(ma), crate::data::Storage::Dense(mb)) => {
                assert_eq!(ma.data, mb.data)
            }
            _ => panic!(),
        }
    }

    #[test]
    fn seeds_differ() {
        let spec = GaussianMixture::default_spec(4, 8);
        let a = spec.generate(10, 1);
        let b = spec.generate(10, 2);
        let (ma, mb) = match (&a.storage, &b.storage) {
            (crate::data::Storage::Dense(x), crate::data::Storage::Dense(y)) => (x, y),
            _ => panic!(),
        };
        assert_ne!(ma.data, mb.data);
    }

    #[test]
    fn points_cluster_near_centers() {
        let spec = GaussianMixture { k: 3, d: 16, center_spread: 20.0, noise: 0.5, weights: vec![] };
        let data = spec.generate(300, 42);
        let centers = spec.centers(42);
        let cn = centers.row_sq_norms();
        // every point should be within ~d·(3σ)² of *some* center
        for i in 0..data.n() {
            let (_, d2) = data.nearest(i, &centers, &cn);
            assert!(d2 < 16.0 * 9.0 * 0.25 * 4.0, "point {i} too far: {d2}");
        }
    }

    #[test]
    fn weights_respected() {
        let spec = GaussianMixture {
            k: 2, d: 4, center_spread: 50.0, noise: 0.1,
            weights: vec![0.9, 0.1],
        };
        let data = spec.generate(2000, 3);
        let centers = spec.centers(3);
        let cn = centers.row_sq_norms();
        let mut counts = [0usize; 2];
        for i in 0..data.n() {
            counts[data.nearest(i, &centers, &cn).0 as usize] += 1;
        }
        assert!(counts[0] > 5 * counts[1], "counts={counts:?}");
    }

    #[test]
    fn dataset_pair_shapes() {
        let ds = GaussianMixture::default_spec(2, 3).dataset(50, 10, 0);
        assert_eq!(ds.train.n(), 50);
        assert_eq!(ds.val.n(), 10);
        assert_eq!(ds.train.dim(), 3);
        assert!(!ds.train.is_sparse());
    }
}
