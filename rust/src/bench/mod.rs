//! Benchmark harness (criterion is unavailable offline).
//!
//! [`BenchSet`] runs named closures with warmup, multiple samples, and
//! reports min/median/mean — enough statistical hygiene for the paper's
//! throughput tables. `cargo bench` targets under `rust/benches/` are
//! `harness = false` binaries built on this.

use crate::util::stats;
use std::time::Instant;

/// One measured result.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<f64>,
}

impl Measurement {
    pub fn median_secs(&self) -> f64 {
        stats::median(&self.samples)
    }

    pub fn mean_secs(&self) -> f64 {
        stats::mean(&self.samples)
    }

    pub fn min_secs(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }
}

/// Bench runner configuration; `quick()` keeps CI latency sane and is
/// selected by the `--quick` flag or `NMBKM_BENCH_QUICK=1`.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    pub warmup: usize,
    pub samples: usize,
}

impl BenchOpts {
    pub fn standard() -> Self {
        Self { warmup: 2, samples: 7 }
    }

    pub fn quick() -> Self {
        Self { warmup: 1, samples: 3 }
    }

    pub fn from_env_or_args(args: &[String]) -> Self {
        let quick = args.iter().any(|a| a == "--quick")
            || std::env::var("NMBKM_BENCH_QUICK").ok().as_deref() == Some("1");
        if quick {
            Self::quick()
        } else {
            Self::standard()
        }
    }
}

/// A set of related benchmarks printed as one table.
pub struct BenchSet {
    pub title: String,
    pub opts: BenchOpts,
    pub results: Vec<Measurement>,
}

impl BenchSet {
    pub fn new(title: &str, opts: BenchOpts) -> Self {
        println!("== {title} ==");
        Self { title: title.to_string(), opts, results: vec![] }
    }

    /// Time `f` (warmup + samples); prints and records.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Measurement {
        for _ in 0..self.opts.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.opts.samples);
        for _ in 0..self.opts.samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let m = Measurement { name: name.to_string(), samples };
        println!(
            "  {:<42} min {:>9.4}s  median {:>9.4}s  mean {:>9.4}s",
            m.name,
            m.min_secs(),
            m.median_secs(),
            m.mean_secs()
        );
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Record an externally measured value (e.g. a full run's work time).
    pub fn record(&mut self, name: &str, secs: f64) {
        println!("  {name:<42} {secs:>9.4}s");
        self.results.push(Measurement { name: name.to_string(), samples: vec![secs] });
    }

    pub fn get(&self, name: &str) -> Option<&Measurement> {
        self.results.iter().find(|m| m.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut set = BenchSet::new("t", BenchOpts { warmup: 1, samples: 4 });
        let mut calls = 0;
        set.bench("noop", || {
            calls += 1;
        });
        assert_eq!(calls, 5); // warmup + samples
        let m = set.get("noop").unwrap();
        assert_eq!(m.samples.len(), 4);
        assert!(m.min_secs() <= m.median_secs());
        assert!(m.median_secs() >= 0.0);
    }

    #[test]
    fn quick_mode_from_args() {
        let o = BenchOpts::from_env_or_args(&["--quick".to_string()]);
        assert_eq!(o.samples, BenchOpts::quick().samples);
        let o = BenchOpts::from_env_or_args(&[]);
        assert_eq!(o.samples, BenchOpts::standard().samples);
    }

    #[test]
    fn record_external() {
        let mut set = BenchSet::new("t", BenchOpts::quick());
        set.record("runtime", 1.25);
        assert_eq!(set.get("runtime").unwrap().median_secs(), 1.25);
    }
}
