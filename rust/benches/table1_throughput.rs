//! Bench T1 — regenerates the paper's Table 1 (mb implementation
//! throughput: seconds to process N datapoints, dense + sparse).
//!
//! Paper rows: our 12.4s vs sklearn 20.6s (infMNIST); our 15.2s vs
//! sklearn 63.6s vs sofia 23.3s (RCV1). Offline substitution: the
//! Alg-8 S/v formulation ("our") vs the Alg-1 per-sample formulation
//! (what sklearn/sofia structurally do), plus the XLA dense path.
//! Expected shape: alg8 ≤ alg1 everywhere, with the largest gap on the
//! sparse dataset. Run with `--full` / NMBKM_BENCH_FULL=1 for paper
//! scale.

use nmbkm::experiments::{common::ExpOpts, table1};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = ExpOpts::from_args(&args);
    println!(
        "[table1] scale={:?} threads={} (use --full for paper scale)",
        opts.scale, opts.threads
    );
    table1::run(&opts).expect("table1 failed");
}
