//! Elkan's exact accelerated k-means (Elkan 2003; paper §2.2).
//!
//! Produces *identical* assignments to Lloyd each round (integration
//! test `elkan_equals_lloyd`) while eliminating most distance
//! computations via three devices:
//!
//! * per-point upper bound `u(i) ≥ ‖x_i − c_{a(i)}‖`, decayed by
//!   `p(a(i))` after each centroid update;
//! * per-(point, centroid) lower bounds `l(i,j)`, decayed by `p(j)`;
//! * inter-centroid distances: if `u(i) ≤ ½·min_{j≠a} ‖c_a − c_j‖`, the
//!   point cannot change assignment and is skipped outright.
//!
//! This is the baseline family the paper borrows bounds from; comparing
//! its distance-calculation counts against `tb-ρ` quantifies what
//! nesting buys in the mini-batch regime.

use crate::coordinator::shard::chunk_ranges;
use crate::kmeans::state::{Assignments, Centroids, SuffStats, UNASSIGNED};
use crate::kmeans::{Clusterer, Ctx, RoundInfo};
use crate::linalg::{neighbours, simd};

pub struct Elkan {
    cent: Centroids,
    stats: SuffStats,
    assign: Assignments,
    /// u(i): upper bound on distance to assigned centroid.
    upper: Vec<f32>,
    /// l(i,j) lower bounds, n × k row-major.
    lb: Vec<f32>,
    n: usize,
    first_done: bool,
    fixed_point: bool,
}

impl Elkan {
    pub fn new(cent: Centroids, n: usize) -> Self {
        let k = cent.k();
        let d = cent.d();
        Self {
            cent,
            stats: SuffStats::zeros(k, d),
            assign: Assignments::new(n),
            upper: vec![f32::INFINITY; n],
            lb: vec![0.0; n * k],
            n,
            first_done: false,
            fixed_point: false,
        }
    }

    /// ½·inter-centroid distances and s(j) = ½ min_{j'≠j} ‖c_j − c_j'‖.
    /// Runs the SIMD diff-square kernel the exponion neighbour builder
    /// uses — the k²/2 pair distances were the scalar hot spot of every
    /// Elkan round at serving-scale k.
    fn half_cc(&self) -> (Vec<f32>, Vec<f32>) {
        let k = self.cent.k();
        let t = simd::tier();
        let mut diff = vec![0f32; self.cent.d()];
        let mut half = vec![0f32; k * k];
        let mut s = vec![f32::INFINITY; k];
        for j in 0..k {
            for j2 in (j + 1)..k {
                let dist = neighbours::diff_sq(
                    t,
                    self.cent.c.row(j),
                    self.cent.c.row(j2),
                    &mut diff,
                )
                .sqrt() as f32;
                half[j * k + j2] = 0.5 * dist;
                half[j2 * k + j] = 0.5 * dist;
                s[j] = s[j].min(0.5 * dist);
                s[j2] = s[j2].min(0.5 * dist);
            }
        }
        (half, s)
    }
}

struct ShardOut {
    delta: SuffStats,
    changed: u64,
    calcs: u64,
    skips: u64,
    sum_u2: f64,
}

impl Clusterer for Elkan {
    fn round(&mut self, ctx: &mut Ctx) -> RoundInfo {
        let k = self.cent.k();
        let d = self.cent.d();
        let data = ctx.data;

        if !self.first_done {
            // first pass: exact distances everywhere, bounds installed
            let ranges = chunk_ranges(self.n, ctx.pool.threads, 256);
            let mut lb_rest: &mut [f32] = &mut self.lb;
            let mut lbl_rest: &mut [u32] = &mut self.assign.label;
            let mut up_rest: &mut [f32] = &mut self.upper;
            let mut jobs = Vec::new();
            for r in ranges.iter().cloned() {
                let (bh, bt) = lb_rest.split_at_mut(r.len() * k);
                let (lh, lt) = lbl_rest.split_at_mut(r.len());
                let (uh, ut) = up_rest.split_at_mut(r.len());
                lb_rest = bt;
                lbl_rest = lt;
                up_rest = ut;
                jobs.push((r, bh, lh, uh));
            }
            let cent = &self.cent;
            let work = |r: std::ops::Range<usize>,
                        bh: &mut [f32],
                        lh: &mut [u32],
                        uh: &mut [f32]|
             -> (SuffStats, f64) {
                let mut delta = SuffStats::zeros(k, d);
                let mut sum = 0f64;
                for (slot, i) in r.enumerate() {
                    let out = crate::kmeans::bounds::full_assign_fill(
                        data,
                        i,
                        cent,
                        &mut bh[slot * k..(slot + 1) * k],
                    );
                    delta.add_point(data, i, out.label, out.d2);
                    lh[slot] = out.label;
                    uh[slot] = out.d2.sqrt();
                    sum += out.d2 as f64;
                }
                (delta, sum)
            };
            let parts: Vec<(SuffStats, f64)> = ctx
                .pool
                .run_jobs(jobs, |_, (r, bh, lh, uh)| work(r, bh, lh, uh));
            let mut sum_d2 = 0f64;
            for (p, s) in parts {
                crate::coordinator::merge::Mergeable::merge(&mut self.stats, p);
                sum_d2 += s;
            }
            // decay for next round happens against the update we do now
            self.stats.update_centroids(&mut self.cent);
            self.decay_bounds();
            self.first_done = true;
            return RoundInfo {
                dist_calcs: (self.n * k) as u64,
                bound_skips: 0,
                changed: self.n as u64,
                batch: self.n,
                train_mse: sum_d2 / self.n as f64,
            };
        }

        let (half, s) = self.half_cc();
        let ranges = chunk_ranges(self.n, ctx.pool.threads, 256);
        let mut lb_rest: &mut [f32] = &mut self.lb;
        let mut lbl_rest: &mut [u32] = &mut self.assign.label;
        let mut up_rest: &mut [f32] = &mut self.upper;
        let mut jobs = Vec::new();
        for r in ranges.iter().cloned() {
            let (bh, bt) = lb_rest.split_at_mut(r.len() * k);
            let (lh, lt) = lbl_rest.split_at_mut(r.len());
            let (uh, ut) = up_rest.split_at_mut(r.len());
            lb_rest = bt;
            lbl_rest = lt;
            up_rest = ut;
            jobs.push((r, bh, lh, uh));
        }
        let cent = &self.cent;
        let half_ref = &half;
        let s_ref = &s;
        let work = |r: std::ops::Range<usize>,
                    bh: &mut [f32],
                    lh: &mut [u32],
                    uh: &mut [f32]|
         -> ShardOut {
            let mut out = ShardOut {
                delta: SuffStats::zeros(k, d),
                changed: 0,
                calcs: 0,
                skips: 0,
                sum_u2: 0.0,
            };
            for (slot, i) in r.enumerate() {
                let lbrow = &mut bh[slot * k..(slot + 1) * k];
                let mut a = lh[slot] as usize;
                let a_old = a as u32;
                let mut u = uh[slot];
                // global skip: cannot change assignment at all
                if u <= s_ref[a] {
                    out.skips += (k - 1) as u64;
                    out.sum_u2 += (u * u) as f64;
                    continue;
                }
                let mut tight = false;
                for j in 0..k {
                    if j == a {
                        continue;
                    }
                    let gate = lbrow[j].max(half_ref[a * k + j]);
                    if u <= gate {
                        out.skips += 1;
                        continue;
                    }
                    if !tight {
                        // tighten the upper bound once
                        let d2 = data
                            .sq_dist_to(i, cent.c.row(a), cent.norms[a]);
                        u = d2.sqrt();
                        lbrow[a] = u;
                        out.calcs += 1;
                        tight = true;
                        if u <= gate {
                            continue;
                        }
                    }
                    let dj2 =
                        data.sq_dist_to(i, cent.c.row(j), cent.norms[j]);
                    let dj = dj2.sqrt();
                    lbrow[j] = dj;
                    out.calcs += 1;
                    if dj < u {
                        a = j;
                        u = dj;
                        // u is exact for the new assignment
                    }
                }
                if a as u32 != a_old {
                    out.delta.reassign_point(data, i, a_old, a as u32, u * u);
                    out.changed += 1;
                }
                lh[slot] = a as u32;
                uh[slot] = u;
                out.sum_u2 += (u * u) as f64;
            }
            out
        };
        let parts: Vec<ShardOut> = ctx
            .pool
            .run_jobs(jobs, |_, (r, bh, lh, uh)| work(r, bh, lh, uh));
        let mut changed = 0u64;
        let mut calcs = 0u64;
        let mut skips = 0u64;
        let mut sum_u2 = 0f64;
        for p in parts {
            crate::coordinator::merge::Mergeable::merge(&mut self.stats, p.delta);
            changed += p.changed;
            calcs += p.calcs;
            skips += p.skips;
            sum_u2 += p.sum_u2;
        }
        self.stats.update_centroids(&mut self.cent);
        self.decay_bounds();
        self.fixed_point = changed == 0;
        RoundInfo {
            dist_calcs: calcs,
            bound_skips: skips,
            changed,
            batch: self.n,
            // u(i) is an upper bound; exact right after a tightening —
            // close enough for the progress log (quality numbers come
            // from the validation protocol)
            train_mse: sum_u2 / self.n as f64,
        }
    }

    fn centroids(&self) -> &Centroids {
        &self.cent
    }

    fn converged(&self) -> bool {
        self.fixed_point
    }

    fn name(&self) -> String {
        "elkan".into()
    }
}

impl Elkan {
    /// Post-update bound maintenance: `l(i,j) ← l(i,j) − p(j)`,
    /// `u(i) ← u(i) + p(a(i))`.
    fn decay_bounds(&mut self) {
        let k = self.cent.k();
        let p = &self.cent.p;
        if self.cent.max_p() == 0.0 {
            return;
        }
        for i in 0..self.n {
            let row = &mut self.lb[i * k..(i + 1) * k];
            for j in 0..k {
                row[j] -= p[j];
            }
            let a = self.assign.label[i];
            if a != UNASSIGNED {
                self.upper[i] += p[a as usize];
            }
        }
    }
}

#[cfg(test)]
mod tests {

    use crate::config::{Algo, RunConfig};
    use crate::data::gaussian::GaussianMixture;
    use crate::kmeans::run;

    #[test]
    fn elkan_equals_lloyd_trajectory() {
        let data = GaussianMixture::default_spec(5, 7).generate(700, 4);
        let mk = |algo| RunConfig {
            algo,
            k: 5,
            max_rounds: 12,
            max_seconds: 60.0,
            seed: 9,
            threads: 3,
            stop_on_convergence: false,
            ..Default::default()
        };
        let l = run(&data, None, &mk(Algo::Lloyd)).unwrap();
        let e = run(&data, None, &mk(Algo::Elkan)).unwrap();
        for j in 0..5 {
            for t in 0..7 {
                let a = l.centroids.c.row(j)[t];
                let b = e.centroids.c.row(j)[t];
                assert!(
                    (a - b).abs() <= 2e-3 * (1.0 + a.abs()),
                    "centroid {j},{t}: lloyd={a} elkan={b}"
                );
            }
        }
    }

    #[test]
    fn elkan_skips_most_distance_calcs() {
        let data = GaussianMixture::default_spec(8, 10).generate(1500, 2);
        let cfg = RunConfig {
            algo: Algo::Elkan,
            k: 8,
            max_rounds: 15,
            max_seconds: 60.0,
            seed: 1,
            threads: 2,
            stop_on_convergence: true,
            ..Default::default()
        };
        let out = run(&data, None, &cfg).unwrap();
        // after the first full pass, later rounds should do far fewer
        // than n·k computations
        let later: Vec<u64> = out
            .trace
            .records
            .iter()
            .skip(2)
            .map(|r| r.dist_calcs)
            .collect();
        let full = (1500 * 8) as u64;
        assert!(!later.is_empty());
        let mean = later.iter().sum::<u64>() as f64 / later.len() as f64;
        assert!(
            mean < full as f64 * 0.5,
            "elkan mean calcs {mean} vs full pass {full}"
        );
    }

    #[test]
    fn converges_like_lloyd() {
        let data = GaussianMixture::default_spec(3, 4).generate(300, 8);
        let cfg = RunConfig {
            algo: Algo::Elkan,
            k: 3,
            max_rounds: 300,
            max_seconds: 60.0,
            seed: 5,
            threads: 1,
            ..Default::default()
        };
        let out = run(&data, None, &cfg).unwrap();
        assert_eq!(out.trace.records.last().unwrap().changed, 0);
    }
}
