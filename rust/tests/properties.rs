//! Randomised end-to-end property tests over the full stack
//! (hand-rolled harness, DESIGN.md §Testing strategy).

use nmbkm::config::{Algo, Rho, RunConfig};
use nmbkm::data::gaussian::GaussianMixture;
use nmbkm::kmeans::run;
use nmbkm::util::propcheck::Cases;
use nmbkm::util::rng::Pcg64;

fn random_cfg(rng: &mut Pcg64, k: usize) -> RunConfig {
    let algos = [
        Algo::Lloyd,
        Algo::Elkan,
        Algo::Sgd,
        Algo::Mb,
        Algo::MbF,
        Algo::GbRho,
        Algo::TbRho,
    ];
    let rhos = [
        Rho::Finite(1.0),
        Rho::Finite(10.0),
        Rho::Finite(1000.0),
        Rho::Infinite,
    ];
    RunConfig {
        algo: algos[rng.below(algos.len())],
        rho: rhos[rng.below(rhos.len())],
        k,
        b0: 16 + rng.below(200),
        threads: 1 + rng.below(4),
        seed: rng.next_u64(),
        max_rounds: 3 + rng.below(12),
        max_seconds: 30.0,
        eval_every_secs: 0.0,
        stop_on_convergence: rng.next_f64() < 0.5,
        ..Default::default()
    }
}

#[test]
fn any_config_any_shape_terminates_with_finite_state() {
    Cases::new(30).run(|rng| {
        let k = 2 + rng.below(8);
        let n = k * 4 + rng.below(600);
        let d = 2 + rng.below(24);
        let spec = GaussianMixture {
            k,
            d,
            center_spread: 10f64.powf(rng.range_f64(-0.5, 1.2)),
            noise: 10f64.powf(rng.range_f64(-1.0, 0.5)),
            weights: vec![],
        };
        let data = spec.generate(n, rng.next_u64());
        let cfg = random_cfg(rng, k);
        let out = run(&data, None, &cfg)
            .unwrap_or_else(|e| panic!("{cfg:?} failed: {e:#}"));
        // invariants on any run whatsoever:
        assert!(out.rounds >= 1 && out.rounds <= cfg.max_rounds);
        assert!(out.centroids.c.data.iter().all(|x| x.is_finite()),
                "{cfg:?}: non-finite centroid");
        assert!(out.final_mse.is_finite() && out.final_mse >= 0.0);
        // batches never exceed n and never shrink for gb/tb
        if matches!(cfg.algo, Algo::GbRho | Algo::TbRho) {
            let batches: Vec<usize> =
                out.trace.records.iter().map(|r| r.batch).collect();
            for w in batches.windows(2) {
                assert!(w[1] >= w[0], "batch shrank: {batches:?}");
                assert!(w[1] <= n);
            }
        }
    });
}

#[test]
fn quality_never_catastrophically_worse_than_lloyd() {
    // any algorithm given a decent budget should land within a factor
    // of lloyd's local minimum on an easy, well-separated mixture
    Cases::new(8).run(|rng| {
        let k = 3 + rng.below(4);
        let spec = GaussianMixture {
            k,
            d: 8,
            center_spread: 25.0,
            noise: 1.0,
            weights: vec![],
        };
        let data = spec.generate(1_200, rng.next_u64());
        let seed = rng.next_u64();
        let mk = |algo| RunConfig {
            algo,
            k,
            b0: 128,
            rho: Rho::Infinite,
            seed,
            threads: 2,
            max_rounds: 60,
            max_seconds: 10.0,
            eval_every_secs: 0.0,
            ..Default::default()
        };
        let lloyd = run(&data, None, &mk(Algo::Lloyd)).unwrap();
        for algo in [Algo::MbF, Algo::GbRho, Algo::TbRho] {
            let out = run(&data, None, &mk(algo)).unwrap();
            let base = nmbkm::kmeans::state::exact_mse(&data, &lloyd.centroids);
            let got = nmbkm::kmeans::state::exact_mse(&data, &out.centroids);
            assert!(
                got <= base * 3.0 + 1e-9,
                "{algo:?}: mse {got} vs lloyd {base}"
            );
        }
    });
}

#[test]
fn determinism_full_stack() {
    Cases::new(10).run(|rng| {
        let k = 2 + rng.below(5);
        let data = GaussianMixture::default_spec(k, 6)
            .generate(300 + rng.below(300), rng.next_u64());
        let cfg = random_cfg(rng, k);
        let a = run(&data, None, &cfg).unwrap();
        let b = run(&data, None, &cfg).unwrap();
        assert_eq!(a.rounds, b.rounds, "{cfg:?}");
        assert_eq!(a.centroids.c.data, b.centroids.c.data, "{cfg:?}");
    });
}
