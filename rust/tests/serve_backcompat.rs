//! Protocol back-compat regression: a transcript of PR 1-era requests —
//! no `model` field, dense `point` arrays only — replayed against the
//! overhauled server must produce **byte-identical** responses to the
//! documented v1 layout. The expected bytes are assembled independently
//! of the protocol layer, from a twin session driven through the same
//! operations in-process, so a renamed field, a new field, a reordered
//! key or a changed float rendering on the legacy route fails here
//! before any old client sees it.
//!
//! (`stats` is the one response carrying a wall-clock field,
//! `work_secs`; it is compared with that single field neutralised and
//! every other field byte-pinned.)

use nmbkm::config::{Algo, Rho, RunConfig};
use nmbkm::data::gaussian::GaussianMixture;
use nmbkm::data::Data;
use nmbkm::serve::wire::dense_points_json;
use nmbkm::serve::{protocol, session, ModelRegistry, OnlineSession, Snapshot};
use nmbkm::util::json::{self, Json};
use std::path::Path;

fn cfg() -> RunConfig {
    RunConfig {
        algo: Algo::TbRho,
        k: 4,
        b0: 64,
        rho: Rho::Infinite,
        threads: 2,
        seed: 31,
        max_rounds: 4,
        max_seconds: 60.0,
        eval_every_secs: 0.0,
        ..Default::default()
    }
}

fn rows_of(data: &Data, lo: usize, hi: usize) -> Vec<Vec<f32>> {
    let mut out = Vec::with_capacity(hi - lo);
    let mut row = vec![0f32; data.dim()];
    for i in lo..hi {
        data.write_row_dense(i, &mut row);
        out.push(row.clone());
    }
    out
}

/// The v1 predict response layout, assembled field by field.
fn v1_predict(lbl: &[u32], d2: &[f32]) -> String {
    json::obj(vec![
        ("ok", Json::Bool(true)),
        ("op", json::s("predict")),
        ("model", json::s("default")),
        (
            "labels",
            Json::Arr(lbl.iter().map(|&j| json::num(j as f64)).collect()),
        ),
        (
            "d2",
            Json::Arr(d2.iter().map(|&x| json::num(x as f64)).collect()),
        ),
    ])
    .to_string()
}

#[test]
fn v1_dense_jsonl_transcript_replays_byte_identically() {
    let data = GaussianMixture::default_spec(4, 5).generate(600, 8);
    // served session and its twin: same data, same config, fully
    // deterministic — the twin supplies the expected response values
    let (served, _) = session::train(&data.slice(0, 500), &cfg()).unwrap();
    let (mut twin, _) = session::train(&data.slice(0, 500), &cfg()).unwrap();

    let fresh = rows_of(&data, 500, 502);
    let queries = rows_of(&data, 100, 103);
    let transcript = [
        r#"{"op":"stats"}"#.to_string(),
        format!(
            "{{\"op\":\"ingest\",\"points\":{},\"rounds\":1}}",
            dense_points_json(&fresh)
        ),
        format!("{{\"op\":\"predict\",\"points\":{}}}", dense_points_json(&queries)),
        r#"{"op":"step","rounds":2}"#.to_string(),
        format!("{{\"op\":\"predict\",\"points\":{}}}", dense_points_json(&queries)),
        r#"{"op":"transmogrify"}"#.to_string(),
        r#"{"op":"shutdown"}"#.to_string(),
    ];

    // expected responses, in v1 layout, from the twin's trajectory
    let mut expected: Vec<Option<String>> = Vec::new();
    // [0] stats — wall-clock field neutralised below, shape pinned here
    let mut stats = twin.stats_json();
    if let Json::Obj(m) = &mut stats {
        m.insert("ok".to_string(), Json::Bool(true));
        m.insert("op".to_string(), json::s("stats"));
        m.insert("model".to_string(), json::s("default"));
    }
    expected.push(None); // compared structurally, not byte-wise
    // [1] ingest: append 2 rows, one training round
    let n = twin.ingest_rows(&fresh).unwrap();
    let rep = twin.step(1, f64::INFINITY).unwrap();
    let info = rep.last.expect("initialised session always steps");
    expected.push(Some(
        json::obj(vec![
            ("ok", Json::Bool(true)),
            ("op", json::s("ingest")),
            ("model", json::s("default")),
            ("added", json::num(2.0)),
            ("n", json::num(n as f64)),
            ("rounds_run", json::num(rep.rounds_run as f64)),
            ("initialised", Json::Bool(true)),
            ("batch", json::num(info.batch as f64)),
            ("train_mse", json::num(info.train_mse)),
        ])
        .to_string(),
    ));
    // [2] predict
    let (lbl, d2) = twin.predict_rows(&queries).unwrap();
    expected.push(Some(v1_predict(&lbl, &d2)));
    // [3] step ×2
    let rep = twin.step(2, f64::INFINITY).unwrap();
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("op", json::s("step")),
        ("model", json::s("default")),
        ("rounds_run", json::num(rep.rounds_run as f64)),
        ("converged", Json::Bool(rep.converged)),
        ("waiting_for_points", Json::Bool(rep.waiting_for_points)),
    ];
    if let Some(info) = rep.last {
        fields.push(("batch", json::num(info.batch as f64)));
        fields.push(("train_mse", json::num(info.train_mse)));
    }
    expected.push(Some(json::obj(fields).to_string()));
    // [4] predict against the stepped model
    let (lbl, d2) = twin.predict_rows(&queries).unwrap();
    expected.push(Some(v1_predict(&lbl, &d2)));
    // [5] unknown op: the exact v1 error envelope and text
    expected.push(Some(
        json::obj(vec![
            ("ok", Json::Bool(false)),
            (
                "error",
                json::s(
                    "unknown op 'transmogrify' (create|list|drop|ingest|\
                     predict|step|stats|snapshot|shutdown)",
                ),
            ),
        ])
        .to_string(),
    ));
    // [6] shutdown
    expected.push(Some(
        json::obj(vec![
            ("ok", Json::Bool(true)),
            ("op", json::s("shutdown")),
        ])
        .to_string(),
    ));

    // replay the whole transcript against the served registry
    let reg = ModelRegistry::with_default(served);
    let input = transcript.join("\n") + "\n";
    let mut out = Vec::new();
    let shutdown =
        protocol::serve_lines(&reg, std::io::Cursor::new(input), &mut out)
            .unwrap();
    assert!(shutdown, "transcript ends with an explicit shutdown");
    let served_lines: Vec<String> = String::from_utf8(out)
        .unwrap()
        .trim()
        .lines()
        .map(|l| l.to_string())
        .collect();
    assert_eq!(served_lines.len(), expected.len());

    // stats: every byte pinned except the wall-clock work_secs
    let neutralise = |v: &Json| -> Json {
        let mut v = v.clone();
        if let Json::Obj(m) = &mut v {
            m.insert("work_secs".to_string(), json::num(0.0));
        }
        v
    };
    let served_stats = Json::parse(&served_lines[0]).unwrap();
    assert!(
        served_lines[0].contains("\"work_secs\":"),
        "{}",
        served_lines[0]
    );
    assert_eq!(
        neutralise(&served_stats).to_string(),
        neutralise(&stats).to_string(),
        "v1 stats response changed shape"
    );

    // everything else: byte-identical to the v1 layout
    for (t, exp) in expected.iter().enumerate() {
        if let Some(exp) = exp {
            assert_eq!(
                &served_lines[t], exp,
                "transcript line {t} diverged from the v1 bytes"
            );
        }
    }
}

/// The committed golden corpus: one artifact per on-disk snapshot
/// format, written when that format was frozen (see
/// `tests/data/gen_golden.py`, which documents the model inside them
/// and regenerates the bytes). Every future build must keep decoding
/// both files to the identical state and answering pinned predict
/// queries bit-for-bit — a deliberate format break has to regenerate
/// the corpus, so the break is explicit in review instead of silently
/// orphaning old artifacts.
#[test]
fn golden_snapshot_corpus_stays_loadable_and_pinned() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/data");
    let from_json =
        Snapshot::load(&dir.join("golden-snapshot-v1.json")).unwrap();
    let from_bin =
        Snapshot::load(&dir.join("golden-snapshot-v2.bin")).unwrap();
    // both formats carry the same model and must decode to one state
    assert_eq!(
        from_json.to_json().to_string(),
        from_bin.to_json().to_string(),
        "JSON and binary goldens decoded to different states"
    );
    for (tag, snap) in [("v1-json", from_json), ("v2-binary", from_bin)] {
        // pinned geometry: k=2 centroids at (0,1) and (4,1)
        let cent = snap.centroids();
        assert_eq!(cent.k(), 2, "{tag}");
        assert_eq!(cent.d(), 2, "{tag}");
        let mut sess = OnlineSession::resume(snap).unwrap();
        let queries = vec![
            vec![0.0f32, 0.0],
            vec![0.5, 1.0],
            vec![3.0, 1.0],
            vec![4.0, 2.0],
        ];
        let (labels, d2) = sess.predict_rows(&queries).unwrap();
        assert_eq!(labels, vec![0u32, 0, 1, 1], "{tag}: labels moved");
        // every quantity here is exactly representable in f32, so the
        // distances are pinned to the bit regardless of engine order
        let want = [1.0f32, 0.25, 1.0, 1.0];
        assert_eq!(
            d2.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "{tag}: predict distances moved"
        );
        // the golden artifact is a live model, not a husk: it resumes
        // training from its data section
        let rep = sess.step(1, f64::INFINITY).unwrap();
        assert!(!rep.waiting_for_points, "{tag}: resumed session is stuck");
    }
}
