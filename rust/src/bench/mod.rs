//! Benchmark harness (criterion is unavailable offline).
//!
//! [`BenchSet`] runs named closures with warmup, multiple samples, and
//! reports min/median/mean — enough statistical hygiene for the paper's
//! throughput tables. `cargo bench` targets under `rust/benches/` are
//! `harness = false` binaries built on this. [`BenchReport`] collects
//! finished sets plus free-form metadata and serialises everything to a
//! machine-readable JSON document (`BENCH_micro.json` et al.), so the
//! perf trajectory is tracked per-commit instead of scraped from logs.

use crate::util::json::{self, Json};
use crate::util::stats;
use std::time::Instant;

/// One measured result.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<f64>,
}

impl Measurement {
    pub fn median_secs(&self) -> f64 {
        stats::median(&self.samples)
    }

    pub fn mean_secs(&self) -> f64 {
        stats::mean(&self.samples)
    }

    pub fn min_secs(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("name", json::s(&self.name)),
            ("min_s", json::num(self.min_secs())),
            ("median_s", json::num(self.median_secs())),
            ("mean_s", json::num(self.mean_secs())),
            (
                "samples_s",
                Json::Arr(self.samples.iter().map(|&s| json::num(s)).collect()),
            ),
        ])
    }
}

/// Bench runner configuration; `quick()` keeps CI latency sane and is
/// selected by the `--quick` flag or `NMBKM_BENCH_QUICK=1`.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    pub warmup: usize,
    pub samples: usize,
}

impl BenchOpts {
    pub fn standard() -> Self {
        Self { warmup: 2, samples: 7 }
    }

    pub fn quick() -> Self {
        Self { warmup: 1, samples: 3 }
    }

    /// One iteration, no warmup: CI smoke mode — proves the bench (and
    /// every dispatch path it touches) still runs, without the latency.
    pub fn smoke() -> Self {
        Self { warmup: 0, samples: 1 }
    }

    pub fn from_env_or_args(args: &[String]) -> Self {
        let smoke = args.iter().any(|a| a == "--smoke")
            || std::env::var("NMBKM_BENCH_SMOKE").ok().as_deref() == Some("1");
        if smoke {
            return Self::smoke();
        }
        let quick = args.iter().any(|a| a == "--quick")
            || std::env::var("NMBKM_BENCH_QUICK").ok().as_deref() == Some("1");
        if quick {
            Self::quick()
        } else {
            Self::standard()
        }
    }
}

/// A set of related benchmarks printed as one table.
pub struct BenchSet {
    pub title: String,
    pub opts: BenchOpts,
    pub results: Vec<Measurement>,
}

impl BenchSet {
    pub fn new(title: &str, opts: BenchOpts) -> Self {
        println!("== {title} ==");
        Self { title: title.to_string(), opts, results: vec![] }
    }

    /// Time `f` (warmup + samples); prints and records.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Measurement {
        for _ in 0..self.opts.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.opts.samples);
        for _ in 0..self.opts.samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let m = Measurement { name: name.to_string(), samples };
        println!(
            "  {:<42} min {:>9.4}s  median {:>9.4}s  mean {:>9.4}s",
            m.name,
            m.min_secs(),
            m.median_secs(),
            m.mean_secs()
        );
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Record an externally measured value (e.g. a full run's work time).
    pub fn record(&mut self, name: &str, secs: f64) {
        println!("  {name:<42} {secs:>9.4}s");
        self.results.push(Measurement { name: name.to_string(), samples: vec![secs] });
    }

    pub fn get(&self, name: &str) -> Option<&Measurement> {
        self.results.iter().find(|m| m.name == name)
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("title", json::s(&self.title)),
            (
                "results",
                Json::Arr(self.results.iter().map(|m| m.to_json()).collect()),
            ),
        ])
    }
}

/// A finished benchmark run ready for serialisation: every [`BenchSet`]
/// plus free-form metadata (dispatch tier, thread count, derived
/// speedups). Written as one JSON document so successive commits'
/// `BENCH_micro.json` files diff cleanly.
pub struct BenchReport {
    pub bench: String,
    meta: Vec<(String, Json)>,
    sets: Vec<BenchSet>,
}

impl BenchReport {
    pub fn new(bench: &str) -> Self {
        Self { bench: bench.to_string(), meta: vec![], sets: vec![] }
    }

    /// Attach a metadata key (last write wins on serialisation since
    /// the object map is keyed).
    pub fn meta(&mut self, key: &str, v: Json) {
        self.meta.push((key.to_string(), v));
    }

    /// Take ownership of a finished set.
    pub fn push(&mut self, set: BenchSet) {
        self.sets.push(set);
    }

    /// Min-of-samples seconds for `(set_title, measurement_name)`.
    pub fn min_secs(&self, set_title: &str, name: &str) -> Option<f64> {
        self.sets
            .iter()
            .find(|s| s.title == set_title)
            .and_then(|s| s.get(name))
            .map(|m| m.min_secs())
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("bench", json::s(&self.bench)),
            ("schema", json::num(1.0)),
            (
                "meta",
                Json::Obj(
                    self.meta
                        .iter()
                        .map(|(k, v)| (k.clone(), v.clone()))
                        .collect(),
                ),
            ),
            (
                "sets",
                Json::Arr(self.sets.iter().map(|s| s.to_json()).collect()),
            ),
        ])
    }

    /// Serialise to `path` (single line + trailing newline).
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        let mut doc = self.to_json().to_string();
        doc.push('\n');
        std::fs::write(path, doc)?;
        println!("wrote {path}");
        Ok(())
    }
}

/// One `(set, measurement)` pair present in both of two serialised
/// bench reports — the unit of the CI trend check.
#[derive(Clone, Debug, PartialEq)]
pub struct TrendRow {
    pub set: String,
    pub name: String,
    pub base_median_s: f64,
    pub cur_median_s: f64,
    /// Sample count behind the baseline median. Single-sample medians
    /// (smoke runs) carry too much noise to gate on — callers should
    /// treat those rows as informational.
    pub base_samples: usize,
}

impl TrendRow {
    /// `current / baseline` median ratio (> 1 means slower).
    pub fn ratio(&self) -> f64 {
        if self.base_median_s <= 0.0 {
            // degenerate baselines (zero-duration smoke samples) carry
            // no signal; report parity instead of inf
            return 1.0;
        }
        self.cur_median_s / self.base_median_s
    }

    /// Whether the baseline has enough samples for its median to be a
    /// regression gate rather than a single noisy timing.
    pub fn gateable(&self) -> bool {
        self.base_samples >= 2
    }
}

/// Pair up the measurements two serialised [`BenchReport`] documents
/// share, by `(set title, measurement name)`. Measurements present in
/// only one report are skipped — bench sets come and go across commits
/// and their appearance is not a regression. Errors only on documents
/// that are not bench reports at all.
pub fn compare_reports(
    baseline: &Json,
    current: &Json,
) -> Result<Vec<TrendRow>, String> {
    let base = report_medians(baseline, "baseline")?;
    let cur = report_medians(current, "current")?;
    let mut rows = Vec::new();
    for (key, (base_median, base_samples)) in &base {
        if let Some((cur_median, _)) = cur.get(key) {
            rows.push(TrendRow {
                set: key.0.clone(),
                name: key.1.clone(),
                base_median_s: *base_median,
                cur_median_s: *cur_median,
                base_samples: *base_samples,
            });
        }
    }
    Ok(rows)
}

/// `(set title, measurement name) → (median_s, sample count)` of one
/// serialised report.
#[allow(clippy::type_complexity)]
fn report_medians(
    doc: &Json,
    tag: &str,
) -> Result<std::collections::BTreeMap<(String, String), (f64, usize)>, String> {
    let sets = doc
        .get("sets")
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("{tag}: not a bench report (no 'sets' array)"))?;
    let mut out = std::collections::BTreeMap::new();
    for set in sets {
        let title = set
            .get("title")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("{tag}: set without a 'title'"))?;
        let results = set
            .get("results")
            .and_then(Json::as_arr)
            .ok_or_else(|| format!("{tag}: set '{title}' has no 'results'"))?;
        for m in results {
            let name = m
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("{tag}: measurement without 'name'"))?;
            let median = m
                .get("median_s")
                .and_then(Json::as_f64)
                .ok_or_else(|| {
                    format!("{tag}: '{title}/{name}' has no numeric median_s")
                })?;
            let samples = m
                .get("samples_s")
                .and_then(Json::as_arr)
                .map(|a| a.len())
                .unwrap_or(1);
            out.insert((title.to_string(), name.to_string()), (median, samples));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut set = BenchSet::new("t", BenchOpts { warmup: 1, samples: 4 });
        let mut calls = 0;
        set.bench("noop", || {
            calls += 1;
        });
        assert_eq!(calls, 5); // warmup + samples
        let m = set.get("noop").unwrap();
        assert_eq!(m.samples.len(), 4);
        assert!(m.min_secs() <= m.median_secs());
        assert!(m.median_secs() >= 0.0);
    }

    #[test]
    fn quick_mode_from_args() {
        let o = BenchOpts::from_env_or_args(&["--quick".to_string()]);
        assert_eq!(o.samples, BenchOpts::quick().samples);
        let o = BenchOpts::from_env_or_args(&[]);
        assert_eq!(o.samples, BenchOpts::standard().samples);
        // smoke wins over quick (CI passes both defensively)
        let o = BenchOpts::from_env_or_args(&[
            "--quick".to_string(),
            "--smoke".to_string(),
        ]);
        assert_eq!(o.samples, 1);
        assert_eq!(o.warmup, 0);
    }

    #[test]
    fn report_roundtrips_as_json() {
        let mut set = BenchSet::new("kernels", BenchOpts::smoke());
        set.bench("dot", || 1 + 1);
        let mut report = BenchReport::new("micro_test");
        report.meta("tier", json::s("scalar"));
        report.meta("threads", json::num(4.0));
        report.push(set);
        assert!(report.min_secs("kernels", "dot").is_some());
        assert!(report.min_secs("kernels", "nope").is_none());
        assert!(report.min_secs("nope", "dot").is_none());
        let doc = report.to_json().to_string();
        let parsed = Json::parse(&doc).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("micro_test"));
        assert_eq!(
            parsed.get("meta").unwrap().get("tier").unwrap().as_str(),
            Some("scalar")
        );
        let sets = parsed.get("sets").unwrap().as_arr().unwrap();
        assert_eq!(sets.len(), 1);
        let results = sets[0].get("results").unwrap().as_arr().unwrap();
        assert_eq!(results[0].get("name").unwrap().as_str(), Some("dot"));
        assert_eq!(
            results[0]
                .get("samples_s")
                .unwrap()
                .as_arr()
                .unwrap()
                .len(),
            1
        );
    }

    #[test]
    fn record_external() {
        let mut set = BenchSet::new("t", BenchOpts::quick());
        set.record("runtime", 1.25);
        assert_eq!(set.get("runtime").unwrap().median_secs(), 1.25);
    }

    fn report_doc(pairs: &[(&str, &str, f64)]) -> Json {
        let mut report = BenchReport::new("trend_test");
        let mut titles: Vec<&str> = pairs.iter().map(|(s, _, _)| *s).collect();
        titles.dedup();
        for title in titles {
            let mut set = BenchSet::new(title, BenchOpts::smoke());
            for (s, name, median) in pairs {
                if s == &title {
                    set.record(name, *median);
                }
            }
            report.push(set);
        }
        Json::parse(&report.to_json().to_string()).unwrap()
    }

    #[test]
    fn trend_compare_pairs_shared_measurements() {
        let base = report_doc(&[
            ("kernels", "dot", 1.0),
            ("kernels", "dot4", 2.0),
            ("gone", "old", 9.0),
        ]);
        let cur = report_doc(&[
            ("kernels", "dot", 1.1),
            ("kernels", "dot4", 1.0),
            ("fresh", "new", 5.0),
        ]);
        let rows = compare_reports(&base, &cur).unwrap();
        assert_eq!(rows.len(), 2, "only shared measurements compare");
        let dot = rows.iter().find(|r| r.name == "dot").unwrap();
        assert!((dot.ratio() - 1.1).abs() < 1e-9);
        let dot4 = rows.iter().find(|r| r.name == "dot4").unwrap();
        assert!((dot4.ratio() - 0.5).abs() < 1e-9);
        // a 10% slowdown trips a 5% gate but not a 20% gate
        assert!(dot.ratio() > 1.05);
        assert!(dot.ratio() <= 1.20);
        // single-sample (record/smoke) baselines are not gateable
        assert_eq!(dot.base_samples, 1);
        assert!(!dot.gateable());
    }

    #[test]
    fn trend_gateable_requires_multi_sample_baseline() {
        let mut set = BenchSet::new("kernels", BenchOpts { warmup: 0, samples: 3 });
        set.bench("dot", || 1 + 1);
        let mut report = BenchReport::new("trend_test");
        report.push(set);
        let multi = Json::parse(&report.to_json().to_string()).unwrap();
        let rows = compare_reports(&multi, &multi).unwrap();
        assert_eq!(rows[0].base_samples, 3);
        assert!(rows[0].gateable());
        assert_eq!(rows[0].ratio(), 1.0);
    }

    #[test]
    fn trend_compare_rejects_non_reports() {
        let bad = Json::parse(r#"{"hello":1}"#).unwrap();
        let good = report_doc(&[("a", "b", 1.0)]);
        assert!(compare_reports(&bad, &good).is_err());
        assert!(compare_reports(&good, &bad).is_err());
        // zero-baseline medians report parity, not infinity
        let zero = report_doc(&[("a", "b", 0.0)]);
        let rows = compare_reports(&zero, &good).unwrap();
        assert_eq!(rows[0].ratio(), 1.0);
    }
}
