//! Ablations beyond the paper's evaluation — its two §5 future-work
//! directions, made concrete:
//!
//! * **Growth policy** (A-G): the double-or-nothing law (Alg. 6) versus
//!   gentler geometric (×1.5), additive (+b0), and a vote-ignoring
//!   always-double schedule, all on tb-∞. Tests the paper's √2 argument
//!   for doubling.
//! * **Initialisation** (A-I): the paper's shuffle-first-k versus
//!   uniform sampling and a *mini-batch-compatible* k-means++ (D²
//!   seeding over the initial batch only — no full pass, addressing the
//!   paper's observation that classic k-means++ is impractical for mb).

use crate::config::{Algo, InitScheme, Rho, RunConfig};
use crate::coordinator::progress::{results_dir, Table};
use crate::data::Dataset;
use crate::experiments::common::{self, ExpOpts};
use crate::kmeans::controller::GrowthPolicy;
use crate::kmeans::{init, Clusterer, Ctx};
use crate::util::stats;

/// A-G: final training MSE + rounds-to-convergence per growth policy.
pub struct GrowthRow {
    pub policy: String,
    pub mean_final: f64,
    pub mean_rounds: f64,
    pub mean_dist_calcs: f64,
}

pub fn growth_policy_study(ds: &Dataset, opts: &ExpOpts) -> Vec<GrowthRow> {
    let b0 = common::default_b0(opts.scale).min(ds.train.n() / 8).max(16);
    let k = 50.min(ds.train.n() / 4).max(2);
    let policies: [(&str, GrowthPolicy); 4] = [
        ("double (paper)", GrowthPolicy::Double),
        ("geometric x1.5", GrowthPolicy::Geometric15),
        ("additive +b0", GrowthPolicy::Additive(b0)),
        ("always-double", GrowthPolicy::AlwaysDouble),
    ];
    let mut rows = Vec::new();
    for (name, policy) in policies {
        let mut finals = Vec::new();
        let mut rounds = Vec::new();
        let mut calcs = Vec::new();
        for seed in 0..opts.seeds {
            let data = crate::data::shuffle::shuffled(&ds.train, seed);
            let mut alg = crate::kmeans::turbobatch::TurboBatch::new(
                init::first_k(&data, k),
                data.n(),
                b0,
                Rho::Infinite,
                false,
            )
            .with_policy(policy);
            let engine = crate::kmeans::assign::NativeEngine::default();
            let mut ctx = Ctx {
                data: &data,
                engine: &engine,
                pool: crate::coordinator::Pool::new(opts.threads),
                rng: crate::util::rng::Pcg64::new(seed, 0xAB1A),
            };
            let mut total_calcs = 0u64;
            let mut r = 0usize;
            let t0 = std::time::Instant::now();
            loop {
                let info = alg.round(&mut ctx);
                total_calcs += info.dist_calcs;
                r += 1;
                if alg.converged()
                    || r >= 400
                    || t0.elapsed().as_secs_f64() > opts.seconds
                {
                    break;
                }
            }
            finals.push(crate::kmeans::state::exact_mse(
                &data,
                alg.centroids(),
            ));
            rounds.push(r as f64);
            calcs.push(total_calcs as f64);
        }
        let row = GrowthRow {
            policy: name.to_string(),
            mean_final: stats::mean(&finals),
            mean_rounds: stats::mean(&rounds),
            mean_dist_calcs: stats::mean(&calcs),
        };
        println!(
            "   {:<16} final MSE {:.6e}  rounds {:>6.1}  dist calcs {:>12.0}",
            row.policy, row.mean_final, row.mean_rounds, row.mean_dist_calcs
        );
        rows.push(row);
    }
    rows
}

/// A-I: final validation MSE per initialisation scheme (tb-∞ and mb).
pub struct InitRow {
    pub algo: String,
    pub scheme: String,
    pub mean_final: f64,
    pub std_final: f64,
}

pub fn init_study(ds: &Dataset, opts: &ExpOpts) -> Vec<InitRow> {
    let k = 50.min(ds.train.n() / 4).max(2);
    let mut rows = Vec::new();
    for algo in [Algo::TbRho, Algo::Mb] {
        for scheme in
            [InitScheme::FirstK, InitScheme::Uniform, InitScheme::KmeansPPBatch]
        {
            let mut finals = Vec::new();
            for seed in 0..opts.seeds {
                let cfg = RunConfig {
                    algo,
                    rho: Rho::Infinite,
                    k,
                    b0: common::default_b0(opts.scale),
                    seed,
                    threads: opts.threads,
                    max_seconds: opts.seconds,
                    eval_every_secs: opts.seconds,
                    init: scheme,
                    ..Default::default()
                };
                let out =
                    crate::kmeans::run(&ds.train, Some(&ds.val), &cfg).unwrap();
                finals.push(out.final_mse);
            }
            let row = InitRow {
                algo: algo.name().to_string(),
                scheme: scheme.name().to_string(),
                mean_final: stats::mean(&finals),
                std_final: stats::std(&finals),
            };
            println!(
                "   {:<6} init={:<10} final MSE {:.6e} (±{:.1e})",
                row.algo, row.scheme, row.mean_final, row.std_final
            );
            rows.push(row);
        }
    }
    rows
}

pub fn run(opts: &ExpOpts) -> anyhow::Result<()> {
    let ds = common::infmnist(opts.scale);
    println!("== Ablation A-G: growth policy (tb-∞, {}) ==", ds.summary());
    let growth = growth_policy_study(&ds, opts);
    println!("== Ablation A-I: initialisation ({}) ==", ds.summary());
    let inits = init_study(&ds, opts);

    let mut t = Table::new(&["study", "variant", "metric", "value"]);
    for r in &growth {
        t.push(vec!["growth".into(), r.policy.clone(), "final_mse".into(),
                    format!("{:.8e}", r.mean_final)]);
        t.push(vec!["growth".into(), r.policy.clone(), "rounds".into(),
                    format!("{:.1}", r.mean_rounds)]);
        t.push(vec!["growth".into(), r.policy.clone(), "dist_calcs".into(),
                    format!("{:.0}", r.mean_dist_calcs)]);
    }
    for r in &inits {
        t.push(vec!["init".into(), format!("{}/{}", r.algo, r.scheme),
                    "final_mse".into(), format!("{:.8e}", r.mean_final)]);
    }
    let path = results_dir().join("ablations.csv");
    t.write_csv(&path)?;
    println!("   wrote {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Engine;

    #[test]
    fn both_studies_run_tiny() {
        let dir = std::env::temp_dir()
            .join(format!("nmbkm-abl-{}", std::process::id()));
        std::env::set_var("NMBKM_RESULTS_DIR", &dir);
        let ds = common::gaussian_small();
        let opts = ExpOpts {
            scale: common::Scale::Quick,
            seeds: 2,
            threads: 2,
            engine: Engine::Native,
            seconds: 0.3,
        };
        let g = growth_policy_study(&ds, &opts);
        assert_eq!(g.len(), 4);
        assert!(g.iter().all(|r| r.mean_final.is_finite()));
        let i = init_study(&ds, &opts);
        assert_eq!(i.len(), 6);
        assert!(i.iter().all(|r| r.mean_final.is_finite()));
        std::env::remove_var("NMBKM_RESULTS_DIR");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
