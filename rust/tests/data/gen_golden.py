#!/usr/bin/env python3
"""Regenerate the committed golden snapshot corpus.

The two artifacts next to this script pin the on-disk snapshot formats:

  golden-snapshot-v1.json  -- the v1 hex-JSON interchange format
  golden-snapshot-v2.bin   -- the v2 binary sidecar format

Both encode the SAME tiny, mathematically consistent model, so the
backcompat test can assert that every reader decodes them to one
identical state and answers pinned predict queries. The model:

  k=2, d=2, n=4 points (0,0) (0,2) (4,0) (4,2)
  labels [0,0,1,1], centroids (0,1) and (4,1) = per-cluster means
  suff stats: s=[(0,2),(8,2)], v=[2,2], sse=[2,2] (true residuals)
  cursor b=b_prev=n=4, rounds=1, tb-inf config, seed 0x2a

The files are committed; this script exists so a format change that
*intends* to break compatibility can regenerate them in one step (and
the diff makes the break explicit in review). Run from anywhere:

  python3 rust/tests/data/gen_golden.py
"""
import json
import os
import struct

HERE = os.path.dirname(os.path.abspath(__file__))

CENTROIDS = [0.0, 1.0, 4.0, 1.0]          # k*d f32
CENT_NORMS = [1.0, 17.0]                  # ||c_j||^2 f32
CENT_P = [0.0, 0.0]                       # last-move distances f32
STATS_S = [0.0, 2.0, 8.0, 2.0]            # k*d f64 coordinate sums
STATS_V = [2.0, 2.0]                      # k f64 counts
STATS_SSE = [2.0, 2.0]                    # k f64 residuals
LABELS = [0, 0, 1, 1]                     # n u32
DIST2 = [1.0, 1.0, 1.0, 1.0]              # n f32
SEEN_MASK = bytes([0x0F])                 # ceil(n/8), LSB-first
POINTS = [[0.0, 0.0], [0.0, 2.0], [4.0, 0.0], [4.0, 2.0]]
RNG_WORDS = [0x0123456789ABCDEF, 0xFEDCBA9876543210,
             0xDEADBEEFCAFEF00D, 0x0DDC0FFEEBADF00D]
K, D, N = 2, 2, 4

CONFIG = {
    "algo": "tb",
    "k": K,
    "b0": 4,
    "rho": "inf",
    "engine": "native",
    "threads": 1,
    "seed": "%x" % 0x2A,
    "max_seconds": "%x" % struct.unpack("<Q", struct.pack("<d", 60.0))[0],
    "max_rounds": "%x" % 50,
    "eval_every_secs": "%x" % struct.unpack("<Q", struct.pack("<d", 0.0))[0],
    "stop_on_convergence": False,
    "artifacts_dir": "",
    "init": "first-k",
}


def hex_f32s(xs):
    return b"".join(struct.pack("<f", x) for x in xs).hex()


def hex_f64s(xs):
    return b"".join(struct.pack("<d", x) for x in xs).hex()


def hex_u32s(xs):
    return b"".join(struct.pack("<I", x) for x in xs).hex()


def le_f32s(xs):
    return b"".join(struct.pack("<f", x) for x in xs)


def le_f64s(xs):
    return b"".join(struct.pack("<d", x) for x in xs)


def le_u32s(xs):
    return b"".join(struct.pack("<I", x) for x in xs)


def write_v1_json(path):
    doc = {
        "format": "nmbkm-snapshot",
        "version": 1,
        "config": CONFIG,
        "k": K,
        "d": D,
        "n": N,
        "b": N,
        "b_prev": N,
        "rounds": 1,
        "centroids": hex_f32s(CENTROIDS),
        "cent_norms": hex_f32s(CENT_NORMS),
        "cent_p": hex_f32s(CENT_P),
        "stats_s": hex_f64s(STATS_S),
        "stats_v": hex_f64s(STATS_V),
        "stats_sse": hex_f64s(STATS_SSE),
        "labels": hex_u32s(LABELS),
        "dist2": hex_f32s(DIST2),
        "seen_mask": SEEN_MASK.hex(),
        "rng_state": ["%x" % w for w in RNG_WORDS],
        "rng_spare": None,
        "data": {
            "kind": "dense",
            "rows": N,
            "cols": D,
            "values": hex_f32s([x for row in POINTS for x in row]),
        },
    }
    with open(path, "w") as f:
        json.dump(doc, f, separators=(",", ":"))
        f.write("\n")


def write_v2_binary(path):
    header = json.dumps(
        {
            "format": "nmbkm-snapshot",
            "version": 2,
            "config": CONFIG,
            "k": K,
            "d": D,
            "n": N,
            "b": N,
            "b_prev": N,
            "rounds": 1,
            "rng_state": ["%x" % w for w in RNG_WORDS],
            "rng_spare": None,
            "data": "dense",
        },
        separators=(",", ":"),
    ).encode()
    # data section: wire::encode_rows batch (u32 n, then tag-1 dense rows)
    payload = struct.pack("<I", N)
    for row in POINTS:
        payload += b"\x01" + struct.pack("<I", len(row)) + le_f32s(row)
    body = (
        le_f32s(CENTROIDS)
        + le_f32s(CENT_NORMS)
        + le_f32s(CENT_P)
        + le_f64s(STATS_S)
        + le_f64s(STATS_V)
        + le_f64s(STATS_SSE)
        + le_u32s(LABELS)
        + le_f32s(DIST2)
        + SEEN_MASK
        + struct.pack("<Q", len(payload))
        + payload
    )
    with open(path, "wb") as f:
        f.write(b"NMBKMSB1" + struct.pack("<I", len(header)) + header + body)


if __name__ == "__main__":
    write_v1_json(os.path.join(HERE, "golden-snapshot-v1.json"))
    write_v2_binary(os.path.join(HERE, "golden-snapshot-v2.bin"))
    print("wrote golden-snapshot-v1.json and golden-snapshot-v2.bin")
