//! Bench P — §Perf micro-benchmarks over the hot paths the profiles
//! identified: dense/sparse distance kernels, the bound screen, the
//! tb point-step, stats merging, and engine-level assignment throughput
//! (native serial vs threaded vs XLA). Drives the EXPERIMENTS.md §Perf
//! iteration log; each row is before/after comparable.

use nmbkm::bench::{BenchOpts, BenchSet};
use nmbkm::coordinator::Pool;
use nmbkm::data::{gaussian::GaussianMixture, infmnist::InfMnist, rcv1::Rcv1Sim};
use nmbkm::kmeans::assign::{AssignEngine, NativeEngine, Sel};
use nmbkm::kmeans::{bounds, init};
use nmbkm::linalg::dense;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = BenchOpts::from_env_or_args(&args);
    let threads = std::thread::available_parallelism().map(|x| x.get()).unwrap_or(4);

    // --- raw kernels -----------------------------------------------------
    let mut set = BenchSet::new("L3 native kernels", opts);
    let a: Vec<f32> = (0..784).map(|i| (i as f32).sin()).collect();
    let b: Vec<f32> = (0..784).map(|i| (i as f32).cos()).collect();
    set.bench("dot d=784 x 100k", || {
        let mut acc = 0f32;
        for _ in 0..100_000 {
            acc += dense::dot(std::hint::black_box(&a), std::hint::black_box(&b));
        }
        acc
    });
    // memory-roofline context: 2 vectors × 784 × 4B × 100k = 627 MB read
    let m = set.get("dot d=784 x 100k").unwrap().min_secs();
    println!(
        "     → {:.2} GFLOP/s, {:.2} GB/s effective",
        2.0 * 784.0 * 100_000.0 / m / 1e9,
        2.0 * 784.0 * 4.0 * 100_000.0 / m / 1e9
    );

    // --- engine assignment throughput -------------------------------------
    let data = InfMnist::default().generate(20_000, 1);
    let cent = init::first_k(&data, 50);
    let eng = NativeEngine;
    let mut lbl = vec![0u32; data.n()];
    let mut d2 = vec![0f32; data.n()];
    let mut set = BenchSet::new("assignment step (dense 20k x 784, k=50)", opts);
    set.bench("native 1 thread", || {
        eng.assign(&data, Sel::Range(0, data.n()), &cent, &Pool::new(1), &mut lbl, &mut d2)
    });
    set.bench(&format!("native {threads} threads"), || {
        eng.assign(&data, Sel::Range(0, data.n()), &cent, &Pool::new(threads), &mut lbl, &mut d2)
    });
    if let Ok(xla) = nmbkm::runtime::make_engine("artifacts") {
        set.bench("xla engine (PJRT tiles)", || {
            xla.assign(&data, Sel::Range(0, data.n()), &cent, &Pool::new(threads), &mut lbl, &mut d2)
        });
    } else {
        println!("  (xla engine skipped: run `make artifacts`)");
    }
    let t1 = set.get("native 1 thread").unwrap().min_secs();
    let tn = set.get(&format!("native {threads} threads")).unwrap().min_secs();
    println!("     → thread scaling {:.2}x on {threads} threads", t1 / tn);

    // --- sparse engine -----------------------------------------------------
    let sdata = Rcv1Sim::default().generate(20_000, 2);
    let scent = init::first_k(&sdata, 50);
    let mut slbl = vec![0u32; sdata.n()];
    let mut sd2 = vec![0f32; sdata.n()];
    let mut set = BenchSet::new("assignment step (sparse 20k x 47k, k=50)", opts);
    set.bench("native 1 thread", || {
        eng.assign(&sdata, Sel::Range(0, sdata.n()), &scent, &Pool::new(1), &mut slbl, &mut sd2)
    });
    set.bench(&format!("native {threads} threads"), || {
        eng.assign(&sdata, Sel::Range(0, sdata.n()), &scent, &Pool::new(threads), &mut slbl, &mut sd2)
    });

    // --- bound machinery ---------------------------------------------------
    let gdata = GaussianMixture::default_spec(8, 64).generate(10_000, 3);
    let gcent = init::first_k(&gdata, 50);
    let mut store = bounds::BoundStore::new(50);
    store.grow_to(10_000);
    let mut labels = vec![0u32; 10_000];
    for i in 0..10_000 {
        labels[i] = bounds::full_assign_fill(&gdata, i, &gcent, store.row_mut(i)).label;
    }
    let mut set = BenchSet::new("tb bound machinery (10k pts, k=50)", opts);
    set.bench("tb_point_step pass (stationary)", || {
        let mut calcs = 0u64;
        for i in 0..10_000 {
            calcs += bounds::tb_point_step(&gdata, i, &gcent, store.row_mut(i), labels[i])
                .dist_calcs;
        }
        calcs
    });
    set.bench("screen pass (clean)", || {
        let mut dirty = 0u32;
        for i in 0..10_000 {
            let mut row = store.row(i).to_vec();
            dirty += bounds::screen(&mut row, &gcent.p, labels[i], 0.0) as u32;
        }
        dirty
    });
    set.bench("full_assign_fill pass (no bounds)", || {
        let mut row = vec![0f32; 50];
        let mut acc = 0u64;
        for i in 0..10_000 {
            acc += bounds::full_assign_fill(&gdata, i, &gcent, &mut row).dist_calcs;
        }
        acc
    });
    let screened = set.get("screen pass (clean)").unwrap().min_secs();
    let full = set.get("full_assign_fill pass (no bounds)").unwrap().min_secs();
    println!(
        "     → screen is {:.0}x cheaper than full recompute (must be ≫1 for the tile path to pay)",
        full / screened
    );

    // --- stats merge -------------------------------------------------------
    let mut set = BenchSet::new("coordinator merge (k=64, d=784)", opts);
    set.bench("merge 8 SuffStats deltas", || {
        use nmbkm::coordinator::merge::Mergeable;
        let mut total = nmbkm::kmeans::state::SuffStats::zeros(64, 784);
        for _ in 0..8 {
            total.merge(nmbkm::kmeans::state::SuffStats::zeros(64, 784));
        }
        total.v[0]
    });

    println!("\nmicro_hotpaths done");
}
