//! Seeded shuffling and splitting, matching the paper's protocol:
//! "For 20 random seeds, the training dataset is shuffled and the first
//! k datapoints are taken as initialising centroids" (§4.3).

use crate::data::Data;
use crate::util::rng::Pcg64;

/// A seeded permutation of `0..n`.
pub fn permutation(n: usize, seed: u64) -> Vec<usize> {
    let mut perm: Vec<usize> = (0..n).collect();
    let mut rng = Pcg64::new(seed, 0x5811F).derive("shuffle");
    rng.shuffle(&mut perm);
    perm
}

/// Materialise the paper's per-seed shuffle of the training set.
pub fn shuffled(data: &Data, seed: u64) -> Data {
    data.permute(&permutation(data.n(), seed))
}

/// Split a dataset into (train, val) by taking the last `n_val` rows as
/// validation (used when a generator produces a single pool).
pub fn split(data: &Data, n_val: usize) -> (Data, Data) {
    assert!(n_val < data.n());
    let cut = data.n() - n_val;
    (data.slice(0, cut), data.slice(cut, data.n()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::DenseMatrix;

    fn toy(n: usize) -> Data {
        let vals: Vec<f32> = (0..n * 2).map(|x| x as f32).collect();
        Data::dense(DenseMatrix::from_vec(n, 2, vals))
    }

    #[test]
    fn permutation_is_bijective_and_seeded() {
        let p1 = permutation(100, 1);
        let p2 = permutation(100, 1);
        let p3 = permutation(100, 2);
        assert_eq!(p1, p2);
        assert_ne!(p1, p3);
        let mut sorted = p1.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn shuffled_preserves_multiset() {
        let d = toy(50);
        let s = shuffled(&d, 9);
        let mut a = d.norms.clone();
        let mut b = s.norms.clone();
        a.sort_by(f32::total_cmp);
        b.sort_by(f32::total_cmp);
        assert_eq!(a, b);
        assert_ne!(d.norms, s.norms); // actually shuffled
    }

    #[test]
    fn split_sizes() {
        let d = toy(30);
        let (tr, va) = split(&d, 5);
        assert_eq!(tr.n(), 25);
        assert_eq!(va.n(), 5);
        let mut row = vec![0.0; 2];
        va.write_row_dense(0, &mut row);
        assert_eq!(row, vec![50.0, 51.0]);
    }
}
