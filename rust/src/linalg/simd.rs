//! Runtime-dispatched SIMD distance kernels.
//!
//! Every FLOP of the native engine funnels through this module: `dot`,
//! the 4-row block `dot4`, `sq_norm`, the f64 accumulator ops
//! `add_into`/`sub_from`, and the point-blocked assignment micro-kernels
//! [`nearest_block`]/[`dist_rows_block`]. A [`Tier`] is picked once at
//! runtime (AVX2/SSE2 on x86_64, NEON on aarch64, scalar anywhere) and
//! cached; `NMBKM_SIMD=scalar|sse2|avx2|fma` forces a tier and
//! `NMBKM_FMA=1` opts into fused multiply-add.
//!
//! ## The bit-identity invariant
//!
//! Except for the opt-in FMA tier, **every tier produces bit-identical
//! results**, and `dot4(x, c0..c3)[j]` is bit-identical to
//! `dot(x, c_j)`. All variants accumulate partial products into the same
//! eight virtual lanes — lane `j` sums `a[8c+j]·b[8c+j]` over chunks
//! `c` in order — and reduce them with the same tree
//! `((s0+s1)+(s2+s3)) + ((s4+s5)+(s6+s7)) + tail`. The scalar reference
//! keeps eight independent accumulators, AVX2 holds the lanes in one
//! 256-bit register, SSE2 and NEON in two 128-bit registers; IEEE
//! addition order is identical in all four. This is what keeps runs
//! deterministic across machines, thread counts, and the blocked vs
//! per-point code paths (the repo's engine-parity and
//! threads-don't-change-results tests rely on it).
//!
//! The FMA tier (`NMBKM_FMA=1`, requires AVX2+FMA) contracts
//! multiply-add pairs and is therefore *not* bit-identical — it trades
//! reproducibility-across-tiers for ~2x FLOP throughput on
//! FMA-dominated shapes. It is never selected by default.

use crate::linalg::dense::DenseMatrix;
use std::sync::atomic::{AtomicU8, AtomicU64, Ordering};

#[cfg(target_arch = "x86_64")]
use core::arch::x86_64::*;

#[cfg(target_arch = "aarch64")]
use core::arch::aarch64::*;

/// A dispatchable kernel implementation level.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    /// Portable reference (8-way unrolled; autovectorises to the
    /// target baseline, i.e. SSE2 on x86_64).
    Scalar,
    /// Explicit 128-bit SSE2 (x86_64 baseline — always available there).
    Sse2,
    /// Explicit 256-bit AVX2, separate mul-then-add (bit-identical).
    Avx2,
    /// AVX2 with fused multiply-add — opt-in, NOT bit-identical.
    Avx2Fma,
    /// Explicit 128-bit NEON (aarch64 baseline).
    Neon,
}

impl Tier {
    pub fn name(self) -> &'static str {
        match self {
            Tier::Scalar => "scalar",
            Tier::Sse2 => "sse2",
            Tier::Avx2 => "avx2",
            Tier::Avx2Fma => "avx2+fma",
            Tier::Neon => "neon",
        }
    }
}

const TIER_UNSET: u8 = 0xFF;
static TIER: AtomicU8 = AtomicU8::new(TIER_UNSET);

fn encode(t: Tier) -> u8 {
    match t {
        Tier::Scalar => 0,
        Tier::Sse2 => 1,
        Tier::Avx2 => 2,
        Tier::Avx2Fma => 3,
        Tier::Neon => 4,
    }
}

fn decode(v: u8) -> Tier {
    match v {
        0 => Tier::Scalar,
        1 => Tier::Sse2,
        2 => Tier::Avx2,
        3 => Tier::Avx2Fma,
        _ => Tier::Neon,
    }
}

/// Tiers the current host can actually execute, widest last.
pub fn available_tiers() -> Vec<Tier> {
    #[allow(unused_mut)]
    let mut v = vec![Tier::Scalar];
    #[cfg(target_arch = "x86_64")]
    {
        v.push(Tier::Sse2);
        if std::arch::is_x86_64_feature_detected!("avx2") {
            v.push(Tier::Avx2);
            if std::arch::is_x86_64_feature_detected!("fma") {
                v.push(Tier::Avx2Fma);
            }
        }
    }
    #[cfg(target_arch = "aarch64")]
    v.push(Tier::Neon);
    v
}

/// Pure dispatch core: `simd_override` is the raw `NMBKM_SIMD` value and
/// `fma_optin` the raw `NMBKM_FMA` value, if set. Unknown or unsupported
/// requests fall back to auto-detection (never to a tier the host can't
/// run). Split out so tests never need `set_var`.
pub fn detect(simd_override: Option<&str>, fma_optin: Option<&str>) -> Tier {
    let avail = available_tiers();
    let has = |t: Tier| avail.contains(&t);
    if let Some(raw) = simd_override {
        match raw.trim().to_ascii_lowercase().as_str() {
            "scalar" => return Tier::Scalar,
            "sse2" if has(Tier::Sse2) => return Tier::Sse2,
            "avx2" if has(Tier::Avx2) => return Tier::Avx2,
            "fma" | "avx2+fma" if has(Tier::Avx2Fma) => return Tier::Avx2Fma,
            "neon" if has(Tier::Neon) => return Tier::Neon,
            _ => {}
        }
    }
    let fma_ok = fma_optin.map(|v| v.trim() == "1").unwrap_or(false);
    if fma_ok && has(Tier::Avx2Fma) {
        return Tier::Avx2Fma;
    }
    if has(Tier::Avx2) {
        return Tier::Avx2;
    }
    if has(Tier::Neon) {
        return Tier::Neon;
    }
    if has(Tier::Sse2) {
        return Tier::Sse2;
    }
    Tier::Scalar
}

/// The active dispatch tier (detected once, then cached).
#[inline]
pub fn tier() -> Tier {
    let v = TIER.load(Ordering::Relaxed);
    if v != TIER_UNSET {
        return decode(v);
    }
    let t = detect(
        std::env::var("NMBKM_SIMD").ok().as_deref(),
        std::env::var("NMBKM_FMA").ok().as_deref(),
    );
    TIER.store(encode(t), Ordering::Relaxed);
    t
}

/// Per-tier dispatch tally: how many block-kernel invocations ran at
/// each tier since process start. Kept as plain module statics (not in
/// the obs registry) so this module stays free of upward dependencies;
/// the serve metrics layer polls [`dispatch_tally`] at scrape time.
/// Callers batch counts per work chunk, so the `fetch_add` here is off
/// the per-point hot path.
static DISPATCH_TALLY: [AtomicU64; 5] = [
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
];

/// Record `n` block-kernel dispatches at tier `t`.
#[inline]
pub fn note_dispatch(t: Tier, n: u64) {
    if n > 0 {
        DISPATCH_TALLY[encode(t) as usize].fetch_add(n, Ordering::Relaxed);
    }
}

/// Snapshot of the per-tier dispatch tally, every tier listed (zeros
/// included) so exported metric series never appear and disappear.
pub fn dispatch_tally() -> Vec<(&'static str, u64)> {
    (0u8..5)
        .map(|v| {
            let t = decode(v);
            (t.name(), DISPATCH_TALLY[v as usize].load(Ordering::Relaxed))
        })
        .collect()
}

/// Force the dispatch tier (benches / CI smoke runs). Panics if the
/// host can't execute `t`. `force_tier(None)` re-runs auto-detection on
/// the next [`tier`] call.
pub fn force_tier(t: Option<Tier>) {
    match t {
        Some(t) => {
            assert!(
                available_tiers().contains(&t),
                "tier {} not available on this host",
                t.name()
            );
            TIER.store(encode(t), Ordering::Relaxed);
        }
        None => TIER.store(TIER_UNSET, Ordering::Relaxed),
    }
}

// ---------------------------------------------------------------------
// scalar reference kernels (the 8-virtual-lane accumulation pattern)
// ---------------------------------------------------------------------

/// Dot product, 8 independent accumulators — the bit-level reference
/// every SIMD tier reproduces exactly.
#[inline]
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    let (mut s4, mut s5, mut s6, mut s7) = (0f32, 0f32, 0f32, 0f32);
    for c in 0..chunks {
        let i = c * 8;
        // Safety: i+7 < chunks*8 <= n, same for b.
        unsafe {
            s0 += a.get_unchecked(i) * b.get_unchecked(i);
            s1 += a.get_unchecked(i + 1) * b.get_unchecked(i + 1);
            s2 += a.get_unchecked(i + 2) * b.get_unchecked(i + 2);
            s3 += a.get_unchecked(i + 3) * b.get_unchecked(i + 3);
            s4 += a.get_unchecked(i + 4) * b.get_unchecked(i + 4);
            s5 += a.get_unchecked(i + 5) * b.get_unchecked(i + 5);
            s6 += a.get_unchecked(i + 6) * b.get_unchecked(i + 6);
            s7 += a.get_unchecked(i + 7) * b.get_unchecked(i + 7);
        }
    }
    let mut tail = 0f32;
    for i in chunks * 8..n {
        tail += a[i] * b[i];
    }
    ((s0 + s1) + (s2 + s3)) + ((s4 + s5) + (s6 + s7)) + tail
}

/// Shared reduction tree over the eight virtual lanes (must match the
/// scalar combine above exactly).
#[inline]
fn reduce_lanes(l: &[f32; 8]) -> f32 {
    ((l[0] + l[1]) + (l[2] + l[3])) + ((l[4] + l[5]) + (l[6] + l[7]))
}

#[inline]
fn dot4_scalar(x: &[f32], c0: &[f32], c1: &[f32], c2: &[f32], c3: &[f32]) -> [f32; 4] {
    [dot_scalar(x, c0), dot_scalar(x, c1), dot_scalar(x, c2), dot_scalar(x, c3)]
}

#[inline]
fn dot4x2_scalar(
    xa: &[f32],
    xb: &[f32],
    c0: &[f32],
    c1: &[f32],
    c2: &[f32],
    c3: &[f32],
) -> [[f32; 4]; 2] {
    [dot4_scalar(xa, c0, c1, c2, c3), dot4_scalar(xb, c0, c1, c2, c3)]
}

#[inline]
fn add_into_scalar(acc: &mut [f64], x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    for i in 0..x.len() {
        acc[i] += x[i] as f64;
    }
}

#[inline]
fn sub_from_scalar(acc: &mut [f64], x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    for i in 0..x.len() {
        acc[i] -= x[i] as f64;
    }
}

// ---------------------------------------------------------------------
// SSE2 (x86_64 baseline): lanes 0..3 and 4..7 in two 128-bit registers
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse2")]
unsafe fn dot_sse2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let mut lo = _mm_setzero_ps();
    let mut hi = _mm_setzero_ps();
    for c in 0..chunks {
        let i = c * 8;
        let av0 = _mm_loadu_ps(a.as_ptr().add(i));
        let bv0 = _mm_loadu_ps(b.as_ptr().add(i));
        let av1 = _mm_loadu_ps(a.as_ptr().add(i + 4));
        let bv1 = _mm_loadu_ps(b.as_ptr().add(i + 4));
        lo = _mm_add_ps(lo, _mm_mul_ps(av0, bv0));
        hi = _mm_add_ps(hi, _mm_mul_ps(av1, bv1));
    }
    let mut lanes = [0f32; 8];
    _mm_storeu_ps(lanes.as_mut_ptr(), lo);
    _mm_storeu_ps(lanes.as_mut_ptr().add(4), hi);
    let mut tail = 0f32;
    for i in chunks * 8..n {
        tail += a.get_unchecked(i) * b.get_unchecked(i);
    }
    reduce_lanes(&lanes) + tail
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse2")]
unsafe fn dot4_sse2(
    x: &[f32],
    c0: &[f32],
    c1: &[f32],
    c2: &[f32],
    c3: &[f32],
) -> [f32; 4] {
    let n = x.len();
    let chunks = n / 8;
    let mut acc = [_mm_setzero_ps(); 8]; // [lo0, hi0, lo1, hi1, ...]
    let cs = [c0, c1, c2, c3];
    for c in 0..chunks {
        let i = c * 8;
        let xv0 = _mm_loadu_ps(x.as_ptr().add(i));
        let xv1 = _mm_loadu_ps(x.as_ptr().add(i + 4));
        for (j, cj) in cs.iter().enumerate() {
            let cv0 = _mm_loadu_ps(cj.as_ptr().add(i));
            let cv1 = _mm_loadu_ps(cj.as_ptr().add(i + 4));
            acc[j * 2] = _mm_add_ps(acc[j * 2], _mm_mul_ps(xv0, cv0));
            acc[j * 2 + 1] = _mm_add_ps(acc[j * 2 + 1], _mm_mul_ps(xv1, cv1));
        }
    }
    let mut out = [0f32; 4];
    let mut tails = [0f32; 4];
    for i in chunks * 8..n {
        let xi = *x.get_unchecked(i);
        for (j, cj) in cs.iter().enumerate() {
            tails[j] += xi * cj.get_unchecked(i);
        }
    }
    for j in 0..4 {
        let mut lanes = [0f32; 8];
        _mm_storeu_ps(lanes.as_mut_ptr(), acc[j * 2]);
        _mm_storeu_ps(lanes.as_mut_ptr().add(4), acc[j * 2 + 1]);
        out[j] = reduce_lanes(&lanes) + tails[j];
    }
    out
}

/// `acc += x` widened to f64, four lanes per step through two 128-bit
/// converts. Elementwise (f32→f64 widening is exact), so trivially
/// bit-identical to the scalar loop.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse2")]
unsafe fn add_into_sse2(acc: &mut [f64], x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    let n = x.len();
    let chunks = n / 4;
    for c in 0..chunks {
        let i = c * 4;
        let xv = _mm_loadu_ps(x.as_ptr().add(i));
        let lo = _mm_cvtps_pd(xv);
        let hi = _mm_cvtps_pd(_mm_movehl_ps(xv, xv));
        let a0 = _mm_loadu_pd(acc.as_ptr().add(i));
        let a1 = _mm_loadu_pd(acc.as_ptr().add(i + 2));
        _mm_storeu_pd(acc.as_mut_ptr().add(i), _mm_add_pd(a0, lo));
        _mm_storeu_pd(acc.as_mut_ptr().add(i + 2), _mm_add_pd(a1, hi));
    }
    for i in chunks * 4..n {
        *acc.get_unchecked_mut(i) += *x.get_unchecked(i) as f64;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse2")]
unsafe fn sub_from_sse2(acc: &mut [f64], x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    let n = x.len();
    let chunks = n / 4;
    for c in 0..chunks {
        let i = c * 4;
        let xv = _mm_loadu_ps(x.as_ptr().add(i));
        let lo = _mm_cvtps_pd(xv);
        let hi = _mm_cvtps_pd(_mm_movehl_ps(xv, xv));
        let a0 = _mm_loadu_pd(acc.as_ptr().add(i));
        let a1 = _mm_loadu_pd(acc.as_ptr().add(i + 2));
        _mm_storeu_pd(acc.as_mut_ptr().add(i), _mm_sub_pd(a0, lo));
        _mm_storeu_pd(acc.as_mut_ptr().add(i + 2), _mm_sub_pd(a1, hi));
    }
    for i in chunks * 4..n {
        *acc.get_unchecked_mut(i) -= *x.get_unchecked(i) as f64;
    }
}

// ---------------------------------------------------------------------
// AVX2: all eight lanes in one 256-bit register
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let mut acc = _mm256_setzero_ps();
    for c in 0..chunks {
        let i = c * 8;
        let av = _mm256_loadu_ps(a.as_ptr().add(i));
        let bv = _mm256_loadu_ps(b.as_ptr().add(i));
        acc = _mm256_add_ps(acc, _mm256_mul_ps(av, bv));
    }
    let mut lanes = [0f32; 8];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
    let mut tail = 0f32;
    for i in chunks * 8..n {
        tail += a.get_unchecked(i) * b.get_unchecked(i);
    }
    reduce_lanes(&lanes) + tail
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot4_avx2(
    x: &[f32],
    c0: &[f32],
    c1: &[f32],
    c2: &[f32],
    c3: &[f32],
) -> [f32; 4] {
    let n = x.len();
    let chunks = n / 8;
    let mut a0 = _mm256_setzero_ps();
    let mut a1 = _mm256_setzero_ps();
    let mut a2 = _mm256_setzero_ps();
    let mut a3 = _mm256_setzero_ps();
    for c in 0..chunks {
        let i = c * 8;
        let xv = _mm256_loadu_ps(x.as_ptr().add(i));
        a0 = _mm256_add_ps(a0, _mm256_mul_ps(xv, _mm256_loadu_ps(c0.as_ptr().add(i))));
        a1 = _mm256_add_ps(a1, _mm256_mul_ps(xv, _mm256_loadu_ps(c1.as_ptr().add(i))));
        a2 = _mm256_add_ps(a2, _mm256_mul_ps(xv, _mm256_loadu_ps(c2.as_ptr().add(i))));
        a3 = _mm256_add_ps(a3, _mm256_mul_ps(xv, _mm256_loadu_ps(c3.as_ptr().add(i))));
    }
    let mut tails = [0f32; 4];
    let cs = [c0, c1, c2, c3];
    for i in chunks * 8..n {
        let xi = *x.get_unchecked(i);
        for (j, cj) in cs.iter().enumerate() {
            tails[j] += xi * cj.get_unchecked(i);
        }
    }
    let mut out = [0f32; 4];
    for (j, av) in [a0, a1, a2, a3].into_iter().enumerate() {
        let mut lanes = [0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), av);
        out[j] = reduce_lanes(&lanes) + tails[j];
    }
    out
}

/// Rank-2 (two-point) × 4-centroid dot tile: each centroid chunk is
/// loaded **once** and multiplied into both points' accumulators,
/// halving centroid memory traffic versus two `dot4` passes. Eight
/// independent 256-bit accumulators (4 centroids × 2 points) — each dot
/// keeps its own eight virtual lanes, so every output is bit-identical
/// to the corresponding single `dot_avx2`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot4x2_avx2(
    xa: &[f32],
    xb: &[f32],
    c0: &[f32],
    c1: &[f32],
    c2: &[f32],
    c3: &[f32],
) -> [[f32; 4]; 2] {
    let n = xa.len();
    let chunks = n / 8;
    let mut aa = [_mm256_setzero_ps(); 4];
    let mut ab = [_mm256_setzero_ps(); 4];
    let cs = [c0, c1, c2, c3];
    for c in 0..chunks {
        let i = c * 8;
        let xav = _mm256_loadu_ps(xa.as_ptr().add(i));
        let xbv = _mm256_loadu_ps(xb.as_ptr().add(i));
        for (j, cj) in cs.iter().enumerate() {
            let cv = _mm256_loadu_ps(cj.as_ptr().add(i));
            aa[j] = _mm256_add_ps(aa[j], _mm256_mul_ps(xav, cv));
            ab[j] = _mm256_add_ps(ab[j], _mm256_mul_ps(xbv, cv));
        }
    }
    let mut tails = [[0f32; 4]; 2];
    for i in chunks * 8..n {
        let xai = *xa.get_unchecked(i);
        let xbi = *xb.get_unchecked(i);
        for (j, cj) in cs.iter().enumerate() {
            let cji = *cj.get_unchecked(i);
            tails[0][j] += xai * cji;
            tails[1][j] += xbi * cji;
        }
    }
    let mut out = [[0f32; 4]; 2];
    for j in 0..4 {
        let mut lanes = [0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), aa[j]);
        out[0][j] = reduce_lanes(&lanes) + tails[0][j];
        _mm256_storeu_ps(lanes.as_mut_ptr(), ab[j]);
        out[1][j] = reduce_lanes(&lanes) + tails[1][j];
    }
    out
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn dot_avx2fma(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let mut acc = _mm256_setzero_ps();
    for c in 0..chunks {
        let i = c * 8;
        let av = _mm256_loadu_ps(a.as_ptr().add(i));
        let bv = _mm256_loadu_ps(b.as_ptr().add(i));
        acc = _mm256_fmadd_ps(av, bv, acc);
    }
    let mut lanes = [0f32; 8];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
    let mut tail = 0f32;
    for i in chunks * 8..n {
        tail += a.get_unchecked(i) * b.get_unchecked(i);
    }
    reduce_lanes(&lanes) + tail
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn dot4_avx2fma(
    x: &[f32],
    c0: &[f32],
    c1: &[f32],
    c2: &[f32],
    c3: &[f32],
) -> [f32; 4] {
    let n = x.len();
    let chunks = n / 8;
    let mut a0 = _mm256_setzero_ps();
    let mut a1 = _mm256_setzero_ps();
    let mut a2 = _mm256_setzero_ps();
    let mut a3 = _mm256_setzero_ps();
    for c in 0..chunks {
        let i = c * 8;
        let xv = _mm256_loadu_ps(x.as_ptr().add(i));
        a0 = _mm256_fmadd_ps(xv, _mm256_loadu_ps(c0.as_ptr().add(i)), a0);
        a1 = _mm256_fmadd_ps(xv, _mm256_loadu_ps(c1.as_ptr().add(i)), a1);
        a2 = _mm256_fmadd_ps(xv, _mm256_loadu_ps(c2.as_ptr().add(i)), a2);
        a3 = _mm256_fmadd_ps(xv, _mm256_loadu_ps(c3.as_ptr().add(i)), a3);
    }
    let mut tails = [0f32; 4];
    let cs = [c0, c1, c2, c3];
    for i in chunks * 8..n {
        let xi = *x.get_unchecked(i);
        for (j, cj) in cs.iter().enumerate() {
            tails[j] += xi * cj.get_unchecked(i);
        }
    }
    let mut out = [0f32; 4];
    for (j, av) in [a0, a1, a2, a3].into_iter().enumerate() {
        let mut lanes = [0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), av);
        out[j] = reduce_lanes(&lanes) + tails[j];
    }
    out
}

/// FMA variant of the rank-2 tile (fused accumulate, same shape).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn dot4x2_avx2fma(
    xa: &[f32],
    xb: &[f32],
    c0: &[f32],
    c1: &[f32],
    c2: &[f32],
    c3: &[f32],
) -> [[f32; 4]; 2] {
    let n = xa.len();
    let chunks = n / 8;
    let mut aa = [_mm256_setzero_ps(); 4];
    let mut ab = [_mm256_setzero_ps(); 4];
    let cs = [c0, c1, c2, c3];
    for c in 0..chunks {
        let i = c * 8;
        let xav = _mm256_loadu_ps(xa.as_ptr().add(i));
        let xbv = _mm256_loadu_ps(xb.as_ptr().add(i));
        for (j, cj) in cs.iter().enumerate() {
            let cv = _mm256_loadu_ps(cj.as_ptr().add(i));
            aa[j] = _mm256_fmadd_ps(xav, cv, aa[j]);
            ab[j] = _mm256_fmadd_ps(xbv, cv, ab[j]);
        }
    }
    let mut tails = [[0f32; 4]; 2];
    for i in chunks * 8..n {
        let xai = *xa.get_unchecked(i);
        let xbi = *xb.get_unchecked(i);
        for (j, cj) in cs.iter().enumerate() {
            let cji = *cj.get_unchecked(i);
            tails[0][j] += xai * cji;
            tails[1][j] += xbi * cji;
        }
    }
    let mut out = [[0f32; 4]; 2];
    for j in 0..4 {
        let mut lanes = [0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), aa[j]);
        out[0][j] = reduce_lanes(&lanes) + tails[0][j];
        _mm256_storeu_ps(lanes.as_mut_ptr(), ab[j]);
        out[1][j] = reduce_lanes(&lanes) + tails[1][j];
    }
    out
}

/// `acc += x` widened to f64, four lanes per step. Elementwise, so
/// trivially bit-identical to the scalar loop.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn add_into_avx2(acc: &mut [f64], x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    let n = x.len();
    let chunks = n / 4;
    for c in 0..chunks {
        let i = c * 4;
        let xv = _mm256_cvtps_pd(_mm_loadu_ps(x.as_ptr().add(i)));
        let av = _mm256_loadu_pd(acc.as_ptr().add(i));
        _mm256_storeu_pd(acc.as_mut_ptr().add(i), _mm256_add_pd(av, xv));
    }
    for i in chunks * 4..n {
        *acc.get_unchecked_mut(i) += *x.get_unchecked(i) as f64;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn sub_from_avx2(acc: &mut [f64], x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    let n = x.len();
    let chunks = n / 4;
    for c in 0..chunks {
        let i = c * 4;
        let xv = _mm256_cvtps_pd(_mm_loadu_ps(x.as_ptr().add(i)));
        let av = _mm256_loadu_pd(acc.as_ptr().add(i));
        _mm256_storeu_pd(acc.as_mut_ptr().add(i), _mm256_sub_pd(av, xv));
    }
    for i in chunks * 4..n {
        *acc.get_unchecked_mut(i) -= *x.get_unchecked(i) as f64;
    }
}

// ---------------------------------------------------------------------
// NEON (aarch64 baseline): lanes 0..3 and 4..7 in two 128-bit registers.
// Explicit mul-then-add (vfma would contract and break bit-identity).
// ---------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn dot_neon(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let mut lo = vdupq_n_f32(0.0);
    let mut hi = vdupq_n_f32(0.0);
    for c in 0..chunks {
        let i = c * 8;
        let av0 = vld1q_f32(a.as_ptr().add(i));
        let bv0 = vld1q_f32(b.as_ptr().add(i));
        let av1 = vld1q_f32(a.as_ptr().add(i + 4));
        let bv1 = vld1q_f32(b.as_ptr().add(i + 4));
        lo = vaddq_f32(lo, vmulq_f32(av0, bv0));
        hi = vaddq_f32(hi, vmulq_f32(av1, bv1));
    }
    let mut lanes = [0f32; 8];
    vst1q_f32(lanes.as_mut_ptr(), lo);
    vst1q_f32(lanes.as_mut_ptr().add(4), hi);
    let mut tail = 0f32;
    for i in chunks * 8..n {
        tail += a.get_unchecked(i) * b.get_unchecked(i);
    }
    reduce_lanes(&lanes) + tail
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn dot4_neon(
    x: &[f32],
    c0: &[f32],
    c1: &[f32],
    c2: &[f32],
    c3: &[f32],
) -> [f32; 4] {
    let n = x.len();
    let chunks = n / 8;
    let mut acc = [vdupq_n_f32(0.0); 8]; // [lo0, hi0, lo1, hi1, ...]
    let cs = [c0, c1, c2, c3];
    for c in 0..chunks {
        let i = c * 8;
        let xv0 = vld1q_f32(x.as_ptr().add(i));
        let xv1 = vld1q_f32(x.as_ptr().add(i + 4));
        for (j, cj) in cs.iter().enumerate() {
            let cv0 = vld1q_f32(cj.as_ptr().add(i));
            let cv1 = vld1q_f32(cj.as_ptr().add(i + 4));
            acc[j * 2] = vaddq_f32(acc[j * 2], vmulq_f32(xv0, cv0));
            acc[j * 2 + 1] = vaddq_f32(acc[j * 2 + 1], vmulq_f32(xv1, cv1));
        }
    }
    let mut out = [0f32; 4];
    let mut tails = [0f32; 4];
    for i in chunks * 8..n {
        let xi = *x.get_unchecked(i);
        for (j, cj) in cs.iter().enumerate() {
            tails[j] += xi * cj.get_unchecked(i);
        }
    }
    for j in 0..4 {
        let mut lanes = [0f32; 8];
        vst1q_f32(lanes.as_mut_ptr(), acc[j * 2]);
        vst1q_f32(lanes.as_mut_ptr().add(4), acc[j * 2 + 1]);
        out[j] = reduce_lanes(&lanes) + tails[j];
    }
    out
}

/// Rank-2 (two-point) × 4-centroid dot tile on NEON: 16 independent
/// 128-bit accumulators (4 centroids × 2 points × lo/hi), centroid
/// chunks loaded once for both points. Bit-identical per output to
/// `dot_neon` (same eight virtual lanes per dot).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn dot4x2_neon(
    xa: &[f32],
    xb: &[f32],
    c0: &[f32],
    c1: &[f32],
    c2: &[f32],
    c3: &[f32],
) -> [[f32; 4]; 2] {
    let n = xa.len();
    let chunks = n / 8;
    let mut aa = [vdupq_n_f32(0.0); 8]; // [lo0, hi0, lo1, hi1, ...] for xa
    let mut ab = [vdupq_n_f32(0.0); 8]; // same layout for xb
    let cs = [c0, c1, c2, c3];
    for c in 0..chunks {
        let i = c * 8;
        let xa0 = vld1q_f32(xa.as_ptr().add(i));
        let xa1 = vld1q_f32(xa.as_ptr().add(i + 4));
        let xb0 = vld1q_f32(xb.as_ptr().add(i));
        let xb1 = vld1q_f32(xb.as_ptr().add(i + 4));
        for (j, cj) in cs.iter().enumerate() {
            let cv0 = vld1q_f32(cj.as_ptr().add(i));
            let cv1 = vld1q_f32(cj.as_ptr().add(i + 4));
            aa[j * 2] = vaddq_f32(aa[j * 2], vmulq_f32(xa0, cv0));
            aa[j * 2 + 1] = vaddq_f32(aa[j * 2 + 1], vmulq_f32(xa1, cv1));
            ab[j * 2] = vaddq_f32(ab[j * 2], vmulq_f32(xb0, cv0));
            ab[j * 2 + 1] = vaddq_f32(ab[j * 2 + 1], vmulq_f32(xb1, cv1));
        }
    }
    let mut tails = [[0f32; 4]; 2];
    for i in chunks * 8..n {
        let xai = *xa.get_unchecked(i);
        let xbi = *xb.get_unchecked(i);
        for (j, cj) in cs.iter().enumerate() {
            let cji = *cj.get_unchecked(i);
            tails[0][j] += xai * cji;
            tails[1][j] += xbi * cji;
        }
    }
    let mut out = [[0f32; 4]; 2];
    for j in 0..4 {
        let mut lanes = [0f32; 8];
        vst1q_f32(lanes.as_mut_ptr(), aa[j * 2]);
        vst1q_f32(lanes.as_mut_ptr().add(4), aa[j * 2 + 1]);
        out[0][j] = reduce_lanes(&lanes) + tails[0][j];
        vst1q_f32(lanes.as_mut_ptr(), ab[j * 2]);
        vst1q_f32(lanes.as_mut_ptr().add(4), ab[j * 2 + 1]);
        out[1][j] = reduce_lanes(&lanes) + tails[1][j];
    }
    out
}

/// `acc += x` widened to f64 on NEON: four f32 lanes per step via the
/// low/high f64 converts. Elementwise ⇒ bit-identical to scalar.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn add_into_neon(acc: &mut [f64], x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    let n = x.len();
    let chunks = n / 4;
    for c in 0..chunks {
        let i = c * 4;
        let xv = vld1q_f32(x.as_ptr().add(i));
        let lo = vcvt_f64_f32(vget_low_f32(xv));
        let hi = vcvt_high_f64_f32(xv);
        let a0 = vld1q_f64(acc.as_ptr().add(i));
        let a1 = vld1q_f64(acc.as_ptr().add(i + 2));
        vst1q_f64(acc.as_mut_ptr().add(i), vaddq_f64(a0, lo));
        vst1q_f64(acc.as_mut_ptr().add(i + 2), vaddq_f64(a1, hi));
    }
    for i in chunks * 4..n {
        *acc.get_unchecked_mut(i) += *x.get_unchecked(i) as f64;
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn sub_from_neon(acc: &mut [f64], x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    let n = x.len();
    let chunks = n / 4;
    for c in 0..chunks {
        let i = c * 4;
        let xv = vld1q_f32(x.as_ptr().add(i));
        let lo = vcvt_f64_f32(vget_low_f32(xv));
        let hi = vcvt_high_f64_f32(xv);
        let a0 = vld1q_f64(acc.as_ptr().add(i));
        let a1 = vld1q_f64(acc.as_ptr().add(i + 2));
        vst1q_f64(acc.as_mut_ptr().add(i), vsubq_f64(a0, lo));
        vst1q_f64(acc.as_mut_ptr().add(i + 2), vsubq_f64(a1, hi));
    }
    for i in chunks * 4..n {
        *acc.get_unchecked_mut(i) -= *x.get_unchecked(i) as f64;
    }
}

// ---------------------------------------------------------------------
// k-strided sparse AXPY kernels: acc[j] += v · row[j]
//
// The sparse assignment hot loop (`TransposedCentroids::dots`) runs one
// of these per non-zero: `row` is the k-length transpose strip of the
// non-zero's column and `acc` the k-length all-centroid dot accumulator.
// Unlike the reduction kernels above, AXPY is *elementwise* — lane j
// only ever computes fl(acc[j] + fl(v·row[j])) — so every non-FMA tier
// is bit-identical to the scalar reference by construction, and the
// accumulation order per lane equals the gather path's `spdot` order.
// The paired variant folds two non-zeros into one pass over `acc`
// (halves the accumulator traffic); its per-lane operation is the same
// two sequential rounded adds the scalar loop performs.
// ---------------------------------------------------------------------

/// `acc[j] += v·row[j]` — 8-lane unrolled scalar reference.
///
/// Length equality is a real assert (not debug-only): the unrolled body
/// does unchecked reads, and unlike `spdot` the safety condition here
/// is purely caller-supplied.
#[inline]
pub fn axpy_scalar(v: f32, row: &[f32], acc: &mut [f32]) {
    assert_eq!(row.len(), acc.len(), "axpy: length mismatch");
    let n = acc.len();
    let chunks = n / 8;
    for c in 0..chunks {
        let i = c * 8;
        // Safety: i + 7 < chunks*8 <= n, same for row.
        unsafe {
            for o in 0..8 {
                *acc.get_unchecked_mut(i + o) +=
                    v * row.get_unchecked(i + o);
            }
        }
    }
    for i in chunks * 8..n {
        acc[i] += v * row[i];
    }
}

/// Two stacked AXPYs in one pass: `acc[j] += v0·r0[j]; acc[j] += v1·r1[j]`
/// (two separately rounded adds per lane, exactly like calling
/// [`axpy_scalar`] twice).
#[inline]
pub fn axpy2_scalar(v0: f32, r0: &[f32], v1: f32, r1: &[f32], acc: &mut [f32]) {
    assert_eq!(r0.len(), acc.len(), "axpy2: row 0 length mismatch");
    assert_eq!(r1.len(), acc.len(), "axpy2: row 1 length mismatch");
    let n = acc.len();
    let chunks = n / 8;
    for c in 0..chunks {
        let i = c * 8;
        // Safety: i + 7 < chunks*8 <= n, same for r0/r1.
        unsafe {
            for o in 0..8 {
                let a = acc.get_unchecked_mut(i + o);
                let mut x = *a;
                x += v0 * r0.get_unchecked(i + o);
                x += v1 * r1.get_unchecked(i + o);
                *a = x;
            }
        }
    }
    for i in chunks * 8..n {
        let mut x = acc[i];
        x += v0 * r0[i];
        x += v1 * r1[i];
        acc[i] = x;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse2")]
unsafe fn axpy_sse2(v: f32, row: &[f32], acc: &mut [f32]) {
    debug_assert_eq!(row.len(), acc.len());
    let n = acc.len();
    let chunks = n / 8;
    let vv = _mm_set1_ps(v);
    for c in 0..chunks {
        let i = c * 8;
        let r0 = _mm_loadu_ps(row.as_ptr().add(i));
        let r1 = _mm_loadu_ps(row.as_ptr().add(i + 4));
        let a0 = _mm_loadu_ps(acc.as_ptr().add(i));
        let a1 = _mm_loadu_ps(acc.as_ptr().add(i + 4));
        _mm_storeu_ps(acc.as_mut_ptr().add(i), _mm_add_ps(a0, _mm_mul_ps(vv, r0)));
        _mm_storeu_ps(
            acc.as_mut_ptr().add(i + 4),
            _mm_add_ps(a1, _mm_mul_ps(vv, r1)),
        );
    }
    for i in chunks * 8..n {
        *acc.get_unchecked_mut(i) += v * row.get_unchecked(i);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse2")]
unsafe fn axpy2_sse2(v0: f32, r0: &[f32], v1: f32, r1: &[f32], acc: &mut [f32]) {
    let n = acc.len();
    let chunks = n / 8;
    let vv0 = _mm_set1_ps(v0);
    let vv1 = _mm_set1_ps(v1);
    for c in 0..chunks {
        let i = c * 8;
        let mut a0 = _mm_loadu_ps(acc.as_ptr().add(i));
        let mut a1 = _mm_loadu_ps(acc.as_ptr().add(i + 4));
        a0 = _mm_add_ps(a0, _mm_mul_ps(vv0, _mm_loadu_ps(r0.as_ptr().add(i))));
        a1 = _mm_add_ps(a1, _mm_mul_ps(vv0, _mm_loadu_ps(r0.as_ptr().add(i + 4))));
        a0 = _mm_add_ps(a0, _mm_mul_ps(vv1, _mm_loadu_ps(r1.as_ptr().add(i))));
        a1 = _mm_add_ps(a1, _mm_mul_ps(vv1, _mm_loadu_ps(r1.as_ptr().add(i + 4))));
        _mm_storeu_ps(acc.as_mut_ptr().add(i), a0);
        _mm_storeu_ps(acc.as_mut_ptr().add(i + 4), a1);
    }
    for i in chunks * 8..n {
        let a = acc.get_unchecked_mut(i);
        let mut x = *a;
        x += v0 * r0.get_unchecked(i);
        x += v1 * r1.get_unchecked(i);
        *a = x;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_avx2(v: f32, row: &[f32], acc: &mut [f32]) {
    debug_assert_eq!(row.len(), acc.len());
    let n = acc.len();
    let chunks = n / 8;
    let vv = _mm256_set1_ps(v);
    for c in 0..chunks {
        let i = c * 8;
        let rv = _mm256_loadu_ps(row.as_ptr().add(i));
        let av = _mm256_loadu_ps(acc.as_ptr().add(i));
        _mm256_storeu_ps(
            acc.as_mut_ptr().add(i),
            _mm256_add_ps(av, _mm256_mul_ps(vv, rv)),
        );
    }
    for i in chunks * 8..n {
        *acc.get_unchecked_mut(i) += v * row.get_unchecked(i);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy2_avx2(v0: f32, r0: &[f32], v1: f32, r1: &[f32], acc: &mut [f32]) {
    let n = acc.len();
    let chunks = n / 8;
    let vv0 = _mm256_set1_ps(v0);
    let vv1 = _mm256_set1_ps(v1);
    for c in 0..chunks {
        let i = c * 8;
        let mut av = _mm256_loadu_ps(acc.as_ptr().add(i));
        av = _mm256_add_ps(
            av,
            _mm256_mul_ps(vv0, _mm256_loadu_ps(r0.as_ptr().add(i))),
        );
        av = _mm256_add_ps(
            av,
            _mm256_mul_ps(vv1, _mm256_loadu_ps(r1.as_ptr().add(i))),
        );
        _mm256_storeu_ps(acc.as_mut_ptr().add(i), av);
    }
    for i in chunks * 8..n {
        let a = acc.get_unchecked_mut(i);
        let mut x = *a;
        x += v0 * r0.get_unchecked(i);
        x += v1 * r1.get_unchecked(i);
        *a = x;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn axpy_avx2fma(v: f32, row: &[f32], acc: &mut [f32]) {
    debug_assert_eq!(row.len(), acc.len());
    let n = acc.len();
    let chunks = n / 8;
    let vv = _mm256_set1_ps(v);
    for c in 0..chunks {
        let i = c * 8;
        let rv = _mm256_loadu_ps(row.as_ptr().add(i));
        let av = _mm256_loadu_ps(acc.as_ptr().add(i));
        _mm256_storeu_ps(acc.as_mut_ptr().add(i), _mm256_fmadd_ps(vv, rv, av));
    }
    for i in chunks * 8..n {
        *acc.get_unchecked_mut(i) += v * row.get_unchecked(i);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn axpy2_avx2fma(v0: f32, r0: &[f32], v1: f32, r1: &[f32], acc: &mut [f32]) {
    let n = acc.len();
    let chunks = n / 8;
    let vv0 = _mm256_set1_ps(v0);
    let vv1 = _mm256_set1_ps(v1);
    for c in 0..chunks {
        let i = c * 8;
        let mut av = _mm256_loadu_ps(acc.as_ptr().add(i));
        av = _mm256_fmadd_ps(vv0, _mm256_loadu_ps(r0.as_ptr().add(i)), av);
        av = _mm256_fmadd_ps(vv1, _mm256_loadu_ps(r1.as_ptr().add(i)), av);
        _mm256_storeu_ps(acc.as_mut_ptr().add(i), av);
    }
    for i in chunks * 8..n {
        let a = acc.get_unchecked_mut(i);
        let mut x = *a;
        x += v0 * r0.get_unchecked(i);
        x += v1 * r1.get_unchecked(i);
        *a = x;
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn axpy_neon(v: f32, row: &[f32], acc: &mut [f32]) {
    debug_assert_eq!(row.len(), acc.len());
    let n = acc.len();
    let chunks = n / 8;
    let vv = vdupq_n_f32(v);
    for c in 0..chunks {
        let i = c * 8;
        let r0 = vld1q_f32(row.as_ptr().add(i));
        let r1 = vld1q_f32(row.as_ptr().add(i + 4));
        let a0 = vld1q_f32(acc.as_ptr().add(i));
        let a1 = vld1q_f32(acc.as_ptr().add(i + 4));
        // explicit mul-then-add (vfmaq would contract, breaking
        // bit-identity with the scalar reference)
        vst1q_f32(acc.as_mut_ptr().add(i), vaddq_f32(a0, vmulq_f32(vv, r0)));
        vst1q_f32(
            acc.as_mut_ptr().add(i + 4),
            vaddq_f32(a1, vmulq_f32(vv, r1)),
        );
    }
    for i in chunks * 8..n {
        *acc.get_unchecked_mut(i) += v * row.get_unchecked(i);
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn axpy2_neon(v0: f32, r0: &[f32], v1: f32, r1: &[f32], acc: &mut [f32]) {
    let n = acc.len();
    let chunks = n / 8;
    let vv0 = vdupq_n_f32(v0);
    let vv1 = vdupq_n_f32(v1);
    for c in 0..chunks {
        let i = c * 8;
        let mut a0 = vld1q_f32(acc.as_ptr().add(i));
        let mut a1 = vld1q_f32(acc.as_ptr().add(i + 4));
        a0 = vaddq_f32(a0, vmulq_f32(vv0, vld1q_f32(r0.as_ptr().add(i))));
        a1 = vaddq_f32(a1, vmulq_f32(vv0, vld1q_f32(r0.as_ptr().add(i + 4))));
        a0 = vaddq_f32(a0, vmulq_f32(vv1, vld1q_f32(r1.as_ptr().add(i))));
        a1 = vaddq_f32(a1, vmulq_f32(vv1, vld1q_f32(r1.as_ptr().add(i + 4))));
        vst1q_f32(acc.as_mut_ptr().add(i), a0);
        vst1q_f32(acc.as_mut_ptr().add(i + 4), a1);
    }
    for i in chunks * 8..n {
        let a = acc.get_unchecked_mut(i);
        let mut x = *a;
        x += v0 * r0.get_unchecked(i);
        x += v1 * r1.get_unchecked(i);
        *a = x;
    }
}

/// `acc += v·row` through an explicit tier. Length equality is a real
/// assert: the tier kernels do unchecked SIMD loads.
#[inline]
pub fn axpy_with(t: Tier, v: f32, row: &[f32], acc: &mut [f32]) {
    assert_eq!(row.len(), acc.len(), "axpy: length mismatch");
    match t {
        Tier::Scalar => axpy_scalar(v, row, acc),
        #[cfg(target_arch = "x86_64")]
        Tier::Sse2 => unsafe { axpy_sse2(v, row, acc) },
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 => unsafe { axpy_avx2(v, row, acc) },
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2Fma => unsafe { axpy_avx2fma(v, row, acc) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { axpy_neon(v, row, acc) },
        _ => axpy_scalar(v, row, acc),
    }
}

/// Two stacked AXPYs through an explicit tier; bit-identical to two
/// [`axpy_with`] calls on every non-FMA tier.
#[inline]
pub fn axpy2_with(t: Tier, v0: f32, r0: &[f32], v1: f32, r1: &[f32], acc: &mut [f32]) {
    assert_eq!(r0.len(), acc.len(), "axpy2: row 0 length mismatch");
    assert_eq!(r1.len(), acc.len(), "axpy2: row 1 length mismatch");
    match t {
        Tier::Scalar => axpy2_scalar(v0, r0, v1, r1, acc),
        #[cfg(target_arch = "x86_64")]
        Tier::Sse2 => unsafe { axpy2_sse2(v0, r0, v1, r1, acc) },
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 => unsafe { axpy2_avx2(v0, r0, v1, r1, acc) },
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2Fma => unsafe { axpy2_avx2fma(v0, r0, v1, r1, acc) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { axpy2_neon(v0, r0, v1, r1, acc) },
        _ => axpy2_scalar(v0, r0, v1, r1, acc),
    }
}

// ---------------------------------------------------------------------
// per-tier entry points + dispatched wrappers
// ---------------------------------------------------------------------

/// `⟨a, b⟩` through an explicit tier (tests/benches).
///
/// Length equality is checked here with a real assert: the tier kernels
/// do unchecked SIMD loads, so a mismatch must not reach them in
/// release builds (one predictable branch, amortised over ≥ 8 lanes).
#[inline]
pub fn dot_with(t: Tier, a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "dot: length mismatch");
    match t {
        Tier::Scalar => dot_scalar(a, b),
        #[cfg(target_arch = "x86_64")]
        Tier::Sse2 => unsafe { dot_sse2(a, b) },
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 => unsafe { dot_avx2(a, b) },
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2Fma => unsafe { dot_avx2fma(a, b) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { dot_neon(a, b) },
        _ => dot_scalar(a, b),
    }
}

/// Four dots against consecutive centroid rows sharing one pass over
/// `x`; `dot4_with(t, x, c0..c3)[j]` is bit-identical to
/// `dot_with(t, x, c_j)` for every non-FMA tier.
#[inline]
pub fn dot4_with(
    t: Tier,
    x: &[f32],
    c0: &[f32],
    c1: &[f32],
    c2: &[f32],
    c3: &[f32],
) -> [f32; 4] {
    // real asserts: the tier kernels below do unchecked SIMD loads
    assert_eq!(x.len(), c0.len(), "dot4: row 0 length mismatch");
    assert_eq!(x.len(), c1.len(), "dot4: row 1 length mismatch");
    assert_eq!(x.len(), c2.len(), "dot4: row 2 length mismatch");
    assert_eq!(x.len(), c3.len(), "dot4: row 3 length mismatch");
    match t {
        Tier::Scalar => dot4_scalar(x, c0, c1, c2, c3),
        #[cfg(target_arch = "x86_64")]
        Tier::Sse2 => unsafe { dot4_sse2(x, c0, c1, c2, c3) },
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 => unsafe { dot4_avx2(x, c0, c1, c2, c3) },
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2Fma => unsafe { dot4_avx2fma(x, c0, c1, c2, c3) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { dot4_neon(x, c0, c1, c2, c3) },
        _ => dot4_scalar(x, c0, c1, c2, c3),
    }
}

/// The rank-2 / multi-point tile: dots of **two** points against the
/// same four centroid rows in one pass, so each centroid chunk is
/// loaded once instead of twice. `dot4x2_with(t, xa, xb, …)[0][j]` is
/// bit-identical to `dot_with(t, xa, c_j)` (and `[1][j]` to `xb`'s) for
/// every non-FMA tier: each of the eight dots owns its accumulators and
/// reduces through the shared lane tree. SSE2 composes two `dot4`
/// passes (16 independent 128-bit accumulators would spill the
/// register file); AVX2/FMA/NEON run true fused tiles.
#[inline]
pub fn dot4x2_with(
    t: Tier,
    xa: &[f32],
    xb: &[f32],
    c0: &[f32],
    c1: &[f32],
    c2: &[f32],
    c3: &[f32],
) -> [[f32; 4]; 2] {
    // real asserts: the tier kernels below do unchecked SIMD loads
    assert_eq!(xa.len(), xb.len(), "dot4x2: point length mismatch");
    assert_eq!(xa.len(), c0.len(), "dot4x2: row 0 length mismatch");
    assert_eq!(xa.len(), c1.len(), "dot4x2: row 1 length mismatch");
    assert_eq!(xa.len(), c2.len(), "dot4x2: row 2 length mismatch");
    assert_eq!(xa.len(), c3.len(), "dot4x2: row 3 length mismatch");
    match t {
        Tier::Scalar => dot4x2_scalar(xa, xb, c0, c1, c2, c3),
        #[cfg(target_arch = "x86_64")]
        Tier::Sse2 => unsafe {
            [dot4_sse2(xa, c0, c1, c2, c3), dot4_sse2(xb, c0, c1, c2, c3)]
        },
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 => unsafe { dot4x2_avx2(xa, xb, c0, c1, c2, c3) },
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2Fma => unsafe { dot4x2_avx2fma(xa, xb, c0, c1, c2, c3) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { dot4x2_neon(xa, xb, c0, c1, c2, c3) },
        _ => dot4x2_scalar(xa, xb, c0, c1, c2, c3),
    }
}

#[inline]
pub fn add_into_with(t: Tier, acc: &mut [f64], x: &[f32]) {
    // real assert: the tier kernels below do unchecked SIMD loads
    assert_eq!(acc.len(), x.len(), "add_into: length mismatch");
    match t {
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 | Tier::Avx2Fma => unsafe { add_into_avx2(acc, x) },
        #[cfg(target_arch = "x86_64")]
        Tier::Sse2 => unsafe { add_into_sse2(acc, x) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { add_into_neon(acc, x) },
        _ => add_into_scalar(acc, x),
    }
}

#[inline]
pub fn sub_from_with(t: Tier, acc: &mut [f64], x: &[f32]) {
    assert_eq!(acc.len(), x.len(), "sub_from: length mismatch");
    match t {
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 | Tier::Avx2Fma => unsafe { sub_from_avx2(acc, x) },
        #[cfg(target_arch = "x86_64")]
        Tier::Sse2 => unsafe { sub_from_sse2(acc, x) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { sub_from_neon(acc, x) },
        _ => sub_from_scalar(acc, x),
    }
}

/// Dot product through the active tier.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    dot_with(tier(), a, b)
}

/// ‖a‖² through the active tier.
#[inline]
pub fn sq_norm(a: &[f32]) -> f32 {
    dot_with(tier(), a, a)
}

/// Four-row block dot through the active tier.
#[inline]
pub fn dot4(x: &[f32], c0: &[f32], c1: &[f32], c2: &[f32], c3: &[f32]) -> [f32; 4] {
    dot4_with(tier(), x, c0, c1, c2, c3)
}

/// `acc += x` with f64 accumulation (sufficient-statistics path).
#[inline]
pub fn add_into(acc: &mut [f64], x: &[f32]) {
    add_into_with(tier(), acc, x)
}

/// `acc -= x` with f64 accumulation.
#[inline]
pub fn sub_from(acc: &mut [f64], x: &[f32]) {
    sub_from_with(tier(), acc, x)
}

// ---------------------------------------------------------------------
// point-blocked assignment micro-kernels
// ---------------------------------------------------------------------

/// Points handled per block by the assignment hot loop: a 4-centroid
/// strip (≤ 4·d floats) is re-used from L1 across this many points, so
/// centroid memory traffic drops by ~this factor versus per-point scans.
pub const POINT_BLOCK: usize = 8;

/// Nearest centroid for one point through an explicit tier; identical
/// scan order to [`nearest_block_with`], so blocked and per-point paths
/// agree bit-for-bit.
#[inline]
pub fn nearest_with(
    t: Tier,
    x: &[f32],
    xn: f32,
    c: &DenseMatrix,
    cnorms: &[f32],
) -> (u32, f32) {
    assert_eq!(x.len(), c.cols, "nearest: dimension mismatch");
    assert_eq!(c.rows, cnorms.len(), "nearest: norms length mismatch");
    let k = c.rows;
    let mut best_j = 0u32;
    let mut best = f32::INFINITY;
    let blocks = k / 4;
    for b in 0..blocks {
        let j = b * 4;
        let dots = dot4_with(t, x, c.row(j), c.row(j + 1), c.row(j + 2), c.row(j + 3));
        for (o, &dt) in dots.iter().enumerate() {
            let d2 = (xn + cnorms[j + o] - 2.0 * dt).max(0.0);
            if d2 < best {
                best = d2;
                best_j = (j + o) as u32;
            }
        }
    }
    for j in blocks * 4..k {
        let d2 = (xn + cnorms[j] - 2.0 * dot_with(t, x, c.row(j))).max(0.0);
        if d2 < best {
            best = d2;
            best_j = j as u32;
        }
    }
    (best_j, best)
}

/// Nearest centroid through the active tier.
#[inline]
pub fn nearest(x: &[f32], xn: f32, c: &DenseMatrix, cnorms: &[f32]) -> (u32, f32) {
    nearest_with(tier(), x, xn, c, cnorms)
}

/// Point-blocked nearest-centroid kernel: `rows` is a block of ≤
/// [`POINT_BLOCK`] point rows, and the centroid matrix is walked in
/// strips of four rows with the *point* loop innermost, so each strip
/// is streamed from memory once per block instead of once per point.
/// Per-point results are bit-identical to [`nearest_with`] on the same
/// tier (independent accumulators, same centroid scan order).
pub fn nearest_block_with(
    t: Tier,
    rows: &[&[f32]],
    xns: &[f32],
    c: &DenseMatrix,
    cnorms: &[f32],
    out_lbl: &mut [u32],
    out_d2: &mut [f32],
) {
    let p = rows.len();
    assert_eq!(xns.len(), p, "nearest_block: norms length mismatch");
    assert_eq!(out_lbl.len(), p, "nearest_block: label buffer mismatch");
    assert_eq!(out_d2.len(), p, "nearest_block: d2 buffer mismatch");
    assert_eq!(c.rows, cnorms.len(), "nearest_block: centroid norms mismatch");
    for r in rows {
        assert_eq!(r.len(), c.cols, "nearest_block: point dimension mismatch");
    }
    let k = c.rows;
    out_lbl.fill(0);
    out_d2.fill(f32::INFINITY);
    let blocks = k / 4;
    for b in 0..blocks {
        let j = b * 4;
        let (c0, c1, c2, c3) = (c.row(j), c.row(j + 1), c.row(j + 2), c.row(j + 3));
        // point-pair inner loop through the rank-2 tile: each centroid
        // chunk streams once per two points (per-dot results match the
        // single-point dot4 strip bit-for-bit on non-FMA tiers)
        let mut ti = 0;
        while ti + 2 <= p {
            let dd = dot4x2_with(t, rows[ti], rows[ti + 1], c0, c1, c2, c3);
            for (pi, dots) in dd.iter().enumerate() {
                let tt = ti + pi;
                for (o, &dt) in dots.iter().enumerate() {
                    let d2 = (xns[tt] + cnorms[j + o] - 2.0 * dt).max(0.0);
                    if d2 < out_d2[tt] {
                        out_d2[tt] = d2;
                        out_lbl[tt] = (j + o) as u32;
                    }
                }
            }
            ti += 2;
        }
        if ti < p {
            let dots = dot4_with(t, rows[ti], c0, c1, c2, c3);
            for (o, &dt) in dots.iter().enumerate() {
                let d2 = (xns[ti] + cnorms[j + o] - 2.0 * dt).max(0.0);
                if d2 < out_d2[ti] {
                    out_d2[ti] = d2;
                    out_lbl[ti] = (j + o) as u32;
                }
            }
        }
    }
    for j in blocks * 4..k {
        let cj = c.row(j);
        for ti in 0..p {
            let d2 = (xns[ti] + cnorms[j] - 2.0 * dot_with(t, rows[ti], cj)).max(0.0);
            if d2 < out_d2[ti] {
                out_d2[ti] = d2;
                out_lbl[ti] = j as u32;
            }
        }
    }
}

/// Point-blocked full distance rows: `out[t*k + j] = ‖x_t − c_j‖²`
/// via the norms trick, same centroid-strip tiling as
/// [`nearest_block_with`]. `out` must hold `rows.len() * k` floats.
pub fn dist_rows_block_with(
    t: Tier,
    rows: &[&[f32]],
    xns: &[f32],
    c: &DenseMatrix,
    cnorms: &[f32],
    out: &mut [f32],
) {
    let p = rows.len();
    let k = c.rows;
    assert_eq!(xns.len(), p, "dist_rows_block: norms length mismatch");
    assert_eq!(out.len(), p * k, "dist_rows_block: output buffer mismatch");
    assert_eq!(cnorms.len(), k, "dist_rows_block: centroid norms mismatch");
    for r in rows {
        assert_eq!(r.len(), c.cols, "dist_rows_block: point dimension mismatch");
    }
    let blocks = k / 4;
    for b in 0..blocks {
        let j = b * 4;
        let (c0, c1, c2, c3) = (c.row(j), c.row(j + 1), c.row(j + 2), c.row(j + 3));
        // same point-pair rank-2 tile as `nearest_block_with`
        let mut ti = 0;
        while ti + 2 <= p {
            let dd = dot4x2_with(t, rows[ti], rows[ti + 1], c0, c1, c2, c3);
            for (pi, dots) in dd.iter().enumerate() {
                let tt = ti + pi;
                let orow = &mut out[tt * k..(tt + 1) * k];
                for (o, &dt) in dots.iter().enumerate() {
                    orow[j + o] = (xns[tt] + cnorms[j + o] - 2.0 * dt).max(0.0);
                }
            }
            ti += 2;
        }
        if ti < p {
            let dots = dot4_with(t, rows[ti], c0, c1, c2, c3);
            let orow = &mut out[ti * k..(ti + 1) * k];
            for (o, &dt) in dots.iter().enumerate() {
                orow[j + o] = (xns[ti] + cnorms[j + o] - 2.0 * dt).max(0.0);
            }
        }
    }
    for j in blocks * 4..k {
        let cj = c.row(j);
        for ti in 0..p {
            out[ti * k + j] =
                (xns[ti] + cnorms[j] - 2.0 * dot_with(t, rows[ti], cj)).max(0.0);
        }
    }
}

/// [`nearest_block_with`] through the active tier.
#[inline]
pub fn nearest_block(
    rows: &[&[f32]],
    xns: &[f32],
    c: &DenseMatrix,
    cnorms: &[f32],
    out_lbl: &mut [u32],
    out_d2: &mut [f32],
) {
    nearest_block_with(tier(), rows, xns, c, cnorms, out_lbl, out_d2)
}

/// [`dist_rows_block_with`] through the active tier.
#[inline]
pub fn dist_rows_block(
    rows: &[&[f32]],
    xns: &[f32],
    c: &DenseMatrix,
    cnorms: &[f32],
    out: &mut [f32],
) {
    dist_rows_block_with(tier(), rows, xns, c, cnorms, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{gen, Cases};

    fn exact_tiers() -> Vec<Tier> {
        available_tiers()
            .into_iter()
            .filter(|&t| t != Tier::Avx2Fma)
            .collect()
    }

    #[test]
    fn scalar_tier_always_available() {
        let avail = available_tiers();
        assert!(avail.contains(&Tier::Scalar));
        assert!(avail.contains(&tier()), "active tier must be executable");
    }

    #[test]
    fn detect_honors_overrides() {
        assert_eq!(detect(Some("scalar"), None), Tier::Scalar);
        assert_eq!(detect(Some(" SCALAR "), Some("1")), Tier::Scalar);
        // garbage falls back to auto detection, which must be executable
        assert!(available_tiers().contains(&detect(Some("not-a-tier"), None)));
        let auto = detect(None, None);
        assert!(available_tiers().contains(&auto));
        assert_ne!(auto, Tier::Avx2Fma, "FMA must stay opt-in");
        if available_tiers().contains(&Tier::Avx2Fma) {
            assert_eq!(detect(None, Some("1")), Tier::Avx2Fma);
            assert_eq!(detect(Some("fma"), None), Tier::Avx2Fma);
        }
    }

    #[test]
    fn dot_bit_identical_across_tiers() {
        Cases::new(200).run(|rng| {
            let n = rng.below(300);
            let a = gen::matrix(rng, 1, n);
            let b = gen::matrix(rng, 1, n);
            let reference = dot_scalar(&a, &b);
            for t in exact_tiers() {
                let got = dot_with(t, &a, &b);
                assert_eq!(
                    got.to_bits(),
                    reference.to_bits(),
                    "tier {} n={n}: {got} != {reference}",
                    t.name()
                );
            }
        });
    }

    #[test]
    fn sq_norm_bit_identical_across_tiers() {
        Cases::new(100).run(|rng| {
            let n = rng.below(200);
            let a = gen::matrix(rng, 1, n);
            let reference = dot_scalar(&a, &a);
            for t in exact_tiers() {
                assert_eq!(dot_with(t, &a, &a).to_bits(), reference.to_bits());
            }
            assert_eq!(sq_norm(&a).to_bits(), dot(&a, &a).to_bits());
        });
    }

    #[test]
    fn dot4_matches_naive_dots() {
        // satellite: dot4 property-tested directly against naive dots
        Cases::new(150).run(|rng| {
            let n = rng.below(260);
            let x = gen::matrix(rng, 1, n);
            let c = gen::matrix(rng, 4, n);
            let rows: Vec<&[f32]> = (0..4).map(|j| &c[j * n..(j + 1) * n]).collect();
            let naive: Vec<f32> = rows
                .iter()
                .map(|r| r.iter().zip(&x).map(|(a, b)| a * b).sum())
                .collect();
            let got = dot4(&x, rows[0], rows[1], rows[2], rows[3]);
            for j in 0..4 {
                assert!(
                    (got[j] - naive[j]).abs() <= 1e-3 * (1.0 + naive[j].abs()),
                    "n={n} lane {j}: {} vs naive {}",
                    got[j],
                    naive[j]
                );
            }
        });
    }

    #[test]
    fn dot4_lanes_bit_identical_to_dot_per_tier() {
        // the invariant the engine-parity guarantees rest on:
        // dot4(x, c0..c3)[j] == dot(x, c_j) bitwise on every exact tier
        Cases::new(150).run(|rng| {
            let n = rng.below(260);
            let x = gen::matrix(rng, 1, n);
            let c = gen::matrix(rng, 4, n);
            let rows: Vec<&[f32]> = (0..4).map(|j| &c[j * n..(j + 1) * n]).collect();
            for t in exact_tiers() {
                let block = dot4_with(t, &x, rows[0], rows[1], rows[2], rows[3]);
                for j in 0..4 {
                    assert_eq!(
                        block[j].to_bits(),
                        dot_with(t, &x, rows[j]).to_bits(),
                        "tier {} lane {j} n={n}",
                        t.name()
                    );
                }
                // and every tier agrees with the scalar reference
                for j in 0..4 {
                    assert_eq!(
                        block[j].to_bits(),
                        dot_scalar(&x, rows[j]).to_bits(),
                        "tier {} vs scalar, lane {j} n={n}",
                        t.name()
                    );
                }
            }
        });
    }

    #[test]
    fn dot4x2_lanes_bit_identical_to_dot_per_tier() {
        // the rank-2 tile: both points' four dots must reproduce the
        // single-dot (and the existing dot4 strip) bits on every exact
        // tier — this is what lets the blocked kernels pair points
        // without perturbing assignment results
        Cases::new(150).run(|rng| {
            let n = rng.below(260);
            let xa = gen::matrix(rng, 1, n);
            let xb = gen::matrix(rng, 1, n);
            let c = gen::matrix(rng, 4, n);
            let rows: Vec<&[f32]> = (0..4).map(|j| &c[j * n..(j + 1) * n]).collect();
            for t in exact_tiers() {
                let tile =
                    dot4x2_with(t, &xa, &xb, rows[0], rows[1], rows[2], rows[3]);
                let strip_a = dot4_with(t, &xa, rows[0], rows[1], rows[2], rows[3]);
                let strip_b = dot4_with(t, &xb, rows[0], rows[1], rows[2], rows[3]);
                for j in 0..4 {
                    assert_eq!(
                        tile[0][j].to_bits(),
                        dot_with(t, &xa, rows[j]).to_bits(),
                        "tier {} point a lane {j} n={n}",
                        t.name()
                    );
                    assert_eq!(
                        tile[1][j].to_bits(),
                        dot_with(t, &xb, rows[j]).to_bits(),
                        "tier {} point b lane {j} n={n}",
                        t.name()
                    );
                    assert_eq!(tile[0][j].to_bits(), strip_a[j].to_bits());
                    assert_eq!(tile[1][j].to_bits(), strip_b[j].to_bits());
                    assert_eq!(
                        tile[0][j].to_bits(),
                        dot_scalar(&xa, rows[j]).to_bits(),
                        "tier {} vs scalar, point a lane {j} n={n}",
                        t.name()
                    );
                }
            }
        });
    }

    #[test]
    fn dot4x2_tail_lengths_every_tier() {
        // lengths 0..=17 force the 8-wide chunk loop plus every tail
        // shape through each tier's cleanup path
        for n in 0..=17usize {
            let xa: Vec<f32> = (0..n).map(|i| (i as f32) * 0.75 - 2.0).collect();
            let xb: Vec<f32> = (0..n).map(|i| 1.5 - (i as f32) * 0.5).collect();
            let c: Vec<f32> = (0..4 * n).map(|i| (i as f32) * 0.3 - 5.0).collect();
            let rows: Vec<&[f32]> = (0..4).map(|j| &c[j * n..(j + 1) * n]).collect();
            for t in exact_tiers() {
                let tile =
                    dot4x2_with(t, &xa, &xb, rows[0], rows[1], rows[2], rows[3]);
                for j in 0..4 {
                    assert_eq!(
                        tile[0][j].to_bits(),
                        dot_scalar(&xa, rows[j]).to_bits(),
                        "tier {} n={n} a lane {j}",
                        t.name()
                    );
                    assert_eq!(
                        tile[1][j].to_bits(),
                        dot_scalar(&xb, rows[j]).to_bits(),
                        "tier {} n={n} b lane {j}",
                        t.name()
                    );
                }
            }
        }
    }

    #[test]
    fn dot4x2_fma_tier_close_to_scalar() {
        if !available_tiers().contains(&Tier::Avx2Fma) {
            return;
        }
        Cases::new(60).run(|rng| {
            let n = rng.below(300);
            let xa = gen::matrix(rng, 1, n);
            let xb = gen::matrix(rng, 1, n);
            let c = gen::matrix(rng, 4, n);
            let rows: Vec<&[f32]> = (0..4).map(|j| &c[j * n..(j + 1) * n]).collect();
            let tile = dot4x2_with(
                Tier::Avx2Fma,
                &xa,
                &xb,
                rows[0],
                rows[1],
                rows[2],
                rows[3],
            );
            for j in 0..4 {
                for (x, got) in [(&xa, tile[0][j]), (&xb, tile[1][j])] {
                    let sc = dot_scalar(x, rows[j]);
                    let mag: f32 =
                        x.iter().zip(rows[j]).map(|(a, b)| (a * b).abs()).sum();
                    assert!(
                        (sc - got).abs() <= 1e-4 * (1.0 + mag),
                        "n={n} lane {j}: scalar {sc} vs fma {got}"
                    );
                }
            }
        });
    }

    #[test]
    fn fma_tier_close_to_scalar() {
        if !available_tiers().contains(&Tier::Avx2Fma) {
            return;
        }
        Cases::new(80).run(|rng| {
            let n = rng.below(300);
            let a = gen::matrix(rng, 1, n);
            let b = gen::matrix(rng, 1, n);
            let sc = dot_scalar(&a, &b);
            let fm = dot_with(Tier::Avx2Fma, &a, &b);
            let mag: f32 = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum();
            assert!(
                (sc - fm).abs() <= 1e-4 * (1.0 + mag),
                "n={n}: scalar {sc} vs fma {fm}"
            );
        });
    }

    #[test]
    fn add_sub_bit_identical_across_tiers() {
        // covers the explicit SSE2/NEON kernels (previously scalar
        // fallbacks) alongside AVX2: every tier, bit-for-bit
        Cases::new(150).run(|rng| {
            let n = rng.below(400);
            let x = gen::matrix(rng, 1, n);
            let init: Vec<f64> = (0..n).map(|i| (i as f64) * 0.25 - 3.0).collect();
            let mut reference = init.clone();
            add_into_scalar(&mut reference, &x);
            for t in available_tiers() {
                let mut acc = init.clone();
                add_into_with(t, &mut acc, &x);
                let bits = |v: &[f64]| {
                    v.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
                };
                assert_eq!(bits(&acc), bits(&reference), "add tier {}", t.name());
                sub_from_with(t, &mut acc, &x);
                assert_eq!(bits(&acc), bits(&init), "sub tier {}", t.name());
            }
        });
    }

    #[test]
    fn add_sub_tail_lengths_every_tier() {
        // the SIMD kernels step four lanes; lengths 0..=9 force every
        // tail shape through each tier's cleanup loop
        for n in 0..=9usize {
            let x: Vec<f32> = (0..n).map(|i| (i as f32) * 1.5 - 2.0).collect();
            let init: Vec<f64> = (0..n).map(|i| (i as f64) * -0.5).collect();
            let mut reference = init.clone();
            add_into_scalar(&mut reference, &x);
            for t in available_tiers() {
                let mut acc = init.clone();
                add_into_with(t, &mut acc, &x);
                assert_eq!(acc, reference, "add n={n} tier {}", t.name());
                sub_from_with(t, &mut acc, &x);
                assert_eq!(acc, init, "sub n={n} tier {}", t.name());
            }
        }
    }

    #[test]
    fn axpy_bit_identical_across_tiers() {
        // the sparse k-strided kernel: every non-FMA tier must match the
        // scalar reference bit-for-bit, including k % 8 != 0 tails
        Cases::new(150).run(|rng| {
            let k = rng.below(130);
            let v = rng.gauss_f32();
            let row = gen::matrix(rng, 1, k);
            let init = gen::matrix(rng, 1, k);
            let mut reference = init.clone();
            axpy_scalar(v, &row, &mut reference);
            for t in exact_tiers() {
                let mut acc = init.clone();
                axpy_with(t, v, &row, &mut acc);
                let bits =
                    |x: &[f32]| x.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&acc), bits(&reference), "axpy tier {}", t.name());
            }
        });
    }

    #[test]
    fn axpy2_equals_two_sequential_axpys_per_tier() {
        // the paired kernel folds two non-zeros into one accumulator
        // pass; per lane it must perform the same two rounded adds
        Cases::new(150).run(|rng| {
            let k = rng.below(130);
            let (v0, v1) = (rng.gauss_f32(), rng.gauss_f32());
            let r0 = gen::matrix(rng, 1, k);
            let r1 = gen::matrix(rng, 1, k);
            let init = gen::matrix(rng, 1, k);
            let mut reference = init.clone();
            axpy_scalar(v0, &r0, &mut reference);
            axpy_scalar(v1, &r1, &mut reference);
            let bits = |x: &[f32]| x.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            for t in exact_tiers() {
                let mut acc = init.clone();
                axpy2_with(t, v0, &r0, v1, &r1, &mut acc);
                assert_eq!(bits(&acc), bits(&reference), "axpy2 tier {}", t.name());
            }
        });
    }

    #[test]
    fn axpy_tail_lengths_every_tier() {
        // lengths 0..=17 force the empty, sub-chunk and tail shapes
        // through every tier's cleanup loop
        for k in 0..=17usize {
            let row: Vec<f32> = (0..k).map(|i| (i as f32) * 0.75 - 2.0).collect();
            let r1: Vec<f32> = (0..k).map(|i| 1.5 - (i as f32) * 0.25).collect();
            let init: Vec<f32> = (0..k).map(|i| (i as f32) * -0.5 + 0.125).collect();
            let mut reference = init.clone();
            axpy_scalar(0.7, &row, &mut reference);
            let mut ref2 = init.clone();
            axpy2_scalar(0.7, &row, -1.3, &r1, &mut ref2);
            for t in exact_tiers() {
                let mut acc = init.clone();
                axpy_with(t, 0.7, &row, &mut acc);
                assert_eq!(acc, reference, "axpy k={k} tier {}", t.name());
                let mut acc2 = init.clone();
                axpy2_with(t, 0.7, &row, -1.3, &r1, &mut acc2);
                assert_eq!(acc2, ref2, "axpy2 k={k} tier {}", t.name());
            }
        }
    }

    #[test]
    fn axpy_fma_tier_close_to_scalar() {
        if !available_tiers().contains(&Tier::Avx2Fma) {
            return;
        }
        Cases::new(60).run(|rng| {
            let k = rng.below(200);
            let v = rng.gauss_f32();
            let row = gen::matrix(rng, 1, k);
            let init = gen::matrix(rng, 1, k);
            let mut sc = init.clone();
            axpy_scalar(v, &row, &mut sc);
            let mut fm = init.clone();
            axpy_with(Tier::Avx2Fma, v, &row, &mut fm);
            for j in 0..k {
                assert!(
                    (sc[j] - fm[j]).abs()
                        <= 1e-5 * (1.0 + sc[j].abs() + (v * row[j]).abs()),
                    "k={k} lane {j}: scalar {} vs fma {}",
                    sc[j],
                    fm[j]
                );
            }
        });
    }

    #[test]
    fn nearest_block_bit_identical_to_per_point_scalar() {
        Cases::new(80).run(|rng| {
            let (_, d, k) = gen::shape(rng, 1, 60, 14);
            let p = rng.below(POINT_BLOCK) + 1;
            let c = DenseMatrix::from_vec(k, d, gen::matrix(rng, k, d));
            let cn = c.row_sq_norms();
            let xs = gen::matrix(rng, p, d);
            let rows: Vec<&[f32]> = (0..p).map(|i| &xs[i * d..(i + 1) * d]).collect();
            let xns: Vec<f32> = rows.iter().map(|r| dot_scalar(r, r)).collect();
            let mut ref_lbl = vec![0u32; p];
            let mut ref_d2 = vec![0f32; p];
            for i in 0..p {
                let (j, d2) = nearest_with(Tier::Scalar, rows[i], xns[i], &c, &cn);
                ref_lbl[i] = j;
                ref_d2[i] = d2;
            }
            for t in exact_tiers() {
                let mut lbl = vec![9u32; p];
                let mut d2 = vec![0f32; p];
                nearest_block_with(t, &rows, &xns, &c, &cn, &mut lbl, &mut d2);
                assert_eq!(lbl, ref_lbl, "labels, tier {}", t.name());
                for i in 0..p {
                    assert_eq!(
                        d2[i].to_bits(),
                        ref_d2[i].to_bits(),
                        "d2[{i}], tier {}",
                        t.name()
                    );
                }
            }
        });
    }

    #[test]
    fn dist_rows_block_matches_norms_formula() {
        Cases::new(60).run(|rng| {
            let (_, d, k) = gen::shape(rng, 1, 50, 11);
            let p = rng.below(POINT_BLOCK) + 1;
            let c = DenseMatrix::from_vec(k, d, gen::matrix(rng, k, d));
            let cn = c.row_sq_norms();
            let xs = gen::matrix(rng, p, d);
            let rows: Vec<&[f32]> = (0..p).map(|i| &xs[i * d..(i + 1) * d]).collect();
            let xns: Vec<f32> = rows.iter().map(|r| dot_scalar(r, r)).collect();
            for t in exact_tiers() {
                let mut out = vec![0f32; p * k];
                dist_rows_block_with(t, &rows, &xns, &c, &cn, &mut out);
                for i in 0..p {
                    for j in 0..k {
                        let e = (xns[i] + cn[j]
                            - 2.0 * dot_scalar(rows[i], c.row(j)))
                        .max(0.0);
                        assert_eq!(
                            out[i * k + j].to_bits(),
                            e.to_bits(),
                            "({i},{j}) tier {}",
                            t.name()
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn empty_inputs_are_zero() {
        assert_eq!(dot(&[], &[]), 0.0);
        for t in available_tiers() {
            assert_eq!(dot_with(t, &[], &[]), 0.0);
            assert_eq!(dot4_with(t, &[], &[], &[], &[]), [0.0; 4]);
        }
    }
}
