//! Bench F1 — regenerates the paper's Figure 1 (validation MSE relative
//! to best V0, versus work time, for lloyd / mb / mb-f / gb-∞ / tb-∞ on
//! infMNIST and RCV1).
//!
//! Expected shape (paper §4.3.2): mb-f overtakes mb after ~one data
//! pass; gb-∞ is favourable vs mb-f; tb-∞ dominates and reaches
//! lloyd-quality minima far sooner than lloyd. CSV series land in
//! artifacts/results/fig1_{infmnist,rcv1}.csv.

use nmbkm::experiments::{common::ExpOpts, fig1};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = ExpOpts::from_args(&args);
    println!(
        "[fig1] scale={:?} seeds={} budget={}s/run",
        opts.scale, opts.seeds, opts.seconds
    );
    fig1::run(&opts).expect("fig1 failed");
}
